"""Unreliable-edge subsystem tests (DESIGN.md §10): fault processes,
retry/backoff accounting, masked aggregation, failure-aware scheduling,
driver parity under faults, and faulty-sweep kill/resume."""

import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import msgpack_ckpt
from repro.core import compression, faults, federated, scheduler, wireless
from repro.data import partition, synthetic
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref
from repro.models import paper_nets
from repro.sweep import engine as engine_lib
from repro.sweep import grid as grid_lib
from repro.sweep import runner as runner_lib


# ---------------------------------------------------------------------------
# Fixtures: one tiny world shared module-wide (compiles dominate runtime)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def world():
    imgs, labs = synthetic.generate(0, samples_per_class=200)
    data = partition.partition(
        imgs, labs, seed=1,
        spec=partition.PartitionSpec(num_devices=8, num_shards=36,
                                     shard_size=50))
    mspec = paper_nets.PaperNetSpec(kind="mlp", mlp_hidden=8)
    params = paper_nets.init(jax.random.key(3), mspec)
    loss = functools.partial(paper_nets.loss_fn, spec=mspec)
    ev = functools.partial(paper_nets.accuracy, spec=mspec)
    return data, params, loss, ev


WCFG = wireless.WirelessConfig()
SCFG = scheduler.SchedulerConfig(method="das", n_min=2, iterations_max=3,
                                 reliability_weight=0.4)
FL = federated.FLConfig(num_rounds=3, batch_size=50, learning_rate=0.1)
# Every fault channel live at once: drops, retries, stragglers,
# dropouts, a moving reliability EMA and an overprovisioned floor.
FULL_FAULTS = faults.FaultConfig(
    drop_prob=0.35, max_retries=2, backoff_base=0.5, straggler_prob=0.3,
    straggler_scale=3.0, dropout_prob=0.1, reliability_ema=0.3,
    overprovision=1)


def _run_kwargs(world):
    data, params, loss, ev = world
    net = wireless.sample_network(jax.random.key(0), data.num_devices,
                                  WCFG)
    return dict(init_params=params, loss_fn=loss, eval_fn=ev, data=data,
                net=net, wcfg=WCFG, scfg=SCFG, key=jax.random.key(42))


def _same_tree(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _assert_history_equal(ha, hb):
    for a, b in zip(ha, hb):
        assert a.accuracy == b.accuracy
        assert a.round_time == b.round_time
        assert a.energy_total == b.energy_total
        assert a.n_selected == b.n_selected
        assert a.n_success == b.n_success
        assert np.array_equal(a.selected, b.selected)


# ---------------------------------------------------------------------------
# Config semantics: inert normalization, closed-form retry pricing
# ---------------------------------------------------------------------------

def test_inert_detection_and_normalization():
    assert faults.is_inert(faults.FaultConfig())
    # Retry/backoff/straggler-shape knobs are irrelevant with zero
    # probabilities — still inert.
    assert faults.is_inert(faults.FaultConfig(max_retries=5,
                                              backoff_base=2.0,
                                              straggler_scale=100.0))
    for live in (dict(drop_prob=0.1), dict(deep_fade_threshold=0.5),
                 dict(straggler_prob=0.1), dict(dropout_prob=0.1),
                 dict(overprovision=1), dict(reliability_ema=0.2)):
        assert not faults.is_inert(faults.FaultConfig(**live))
    assert faults.active(None) is None
    assert faults.active(faults.FaultConfig()) is None
    cfg = faults.FaultConfig(drop_prob=0.1)
    assert faults.active(cfg) is cfg


def test_expected_time_mult_closed_form():
    assert faults.expected_time_mult(faults.FaultConfig()) == 1.0
    assert faults.expected_time_mult(
        faults.FaultConfig(max_retries=4)) == 1.0     # q = 0
    # drop=0.5, one retry, backoff 0.5: P(1)=0.5 at mult 1,
    # P(2)=0.5 at mult 2 + 0.5*(2^1 - 1) = 2.5 -> E = 1.75.
    cfg = faults.FaultConfig(drop_prob=0.5, max_retries=1,
                             backoff_base=0.5)
    assert faults.expected_time_mult(cfg) == pytest.approx(1.75)
    # Monotone in drop probability and in the retry budget.
    mults_q = [faults.expected_time_mult(
        faults.FaultConfig(drop_prob=q, max_retries=2))
        for q in (0.1, 0.3, 0.5, 0.8)]
    assert all(a < b for a, b in zip(mults_q, mults_q[1:]))
    mults_r = [faults.expected_time_mult(
        faults.FaultConfig(drop_prob=0.5, max_retries=r))
        for r in (0, 1, 2, 4)]
    assert mults_r[0] == 1.0
    assert all(a < b for a, b in zip(mults_r, mults_r[1:]))


def test_time_mult_retry_geometry():
    cfg = faults.FaultConfig(max_retries=3, backoff_base=0.5)
    n = jnp.asarray([0.0, 1.0, 2.0, 3.0, 4.0])
    got = np.asarray(faults.time_mult(n, cfg))
    # n attempts + backoff_base * (2^(n-1) - 1) waits; dropout spends 0.
    np.testing.assert_allclose(got, [0.0, 1.0, 2.5, 4.5, 7.5])


def test_sample_faults_distribution_edges():
    net = wireless.sample_network(jax.random.key(0), 64, WCFG)
    gains = wireless.sample_fading(jax.random.key(1), net)
    key = jax.random.key(2)
    # No fault channel live: every upload lands on attempt 1.
    d = faults.sample_faults(key, gains, net,
                             faults.FaultConfig(max_retries=3))
    assert np.all(np.asarray(d.success) == 1.0)
    assert np.all(np.asarray(d.attempts) == 1.0)
    assert np.all(np.asarray(d.compute_mult) == 1.0)
    # Certain drop: nobody succeeds, everyone burns the whole budget.
    d = faults.sample_faults(key, gains, net,
                             faults.FaultConfig(drop_prob=1.0,
                                                max_retries=2))
    assert np.all(np.asarray(d.success) == 0.0)
    assert np.all(np.asarray(d.attempts) == 3.0)
    # Certain dropout: zero attempts regardless of the channel.
    d = faults.sample_faults(key, gains, net,
                             faults.FaultConfig(dropout_prob=1.0))
    assert np.all(np.asarray(d.success) == 0.0)
    assert np.all(np.asarray(d.attempts) == 0.0)
    # Deep fade above every |h|^2: block fading kills all attempts.
    d = faults.sample_faults(key, gains, net,
                             faults.FaultConfig(deep_fade_threshold=1e30,
                                                max_retries=1))
    assert np.all(np.asarray(d.success) == 0.0)
    # Stragglers stretch compute by at least the scale floor.
    d = faults.sample_faults(key, gains, net,
                             faults.FaultConfig(straggler_prob=1.0,
                                                straggler_scale=4.0))
    assert np.all(np.asarray(d.compute_mult) >= 4.0)


def test_apply_faults_retry_accounting():
    """Energy charges attempts; airtime stretches by the backoff sum; a
    failed device still holds the round open."""
    k = 4
    net = wireless.sample_network(jax.random.key(0), k, WCFG)
    gains = wireless.sample_fading(jax.random.key(1), net)
    selected = jnp.ones((k,))
    alpha = jnp.full((k,), 1.0 / k)
    t_train = jnp.full((k,), 0.1)
    cfg = faults.FaultConfig(drop_prob=0.5, max_retries=2,
                             backoff_base=0.5)
    base = faults.FaultDraw(success=jnp.ones((k,)),
                            attempts=jnp.ones((k,)),
                            compute_mult=jnp.ones((k,)))
    _, e1, t1 = faults.apply_faults(base, selected, alpha, t_train, gains,
                                    net, WCFG, None, cfg)
    tripled = faults.FaultDraw(success=jnp.zeros((k,)),
                               attempts=jnp.full((k,), 3.0),
                               compute_mult=jnp.ones((k,)))
    ok, e3, t3 = faults.apply_faults(tripled, selected, alpha, t_train,
                                     gains, net, WCFG, None, cfg)
    assert np.all(np.asarray(ok) == 0.0)
    np.testing.assert_allclose(np.asarray(e3), 3.0 * np.asarray(e1),
                               rtol=1e-6)
    assert float(t3) > float(t1)        # retries hold the round open
    dropout = faults.FaultDraw(success=jnp.zeros((k,)),
                               attempts=jnp.zeros((k,)),
                               compute_mult=jnp.ones((k,)))
    _, e0, t0 = faults.apply_faults(dropout, selected, alpha, t_train,
                                    gains, net, WCFG, None, cfg)
    assert np.all(np.asarray(e0) == 0.0)    # dead radio spends nothing
    np.testing.assert_allclose(float(t0), 0.1)  # compute still waits


def test_reliability_update_and_discount():
    rel = jnp.ones((4,), jnp.float32)
    sel = jnp.asarray([1.0, 1.0, 0.0, 0.0])
    ok = jnp.asarray([1.0, 0.0, 0.0, 0.0])
    frozen = faults.reliability_update(rel, sel, ok,
                                       faults.FaultConfig())
    assert frozen is rel                     # beta = 0: carry untouched
    upd = np.asarray(faults.reliability_update(
        rel, sel, ok, faults.FaultConfig(reliability_ema=0.25)))
    np.testing.assert_allclose(upd, [1.0, 0.75, 1.0, 1.0])
    # Discount hook: identity with no signal or zero weight; a failing
    # device shrinks toward (1 - w) of nominal, a reliable one is
    # untouched at any weight.
    pri = jnp.asarray([2.0, 2.0, 2.0, 2.0])
    sch = scheduler.SchedulerConfig(reliability_weight=0.0)
    assert scheduler.reliability_discount(pri, jnp.asarray(upd),
                                          sch) is pri
    assert scheduler.reliability_discount(pri, None, SCFG) is pri
    got = np.asarray(scheduler.reliability_discount(
        pri, jnp.asarray([1.0, 0.0, 0.5, 1.0]),
        scheduler.SchedulerConfig(reliability_weight=0.5)))
    np.testing.assert_allclose(got, [2.0, 1.0, 1.5, 2.0])


def test_sched_cfg_overprovision_bumps_floors():
    base = scheduler.SchedulerConfig(n_min=2, n_fixed=3)
    fl = dataclasses.replace(
        FL, faults=faults.FaultConfig(drop_prob=0.2, overprovision=2))
    sch = federated._sched_cfg(base, fl)
    assert sch.n_min == 4 and sch.n_fixed == 5
    # No faults (or inert config): floors untouched.
    assert federated._sched_cfg(base, FL).n_min == 2
    assert federated._sched_cfg(base, FL).n_fixed == 3


# ---------------------------------------------------------------------------
# Masked FedAvg: kernel oracle + all-success and all-fail properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,p", [(1, 128), (7, 1000), (16, 4096)])
def test_fedavg_agg_masked_kernel_matches_ref(k, p):
    u = jax.random.normal(jax.random.key(k * 100 + p), (k, p))
    w = jax.nn.softmax(jax.random.normal(jax.random.key(1), (k,)))
    m = (jax.random.uniform(jax.random.key(2), (k,)) > 0.4
         ).astype(jnp.float32)
    got = kernel_ops.fedavg_agg_masked(u, w, m)
    want = kernel_ref.fedavg_agg_masked(u, w, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fedavg_agg_masked_all_success_bitwise_equals_unmasked():
    """The masked lane with an all-ones mask IS the unmasked kernel:
    w * 1.0 == w in f32, no renormalization inside the kernel."""
    u = jax.random.normal(jax.random.key(5), (9, 1536))
    w = jax.nn.softmax(jax.random.normal(jax.random.key(6), (9,)))
    ones = jnp.ones((9,), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(kernel_ops.fedavg_agg_masked(u, w, ones)),
        np.asarray(kernel_ops.fedavg_agg(u, w)))
    np.testing.assert_array_equal(
        np.asarray(kernel_ref.fedavg_agg_masked(u, w, ones)),
        np.asarray(kernel_ref.fedavg_agg(u, w)))


def test_fedavg_aggregate_masked_all_fail_carries_params(world):
    """Update form: all-zero masked weights leave the global model
    bitwise unchanged — the no-branch graceful-degradation guarantee."""
    _, params, _, _ = world
    k = 5
    client = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (k,) + p.shape) + 1.0, params)
    w = jnp.full((k,), 0.2)
    out = federated.fedavg_aggregate_masked(params, client, w,
                                            jnp.zeros((k,)))
    assert _same_tree(out, params)


def test_apply_codec_failed_upload_folds_back_losslessly():
    """A scheduled-but-failed device's raw update lands in the residual
    bit for bit (r' = r + u): the air lost the payload, error feedback
    did not."""
    ccfg = compression.CompressionConfig(codec="quant", bit_width=4,
                                         error_feedback=True)
    codec = compression.get_codec("quant")
    k, p = 4, 64
    u = jax.random.normal(jax.random.key(0), (k, p))
    r = 0.3 * jax.random.normal(jax.random.key(1), (k, p))
    gains = jnp.ones((k,))
    index = jnp.ones((k,))
    selected = jnp.asarray([1.0, 1.0, 1.0, 0.0])
    success = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    c, res = compression.apply_codec(codec, u, r, selected,
                                     jax.random.key(2), ccfg, gains,
                                     index, success=success)
    # Device 1 (selected, failed): entire update folded back.
    np.testing.assert_array_equal(np.asarray(res[1]),
                                  np.asarray(r[1] + u[1]))
    # Device 3 (never scheduled): residual untouched.
    np.testing.assert_array_equal(np.asarray(res[3]), np.asarray(r[3]))
    # Delivered devices match the failure-blind path with the success
    # set as the transmitted set.
    c_ref, res_ref = compression.apply_codec(
        codec, u, r, selected * success, jax.random.key(2), ccfg, gains,
        index)
    np.testing.assert_array_equal(np.asarray(c[0]), np.asarray(c_ref[0]))
    np.testing.assert_array_equal(np.asarray(res[0]),
                                  np.asarray(res_ref[0]))
    # error_feedback=False: the fold-back is gated off with the rest of
    # the residual machinery.
    _, res_off = compression.apply_codec(
        codec, u, r, selected, jax.random.key(2),
        dataclasses.replace(ccfg, error_feedback=False), gains, index,
        success=success)
    assert np.all(np.asarray(res_off) == 0.0)


def test_empty_selection_carries_model(world):
    """Satellite fix: an empty admitted set returns the carried model
    (0 participants), not a 0/0 aggregate."""
    data, params, loss, _ = world
    round_fn = federated.make_round_fn(loss, FL, data.capacity)
    none_sel = jnp.zeros((data.num_devices,))
    out = round_fn(params, data.images, data.labels, data.mask,
                   data.sizes, none_sel, jax.random.key(0))
    assert _same_tree(out, params)
    leaves = [np.asarray(x) for x in jax.tree_util.tree_leaves(out)]
    assert all(np.isfinite(l).all() for l in leaves)


# ---------------------------------------------------------------------------
# Driver parity under faults (the DESIGN.md §3 contracts extended)
# ---------------------------------------------------------------------------

def test_inert_fault_config_bitwise_identical_to_none(world):
    kw = _run_kwargs(world)
    p0, h0 = federated.run_federated(fcfg=FL, **kw)
    p1, h1 = federated.run_federated(
        fcfg=dataclasses.replace(FL, faults=faults.FaultConfig()), **kw)
    assert _same_tree(p0, p1)
    _assert_history_equal(h0, h1)
    # Reliable edge: every admitted upload lands.
    assert all(r.n_success == r.n_selected for r in h0)


def test_scan_matches_loop_under_faults(world):
    kw = _run_kwargs(world)
    fl = dataclasses.replace(FL, faults=FULL_FAULTS)
    p_scan, h_scan = federated.run_federated(fcfg=fl, **kw)
    p_loop, h_loop = federated.run_federated_loop(fcfg=fl, **kw)
    assert _same_tree(p_scan, p_loop)
    _assert_history_equal(h_scan, h_loop)
    # The faults actually fired somewhere in the run.
    assert any(r.n_success < r.n_selected for r in h_scan)


def test_compressed_scan_matches_loop_under_faults(world):
    kw = _run_kwargs(world)
    fl = dataclasses.replace(
        FL, faults=FULL_FAULTS,
        compression=compression.CompressionConfig(codec="quant",
                                                  bit_width=8))
    p_scan, h_scan = federated.run_federated(fcfg=fl, **kw)
    p_loop, h_loop = federated.run_federated_loop(fcfg=fl, **kw)
    assert _same_tree(p_scan, p_loop)
    _assert_history_equal(h_scan, h_loop)


def test_batch_matches_singles_under_faults(world):
    data, params, loss, ev = world
    fl = dataclasses.replace(FL, faults=FULL_FAULTS)
    s = 2
    nets = wireless.sample_networks(jax.random.key(5), s,
                                    data.num_devices, WCFG)
    keys = federated.scenario_keys(jax.random.key(9), 0, s)
    p_b, m_b = federated.run_federated_batch(
        fcfg=fl, init_params=params, loss_fn=loss, eval_fn=ev, data=data,
        nets=nets, wcfg=WCFG, scfg=SCFG, keys=keys)
    recs = federated.batch_metrics_to_records(m_b)
    for i in range(s):
        net_i = jax.tree_util.tree_map(lambda a, i=i: a[i], nets)
        p_i, h_i = federated.run_federated(
            fcfg=fl, init_params=params, loss_fn=loss, eval_fn=ev,
            data=data, net=net_i, wcfg=WCFG, scfg=SCFG, key=keys[i])
        assert _same_tree(
            p_i, jax.tree_util.tree_map(lambda a, i=i: a[i], p_b))
        _assert_history_equal(h_i, recs[i])


def test_all_uploads_fail_carries_model(world):
    data, params, loss, ev = world
    kw = _run_kwargs(world)
    fl = dataclasses.replace(
        FL, faults=faults.FaultConfig(drop_prob=1.0, max_retries=1))
    p, h = federated.run_federated(fcfg=fl, **kw)
    assert _same_tree(p, params)            # server never moved
    assert all(r.n_success == 0 for r in h)
    assert all(np.isfinite(r.accuracy) for r in h)
    assert all(r.energy_total > 0.0 for r in h)   # futile attempts billed


def test_overprovision_admits_extra_devices(world):
    kw = _run_kwargs(world)
    scfg = scheduler.SchedulerConfig(method="das", n_fixed=3,
                                     iterations_max=3)
    kw["scfg"] = scfg
    _, h_base = federated.run_federated(fcfg=FL, **kw)
    fl = dataclasses.replace(
        FL, faults=faults.FaultConfig(drop_prob=0.2, overprovision=2))
    _, h_over = federated.run_federated(fcfg=fl, **kw)
    assert all(r.n_selected == 3 for r in h_base)
    assert all(r.n_selected == 5 for r in h_over)


# ---------------------------------------------------------------------------
# Sweep integration: fault axis, fingerprint, kill/resume durability
# ---------------------------------------------------------------------------

def _fault_spec(**kw):
    base = dict(
        fl=dataclasses.replace(
            FL, faults=faults.FaultConfig(drop_prob=0.3, max_retries=1,
                                          reliability_ema=0.3)),
        sched=SCFG, wireless=WCFG,
        scenarios_per_point=4, chunk_scenarios=2, base_seed=7)
    base.update(kw)
    return grid_lib.SweepSpec(**base)


def test_fault_axis_expansion_and_fingerprint():
    spec = _fault_spec(axes=(grid_lib.Axis("fault", "drop_prob",
                                           (0.0, 0.2, 0.4)),))
    points = spec.expand()
    assert [p.fl.faults.drop_prob for p in points] == [0.0, 0.2, 0.4]
    assert [p.name for p in points] == \
        ["drop_prob=0", "drop_prob=0.2", "drop_prob=0.4"]
    # Base configs untouched; fingerprints differ per fault setting.
    assert spec.fl.faults.drop_prob == 0.3
    assert spec.fingerprint() != _fault_spec().fingerprint()
    with pytest.raises(ValueError, match="faults is None"):
        grid_lib.SweepSpec(
            fl=FL, axes=(grid_lib.Axis("fault", "drop_prob",
                                       (0.1,)),)).expand()
    with pytest.raises(ValueError, match="no field"):
        _fault_spec(axes=(grid_lib.Axis("fault", "nope", (1,)),)).expand()


@pytest.fixture(scope="module")
def fault_engine(world):
    data, params, loss, ev = world
    return engine_lib.SweepEngine(
        _fault_spec(), data=data, loss_fn=loss, eval_fn=ev,
        init_params=params, target_accuracy=0.3)


def test_faulty_sweep_kill_resume_bitwise(fault_engine, tmp_path):
    """Kill a faulty-scenario sweep mid-run — including a simulated
    kill *mid checkpoint write* (garbage .tmp left behind) — and resume:
    aggregates must be bitwise identical to the uninterrupted run."""
    ck = str(tmp_path / "faulty.msgpack")
    r = runner_lib.SweepRunner(fault_engine, ck)
    assert r.run(max_chunks=1) is None
    # Simulated kill mid-write: the atomic writer's temp file holds
    # torn garbage, the real checkpoint is intact.  Resume must ignore
    # the temp file entirely.
    with open(ck + ".tmp", "wb") as f:
        f.write(b"\x93torn-garbage")
    out = r.run()
    assert out is not None
    full = runner_lib.SweepRunner(
        fault_engine, str(tmp_path / "full.msgpack")).run()
    for (p, s), (pf, sf) in zip(out, full):
        assert p.name == pf.name
        for metric in s:
            for stat in ("mean", "var", "count"):
                assert np.array_equal(np.asarray(s[metric][stat]),
                                      np.asarray(sf[metric][stat]),
                                      equal_nan=True), metric
    # Faults visibly fired: fewer successes than admissions on average.
    ok = np.asarray(out[0][1]["round.n_success"]["mean"])
    sel = np.asarray(out[0][1]["round.n_selected"]["mean"])
    assert np.all(ok <= sel)
    assert np.any(ok < sel)


def test_truncated_checkpoint_fails_loudly(fault_engine, tmp_path):
    """Satellite hardening: a checkpoint damaged after the fact (the
    atomic writer cannot produce one) raises a clear ValueError instead
    of a bare decoder traceback."""
    ck = str(tmp_path / "trunc.msgpack")
    r = runner_lib.SweepRunner(fault_engine, ck)
    assert r.run(max_chunks=1) is None
    raw = open(ck, "rb").read()
    with open(ck, "wb") as f:
        f.write(raw[:len(raw) // 2])
    with pytest.raises(ValueError, match="corrupt or truncated"):
        msgpack_ckpt.load_flat(ck)
    with pytest.raises(ValueError, match="corrupt or truncated"):
        r.run()


def test_jsonl_rewind_tolerates_torn_and_nondict_lines(fault_engine,
                                                       tmp_path):
    """Satellite hardening: the resume rewind drops a torn final line
    AND a valid-JSON-but-not-an-object line instead of crashing."""
    ck = str(tmp_path / "jl.msgpack")
    jl = str(tmp_path / "jl.jsonl")
    r = runner_lib.SweepRunner(fault_engine, ck, jsonl_path=jl)
    assert r.run(max_chunks=1) is None
    with open(jl, "a") as f:
        f.write("[1, 2, 3]\n")              # valid JSON, wrong shape
        f.write('{"cursor": 99, "tor')      # torn tail write
    out = r.run()
    assert out is not None
    lines = [json.loads(ln) for ln in open(jl)]
    assert [ln["cursor"] for ln in lines] == \
        list(range(1, len(fault_engine.spec.schedule()) + 1))


# ---------------------------------------------------------------------------
# Chronic per-device drop rates (persistent signal for the reliability EMA)
# ---------------------------------------------------------------------------

def test_chronic_rates_gating_shape_and_closed_form():
    key = jax.random.key(11)
    # Either knob at zero gates the feature off entirely (None -> the
    # scalar i.i.d. path, bitwise unchanged).
    assert faults.chronic_rates(key, 8, faults.FaultConfig(
        drop_prob=0.0, chronic_spread=1.0)) is None
    assert faults.chronic_rates(key, 8, faults.FaultConfig(
        drop_prob=0.35, chronic_spread=0.0)) is None
    cfg = faults.FaultConfig(drop_prob=0.35, chronic_spread=1.0)
    r = faults.chronic_rates(key, 8, cfg)
    assert r.shape == (8,)
    rn = np.asarray(r)
    assert np.all((rn >= 0.0) & (rn <= 1.0))
    assert np.std(rn) > 0.0                  # actually heterogeneous
    # Deterministic given the scenario key.
    np.testing.assert_array_equal(
        rn, np.asarray(faults.chronic_rates(key, 8, cfg)))
    # Mean-preserving log-normal: rate_k = p * exp(s z_k - s^2/2).
    z = np.asarray(jax.random.normal(key, (8,)))
    np.testing.assert_allclose(
        rn, np.clip(0.35 * np.exp(1.0 * z - 0.5), 0.0, 1.0), rtol=1e-6)


def test_chronic_spread_noop_without_drop_prob(world):
    """chronic_spread on a config whose drop_prob is zero must be a
    bitwise no-op — the gate returns None, not a (K,) field of zeros."""
    kw = _run_kwargs(world)
    base = dataclasses.replace(FULL_FAULTS, drop_prob=0.0)
    p0, h0 = federated.run_federated(fcfg=dataclasses.replace(
        FL, faults=base), **kw)
    p1, h1 = federated.run_federated(fcfg=dataclasses.replace(
        FL, faults=dataclasses.replace(base, chronic_spread=2.0)), **kw)
    assert _same_tree(p0, p1)
    _assert_history_equal(h0, h1)


def test_chronic_scan_matches_loop(world):
    """Scan==legacy parity holds with the once-per-scenario (K,) rate
    field threaded through both drivers."""
    kw = _run_kwargs(world)
    fl = dataclasses.replace(FL, faults=dataclasses.replace(
        FULL_FAULTS, chronic_spread=1.2))
    p_scan, h_scan = federated.run_federated(fcfg=fl, **kw)
    p_loop, h_loop = federated.run_federated_loop(fcfg=fl, **kw)
    assert _same_tree(p_scan, p_loop)
    _assert_history_equal(h_scan, h_loop)
    # Chronic rates perturb the draw stream: results differ from the
    # i.i.d. configuration (the feature is not silently inert).
    p_iid, _ = federated.run_federated(
        fcfg=dataclasses.replace(FL, faults=FULL_FAULTS), **kw)
    assert not _same_tree(p_scan, p_iid)
