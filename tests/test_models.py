"""Model-zoo tests: per-arch smoke (reduced configs), decode parity,
chunked-vs-sequential oracles, SWA ring-buffer wraparound."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels import ref as kref
from repro.models import ssm, transformer, xlstm
from repro.models.config import LayerSpec


def _inputs(cfg, key, b, s):
    if cfg.input_mode == "embeddings":
        return jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    return jax.random.randint(key, (b, s), 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced variant (<=2-layer pattern, d_model<=256, <=4 experts):
    one forward + one SGD train step on CPU; shapes + finiteness."""
    cfg = configs.get(arch).reduced()
    key = jax.random.key(0)
    params = transformer.init(key, cfg)
    b, s = 2, 64
    inputs = _inputs(cfg, key, b, s)
    enc = (jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
           if cfg.is_encdec else None)
    logits, aux = transformer.forward(params, inputs, cfg, None,
                                      encoder_inputs=enc)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    labels = jax.random.randint(jax.random.key(1), (b, s), 0,
                                cfg.vocab_size)

    def loss(p):
        lg, ax = transformer.forward(p, inputs, cfg, None,
                                     encoder_inputs=enc)
        lp = jax.nn.log_softmax(lg.astype(jnp.float32))
        nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)
        return jnp.mean(nll) + 0.01 * ax

    l0, grads = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(l0))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree_util.tree_leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0
    params2 = jax.tree_util.tree_map(lambda p, g: p - 0.01 * g, params,
                                     grads)
    l1 = loss(params2)
    assert bool(jnp.isfinite(l1))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_decode_parity(arch):
    """prefill(S) + decode(S) == forward(S+1) at the last position."""
    cfg = configs.get(arch).reduced()
    key = jax.random.key(2)
    params = transformer.init(key, cfg)
    b, s = 2, 33
    if cfg.input_mode == "embeddings":
        prompt = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
        tok = jnp.full((b, 1), 7, jnp.int32)
        emb_last = jnp.take(params["embed"], tok[:, 0], axis=0)[:, None]
        full = jnp.concatenate([prompt, emb_last], axis=1)
    else:
        full = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
        prompt, tok = full[:, :s], full[:, s:s + 1]
    enc = (jax.random.normal(key, (b, 16, cfg.d_model), jnp.float32)
           if cfg.is_encdec else None)
    want, _ = transformer.forward(params, full, cfg, None,
                                  encoder_inputs=enc)
    _, cache = transformer.prefill(params, prompt, cfg, None,
                                   encoder_inputs=enc, pad_to=s + 8)
    got, _ = transformer.decode_step(params, tok, cache, jnp.asarray(s),
                                     cfg, None)
    a, b_ = np.asarray(want[:, -1]), np.asarray(got[:, 0])
    rel = np.max(np.abs(a - b_)) / max(np.max(np.abs(a)), 1e-6)
    assert rel < 2e-2, f"{arch}: decode parity rel err {rel:.2e}"


def test_swa_ring_wraparound():
    """Decode correctness when the prompt exceeds the SWA window."""
    cfg = configs.get("h2o_danube_3_4b").reduced(sliding_window=32,
                                                 num_layers=2)
    key = jax.random.key(3)
    params = transformer.init(key, cfg)
    b, s = 1, 100   # prompt 100 >> window 32
    full = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    want, _ = transformer.forward(params, full, cfg, None)
    _, cache = transformer.prefill(params, full[:, :s], cfg, None,
                                   pad_to=s + 8)
    got, _ = transformer.decode_step(params, full[:, s:s + 1], cache,
                                     jnp.asarray(s), cfg, None)
    rel = (np.max(np.abs(np.asarray(want[:, -1]) - np.asarray(got[:, 0])))
           / max(np.max(np.abs(np.asarray(want[:, -1]))), 1e-6))
    assert rel < 2e-2, f"SWA ring wraparound rel err {rel:.2e}"


# ---------------------------------------------------------------------------
# Chunked-vs-sequential recurrence oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_matches_sequential(chunk):
    b, s, nh, p, n = 2, 64, 3, 8, 4
    key = jax.random.key(4)
    xh = jax.random.normal(key, (b, s, nh, p))
    bm = jax.random.normal(jax.random.key(5), (b, s, n)) * 0.5
    cm = jax.random.normal(jax.random.key(6), (b, s, n)) * 0.5
    dt_s = jax.nn.softplus(jax.random.normal(jax.random.key(7),
                                             (b, s, nh)))
    log_a = -dt_s * 0.5
    y_c, h_c = ssm._ssd_chunked(xh, bm, cm, log_a, dt_s, chunk)
    y_s, h_s = kref.ssd_sequential(xh, bm, cm, log_a, dt_s)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_s),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_mlstm_chunked_matches_sequential(chunk):
    b, s, nh, hd = 2, 64, 2, 16
    key = jax.random.key(8)
    q = jax.random.normal(key, (b, s, nh, hd))
    k = jax.random.normal(jax.random.key(9), (b, s, nh, hd))
    v = jax.random.normal(jax.random.key(10), (b, s, nh, hd))
    ig = jax.random.normal(jax.random.key(11), (b, s, nh))
    fg = jax.random.normal(jax.random.key(12), (b, s, nh)) + 3.0
    h_c, _ = xlstm._mlstm_chunked(q, k, v, ig, fg, chunk)
    h_s = kref.mlstm_sequential(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_s),
                               rtol=2e-4, atol=2e-4)


def test_long_context_support_flags():
    assert configs.get("xlstm_125m").supports_long_context()
    assert configs.get("jamba_1_5_large_398b").supports_long_context()
    assert configs.get("mixtral_8x22b").supports_long_context()
    assert configs.get("h2o_danube_3_4b").supports_long_context()
    assert not configs.get("qwen3_14b").supports_long_context()
    assert not configs.get("whisper_small").supports_long_context()
    assert not configs.get("codeqwen1_5_7b").supports_long_context()


def test_mrope_equals_rope_for_text():
    """Equal (t,h,w) positions must reduce M-RoPE to plain RoPE."""
    from repro.models import common
    pos = jnp.arange(16)[None]
    sin_r, cos_r = common.rope_sin_cos(pos, 32, 1e4)
    pos3 = jnp.broadcast_to(pos[None], (3, 1, 16))
    sin_m, cos_m = common.mrope_sin_cos(pos3, 32, 1e4, (6, 5, 5))
    np.testing.assert_allclose(np.asarray(sin_r), np.asarray(sin_m),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(cos_r), np.asarray(cos_m),
                               rtol=1e-6)
