"""Event-driven asynchronous FEEL tests (DESIGN.md §12): availability
processes, the staleness-weighted buffered flush and its Pallas lane,
the synchronous-limit bitwise contract across subsystem compositions,
batch==singles parity, and the async sweep axis."""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (compression, events, faults, federated,
                        scheduler, streaming, wireless)
from repro.data import partition, synthetic
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref
from repro.models import paper_nets
from repro.sweep import grid as grid_lib


# ---------------------------------------------------------------------------
# Fixtures: one tiny world shared module-wide (compiles dominate runtime)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def world():
    imgs, labs = synthetic.generate(0, samples_per_class=200)
    data = partition.partition(
        imgs, labs, seed=1,
        spec=partition.PartitionSpec(num_devices=8, num_shards=36,
                                     shard_size=50))
    mspec = paper_nets.PaperNetSpec(kind="mlp", mlp_hidden=8)
    params = paper_nets.init(jax.random.key(3), mspec)
    loss = functools.partial(paper_nets.loss_fn, spec=mspec)
    ev = functools.partial(paper_nets.accuracy, spec=mspec)
    return data, params, loss, ev


WCFG = wireless.WirelessConfig()
SCFG = scheduler.SchedulerConfig(method="das", n_min=2, iterations_max=3,
                                 reliability_weight=0.4)
FL = federated.FLConfig(num_rounds=3, batch_size=50, learning_rate=0.1)
# Active-but-harmless fault config: no channel ever fires (ok ==
# selected, airtime multiplier exactly 1.0), yet the *fault-aware*
# aggregation path — update form over the success mask, reliability EMA
# in the carry — is the one traced.  That is the path the
# synchronous-limit contract targets.
HARMLESS = faults.FaultConfig(reliability_ema=0.3)


def _run_kwargs(world):
    data, params, loss, ev = world
    net = wireless.sample_network(jax.random.key(0), data.num_devices,
                                  WCFG)
    return dict(init_params=params, loss_fn=loss, eval_fn=ev, data=data,
                net=net, wcfg=WCFG, scfg=SCFG, key=jax.random.key(42))


def _same_tree(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _assert_history_equal(ha, hb):
    for a, b in zip(ha, hb):
        assert a.accuracy == b.accuracy
        assert a.round_time == b.round_time
        assert a.energy_total == b.energy_total
        assert a.n_selected == b.n_selected
        assert a.n_success == b.n_success
        assert np.array_equal(a.selected, b.selected)


# ---------------------------------------------------------------------------
# EventConfig validation and the availability-process registry
# ---------------------------------------------------------------------------

def test_event_config_validation(world):
    data, params, loss, ev = world

    def _build(ecfg):
        events.make_event_sim(
            loss_fn=loss, eval_fn=ev, wcfg=WCFG, scfg=SCFG,
            fcfg=dataclasses.replace(FL, events=ecfg),
            capacity=data.capacity)

    with pytest.raises(ValueError, match="buffer_size"):
        _build(events.EventConfig(buffer_size=0))
    with pytest.raises(ValueError, match="tick_horizon"):
        _build(events.EventConfig(tick_horizon=-0.5))
    with pytest.raises(ValueError, match="unknown availability"):
        _build(events.EventConfig(availability="no_such_process"))
    with pytest.raises(ValueError, match="events is None"):
        events.make_event_sim(
            loss_fn=loss, eval_fn=ev, wcfg=WCFG, scfg=SCFG, fcfg=FL,
            capacity=data.capacity)


def test_availability_registry():
    names = events.availability_names()
    assert {"always", "churn", "diurnal"} <= set(names)
    with pytest.raises(ValueError, match="unknown availability"):
        events.get_availability("no_such_process")
    with pytest.raises(ValueError, match="already registered"):
        events.register_availability("always", events.AlwaysOn)


@pytest.mark.parametrize("name", ["always", "churn", "diurnal"])
def test_availability_process_shapes_and_determinism(name):
    cfg = events.EventConfig(availability=name, avail_prob=0.6,
                             duty=0.4)
    proc = events.get_availability(name)
    k = 8
    state = proc.init(jax.random.key(1), k, cfg)
    assert state.shape == (k,)
    mask = proc.sample(jax.random.key(2), state,
                       jnp.asarray(3, jnp.int32), cfg)
    assert mask.shape == (k,)
    mn = np.asarray(mask)
    assert np.all((mn == 0.0) | (mn == 1.0))
    # Deterministic given (key, state, tick).
    np.testing.assert_array_equal(
        mn, np.asarray(proc.sample(jax.random.key(2), state,
                                   jnp.asarray(3, jnp.int32), cfg)))
    if name == "always":
        assert np.all(mn == 1.0)


def test_diurnal_duty_sets_mean_availability():
    """The sinusoidal level is rescaled so its cycle mean is ``duty``
    (exact for duty <= 0.5)."""
    proc = events.get_availability("diurnal")
    means = {}
    for duty in (0.2, 0.5):
        cfg = events.EventConfig(availability="diurnal", duty=duty,
                                 period=24.0, phase_spread=0.3)
        state = proc.init(jax.random.key(7), 64, cfg)
        total = 0.0
        for t in range(48):                 # two full cycles
            m = proc.sample(jax.random.fold_in(jax.random.key(8), t),
                            state, jnp.asarray(t, jnp.int32), cfg)
            total += float(jnp.mean(m))
        means[duty] = total / 48
    assert abs(means[0.2] - 0.2) < 0.08
    assert abs(means[0.5] - 0.5) < 0.08
    assert means[0.2] < means[0.5]


# ---------------------------------------------------------------------------
# Staleness weighting: closed form + the Pallas lane vs the einsum oracle
# ---------------------------------------------------------------------------

def test_staleness_multiplier_closed_form():
    tau = jnp.asarray([0.0, 1.0, 3.0, 7.0], jnp.float32)
    # decay == 0 is *exact* ones — no pow in the traced program, which
    # is what keeps the zero-decay flush bitwise synchronous.
    np.testing.assert_array_equal(
        np.asarray(events.staleness_multiplier(tau, 0.0)),
        np.ones(4, np.float32))
    got = np.asarray(events.staleness_multiplier(tau, 0.7))
    np.testing.assert_allclose(
        got, (1.0 + np.asarray(tau)) ** -0.7, rtol=1e-6)
    assert np.all(np.diff(got) < 0.0)       # staler -> lighter


@pytest.mark.parametrize("k,p", [(4, 64), (8, 1000), (16, 4096)])
def test_fedavg_agg_stale_kernel_matches_ref(k, p):
    u = jax.random.normal(jax.random.key(k * 100 + p), (k, p))
    w = jax.nn.softmax(jax.random.normal(jax.random.key(1), (k,)))
    m = (jax.random.uniform(jax.random.key(2), (k,)) > 0.4
         ).astype(jnp.float32)
    s = events.staleness_multiplier(
        jax.random.randint(jax.random.key(3), (k,), 0, 5
                           ).astype(jnp.float32), 0.5)
    got = kernel_ops.fedavg_agg_stale(u, w, m, s)
    want = kernel_ref.fedavg_agg_stale(u, w, m, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fedavg_agg_stale_all_ones_bitwise_equals_masked():
    """An all-ones staleness row IS the masked kernel: w * m * 1.0 ==
    w * m in f32, no renormalization inside the kernel — the reduction
    identity the synchronous-limit contract leans on."""
    u = jax.random.normal(jax.random.key(5), (9, 1536))
    w = jax.nn.softmax(jax.random.normal(jax.random.key(6), (9,)))
    m = (jax.random.uniform(jax.random.key(7), (9,)) > 0.3
         ).astype(jnp.float32)
    ones = jnp.ones((9,), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(kernel_ops.fedavg_agg_stale(u, w, m, ones)),
        np.asarray(kernel_ops.fedavg_agg_masked(u, w, m)))
    np.testing.assert_array_equal(
        np.asarray(kernel_ref.fedavg_agg_stale(u, w, m, ones)),
        np.asarray(kernel_ref.fedavg_agg_masked(u, w, m)))


# ---------------------------------------------------------------------------
# The synchronous-limit contract: default EventConfig == sync driver,
# bitwise, across every subsystem composition
# ---------------------------------------------------------------------------

_QUANT = compression.CompressionConfig(codec="quant", bit_width=4)
_STREAM = streaming.StreamConfig(rate=6.0)

SYNC_LIMIT_CASES = {
    "plain": {},
    "compressed": dict(compression=_QUANT),
    "streaming": dict(stream=_STREAM),
    "dispatch_cap": dict(dispatch_cap=3),
    "kernel_agg": dict(use_kernel_agg=True),
    "combined_bf16": dict(compression=_QUANT, stream=_STREAM,
                          dispatch_cap=3, carry_dtype="bfloat16"),
}


@pytest.mark.parametrize("case", sorted(SYNC_LIMIT_CASES))
def test_sync_limit_bitwise(world, case):
    """EventConfig() — always-on availability, buffer_size 1, zero
    staleness decay, whole-cohort ticks — reproduces the synchronous
    driver bit for bit (params AND every per-round metric), with each
    subsystem riding along."""
    kw = _run_kwargs(world)
    fl = dataclasses.replace(FL, faults=HARMLESS,
                             **SYNC_LIMIT_CASES[case])
    p_sync, h_sync = federated.run_federated(fcfg=fl, **kw)
    p_evt, h_evt = federated.run_federated(
        fcfg=dataclasses.replace(fl, events=events.EventConfig()), **kw)
    assert _same_tree(p_sync, p_evt)
    _assert_history_equal(h_sync, h_evt)


def test_sync_limit_bitwise_under_live_faults(world):
    """The contract holds when faults actually fire (drops, retries,
    stragglers): the event scan recomputes apply_faults' timing
    expressions op-for-op."""
    kw = _run_kwargs(world)
    fl = dataclasses.replace(FL, faults=faults.FaultConfig(
        drop_prob=0.35, max_retries=2, backoff_base=0.5,
        straggler_prob=0.3, straggler_scale=3.0, dropout_prob=0.1,
        reliability_ema=0.3, overprovision=1))
    p_sync, h_sync = federated.run_federated(fcfg=fl, **kw)
    p_evt, h_evt = federated.run_federated(
        fcfg=dataclasses.replace(fl, events=events.EventConfig()), **kw)
    assert _same_tree(p_sync, p_evt)
    _assert_history_equal(h_sync, h_evt)
    assert any(r.n_success < r.n_selected for r in h_sync)


# ---------------------------------------------------------------------------
# Asynchronous mode: buffered flushes, staleness, availability gating
# ---------------------------------------------------------------------------

def test_async_mode_runs_and_stamps_horizon(world):
    kw = _run_kwargs(world)
    ecfg = events.EventConfig(availability="diurnal", duty=0.6,
                              buffer_size=2, staleness_decay=0.5,
                              tick_horizon=0.05, num_events=6)
    fl = dataclasses.replace(FL, faults=HARMLESS, events=ecfg)
    p, h = federated.run_federated(fcfg=fl, **kw)
    # num_events overrides num_rounds as the scan length, and a fixed
    # horizon means every event advances the clock by exactly that much.
    assert len(h) == 6
    assert all(np.isclose(r.round_time, 0.05) for r in h)
    leaves = [np.asarray(x) for x in jax.tree_util.tree_leaves(p)]
    assert all(np.isfinite(l).all() for l in leaves)
    assert all(np.isfinite(r.accuracy) for r in h)


def test_event_batch_matches_singles(world):
    """vmapped event scan == S independent single-scenario runs,
    bitwise, in full async mode (diurnal churn, buffered flushes,
    staleness discount, short horizon)."""
    data, params, loss, ev = world
    ecfg = events.EventConfig(availability="diurnal", duty=0.6,
                              buffer_size=2, staleness_decay=0.5,
                              tick_horizon=0.03, num_events=4)
    fl = dataclasses.replace(FL, faults=HARMLESS, events=ecfg)
    s = 2
    nets = wireless.sample_networks(jax.random.key(5), s,
                                    data.num_devices, WCFG)
    keys = federated.scenario_keys(jax.random.key(9), 0, s)
    p_b, m_b = federated.run_federated_batch(
        fcfg=fl, init_params=params, loss_fn=loss, eval_fn=ev, data=data,
        nets=nets, wcfg=WCFG, scfg=SCFG, keys=keys)
    recs = federated.batch_metrics_to_records(m_b)
    for i in range(s):
        net_i = jax.tree_util.tree_map(lambda a, i=i: a[i], nets)
        p_i, h_i = federated.run_federated(
            fcfg=fl, init_params=params, loss_fn=loss, eval_fn=ev,
            data=data, net=net_i, wcfg=WCFG, scfg=SCFG, key=keys[i])
        assert _same_tree(
            p_i, jax.tree_util.tree_map(lambda a, i=i: a[i], p_b))
        _assert_history_equal(h_i, recs[i])


def test_run_federated_loop_refuses_events(world):
    kw = _run_kwargs(world)
    fl = dataclasses.replace(FL, events=events.EventConfig())
    with pytest.raises(ValueError, match="legacy per-round loop"):
        federated.run_federated_loop(fcfg=fl, **kw)


def test_sim_length():
    assert federated.sim_length(FL) == 3
    assert federated.sim_length(dataclasses.replace(
        FL, events=events.EventConfig())) == 3
    assert federated.sim_length(dataclasses.replace(
        FL, events=events.EventConfig(num_events=7))) == 7


# ---------------------------------------------------------------------------
# Sweep integration: the async axis
# ---------------------------------------------------------------------------

def test_async_axis_requires_event_config():
    spec = grid_lib.SweepSpec(
        fl=FL, sched=SCFG, wireless=WCFG,
        axes=(grid_lib.Axis("async", "staleness_decay", (0.0, 0.5)),))
    with pytest.raises(ValueError, match="async.staleness_decay"):
        spec.expand()


def test_async_axis_expands_event_knobs():
    spec = grid_lib.SweepSpec(
        fl=dataclasses.replace(FL, events=events.EventConfig()),
        sched=SCFG, wireless=WCFG,
        axes=(grid_lib.Axis("async", "staleness_decay", (0.0, 0.5)),))
    points = spec.expand()
    assert [p.fl.events.staleness_decay for p in points] == [0.0, 0.5]
    # Sync-vs-async itself rides the generic fl axis.
    spec2 = grid_lib.SweepSpec(
        fl=FL, sched=SCFG, wireless=WCFG,
        axes=(grid_lib.Axis(
            "fl", "events",
            (None, events.EventConfig(tick_horizon=0.05))),))
    pts = spec2.expand()
    assert pts[0].fl.events is None
    assert pts[1].fl.events.tick_horizon == 0.05
