"""FEEL integration tests: Algorithm 1 end-to-end at small scale."""

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import federated, scheduler, wireless
from repro.data import partition, synthetic
from repro.models import paper_nets


@pytest.fixture(scope="module")
def small_world():
    imgs, labs = synthetic.generate(0, samples_per_class=600)
    pspec = partition.PartitionSpec(num_devices=12, num_shards=100,
                                    shard_size=50)
    data = partition.partition(imgs, labs, seed=1, spec=pspec)
    wcfg = wireless.WirelessConfig()
    net = wireless.sample_network(jax.random.key(0), 12, wcfg)
    return data, net, wcfg


def _run(data, net, wcfg, method, rounds=4, model="mlp", **sch_kw):
    mspec = paper_nets.PaperNetSpec(kind=model)
    params = paper_nets.init(jax.random.key(3), mspec)
    scfg = scheduler.SchedulerConfig(method=method, n_min=2,
                                     iterations_max=4, **sch_kw)
    fcfg = federated.FLConfig(num_rounds=rounds, batch_size=50,
                              learning_rate=0.1)
    return federated.run_federated(
        init_params=params,
        loss_fn=functools.partial(paper_nets.loss_fn, spec=mspec),
        eval_fn=functools.partial(paper_nets.accuracy, spec=mspec),
        data=data, net=net, wcfg=wcfg, scfg=scfg, fcfg=fcfg,
        key=jax.random.key(4))


def test_fl_learns(small_world):
    data, net, wcfg = small_world
    _, hist = _run(data, net, wcfg, "das")
    assert hist[-1].accuracy > 0.5, \
        f"FL failed to learn: {hist[-1].accuracy}"
    assert hist[-1].accuracy > hist[0].accuracy


def test_round_accounting(small_world):
    data, net, wcfg = small_world
    _, hist = _run(data, net, wcfg, "das", rounds=3)
    for rec in hist:
        assert rec.n_selected >= 2              # n_min
        assert rec.round_time > 0.0
        assert rec.energy_total > 0.0
        assert rec.energy_per_device <= rec.energy_total + 1e-9


def test_full_baseline_selects_everyone(small_world):
    data, net, wcfg = small_world
    _, hist = _run(data, net, wcfg, "full", rounds=2)
    assert all(r.n_selected == data.num_devices for r in hist)


def test_ages_reset_on_selection(small_world):
    data, net, wcfg = small_world
    _, hist = _run(data, net, wcfg, "random", rounds=3,
                   n_fixed=3)
    # With n_fixed=3, every round selects exactly 3.
    assert all(r.n_selected == 3 for r in hist)


def test_fedavg_aggregate_weighted():
    stacked = {"w": jnp.stack([jnp.ones((4,)), 3.0 * jnp.ones((4,))])}
    weights = jnp.asarray([0.25, 0.75])
    out = federated.fedavg_aggregate(stacked, weights)
    np.testing.assert_allclose(np.asarray(out["w"]), 2.5)


def test_fedavg_aggregate_kernel_path():
    key = jax.random.key(5)
    stacked = {"w": jax.random.normal(key, (6, 37))}
    weights = jax.nn.softmax(jax.random.normal(key, (6,)))
    ref_out = federated.fedavg_aggregate(stacked, weights,
                                         use_kernel=False)
    krn_out = federated.fedavg_aggregate(stacked, weights,
                                         use_kernel=True)
    np.testing.assert_allclose(np.asarray(krn_out["w"]),
                               np.asarray(ref_out["w"]), rtol=1e-5,
                               atol=1e-5)


def test_fedavg_aggregate_kernel_multi_leaf_pytree():
    """Kernel path flattens the whole pytree into ONE launch; parity with
    the tensordot path across heterogeneous leaf shapes."""
    key = jax.random.key(6)
    k = 5
    stacked = {
        "fc1": {"w": jax.random.normal(key, (k, 7, 11)),
                "b": jax.random.normal(jax.random.key(7), (k, 11))},
        "fc2": {"w": jax.random.normal(jax.random.key(8), (k, 11, 3))},
    }
    weights = jax.nn.softmax(jax.random.normal(jax.random.key(9), (k,)))
    ref = federated.fedavg_aggregate(stacked, weights, use_kernel=False)
    krn = federated.fedavg_aggregate(stacked, weights, use_kernel=True)
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(krn)):
        assert a.shape == b.shape
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-5, atol=1e-5)


def test_kernel_agg_driver_parity(small_world):
    """use_kernel_agg=True runs the whole scan driver through the Pallas
    aggregation and must match the tensordot path."""
    data, net, wcfg = small_world
    mspec = paper_nets.PaperNetSpec(kind="mlp")
    params = paper_nets.init(jax.random.key(3), mspec)
    scfg = scheduler.SchedulerConfig(method="random", n_min=2, n_fixed=2,
                                     iterations_max=2)
    outs = {}
    for use_kernel in (False, True):
        fcfg = federated.FLConfig(num_rounds=2, batch_size=50,
                                  learning_rate=0.1,
                                  use_kernel_agg=use_kernel)
        p, hist = federated.run_federated(
            init_params=params,
            loss_fn=functools.partial(paper_nets.loss_fn, spec=mspec),
            eval_fn=functools.partial(paper_nets.accuracy, spec=mspec),
            data=data, net=net, wcfg=wcfg, scfg=scfg, fcfg=fcfg,
            key=jax.random.key(4))
        outs[use_kernel] = (p, hist)
    for a, b in zip(jax.tree_util.tree_leaves(outs[False][0]),
                    jax.tree_util.tree_leaves(outs[True][0])):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-5, atol=1e-6)
    assert all(np.array_equal(x.selected, y.selected)
               for x, y in zip(outs[False][1], outs[True][1]))


# ---------------------------------------------------------------------------
# Scan driver vs legacy loop; vmapped scenario batch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["das", "random", "full"])
def test_scan_driver_matches_legacy_loop(small_world, method):
    """The device-resident scan driver must be bit-for-bit consistent
    with the legacy per-round loop: selection masks, round times,
    per-device energies, accuracies and final params (energy *totals*
    are compared at float tolerance — the fused in-scan reduction may
    sum in a different order than the legacy eager ``jnp.sum``)."""
    data, net, wcfg = small_world
    mspec = paper_nets.PaperNetSpec(kind="mlp")
    params = paper_nets.init(jax.random.key(3), mspec)
    scfg = scheduler.SchedulerConfig(method=method, n_min=2,
                                     iterations_max=4)
    fcfg = federated.FLConfig(num_rounds=3, batch_size=50,
                              learning_rate=0.1)
    kw = dict(init_params=params,
              loss_fn=functools.partial(paper_nets.loss_fn, spec=mspec),
              eval_fn=functools.partial(paper_nets.accuracy, spec=mspec),
              data=data, net=net, wcfg=wcfg, scfg=scfg, fcfg=fcfg,
              key=jax.random.key(4))
    p_scan, h_scan = federated.run_federated(**kw)
    p_loop, h_loop = federated.run_federated_loop(**kw)
    assert len(h_scan) == len(h_loop)
    for a, b in zip(h_scan, h_loop):
        assert np.array_equal(a.selected, b.selected)
        assert a.n_selected == b.n_selected
        assert a.round_time == b.round_time
        np.testing.assert_allclose(a.energy_total, b.energy_total,
                                   rtol=1e-6)
        if b.accuracy == b.accuracy:        # not NaN
            assert a.accuracy == b.accuracy
        else:
            assert a.accuracy != a.accuracy
    for a, b in zip(jax.tree_util.tree_leaves(p_scan),
                    jax.tree_util.tree_leaves(p_loop)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scan_eval_stride(small_world):
    """eval_every > 1 skips evaluation (NaN accuracy) on the same rounds
    as the legacy loop: multiples of the stride plus the final round."""
    data, net, wcfg = small_world
    mspec = paper_nets.PaperNetSpec(kind="mlp")
    params = paper_nets.init(jax.random.key(3), mspec)
    scfg = scheduler.SchedulerConfig(method="random", n_min=2, n_fixed=2)
    fcfg = federated.FLConfig(num_rounds=4, batch_size=50,
                              learning_rate=0.1)
    _, hist = federated.run_federated(
        init_params=params,
        loss_fn=functools.partial(paper_nets.loss_fn, spec=mspec),
        eval_fn=functools.partial(paper_nets.accuracy, spec=mspec),
        data=data, net=net, wcfg=wcfg, scfg=scfg, fcfg=fcfg,
        key=jax.random.key(4), eval_every=3)
    want_eval = [True, False, False, True]   # rounds 0, 3(final)
    got_eval = [r.accuracy == r.accuracy for r in hist]
    assert got_eval == want_eval


def test_batch_matches_independent_runs(small_world):
    """S=3 scenarios through run_federated_batch reproduce, scenario by
    scenario and bit-for-bit, three independent run_federated calls with
    the matching (net, key) pair — shape check + determinism."""
    data, net, wcfg = small_world
    del net
    num_scenarios, rounds = 3, 3
    nets = wireless.sample_networks(jax.random.key(21),
                                    num_scenarios, data.num_devices,
                                    wireless.WirelessConfig())
    keys = jax.random.split(jax.random.key(22), num_scenarios)
    mspec = paper_nets.PaperNetSpec(kind="mlp")
    params = paper_nets.init(jax.random.key(3), mspec)
    scfg = scheduler.SchedulerConfig(method="das", n_min=2,
                                     iterations_max=3)
    fcfg = federated.FLConfig(num_rounds=rounds, batch_size=50,
                              learning_rate=0.1)
    loss = functools.partial(paper_nets.loss_fn, spec=mspec)
    ev = functools.partial(paper_nets.accuracy, spec=mspec)
    p_b, metrics = federated.run_federated_batch(
        init_params=params, loss_fn=loss, eval_fn=ev, data=data,
        nets=nets, wcfg=wcfg, scfg=scfg, fcfg=fcfg, keys=keys)
    assert metrics.selected.shape == (num_scenarios, rounds,
                                      data.num_devices)
    assert metrics.accuracy.shape == (num_scenarios, rounds)
    hists_b = federated.batch_metrics_to_records(metrics)
    for s in range(num_scenarios):
        net_s = jax.tree_util.tree_map(lambda a, s=s: a[s], nets)
        p_s, hist_s = federated.run_federated(
            init_params=params, loss_fn=loss, eval_fn=ev, data=data,
            net=net_s, wcfg=wcfg, scfg=scfg, fcfg=fcfg, key=keys[s])
        for a, b in zip(hists_b[s], hist_s):
            assert np.array_equal(a.selected, b.selected)
            assert a.round_time == b.round_time
            if b.accuracy == b.accuracy:
                assert a.accuracy == b.accuracy
        for a, b in zip(jax.tree_util.tree_leaves(p_b),
                        jax.tree_util.tree_leaves(p_s)):
            np.testing.assert_array_equal(np.asarray(a[s]), np.asarray(b))


def test_donated_params_scan_matches_undonated(small_world):
    """donate_params=True hands the init-params buffers to the scan carry
    (peak-memory open item): results must be identical, and the caller's
    obligation is only to not reuse the donated arrays afterwards."""
    data, net, wcfg = small_world
    mspec = paper_nets.PaperNetSpec(kind="mlp")
    params = paper_nets.init(jax.random.key(3), mspec)
    scfg = scheduler.SchedulerConfig(method="random", n_min=2, n_fixed=2)
    fcfg = federated.FLConfig(num_rounds=2, batch_size=50,
                              learning_rate=0.1)
    kw = dict(loss_fn=functools.partial(paper_nets.loss_fn, spec=mspec),
              eval_fn=functools.partial(paper_nets.accuracy, spec=mspec),
              data=data, net=net, wcfg=wcfg, scfg=scfg, fcfg=fcfg,
              key=jax.random.key(4))
    p_ref, h_ref = federated.run_federated(init_params=params, **kw)
    donated = jax.tree_util.tree_map(jnp.array, params)  # fresh buffers
    p_don, h_don = federated.run_federated(init_params=donated,
                                           donate_params=True, **kw)
    for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                    jax.tree_util.tree_leaves(p_don)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert [r.accuracy for r in h_ref] == [r.accuracy for r in h_don]


def _donation_fixture(small_world, rounds=2):
    data, net, wcfg = small_world
    mspec = paper_nets.PaperNetSpec(kind="mlp")
    params = paper_nets.init(jax.random.key(3), mspec)
    scfg = scheduler.SchedulerConfig(method="random", n_min=2, n_fixed=2)
    fcfg = federated.FLConfig(num_rounds=rounds, batch_size=50,
                              learning_rate=0.1)
    loss = functools.partial(paper_nets.loss_fn, spec=mspec)
    ev = functools.partial(paper_nets.accuracy, spec=mspec)
    hists = federated.client_histograms(data, fcfg.num_classes)
    test_x = synthetic.to_float(data.test_images)
    return data, net, wcfg, params, scfg, fcfg, loss, ev, hists, test_x


def _assert_donated(donated, warn_records):
    """The donation must actually be used: every initial-params buffer
    handed to the compiled sim is consumed (no aliasing copy), and XLA
    did not warn that it declined any donated buffer."""
    for leaf in jax.tree_util.tree_leaves(donated):
        assert leaf.is_deleted(), "donated buffer survived the call"
    declined = [str(w.message) for w in warn_records
                if "donated" in str(w.message).lower()]
    assert not declined, f"XLA declined the donation: {declined}"


def test_make_feel_sim_donates_params_buffer(small_world):
    data, net, wcfg, params, scfg, fcfg, loss, ev, hists, test_x = \
        _donation_fixture(small_world)
    sim = federated.make_feel_sim(loss_fn=loss, eval_fn=ev, wcfg=wcfg,
                                  scfg=scfg, fcfg=fcfg,
                                  capacity=data.capacity,
                                  donate_params=True)
    donated = jax.tree_util.tree_map(jnp.array, params)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = sim(donated, data.images, data.labels, data.mask,
                  data.sizes, hists, test_x, data.test_labels, net,
                  jax.random.key(4))
        jax.block_until_ready(out)
    _assert_donated(donated, rec)


def test_make_feel_sim_batch_donates_tiled_params(small_world):
    """The batch driver's donate contract: params pre-tiled to (S, ...)
    (tile_params) are donated into the vmapped scan carry — the tiled
    buffers are consumed and XLA does not fall back to an aliasing
    copy.  (A broadcast input cannot be donated; see
    make_feel_sim_batch.)"""
    data, _, wcfg, params, scfg, fcfg, loss, ev, hists, test_x = \
        _donation_fixture(small_world)
    s = 2
    nets = wireless.sample_networks(jax.random.key(21), s,
                                    data.num_devices, wcfg)
    keys = jax.random.split(jax.random.key(22), s)
    sim = federated.make_feel_sim_batch(loss_fn=loss, eval_fn=ev,
                                        wcfg=wcfg, scfg=scfg, fcfg=fcfg,
                                        capacity=data.capacity,
                                        donate_params=True)
    donated = federated.tile_params(params, s)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = sim(donated, data.images, data.labels, data.mask,
                  data.sizes, hists, test_x, data.test_labels, nets, keys)
        jax.block_until_ready(out)
    _assert_donated(donated, rec)


def test_run_federated_batch_donated_matches_undonated(small_world):
    """run_federated_batch(donate_params=True) tiles internally, leaves
    the caller's params intact, and returns identical results."""
    data, _, wcfg, params, scfg, fcfg, loss, ev, _, _ = \
        _donation_fixture(small_world)
    s = 2
    nets = wireless.sample_networks(jax.random.key(21), s,
                                    data.num_devices, wcfg)
    keys = jax.random.split(jax.random.key(22), s)
    kw = dict(init_params=params, loss_fn=loss, eval_fn=ev, data=data,
              nets=nets, wcfg=wcfg, scfg=scfg, fcfg=fcfg, keys=keys)
    p_ref, m_ref = federated.run_federated_batch(**kw)
    p_don, m_don = federated.run_federated_batch(donate_params=True, **kw)
    for leaf in jax.tree_util.tree_leaves(params):
        assert not leaf.is_deleted()       # caller's buffers untouched
    for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                    jax.tree_util.tree_leaves(p_don)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(m_ref.selected),
                                  np.asarray(m_don.selected))


def test_das_beats_random_on_noniid(small_world):
    """The paper's core claim at miniature scale: with few devices
    schedulable, data-aware selection reaches higher accuracy in equal
    rounds.  Averaged over 3 seeds to damp run-to-run noise."""
    data, net, wcfg = small_world
    gaps = []
    for seed in range(3):
        mspec = paper_nets.PaperNetSpec(kind="mlp")
        params = paper_nets.init(jax.random.key(seed), mspec)
        accs = {}
        for method in ("das", "random"):
            scfg = scheduler.SchedulerConfig(method=method, n_min=2,
                                             n_fixed=2,
                                             iterations_max=4)
            fcfg = federated.FLConfig(num_rounds=4, batch_size=50,
                                      learning_rate=0.1)
            _, hist = federated.run_federated(
                init_params=params,
                loss_fn=functools.partial(paper_nets.loss_fn,
                                          spec=mspec),
                eval_fn=functools.partial(paper_nets.accuracy,
                                          spec=mspec),
                data=data, net=net, wcfg=wcfg, scfg=scfg, fcfg=fcfg,
                key=jax.random.key(seed + 40))
            accs[method] = hist[-1].accuracy
        gaps.append(accs["das"] - accs["random"])
    assert float(np.mean(gaps)) > -0.02, \
        f"DAS under-performs random: gaps={gaps}"
