"""FEEL integration tests: Algorithm 1 end-to-end at small scale."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import diversity, federated, scheduler, wireless
from repro.data import partition, synthetic
from repro.models import paper_nets


@pytest.fixture(scope="module")
def small_world():
    imgs, labs = synthetic.generate(0, samples_per_class=600)
    pspec = partition.PartitionSpec(num_devices=12, num_shards=100,
                                    shard_size=50)
    data = partition.partition(imgs, labs, seed=1, spec=pspec)
    wcfg = wireless.WirelessConfig()
    net = wireless.sample_network(jax.random.key(0), 12, wcfg)
    return data, net, wcfg


def _run(data, net, wcfg, method, rounds=4, model="mlp", **sch_kw):
    mspec = paper_nets.PaperNetSpec(kind=model)
    params = paper_nets.init(jax.random.key(3), mspec)
    scfg = scheduler.SchedulerConfig(method=method, n_min=2,
                                     iterations_max=4, **sch_kw)
    fcfg = federated.FLConfig(num_rounds=rounds, batch_size=50,
                              learning_rate=0.1)
    return federated.run_federated(
        init_params=params,
        loss_fn=functools.partial(paper_nets.loss_fn, spec=mspec),
        eval_fn=functools.partial(paper_nets.accuracy, spec=mspec),
        data=data, net=net, wcfg=wcfg, scfg=scfg, fcfg=fcfg,
        key=jax.random.key(4))


def test_fl_learns(small_world):
    data, net, wcfg = small_world
    _, hist = _run(data, net, wcfg, "das")
    assert hist[-1].accuracy > 0.5, \
        f"FL failed to learn: {hist[-1].accuracy}"
    assert hist[-1].accuracy > hist[0].accuracy


def test_round_accounting(small_world):
    data, net, wcfg = small_world
    _, hist = _run(data, net, wcfg, "das", rounds=3)
    for rec in hist:
        assert rec.n_selected >= 2              # n_min
        assert rec.round_time > 0.0
        assert rec.energy_total > 0.0
        assert rec.energy_per_device <= rec.energy_total + 1e-9


def test_full_baseline_selects_everyone(small_world):
    data, net, wcfg = small_world
    _, hist = _run(data, net, wcfg, "full", rounds=2)
    assert all(r.n_selected == data.num_devices for r in hist)


def test_ages_reset_on_selection(small_world):
    data, net, wcfg = small_world
    _, hist = _run(data, net, wcfg, "random", rounds=3,
                   n_fixed=3)
    # With n_fixed=3, every round selects exactly 3.
    assert all(r.n_selected == 3 for r in hist)


def test_fedavg_aggregate_weighted():
    stacked = {"w": jnp.stack([jnp.ones((4,)), 3.0 * jnp.ones((4,))])}
    weights = jnp.asarray([0.25, 0.75])
    out = federated.fedavg_aggregate(stacked, weights)
    np.testing.assert_allclose(np.asarray(out["w"]), 2.5)


def test_fedavg_aggregate_kernel_path():
    key = jax.random.key(5)
    stacked = {"w": jax.random.normal(key, (6, 37))}
    weights = jax.nn.softmax(jax.random.normal(key, (6,)))
    ref_out = federated.fedavg_aggregate(stacked, weights,
                                         use_kernel=False)
    krn_out = federated.fedavg_aggregate(stacked, weights,
                                         use_kernel=True)
    np.testing.assert_allclose(np.asarray(krn_out["w"]),
                               np.asarray(ref_out["w"]), rtol=1e-5,
                               atol=1e-5)


def test_das_beats_random_on_noniid(small_world):
    """The paper's core claim at miniature scale: with few devices
    schedulable, data-aware selection reaches higher accuracy in equal
    rounds.  Averaged over 3 seeds to damp run-to-run noise."""
    data, net, wcfg = small_world
    gaps = []
    for seed in range(3):
        mspec = paper_nets.PaperNetSpec(kind="mlp")
        params = paper_nets.init(jax.random.key(seed), mspec)
        accs = {}
        for method in ("das", "random"):
            scfg = scheduler.SchedulerConfig(method=method, n_min=2,
                                             n_fixed=2,
                                             iterations_max=4)
            fcfg = federated.FLConfig(num_rounds=4, batch_size=50,
                                      learning_rate=0.1)
            _, hist = federated.run_federated(
                init_params=params,
                loss_fn=functools.partial(paper_nets.loss_fn,
                                          spec=mspec),
                eval_fn=functools.partial(paper_nets.accuracy,
                                          spec=mspec),
                data=data, net=net, wcfg=wcfg, scfg=scfg, fcfg=fcfg,
                key=jax.random.key(seed + 40))
            accs[method] = hist[-1].accuracy
        gaps.append(accs["das"] - accs["random"])
    assert float(np.mean(gaps)) > -0.02, \
        f"DAS under-performs random: gaps={gaps}"
