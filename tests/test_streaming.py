"""Streaming-data subsystem: refresh kernel, processes, driver parity.

Contracts under test (ISSUE 3 acceptance, DESIGN.md §7):

* ``kernels/stream_update.py`` == the ``kernels/ref.py`` oracle to <1e-5
  in interpret mode — single ``(K, C)`` instance, batched ``(S, K, C)``
  lane, and vmap of the single entry (the scenario-driver path)
* refresh semantics: clamp-at-zero accumulation, proportional cap
  rescale, staleness reset-on-selection + decayed arrival backlog
* arrival processes are traceable, deterministic per key, and registered
  (registry errors mirror the allocator registry)
* streaming runs are bit-for-bit identical between the scan driver and
  the legacy ``run_federated_loop``, and ``run_federated_batch`` over S
  streaming scenarios equals S independent runs
* regression: under drift, the static round-0 diversity snapshot keeps
  selecting a device set that excludes the now-richest device, while
  per-round streaming refresh re-ranks DAS onto it
* the scheduler staleness hook re-ranks DAS and ABS when
  ``staleness_weight > 0`` and is inert at 0
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import diversity, federated, scheduler, streaming, wireless
from repro.data import partition, synthetic
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref
from repro.models import paper_nets

WCFG = wireless.WirelessConfig()


def _refresh_instance(seed: int, s: int, k: int, c: int):
    hists = jax.random.uniform(jax.random.key(seed), (s, k, c),
                               minval=0.0, maxval=60.0)
    deltas = jax.random.uniform(jax.random.key(seed + 1), (s, k, c),
                                minval=-5.0, maxval=12.0)
    arrivals = jax.random.uniform(jax.random.key(seed + 4), (s, k),
                                  maxval=20.0)
    stale = jax.random.uniform(jax.random.key(seed + 2), (s, k),
                               maxval=8.0)
    sel = (jax.random.uniform(jax.random.key(seed + 3), (s, k)) > 0.5
           ).astype(jnp.float32)
    return hists, deltas, arrivals, stale, sel


# ---------------------------------------------------------------------------
# Refresh oracle semantics
# ---------------------------------------------------------------------------

def test_refresh_clamps_and_counts():
    hists = jnp.asarray([[10.0, 2.0, 0.0]])
    deltas = jnp.asarray([[-15.0, 3.0, 4.0]])        # class 0 over-evicted
    h, stats, stale = kernel_ref.stream_update(
        hists, deltas, jnp.asarray([7.0]), jnp.zeros((1,)),
        jnp.zeros((1,)), decay=0.5)
    np.testing.assert_allclose(np.asarray(h), [[0.0, 5.0, 4.0]])
    assert float(stats[0, 2]) == pytest.approx(9.0)
    # staleness accumulates the reported arrival mass
    assert float(stale[0]) == pytest.approx(7.0)


def test_refresh_size_cap_rescales_proportionally():
    hists = jnp.asarray([[30.0, 10.0], [5.0, 5.0]])
    deltas = jnp.zeros((2, 2))
    h, stats, _ = kernel_ref.stream_update(
        hists, deltas, jnp.zeros((2,)), jnp.zeros((2,)), jnp.zeros((2,)),
        decay=0.5, size_cap=20.0)
    np.testing.assert_allclose(np.asarray(h[0]), [15.0, 5.0])
    np.testing.assert_allclose(np.asarray(h[1]), [5.0, 5.0])  # under cap
    assert float(stats[0, 2]) == pytest.approx(20.0)


def test_refresh_staleness_reset_and_decay():
    hists = jnp.ones((3, 4))
    deltas = jnp.full((3, 4), 2.0)
    arrivals = jnp.full((3,), 8.0)
    stale = jnp.asarray([6.0, 6.0, 0.0])
    sel = jnp.asarray([1.0, 0.0, 0.0])
    _, _, out = kernel_ref.stream_update(hists, deltas, arrivals, stale,
                                         sel, decay=0.5)
    # selected: reset then accumulate; unselected: decay then accumulate
    np.testing.assert_allclose(np.asarray(out), [8.0, 11.0, 8.0])


def test_refresh_diversity_matches_measures():
    hists, deltas, arrivals, stale, sel = _refresh_instance(3, 1, 5, 7)
    h, stats, _ = kernel_ref.stream_update(hists[0], deltas[0],
                                           arrivals[0], stale[0],
                                           sel[0], decay=0.9)
    probs = diversity.class_probs(h)
    np.testing.assert_allclose(np.asarray(stats[:, 0]),
                               np.asarray(diversity.gini_simpson(probs)),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(stats[:, 1]),
                               np.asarray(diversity.shannon_entropy(probs)),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Pallas kernel vs oracle
# ---------------------------------------------------------------------------

@given(st.integers(1, 6), st.integers(2, 40), st.integers(2, 16),
       st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_stream_update_kernel_matches_oracle(s, k, c, seed):
    args = _refresh_instance(seed % 1000, s, k, c)
    for cap in (0.0, 150.0):
        want = kernel_ref.stream_update(*args, decay=0.8, size_cap=cap)
        got = kernel_ops.stream_update(*args, decay=0.8, size_cap=cap)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-5, atol=1e-5)


def test_stream_update_kernel_single_and_vmap_lane():
    """Single-instance entry == row of the batched lane == vmap of the
    single entry (the vmapped scenario driver's shape)."""
    args = _refresh_instance(11, 4, 9, 10)
    got_b = kernel_ops.stream_update(*args, decay=0.7)
    for i in range(4):
        got_1 = kernel_ops.stream_update(*(a[i] for a in args), decay=0.7)
        for g1, gb in zip(got_1, got_b):
            np.testing.assert_array_equal(np.asarray(g1),
                                          np.asarray(gb[i]))
    got_v = jax.vmap(
        lambda *a: kernel_ops.stream_update(*a, decay=0.7))(*args)
    for gv, gb in zip(got_v, got_b):
        np.testing.assert_allclose(np.asarray(gv), np.asarray(gb),
                                   rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------

def _hists0(k: int = 6, c: int = 5) -> jnp.ndarray:
    return jax.random.uniform(jax.random.key(0), (k, c), minval=0.0,
                              maxval=40.0)


@pytest.mark.parametrize("name", ["static", "poisson", "drift", "shift",
                                  "evict"])
def test_processes_traceable_and_deterministic(name):
    cfg = streaming.StreamConfig(process=name, rate=15.0)
    proc = streaming.get_process(name)
    h0 = _hists0()

    def roll(key):
        st = proc.init(key, h0, cfg)
        ds, arrs = [], []
        for i in range(3):
            d, arr, st = proc.sample(jax.random.key(100 + i), st, cfg)
            st = dataclasses.replace(st, round=st.round + 1)
            ds.append(d)
            arrs.append(arr)
        return jnp.stack(ds), jnp.stack(arrs)

    d_a, arr_a = jax.jit(roll)(jax.random.key(7))
    d_b, arr_b = roll(jax.random.key(7))
    np.testing.assert_array_equal(np.asarray(d_a), np.asarray(d_b))
    np.testing.assert_array_equal(np.asarray(arr_a), np.asarray(arr_b))
    assert d_a.shape == (3,) + h0.shape
    assert arr_a.shape == (3, h0.shape[0])
    assert np.all(np.asarray(arr_a) >= 0.0)
    if name == "static":
        np.testing.assert_array_equal(np.asarray(d_a), 0.0)
    if name in ("poisson", "drift", "shift"):
        assert np.all(np.asarray(d_a) >= 0.0)     # pure arrivals
        assert float(jnp.sum(d_a)) > 0.0
        # pure-arrival processes: reported mass == delivered mass
        np.testing.assert_allclose(np.asarray(arr_a),
                                   np.asarray(jnp.sum(d_a, -1)),
                                   rtol=1e-6)


def test_process_registry_errors():
    assert {"static", "poisson", "drift", "shift",
            "evict"} <= set(streaming.process_names())
    with pytest.raises(ValueError, match="unknown arrival process"):
        streaming.get_process("nope")
    with pytest.raises(ValueError, match="already registered"):
        streaming.register_process("poisson", streaming.Poisson)


def test_evict_keeps_counts_nonnegative():
    cfg = streaming.StreamConfig(process="evict", rate=2.0,
                                 evict_frac=0.9)
    proc = streaming.get_process("evict")
    st = proc.init(jax.random.key(1), _hists0(), cfg)
    for i in range(5):
        d, arr, st = proc.sample(jax.random.key(i), st, cfg)
        h, _, stale = streaming.refresh(st.hists, d, arr, st.staleness,
                                        st.selected_prev, cfg)
        st = dataclasses.replace(st, hists=h, staleness=stale,
                                 round=st.round + 1)
        assert np.all(np.asarray(st.hists) >= 0.0)


def test_evict_staleness_tracks_arrivals_under_heavy_eviction():
    """Heavy eviction nets every per-class delta negative, but the
    device's data is still turning over — the reported arrival mass
    (not the positive part of the net deltas) must keep the staleness
    signal accumulating."""
    cfg = streaming.StreamConfig(process="evict", rate=2.0,
                                 evict_frac=0.9)
    proc = streaming.get_process("evict")
    h0 = jnp.full((4, 5), 40.0)
    st = proc.init(jax.random.key(1), h0, cfg)
    d, arr, st = proc.sample(jax.random.key(2), st, cfg)
    assert np.all(np.asarray(d) < 0.0), "setup: eviction must dominate"
    assert float(jnp.sum(arr)) > 0.0
    _, _, stale = streaming.refresh(st.hists, d, arr, st.staleness,
                                    st.selected_prev, cfg)
    np.testing.assert_allclose(np.asarray(stale), np.asarray(arr),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# Driver parity under streaming (acceptance criteria)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def stream_world():
    imgs, labs = synthetic.generate(0, samples_per_class=400)
    pspec = partition.PartitionSpec(num_devices=8, num_shards=60,
                                    shard_size=50)
    data = partition.partition(imgs, labs, seed=1, spec=pspec)
    net = wireless.sample_network(jax.random.key(0), 8, WCFG)
    mspec = paper_nets.PaperNetSpec(kind="mlp")
    params = paper_nets.init(jax.random.key(3), mspec)
    loss = functools.partial(paper_nets.loss_fn, spec=mspec)
    ev = functools.partial(paper_nets.accuracy, spec=mspec)
    return data, net, params, loss, ev


@pytest.mark.parametrize("method,process", [("das", "poisson"),
                                            ("abs", "drift")])
def test_scan_matches_legacy_under_streaming(stream_world, method,
                                             process):
    """Streaming runs must stay bit-for-bit identical between the scan
    driver and the legacy per-round loop (same contract as the static
    parity test, now with the StreamState in the carry)."""
    data, net, params, loss, ev = stream_world
    scfg = scheduler.SchedulerConfig(method=method, n_min=2,
                                     iterations_max=3,
                                     staleness_weight=0.25)
    fcfg = federated.FLConfig(
        num_rounds=3, batch_size=50, learning_rate=0.1,
        stream=streaming.StreamConfig(process=process, rate=25.0))
    kw = dict(init_params=params, loss_fn=loss, eval_fn=ev, data=data,
              net=net, wcfg=WCFG, scfg=scfg, fcfg=fcfg,
              key=jax.random.key(4))
    p_scan, h_scan = federated.run_federated(**kw)
    p_loop, h_loop = federated.run_federated_loop(**kw)
    assert len(h_scan) == len(h_loop)
    for a, b in zip(h_scan, h_loop):
        assert np.array_equal(a.selected, b.selected)
        assert a.round_time == b.round_time
        np.testing.assert_allclose(a.energy_total, b.energy_total,
                                   rtol=1e-6)
        if b.accuracy == b.accuracy:
            assert a.accuracy == b.accuracy
    for a, b in zip(jax.tree_util.tree_leaves(p_scan),
                    jax.tree_util.tree_leaves(p_loop)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batch_matches_independent_streaming_runs(stream_world):
    """S streaming scenarios through run_federated_batch == S independent
    run_federated calls, scenario by scenario."""
    data, _, params, loss, ev = stream_world
    s = 2
    nets = wireless.sample_networks(jax.random.key(21), s,
                                    data.num_devices, WCFG)
    keys = jax.random.split(jax.random.key(22), s)
    scfg = scheduler.SchedulerConfig(method="das", n_min=2,
                                     iterations_max=3,
                                     staleness_weight=0.25)
    fcfg = federated.FLConfig(
        num_rounds=3, batch_size=50, learning_rate=0.1,
        stream=streaming.StreamConfig(process="poisson", rate=25.0))
    p_b, metrics = federated.run_federated_batch(
        init_params=params, loss_fn=loss, eval_fn=ev, data=data,
        nets=nets, wcfg=WCFG, scfg=scfg, fcfg=fcfg, keys=keys)
    hists_b = federated.batch_metrics_to_records(metrics)
    for i in range(s):
        net_i = jax.tree_util.tree_map(lambda a, i=i: a[i], nets)
        p_i, hist_i = federated.run_federated(
            init_params=params, loss_fn=loss, eval_fn=ev, data=data,
            net=net_i, wcfg=WCFG, scfg=scfg, fcfg=fcfg, key=keys[i])
        for a, b in zip(hists_b[i], hist_i):
            assert np.array_equal(a.selected, b.selected)
            assert a.round_time == b.round_time
            if b.accuracy == b.accuracy:
                assert a.accuracy == b.accuracy
        for a, b in zip(jax.tree_util.tree_leaves(p_b),
                        jax.tree_util.tree_leaves(p_i)):
            np.testing.assert_array_equal(np.asarray(a[i]), np.asarray(b))


def test_kernel_refresh_matches_reference_in_driver(stream_world):
    """use_kernel=True routes the per-round refresh through the Pallas
    stream_update kernel; the whole run must match the jnp path."""
    data, net, params, loss, ev = stream_world
    scfg = scheduler.SchedulerConfig(method="das", n_min=2,
                                     iterations_max=3)
    outs = {}
    for use_kernel in (False, True):
        fcfg = federated.FLConfig(
            num_rounds=2, batch_size=50, learning_rate=0.1,
            stream=streaming.StreamConfig(process="poisson", rate=25.0,
                                          use_kernel=use_kernel))
        outs[use_kernel] = federated.run_federated(
            init_params=params, loss_fn=loss, eval_fn=ev, data=data,
            net=net, wcfg=WCFG, scfg=scfg, fcfg=fcfg,
            key=jax.random.key(4))
    for a, b in zip(outs[False][1], outs[True][1]):
        assert np.array_equal(a.selected, b.selected)
        np.testing.assert_allclose(a.round_time, b.round_time, rtol=1e-6)


# ---------------------------------------------------------------------------
# Regression: streaming refresh re-ranks where the round-0 snapshot fails
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _EnrichWorst:
    """Deterministic drift: the round-0 least-diverse device receives a
    flood of uniformly-spread arrivals every round (its environment
    changed), everyone else receives nothing."""

    rate: float = 400.0

    def init(self, key, hists0, cfg):
        del key
        gini = diversity.diversity_measure(hists0, "gini_simpson")
        target = jnp.argmin(gini, axis=-1)
        k = hists0.shape[-2]
        rates = jnp.where(jnp.arange(k) == target, self.rate, 0.0)
        return streaming.base_state(hists0, rates=rates)

    def sample(self, key, state, cfg):
        del key, cfg
        deltas = state.rates[..., None] * state.affinity
        return deltas, jnp.sum(deltas, axis=-1), state


streaming.register_process("enrich_worst_test", _EnrichWorst,
                           overwrite=True)


def test_round0_snapshot_vs_streaming_index_rank():
    """After drift, the index computed from refreshed stats ranks the
    enriched device top while the round-0 snapshot still ranks it last —
    the static scheduler is acting on stale data."""
    hists0 = jnp.asarray([[40.0, 0.0, 0.0, 0.0],      # single-class, poor
                          [12.0, 10.0, 9.0, 11.0],    # diverse
                          [20.0, 15.0, 0.0, 0.0],
                          [9.0, 0.0, 14.0, 8.0]])
    cfg = streaming.StreamConfig(process="enrich_worst_test")
    proc = streaming.get_process("enrich_worst_test")
    state = proc.init(jax.random.key(0), hists0, cfg)
    ages = jnp.zeros((4,), jnp.int32)
    idx0 = diversity.diversity_index(label_hists=hists0,
                                     data_sizes=jnp.sum(hists0, -1),
                                     ages=ages)
    assert int(jnp.argmin(idx0)) == 0
    stats = None
    for i in range(4):
        deltas, arr, state = proc.sample(jax.random.key(i), state, cfg)
        h, stats, stale = streaming.refresh(state.hists, deltas, arr,
                                            state.staleness,
                                            state.selected_prev, cfg)
        state = dataclasses.replace(state, hists=h, staleness=stale,
                                    round=state.round + 1)
    idx_t = diversity.diversity_index_from_stats(
        div=stats[..., 0], data_sizes=stats[..., 2], ages=ages)
    assert int(jnp.argmax(idx_t)) == 0          # streaming re-ranks
    assert int(jnp.argmax(idx0)) != 0           # snapshot never would


def test_das_rerank_under_drift(stream_world):
    """Driver-level acceptance: with the drift scenario, static round-0
    diversity never schedules the enriched device, while the streaming
    refresh re-ranks DAS onto it within a few rounds — the snapshot
    scheduler is provably picking the worse (stale) set once the device
    holds the richest data."""
    data, net, params, loss, ev = stream_world
    hists0 = federated.client_histograms(data, 10)
    gini0 = diversity.diversity_measure(hists0, "gini_simpson")
    target = int(jnp.argmin(gini0))
    weights = diversity.IndexWeights(diversity=0.7, size=0.2, age=0.1)
    scfg = scheduler.SchedulerConfig(method="das", n_min=2, n_fixed=2)
    sel_by_stream = {}
    for stream in (None, streaming.StreamConfig(
            process="enrich_worst_test")):
        fcfg = federated.FLConfig(num_rounds=5, batch_size=50,
                                  learning_rate=0.1,
                                  index_weights=weights, stream=stream)
        _, hist = federated.run_federated(
            init_params=params, loss_fn=loss, eval_fn=ev, data=data,
            net=net, wcfg=WCFG, scfg=scfg, fcfg=fcfg,
            key=jax.random.key(4), eval_every=5)
        sel_by_stream[stream is not None] = np.stack(
            [r.selected for r in hist])
    assert sel_by_stream[False][:, target].sum() == 0, \
        "static round-0 snapshot unexpectedly selected the drifting device"
    assert sel_by_stream[True][:, target].sum() >= 1, \
        "streaming refresh failed to re-rank DAS onto the enriched device"
    # And the two policies genuinely disagree on at least one round's set.
    assert np.any(sel_by_stream[False] != sel_by_stream[True])


# ---------------------------------------------------------------------------
# Staleness-aware scheduling hook
# ---------------------------------------------------------------------------

def test_staleness_hook_reranks_das_and_abs():
    k = 5
    net = wireless.sample_network(jax.random.key(2), k, WCFG)
    gains = wireless.sample_fading(jax.random.key(3), net)
    sizes = jnp.full((k,), 500)
    ages = jnp.full((k,), 3, jnp.int32)
    index = jnp.full((k,), 0.5)
    staleness = jnp.zeros((k,)).at[3].set(25.0)
    for method in ("das", "abs"):
        sch = scheduler.SchedulerConfig(method=method, n_min=1, n_fixed=1,
                                        staleness_weight=1.0)
        res = scheduler.schedule(jax.random.key(5), index, ages, sizes,
                                 gains, net, WCFG, sch, staleness)
        assert np.asarray(res.selected)[3] == 1.0, method


def test_staleness_hook_inert_at_zero_weight():
    k = 4
    net = wireless.sample_network(jax.random.key(2), k, WCFG)
    gains = wireless.sample_fading(jax.random.key(3), net)
    sizes = jnp.full((k,), 500)
    ages = jnp.asarray([0, 1, 2, 3], jnp.int32)
    index = jnp.linspace(0.2, 0.8, k)
    staleness = jnp.asarray([50.0, 0.0, 0.0, 0.0])
    sch = scheduler.SchedulerConfig(method="das", n_min=1, n_fixed=1)
    res_none = scheduler.schedule(jax.random.key(5), index, ages, sizes,
                                  gains, net, WCFG, sch)
    res_stale = scheduler.schedule(jax.random.key(5), index, ages, sizes,
                                   gains, net, WCFG, sch, staleness)
    np.testing.assert_array_equal(np.asarray(res_none.selected),
                                  np.asarray(res_stale.selected))


# ---------------------------------------------------------------------------
# Trace arrival process (ROADMAP trace-driven item, minimal version)
# ---------------------------------------------------------------------------

def test_trace_process_replays_deltas_and_wraps():
    k, c = 5, 4
    deltas = np.zeros((3, k, c), np.float32)
    deltas[0, :, 0] = 6.0
    deltas[1, :, 1] = 2.0
    deltas[2, :, 2] = -1.5
    proc = streaming.Trace(deltas)
    cfg = streaming.StreamConfig(process="trace")
    h0 = jnp.full((k, c), 10.0)
    state = proc.init(jax.random.key(0), h0, cfg)
    for r in range(5):                       # wraps past the trace end
        d, arr, state = proc.sample(jax.random.key(r), state, cfg)
        np.testing.assert_array_equal(np.asarray(d), deltas[r % 3])
        np.testing.assert_allclose(
            np.asarray(arr),
            np.sum(np.maximum(deltas[r % 3], 0.0), axis=-1))
        state = dataclasses.replace(state, round=state.round + 1)


def test_trace_process_traceable_and_vmappable():
    deltas = np.arange(2 * 3 * 4, dtype=np.float32).reshape(2, 3, 4)
    proc = streaming.Trace(deltas)
    cfg = streaming.StreamConfig(process="trace")
    h0 = jnp.ones((3, 4))

    def step(r):
        st = proc.init(jax.random.key(0), h0, cfg)
        st = dataclasses.replace(st, round=r)
        d, arr, _ = proc.sample(jax.random.key(1), st, cfg)
        return d, arr

    d_j, _ = jax.jit(step)(jnp.asarray(1, jnp.int32))
    np.testing.assert_array_equal(np.asarray(d_j), deltas[1])
    # Per-lane round counters under vmap select per-lane trace rows.
    d_v, _ = jax.vmap(step)(jnp.asarray([0, 1], jnp.int32))
    np.testing.assert_array_equal(np.asarray(d_v), deltas)


def test_trace_registration_with_real_deltas_end_to_end(stream_world):
    """The built-in ``"trace"`` name accepts a real delta array via the
    documented overwrite recipe and drives the scan driver; the
    data-less placeholder (and a wrong-rank array) still raise the
    recipe.  Replaces the placeholder-only registration test."""
    assert "trace" in streaming.process_names()
    proc = streaming.get_process("trace")
    with pytest.raises(ValueError, match="register_process"):
        proc.init(jax.random.key(0), jnp.ones((2, 3)),
                  streaming.StreamConfig(process="trace"))
    with pytest.raises(ValueError, match="\\(R, K, C\\)"):
        streaming.Trace(np.ones((4, 3))).init(
            jax.random.key(0), jnp.ones((4, 3)),
            streaming.StreamConfig(process="trace"))
    data, net, params, loss, ev = stream_world
    k = data.num_devices
    deltas = np.zeros((3, k, 10), np.float32)
    deltas[0, :, 1] = 25.0
    deltas[1, :, 7] = 10.0
    streaming.register_process(
        "trace", lambda: streaming.Trace(deltas), overwrite=True)
    try:
        fcfg = federated.FLConfig(
            num_rounds=3, batch_size=50, learning_rate=0.1,
            stream=streaming.StreamConfig(process="trace"))
        scfg = scheduler.SchedulerConfig(method="das", n_min=2,
                                         iterations_max=3)
        p, hist = federated.run_federated(
            init_params=params, loss_fn=loss, eval_fn=ev, data=data,
            net=net, wcfg=WCFG, scfg=scfg, fcfg=fcfg,
            key=jax.random.key(4))
        assert len(hist) == 3
        assert all(np.isfinite(r.round_time) for r in hist)
    finally:
        # restore the data-less placeholder for other tests
        streaming.register_process("trace", streaming.Trace,
                                   overwrite=True)


def test_usage_log_to_deltas_buckets_counts():
    """JSONL usage records bucket into (R, K, C): window assignment by
    timestamp, signed counts accumulate, out-of-range events and blank
    lines drop, the log-extent right edge lands in the last window."""
    records = [
        '{"t": 0.0, "device": 0, "class": 1, "count": 3}',
        '{"t": 0.1, "device": 0, "class": 1}',          # default count 1
        "",                                             # blank line
        {"t": 5.0, "device": 1, "class": 2, "count": -2},  # eviction
        '{"t": 9.9, "device": 1, "class": 0, "count": 4}',
        '{"t": 10.0, "device": 2, "class": 3}',   # == max(t): last window
        '{"t": 3.0, "device": 99, "class": 0}',   # device out of range
        '{"t": 3.0, "device": 0, "class": 99}',   # class out of range
    ]
    d = streaming.usage_log_to_deltas(records, num_rounds=2,
                                      num_devices=3, num_classes=4)
    assert d.shape == (2, 3, 4)
    assert d[0, 0, 1] == 4.0          # 3 + default 1, first window
    assert d[1, 1, 2] == -2.0         # signed eviction, second window
    assert d[1, 1, 0] == 4.0
    assert d[1, 2, 3] == 1.0          # right-edge event
    assert d.sum() == 4.0 + (-2.0) + 4.0 + 1.0
    # explicit span: events outside [t_start, t_end) drop
    d2 = streaming.usage_log_to_deltas(records, num_rounds=2,
                                       num_devices=3, num_classes=4,
                                       t_start=0.0, t_end=6.0)
    assert d2[1, 1, 0] == 0.0         # t=9.9 outside the span
    assert streaming.usage_log_to_deltas([], 2, 3, 4).sum() == 0.0


def test_trace_bank_placeholder_and_validation():
    assert "trace_bank" in streaming.process_names()
    proc = streaming.get_process("trace_bank")
    with pytest.raises(ValueError, match="register_process"):
        proc.init(jax.random.key(0), jnp.ones((2, 3)),
                  streaming.StreamConfig(process="trace_bank"))
    with pytest.raises(ValueError, match="\\(S_bank, R, K, C\\)"):
        streaming.TraceBank(np.ones((4, 2, 3))).init(
            jax.random.key(0), jnp.ones((2, 3)),
            streaming.StreamConfig(process="trace_bank"))
    with pytest.raises(ValueError, match="does not match"):
        streaming.TraceBank(np.ones((2, 4, 5, 6))).init(
            jax.random.key(0), jnp.ones((2, 3)),
            streaming.StreamConfig(process="trace_bank"))


def test_trace_bank_batch_matches_singles_bitwise(stream_world):
    """The batch driver under a trace bank: each scenario draws its own
    bank row off its scenario key (so at least two lanes replay
    different traces), and the S-scenario vmapped run equals the S
    single-scenario runs bit for bit."""
    data, _, params, loss, ev = stream_world
    k = data.num_devices
    rng = np.random.default_rng(3)
    logs = [[{"t": float(rng.uniform(0.0, 50.0)),
              "device": int(rng.integers(0, k)),
              "class": int(rng.integers(0, 10)),
              "count": int(rng.integers(1, 6))}
             for _ in range(60)] for _ in range(4)]
    bank = streaming.trace_bank(logs, num_rounds=3, num_devices=k,
                                num_classes=10, t_start=0.0, t_end=50.0)
    assert bank.shape == (4, 3, k, 10)
    streaming.register_process(
        "trace_bank", lambda: streaming.TraceBank(bank), overwrite=True)
    try:
        fcfg = federated.FLConfig(
            num_rounds=3, batch_size=50, learning_rate=0.1,
            stream=streaming.StreamConfig(process="trace_bank"))
        scfg = scheduler.SchedulerConfig(method="das", n_min=2,
                                         iterations_max=3)
        hists = federated.client_histograms(data, fcfg.num_classes)
        test_x = synthetic.to_float(data.test_images)
        s = 3
        nets = wireless.sample_networks(jax.random.key(21), s, k, WCFG)
        keys = federated.scenario_keys(jax.random.key(7), 0, s)
        batch = federated.make_feel_sim_batch(
            loss_fn=loss, eval_fn=ev, wcfg=WCFG, scfg=scfg, fcfg=fcfg,
            capacity=data.capacity)
        single = federated.make_feel_sim(
            loss_fn=loss, eval_fn=ev, wcfg=WCFG, scfg=scfg, fcfg=fcfg,
            capacity=data.capacity)
        args = (data.images, data.labels, data.mask, data.sizes, hists,
                test_x, data.test_labels)
        pb, mb = batch(params, *args, nets, keys)
        for i in range(s):
            net_i = jax.tree_util.tree_map(lambda a: a[i], nets)
            ps, ms = single(params, *args, net_i, keys[i])
            for a, b in zip(jax.tree_util.tree_leaves(pb),
                            jax.tree_util.tree_leaves(ps)):
                np.testing.assert_array_equal(np.asarray(a)[i],
                                              np.asarray(b))
            assert np.array_equal(np.asarray(mb.accuracy)[i],
                                  np.asarray(ms.accuracy),
                                  equal_nan=True)
        # the per-scenario draws actually vary.  The s=3 run above can
        # legitimately collide (3 draws over 4 rows), so check over a
        # wider key set, derived exactly as the driver derives the
        # stream-init key (split(scenario_key)[1]).
        proc = streaming.get_process("trace_bank")
        more = federated.scenario_keys(jax.random.key(7), 0, 8)
        rows = []
        for i in range(8):
            k_init = jax.random.split(more[i])[1]
            st = proc.init(k_init, hists, fcfg.stream)
            rows.append(np.asarray(st.bank))
        assert any(not np.array_equal(rows[0], r) for r in rows[1:])
    finally:
        streaming.register_process("trace_bank", streaming.TraceBank,
                                   overwrite=True)


def test_trace_process_in_both_drivers(stream_world):
    """A registered trace drives the scan driver and the legacy loop to
    the same bit-for-bit run, and the traced arrivals move the live
    histograms."""
    data, net, params, loss, ev = stream_world
    k = data.num_devices
    deltas = np.zeros((2, k, 10), np.float32)
    deltas[0, :, 0] = 30.0
    deltas[1, :, 5] = 12.0
    streaming.register_process(
        "trace_test", lambda: streaming.Trace(deltas), overwrite=True)
    scfg = scheduler.SchedulerConfig(method="das", n_min=2,
                                     iterations_max=3,
                                     staleness_weight=0.25)
    fcfg = federated.FLConfig(
        num_rounds=3, batch_size=50, learning_rate=0.1,
        stream=streaming.StreamConfig(process="trace_test"))
    kw = dict(init_params=params, loss_fn=loss, eval_fn=ev, data=data,
              net=net, wcfg=WCFG, scfg=scfg, fcfg=fcfg,
              key=jax.random.key(4))
    p_scan, h_scan = federated.run_federated(**kw)
    p_loop, h_loop = federated.run_federated_loop(**kw)
    for a, b in zip(h_scan, h_loop):
        assert np.array_equal(a.selected, b.selected)
        assert a.round_time == b.round_time
    for a, b in zip(jax.tree_util.tree_leaves(p_scan),
                    jax.tree_util.tree_leaves(p_loop)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
