"""Compressed-uplink subsystem: codecs, kernel, threading, driver parity.

Contracts under test (ISSUE 5 acceptance, DESIGN.md §9):

* ``kernels/compress.py`` == the ``kernels/ref.py::compress_update``
  oracle in interpret mode — quant + topk, including the all-zero and
  single-element edges, the batched ``(S, K, P)`` lane, and vmap of the
  single entry (the scenario-driver path through ``custom_vmap``)
* ``payload_bits`` is per-device end-to-end: ``upload_time`` /
  ``upload_energy`` / ``sub2_objective`` / ``min_time_allocation``
  accept a ``(K,)`` bits array with the scalar ``model_bits`` staying
  the working default
* the ``adaptive`` codec assigns fewer bits to weak-channel devices
  (regression pin)
* compressed runs with error feedback are bit-for-bit identical between
  the scan driver and the legacy loop, and the batched driver equals S
  independent runs
* e2e: ``quant`` at 8 bits reduces total transmission energy vs
  ``none`` at equal round count without degrading final accuracy by
  more than the EXPERIMENTS.md §Compression recorded tolerance (0.1)
* the codec registry mirrors the allocator/arrival-process registries
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import bandwidth as bw
from repro.core import compression, federated, scheduler, wireless
from repro.data import partition, synthetic
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref
from repro.models import paper_nets

WCFG = wireless.WirelessConfig()

# The EXPERIMENTS.md §Compression accuracy tolerance for the quant@8 vs
# none e2e comparison (probe values recorded there).
E2E_ACC_TOLERANCE = 0.1


def _compress_instance(seed: int, s: int, k: int, p: int,
                       bits: float = 8.0):
    u = jax.random.normal(jax.random.key(seed), (s, k, p))
    r = 0.2 * jax.random.normal(jax.random.key(seed + 1), (s, k, p))
    widths = jnp.full((s, k), bits, jnp.float32)
    sel = (jax.random.uniform(jax.random.key(seed + 2), (s, k)) > 0.5
           ).astype(jnp.float32)
    noise = jax.random.uniform(jax.random.key(seed + 3), (s, k, p))
    return u, r, widths, sel, noise


# ---------------------------------------------------------------------------
# Kernel vs oracle
# ---------------------------------------------------------------------------

@given(st.integers(1, 4), st.integers(2, 12), st.integers(2, 48),
       st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_compress_kernel_matches_oracle(s, k, p, seed):
    args = _compress_instance(seed % 1000, s, k, p)
    for mode, keep in (("quant", 0), ("topk", max(1, p // 4))):
        want = kernel_ref.compress_update(*args, mode=mode, keep=keep)
        got = kernel_ops.compress_update(*args, mode=mode, keep=keep)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-5, atol=1e-6)


def test_compress_kernel_mixed_widths_matches_oracle():
    """Per-device bit widths (the adaptive codec's shape) through the
    quant lane."""
    u, r, _, sel, noise = _compress_instance(5, 2, 6, 40)
    widths = jnp.asarray([[4.0, 6.0, 8.0, 10.0, 12.0, 5.0]] * 2)
    want = kernel_ref.compress_update(u, r, widths, sel, noise,
                                      mode="quant")
    got = kernel_ops.compress_update(u, r, widths, sel, noise,
                                     mode="quant")
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)


def test_compress_all_zero_and_single_element_edges():
    """All-zero rows compress to zeros with zero residual advance; a
    single-coordinate row reconstructs exactly under quant (it IS the
    row max) and survives topk keep=1."""
    for mode, keep in (("quant", 0), ("topk", 1)):
        u = jnp.zeros((1, 3, 1))
        u = u.at[0, 1, 0].set(2.5)
        r = jnp.zeros_like(u)
        widths = jnp.full((1, 3), 8.0)
        sel = jnp.ones((1, 3))
        noise = jax.random.uniform(jax.random.key(0), u.shape)
        c, new_r = kernel_ref.compress_update(u, r, widths, sel, noise,
                                              mode=mode, keep=keep)
        np.testing.assert_allclose(np.asarray(c), np.asarray(u),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(new_r), 0.0, atol=1e-6)
        ck, rk = kernel_ops.compress_update(u, r, widths, sel, noise,
                                            mode=mode, keep=keep)
        np.testing.assert_allclose(np.asarray(ck), np.asarray(c),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(rk), np.asarray(new_r),
                                   atol=1e-6)


def test_compress_error_feedback_semantics():
    """v = u + r; selected rows advance to v - c, unselected keep r."""
    u, r, widths, _, noise = _compress_instance(9, 1, 4, 16)
    sel = jnp.asarray([[1.0, 0.0, 1.0, 0.0]])
    c, new_r = kernel_ref.compress_update(u, r, widths, sel, noise,
                                          mode="quant")
    v = np.asarray(u + r)
    np.testing.assert_allclose(np.asarray(new_r[0, 0]),
                               v[0, 0] - np.asarray(c[0, 0]), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(new_r[0, 1]),
                                  np.asarray(r[0, 1]))


def test_topk_keeps_at_most_k_entries():
    u, r, widths, sel, noise = _compress_instance(11, 2, 5, 64)
    keep = 6
    c, _ = kernel_ref.compress_update(u, r, widths, sel, noise,
                                      mode="topk", keep=keep)
    nonzero = np.sum(np.asarray(c) != 0.0, axis=-1)
    assert np.all(nonzero <= keep)
    assert np.all(nonzero >= 1)


def test_compress_single_and_vmap_lane():
    """Single-instance entry == row of the batched lane == vmap of the
    single entry, and the custom_vmap rule (not pallas's generic
    batching) handled the scenario map."""
    args = _compress_instance(13, 3, 5, 24)
    got_b = kernel_ops.compress_update(*args, mode="quant")
    for i in range(3):
        got_1 = kernel_ops.compress_update(*(a[i] for a in args),
                                           mode="quant")
        for g1, gb in zip(got_1, got_b):
            np.testing.assert_array_equal(np.asarray(g1),
                                          np.asarray(gb[i]))
    before = kernel_ops.COMPRESS_LANE_TRACES
    got_v = jax.vmap(
        lambda *a: kernel_ops.compress_update(*a, mode="quant"))(*args)
    assert kernel_ops.COMPRESS_LANE_TRACES > before
    for gv, gb in zip(got_v, got_b):
        np.testing.assert_allclose(np.asarray(gv), np.asarray(gb),
                                   rtol=1e-6, atol=1e-7)


def test_quant_reconstruction_bounded_by_level():
    """|c - v| <= m / (2^b - 1) per coordinate — one quantization step."""
    u, r, widths, sel, noise = _compress_instance(17, 1, 4, 128)
    c, _ = kernel_ref.compress_update(u, r, widths, sel, noise,
                                      mode="quant")
    v = np.asarray(u + r)
    step = np.max(np.abs(v), axis=-1, keepdims=True) / (2.0 ** 8 - 1.0)
    assert np.all(np.abs(np.asarray(c) - v) <= step + 1e-6)


# ---------------------------------------------------------------------------
# payload_bits threading (acceptance: per-device end-to-end)
# ---------------------------------------------------------------------------

def _channel(k: int, seed: int = 0):
    net = wireless.sample_network(jax.random.key(seed), k, WCFG)
    gains = wireless.sample_fading(jax.random.key(seed + 1), net)
    sizes = jax.random.randint(jax.random.key(seed + 2), (k,), 50, 1500)
    t_train = wireless.train_time(sizes, net, WCFG)
    return net, gains, sizes, t_train


def test_upload_time_energy_accept_bits_array():
    net, gains, _, _ = _channel(6)
    alpha = jnp.full((6,), 1.0 / 6.0)
    bits = jnp.asarray([1e5, 5e4, 2.5e4, 1e5, 1e4, 7.5e4])
    t = wireless.upload_time(alpha, gains, net.tx_power, WCFG, bits)
    e = wireless.upload_energy(alpha, gains, net.tx_power, WCFG, bits)
    t_scalar = wireless.upload_time(alpha, gains, net.tx_power, WCFG)
    # Per-device: each row scales by its own bits / model_bits ratio.
    np.testing.assert_allclose(
        np.asarray(t), np.asarray(t_scalar * bits / WCFG.model_bits),
        rtol=1e-6)
    np.testing.assert_allclose(np.asarray(e),
                               np.asarray(net.tx_power * t), rtol=1e-6)
    # Scalar default unchanged: a full-payload array equals None bitwise.
    full = jnp.full((6,), WCFG.model_bits)
    np.testing.assert_array_equal(
        np.asarray(wireless.upload_time(alpha, gains, net.tx_power,
                                        WCFG, full)),
        np.asarray(t_scalar))


def test_sub2_objective_and_min_time_accept_bits_array():
    net, gains, _, t_train = _channel(8, seed=3)
    sel = jnp.ones((8,))
    bits = jnp.full((8,), WCFG.model_bits / 4.0)
    # Full-payload array == scalar default, bitwise.
    a_def, t_def = bw.min_time_allocation(sel, t_train, gains,
                                          net.tx_power, WCFG)
    a_full, t_full = bw.min_time_allocation(
        sel, t_train, gains, net.tx_power, WCFG,
        payload_bits=jnp.full((8,), WCFG.model_bits))
    np.testing.assert_array_equal(np.asarray(a_def), np.asarray(a_full))
    assert float(t_def) == float(t_full)
    # Smaller payloads finish strictly sooner and remain feasible.
    a_small, t_small = bw.min_time_allocation(sel, t_train, gains,
                                              net.tx_power, WCFG,
                                              payload_bits=bits)
    assert float(t_small) < float(t_def)
    assert float(jnp.sum(a_small)) <= 1.0 + 1e-5
    o_def = bw.sub2_objective(a_def, sel, t_train, gains, net.tx_power,
                              WCFG, rho=0.5)
    o_small = bw.sub2_objective(a_def, sel, t_train, gains, net.tx_power,
                                WCFG, rho=0.5, payload_bits=bits)
    assert float(o_small) < float(o_def)
    # pgd_allocation prices the bits too (objective drops with payload).
    _, po_def = bw.pgd_allocation(sel, t_train, gains, net.tx_power,
                                  WCFG, bw.Sub2Params.fast())
    _, po_small = bw.pgd_allocation(sel, t_train, gains, net.tx_power,
                                    WCFG, bw.Sub2Params.fast(),
                                    payload_bits=bits)
    assert float(po_small) < float(po_def)


def test_schedule_prices_post_compression_bits():
    """The realized ScheduleResult accounting follows the payload: the
    same decision inputs with 4x smaller uplinks must report lower
    per-device energy for the selected set."""
    k = 10
    net, gains, sizes, _ = _channel(k, seed=5)
    ages = jnp.zeros((k,), jnp.int32)
    index = jnp.linspace(0.2, 0.8, k)
    sch = scheduler.SchedulerConfig(method="full")
    res_full = scheduler.schedule(jax.random.key(1), index, ages, sizes,
                                  gains, net, WCFG, sch)
    res_comp = scheduler.schedule(jax.random.key(1), index, ages, sizes,
                                  gains, net, WCFG, sch, None,
                                  jnp.full((k,), WCFG.model_bits / 4.0))
    assert float(jnp.sum(res_comp.energy)) \
        < float(jnp.sum(res_full.energy))
    assert float(res_comp.round_time) <= float(res_full.round_time)


# ---------------------------------------------------------------------------
# Codec registry + adaptive regression
# ---------------------------------------------------------------------------

def test_codec_registry_errors():
    assert {"none", "quant", "topk",
            "adaptive"} <= set(compression.codec_names())
    with pytest.raises(ValueError, match="unknown codec"):
        compression.get_codec("nope")
    with pytest.raises(ValueError, match="already registered"):
        compression.register_codec("quant", compression.Quant)


def test_payload_bits_per_codec():
    ccfg = compression.CompressionConfig(bit_width=8, topk_frac=0.1)
    gains = jnp.ones((4,))
    index = jnp.linspace(0.0, 1.0, 4)
    # none -> None: the nominal scalar payload (keeps solvers on their
    # scalar path, fused_pgd kernel lane included).
    assert compression.get_codec("none").payload_bits(
        ccfg, WCFG, gains, index) is None
    q_bits = compression.get_codec("quant").payload_bits(
        ccfg, WCFG, gains, index)
    np.testing.assert_allclose(np.asarray(q_bits),
                               WCFG.model_bits * 8.0 / 32.0)
    t_bits = compression.get_codec("topk").payload_bits(
        ccfg, WCFG, gains, index)
    idx_bits = compression.topk_index_bits(ccfg, WCFG)
    assert idx_bits == np.ceil(np.log2(WCFG.model_bits / 32.0))
    np.testing.assert_allclose(
        np.asarray(t_bits),
        WCFG.model_bits * 0.1 * (32.0 + idx_bits) / 32.0)


def test_adaptive_assigns_fewer_bits_to_weak_channels():
    """Regression pin: with diversity held equal, bit width is monotone
    in channel gain — the weakest channel gets the floor width, the
    strongest the ceiling."""
    ccfg = compression.CompressionConfig(codec="adaptive",
                                         adaptive_min_bits=4,
                                         adaptive_max_bits=12,
                                         adaptive_channel_weight=1.0)
    gains = jnp.asarray([1e-9, 5e-8, 2e-7, 1e-6, 4e-6])
    index = jnp.full((5,), 0.5)
    widths = compression.adaptive_bit_widths(ccfg, gains, index)
    w = np.asarray(widths)
    assert np.all(np.diff(w) >= 0.0)          # monotone in gain
    assert w[0] == 4.0 and w[-1] == 12.0
    # And the per-device payload follows the widths.
    bits = compression.get_codec("adaptive").payload_bits(
        ccfg, WCFG, gains, index)
    np.testing.assert_allclose(np.asarray(bits),
                               WCFG.model_bits * w / 32.0)
    # Diversity rank matters at channel_weight < 1: richer data earns
    # more bits on an equal channel.
    ccfg_mix = compression.CompressionConfig(
        codec="adaptive", adaptive_channel_weight=0.0)
    widths_div = compression.adaptive_bit_widths(
        ccfg_mix, jnp.full((5,), 1e-7), jnp.linspace(0.1, 0.9, 5))
    assert np.all(np.diff(np.asarray(widths_div)) >= 0.0)


# ---------------------------------------------------------------------------
# Driver parity + e2e acceptance
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def comp_world():
    imgs, labs = synthetic.generate(0, samples_per_class=400)
    pspec = partition.PartitionSpec(num_devices=8, num_shards=60,
                                    shard_size=50)
    data = partition.partition(imgs, labs, seed=1, spec=pspec)
    net = wireless.sample_network(jax.random.key(0), 8, WCFG)
    mspec = paper_nets.PaperNetSpec(kind="mlp")
    params = paper_nets.init(jax.random.key(3), mspec)
    loss = functools.partial(paper_nets.loss_fn, spec=mspec)
    ev = functools.partial(paper_nets.accuracy, spec=mspec)
    return data, net, params, loss, ev


def _fcfg(codec: str, rounds: int = 3,
          **comp_kw) -> federated.FLConfig:
    return federated.FLConfig(
        num_rounds=rounds, batch_size=50, learning_rate=0.1,
        compression=compression.CompressionConfig(codec=codec,
                                                  bit_width=8,
                                                  **comp_kw))


@pytest.mark.parametrize("codec", ["quant", "topk", "adaptive"])
def test_scan_matches_legacy_under_compression(comp_world, codec):
    """Compressed runs with error feedback must stay bit-for-bit
    identical between the scan driver (residual in the scan carry) and
    the legacy per-round loop."""
    data, net, params, loss, ev = comp_world
    scfg = scheduler.SchedulerConfig(method="das", n_min=2,
                                     iterations_max=3)
    kw = dict(init_params=params, loss_fn=loss, eval_fn=ev, data=data,
              net=net, wcfg=WCFG, scfg=scfg, fcfg=_fcfg(codec),
              key=jax.random.key(4))
    p_scan, h_scan = federated.run_federated(**kw)
    p_loop, h_loop = federated.run_federated_loop(**kw)
    assert len(h_scan) == len(h_loop)
    for a, b in zip(h_scan, h_loop):
        assert np.array_equal(a.selected, b.selected)
        assert a.round_time == b.round_time
        np.testing.assert_allclose(a.energy_total, b.energy_total,
                                   rtol=1e-6)
        if b.accuracy == b.accuracy:
            assert a.accuracy == b.accuracy
    for a, b in zip(jax.tree_util.tree_leaves(p_scan),
                    jax.tree_util.tree_leaves(p_loop)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batch_matches_independent_compressed_runs(comp_world):
    """S compressed scenarios through run_federated_batch == S
    independent run_federated calls (the error-feedback residual rides
    the vmapped carry per lane)."""
    data, _, params, loss, ev = comp_world
    s = 2
    nets = wireless.sample_networks(jax.random.key(21), s,
                                    data.num_devices, WCFG)
    keys = jax.random.split(jax.random.key(22), s)
    scfg = scheduler.SchedulerConfig(method="das", n_min=2,
                                     iterations_max=3)
    fcfg = _fcfg("quant")
    p_b, metrics = federated.run_federated_batch(
        init_params=params, loss_fn=loss, eval_fn=ev, data=data,
        nets=nets, wcfg=WCFG, scfg=scfg, fcfg=fcfg, keys=keys)
    hists_b = federated.batch_metrics_to_records(metrics)
    for i in range(s):
        net_i = jax.tree_util.tree_map(lambda a, i=i: a[i], nets)
        p_i, hist_i = federated.run_federated(
            init_params=params, loss_fn=loss, eval_fn=ev, data=data,
            net=net_i, wcfg=WCFG, scfg=scfg, fcfg=fcfg, key=keys[i])
        for a, b in zip(hists_b[i], hist_i):
            assert np.array_equal(a.selected, b.selected)
            assert a.round_time == b.round_time
            if b.accuracy == b.accuracy:
                assert a.accuracy == b.accuracy
        for a, b in zip(jax.tree_util.tree_leaves(p_b),
                        jax.tree_util.tree_leaves(p_i)):
            np.testing.assert_array_equal(np.asarray(a[i]),
                                          np.asarray(b))


def test_kernel_compress_matches_reference_in_driver(comp_world):
    """use_kernel=True routes the round's uplink through the Pallas
    compress kernel; the whole run must match the jnp path."""
    data, net, params, loss, ev = comp_world
    scfg = scheduler.SchedulerConfig(method="das", n_min=2,
                                     iterations_max=3)
    outs = {}
    for use_kernel in (False, True):
        kw = dict(init_params=params, loss_fn=loss, eval_fn=ev,
                  data=data, net=net, wcfg=WCFG, scfg=scfg,
                  fcfg=_fcfg("quant", rounds=2, use_kernel=use_kernel),
                  key=jax.random.key(4))
        outs[use_kernel] = federated.run_federated(**kw)
    for a, b in zip(outs[False][1], outs[True][1]):
        assert np.array_equal(a.selected, b.selected)
        np.testing.assert_allclose(a.round_time, b.round_time, rtol=1e-6)
        np.testing.assert_allclose(a.energy_total, b.energy_total,
                                   rtol=1e-5)


def test_quant8_cuts_energy_without_accuracy_loss_e2e(comp_world):
    """Acceptance: quant@8 vs none at equal round count — total
    transmission energy drops (the payload is 4x smaller and the
    schedulers price it), final accuracy within the EXPERIMENTS.md
    §Compression tolerance."""
    data, net, params, loss, ev = comp_world
    scfg = scheduler.SchedulerConfig(method="das", n_min=2,
                                     iterations_max=3)
    out = {}
    for codec in ("none", "quant"):
        _, hist = federated.run_federated(
            init_params=params, loss_fn=loss, eval_fn=ev, data=data,
            net=net, wcfg=WCFG, scfg=scfg, fcfg=_fcfg(codec, rounds=5),
            key=jax.random.key(4), eval_every=5)
        out[codec] = (sum(r.energy_total for r in hist),
                      hist[-1].accuracy)
    e_none, acc_none = out["none"]
    e_quant, acc_quant = out["quant"]
    assert e_quant < 0.5 * e_none, (e_quant, e_none)
    assert acc_none - acc_quant <= E2E_ACC_TOLERANCE, out


def test_error_feedback_off_still_runs_and_differs(comp_world):
    """error_feedback=False is the biased compressor: same plumbing, no
    residual accumulation — the two settings genuinely diverge."""
    data, net, params, loss, ev = comp_world
    scfg = scheduler.SchedulerConfig(method="das", n_min=2,
                                     iterations_max=3)
    leaves = {}
    for ef in (True, False):
        p, _ = federated.run_federated(
            init_params=params, loss_fn=loss, eval_fn=ev, data=data,
            net=net, wcfg=WCFG, scfg=scfg,
            fcfg=_fcfg("quant", rounds=2, error_feedback=ef),
            key=jax.random.key(4), eval_every=2)
        leaves[ef] = jax.tree_util.tree_leaves(p)
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(leaves[True], leaves[False]))
