"""Run-observatory tests (DESIGN.md §14): Jain oracle vs NumPy, the
signals group's observer-purity (signals-only on vs telemetry=None,
bitwise, across every driver composition), batch==singles on every new
signal leaf, the cross-run metrics store round-trip, the regression
gate's 0/1/2 exit contract, and non-finite-float JSONL normalization."""

import dataclasses
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry
from repro.core import (compression, events, faults, federated,
                        scheduler, streaming, wireless)
from repro.data import partition, synthetic
from repro.models import paper_nets
from repro.telemetry import compare as compare_lib
from repro.telemetry import health
from repro.telemetry import report as report_lib
from repro.telemetry import sinks
from repro.telemetry import store as store_lib


# ---------------------------------------------------------------------------
# Fixtures (same tiny world as test_telemetry; compiles dominate runtime)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def world():
    imgs, labs = synthetic.generate(0, samples_per_class=200)
    data = partition.partition(
        imgs, labs, seed=1,
        spec=partition.PartitionSpec(num_devices=8, num_shards=36,
                                     shard_size=50))
    mspec = paper_nets.PaperNetSpec(kind="mlp", mlp_hidden=8)
    params = paper_nets.init(jax.random.key(3), mspec)
    loss = functools.partial(paper_nets.loss_fn, spec=mspec)
    ev = functools.partial(paper_nets.accuracy, spec=mspec)
    return data, params, loss, ev


WCFG = wireless.WirelessConfig()
SCFG = scheduler.SchedulerConfig(method="das", n_min=2, iterations_max=3,
                                 reliability_weight=0.4)
FL = federated.FLConfig(num_rounds=3, batch_size=50, learning_rate=0.1)

# The signals group alone: every other telemetry family off.
SIG_ONLY = telemetry.TelemetryConfig(scores=False, sub2=False,
                                     transport=False, faults=False,
                                     events=False, signals=True)

COMPOSITIONS = {
    "plain": {},
    "faulty": {"faults": faults.FaultConfig(drop_prob=0.3, max_retries=2,
                                            reliability_ema=0.3)},
    "compressed": {"compression": compression.CompressionConfig(
        codec="quant", bit_width=8)},
    "streaming": {"stream": streaming.StreamConfig()},
    "dispatch": {"dispatch_cap": 4},
    "async": {"events": events.EventConfig(availability="churn",
                                           buffer_size=2,
                                           tick_horizon=0.5,
                                           num_events=4),
              "faults": faults.FaultConfig(reliability_ema=0.3)},
}


def _run_kwargs(world):
    data, params, loss, ev = world
    net = wireless.sample_network(jax.random.key(0), data.num_devices,
                                  WCFG)
    return dict(init_params=params, loss_fn=loss, eval_fn=ev, data=data,
                net=net, wcfg=WCFG, scfg=SCFG, key=jax.random.key(42))


def _same_tree(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


# ---------------------------------------------------------------------------
# Jain oracle vs NumPy
# ---------------------------------------------------------------------------

def _jain_np(x):
    x = np.asarray(x, np.float64)
    ss = (x * x).sum()
    return 1.0 if ss <= 0 else (x.sum() ** 2) / (x.size * ss)


def test_jain_oracle_edge_cases():
    for k in (1, 4, 100):
        # All-equal share -> perfectly fair.
        assert float(health.jain_index(jnp.full((k,), 3.0))) \
            == pytest.approx(1.0)
        # Single participant -> 1/K.
        one = jnp.zeros((k,)).at[0].set(7.0)
        assert float(health.jain_index(one)) == pytest.approx(1.0 / k)
    # All-zero (no uploads yet) is defined as fair, not 0/0.
    assert float(health.jain_index(jnp.zeros((5,)))) == 1.0


def test_jain_oracle_random_vs_numpy():
    rng = np.random.default_rng(0)
    for _ in range(5):
        x = rng.uniform(0.0, 10.0, size=16).astype(np.float32)
        assert float(health.jain_index(jnp.asarray(x))) \
            == pytest.approx(_jain_np(x), rel=1e-5)


def test_signal_update_semantics():
    st = health.signal_init(4)
    ok = jnp.array([1.0, 0.0, 1.0, 0.0])
    ld = jnp.array([0.5, 9.0, -0.1, 9.0])
    un = jnp.array([1.0, 9.0, 2.0, 9.0])
    en = jnp.array([0.2, 0.0, 0.3, 0.0])
    st = health.signal_update(st, ok, ld, un, en)
    # Last-observed fields move only on delivered lanes.
    np.testing.assert_allclose(np.asarray(st.loss_delta),
                               [0.5, 0.0, -0.1, 0.0], rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(st.update_norm),
                                  [1.0, 0.0, 2.0, 0.0])
    np.testing.assert_array_equal(np.asarray(st.participation),
                                  [1, 0, 1, 0])
    st = health.signal_update(st, jnp.array([0.0, 1.0, 1.0, 0.0]),
                              ld, un, en)
    np.testing.assert_array_equal(np.asarray(st.participation),
                                  [1, 1, 2, 0])
    # Device 0 sat out round 2: its last-observed value is retained.
    assert float(st.loss_delta[0]) == pytest.approx(0.5)
    assert float(st.energy[2]) == pytest.approx(0.6)
    agg = health.signals_aggregates(st, ld, jnp.array([0., 1., 1., 0.])
                                    > 0.0)
    assert int(agg["starved"]) == 1
    assert int(agg["div_nonfinite"]) == 0
    assert int(agg["div_exploding"]) == 0


def test_divergence_sentinels_fire():
    st = health.signal_init(3)
    hit = jnp.array([True, True, True])
    ld = jnp.array([jnp.nan, 100.0, 0.1])
    agg = health.signals_aggregates(st, ld, hit)
    assert int(agg["div_nonfinite"]) == 1
    assert int(agg["div_exploding"]) == 1


# ---------------------------------------------------------------------------
# Observer purity: signals on vs telemetry=None, bitwise, every driver
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("comp", sorted(COMPOSITIONS))
def test_signals_only_bitwise(world, comp):
    kw = _run_kwargs(world)
    fcfg = dataclasses.replace(FL, **COMPOSITIONS[comp])
    p0, h0 = federated.run_federated(fcfg=fcfg, **kw)
    p1, h1, frames = federated.run_federated(
        fcfg=dataclasses.replace(fcfg, telemetry=SIG_ONLY), **kw)
    assert _same_tree(p0, p1)
    for a, b in zip(h0, h1):
        assert a.accuracy == b.accuracy
        assert a.energy_total == b.energy_total
        assert np.array_equal(a.selected, b.selected)
    # Every signal leaf is present with a full round axis.
    assert set(health.SIGNAL_LEAVES) <= set(frames)
    n = federated.sim_length(fcfg)
    for name in health.SIGNAL_LEAVES:
        assert np.asarray(frames[name]).shape[0] == n, name


def test_signal_frames_sane(world):
    kw = _run_kwargs(world)
    _, hist, frames = federated.run_federated(
        fcfg=dataclasses.replace(FL, telemetry=SIG_ONLY), **kw)
    k = kw["data"].num_devices
    part = np.asarray(frames["sig_participation"])
    deliv = np.asarray(frames["delivered"])
    # The carry snapshot is the cumulative delivered count.
    np.testing.assert_array_equal(part, np.cumsum(deliv, axis=0))
    # Cumulative energy matches the history's realized totals.
    eng = np.asarray(frames["sig_energy_cum"])[-1]
    assert eng.sum() == pytest.approx(
        sum(r.energy_total for r in hist), rel=1e-5)
    # Jain over all-delivered rounds stays in (0, 1].
    jp = np.asarray(frames["jain_participation"])
    assert ((jp > 0.0) & (jp <= 1.0 + 1e-6)).all()
    starved = np.asarray(frames["starved"])
    assert ((starved >= 0) & (starved <= k)).all()
    assert (np.diff(starved) <= 0).all()       # starved set only shrinks
    # A healthy 3-round MLP run never trips the divergence sentinels.
    assert np.asarray(frames["div_nonfinite"]).sum() == 0
    assert np.asarray(frames["div_exploding"]).sum() == 0
    # Delivered devices report this-round observations; the masked
    # leaves are zero off the delivered set.
    ld = np.asarray(frames["sig_loss_delta"])
    assert (ld[deliv <= 0.0] == 0.0).all()


def test_signal_norm_masked_to_delivered(world):
    # Trained lanes moved (positive norm); frozen lanes are exactly 0
    # by the masked-frame contract.
    kw = _run_kwargs(world)
    fcfg = dataclasses.replace(FL, num_rounds=1, telemetry=SIG_ONLY)
    _, _, frames = federated.run_federated(fcfg=fcfg, **kw)
    un = np.asarray(frames["sig_update_norm"])[0]
    deliv = np.asarray(frames["delivered"])[0]
    assert (un[deliv > 0.0] > 0.0).all()
    assert (un[deliv <= 0.0] == 0.0).all()


def test_signal_batch_matches_singles(world):
    data, params, loss, ev = world
    s = 3
    fcfg = dataclasses.replace(
        FL, faults=COMPOSITIONS["faulty"]["faults"], telemetry=SIG_ONLY)
    nets = wireless.sample_networks(jax.random.key(5), s,
                                    data.num_devices, WCFG)
    keys = federated.scenario_keys(jax.random.key(11), 0, s)
    _, _, frames_b = federated.run_federated_batch(
        init_params=params, loss_fn=loss, eval_fn=ev, data=data,
        nets=nets, wcfg=WCFG, scfg=SCFG, fcfg=fcfg, keys=keys)
    for i in range(s):
        net_i = jax.tree_util.tree_map(lambda a, i=i: a[i], nets)
        _, _, frames_i = federated.run_federated(
            init_params=params, loss_fn=loss, eval_fn=ev, data=data,
            net=net_i, wcfg=WCFG, scfg=SCFG, fcfg=fcfg, key=keys[i])
        for name in health.SIGNAL_LEAVES:
            a = np.asarray(frames_b[name][i])
            b = np.asarray(frames_i[name])
            assert np.array_equal(a, b), name


# ---------------------------------------------------------------------------
# Cross-run metrics store
# ---------------------------------------------------------------------------

def test_run_summary_values():
    acc = np.array([np.nan, 0.5, np.nan, 0.9])
    sel = np.tile(np.array([[1.0, 1.0, 0.0, 0.0]]), (4, 1))
    eng = np.tile(np.array([[0.5, 0.5, 0.0, 0.0]]), (4, 1))
    m = store_lib.run_summary(accuracy=acc, selected=sel, energy=eng,
                              target_accuracy=0.85,
                              timings={"steady_s_per_round": 0.1,
                                       "compile_s": np.nan})
    assert m["final_acc"] == pytest.approx(0.9)
    assert m["rounds_to_target"] == 4        # first reach at index 3
    assert m["total_energy_j"] == pytest.approx(4.0)
    assert m["energy_per_device_j"] == pytest.approx(1.0)
    # Two of four devices participate equally -> Jain = 0.5.
    assert m["jain_participation"] == pytest.approx(0.5)
    assert m["jain_energy"] == pytest.approx(0.5)
    assert m["steady_s_per_round"] == pytest.approx(0.1)
    assert m["compile_s"] is None            # NaN timing -> None
    # Never reaches target / never evaluated.
    m2 = store_lib.run_summary(accuracy=np.full(4, np.nan),
                               selected=sel, energy=eng)
    assert m2["final_acc"] is None
    assert m2["rounds_to_target"] is None


def test_store_append_and_load(tmp_path):
    path = str(tmp_path / "store.jsonl")
    m = {"final_acc": 0.9, "total_energy_j": 4.0}
    rec = store_lib.append_run(path, m, run="smoke", configs=(FL,))
    assert rec["schema_version"] == store_lib.SCHEMA_VERSION
    assert rec["config_fingerprint"] == sinks.config_fingerprint(FL)
    store_lib.append_run(path, {"final_acc": 0.95}, run="other")
    hist = store_lib.load_history(path)
    assert len(hist) == 2
    assert store_lib.latest(path, run="smoke")["metrics"]["final_acc"] \
        == 0.9
    assert store_lib.latest(path)["run"] == "other"
    # Non-run records are skipped, torn tails tolerated.
    with open(path, "a") as f:
        f.write('{"kind": "note"}\n')
        f.write('{"kind": "run", "torn')
    assert len(store_lib.load_history(path)) == 2


def test_sanitize_nonfinite_to_null(tmp_path):
    path = str(tmp_path / "nan.jsonl")
    sinks.jsonl_append(path, {
        "a": float("nan"), "b": float("inf"),
        "nest": {"c": [1.0, float("-inf"), "s"]},
        "arr": np.array([1.0, np.nan])})
    raw = open(path).read()
    assert "NaN" not in raw and "Infinity" not in raw
    rec = json.loads(raw)
    assert rec["a"] is None and rec["b"] is None
    assert rec["nest"]["c"] == [1.0, None, "s"]
    assert rec["arr"] == [1.0, None]


# ---------------------------------------------------------------------------
# Regression gate: exit 0 / 1 / 2
# ---------------------------------------------------------------------------

_BASE_METRICS = {
    "final_acc": 0.90, "rounds_to_target": 5, "total_energy_j": 10.0,
    "energy_per_device_j": 1.25, "jain_participation": 0.8,
    "jain_energy": 0.75, "steady_s_per_round": 0.1, "compile_s": 2.0,
}


def _write_rec(path, metrics, **over):
    rec = store_lib.run_record(metrics, run=over.pop("run", "smoke"))
    rec.update(over)
    with open(path, "w") as f:
        f.write(json.dumps(sinks.sanitize(rec)) + "\n")
    return str(path)


def test_compare_self_is_ok(tmp_path, capsys):
    p = _write_rec(tmp_path / "a.json", _BASE_METRICS)
    assert compare_lib.main([p, p]) == compare_lib.EXIT_OK
    assert "verdict: OK" in capsys.readouterr().out


def test_compare_regression_exits_1(tmp_path, capsys):
    base = _write_rec(tmp_path / "base.json", _BASE_METRICS)
    cur = _write_rec(tmp_path / "cur.json",
                     {**_BASE_METRICS, "final_acc": 0.80})  # -0.10 > 0.05
    assert compare_lib.main([base, cur]) == compare_lib.EXIT_REGRESSION
    out = capsys.readouterr().out
    assert "REGRESSED" in out and "final_acc" in out


def test_compare_improvement_passes(tmp_path):
    base = _write_rec(tmp_path / "base.json", _BASE_METRICS)
    cur = _write_rec(tmp_path / "cur.json",
                     {**_BASE_METRICS, "final_acc": 0.99,
                      "total_energy_j": 5.0})
    assert compare_lib.main([base, cur]) == compare_lib.EXIT_OK


def test_compare_schema_drift_exits_2(tmp_path):
    base = _write_rec(tmp_path / "base.json", _BASE_METRICS)
    # Version bump.
    drift = _write_rec(tmp_path / "v2.json", _BASE_METRICS,
                       schema_version=store_lib.SCHEMA_VERSION + 1)
    assert compare_lib.main([base, drift]) == compare_lib.EXIT_SCHEMA
    # Gated metric vanished.
    missing = {k: v for k, v in _BASE_METRICS.items()
               if k != "final_acc"}
    gone = _write_rec(tmp_path / "gone.json", missing)
    assert compare_lib.main([base, gone]) == compare_lib.EXIT_SCHEMA
    # Empty / unreadable inputs.
    empty = tmp_path / "empty.json"
    empty.write_text("")
    assert compare_lib.main([str(empty), base]) == compare_lib.EXIT_SCHEMA
    assert compare_lib.main([base, str(tmp_path / "nope.json")]) \
        == compare_lib.EXIT_SCHEMA


def test_compare_null_metric_regresses(tmp_path):
    # A metric that diverged to NaN serializes as null and gates.
    base = _write_rec(tmp_path / "base.json", _BASE_METRICS)
    cur = _write_rec(tmp_path / "cur.json",
                     {**_BASE_METRICS, "final_acc": float("nan")})
    assert compare_lib.main([base, cur]) == compare_lib.EXIT_REGRESSION


def test_compare_timings_ungated_unless_promoted(tmp_path):
    base = _write_rec(tmp_path / "base.json", _BASE_METRICS)
    cur = _write_rec(tmp_path / "cur.json",
                     {**_BASE_METRICS, "steady_s_per_round": 10.0})
    assert compare_lib.main([base, cur]) == compare_lib.EXIT_OK
    assert compare_lib.main([base, cur, "--gate-timings"]) \
        == compare_lib.EXIT_REGRESSION


def test_compare_tol_override_and_json(tmp_path, capsys):
    base = _write_rec(tmp_path / "base.json", _BASE_METRICS)
    cur = _write_rec(tmp_path / "cur.json",
                     {**_BASE_METRICS, "final_acc": 0.80})
    assert compare_lib.main([base, cur, "--tol", "final_acc=0.2",
                             "--json"]) == compare_lib.EXIT_OK
    payload = json.loads(capsys.readouterr().out)
    assert payload["regressed"] is False
    names = {v["metric"] for v in payload["verdicts"]}
    assert "final_acc" in names
    assert compare_lib.main([base, cur, "--tol", "bogus=1"]) \
        == compare_lib.EXIT_SCHEMA


def test_compare_reads_jsonl_store_latest(tmp_path):
    store = str(tmp_path / "store.jsonl")
    store_lib.append_run(store, {**_BASE_METRICS, "final_acc": 0.2},
                         run="smoke")
    store_lib.append_run(store, _BASE_METRICS, run="smoke")  # latest wins
    base = _write_rec(tmp_path / "base.json", _BASE_METRICS)
    assert compare_lib.main([base, store, "--run", "smoke"]) \
        == compare_lib.EXIT_OK
    assert compare_lib.main([base, store, "--run", "absent"]) \
        == compare_lib.EXIT_SCHEMA


# ---------------------------------------------------------------------------
# End-to-end: sim -> store -> gate; report --json
# ---------------------------------------------------------------------------

def test_sim_to_store_to_gate(world, tmp_path):
    kw = _run_kwargs(world)
    _, hist = federated.run_federated(fcfg=FL, **kw)
    acc = np.array([r.accuracy for r in hist])
    sel = np.stack([np.asarray(r.selected) for r in hist])
    eng_total = np.array([r.energy_total for r in hist])
    # History has no per-device energy; spread totals evenly over the
    # selected set — good enough for the store round-trip under test.
    eng = sel * (eng_total / np.maximum(sel.sum(axis=1), 1.0))[:, None]
    summary = store_lib.run_summary(accuracy=acc, selected=sel,
                                    energy=eng,
                                    timings={"steady_s_per_round": 0.01,
                                             "compile_s": 1.0})
    store = str(tmp_path / "store.jsonl")
    store_lib.append_run(store, summary, run="e2e",
                         configs=(FL, WCFG, SCFG))
    assert compare_lib.main([store, store, "--run", "e2e"]) \
        == compare_lib.EXIT_OK


def test_sweep_appends_store_records(world, tmp_path):
    from repro.sweep import grid as grid_lib
    from repro.sweep import runner as runner_lib

    data, params, loss, ev = world
    fl = dataclasses.replace(FL, num_rounds=2)
    spec = grid_lib.SweepSpec(
        fl=fl, sched=SCFG, wireless=WCFG,
        axes=(grid_lib.Axis("sched", "method", ("das", "random")),),
        scenarios_per_point=2, base_seed=0)
    store = str(tmp_path / "store.jsonl")
    out = runner_lib.run_sweep(spec, data=data, loss_fn=loss, eval_fn=ev,
                               init_params=params, use_sharding=False,
                               store_path=store)
    assert len(out) == 2
    hist = store_lib.load_history(store)
    assert len(hist) == 2
    for rec in hist:
        assert rec["run"].startswith("sweep/")
        assert rec["schema_version"] == store_lib.SCHEMA_VERSION
        m = rec["metrics"]
        assert m["final_acc"] is not None
        assert m["total_energy_j"] > 0.0
        # Sweep aggregates hold no per-device arrays: fairness metrics
        # are absent on both sides, which the gate treats as
        # not-measured (self-compare stays exit 0).
        assert "jain_participation" not in m
    assert compare_lib.main([store, store,
                             "--run", hist[0]["run"]]) \
        == compare_lib.EXIT_OK


def test_report_json_mode(world, tmp_path, capsys):
    data, params, loss, ev = world
    fcfg = dataclasses.replace(FL, telemetry=telemetry.TelemetryConfig())
    net = wireless.sample_network(jax.random.key(0), data.num_devices,
                                  WCFG)
    sim = federated.make_feel_sim(loss_fn=loss, eval_fn=ev, wcfg=WCFG,
                                  scfg=SCFG, fcfg=fcfg,
                                  capacity=data.capacity)
    hists = federated.client_histograms(data, fcfg.num_classes)
    test_x = synthetic.to_float(data.test_images)
    _, metrics, frames = sim(params, data.images, data.labels, data.mask,
                             data.sizes, hists, test_x,
                             data.test_labels, net, jax.random.key(42))
    log = tmp_path / "run.jsonl"
    sinks.write_round_frames(str(log), frames, metrics=metrics,
                             manifest=sinks.run_manifest(fcfg, WCFG,
                                                         SCFG))
    assert report_lib.main([str(log), "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["rounds"] == fcfg.num_rounds
    assert len(payload["round_table"]) == fcfg.num_rounds
    assert payload["fairness"] is not None
    assert payload["fairness"]["jain_participation"]
    assert payload["signals"] is not None
    assert payload["manifest"]["config_fingerprint"] \
        == sinks.config_fingerprint(fcfg, WCFG, SCFG)
    # The text mode grew the matching sections.
    assert report_lib.main([str(log)]) == 0
    out = capsys.readouterr().out
    assert "Learning signals" in out
    assert "Fairness" in out
