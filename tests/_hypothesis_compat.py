"""Offline fallback for ``hypothesis`` (optional test dependency).

The tier-1 suite must collect and run in containers without the optional
``hypothesis`` package.  When the real library is available we re-export
it untouched; otherwise we provide a minimal deterministic stand-in:

* ``st.integers(lo, hi)`` — a strategy that draws uniform ints.
* ``@given(*strategies)`` — replays the wrapped test ``FALLBACK_EXAMPLES``
  times with draws from a fixed-seed ``numpy`` generator, so the property
  still gets exercised over a spread of inputs, reproducibly.
* ``@settings(...)`` — accepted and ignored (the fallback has no
  shrinking, deadlines, or example databases).

Only the strategy surface the suite uses (``st.integers``) is
implemented; extend here before reaching for new strategies in tests.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies
    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as np

    HAVE_HYPOTHESIS = False
    FALLBACK_EXAMPLES = 5

    class _IntegerStrategy:
        def __init__(self, min_value: int, max_value: int):
            self.min_value = min_value
            self.max_value = max_value

        def sample(self, rng) -> int:
            return int(rng.integers(self.min_value, self.max_value + 1))

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> "_IntegerStrategy":
            return _IntegerStrategy(min_value, max_value)

    strategies = _Strategies()

    def given(*strats):
        """Replay the test over deterministic draws (positional args only,
        matching how this suite invokes ``@given``).  The wrapper takes no
        parameters — pytest must not mistake strategy-drawn arguments for
        fixtures — so ``functools.wraps`` (which exposes the wrapped
        signature via ``__wrapped__``) is deliberately not used."""

        def decorate(fn):
            def runner():
                rng = np.random.default_rng(0)
                for _ in range(FALLBACK_EXAMPLES):
                    fn(*(s.sample(rng) for s in strats))

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner

        return decorate

    def settings(**_kwargs):
        def decorate(fn):
            return fn

        return decorate
