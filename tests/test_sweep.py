"""Sweep subsystem tests: grid expansion, fold_in seed derivation,
Welford oracle, sharded/chunked parity with the unsharded driver, and
bit-for-bit kill/resume (DESIGN.md §8)."""

import dataclasses
import functools
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import msgpack_ckpt
from repro.core import federated, scheduler, wireless
from repro.data import partition, synthetic
from repro.models import paper_nets
from repro.sweep import engine as engine_lib
from repro.sweep import grid as grid_lib
from repro.sweep import runner as runner_lib


# ---------------------------------------------------------------------------
# Fixtures: one tiny world + one engine, shared module-wide (compiles are
# the expensive part — every distinct (point, chunk size) is a fresh jit)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def world():
    imgs, labs = synthetic.generate(0, samples_per_class=200)
    data = partition.partition(
        imgs, labs, seed=1,
        spec=partition.PartitionSpec(num_devices=8, num_shards=36,
                                     shard_size=50))
    mspec = paper_nets.PaperNetSpec(kind="mlp", mlp_hidden=8)
    params = paper_nets.init(jax.random.key(3), mspec)
    loss = functools.partial(paper_nets.loss_fn, spec=mspec)
    ev = functools.partial(paper_nets.accuracy, spec=mspec)
    return data, params, loss, ev


def _spec(**kw) -> grid_lib.SweepSpec:
    base = dict(
        fl=federated.FLConfig(num_rounds=3, batch_size=50,
                              learning_rate=0.1),
        sched=scheduler.SchedulerConfig(method="das", n_min=2,
                                        iterations_max=3),
        wireless=wireless.WirelessConfig(),
        scenarios_per_point=4, chunk_scenarios=2, base_seed=7)
    base.update(kw)
    return grid_lib.SweepSpec(**base)


@pytest.fixture(scope="module")
def engine(world):
    data, params, loss, ev = world
    return engine_lib.SweepEngine(
        _spec(), data=data, loss_fn=loss, eval_fn=ev, init_params=params,
        target_accuracy=0.3)


# ---------------------------------------------------------------------------
# Grid expansion
# ---------------------------------------------------------------------------

def test_grid_expansion_product_order():
    spec = _spec(axes=(grid_lib.Axis("sched", "n_fixed", (3, 5)),
                       grid_lib.Axis("sched", "method",
                                     ("das", "random"))))
    points = spec.expand()
    assert spec.num_points == 4 == len(points)
    assert [p.name for p in points] == [
        "n_fixed=3,method=das", "n_fixed=3,method=random",
        "n_fixed=5,method=das", "n_fixed=5,method=random"]
    assert points[2].sched.n_fixed == 5
    assert points[2].sched.method == "das"
    # Base configs untouched by expansion.
    assert spec.sched.n_fixed is None


def test_grid_axis_targets_fl_and_wireless():
    spec = _spec(axes=(grid_lib.Axis("fl", "local_epochs", (1, 2)),
                       grid_lib.Axis("wireless", "model_bits",
                                     (1e5, 1e6))))
    points = spec.expand()
    assert points[-1].fl.local_epochs == 2
    assert points[-1].wireless.model_bits == 1e6


def test_grid_unknown_field_raises():
    spec = _spec(axes=(grid_lib.Axis("sched", "no_such_knob", (1,)),))
    with pytest.raises(ValueError, match="no_such_knob"):
        spec.expand()


def test_grid_stream_axis_requires_stream_config():
    spec = _spec(axes=(grid_lib.Axis("stream", "rate", (5.0,)),))
    with pytest.raises(ValueError, match="stream"):
        spec.expand()


def test_grid_schedule_and_fingerprint():
    spec = _spec(axes=(grid_lib.Axis("sched", "method",
                                     ("das", "random")),),
                 scenarios_per_point=4, chunk_scenarios=2,
                 common_random_numbers=False)
    # Disjoint index ranges, chunked pairwise.
    assert spec.schedule() == [(0, 0, 2), (0, 2, 2), (1, 4, 2),
                               (1, 6, 2)]
    crn = dataclasses.replace(spec, common_random_numbers=True)
    assert crn.schedule() == [(0, 0, 2), (0, 2, 2), (1, 0, 2), (1, 2, 2)]
    assert spec.fingerprint() != crn.fingerprint()
    assert spec.fingerprint() != \
        dataclasses.replace(spec, chunk_scenarios=4).fingerprint()
    assert spec.fingerprint() == \
        dataclasses.replace(spec, chunk_scenarios=2).fingerprint()


# ---------------------------------------------------------------------------
# Seed derivation: fold_in streams are chunk- and batch-size-invariant
# ---------------------------------------------------------------------------

def test_scenario_keys_chunk_invariant():
    base = jax.random.key(11)
    whole = federated.scenario_keys(base, 0, 8)
    parts = jnp.concatenate([federated.scenario_keys(base, 0, 3),
                             federated.scenario_keys(base, 3, 5)])
    np.testing.assert_array_equal(jax.random.key_data(whole),
                                  jax.random.key_data(parts))
    # Unlike split(key, S), the stream of scenario i never depends on S.
    np.testing.assert_array_equal(
        jax.random.key_data(federated.scenario_keys(base, 2, 1))[0],
        jax.random.key_data(whole)[2])


def test_sample_networks_indexed_chunk_invariant():
    wcfg = wireless.WirelessConfig()
    base = jax.random.key(5)
    whole = wireless.sample_networks_indexed(base, jnp.arange(6), 7, wcfg)
    part = wireless.sample_networks_indexed(base, jnp.arange(4, 6), 7,
                                            wcfg)
    for a, b in zip(jax.tree_util.tree_leaves(whole),
                    jax.tree_util.tree_leaves(part)):
        np.testing.assert_array_equal(np.asarray(a[4:]), np.asarray(b))


# ---------------------------------------------------------------------------
# Welford fold: oracle comparison against jnp.mean/var on the full batch
# ---------------------------------------------------------------------------

def _fold_in_chunks(data, sizes, mask=None):
    state = engine_lib.welford_init(data.shape[1:])
    off = 0
    for s in sizes:
        m = None if mask is None else mask[off:off + s]
        state = engine_lib.welford_fold(state, data[off:off + s], m)
        off += s
    assert off == data.shape[0]
    return state


def test_welford_matches_oracle_across_chunkings():
    data = jax.random.normal(jax.random.key(0), (12, 5)) * 3.0 + 1.0
    for sizes in ((12,), (4, 4, 4), (1, 11), (3, 1, 2, 6)):
        st = _fold_in_chunks(data, sizes)
        np.testing.assert_allclose(np.asarray(st.mean),
                                   np.asarray(jnp.mean(data, axis=0)),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(st.variance),
                                   np.asarray(jnp.var(data, axis=0)),
                                   atol=1e-6)
        np.testing.assert_array_equal(np.asarray(st.min),
                                      np.asarray(jnp.min(data, axis=0)))
        np.testing.assert_array_equal(np.asarray(st.max),
                                      np.asarray(jnp.max(data, axis=0)))
        np.testing.assert_array_equal(np.asarray(st.count), 12.0)


def test_welford_single_scenario_chunks():
    """S=1 chunks are the degenerate edge: within-chunk variance is zero
    and all spread must come from the merge term."""
    data = jax.random.normal(jax.random.key(1), (7, 3))
    st = _fold_in_chunks(data, (1,) * 7)
    np.testing.assert_allclose(np.asarray(st.mean),
                               np.asarray(jnp.mean(data, axis=0)),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(st.variance),
                               np.asarray(jnp.var(data, axis=0)),
                               atol=1e-6)


def test_welford_nan_masking():
    """NaN entries (eval-stride rounds) are excluded elementwise, like
    nanmean/nanvar; all-NaN columns report count 0 and NaN summary."""
    data = np.random.default_rng(2).normal(size=(6, 4)).astype(np.float32)
    data[::2, 1] = np.nan
    data[:, 3] = np.nan
    st = _fold_in_chunks(jnp.asarray(data), (2, 1, 3))
    np.testing.assert_allclose(np.asarray(st.mean)[:2],
                               np.nanmean(data[:, :2], axis=0),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(st.variance)[:2],
                               np.nanvar(data[:, :2], axis=0),
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(st.count),
                                  [6.0, 3.0, 6.0, 0.0])
    assert np.isnan(np.asarray(st.variance)[3])


def test_welford_explicit_mask():
    data = jnp.asarray([[1.0], [2.0], [30.0]])
    mask = jnp.asarray([[True], [True], [False]])
    st = engine_lib.welford_fold(engine_lib.welford_init((1,)), data,
                                 mask)
    np.testing.assert_allclose(np.asarray(st.mean), [1.5])
    np.testing.assert_array_equal(np.asarray(st.max), [2.0])


# ---------------------------------------------------------------------------
# Engine parity: chunked + sharded == the plain unsharded batch driver
# ---------------------------------------------------------------------------

def test_engine_matches_unsharded_batch_driver(world, engine):
    """Acceptance contract: a chunked sweep (2 chunks of 2, shard_map
    over the host mesh) reproduces the one-shot unsharded
    run_federated_batch aggregates within 1e-6."""
    data, params, loss, ev = world
    spec = engine.spec
    agg = engine.run_point(engine.points[0])
    summary = engine_lib.aggregate_summary(agg)

    net_base, sim_base = engine_lib.stream_bases(spec.base_seed)
    s = spec.scenarios_per_point
    nets = wireless.sample_networks_indexed(net_base, jnp.arange(s),
                                            data.num_devices,
                                            spec.wireless)
    keys = federated.scenario_keys(sim_base, 0, s)
    _, metrics = federated.run_federated_batch(
        init_params=params, loss_fn=loss, eval_fn=ev, data=data,
        nets=nets, wcfg=spec.wireless, scfg=spec.sched, fcfg=spec.fl,
        keys=keys)
    acc = np.asarray(metrics.accuracy)
    np.testing.assert_allclose(summary["round.accuracy"]["mean"],
                               np.mean(acc, axis=0), atol=1e-6)
    np.testing.assert_allclose(summary["round.accuracy"]["var"],
                               np.var(acc, axis=0), atol=1e-6)
    rt = np.asarray(metrics.round_time)
    np.testing.assert_allclose(summary["round.round_time"]["mean"],
                               np.mean(rt, axis=0), rtol=1e-6)
    np.testing.assert_allclose(summary["round.round_time"]["min"],
                               np.min(rt, axis=0), rtol=1e-6)
    et = np.asarray(metrics.energy_total)
    np.testing.assert_allclose(summary["round.energy_total"]["mean"],
                               np.mean(et, axis=0), rtol=1e-5)
    np.testing.assert_allclose(summary["scalar.final_accuracy"]["mean"],
                               np.mean(acc[:, -1]), atol=1e-6)
    np.testing.assert_allclose(
        summary["scalar.time_total"]["mean"],
        np.mean(np.sum(rt, axis=1)), rtol=1e-5)
    assert float(summary["scalar.final_accuracy"]["count"]) == s


def test_engine_chunk_size_invariance(world, engine):
    """Chunk partitioning is an execution detail: 4x1 and 1x4 chunkings
    agree with the module fixture's 2x2 within float tolerance."""
    data, params, loss, ev = world
    base = engine_lib.aggregate_summary(
        engine.run_point(engine.points[0]))
    for chunk in (1, 4):
        eng = engine_lib.SweepEngine(
            dataclasses.replace(engine.spec, chunk_scenarios=chunk),
            data=data, loss_fn=loss, eval_fn=ev, init_params=params,
            target_accuracy=0.3)
        summary = engine_lib.aggregate_summary(
            eng.run_point(eng.points[0]))
        for metric in ("round.accuracy", "round.round_time"):
            for field in ("mean", "var", "min", "max", "count"):
                np.testing.assert_allclose(
                    summary[metric][field], base[metric][field],
                    rtol=2e-5, atol=1e-6, err_msg=f"{metric}.{field} "
                    f"chunk={chunk}")


def test_engine_common_random_numbers_pair_grid_points(world):
    """Under CRN every grid point sees identical scenario draws: a
    config axis that doesn't affect the simulation yields bitwise-equal
    aggregates across points."""
    data, params, loss, ev = world
    spec = _spec(axes=(grid_lib.Axis("sched", "staleness_weight",
                                     (0.0, 0.5)),))
    eng = engine_lib.SweepEngine(spec, data=data, loss_fn=loss,
                                 eval_fn=ev, init_params=params,
                                 target_accuracy=0.3)
    # staleness_weight only acts when the driver passes staleness
    # (streaming runs); with static data both points run identically.
    s0 = engine_lib.aggregate_summary(eng.run_point(eng.points[0]))
    s1 = engine_lib.aggregate_summary(eng.run_point(eng.points[1]))
    np.testing.assert_array_equal(s0["round.accuracy"]["mean"],
                                  s1["round.accuracy"]["mean"])
    np.testing.assert_array_equal(s0["round.round_time"]["mean"],
                                  s1["round.round_time"]["mean"])


# ---------------------------------------------------------------------------
# Runner: kill mid-grid, resume, bit-identical aggregates
# ---------------------------------------------------------------------------

def test_runner_kill_resume_bitwise(world, engine, tmp_path):
    ck = str(tmp_path / "sweep.msgpack")
    r = runner_lib.SweepRunner(engine, ck)
    assert r.run(max_chunks=1) is None          # "killed" after chunk 1
    meta = msgpack_ckpt.load_flat(ck)[1]
    assert meta["cursor"] == 1
    assert meta["state_version"] == runner_lib.STATE_VERSION
    out = r.run()                               # resume to completion
    assert out is not None
    full = runner_lib.SweepRunner(
        engine, str(tmp_path / "full.msgpack")).run()
    for (p, s), (pf, sf) in zip(out, full):
        assert p.name == pf.name
        for metric in s:
            for field in s[metric]:
                np.testing.assert_array_equal(
                    s[metric][field], sf[metric][field],
                    err_msg=f"{p.name}/{metric}/{field}")


def test_runner_rejects_fingerprint_mismatch(world, engine, tmp_path):
    data, params, loss, ev = world
    ck = str(tmp_path / "sweep.msgpack")
    runner_lib.SweepRunner(engine, ck).run(max_chunks=1)
    other = engine_lib.SweepEngine(
        dataclasses.replace(engine.spec, base_seed=999), data=data,
        loss_fn=loss, eval_fn=ev, init_params=params)
    with pytest.raises(ValueError, match="fingerprint"):
        runner_lib.SweepRunner(other, ck).run()


def test_runner_rejects_target_accuracy_mismatch(world, engine,
                                                 tmp_path):
    """rounds_to_target scalars are judged against the engine's target:
    resuming under a different target must refuse, not silently mix."""
    data, params, loss, ev = world
    ck = str(tmp_path / "sweep.msgpack")
    runner_lib.SweepRunner(engine, ck).run(max_chunks=1)
    other = engine_lib.SweepEngine(
        engine.spec, data=data, loss_fn=loss, eval_fn=ev,
        init_params=params, target_accuracy=0.9)
    with pytest.raises(ValueError, match="target_accuracy"):
        runner_lib.SweepRunner(other, ck).run()


def test_runner_completed_run_resumes_to_noop(world, engine, tmp_path):
    ck = str(tmp_path / "sweep.msgpack")
    r = runner_lib.SweepRunner(engine, ck)
    first = r.run()
    again = r.run()                 # cursor at end: no chunks re-execute
    for (p, s), (pa, sa) in zip(first, again):
        for metric in s:
            for field in s[metric]:
                np.testing.assert_array_equal(s[metric][field],
                                              sa[metric][field])


# ---------------------------------------------------------------------------
# Checkpoint container: versioned header, dtype + meta round-trip
# ---------------------------------------------------------------------------

def test_msgpack_roundtrip_dtypes_and_meta(tmp_path):
    path = str(tmp_path / "ckpt.msgpack")
    tree = {
        "f32": np.arange(6, dtype=np.float32).reshape(2, 3),
        "f64": np.linspace(0, 1, 4),
        "i32": np.asarray([-1, 2], np.int32),
        "u8": np.asarray([[255, 0]], np.uint8),
        "bool": np.asarray([True, False]),
        "nested": {"leaf": np.asarray(3.5, np.float32)},
    }
    meta = {"cursor": 3, "fingerprint": "abc", "nested": {"k": [1, 2]}}
    msgpack_ckpt.save(path, tree, meta=meta)
    flat, got_meta = msgpack_ckpt.load_flat(path)
    assert got_meta == meta
    for key, want in (("f32", tree["f32"]), ("f64", tree["f64"]),
                      ("i32", tree["i32"]), ("u8", tree["u8"]),
                      ("bool", tree["bool"]),
                      ("nested/leaf", tree["nested"]["leaf"])):
        assert flat[key].dtype == want.dtype, key
        np.testing.assert_array_equal(flat[key], want)


def test_msgpack_versioned_header(tmp_path):
    import msgpack

    path = str(tmp_path / "ckpt.msgpack")
    msgpack_ckpt.save(path, {"x": np.zeros(2, np.float32)})
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    assert payload["__version__"] == msgpack_ckpt.FORMAT_VERSION

    # Pre-header files (no __version__) still load as version 0.
    legacy = str(tmp_path / "legacy.msgpack")
    del payload["__version__"]
    with open(legacy, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    flat, _ = msgpack_ckpt.load_flat(legacy)
    assert "x" in flat

    # Files from a newer writer fail loudly instead of misreading.
    future = str(tmp_path / "future.msgpack")
    payload["__version__"] = msgpack_ckpt.FORMAT_VERSION + 1
    with open(future, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    with pytest.raises(ValueError, match="newer"):
        msgpack_ckpt.load_flat(future)


# ---------------------------------------------------------------------------
# The real multi-device shard_map path (forced host devices, subprocess:
# XLA device count is fixed at jax import)
# ---------------------------------------------------------------------------

_SUBPROCESS_SCRIPT = """
import dataclasses, functools
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 4, jax.devices()
from repro.core import federated, scheduler, wireless
from repro.data import partition, synthetic
from repro.models import paper_nets
from repro.sweep import engine as engine_lib
from repro.sweep import grid as grid_lib

imgs, labs = synthetic.generate(0, samples_per_class=150)
data = partition.partition(imgs, labs, seed=1,
    spec=partition.PartitionSpec(num_devices=6, num_shards=26,
                                 shard_size=50))
mspec = paper_nets.PaperNetSpec(kind="mlp", mlp_hidden=8)
params = paper_nets.init(jax.random.key(3), mspec)
loss = functools.partial(paper_nets.loss_fn, spec=mspec)
ev = functools.partial(paper_nets.accuracy, spec=mspec)
spec = grid_lib.SweepSpec(
    fl=federated.FLConfig(num_rounds=2, batch_size=50,
                          learning_rate=0.1),
    sched=scheduler.SchedulerConfig(method="das", n_min=2,
                                    iterations_max=2),
    wireless=wireless.WirelessConfig(),
    scenarios_per_point=4, chunk_scenarios=4, base_seed=3)
summaries = {}
for sharded in (True, False):
    eng = engine_lib.SweepEngine(spec, data=data, loss_fn=loss,
                                 eval_fn=ev, init_params=params,
                                 target_accuracy=0.3,
                                 use_sharding=sharded)
    assert (eng.mesh is not None) == sharded
    if sharded:
        assert eng.mesh.shape["scenario"] == 4
    summaries[sharded] = engine_lib.aggregate_summary(
        eng.run_point(eng.points[0]))
# Accuracy (count ratios) must agree to 1e-6; the wireless time/energy
# solves run ~100 f32 bisection/Newton steps whose vector shape differs
# between the 4-wide vmap program and the 4x(1-wide) sharded programs,
# so ulp-level drift amplifies to ~1e-4 relative there.
for metric, rtol in (("round.accuracy", 1e-6),
                     ("round.round_time", 5e-4),
                     ("round.energy_total", 5e-4)):
    for field in ("mean", "var", "min", "max"):
        np.testing.assert_allclose(
            summaries[True][metric][field],
            summaries[False][metric][field], rtol=rtol, atol=1e-6,
            err_msg=f"{metric}.{field}")
print("SHARDED_PARITY_OK")
"""


def test_shard_map_parity_on_four_host_devices():
    """The acceptance contract on a real 4-way scenario mesh: a sweep
    sharded with shard_map over 4 (forced host) devices reproduces the
    unsharded aggregates within 1e-6-grade tolerance."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["JAX_PLATFORMS"] = "cpu"
    env.setdefault("PYTHONPATH", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT], env=env,
        capture_output=True, text=True, timeout=540,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "SHARDED_PARITY_OK" in proc.stdout


# ---------------------------------------------------------------------------
# JSONL streaming (--sweep-jsonl): per-chunk lines, resume-safe append
# ---------------------------------------------------------------------------

def test_runner_jsonl_streams_per_chunk(world, engine, tmp_path):
    import json
    ck = str(tmp_path / "sweep.msgpack")
    jl = str(tmp_path / "sweep.jsonl")
    out = runner_lib.SweepRunner(engine, ck, jsonl_path=jl).run()
    lines = [json.loads(ln) for ln in open(jl)]
    assert [ln["cursor"] for ln in lines] == \
        list(range(1, len(engine.spec.schedule()) + 1))
    # The last line of each point carries that point's final aggregate.
    last_by_point = {ln["point"]: ln for ln in lines}
    for point, summary in out:
        rec = last_by_point[point.index]
        assert rec["point_name"] == point.name
        assert not rec["skipped"]
        want = float(summary["scalar.final_accuracy"]["mean"])
        assert rec["scalar"]["final_accuracy"]["mean"] == \
            pytest.approx(want, rel=1e-6)
        assert rec["scalar"]["final_accuracy"]["count"] == \
            engine.spec.scenarios_per_point


def test_runner_jsonl_resume_safe_append(world, engine, tmp_path):
    """Kill after one chunk, resume: the file must hold exactly one
    line per chunk with monotone cursors — stale lines past the resumed
    checkpoint (including a torn tail write) are rewound, never
    duplicated."""
    import json
    ck = str(tmp_path / "sweep.msgpack")
    jl = str(tmp_path / "sweep.jsonl")
    r = runner_lib.SweepRunner(engine, ck, jsonl_path=jl)
    assert r.run(max_chunks=1) is None
    # Simulate a crash that streamed past the checkpoint: one stale
    # whole line and one torn partial line.
    with open(jl, "a") as f:
        f.write(json.dumps({"cursor": 2, "point": 0, "stale": True})
                + "\n")
        f.write('{"cursor": 3, "torn')
    r.run()
    lines = [json.loads(ln) for ln in open(jl)]
    total = len(engine.spec.schedule())
    assert [ln["cursor"] for ln in lines] == list(range(1, total + 1))
    assert not any(ln.get("stale") for ln in lines)


# ---------------------------------------------------------------------------
# Adaptive per-point scenario counts (SweepSpec.ci_target)
# ---------------------------------------------------------------------------

def test_ci_target_skips_converged_chunks(world, tmp_path):
    """A generous CI target stops every point after its first chunk —
    in the engine's run_point loop and in the runner (which streams the
    skip) alike; ci_target=0 keeps the fixed schedule."""
    import json
    data, params, loss, ev = world
    spec = _spec(ci_target=10.0)
    eng = engine_lib.SweepEngine(spec, data=data, loss_fn=loss,
                                 eval_fn=ev, init_params=params,
                                 target_accuracy=0.3)
    agg = eng.run_point(eng.points[0])
    assert float(jax.device_get(
        agg["scalar"]["final_accuracy"].count)) == spec.chunk_scenarios
    jl = str(tmp_path / "ci.jsonl")
    out = runner_lib.SweepRunner(eng, None, jsonl_path=jl).run()
    assert float(out[0][1]["scalar.final_accuracy"]["count"]) == \
        spec.chunk_scenarios
    flags = [json.loads(ln)["skipped"] for ln in open(jl)]
    assert flags == [False, True]


def test_ci_halfwidth_from_welford_carry():
    """The half-width helper matches the closed form on a known batch
    and is inf below two scenarios."""
    batch = jnp.asarray([0.1, 0.4, 0.7, 0.9])
    agg = engine_lib.aggregate_init(2)
    agg["scalar"]["final_accuracy"] = engine_lib.welford_fold(
        agg["scalar"]["final_accuracy"], batch)
    n = 4.0
    want = 1.96 * np.std(np.asarray(batch), ddof=1) / np.sqrt(n)
    assert engine_lib.final_accuracy_ci_halfwidth(agg) == \
        pytest.approx(want, rel=1e-5)
    fresh = engine_lib.aggregate_init(2)
    assert engine_lib.final_accuracy_ci_halfwidth(fresh) == float("inf")
    assert not engine_lib.point_converged(fresh, 10.0)
    assert not engine_lib.point_converged(agg, 0.0)   # disabled


def test_ci_target_joins_fingerprint():
    assert _spec().fingerprint() != \
        _spec(ci_target=0.02).fingerprint()


# ---------------------------------------------------------------------------
# Compression axis (comp target) through the grid
# ---------------------------------------------------------------------------

def test_grid_comp_axis_patches_compression_config():
    from repro.core import compression
    fl = federated.FLConfig(
        num_rounds=3, batch_size=50, learning_rate=0.1,
        compression=compression.CompressionConfig(codec="none"))
    spec = _spec(fl=fl,
                 axes=(grid_lib.Axis("comp", "codec",
                                     ("none", "quant", "topk")),
                       grid_lib.Axis("comp", "bit_width", (4, 8))))
    points = spec.expand()
    assert len(points) == 6
    assert points[-1].fl.compression.codec == "topk"
    assert points[-1].fl.compression.bit_width == 8
    assert points[0].fl.compression.codec == "none"
    # Base config untouched.
    assert spec.fl.compression.bit_width == 8


def test_grid_comp_axis_requires_compression_config():
    spec = _spec(axes=(grid_lib.Axis("comp", "codec", ("quant",)),))
    with pytest.raises(ValueError, match="comp"):
        spec.expand()


def test_codec_axis_grid_through_engine(world):
    """An accuracy-vs-energy codec grid runs through the sharded
    engine: the quant point's folded energy is well below the none
    point's on identical (common-random-number) scenarios."""
    from repro.core import compression
    data, params, loss, ev = world
    fl = federated.FLConfig(
        num_rounds=2, batch_size=50, learning_rate=0.1,
        compression=compression.CompressionConfig(codec="none"))
    spec = _spec(fl=fl, scenarios_per_point=2, chunk_scenarios=0,
                 axes=(grid_lib.Axis("comp", "codec",
                                     ("none", "quant")),))
    eng = engine_lib.SweepEngine(spec, data=data, loss_fn=loss,
                                 eval_fn=ev, init_params=params,
                                 target_accuracy=0.3)
    out = [(p, engine_lib.aggregate_summary(eng.run_point(p)))
           for p in eng.points]
    by_name = {p.name: s for p, s in out}
    e_none = float(by_name["codec=none"]["scalar.energy_total"]["mean"])
    e_quant = float(
        by_name["codec=quant"]["scalar.energy_total"]["mean"])
    assert e_quant < 0.5 * e_none


def test_ci_skips_do_not_burn_max_chunks_budget(world, tmp_path):
    """Skipped (converged) chunks are free: a resumed run whose
    remaining chunks all skip completes in one call instead of
    returning None with the budget spent on no-ops."""
    data, params, loss, ev = world
    spec = _spec(ci_target=10.0)
    eng = engine_lib.SweepEngine(spec, data=data, loss_fn=loss,
                                 eval_fn=ev, init_params=params,
                                 target_accuracy=0.3)
    ck = str(tmp_path / "ci_budget.msgpack")
    r = runner_lib.SweepRunner(eng, ck)
    assert r.run(max_chunks=1) is None      # chunk 1: real compute
    out = r.run(max_chunks=1)               # chunk 2 skips -> finishes
    assert out is not None
    assert float(out[0][1]["scalar.final_accuracy"]["count"]) == \
        spec.chunk_scenarios


def test_runner_rejects_round_metrics_arity_mismatch(world, engine,
                                                     tmp_path):
    """PR 7 widened ROUND_METRICS; resuming a checkpoint folded under a
    different arity would crash deep inside the Welford fold with a
    pytree-structure error.  The stamped arity turns that into a loud,
    actionable schema error — including for pre-stamp ("unstamped")
    checkpoints."""
    ck = str(tmp_path / "arity.msgpack")
    runner_lib.SweepRunner(engine, ck).run(max_chunks=1)
    flat, meta = msgpack_ckpt.load_flat(ck)

    # (a) pre-PR-7 checkpoint: no arity key in the meta at all.
    unstamped = {k: v for k, v in meta.items()
                 if k != "round_metrics_arity"}
    msgpack_ckpt.save(ck, flat, meta=unstamped)
    with pytest.raises(ValueError, match="an unstamped"):
        runner_lib.SweepRunner(engine, ck).run()

    # (b) stamped, but with a different metric-tuple arity.
    wrong = dict(meta)
    wrong["round_metrics_arity"] = len(engine_lib.ROUND_METRICS) + 2
    msgpack_ckpt.save(ck, flat, meta=wrong)
    with pytest.raises(ValueError, match="cannot be resumed"):
        runner_lib.SweepRunner(engine, ck).run()

    # (c) restoring the true meta resumes cleanly to completion.
    msgpack_ckpt.save(ck, flat, meta=dict(meta))
    assert runner_lib.SweepRunner(engine, ck).run() is not None
