"""End-to-end system tests: dry-run plumbing on a small host mesh +
the federated train step at pod granularity (DESIGN.md §3 mapping)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs, optim
from repro.configs import shapes as shapes_lib
from repro.launch import dryrun, specs as specs_lib, steps as steps_lib


def test_collective_parser():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[64]{0} all-reduce(%y), to_apply=%sum
  %a2a = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%p, %q)
  %ars = f32[32]{0} all-reduce-start(%z), to_apply=%sum
  %ard = f32[32]{0} all-reduce-done(%ars)
  %cp = u32[2]{0} collective-permute(%w), source_target_pairs={{0,1}}
"""
    stats = dryrun.collective_bytes(hlo)
    assert stats["counts"]["all-gather"] == 1
    assert stats["counts"]["all-reduce"] == 2      # ar.1 + start (not done)
    assert stats["counts"]["all-to-all"] == 1
    assert stats["counts"]["collective-permute"] == 1
    assert stats["bytes"]["all-gather"] == 8 * 128 * 2
    assert stats["bytes"]["all-reduce"] == 64 * 4 + 32 * 4
    assert stats["bytes"]["all-to-all"] == 2 * 16 * 4


def test_input_specs_cover_all_archs():
    """ShapeDtypeStruct specs build for every (arch x shape) and batch
    dims shard only when divisible."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        for shape in shapes_lib.SHAPES:
            ok, _ = shapes_lib.applicable(cfg, shape)
            if not ok:
                continue
            specs = specs_lib.input_specs(cfg, shape, mesh)
            assert specs, f"{arch} x {shape.name}: empty specs"
            leaves = jax.tree_util.tree_leaves(specs)
            assert all(hasattr(leaf, "shape") for leaf in leaves)


def test_federated_train_step_matches_weighted_grads():
    """The pod-scale FedAvg step == manually weighted per-client grads."""
    cfg = configs.get("xlstm_125m").reduced(num_layers=2)
    ocfg = optim.OptimizerConfig(name="sgd", momentum=0.0,
                                 learning_rate=0.1, grad_clip=0.0,
                                 warmup_steps=0)
    step = steps_lib.make_federated_train_step(cfg, ocfg, None,
                                               num_clients=3)
    key = jax.random.key(0)
    state = steps_lib.init_train_state(key, cfg, ocfg)
    batch = {
        "inputs": jax.random.randint(key, (3, 2, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (3, 2, 16), 0, cfg.vocab_size),
        "selected": jnp.asarray([1.0, 0.0, 1.0]),
        "sizes": jnp.asarray([100.0, 999.0, 300.0]),
    }
    new_state, metrics = step(state, batch)
    w = jnp.asarray([0.25, 0.0, 0.75])
    gs = []
    for i in range(3):
        g = jax.grad(lambda p: steps_lib.loss_fn(
            p, {"inputs": batch["inputs"][i],
                "labels": batch["labels"][i]}, cfg, None)[0]
        )(state["params"])
        gs.append(g)
    want_g = jax.tree_util.tree_map(
        lambda *x: sum(wi * xi for wi, xi in zip(w, x)), *gs)
    want_p = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g,
                                    state["params"], want_g)
    got = jax.tree_util.tree_leaves(new_state["params"])
    want = jax.tree_util.tree_leaves(want_p)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-3)
    assert float(metrics["n_selected"]) == 2.0


def test_chunked_xent_matches_plain():
    cfg = configs.get("codeqwen1_5_7b").reduced(num_layers=2)
    from repro.models import transformer
    key = jax.random.key(1)
    params = transformer.init(key, cfg)
    b, s = 2, 64
    inputs = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.key(2), (b, s), 0,
                                cfg.vocab_size)
    hidden, _ = transformer.forward(params, inputs, cfg, None,
                                    return_hidden=True)
    head = transformer.head_matrix(params, cfg)
    chunked = steps_lib.chunked_xent(hidden, head, labels, cfg, None,
                                     num_chunks=8)
    logits = hidden @ head
    plain = steps_lib.cross_entropy(logits, labels)
    np.testing.assert_allclose(float(chunked), float(plain), rtol=1e-5)


def test_microbatched_train_step_matches_mb1():
    """Gradient accumulation is math-identical to the full batch."""
    cfg = configs.get("xlstm_125m").reduced(num_layers=2)
    ocfg = optim.OptimizerConfig(name="sgd", momentum=0.0,
                                 learning_rate=0.05, grad_clip=0.0,
                                 warmup_steps=0)
    key = jax.random.key(3)
    state = steps_lib.init_train_state(key, cfg, ocfg)
    batch = {
        "inputs": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
    }
    s1, m1 = steps_lib.make_train_step(cfg, ocfg, None, 1)(state, batch)
    s2, m2 = steps_lib.make_train_step(cfg, ocfg, None, 2)(state, batch)
    for a, b in zip(jax.tree_util.tree_leaves(s1["params"]),
                    jax.tree_util.tree_leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(float(m1["ce"]), float(m2["ce"]),
                               rtol=1e-3)
