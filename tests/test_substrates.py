"""Optimizer / checkpoint / data-pipeline / sharding substrate tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro import optim
from repro.checkpoint import msgpack_ckpt
from repro.data import partition, synthetic


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def _quad_params():
    return {"a": jnp.asarray([2.0, -3.0]), "b": {"c": jnp.asarray([1.5])}}


@pytest.mark.parametrize("name", ["sgd", "adamw"])
def test_optimizer_converges_on_quadratic(name):
    cfg = optim.OptimizerConfig(name=name, learning_rate=0.1,
                                weight_decay=0.0, warmup_steps=0,
                                grad_clip=0.0)
    params = _quad_params()
    state = optim.init_state(params, cfg)
    loss = lambda p: (jnp.sum(p["a"] ** 2) + jnp.sum(p["b"]["c"] ** 2))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = optim.apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_adamw_matches_reference_update():
    """One AdamW step against the textbook update."""
    cfg = optim.OptimizerConfig(name="adamw", learning_rate=0.01,
                                beta1=0.9, beta2=0.999, eps=1e-8,
                                weight_decay=0.1, warmup_steps=0,
                                grad_clip=0.0)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.25])}
    state = optim.init_state(p, cfg)
    new_p, _, _ = optim.apply_updates(p, g, state, cfg)
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    want = (np.asarray(p["w"]) - 0.01 *
            (mhat / (np.sqrt(vhat) + 1e-8) + 0.1 * np.asarray(p["w"])))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)


def test_grad_clip():
    cfg = optim.OptimizerConfig(name="sgd", learning_rate=1.0,
                                momentum=0.0, grad_clip=1.0,
                                warmup_steps=0)
    p = {"w": jnp.zeros((3,))}
    g = {"w": jnp.asarray([30.0, 40.0, 0.0])}   # norm 50 -> scaled by 1/50
    state = optim.init_state(p, cfg)
    new_p, _, m = optim.apply_updates(p, g, state, cfg)
    np.testing.assert_allclose(np.asarray(new_p["w"]),
                               [-0.6, -0.8, 0.0], rtol=1e-5)
    assert float(m["grad_norm"]) == pytest.approx(50.0, rel=1e-5)


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32),
                  "d": jnp.asarray(2.5, jnp.bfloat16)}}
    path = os.path.join(tmp_path, "ckpt.msgpack")
    msgpack_ckpt.save(path, tree, meta={"step": 7})
    restored = msgpack_ckpt.restore(path, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    _, meta = msgpack_ckpt.load_flat(path)
    assert meta["step"] == 7


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"a": jnp.zeros((2, 3))}
    path = os.path.join(tmp_path, "c.msgpack")
    msgpack_ckpt.save(path, tree)
    with pytest.raises(ValueError):
        msgpack_ckpt.restore(path, {"a": jnp.zeros((3, 2))})


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

@given(st.integers(0, 10 ** 6))
@settings(max_examples=10, deadline=None)
def test_partition_invariants(seed):
    spec = partition.PartitionSpec(num_devices=10, num_shards=60,
                                   shard_size=20)
    imgs, labs = synthetic.generate(seed % 100, samples_per_class=120)
    data = partition.partition(imgs, labs, seed=seed, spec=spec)
    sizes = np.asarray(data.sizes)
    # every device holds at least one shard, in whole-shard multiples
    assert np.all(sizes >= spec.shard_size)
    assert np.all(sizes % spec.shard_size == 0)
    # masks consistent
    assert np.all(np.asarray(data.mask).sum(axis=1) == sizes)
    # shards are single-class: count label transitions within shards
    labels = np.asarray(data.labels)
    mask = np.asarray(data.mask)
    for k in range(spec.num_devices):
        valid = labels[k][mask[k] > 0]
        for s in range(len(valid) // spec.shard_size):
            shard = valid[s * spec.shard_size:(s + 1) * spec.shard_size]
            assert len(np.unique(shard)) == 1, "shard mixes classes"


def test_synthetic_learnable_and_class_distinct():
    imgs, labs = synthetic.generate(0, samples_per_class=200)
    x = imgs.astype(np.float32) / 255.0
    # class-mean prototypes are mutually distinguishable
    means = np.stack([x[labs == c].mean(0) for c in range(10)])
    d = np.linalg.norm(means[:, None] - means[None], axis=(-1, -2))
    off_diag = d[~np.eye(10, dtype=bool)]
    assert off_diag.min() > 1.0, "classes not separable"
