"""Scheduler invariants: unit + hypothesis property tests.

System invariants under test (paper Eq. 13 constraints):
  * bandwidth budget: sum(alpha) <= 1, 0 <= alpha_k <= 1       (13c, 13d)
  * minimum participation: sum(x) >= N                          (13e)
  * binary selection                                            (13f)
  * deadline consistency: selected devices finish within round T (13b)
  * diversity index bounds and monotonicity
  * Sub2 solver matches scipy's SLSQP within tolerance
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import allocator
from repro.core import bandwidth as bw
from repro.core import diversity, scheduler, selection, wireless

WCFG = wireless.WirelessConfig()


def _network(seed: int, k: int):
    net = wireless.sample_network(jax.random.key(seed), k, WCFG)
    gains = wireless.sample_fading(jax.random.key(seed + 1), net)
    return net, gains


# ---------------------------------------------------------------------------
# Diversity index
# ---------------------------------------------------------------------------

@given(st.integers(2, 32), st.integers(2, 12), st.integers(0, 10 ** 6))
@settings(max_examples=25, deadline=None)
def test_diversity_index_bounds(k, c, seed):
    key = jax.random.key(seed)
    hists = jax.random.randint(key, (k, c), 0, 100).astype(jnp.float32)
    sizes = jnp.sum(hists, axis=-1)
    ages = jax.random.randint(jax.random.key(seed + 1), (k,), 0, 50)
    idx = diversity.diversity_index(label_hists=hists, data_sizes=sizes,
                                    ages=ages)
    assert idx.shape == (k,)
    total_gamma = 1.0
    assert np.all(np.asarray(idx) >= -1e-6)
    assert np.all(np.asarray(idx) <= total_gamma + 1e-6)


def test_gini_simpson_extremes():
    one_class = jnp.asarray([[100.0, 0.0, 0.0]])
    uniform = jnp.asarray([[10.0, 10.0, 10.0]])
    p1 = diversity.class_probs(one_class)
    pu = diversity.class_probs(uniform)
    assert float(diversity.gini_simpson(p1)[0]) == pytest.approx(0.0)
    assert float(diversity.gini_simpson(pu)[0]) == pytest.approx(2 / 3,
                                                                 abs=1e-6)
    assert float(diversity.shannon_entropy(pu)[0]) == pytest.approx(
        np.log2(3), abs=1e-5)


def test_sample_entropy_regular_vs_random():
    t = jnp.arange(128, dtype=jnp.float32)
    regular = jnp.sin(0.3 * t)
    noisy = jax.random.normal(jax.random.key(0), (128,))
    se_reg = float(diversity.sample_entropy(regular))
    se_noise = float(diversity.sample_entropy(noisy))
    assert se_reg < se_noise


# ---------------------------------------------------------------------------
# Sub2 bandwidth allocation
# ---------------------------------------------------------------------------

@given(st.integers(2, 40), st.integers(0, 10 ** 6))
@settings(max_examples=20, deadline=None)
def test_min_time_allocation_feasible(k, seed):
    net, gains = _network(seed % 1000, k)
    sizes = jax.random.randint(jax.random.key(seed), (k,), 50, 1500)
    t_train = wireless.train_time(sizes, net, WCFG)
    sel = (jax.random.uniform(jax.random.key(seed + 2), (k,)) > 0.5
           ).astype(jnp.float32)
    sel = sel.at[0].set(1.0)  # at least one selected
    alpha, t_star = bw.min_time_allocation(sel, t_train, gains,
                                           net.tx_power, WCFG)
    alpha = np.asarray(alpha)
    assert alpha.sum() <= 1.0 + 1e-4
    assert np.all(alpha >= 0.0)
    assert np.all(alpha[np.asarray(sel) == 0.0] == 0.0)
    # All selected devices meet the deadline (within bisection tolerance).
    t_up = np.asarray(wireless.upload_time(jnp.asarray(alpha), gains,
                                           net.tx_power, WCFG))
    total = np.asarray(t_train) + t_up
    assert np.all(total[np.asarray(sel) > 0] <= float(t_star) * 1.01)


def test_pgd_matches_scipy():
    minimize = pytest.importorskip("scipy.optimize").minimize
    k = 8
    net, gains = _network(7, k)
    sizes = jnp.full((k,), 500)
    t_train = wireless.train_time(sizes, net, WCFG)
    sel = jnp.ones((k,), jnp.float32)
    params = bw.Sub2Params(rho=0.5)
    alpha_jax, obj_jax = allocator.PGD(params).solve(
        sel, t_train, gains, net.tx_power, WCFG)

    def obj_np(a):
        return float(bw.sub2_objective(jnp.asarray(a, jnp.float32), sel,
                                       t_train, gains, net.tx_power, WCFG,
                                       0.5))

    x0 = np.full(k, 1.0 / k)
    res = minimize(obj_np, x0, method="SLSQP",
                   bounds=[(1e-6, 1.0)] * k,
                   constraints=[{"type": "ineq",
                                 "fun": lambda a: 1.0 - a.sum()}])
    assert float(obj_jax) <= res.fun * 1.02 + 1e-9, \
        f"PGD {float(obj_jax):.4f} vs scipy {res.fun:.4f}"


def test_project_simplex():
    v = jnp.asarray([0.5, 0.8, -0.1, 0.3])
    mask = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    p = bw.project_simplex(v, mask)
    p = np.asarray(p)
    assert p[2] == 0.0
    assert p.sum() == pytest.approx(1.0, abs=1e-5)
    assert np.all(p >= 0)


# ---------------------------------------------------------------------------
# Sub1 selection
# ---------------------------------------------------------------------------

@given(st.integers(3, 50), st.integers(1, 5), st.integers(0, 10 ** 6))
@settings(max_examples=25, deadline=None)
def test_sub1_minimum_count_and_binary(k, n_min, seed):
    key = jax.random.key(seed)
    energy = jax.random.uniform(key, (k,), minval=0.01, maxval=5.0)
    times = jax.random.uniform(jax.random.key(seed + 1), (k,),
                               minval=0.01, maxval=2.0)
    index = jax.random.uniform(jax.random.key(seed + 2), (k,))
    n_min = min(n_min, k)
    x, x_rel, t_star = selection.solve_sub1(
        energy, times, index,
        selection.Sub1Params(n_min=n_min))
    x = np.asarray(x)
    assert set(np.unique(x)).issubset({0.0, 1.0})        # (13f)
    assert x.sum() >= n_min                              # (13e)
    assert np.all((np.asarray(x_rel) >= 0) & (np.asarray(x_rel) <= 1))


def test_sub1_prefers_high_index():
    """With equal costs, Sub1 must select the diverse devices first."""
    k = 10
    energy = jnp.full((k,), 1.0)
    times = jnp.full((k,), 0.5)
    index = jnp.asarray([0.95, 0.9, 0.85, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1,
                         0.05])
    x, _, _ = selection.solve_sub1(energy, times, index,
                                   selection.Sub1Params(n_min=3))
    x = np.asarray(x)
    # the three high-index devices are selected whenever anything is
    chosen = np.nonzero(x)[0]
    assert set([0, 1, 2]).issubset(set(chosen.tolist())) or x.sum() >= 3


# ---------------------------------------------------------------------------
# Full schedulers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["das", "abs", "random", "full"])
def test_schedule_invariants(method):
    k = 30
    net, gains = _network(3, k)
    sizes = jax.random.randint(jax.random.key(5), (k,), 50, 1500)
    hists = jax.random.randint(jax.random.key(6), (k, 10), 0,
                               30).astype(jnp.float32)
    ages = jax.random.randint(jax.random.key(7), (k,), 0, 10)
    idx = diversity.diversity_index(label_hists=hists, data_sizes=sizes,
                                    ages=ages)
    sch = scheduler.SchedulerConfig(method=method, n_min=2,
                                    iterations_max=4)
    res = scheduler.schedule(jax.random.key(8), idx, ages, sizes, gains,
                             net, WCFG, sch)
    sel = np.asarray(res.selected)
    alpha = np.asarray(res.alpha)
    assert set(np.unique(sel)).issubset({0.0, 1.0})
    assert sel.sum() >= 2                               # n_min
    assert alpha.sum() <= 1.0 + 1e-4                    # (13c)
    assert np.all(alpha >= 0) and np.all(alpha <= 1)    # (13d)
    assert np.all(alpha[sel == 0] == 0)
    if method == "full":
        assert sel.sum() == k
    # Round time covers every selected device (13b).
    t_up = np.asarray(res.t_up)
    t_tr = np.asarray(res.t_train)
    tot = np.where(sel > 0, t_tr + t_up, 0.0)
    assert np.nanmax(tot) <= float(res.round_time) * 1.01 + 1e-6


def test_abs_nmin_backstop_does_not_poison_admission():
    """A forced (n_min) admit that is infeasible at the deadline must not
    block feasible lower-priority devices: its sentinel share is clamped
    out of the cumulative budget, so the greedy admission continues past
    it instead of collapsing the selection to the top-n_min sort order."""
    k = 12
    net, gains = _network(19, k)
    sizes = jnp.full((k,), 200).at[0].set(20000)  # device 0: huge t_train
    ages = jnp.ones((k,), jnp.int32).at[0].set(50)  # device 0: top priority
    t_train = wireless.train_time(sizes, net, WCFG)
    # Deadline every other device can meet at a modest share, but device
    # 0's training alone overruns it.
    others = np.asarray(t_train)[1:]
    a_eq = jnp.full((k,), 1.0 / 4.0)
    t_up_eq = np.asarray(wireless.upload_time(a_eq, gains, net.tx_power,
                                              WCFG))
    deadline = float((others + t_up_eq[1:]).max() * 1.05)
    assert float(t_train[0]) > deadline
    sch = scheduler.SchedulerConfig(method="abs", n_min=1)
    res = scheduler.abs_schedule(ages, sizes, gains, net, WCFG, sch,
                                 deadline=deadline)
    sel = np.asarray(res.selected)
    assert sel[0] == 1.0                      # backstop still honored
    assert sel.sum() > 1, "sentinel share locked out feasible devices"


def test_das_selects_fewer_than_full_at_scale():
    """DAS (strict re-entry, the paper-literal Alg. 2 reading) schedules a
    strict subset at K=100 under the 1 MHz band; the paper's <=20% figure
    is not derivable from the stated constants (EXPERIMENTS.md
    §Repro-divergences) but the qualitative claim — a small, diverse
    subset instead of full participation — must hold."""
    k = 100
    net, gains = _network(11, k)
    sizes = jax.random.randint(jax.random.key(12), (k,), 50, 1500)
    hists = jax.random.randint(jax.random.key(13), (k, 10), 0,
                               30).astype(jnp.float32)
    idx = diversity.diversity_index(label_hists=hists, data_sizes=sizes,
                                    ages=jnp.zeros((k,), jnp.int32))
    sch = scheduler.SchedulerConfig(method="das", n_min=1,
                                    iterations_max=6, reentry="strict")
    res = scheduler.schedule(jax.random.key(14), idx,
                             jnp.zeros((k,), jnp.int32), sizes, gains,
                             net, WCFG, sch)
    frac = float(np.asarray(res.selected).sum()) / k
    assert frac <= 0.7, f"DAS selected {frac:.0%} at K=100"
    # And the selected set skews diverse: mean index of selected devices
    # exceeds the population mean.
    sel = np.asarray(res.selected) > 0
    assert np.asarray(idx)[sel].mean() > np.asarray(idx).mean()
