"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("k", [1, 3, 17, 64])
@pytest.mark.parametrize("p", [128, 1000, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_agg_sweep(k, p, dtype):
    key = jax.random.key(k * 1000 + p)
    u = jax.random.normal(key, (k, p), dtype)
    w = jax.nn.softmax(jax.random.normal(jax.random.key(1), (k,)))
    got = ops.fedavg_agg(u, w)
    want = ref.fedavg_agg(u, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("k,n,c", [(1, 64, 10), (7, 300, 10), (16, 128, 3),
                                   (5, 1024, 32)])
def test_diversity_sweep(k, n, c):
    key = jax.random.key(k + n)
    labels = jax.random.randint(key, (k, n), 0, c)
    mask = (jax.random.uniform(jax.random.key(2), (k, n)) > 0.3
            ).astype(jnp.float32)
    got = ops.diversity_stats(labels, mask, c)
    want = ref.diversity(labels, mask, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # Gini-Simpson in [0, 1 - 1/C]
    assert np.all(np.asarray(got)[:, 0] >= -1e-6)
    assert np.all(np.asarray(got)[:, 0] <= 1 - 1.0 / c + 1e-6)


@pytest.mark.parametrize("s,k,c", [(1, 4, 3), (3, 20, 10), (2, 64, 16)])
@pytest.mark.parametrize("size_cap", [0.0, 120.0])
def test_stream_update_sweep(s, k, c, size_cap):
    key = jax.random.key(s * 100 + k)
    hists = jax.random.uniform(key, (s, k, c), minval=0.0, maxval=80.0)
    deltas = jax.random.uniform(jax.random.key(1), (s, k, c),
                                minval=-10.0, maxval=15.0)
    arrivals = jax.random.uniform(jax.random.key(4), (s, k), maxval=25.0)
    stale = jax.random.uniform(jax.random.key(2), (s, k), maxval=6.0)
    sel = (jax.random.uniform(jax.random.key(3), (s, k)) > 0.5
           ).astype(jnp.float32)
    got = ops.stream_update(hists, deltas, arrivals, stale, sel,
                            decay=0.75, size_cap=size_cap)
    want = ref.stream_update(hists, deltas, arrivals, stale, sel,
                             decay=0.75, size_cap=size_cap)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)
    counts, stats = got[0], got[1]
    assert np.all(np.asarray(counts) >= 0.0)
    if size_cap > 0.0:
        assert np.all(np.asarray(stats[..., 2]) <= size_cap + 1e-3)


@pytest.mark.parametrize("seq", [64, 192, 257])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                           (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(seq, causal, window, dtype):
    b, h, kv, hd = 2, 4, 2, 64
    key = jax.random.key(seq)
    q = jax.random.normal(key, (b, seq, h, hd), dtype)
    k = jax.random.normal(jax.random.key(1), (b, seq, kv, hd), dtype)
    v = jax.random.normal(jax.random.key(2), (b, seq, kv, hd), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64)
    kk = jnp.repeat(k, h // kv, axis=2)
    vv = jnp.repeat(v, h // kv, axis=2)

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, seq, hd)

    want = ref.flash_attention(flat(q), flat(kk), flat(vv), causal=causal,
                               window=window)
    want = want.reshape(b, h, seq, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_cross_attention_lengths():
    """Sq != Skv (cross attention / decode-style)."""
    b, h, hd = 1, 2, 64
    q = jax.random.normal(jax.random.key(0), (b, 64, h, hd))
    k = jax.random.normal(jax.random.key(1), (b, 200, h, hd))
    v = jax.random.normal(jax.random.key(2), (b, 200, h, hd))
    got = ops.flash_attention(q, k, v, causal=False, block_q=64,
                              block_k=64)

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], hd)

    want = ref.flash_attention(flat(q), flat(k), flat(v), causal=False)
    want = want.reshape(b, h, 64, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
