"""Allocator subsystem: fused solvers, Pallas PGD kernel, policy routing.

Contracts under test:

* fused joint bisection == nested reference bisection to <1e-3 on
  random instances (alpha vector and T*), warm-started or cold
* Newton rate inversion == bisection reference
* ``project_simplex`` edge cases: empty mask, single active device,
  radius != 1
* Pallas ``sub2_pgd`` kernel == pure-jnp oracle (``kernels/ref.py``) in
  interpret mode — single instance, batched (S, K) lane, and vmap of
  the single-instance entry (the scenario-driver path)
* ``FusedPGD`` produces feasible allocations with objectives matching
  the tangent-PGD reference allocator
* every policy routes Sub2 through the registry (spy allocator), and
  the DAS/scan/batch parity contract holds with ``fused_pgd`` swapped in
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import allocator
from repro.core import bandwidth as bw
from repro.core import federated, scheduler, wireless
from repro.data import partition, synthetic
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref
from repro.models import paper_nets

WCFG = wireless.WirelessConfig()


def _instance(seed: int, k: int, sel_p: float = 0.5):
    net = wireless.sample_network(jax.random.key(seed), k, WCFG)
    gains = wireless.sample_fading(jax.random.key(seed + 1), net)
    sizes = jax.random.randint(jax.random.key(seed + 2), (k,), 50, 1500)
    t_train = wireless.train_time(sizes, net, WCFG)
    sel = (jax.random.uniform(jax.random.key(seed + 3), (k,)) > sel_p
           ).astype(jnp.float32).at[0].set(1.0)
    return net, gains, t_train, sel


# ---------------------------------------------------------------------------
# Fused joint bisection vs nested reference
# ---------------------------------------------------------------------------

@given(st.integers(2, 60), st.integers(0, 10 ** 6))
@settings(max_examples=25, deadline=None)
def test_fused_min_time_matches_nested_reference(k, seed):
    net, gains, t_train, sel = _instance(seed % 1000, k)
    a_ref, t_ref = bw.min_time_allocation_reference(
        sel, t_train, gains, net.tx_power, WCFG)
    a_fus, t_fus = bw.min_time_allocation(
        sel, t_train, gains, net.tx_power, WCFG)
    np.testing.assert_allclose(np.asarray(a_fus), np.asarray(a_ref),
                               atol=1e-3)
    assert abs(float(t_fus) - float(t_ref)) <= 1e-3 * max(float(t_ref),
                                                          1.0)


@given(st.integers(2, 40), st.integers(0, 10 ** 6))
@settings(max_examples=10, deadline=None)
def test_fused_min_time_warm_start_agrees(k, seed):
    """Any positive warm start must land on the same solution (Newton on
    concave f converges globally)."""
    net, gains, t_train, sel = _instance(seed % 1000, k)
    cold, t_cold = bw.min_time_allocation(sel, t_train, gains,
                                          net.tx_power, WCFG)
    warm_seed = jax.random.uniform(jax.random.key(seed + 9), (k,),
                                   minval=0.01, maxval=1.0)
    warm, t_warm = bw.min_time_allocation(sel, t_train, gains,
                                          net.tx_power, WCFG,
                                          alpha0=warm_seed)
    np.testing.assert_allclose(np.asarray(warm), np.asarray(cold),
                               atol=1e-4)
    assert float(t_warm) == pytest.approx(float(t_cold), rel=1e-5)


def test_newton_invert_rate_matches_bisect():
    k = 32
    net, gains, _, _ = _instance(17, k)
    r_req = jnp.logspace(3, 5.5, k)
    a_newton = bw.invert_rate(r_req, gains, net.tx_power, WCFG)
    a_bisect = bw.invert_rate_bisect(r_req, gains, net.tx_power, WCFG)
    np.testing.assert_allclose(np.asarray(a_newton), np.asarray(a_bisect),
                               atol=1e-6)


def test_newton_invert_rate_infeasible_hits_ceiling():
    """Requirements beyond the band saturate at the same sentinel the
    bisection used, so budget checks see the same overflow."""
    k = 8
    net, gains, _, _ = _instance(23, k)
    a = bw.invert_rate(jnp.full((k,), 1e30), gains, net.tx_power, WCFG)
    np.testing.assert_allclose(np.asarray(a), bw.ALPHA_CEIL)


# ---------------------------------------------------------------------------
# project_simplex edge cases
# ---------------------------------------------------------------------------

def test_project_simplex_empty_mask():
    v = jnp.asarray([0.3, -0.2, 0.9])
    out = bw.project_simplex(v, jnp.zeros(3))
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_project_simplex_single_active():
    v = jnp.asarray([0.3, -5.0, 0.9])
    mask = jnp.asarray([0.0, 1.0, 0.0])
    out = np.asarray(bw.project_simplex(v, mask))
    np.testing.assert_allclose(out, [0.0, 1.0, 0.0], atol=1e-6)


def test_project_simplex_radius():
    v = jnp.asarray([0.5, 0.8, -0.1, 0.3])
    mask = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    for radius in (0.25, 2.0):
        p = np.asarray(bw.project_simplex(v, mask, radius=radius))
        assert p[2] == 0.0
        assert p.sum() == pytest.approx(radius, abs=1e-5)
        assert np.all(p >= 0.0)


# ---------------------------------------------------------------------------
# Pallas PGD kernel vs oracle
# ---------------------------------------------------------------------------

_PGD_KW = dict(rho=0.5, lr=0.05, tau=1e-3, iters=60,
               bandwidth_hz=WCFG.bandwidth_hz, model_bits=WCFG.model_bits,
               min_alpha=WCFG.min_alpha)


def _starts(sel, t_train, gains, tx_power):
    mask = (sel > 0.0).astype(jnp.float32)
    wf, _ = bw.min_time_allocation(sel, t_train, gains, tx_power, WCFG)
    uniform = mask / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.stack([wf, uniform])


@given(st.integers(2, 48), st.integers(0, 10 ** 6))
@settings(max_examples=10, deadline=None)
def test_sub2_pgd_kernel_matches_oracle(k, seed):
    """The oracle's gradient comes from ``jax.grad`` of the smoothed
    objective (independent of the kernel's hand-written analytic one),
    so the two trajectories agree to float-noise amplification rather
    than bitwise: tight on the objective, loose on near-flat alpha
    directions.  A sign/derivative error in the kernel diverges by
    orders of magnitude more than these tolerances."""
    net, gains, t_train, sel = _instance(seed % 1000, k)
    a0 = _starts(sel, t_train, gains, net.tx_power)
    c = gains * net.tx_power / (WCFG.bandwidth_hz * WCFG.noise_psd)
    a_ref, o_ref = kernel_ref.sub2_pgd(sel, t_train, c, net.tx_power, a0,
                                       **_PGD_KW)
    a_krn, o_krn = kernel_ops.sub2_pgd(sel, t_train, gains, net.tx_power,
                                       a0, noise_psd=WCFG.noise_psd,
                                       **_PGD_KW)
    np.testing.assert_allclose(np.asarray(a_krn), np.asarray(a_ref),
                               atol=1e-2)
    assert float(o_krn) == pytest.approx(float(o_ref), rel=1e-3)


def test_sub2_pgd_kernel_batched_lane():
    """The (S, K) lane equals per-row single launches, and vmap of the
    single-instance entry (the vmapped-driver path) equals the batch."""
    k, s = 20, 4
    rows = [_instance(100 + 3 * i, k) for i in range(s)]
    sel = jnp.stack([r[3] for r in rows])
    tt = jnp.stack([r[2] for r in rows])
    gains = jnp.stack([r[1] for r in rows])
    power = jnp.stack([r[0].tx_power for r in rows])
    a0 = jnp.stack([_starts(rows[i][3], rows[i][2], rows[i][1],
                            rows[i][0].tx_power) for i in range(s)])
    kw = dict(noise_psd=WCFG.noise_psd, **_PGD_KW)
    a_b, o_b = kernel_ops.sub2_pgd(sel, tt, gains, power, a0, **kw)
    assert a_b.shape == (s, k) and o_b.shape == (s,)
    for i in range(s):
        a_i, o_i = kernel_ops.sub2_pgd(sel[i], tt[i], gains[i], power[i],
                                       a0[i], **kw)
        np.testing.assert_array_equal(np.asarray(a_b[i]), np.asarray(a_i))
        assert float(o_b[i]) == float(o_i)
    a_v, o_v = jax.vmap(
        lambda *xs: kernel_ops.sub2_pgd(*xs, **kw))(sel, tt, gains, power,
                                                    a0)
    np.testing.assert_allclose(np.asarray(a_v), np.asarray(a_b),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(o_v), np.asarray(o_b),
                               rtol=1e-6)


def test_sub2_pgd_kernel_empty_selection():
    k = 8
    net, gains, t_train, _ = _instance(31, k)
    sel = jnp.zeros((k,), jnp.float32)
    a0 = jnp.zeros((2, k), jnp.float32)
    a, o = kernel_ops.sub2_pgd(sel, t_train, gains, net.tx_power, a0,
                               noise_psd=WCFG.noise_psd, **_PGD_KW)
    np.testing.assert_array_equal(np.asarray(a), 0.0)
    assert float(o) == 0.0


def test_sub2_pgd_vmap_hits_batched_kernel_lane():
    """vmap of the single-instance entry must be wired straight onto the
    kernel's (S, K) grid by the custom_vmap rule — bitwise equal to the
    batched entry, with the rule's trace counter as the proof the
    generic pallas batching rule was bypassed."""
    k, s = 21, 3            # K unique to this test -> fresh trace
    rows = [_instance(70 + 3 * i, k) for i in range(s)]
    sel = jnp.stack([r[3] for r in rows])
    tt = jnp.stack([r[2] for r in rows])
    gains = jnp.stack([r[1] for r in rows])
    power = jnp.stack([r[0].tx_power for r in rows])
    a0 = jnp.stack([_starts(rows[i][3], rows[i][2], rows[i][1],
                            rows[i][0].tx_power) for i in range(s)])
    kw = dict(noise_psd=WCFG.noise_psd, **_PGD_KW)
    traces0 = kernel_ops.BATCHED_LANE_TRACES
    a_b, o_b = kernel_ops.sub2_pgd(sel, tt, gains, power, a0, **kw)
    a_v, o_v = jax.vmap(
        lambda *xs: kernel_ops.sub2_pgd(*xs, **kw))(sel, tt, gains, power,
                                                    a0)
    assert kernel_ops.BATCHED_LANE_TRACES > traces0, \
        "custom vmap rule did not handle the batched lane"
    np.testing.assert_array_equal(np.asarray(a_v), np.asarray(a_b))
    np.testing.assert_array_equal(np.asarray(o_v), np.asarray(o_b))


# ---------------------------------------------------------------------------
# Allocator implementations + registry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["waterfilling", "pgd", "fused_pgd",
                                  "importance"])
def test_allocator_feasibility(name):
    k = 30
    net, gains, t_train, sel = _instance(41, k)
    alloc = allocator.get(name, bw.Sub2Params.fast())
    alpha, obj = alloc.solve(sel, t_train, gains, net.tx_power, WCFG)
    alpha = np.asarray(alpha)
    assert alpha.sum() <= 1.0 + 1e-4
    assert np.all(alpha >= 0.0)
    assert np.all(alpha[np.asarray(sel) == 0.0] == 0.0)
    assert np.isfinite(float(obj))


def test_fused_pgd_objective_matches_reference_pgd():
    """The Pallas descent must land within a few percent of the tangent
    PGD reference (they run the same algorithm; only the simplex
    projection's theta solve and the alpha flooring differ)."""
    params = bw.Sub2Params.fast()
    for seed in (3, 11, 29):
        net, gains, t_train, sel = _instance(seed, 24)
        _, o_ref = allocator.PGD(params).solve(sel, t_train, gains,
                                               net.tx_power, WCFG)
        _, o_fus = allocator.FusedPGD(params).solve(sel, t_train, gains,
                                                    net.tx_power, WCFG)
        assert float(o_fus) <= float(o_ref) * 1.03 + 1e-9


def test_registry_contents_and_errors():
    assert {"waterfilling", "pgd", "fused_pgd",
            "importance"} <= set(allocator.names())
    with pytest.raises(ValueError, match="unknown allocator"):
        allocator.get("nope")
    with pytest.raises(ValueError, match="already registered"):
        allocator.register("pgd", allocator.PGD)


def test_energy_weights_shift_bandwidth():
    """Raising one device's energy price must grow its share: its energy
    term dominates the weighted objective, so the solver buys it down
    with bandwidth (the mechanism ImportanceWeighted builds on)."""
    k = 6
    net, gains, t_train, _ = _instance(7, k)
    sel = jnp.ones((k,), jnp.float32)
    params = bw.Sub2Params(rho=0.9)
    base = jnp.ones((k,))
    boosted = base.at[2].set(8.0)
    a1, _ = bw.pgd_allocation(sel, t_train, gains, net.tx_power, WCFG,
                              params, energy_weights=base)
    a2, _ = bw.pgd_allocation(sel, t_train, gains, net.tx_power, WCFG,
                              params, energy_weights=boosted)
    assert float(a2[2]) > float(a1[2])


def test_energy_weights_none_matches_unweighted():
    k = 10
    net, gains, t_train, sel = _instance(13, k)
    a_none, o_none = bw.pgd_allocation(sel, t_train, gains, net.tx_power,
                                       WCFG, bw.Sub2Params.fast())
    a_ones, o_ones = bw.pgd_allocation(sel, t_train, gains, net.tx_power,
                                       WCFG, bw.Sub2Params.fast(),
                                       energy_weights=jnp.ones((k,)))
    np.testing.assert_array_equal(np.asarray(a_none), np.asarray(a_ones))
    assert float(o_none) == float(o_ones)


def test_importance_allocator_routes_through_scheduler():
    """SchedulerConfig.allocator='importance' must carry every policy's
    Sub2 solve through the importance-weighted objective while keeping
    the Eq. 13 feasibility invariants (the ROADMAP open item)."""
    k = 16
    net, gains, _, _ = _instance(53, k)
    sizes = jax.random.randint(jax.random.key(54), (k,), 50, 1500)
    ages = jnp.zeros((k,), jnp.int32)
    idx = jnp.linspace(0.1, 0.9, k)
    for method in ("das", "abs", "full"):
        sch = scheduler.SchedulerConfig(method=method, n_min=2,
                                        iterations_max=3,
                                        sub2=bw.Sub2Params.fast(),
                                        allocator="importance")
        res = scheduler.schedule(jax.random.key(55), idx, ages, sizes,
                                 gains, net, WCFG, sch)
        sel = np.asarray(res.selected)
        alpha = np.asarray(res.alpha)
        assert sel.sum() >= 2
        assert alpha.sum() <= 1.0 + 1e-4
        assert np.all(alpha >= 0.0)
        assert np.all(alpha[sel == 0.0] == 0.0)
    # The pricing must actually move the solution vs the plain objective.
    sel_full = jnp.ones((k,), jnp.float32)
    t_train = wireless.train_time(sizes, net, WCFG)
    a_plain, _ = allocator.PGD(bw.Sub2Params.fast()).solve(
        sel_full, t_train, gains, net.tx_power, WCFG)
    a_imp, _ = allocator.ImportanceWeighted(bw.Sub2Params.fast()).solve(
        sel_full, t_train, gains, net.tx_power, WCFG, data_sizes=sizes)
    assert not np.allclose(np.asarray(a_plain), np.asarray(a_imp),
                           atol=1e-4)


def test_importance_weights_follow_data_sizes_not_hardware():
    """With |D_k| supplied, the importance factor must track the FedAvg
    data share: equal sizes + wildly different CPU speeds (t_train)
    yield equal importance, and a larger |D_k| yields a larger weight
    (channel pricing held fixed)."""
    k = 4
    sel = jnp.ones((k,), jnp.float32)
    t_train = jnp.asarray([9.0, 1.0, 5.0, 5.0])   # slow CPU != important
    gains = jnp.full((k,), 1e-9)
    power = jnp.full((k,), 2.0)
    sizes = jnp.asarray([500, 500, 250, 1000])
    w = np.asarray(allocator.importance_weights(
        sel, t_train, gains, power, WCFG, data_sizes=sizes))
    assert w[0] == pytest.approx(w[1])            # hardware ignored
    assert w[3] > w[2]                            # data share respected


def test_policies_route_through_registry():
    """A spy allocator registered under a fresh name must be the one every
    policy's Sub2 solve goes through (equal shares are its fingerprint)."""

    @dataclasses.dataclass(frozen=True)
    class EqualShare:
        params: bw.Sub2Params = bw.Sub2Params()

        def solve(self, selected, t_train, gains, tx_power, cfg,
                  alpha0=None, data_sizes=None, payload_bits=None):
            mask = (selected > 0.0).astype(jnp.float32)
            alpha = mask / jnp.maximum(jnp.sum(mask), 1.0)
            return alpha, jnp.asarray(0.0, jnp.float32)

    allocator.register("equal_share_spy", EqualShare, overwrite=True)
    k = 16
    net, gains, _, _ = _instance(53, k)
    sizes = jax.random.randint(jax.random.key(54), (k,), 50, 1500)
    ages = jnp.zeros((k,), jnp.int32)
    idx = jnp.linspace(0.1, 0.9, k)
    for method in ("das", "abs", "random", "full"):
        sch = scheduler.SchedulerConfig(method=method, n_min=2,
                                        iterations_max=3,
                                        allocator="equal_share_spy")
        res = scheduler.schedule(jax.random.key(55), idx, ages, sizes,
                                 gains, net, WCFG, sch)
        sel = np.asarray(res.selected)
        alpha = np.asarray(res.alpha)
        n_sel = sel.sum()
        assert n_sel >= 2
        np.testing.assert_allclose(alpha[sel > 0], 1.0 / n_sel, rtol=1e-6)
        assert np.all(alpha[sel == 0] == 0.0)


# ---------------------------------------------------------------------------
# Driver parity with FusedPGD swapped in
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_world():
    imgs, labs = synthetic.generate(0, samples_per_class=400)
    pspec = partition.PartitionSpec(num_devices=10, num_shards=80,
                                    shard_size=50)
    data = partition.partition(imgs, labs, seed=1, spec=pspec)
    net = wireless.sample_network(jax.random.key(0), 10, WCFG)
    mspec = paper_nets.PaperNetSpec(kind="mlp")
    params = paper_nets.init(jax.random.key(3), mspec)
    loss = functools.partial(paper_nets.loss_fn, spec=mspec)
    ev = functools.partial(paper_nets.accuracy, spec=mspec)
    return data, net, params, loss, ev


def _fused_cfgs(rounds=2):
    scfg = scheduler.SchedulerConfig(method="das", n_min=2,
                                     iterations_max=3,
                                     sub2=bw.Sub2Params.fast(),
                                     allocator="fused_pgd")
    fcfg = federated.FLConfig(num_rounds=rounds, batch_size=50,
                              learning_rate=0.1)
    return scfg, fcfg


def test_scan_matches_legacy_with_fused_pgd(tiny_world):
    data, net, params, loss, ev = tiny_world
    scfg, fcfg = _fused_cfgs()
    kw = dict(init_params=params, loss_fn=loss, eval_fn=ev, data=data,
              net=net, wcfg=WCFG, scfg=scfg, fcfg=fcfg,
              key=jax.random.key(4))
    p_scan, h_scan = federated.run_federated(**kw)
    p_loop, h_loop = federated.run_federated_loop(**kw)
    for a, b in zip(h_scan, h_loop):
        assert np.array_equal(a.selected, b.selected)
        assert a.round_time == b.round_time
    for a, b in zip(jax.tree_util.tree_leaves(p_scan),
                    jax.tree_util.tree_leaves(p_loop)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batch_matches_single_with_fused_pgd(tiny_world):
    data, _, params, loss, ev = tiny_world
    scfg, fcfg = _fused_cfgs()
    s = 2
    nets = wireless.sample_networks(jax.random.key(21), s,
                                    data.num_devices, WCFG)
    keys = jax.random.split(jax.random.key(22), s)
    p_b, metrics = federated.run_federated_batch(
        init_params=params, loss_fn=loss, eval_fn=ev, data=data,
        nets=nets, wcfg=WCFG, scfg=scfg, fcfg=fcfg, keys=keys)
    hists_b = federated.batch_metrics_to_records(metrics)
    for i in range(s):
        net_i = jax.tree_util.tree_map(lambda a, i=i: a[i], nets)
        _, hist_i = federated.run_federated(
            init_params=params, loss_fn=loss, eval_fn=ev, data=data,
            net=net_i, wcfg=WCFG, scfg=scfg, fcfg=fcfg, key=keys[i])
        for a, b in zip(hists_b[i], hist_i):
            assert np.array_equal(a.selected, b.selected)
            assert a.round_time == b.round_time
