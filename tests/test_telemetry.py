"""In-scan telemetry tests (DESIGN.md §13): the inert-dispatch bitwise
contract across subsystem compositions, frame contents, batch==singles
parity on telemetry leaves, the legacy loop's host-collected frames,
JSONL sink round-trips through the report CLI, the shared rewind
contract, and the RoundRecord sentinel fix."""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry
from repro.core import (compression, events, faults, federated,
                        scheduler, streaming, wireless)
from repro.data import partition, synthetic
from repro.models import paper_nets
from repro.telemetry import record as record_lib
from repro.telemetry import report as report_lib
from repro.telemetry import sinks


# ---------------------------------------------------------------------------
# Fixtures: one tiny world shared module-wide (compiles dominate runtime)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def world():
    imgs, labs = synthetic.generate(0, samples_per_class=200)
    data = partition.partition(
        imgs, labs, seed=1,
        spec=partition.PartitionSpec(num_devices=8, num_shards=36,
                                     shard_size=50))
    mspec = paper_nets.PaperNetSpec(kind="mlp", mlp_hidden=8)
    params = paper_nets.init(jax.random.key(3), mspec)
    loss = functools.partial(paper_nets.loss_fn, spec=mspec)
    ev = functools.partial(paper_nets.accuracy, spec=mspec)
    return data, params, loss, ev


WCFG = wireless.WirelessConfig()
SCFG = scheduler.SchedulerConfig(method="das", n_min=2, iterations_max=3,
                                 reliability_weight=0.4)
FL = federated.FLConfig(num_rounds=3, batch_size=50, learning_rate=0.1)
TEL = telemetry.TelemetryConfig()

# Subsystem compositions the bitwise contract must hold across.
COMPOSITIONS = {
    "plain": {},
    "faulty": {"faults": faults.FaultConfig(drop_prob=0.3, max_retries=2,
                                            reliability_ema=0.3)},
    "compressed": {"compression": compression.CompressionConfig(
        codec="quant", bit_width=8)},
    "streaming": {"stream": streaming.StreamConfig()},
    "dispatch": {"dispatch_cap": 4},
    "async": {"events": events.EventConfig(availability="churn",
                                           buffer_size=2,
                                           tick_horizon=0.5,
                                           num_events=4),
              "faults": faults.FaultConfig(reliability_ema=0.3)},
}


def _run_kwargs(world):
    data, params, loss, ev = world
    net = wireless.sample_network(jax.random.key(0), data.num_devices,
                                  WCFG)
    return dict(init_params=params, loss_fn=loss, eval_fn=ev, data=data,
                net=net, wcfg=WCFG, scfg=SCFG, key=jax.random.key(42))


def _same_tree(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


# ---------------------------------------------------------------------------
# Config normalization (the faults.py inert-dispatch pattern)
# ---------------------------------------------------------------------------

def test_inert_config_normalizes_to_none():
    assert telemetry.active(None) is None
    inert = telemetry.TelemetryConfig(scores=False, sub2=False,
                                      transport=False, faults=False,
                                      events=False, signals=False)
    assert telemetry.is_inert(inert)
    assert telemetry.active(inert) is None
    assert telemetry.active(TEL) is TEL
    assert not telemetry.is_inert(TEL)


def test_inert_config_builds_two_tuple_sim(world):
    # An all-False TelemetryConfig compiles the no-telemetry program:
    # same return arity, same values.
    inert = telemetry.TelemetryConfig(scores=False, sub2=False,
                                      transport=False, faults=False,
                                      events=False, signals=False)
    kw = _run_kwargs(world)
    out_none = federated.run_federated(fcfg=FL, **kw)
    out_inert = federated.run_federated(
        fcfg=dataclasses.replace(FL, telemetry=inert), **kw)
    assert len(out_none) == 2 and len(out_inert) == 2
    assert _same_tree(out_none[0], out_inert[0])


# ---------------------------------------------------------------------------
# The bitwise contract: telemetry only observes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("comp", sorted(COMPOSITIONS))
def test_primary_outputs_bitwise_with_telemetry(world, comp):
    kw = _run_kwargs(world)
    fcfg = dataclasses.replace(FL, **COMPOSITIONS[comp])
    p0, h0 = federated.run_federated(fcfg=fcfg, **kw)
    p1, h1, frames = federated.run_federated(
        fcfg=dataclasses.replace(fcfg, telemetry=TEL), **kw)
    assert _same_tree(p0, p1)
    for a, b in zip(h0, h1):
        assert a.accuracy == b.accuracy
        assert a.round_time == b.round_time
        assert a.energy_total == b.energy_total
        assert a.n_selected == b.n_selected
        assert a.n_success == b.n_success
        assert np.array_equal(a.selected, b.selected)
    # Frames exist and carry one row per round.
    n = federated.sim_length(fcfg)
    assert all(np.asarray(v).shape[0] == n for v in frames.values())


def test_frame_contents_faulty(world):
    kw = _run_kwargs(world)
    fcfg = dataclasses.replace(FL, faults=COMPOSITIONS["faulty"]["faults"],
                               telemetry=TEL)
    _, hist, frames = federated.run_federated(fcfg=fcfg, **kw)
    k = kw["data"].num_devices
    expect = {"admitted", "dispatched", "delivered", "score_base",
              "score_boosted", "score_final", "score_rank", "alpha",
              "sub2_iters", "sub2_obj", "sub2_obj_eq", "sub2_gain",
              "payload_bits", "t_up", "energy_up", "fault_outage",
              "fault_dropout", "fault_straggler", "fault_attempts"}
    assert expect <= set(frames)
    for r, rec in enumerate(hist):
        # The realized set in the frame is the history's selected row,
        # and delivered counts match n_success.
        assert np.array_equal(np.asarray(frames["dispatched"][r]),
                              rec.selected)
        assert int(np.asarray(frames["delivered"][r]).sum()) \
            == rec.n_success
        assert int(np.asarray(frames["sub2_iters"][r])) >= 0
    # Score rank is a permutation of 0..K-1 each round.
    for row in np.asarray(frames["score_rank"]):
        assert sorted(row.tolist()) == list(range(k))
    # Fault events are disjoint classifications within the admitted set.
    outage = np.asarray(frames["fault_outage"])
    dropout = np.asarray(frames["fault_dropout"])
    assert ((outage + dropout) <= 1.0 + 1e-6).all()


def test_event_frames_include_event_state(world):
    kw = _run_kwargs(world)
    fcfg = dataclasses.replace(FL, **COMPOSITIONS["async"],
                               telemetry=TEL)
    _, _, frames = federated.run_federated(fcfg=fcfg, **kw)
    expect = {"avail", "free", "in_flight", "buffer_fill", "flushed",
              "staleness_tau", "clock", "model_version"}
    assert expect <= set(frames)
    clock = np.asarray(frames["clock"])
    assert (np.diff(clock) >= 0.0).all()       # time moves forward
    avail = np.asarray(frames["avail"])
    assert ((avail == 0.0) | (avail == 1.0)).all()


def test_streaming_frames_include_staleness(world):
    kw = _run_kwargs(world)
    fcfg = dataclasses.replace(FL, stream=streaming.StreamConfig(),
                               telemetry=TEL)
    _, _, frames = federated.run_federated(fcfg=fcfg, **kw)
    assert "staleness" in frames
    assert np.asarray(frames["staleness"]).shape \
        == (FL.num_rounds, kw["data"].num_devices)


# ---------------------------------------------------------------------------
# Batch == singles on every telemetry leaf
# ---------------------------------------------------------------------------

# The raw score surfaces re-expose the diversity index, whose (S, K, C)
# reduction lowers with a different vectorization under vmap than the
# single-scenario (K, C) program — a <=1-ULP float difference that the
# drivers' decision outputs (rank, admission, Sub2, energy) provably
# absorb (they ARE bitwise below).  Every other leaf is exact.
_ULP_LEAVES = ("score_base", "score_boosted", "score_final")


def test_batch_matches_singles_on_frames(world):
    data, params, loss, ev = world
    s = 3
    fcfg = dataclasses.replace(
        FL, faults=COMPOSITIONS["faulty"]["faults"], telemetry=TEL)
    nets = wireless.sample_networks(jax.random.key(5), s,
                                    data.num_devices, WCFG)
    keys = federated.scenario_keys(jax.random.key(11), 0, s)
    _, _, frames_b = federated.run_federated_batch(
        init_params=params, loss_fn=loss, eval_fn=ev, data=data,
        nets=nets, wcfg=WCFG, scfg=SCFG, fcfg=fcfg, keys=keys)
    for i in range(s):
        net_i = jax.tree_util.tree_map(lambda a, i=i: a[i], nets)
        _, _, frames_i = federated.run_federated(
            init_params=params, loss_fn=loss, eval_fn=ev, data=data,
            net=net_i, wcfg=WCFG, scfg=SCFG, fcfg=fcfg, key=keys[i])
        assert set(frames_b) == set(frames_i)
        for name in frames_i:
            a = np.asarray(frames_b[name][i])
            b = np.asarray(frames_i[name])
            if name in _ULP_LEAVES:
                np.testing.assert_allclose(a, b, rtol=2e-7, atol=0.0,
                                           err_msg=name)
            else:
                assert np.array_equal(a, b), name


# ---------------------------------------------------------------------------
# Legacy loop: host-collected frames, same field set
# ---------------------------------------------------------------------------

def test_loop_frames_match_scan(world):
    kw = _run_kwargs(world)
    fcfg = dataclasses.replace(
        FL, faults=COMPOSITIONS["faulty"]["faults"], telemetry=TEL)
    _, h_scan, f_scan = federated.run_federated(fcfg=fcfg, **kw)
    _, h_loop, f_loop = federated.run_federated_loop(fcfg=fcfg, **kw)
    assert set(f_scan) == set(f_loop)
    for a, b in zip(h_scan, h_loop):
        assert a.accuracy == b.accuracy
        assert np.array_equal(a.selected, b.selected)
    # Same <=1-ULP story as batch==singles: the loop's separately-jitted
    # round program fuses the diversity-index reduction differently
    # than the scan body, so the raw score surfaces may differ in the
    # last bit; every decision leaf is exact.
    for name in f_scan:
        a, b = np.asarray(f_scan[name]), np.asarray(f_loop[name])
        if name in _ULP_LEAVES:
            np.testing.assert_allclose(a, b, rtol=2e-7, atol=0.0,
                                       err_msg=name)
        else:
            assert np.array_equal(a, b), name


# ---------------------------------------------------------------------------
# Sinks: JSONL round-trip, report CLI, shared rewind contract
# ---------------------------------------------------------------------------

def test_jsonl_roundtrip_and_report(world, tmp_path, capsys):
    data, params, loss, ev = world
    fcfg = dataclasses.replace(
        FL, faults=COMPOSITIONS["faulty"]["faults"], telemetry=TEL)
    net = wireless.sample_network(jax.random.key(0), data.num_devices,
                                  WCFG)
    sim = federated.make_feel_sim(loss_fn=loss, eval_fn=ev, wcfg=WCFG,
                                  scfg=SCFG, fcfg=fcfg,
                                  capacity=data.capacity)
    hists = federated.client_histograms(data, fcfg.num_classes)
    test_x = synthetic.to_float(data.test_images)
    _, metrics, frames = sim(params, data.images, data.labels, data.mask,
                             data.sizes, hists, test_x,
                             data.test_labels, net, jax.random.key(42))
    log = tmp_path / "run.jsonl"
    man = sinks.run_manifest(fcfg, WCFG, SCFG)
    n = sinks.write_round_frames(str(log), frames, metrics=metrics,
                                 manifest=man)
    assert n == fcfg.num_rounds
    recs = sinks.read_jsonl(str(log))
    assert recs[0]["type"] == "manifest"
    rounds = [r for r in recs if r.get("type") == "round"]
    assert len(rounds) == n
    # Field round-trip: the JSON line holds the device-resolved frame.
    for r, rec in enumerate(rounds):
        assert rec["round"] == r
        assert rec["dispatched"] \
            == np.asarray(frames["dispatched"][r]).tolist()
        assert "accuracy" in rec and "n_success" in rec
        assert len(rec["score_final"]) == data.num_devices
        assert "sub2_iters" in rec and "fault_outage" in rec
    # Report CLI renders it and exits 0.
    assert report_lib.main([str(log)]) == 0
    out = capsys.readouterr().out
    for block in ("Run summary", "Round table", "Admission heatmap",
                  "Energy / fault breakdown", "Sub2 convergence"):
        assert block in out
    # Empty/absent logs exit non-zero.
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert report_lib.main([str(empty)]) == 1
    assert report_lib.main([str(tmp_path / "missing.jsonl")]) == 2


def test_jsonl_rewind_contract(tmp_path):
    path = tmp_path / "stream.jsonl"
    with open(path, "w") as f:
        f.write('{"cursor": 1, "v": "a"}\n')
        f.write('{"cursor": 2, "v": "b"}\n')
        f.write('{"cursor": 3, "v": "c"}\n')
        f.write('{"cursor": 4, "v": "torn')      # no newline, torn tail
    sinks.jsonl_rewind(str(path), 2)
    recs = sinks.read_jsonl(str(path))
    assert [r["cursor"] for r in recs] == [1, 2]
    # Appending after rewind continues the stream.
    sinks.jsonl_append(str(path), {"cursor": 3, "v": "c2"})
    recs = sinks.read_jsonl(str(path))
    assert [r["v"] for r in recs] == ["a", "b", "c2"]
    # Rewinding a missing file is a no-op, not an error.
    sinks.jsonl_rewind(str(tmp_path / "nope.jsonl"), 0)


def test_manifest_identity(tmp_path):
    man = sinks.write_manifest(str(tmp_path / "m.json"), FL, WCFG, SCFG)
    assert man["config_fingerprint"] \
        == sinks.config_fingerprint(FL, WCFG, SCFG)
    assert man["jax_version"] == jax.__version__
    assert man["device_count"] >= 1
    # Same configs -> same fingerprint; different -> different.
    assert sinks.config_fingerprint(FL) == sinks.config_fingerprint(FL)
    assert sinks.config_fingerprint(FL) != sinks.config_fingerprint(
        dataclasses.replace(FL, num_rounds=99))


# ---------------------------------------------------------------------------
# Sweep integration: per-scenario JSONL streams
# ---------------------------------------------------------------------------

def test_sweep_telemetry_dir(world, tmp_path):
    from repro.sweep import grid as grid_lib
    from repro.sweep import runner as runner_lib

    data, params, loss, ev = world
    fl = dataclasses.replace(FL, num_rounds=2, telemetry=TEL)
    spec = grid_lib.SweepSpec(
        fl=fl, sched=SCFG, wireless=WCFG,
        axes=(grid_lib.Axis("sched", "method", ("das", "random")),),
        scenarios_per_point=2, base_seed=0)
    tel_dir = tmp_path / "tel"
    out = runner_lib.run_sweep(spec, data=data, loss_fn=loss, eval_fn=ev,
                               init_params=params, use_sharding=False,
                               telemetry_dir=str(tel_dir))
    assert len(out) == 2
    logs = sorted(p.name for p in tel_dir.glob("*.jsonl"))
    assert logs == ["point000_scn00000.jsonl", "point000_scn00001.jsonl",
                    "point001_scn00000.jsonl", "point001_scn00001.jsonl"]
    assert (tel_dir / "manifest.json").exists()
    for name in logs:
        recs = sinks.read_jsonl(str(tel_dir / name))
        assert len(recs) == fl.num_rounds
        scn = int(name.split("_scn")[1].split(".")[0])
        assert all(r["scenario"] == scn for r in recs)
    assert report_lib.main([str(tel_dir / n) for n in logs]) == 0


# ---------------------------------------------------------------------------
# Satellites: RoundRecord sentinel, phase scopes
# ---------------------------------------------------------------------------

def test_round_record_sentinel_normalized():
    rec = federated.RoundRecord(
        round=0, accuracy=0.5, n_selected=4, round_time=1.0,
        energy_total=2.0, energy_per_device=0.5,
        selected=np.ones(4))
    assert rec.n_success == 4                  # -1 sentinel never leaks
    rec2 = federated.RoundRecord(
        round=0, accuracy=0.5, n_selected=4, round_time=1.0,
        energy_total=2.0, energy_per_device=0.5,
        selected=np.ones(4), n_success=3)
    assert rec2.n_success == 3                 # explicit value kept


def test_reliable_edge_history_n_success(world):
    kw = _run_kwargs(world)
    _, hist = federated.run_federated(fcfg=FL, **kw)
    for rec in hist:
        assert rec.n_success == rec.n_selected
        assert rec.n_success >= 0


def test_phase_scopes_cover_all_phases(world):
    kw = _run_kwargs(world)
    fcfg = dataclasses.replace(FL, stream=streaming.StreamConfig())
    federated.run_federated(fcfg=fcfg, **kw)
    assert set(telemetry.PHASES) <= telemetry.seen_phases()
