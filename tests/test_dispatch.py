"""Admitted-set dense-block dispatch + scan-carry diet (DESIGN.md §11).

Covers the ISSUE-7 contracts: ``dispatch_cap >= K`` bitwise-equals the
masked all-K path for the plain/compressed/faulty round bodies,
overflow drops are schedule-rank-deterministic (and identical under
vmap), the empty-admitted-set carry survives dispatch, and the
``carry_dtype`` diet keeps the scan==legacy parity while documenting
what the EF fold-back property loses at bf16 storage precision.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression, faults, federated, scheduler, \
    streaming, wireless
from repro.data import partition, synthetic
from repro.models import paper_nets


# ---------------------------------------------------------------------------
# Fixtures: one tiny world shared module-wide (compiles dominate runtime)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def world():
    imgs, labs = synthetic.generate(0, samples_per_class=200)
    data = partition.partition(
        imgs, labs, seed=1,
        spec=partition.PartitionSpec(num_devices=8, num_shards=36,
                                     shard_size=50))
    mspec = paper_nets.PaperNetSpec(kind="mlp", mlp_hidden=8)
    params = paper_nets.init(jax.random.key(3), mspec)
    loss = functools.partial(paper_nets.loss_fn, spec=mspec)
    ev = functools.partial(paper_nets.accuracy, spec=mspec)
    return data, params, loss, ev


WCFG = wireless.WirelessConfig()
SCFG = scheduler.SchedulerConfig(method="das", n_min=2, iterations_max=3)
FL = federated.FLConfig(num_rounds=3, batch_size=50, learning_rate=0.1)
QUANT8 = compression.CompressionConfig(codec="quant", bit_width=8)
FAULTS = faults.FaultConfig(drop_prob=0.35, max_retries=2,
                            reliability_ema=0.3)


def _run_kwargs(world):
    data, params, loss, ev = world
    net = wireless.sample_network(jax.random.key(0), data.num_devices,
                                  WCFG)
    return dict(init_params=params, loss_fn=loss, eval_fn=ev, data=data,
                net=net, wcfg=WCFG, scfg=SCFG, key=jax.random.key(42))


def _same_tree(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _assert_history_equal(ha, hb):
    for a, b in zip(ha, hb):
        assert a.accuracy == b.accuracy
        assert a.round_time == b.round_time
        assert a.energy_total == b.energy_total
        assert a.n_selected == b.n_selected
        assert a.n_success == b.n_success
        assert a.n_dropped == b.n_dropped
        assert np.array_equal(a.selected, b.selected)


# ---------------------------------------------------------------------------
# The plan itself: schedule rank, overflow, vmap determinism
# ---------------------------------------------------------------------------

def test_dispatch_plan_schedule_rank_and_overflow():
    """Admitted devices occupy the block in device-index order (stable
    argsort = the documented schedule rank); overflow drops the highest
    ranks and counts them."""
    selected = jnp.asarray([0.0, 1.0, 1.0, 0.0, 1.0, 1.0, 1.0])
    idx, sel_eff, n_dropped = federated.dispatch_plan(selected, 3)
    np.testing.assert_array_equal(np.asarray(idx), [1, 2, 4])
    np.testing.assert_array_equal(np.asarray(sel_eff),
                                  [0, 1, 1, 0, 1, 0, 0])
    assert int(n_dropped) == 2
    # Capacity beyond the population clamps to K and drops nothing.
    idx_all, sel_all, nd_all = federated.dispatch_plan(selected, 99)
    assert idx_all.shape == (7,)
    np.testing.assert_array_equal(np.asarray(sel_all),
                                  np.asarray(selected))
    assert int(nd_all) == 0
    # Un-admitted lanes in a non-full block stay masked out.
    few = jnp.asarray([0.0, 1.0, 0.0, 0.0])
    idx_f, sel_f, nd_f = federated.dispatch_plan(few, 3)
    assert int(jnp.sum(sel_f)) == 1 and int(nd_f) == 0


def test_dispatch_plan_vmap_matches_singles():
    """The plan is a pure function of the mask — batching it cannot
    change any scenario's gather order (the batch == singles contract's
    dispatch leg)."""
    masks = jnp.asarray([[1.0, 0.0, 1.0, 1.0, 0.0],
                         [0.0, 1.0, 1.0, 1.0, 1.0],
                         [0.0, 0.0, 0.0, 0.0, 0.0]])
    plan = functools.partial(federated.dispatch_plan, n_cap=2)
    bi, bs, bn = jax.vmap(plan)(masks)
    for i in range(masks.shape[0]):
        si, ss, sn = plan(masks[i])
        np.testing.assert_array_equal(np.asarray(bi[i]), np.asarray(si))
        np.testing.assert_array_equal(np.asarray(bs[i]), np.asarray(ss))
        assert int(bn[i]) == int(sn)


def test_dispatch_cap_validation(world):
    kw = _run_kwargs(world)
    with pytest.raises(ValueError, match="dispatch_cap"):
        federated.run_federated(
            fcfg=dataclasses.replace(FL, dispatch_cap=0), **kw)
    with pytest.raises(ValueError, match="dispatch_cap"):
        federated.run_federated_loop(
            fcfg=dataclasses.replace(FL, dispatch_cap=-3), **kw)


# ---------------------------------------------------------------------------
# cap >= K: the dispatched program must be bitwise the masked path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["plain", "compressed", "faulty"])
def test_dispatch_cap_ge_k_bitwise_equals_masked(world, variant):
    kw = _run_kwargs(world)
    fl = {"plain": FL,
          "compressed": dataclasses.replace(FL, compression=QUANT8),
          "faulty": dataclasses.replace(FL, faults=FAULTS)}[variant]
    k = kw["data"].num_devices
    p_mask, h_mask = federated.run_federated(fcfg=fl, **kw)
    for cap in (k, k + 3):
        p_disp, h_disp = federated.run_federated(
            fcfg=dataclasses.replace(fl, dispatch_cap=cap), **kw)
        assert _same_tree(p_mask, p_disp)
        _assert_history_equal(h_mask, h_disp)
        assert all(r.n_dropped == 0 for r in h_disp)


# ---------------------------------------------------------------------------
# cap < admitted: real drops, every driver parity contract extended
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["plain", "compressed", "faulty"])
def test_dispatch_scan_matches_loop_with_drops(world, variant):
    kw = _run_kwargs(world)
    base = {"plain": FL,
            "compressed": dataclasses.replace(FL, compression=QUANT8),
            "faulty": dataclasses.replace(FL, faults=FAULTS)}[variant]
    fl = dataclasses.replace(base, dispatch_cap=2)
    p_scan, h_scan = federated.run_federated(fcfg=fl, **kw)
    p_loop, h_loop = federated.run_federated_loop(fcfg=fl, **kw)
    assert _same_tree(p_scan, p_loop)
    _assert_history_equal(h_scan, h_loop)
    # The cap actually bit: overflow drops happened and were counted.
    assert any(r.n_dropped > 0 for r in h_scan)
    assert all(r.n_selected <= 2 for r in h_scan)


def test_dispatch_batch_matches_singles(world):
    """Overflow-drop determinism under vmap: scenario i of a dispatched
    batch is bit-for-bit the dispatched single run."""
    data, params, loss, ev = world
    fl = dataclasses.replace(FL, dispatch_cap=3)
    s = 2
    nets = wireless.sample_networks(jax.random.key(5), s,
                                    data.num_devices, WCFG)
    keys = federated.scenario_keys(jax.random.key(9), 0, s)
    p_b, m_b = federated.run_federated_batch(
        fcfg=fl, init_params=params, loss_fn=loss, eval_fn=ev, data=data,
        nets=nets, wcfg=WCFG, scfg=SCFG, keys=keys)
    recs = federated.batch_metrics_to_records(m_b)
    dropped_any = False
    for i in range(s):
        net_i = jax.tree_util.tree_map(lambda a, i=i: a[i], nets)
        p_i, h_i = federated.run_federated(
            fcfg=fl, init_params=params, loss_fn=loss, eval_fn=ev,
            data=data, net=net_i, wcfg=WCFG, scfg=SCFG, key=keys[i])
        assert _same_tree(
            p_i, jax.tree_util.tree_map(lambda a, i=i: a[i], p_b))
        _assert_history_equal(h_i, recs[i])
        dropped_any |= any(r.n_dropped > 0 for r in h_i)
    assert dropped_any


def test_dispatch_empty_selection_carries_model(world):
    """The scalar-where empty-set guard survives dispatch: an all-zero
    admitted mask scatters only frozen lanes and the model carries."""
    data, params, loss, _ = world
    k = data.num_devices
    round_fn = federated.make_round_fn(
        loss, dataclasses.replace(FL, dispatch_cap=3), data.capacity)
    none_sel = jnp.zeros((k,))
    idx, sel_eff, n_dropped = federated.dispatch_plan(none_sel, 3)
    assert int(n_dropped) == 0
    out = round_fn(params, data.images, data.labels, data.mask,
                   data.sizes, sel_eff, jax.random.key(0),
                   dispatch_idx=idx)
    assert _same_tree(out, params)
    leaves = [np.asarray(x) for x in jax.tree_util.tree_leaves(out)]
    assert all(np.isfinite(l).all() for l in leaves)


def test_dispatch_drops_are_priced_out(world):
    """A capacity-dropped device neither trains nor transmits: its
    energy is zero and it cannot set the round's wall clock, but it
    also does not reset its age (it never participated)."""
    kw = _run_kwargs(world)
    fl = dataclasses.replace(FL, dispatch_cap=2)
    _, h_disp = federated.run_federated(fcfg=fl, **kw)
    _, h_mask = federated.run_federated(fcfg=FL, **kw)
    for rd in h_disp:
        assert rd.n_dropped >= 0 and rd.n_selected <= 2
    # Histories diverge after round 0 (ages/aggregates differ), so only
    # round 0 admits a direct masked-vs-dispatched comparison: same
    # schedule, strictly fewer joules when the cap bit.
    r0d, r0m = h_disp[0], h_mask[0]
    assert r0d.n_selected + r0d.n_dropped == r0m.n_selected
    if r0d.n_dropped > 0:
        assert r0d.energy_total < r0m.energy_total


# ---------------------------------------------------------------------------
# Scan-carry diet: bf16 storage for the EF residual and stream stats
# ---------------------------------------------------------------------------

def test_carry_dtype_float32_is_identity(world):
    kw = _run_kwargs(world)
    fl = dataclasses.replace(FL, compression=QUANT8)
    p0, h0 = federated.run_federated(fcfg=fl, **kw)
    p1, h1 = federated.run_federated(
        fcfg=dataclasses.replace(fl, carry_dtype="float32"), **kw)
    assert _same_tree(p0, p1)
    _assert_history_equal(h0, h1)


def test_carry_dtype_validation(world):
    kw = _run_kwargs(world)
    with pytest.raises((ValueError, TypeError)):
        federated.run_federated(
            fcfg=dataclasses.replace(FL, compression=QUANT8,
                                     carry_dtype="int8"), **kw)


@pytest.mark.parametrize("extras", ["compressed", "stream",
                                    "compressed_stream_dispatch"])
def test_carry_diet_scan_matches_loop(world, extras):
    """The diet's casts live in shared helpers, so both drivers round
    identically — the parity contract holds at reduced precision."""
    kw = _run_kwargs(world)
    fl = FL
    if "compressed" in extras:
        fl = dataclasses.replace(fl, compression=QUANT8)
    if "stream" in extras:
        fl = dataclasses.replace(
            fl, stream=streaming.StreamConfig(process="poisson"))
    if "dispatch" in extras:
        fl = dataclasses.replace(fl, dispatch_cap=3)
    fl = dataclasses.replace(fl, carry_dtype="bfloat16")
    p_scan, h_scan = federated.run_federated(fcfg=fl, **kw)
    p_loop, h_loop = federated.run_federated_loop(fcfg=fl, **kw)
    assert _same_tree(p_scan, p_loop)
    _assert_history_equal(h_scan, h_loop)
    leaves = [np.asarray(x) for x in jax.tree_util.tree_leaves(p_scan)]
    assert all(np.isfinite(l).all() for l in leaves)


def test_carry_diet_bf16_stays_close_to_f32(world):
    """The diet is a storage rounding, not a different algorithm: a
    compressed run's final params track the f32-carry run closely."""
    kw = _run_kwargs(world)
    fl = dataclasses.replace(FL, compression=QUANT8)
    p32, _ = federated.run_federated(fcfg=fl, **kw)
    pbf, _ = federated.run_federated(
        fcfg=dataclasses.replace(fl, carry_dtype="bfloat16"), **kw)
    for a, b in zip(jax.tree_util.tree_leaves(p32),
                    jax.tree_util.tree_leaves(pbf)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-3, rtol=5e-2)


def test_ef_foldback_bf16_storage_property():
    """EF fold-back at the diet's precision (the re-run of the PR-6
    lossless property): the in-round fold-back is still exactly
    ``r' = r + u`` in f32 arithmetic — what the diet costs is ONE bf16
    quantization of ``r'`` per round at storage time, bounded by half a
    bf16 ulp (2^-8 relative).  Never-scheduled devices' residuals pass
    through the round-trip bitwise (bf16 -> f32 -> bf16 is exact)."""
    ccfg = compression.CompressionConfig(codec="quant", bit_width=4,
                                         error_feedback=True)
    codec = compression.get_codec("quant")
    k, p = 4, 64
    u = jax.random.normal(jax.random.key(0), (k, p))
    r_store = (0.3 * jax.random.normal(jax.random.key(1), (k, p))
               ).astype(jnp.bfloat16)          # the dieted carry
    gains = jnp.ones((k,))
    index = jnp.ones((k,))
    selected = jnp.asarray([1.0, 1.0, 1.0, 0.0])
    success = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    # What _train_round_compressed does under carry_dtype: upcast the
    # stored residual, run the codec in f32, downcast the new residual.
    r32 = r_store.astype(jnp.float32)
    _, res = compression.apply_codec(codec, u, r32, selected,
                                     jax.random.key(2), ccfg, gains,
                                     index, success=success)
    # (a) The f32 fold-back is exact w.r.t. the upcast residual.
    np.testing.assert_array_equal(np.asarray(res[1]),
                                  np.asarray(r32[1] + u[1]))
    # (b) Storage rounding loses at most half a bf16 ulp of r': bf16
    # keeps 7 stored mantissa bits, so half-ulp is 2^-8 relative.
    stored = res.astype(jnp.bfloat16).astype(jnp.float32)
    err = np.abs(np.asarray(stored[1]) - np.asarray(res[1]))
    bound = 2.0 ** -8 * np.maximum(np.abs(np.asarray(res[1])), 1e-30)
    assert np.all(err <= bound)
    # (c) An untouched device's residual survives the round-trip
    # bitwise: bf16 values are exactly representable in f32.
    np.testing.assert_array_equal(
        np.asarray(res[3].astype(jnp.bfloat16)), np.asarray(r_store[3]))


def test_dispatch_sweepable_via_fl_axis():
    """`dispatch_cap` rides the existing `fl` sweep-axis target — grids
    over the capacity need zero sweep-layer changes."""
    from repro.sweep import grid as grid_lib
    spec = grid_lib.SweepSpec(
        fl=FL, sched=SCFG, wireless=WCFG, scenarios_per_point=2,
        base_seed=0,
        axes=(grid_lib.Axis("fl", "dispatch_cap", (None, 4, 8)),))
    points = spec.expand()
    assert [pt.fl.dispatch_cap for pt in points] == [None, 4, 8]
