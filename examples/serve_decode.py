"""Batched serving demo: prefill + token-by-token decode with KV cache.

    PYTHONPATH=src python examples/serve_decode.py \
        [--arch codeqwen1.5-7b] [--batch 4] [--prompt-len 64] [--gen 32]

Uses the reduced config variant (the full configs only lower via the
dry-run on this CPU container).  Exercises the same ``prefill`` /
``decode_step`` entry points the ``serve_step`` dry-run lowers, including
SWA ring caches and recurrent (SSM/xLSTM) state.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = configs.get(args.arch).reduced()
    key = jax.random.key(0)
    params = transformer.init(key, cfg)
    b, s = args.batch, args.prompt_len
    max_len = s + args.gen + 1

    if cfg.input_mode == "embeddings":
        prompt = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    else:
        prompt = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    enc = (jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
           if cfg.is_encdec else None)

    prefill = jax.jit(lambda p, x: transformer.prefill(
        p, x, cfg, None, encoder_inputs=enc, pad_to=max_len))
    decode = jax.jit(lambda p, t, c, i: transformer.decode_step(
        p, t, c, i, cfg, None))

    t0 = time.time()
    logits, cache = prefill(params, prompt)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"[serve] {cfg.name}: prefill {b}x{s} in {t_prefill:.2f}s")

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    toks = [tok]
    t0 = time.time()
    for i in range(args.gen):
        key, ks = jax.random.split(key)
        logits, cache = decode(params, tok, cache, jnp.asarray(s + i))
        probs = jax.nn.softmax(logits[:, 0] / args.temperature, axis=-1)
        tok = jax.random.categorical(
            ks, jnp.log(jnp.maximum(probs, 1e-9)))[:, None]
        tok = tok.astype(jnp.int32)
        toks.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    out = jnp.concatenate(toks, axis=1)
    print(f"[serve] generated {args.gen} tokens x {b} seqs in {dt:.2f}s "
          f"({args.gen * b / dt:.1f} tok/s)")
    print("[serve] first sequence:", out[0].tolist())


if __name__ == "__main__":
    main()
