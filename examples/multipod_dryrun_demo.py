"""Multi-pod dry-run walkthrough for a single (arch, shape).

    PYTHONPATH=src python examples/multipod_dryrun_demo.py \
        [--arch qwen3-14b] [--shape decode_32k]

Shows the artifacts the production launch depends on: the 2x16x16 mesh,
the input ShapeDtypeStructs with their shardings, per-device memory
analysis, cost analysis, and the collective schedule parsed from the
post-SPMD HLO.  (Sets 512 host devices — run standalone, not inside a
session that already initialized jax.)
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--shape", default="decode_32k")
    args = ap.parse_args()

    # dryrun must be imported first: it sets XLA_FLAGS before jax init.
    from repro.launch import dryrun, mesh as mesh_lib
    from repro import configs
    from repro.configs import shapes as shapes_lib

    cfg = configs.get(args.arch)
    shape = shapes_lib.get_shape(args.shape)
    ok, why = shapes_lib.applicable(cfg, shape)
    if not ok:
        raise SystemExit(f"{cfg.name} x {shape.name} skipped: {why}")

    for multi_pod in (False, True):
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
        rec = dryrun.lower_one(cfg, shape, mesh, mem_only=multi_pod)
        print(f"== {rec['mesh']} ({rec['num_devices']} chips) ==")
        print("  per-device memory:", rec["memory"])
        print("  collective bytes by kind:",
              {k: f"{v / 1e6:.1f} MB"
               for k, v in rec["collectives"]["bytes"].items() if v})


if __name__ == "__main__":
    main()
