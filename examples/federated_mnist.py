"""End-to-end driver: the paper's evaluation, runnable at paper scale.

    PYTHONPATH=src python examples/federated_mnist.py \
        [--model cnn|mlp] [--method das|abs|random|full] [--rounds 15]
        [--devices 100] [--n-fixed 7] [--epochs 1] [--full-data]
        [--scenarios 1] [--stream poisson|drift|shift|evict]

Reproduces the §VI setup: K devices with shard-partitioned synthetic
MNIST-like data, DAS/ABS/random/full scheduling, FedAvg training, and
per-round accuracy/energy/time reporting (the numbers behind Figs 2-11).

The whole multi-round simulation runs as one compiled scan
(``federated.run_federated``); with ``--scenarios S > 1`` it reproduces
the paper's Monte-Carlo averaging through the sharded sweep engine
(``repro.sweep``, DESIGN.md §8): scenarios execute in shard_map'd
chunks over the present devices (``--chunk-scenarios`` bounds the
scenarios per dispatch) with online Welford aggregation, so host memory
stays O(rounds) however many scenarios run.  ``--sweep-ckpt PATH``
checkpoints the aggregate + grid cursor after every chunk — a killed
run re-invoked with the same arguments resumes bit-for-bit.

``--stream <process>`` turns the scenario non-stationary: per-device
data arrives/drifts/evicts round by round inside the scan carry and the
scheduler re-ranks on the refreshed statistics (streaming subsystem,
DESIGN.md §7).  Combine with ``--scenarios`` to run S independent
streaming realizations through the batch driver.

``--codec <name>`` compresses the uplink (compressed-uplink subsystem,
DESIGN.md §9): devices upload quantized/sparsified updates with error
feedback, the scheduler and Sub2 price the per-device post-compression
payload bits, and the reported energy/time reflect the smaller uploads.
``--sweep-jsonl PATH`` streams per-chunk aggregates as JSON lines for
live dashboards while a ``--scenarios`` sweep runs.

``--dispatch-cap N`` trains only a dense N-lane block of the admitted
devices instead of masking all K lanes (dense-block dispatch,
DESIGN.md §11) — the steady-state win at the paper's small-admitted-set
regime; admitted devices beyond the cap are dropped by schedule rank
and reported per round.  ``--carry-dtype bfloat16`` stores the large
scan-carry tensors (EF residual, stream stats) at reduced precision.
"""

import argparse
import functools

import jax

from repro import sweep
from repro.core import compression, federated, scheduler, streaming, \
    wireless
from repro.data import partition, synthetic
from repro.models import paper_nets


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="mlp", choices=["mlp", "cnn"])
    ap.add_argument("--method", default="das",
                    choices=["das", "abs", "random", "full"])
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--devices", type=int, default=40)
    ap.add_argument("--n-fixed", type=int, default=0)
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--model-bits", type=float, default=100e3)
    ap.add_argument("--full-data", action="store_true",
                    help="paper scale: 1200 shards x 50 (else 300x50)")
    ap.add_argument("--scenarios", type=int, default=1,
                    help="Monte-Carlo scenarios through the sharded "
                         "sweep engine")
    ap.add_argument("--chunk-scenarios", type=int, default=0,
                    help="scenarios per compiled chunk (0: all in one)")
    ap.add_argument("--sweep-ckpt", default="",
                    help="checkpoint path for resumable sweeps")
    ap.add_argument("--sweep-jsonl", default="",
                    help="stream per-chunk aggregates to this JSONL "
                         "file (live-dashboard feed; resume-safe)")
    ap.add_argument("--codec", default="",
                    choices=["", "none", "quant", "topk", "adaptive"],
                    help="uplink compression codec (default: "
                         "uncompressed full-precision uploads)")
    ap.add_argument("--bit-width", type=int, default=8,
                    help="quantization bit width for --codec quant")
    ap.add_argument("--stream", default="",
                    choices=["", "static", "poisson", "drift", "shift",
                             "evict"],
                    help="streaming-data arrival process (default: "
                         "static data, the paper's frozen partition)")
    ap.add_argument("--stream-rate", type=float, default=25.0,
                    help="mean arrivals per device per round")
    ap.add_argument("--staleness-weight", type=float, default=0.25,
                    help="gamma_s staleness boost for streaming runs")
    ap.add_argument("--dispatch-cap", type=int, default=0,
                    help="dense-block training lanes (0: masked all-K "
                         "path; see DESIGN.md §11)")
    ap.add_argument("--carry-dtype", default="",
                    choices=["", "float32", "bfloat16", "float16"],
                    help="storage dtype for the big scan-carry tensors")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    shards = 1200 if args.full_data else 300
    spc = 6000 if args.full_data else 2000
    imgs, labels = synthetic.generate(args.seed, samples_per_class=spc)
    data = partition.partition(
        imgs, labels, seed=args.seed + 1,
        spec=partition.PartitionSpec(num_devices=args.devices,
                                     num_shards=shards, shard_size=50))
    wcfg = wireless.WirelessConfig(model_bits=args.model_bits)

    mspec = paper_nets.PaperNetSpec(kind=args.model)
    params = paper_nets.init(jax.random.key(args.seed + 3), mspec)
    print(f"[feel] {args.model} ({paper_nets.num_params(params):,} "
          f"params), K={args.devices}, method={args.method}, "
          f"E={args.epochs}, s={args.model_bits / 1e3:.0f} kbit, "
          f"S={args.scenarios}"
          + (f", stream={args.stream}@{args.stream_rate:g}/round"
             if args.stream else "")
          + (f", codec={args.codec}" if args.codec else ""))

    scfg = scheduler.SchedulerConfig(
        method=args.method, n_min=1,
        n_fixed=args.n_fixed or None, iterations_max=6,
        staleness_weight=args.staleness_weight if args.stream else 0.0)
    stream_cfg = streaming.StreamConfig(
        process=args.stream, rate=args.stream_rate) if args.stream \
        else None
    comp_cfg = compression.CompressionConfig(
        codec=args.codec, bit_width=args.bit_width) if args.codec \
        else None
    fcfg = federated.FLConfig(
        num_rounds=args.rounds, local_epochs=args.epochs, batch_size=50,
        learning_rate=0.1 if args.model == "mlp" else 0.05,
        stream=stream_cfg, compression=comp_cfg,
        dispatch_cap=args.dispatch_cap or None,
        carry_dtype=args.carry_dtype or None)
    loss_fn = functools.partial(paper_nets.loss_fn, spec=mspec)
    eval_fn = functools.partial(paper_nets.accuracy, spec=mspec)

    if args.scenarios > 1:
        spec = sweep.SweepSpec(
            fl=fcfg, sched=scfg, wireless=wcfg,
            scenarios_per_point=args.scenarios,
            chunk_scenarios=args.chunk_scenarios,
            base_seed=args.seed)
        results = sweep.run_sweep(
            spec, data=data, loss_fn=loss_fn, eval_fn=eval_fn,
            init_params=params,
            ckpt_path=args.sweep_ckpt or None,
            jsonl_path=args.sweep_jsonl or None)
        _, summary = results[0]
        acc = summary["round.accuracy"]
        sel = summary["round.n_selected"]
        t = summary["round.round_time"]
        for r in range(args.rounds):
            print(f"round {r:3d}: acc={acc['mean'][r]:.4f} "
                  f"[{acc['min'][r]:.4f},{acc['max'][r]:.4f}] "
                  f"sel={sel['mean'][r]:5.1f} "
                  f"T={t['mean'][r]:7.3f}s")
        final = summary["scalar.final_accuracy"]
        print(f"[feel] S={args.scenarios} final acc "
              f"mean={float(final['mean']):.4f} "
              f"min={float(final['min']):.4f} "
              f"max={float(final['max']):.4f} "
              f"(std={float(final['std']):.4f})")
        return

    net = wireless.sample_network(jax.random.key(args.seed + 2),
                                  args.devices, wcfg)
    _, hist = federated.run_federated(
        init_params=params, loss_fn=loss_fn, eval_fn=eval_fn,
        data=data, net=net, wcfg=wcfg, scfg=scfg, fcfg=fcfg,
        key=jax.random.key(args.seed + 4))

    e_tot = t_tot = 0.0
    for r in hist:
        e_tot += r.energy_total
        t_tot += r.round_time
        drop = f" drop={r.n_dropped:2d}" if args.dispatch_cap else ""
        print(f"round {r.round:3d}: acc={r.accuracy:.4f} "
              f"sel={r.n_selected:3d} T={r.round_time:7.3f}s "
              f"E/dev={r.energy_per_device:7.3f}J{drop}")
    print(f"[feel] total: time={t_tot:.1f}s energy={e_tot:.1f}J "
          f"final acc={hist[-1].accuracy:.4f}")


if __name__ == "__main__":
    main()
