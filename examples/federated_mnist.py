"""End-to-end driver: the paper's evaluation, runnable at paper scale.

    PYTHONPATH=src python examples/federated_mnist.py \
        [--model cnn|mlp] [--method das|abs|random|full] [--rounds 15]
        [--devices 100] [--n-fixed 7] [--epochs 1] [--full-data]
        [--scenarios 1] [--stream poisson|drift|shift|evict]

Reproduces the §VI setup: K devices with shard-partitioned synthetic
MNIST-like data, DAS/ABS/random/full scheduling, FedAvg training, and
per-round accuracy/energy/time reporting (the numbers behind Figs 2-11).

The whole multi-round simulation runs as one compiled scan
(``federated.run_federated``); with ``--scenarios S > 1`` it reproduces
the paper's Monte-Carlo averaging — S independent network/PRNG
realizations as ONE vmapped program (``federated.run_federated_batch``)
— and reports the mean and spread of the per-scenario results.

``--stream <process>`` turns the scenario non-stationary: per-device
data arrives/drifts/evicts round by round inside the scan carry and the
scheduler re-ranks on the refreshed statistics (streaming subsystem,
DESIGN.md §7).  Combine with ``--scenarios`` to run S independent
streaming realizations through the batch driver.
"""

import argparse
import functools

import jax

from repro.core import federated, scheduler, streaming, wireless
from repro.data import partition, synthetic
from repro.models import paper_nets


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="mlp", choices=["mlp", "cnn"])
    ap.add_argument("--method", default="das",
                    choices=["das", "abs", "random", "full"])
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--devices", type=int, default=40)
    ap.add_argument("--n-fixed", type=int, default=0)
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--model-bits", type=float, default=100e3)
    ap.add_argument("--full-data", action="store_true",
                    help="paper scale: 1200 shards x 50 (else 300x50)")
    ap.add_argument("--scenarios", type=int, default=1,
                    help="Monte-Carlo scenarios run as one vmapped scan")
    ap.add_argument("--stream", default="",
                    choices=["", "static", "poisson", "drift", "shift",
                             "evict"],
                    help="streaming-data arrival process (default: "
                         "static data, the paper's frozen partition)")
    ap.add_argument("--stream-rate", type=float, default=25.0,
                    help="mean arrivals per device per round")
    ap.add_argument("--staleness-weight", type=float, default=0.25,
                    help="gamma_s staleness boost for streaming runs")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    shards = 1200 if args.full_data else 300
    spc = 6000 if args.full_data else 2000
    imgs, labels = synthetic.generate(args.seed, samples_per_class=spc)
    data = partition.partition(
        imgs, labels, seed=args.seed + 1,
        spec=partition.PartitionSpec(num_devices=args.devices,
                                     num_shards=shards, shard_size=50))
    wcfg = wireless.WirelessConfig(model_bits=args.model_bits)

    mspec = paper_nets.PaperNetSpec(kind=args.model)
    params = paper_nets.init(jax.random.key(args.seed + 3), mspec)
    print(f"[feel] {args.model} ({paper_nets.num_params(params):,} "
          f"params), K={args.devices}, method={args.method}, "
          f"E={args.epochs}, s={args.model_bits / 1e3:.0f} kbit, "
          f"S={args.scenarios}"
          + (f", stream={args.stream}@{args.stream_rate:g}/round"
             if args.stream else ""))

    scfg = scheduler.SchedulerConfig(
        method=args.method, n_min=1,
        n_fixed=args.n_fixed or None, iterations_max=6,
        staleness_weight=args.staleness_weight if args.stream else 0.0)
    stream_cfg = streaming.StreamConfig(
        process=args.stream, rate=args.stream_rate) if args.stream \
        else None
    fcfg = federated.FLConfig(
        num_rounds=args.rounds, local_epochs=args.epochs, batch_size=50,
        learning_rate=0.1 if args.model == "mlp" else 0.05,
        stream=stream_cfg)
    loss_fn = functools.partial(paper_nets.loss_fn, spec=mspec)
    eval_fn = functools.partial(paper_nets.accuracy, spec=mspec)

    if args.scenarios > 1:
        nets = wireless.sample_networks(jax.random.key(args.seed + 2),
                                        args.scenarios, args.devices, wcfg)
        keys = jax.random.split(jax.random.key(args.seed + 4),
                                args.scenarios)
        _, metrics = federated.run_federated_batch(
            init_params=params, loss_fn=loss_fn, eval_fn=eval_fn,
            data=data, nets=nets, wcfg=wcfg, scfg=scfg, fcfg=fcfg,
            keys=keys)
        hists = federated.batch_metrics_to_records(metrics)
        for r in range(args.rounds):
            accs = [h[r].accuracy for h in hists]
            sels = [h[r].n_selected for h in hists]
            times = [h[r].round_time for h in hists]
            print(f"round {r:3d}: acc={sum(accs) / len(accs):.4f} "
                  f"[{min(accs):.4f},{max(accs):.4f}] "
                  f"sel={sum(sels) / len(sels):5.1f} "
                  f"T={sum(times) / len(times):7.3f}s")
        finals = [h[-1].accuracy for h in hists]
        print(f"[feel] S={args.scenarios} final acc "
              f"mean={sum(finals) / len(finals):.4f} "
              f"min={min(finals):.4f} max={max(finals):.4f}")
        return

    net = wireless.sample_network(jax.random.key(args.seed + 2),
                                  args.devices, wcfg)
    _, hist = federated.run_federated(
        init_params=params, loss_fn=loss_fn, eval_fn=eval_fn,
        data=data, net=net, wcfg=wcfg, scfg=scfg, fcfg=fcfg,
        key=jax.random.key(args.seed + 4))

    e_tot = t_tot = 0.0
    for r in hist:
        e_tot += r.energy_total
        t_tot += r.round_time
        print(f"round {r.round:3d}: acc={r.accuracy:.4f} "
              f"sel={r.n_selected:3d} T={r.round_time:7.3f}s "
              f"E/dev={r.energy_per_device:7.3f}J")
    print(f"[feel] total: time={t_tot:.1f}s energy={e_tot:.1f}J "
          f"final acc={hist[-1].accuracy:.4f}")


if __name__ == "__main__":
    main()
