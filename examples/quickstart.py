"""Quickstart: the DAS scheduler + one federated round in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import functools

import jax
import jax.numpy as jnp

from repro.core import diversity, federated, scheduler, wireless
from repro.data import partition, synthetic
from repro.models import paper_nets

# 1. A wireless edge cell with 16 devices holding non-IID shard data.
wcfg = wireless.WirelessConfig()
net = wireless.sample_network(jax.random.key(0), 16, wcfg)
imgs, labels = synthetic.generate(0, samples_per_class=600)
data = partition.partition(
    imgs, labels, seed=1,
    spec=partition.PartitionSpec(num_devices=16, num_shards=100,
                                 shard_size=50))

# 2. On-device statistics -> the paper's diversity index (Eq. 4).
hists = jax.vmap(lambda l, m: diversity.label_histogram(l, m, 10))(
    data.labels, data.mask)
index = diversity.diversity_index(label_hists=hists,
                                  data_sizes=data.sizes,
                                  ages=jnp.zeros((16,), jnp.int32))
print("diversity index:", jnp.round(index, 3))

# 3. One DAS decision: joint selection + bandwidth allocation (Alg. 2).
gains = wireless.sample_fading(jax.random.key(2), net)
sch = scheduler.SchedulerConfig(method="das", n_min=2)
res = scheduler.schedule(jax.random.key(3), index,
                         jnp.zeros((16,), jnp.int32), data.sizes, gains,
                         net, wcfg, sch)
print(f"selected {int(res.selected.sum())}/16 devices, "
      f"round time {float(res.round_time):.3f}s, "
      f"total energy {float(jnp.sum(res.energy)):.3f}J")

# 4. Three federated rounds (Alg. 1) on the paper's MLP.
mspec = paper_nets.PaperNetSpec(kind="mlp")
params = paper_nets.init(jax.random.key(4), mspec)
_, hist = federated.run_federated(
    init_params=params,
    loss_fn=functools.partial(paper_nets.loss_fn, spec=mspec),
    eval_fn=functools.partial(paper_nets.accuracy, spec=mspec),
    data=data, net=net, wcfg=wcfg, scfg=sch,
    fcfg=federated.FLConfig(num_rounds=3, learning_rate=0.1),
    key=jax.random.key(5))
for r in hist:
    print(f"round {r.round}: acc={r.accuracy:.3f} "
          f"selected={r.n_selected} energy/dev={r.energy_per_device:.3f}J")
