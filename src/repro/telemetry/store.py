"""Cross-run metrics store (DESIGN.md §14).

``BENCH_fl_e2e.json`` is overwritten in place, so the repo had no
machine-checkable record of accuracy/energy/throughput trajectories
across PRs.  This module is that record: an **append-only JSONL run
history** that ``benchmarks/run.py``, ``benchmarks/fl_e2e.py`` and
``sweep/runner.py`` append one *run summary* per run to, keyed by the
run manifest's config fingerprint + git sha
(``repro.telemetry.sinks``).  ``repro.telemetry.compare`` diffs two
summaries (or a summary against the stored history) with per-metric
tolerance bands — the CI regression gate.

Record schema (one JSON object per line)::

    {"schema_version": 1, "kind": "run", "run": "<label>",
     "git_sha": ..., "config_fingerprint": ...,
     "metrics": {"final_acc": ..., "rounds_to_target": ...,
                 "total_energy_j": ..., "energy_per_device_j": ...,
                 "jain_participation": ..., "jain_energy": ...,
                 "steady_s_per_round": ..., "compile_s": ...}}

``schema_version`` is explicit so the gate can fail loud (exit 2) on
drift instead of silently comparing renamed metrics.  Non-finite floats
serialize as ``null`` (``sinks.jsonl_append`` sanitizes), so a NaN
divergence sentinel round-trips through JSONL as missing-not-invalid.
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

import numpy as np

from repro.telemetry import sinks

SCHEMA_VERSION = 1

# Canonical metric names.  ``compare`` only gates on names it has a
# tolerance band for; unknown extras ride along un-gated.
METRIC_NAMES = (
    "final_acc", "rounds_to_target", "total_energy_j",
    "energy_per_device_j", "jain_participation", "jain_energy",
    "steady_s_per_round", "compile_s",
)


def _finite(x) -> Optional[float]:
    if x is None:
        return None
    x = float(x)
    return x if math.isfinite(x) else None


def run_summary(*, accuracy, selected, energy,
                target_accuracy: float = 0.85,
                timings: Optional[Dict[str, float]] = None
                ) -> Dict[str, Any]:
    """Host-side run summary from a run's stacked metrics.

    ``accuracy`` is the per-round ``(R,)`` accuracy trace (NaN on
    eval-skipped rounds), ``selected`` the ``(R, K)`` admission matrix,
    ``energy`` the ``(R, K)`` realized per-device energy.  Fairness
    indices are Jain over the cumulative per-device participation and
    energy — the same definition the in-scan frames record
    (``repro.telemetry.health``), recomputed here in NumPy so summaries
    exist even for telemetry-off runs.  ``timings`` merges benchmark-
    measured wall-clock fields (``steady_s_per_round``, ``compile_s``).
    """
    acc = np.asarray(accuracy, np.float64).reshape(-1)
    sel = np.asarray(selected, np.float64)
    eng = np.asarray(energy, np.float64)
    evald = np.isfinite(acc)
    final_acc = float(acc[evald][-1]) if evald.any() else None
    reach = np.where(evald & (acc >= target_accuracy))[0]
    rounds_to_target = int(reach[0]) + 1 if reach.size else None
    part = sel.sum(axis=0)          # (K,) cumulative participation
    eng_dev = eng.sum(axis=0)       # (K,) cumulative energy

    def jain(x):
        ss = float((x * x).sum())
        if ss <= 0.0:
            return 1.0
        s = float(x.sum())
        return (s * s) / (x.size * ss)

    metrics: Dict[str, Any] = {
        "final_acc": _finite(final_acc),
        "rounds_to_target": rounds_to_target,
        "total_energy_j": _finite(eng.sum()),
        "energy_per_device_j": _finite(eng.sum() / max(sel.shape[-1], 1)),
        "jain_participation": _finite(jain(part)),
        "jain_energy": _finite(jain(eng_dev)),
    }
    for name, val in (timings or {}).items():
        metrics[name] = _finite(val)
    return metrics


def run_record(metrics: Dict[str, Any], *, run: str,
               configs=(), extra: Optional[dict] = None) -> dict:
    """Wrap a metrics dict in the store's keyed record envelope."""
    rec = {
        "schema_version": SCHEMA_VERSION,
        "kind": "run",
        "run": run,
        "git_sha": sinks._git_sha(),
        "config_fingerprint": sinks.config_fingerprint(*configs)
        if configs else None,
        "metrics": dict(metrics),
    }
    if extra:
        rec.update(extra)
    return rec


def append_run(path: str, metrics: Dict[str, Any], *, run: str,
               configs=(), extra: Optional[dict] = None,
               fsync: bool = True) -> dict:
    """Append one run summary to the store; returns the written record."""
    rec = run_record(metrics, run=run, configs=configs, extra=extra)
    sinks.jsonl_append(path, rec, fsync=fsync)
    return rec


def load_history(path: str, run: Optional[str] = None) -> List[dict]:
    """All run records in the store (optionally filtered by run label).

    Torn tails tolerated (``sinks.read_jsonl``); non-``run`` records
    are skipped so the store can co-host other record kinds later.
    """
    out = []
    for rec in sinks.read_jsonl(path):
        if rec.get("kind") != "run":
            continue
        if run is not None and rec.get("run") != run:
            continue
        out.append(rec)
    return out


def latest(path: str, run: Optional[str] = None) -> Optional[dict]:
    """The most recently appended run record, or None."""
    hist = load_history(path, run=run)
    return hist[-1] if hist else None


__all__ = ["SCHEMA_VERSION", "METRIC_NAMES", "run_summary", "run_record",
           "append_run", "load_history", "latest"]
