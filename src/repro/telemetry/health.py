"""In-scan learning-signal & fairness health metrics (DESIGN.md §14).

PR 9 frames record what the *scheduler* did; this module records what
the *learning* did.  A :class:`SignalState` rides at the tail of the
scan carry of both FEEL drivers and the legacy loop (gated by
``TelemetryConfig.signals``) and accumulates, per device:

* ``loss_delta``   — last observed local loss improvement (loss at the
  global params minus loss at the device's trained params, evaluated on
  a fixed deterministic probe window of its shard — no PRNG draws).
* ``update_norm``  — last observed L2 norm of the device's model delta,
  computed uniformly from the flattened ``(K, P)`` update matrix so the
  plain / compressed / event paths share one reduction order.
* ``participation`` — cumulative count of delivered uploads.
* ``energy``       — cumulative realized upload energy (J).

Per-round derived aggregates (Jain fairness over participation and over
energy, starved-device count, divergence sentinels) are emitted into
the telemetry frame by :func:`signals_aggregates`.  Everything here is
a pure observer: no extra PRNG splits, nothing feeds back into the
round, so the ``telemetry=None`` bitwise contract of DESIGN.md §13
extends to the signals group unchanged (``tests/test_health.py``).

The per-device signal carry is deliberately the substrate the ROADMAP's
learning-signal-aware scheduler (arXiv 2201.11247; gradient-importance
axis of arXiv 2004.00490) will rank on: a future scheduler family reads
``SignalState`` instead of static diversity indices.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Array = Any

# Local loss delta above this magnitude (or non-finite) marks a device
# as diverging in the frame's sentinel counts.  Softmax CE on the
# paper's 10-class problems lives in [0, ~2.3] at init; |delta| > 50 is
# unambiguously a blow-up, not a large honest step.
EXPLODING_LOSS = 50.0

# Upper bound on the loss-probe window (samples per device).  The probe
# costs two forward passes per device per round; capping it keeps the
# signals group a small fraction of the round body (the <1.10 overhead
# budget) while a 16-sample window still tracks the sign and scale of
# the local loss move.
PROBE_CAP = 16


def jain_index(x: Array) -> Array:
    """Jain's fairness index ``(Σx)² / (K·Σx²)`` over a ``(K,)`` vector.

    1.0 when all devices hold equal share, ``1/K`` when one device holds
    everything.  The all-zero vector (no uploads yet) is *defined* as
    perfectly fair (1.0) rather than 0/0.
    """
    x = x.astype(jnp.float32)
    s = jnp.sum(x)
    ss = jnp.sum(x * x)
    k = jnp.asarray(x.shape[-1], jnp.float32)
    return jnp.where(ss > 0.0, (s * s) / (k * ss), 1.0)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SignalState:
    """Per-device learning-signal accumulators (scan-carry resident).

    ``loss_delta``/``update_norm`` hold the *last observed* value for
    each device (unchanged while it sits out); ``participation`` and
    ``energy`` are cumulative since round 0.
    """

    loss_delta: Array     # (K,) f32 — last local loss improvement
    update_norm: Array    # (K,) f32 — last update L2 norm
    participation: Array  # (K,) i32 — cumulative delivered uploads
    energy: Array         # (K,) f32 — cumulative realized upload J

    def tree_flatten(self):
        return ((self.loss_delta, self.update_norm, self.participation,
                 self.energy), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def signal_init(k: int) -> SignalState:
    """Zero state for ``k`` devices."""
    return SignalState(
        loss_delta=jnp.zeros((k,), jnp.float32),
        update_norm=jnp.zeros((k,), jnp.float32),
        participation=jnp.zeros((k,), jnp.int32),
        energy=jnp.zeros((k,), jnp.float32),
    )


def signal_update(state: SignalState, ok: Array, loss_delta: Array,
                  update_norm: Array, energy: Array) -> SignalState:
    """Fold one round's observations into the carry.

    ``ok`` is the delivered mask (the driver's post-fault upload mask);
    last-observed fields only move for delivered devices, cumulative
    fields add the round's realized contribution.  ``energy`` is the
    driver's realized per-device vector, already zero off the delivered
    set, so it adds directly.
    """
    hit = ok > 0.0
    return SignalState(
        loss_delta=jnp.where(hit, loss_delta, state.loss_delta),
        update_norm=jnp.where(hit, update_norm, state.update_norm),
        participation=state.participation + hit.astype(jnp.int32),
        energy=state.energy + energy,
    )


def update_norms(updates: Array) -> Array:
    """Per-device L2 norm from a flattened ``(K, P)`` update matrix.

    Every driver path funnels through this one reduction so the norms
    agree bitwise between the plain, compressed and event-driven
    bodies (same axis order, same dtype).
    """
    u = updates.astype(jnp.float32)
    return jnp.sqrt(jnp.sum(u * u, axis=-1))


def flatten_updates(client_params, params) -> Array:
    """``(K, P)`` update matrix from stacked client params vs globals.

    Mirrors the compressed path's ravel order (tree leaves in pytree
    order, each ``.reshape(K, -1)``) so plain-path norms match what the
    codec path computes from its own ``updates`` matrix.
    """
    leaves_c = jax.tree_util.tree_leaves(client_params)
    leaves_g = jax.tree_util.tree_leaves(params)
    k = leaves_c[0].shape[0]
    return jnp.concatenate(
        [(c - g[None]).reshape(k, -1) for c, g in zip(leaves_c, leaves_g)],
        axis=1)


def make_signal_probe(loss_fn: Callable, probe_size: int) -> Callable:
    """Build the per-device loss-delta probe.

    Returns ``probe(params, client_params, images, labels, mask) ->
    (K,) f32``: per-device loss at the global params minus loss at the
    device's trained params, both evaluated on the **first**
    ``probe_size`` samples of the device's shard — a fixed window, so
    the probe draws no randomness and stays a pure observer.  Devices
    whose ``client_params`` equal the globals (unselected / frozen
    lanes) get exactly 0 because both terms are the identical
    computation.
    """

    from repro.data import synthetic

    def _one(params_g, params_c, images, labels, mask):
        win = slice(0, probe_size)
        imgs = synthetic.to_float(images[win])
        lbl = labels[win]
        msk = mask[win]
        before = loss_fn(params_g, imgs, lbl, msk)
        after = loss_fn(params_c, imgs, lbl, msk)
        return (before - after).astype(jnp.float32)

    def probe(params, client_params, images, labels, mask):
        return jax.vmap(_one, in_axes=(None, 0, 0, 0, 0))(
            params, client_params, images, labels, mask)

    return probe


def signals_frame(state: SignalState, ok: Array, loss_delta: Array,
                  update_norm: Array) -> Dict[str, Array]:
    """Frame leaves for one round's signals group.

    ``sig_loss_delta``/``sig_update_norm`` are *this round's*
    observations masked to the delivered set; the ``*_last`` /
    cumulative leaves snapshot the post-update carry (the exact state a
    learning-signal scheduler would rank on next round); the scalars
    are the derived health aggregates.
    """
    hit = ok > 0.0
    frame = {
        "sig_loss_delta": jnp.where(hit, loss_delta, 0.0),
        "sig_update_norm": jnp.where(hit, update_norm, 0.0),
        "sig_loss_delta_last": state.loss_delta,
        "sig_update_norm_last": state.update_norm,
        "sig_participation": state.participation,
        "sig_energy_cum": state.energy,
    }
    frame.update(signals_aggregates(state, loss_delta, hit))
    return frame


def signals_aggregates(state: SignalState, loss_delta: Array,
                       hit: Array) -> Dict[str, Array]:
    """Scalar health aggregates derived from the post-update carry."""
    nonfinite = hit & ~jnp.isfinite(loss_delta)
    exploding = hit & jnp.isfinite(loss_delta) \
        & (jnp.abs(loss_delta) > EXPLODING_LOSS)
    return {
        "jain_participation": jain_index(state.participation),
        "jain_energy": jain_index(state.energy),
        "starved": jnp.sum(
            (state.participation == 0).astype(jnp.int32)),
        "div_nonfinite": jnp.sum(nonfinite.astype(jnp.int32)),
        "div_exploding": jnp.sum(exploding.astype(jnp.int32)),
    }


# Frame leaves the signals group adds (report CLI + tests key off this).
SIGNAL_LEAVES: Tuple[str, ...] = (
    "sig_loss_delta", "sig_update_norm", "sig_loss_delta_last",
    "sig_update_norm_last", "sig_participation", "sig_energy_cum",
    "jain_participation", "jain_energy", "starved",
    "div_nonfinite", "div_exploding",
)


__all__ = ["SignalState", "signal_init", "signal_update", "update_norms",
           "flatten_updates", "make_signal_probe", "signals_frame",
           "signals_aggregates", "jain_index", "SIGNAL_LEAVES",
           "EXPLODING_LOSS", "PROBE_CAP"]
