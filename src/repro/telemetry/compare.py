"""Run-summary regression gate (DESIGN.md §14).

``python -m repro.telemetry.compare BASELINE CURRENT`` diffs two run
summaries — each a single-record JSON file or a JSONL store
(``repro.telemetry.store``; the latest matching record is taken) —
against per-metric tolerance bands, prints a verdict table, and exits:

* **0** — every gated metric within tolerance,
* **1** — at least one gated metric regressed beyond tolerance,
* **2** — schema drift (``schema_version`` mismatch, a gated metric
  missing on either side, unreadable/empty input) or usage error.

Tolerance bands are directional: a metric only *regresses* in its bad
direction (accuracy down, energy up, fairness down, rounds-to-target
up); improvements of any size pass.  Timing metrics
(``steady_s_per_round``, ``compile_s``) are reported but **non-gating**
by default — CI machines vary too much for wall clock to gate a merge —
and can be promoted with ``--gate-timings``.

The CI ``regression-gate`` job runs the smoke probes, appends their
summaries to a store, and compares against the committed
``benchmarks/baselines/ci_baseline.json``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Any, Dict, List, Optional

from repro.telemetry import sinks
from repro.telemetry import store as store_lib

EXIT_OK = 0
EXIT_REGRESSION = 1
EXIT_SCHEMA = 2


@dataclasses.dataclass(frozen=True)
class Band:
    """One metric's tolerance band.

    ``direction`` is the *bad* direction: ``"down"`` means a drop
    beyond tolerance regresses (accuracy, fairness), ``"up"`` means a
    rise does (energy, rounds).  ``rel`` tolerances are relative to the
    baseline magnitude; ``abs_tol`` is additive.  ``gating=False``
    metrics are reported only.
    """

    direction: str           # "down" | "up"
    abs_tol: float = 0.0
    rel: float = 0.0
    gating: bool = True


# Default bands: loose enough for seed/PRNG jitter across quick CI
# runs, tight enough to catch a real break (accuracy collapse, energy
# blow-up, fairness cliff).
DEFAULT_BANDS: Dict[str, Band] = {
    "final_acc": Band("down", abs_tol=0.05),
    "rounds_to_target": Band("up", abs_tol=2.0),
    "total_energy_j": Band("up", rel=0.25),
    "energy_per_device_j": Band("up", rel=0.25),
    "jain_participation": Band("down", abs_tol=0.15),
    "jain_energy": Band("down", abs_tol=0.15),
    "steady_s_per_round": Band("up", rel=0.50, gating=False),
    "compile_s": Band("up", rel=0.50, gating=False),
}


class SchemaError(Exception):
    """Input unusable for comparison (drift, missing, unreadable)."""


def load_summary(path: str, run: Optional[str] = None) -> dict:
    """Load one run record from a JSON file or JSONL store.

    A ``.json`` file holds a single record; a JSONL store yields its
    latest ``kind == "run"`` record (optionally filtered by label).
    """
    try:
        with open(path) as f:
            first = f.read(1)
    except OSError as e:
        raise SchemaError(f"cannot read {path}: {e}")
    if not first:
        raise SchemaError(f"{path} is empty")
    try:
        records = sinks.read_jsonl(path)
    except OSError as e:
        raise SchemaError(f"cannot read {path}: {e}")
    runs = [r for r in records
            if r.get("kind") == "run"
            and (run is None or r.get("run") == run)]
    if not runs:
        raise SchemaError(
            f"{path} holds no usable run record"
            + (f" labeled {run!r}" if run else ""))
    rec = runs[-1]
    if rec.get("schema_version") != store_lib.SCHEMA_VERSION:
        raise SchemaError(
            f"{path}: schema_version {rec.get('schema_version')!r} != "
            f"supported {store_lib.SCHEMA_VERSION}")
    if not isinstance(rec.get("metrics"), dict):
        raise SchemaError(f"{path}: record has no metrics dict")
    return rec


def _delta_and_limit(name: str, band: Band, base: float, cur: float):
    """(signed regression amount, allowed amount). Positive = worse."""
    worse = (base - cur) if band.direction == "down" else (cur - base)
    limit = band.abs_tol + band.rel * abs(base)
    return worse, limit


@dataclasses.dataclass
class Verdict:
    metric: str
    baseline: Optional[float]
    current: Optional[float]
    status: str              # "ok" | "regressed" | "improved" |
    #                          "info" | "missing"
    gating: bool
    limit: Optional[float] = None


def compare_records(baseline: dict, current: dict,
                    bands: Optional[Dict[str, Band]] = None,
                    gate_timings: bool = False) -> List[Verdict]:
    """Per-metric verdicts for two run records.

    A gated metric present on one side but not the other is schema
    drift (raises :class:`SchemaError`) — a silently vanished metric
    must fail loud, not pass by omission.  Both-``None`` values (e.g.
    ``rounds_to_target`` when neither run reached target) compare ok.
    """
    bands = dict(bands or DEFAULT_BANDS)
    if gate_timings:
        bands = {k: dataclasses.replace(v, gating=True)
                 for k, v in bands.items()}
    bm = baseline["metrics"]
    cm = current["metrics"]
    verdicts: List[Verdict] = []
    for name, band in bands.items():
        in_b, in_c = name in bm, name in cm
        if not in_b and not in_c:
            continue
        if band.gating and (in_b != in_c):
            missing = "current" if in_b else "baseline"
            raise SchemaError(
                f"gated metric {name!r} missing from {missing} record")
        if not (in_b and in_c):
            verdicts.append(Verdict(name, bm.get(name), cm.get(name),
                                    "missing", band.gating))
            continue
        b, c = bm[name], cm[name]
        if b is None and c is None:
            verdicts.append(Verdict(name, None, None, "ok", band.gating))
            continue
        if b is None or c is None:
            # A metric that became unmeasurable (diverged to NaN →
            # null) regresses; one that became measurable improves.
            status = "regressed" if c is None else "improved"
            if not band.gating and status == "regressed":
                status = "info"
            verdicts.append(Verdict(name, b, c, status, band.gating))
            continue
        worse, limit = _delta_and_limit(name, band, float(b), float(c))
        if worse > limit:
            status = "regressed" if band.gating else "info"
        elif worse < 0.0:
            status = "improved"
        else:
            status = "ok"
        verdicts.append(Verdict(name, float(b), float(c), status,
                                band.gating, limit=limit))
    # Ungated extras both sides share: report only.
    for name in sorted(set(bm) & set(cm) - set(bands)):
        verdicts.append(Verdict(name, bm[name], cm[name], "info", False))
    return verdicts


def render_table(baseline: dict, current: dict,
                 verdicts: List[Verdict]) -> str:
    lines = []
    lines.append("== regression gate ==")
    lines.append(f"baseline: run={baseline.get('run')!r} "
                 f"sha={str(baseline.get('git_sha'))[:10]} "
                 f"fp={str(baseline.get('config_fingerprint'))[:10]}")
    lines.append(f"current : run={current.get('run')!r} "
                 f"sha={str(current.get('git_sha'))[:10]} "
                 f"fp={str(current.get('config_fingerprint'))[:10]}")
    hdr = (f"{'metric':<22} {'baseline':>12} {'current':>12} "
           f"{'limit':>10}  verdict")
    lines.append(hdr)
    lines.append("-" * len(hdr))

    def _fmt(x):
        if x is None:
            return "-"
        if isinstance(x, float):
            return f"{x:.4g}"
        return str(x)

    for v in verdicts:
        tag = v.status + ("" if v.gating else " (ungated)")
        lines.append(f"{v.metric:<22} {_fmt(v.baseline):>12} "
                     f"{_fmt(v.current):>12} {_fmt(v.limit):>10}  {tag}")
    n_reg = sum(1 for v in verdicts
                if v.gating and v.status == "regressed")
    lines.append("-" * len(hdr))
    lines.append("verdict: " + ("REGRESSED "
                                f"({n_reg} metric(s) out of band)"
                                if n_reg else "OK"))
    return "\n".join(lines)


def parse_tol(items: List[str]) -> Dict[str, Band]:
    """``--tol name=value`` overrides onto the default bands (value
    replaces the band's dominant tolerance, abs for abs-band metrics,
    rel for rel-band ones)."""
    bands = dict(DEFAULT_BANDS)
    for item in items:
        if "=" not in item:
            raise ValueError(f"--tol expects name=value, got {item!r}")
        name, val = item.split("=", 1)
        name = name.strip()
        if name not in bands:
            raise ValueError(f"unknown metric for --tol: {name!r}")
        band = bands[name]
        v = float(val)
        if band.rel and not band.abs_tol:
            bands[name] = dataclasses.replace(band, rel=v)
        else:
            bands[name] = dataclasses.replace(band, abs_tol=v)
    return bands


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.compare",
        description="Diff two run summaries with tolerance bands; "
                    "exit 0 ok / 1 regression / 2 schema drift.")
    ap.add_argument("baseline", help="baseline record (.json or store)")
    ap.add_argument("current", help="current record (.json or store)")
    ap.add_argument("--run", default=None,
                    help="run label to select from JSONL stores")
    ap.add_argument("--tol", action="append", default=[],
                    metavar="NAME=VAL", help="override a tolerance band")
    ap.add_argument("--gate-timings", action="store_true",
                    help="promote timing metrics to gating")
    ap.add_argument("--json", action="store_true",
                    help="emit the verdicts as JSON instead of a table")
    try:
        args = ap.parse_args(argv)
    except SystemExit as e:
        return EXIT_SCHEMA if e.code else EXIT_OK
    try:
        bands = parse_tol(args.tol)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return EXIT_SCHEMA
    try:
        baseline = load_summary(args.baseline, run=args.run)
        current = load_summary(args.current, run=args.run)
        verdicts = compare_records(baseline, current, bands,
                                   gate_timings=args.gate_timings)
    except SchemaError as e:
        print(f"schema drift: {e}", file=sys.stderr)
        return EXIT_SCHEMA
    regressed = any(v.gating and v.status == "regressed"
                    for v in verdicts)
    if args.json:
        print(json.dumps({
            "baseline": {k: baseline.get(k) for k in
                         ("run", "git_sha", "config_fingerprint")},
            "current": {k: current.get(k) for k in
                        ("run", "git_sha", "config_fingerprint")},
            "verdicts": [dataclasses.asdict(v) for v in verdicts],
            "regressed": regressed,
        }, indent=2))
    else:
        print(render_table(baseline, current, verdicts))
    return EXIT_REGRESSION if regressed else EXIT_OK


if __name__ == "__main__":
    sys.exit(main())
