"""Traceable per-round telemetry frames (DESIGN.md §13).

A *frame* is a flat ``dict[str, Array]`` built inside the scan body —
dicts are pytrees, so ``lax.scan`` stacks every leaf over the round
axis and the batch driver's vmap adds a scenario axis, with zero
changes to the scan plumbing.  All builders are pure and traceable, and
none of them draws fresh randomness or feeds anything back into the
round: the frame is an *observer*, which is what keeps the primary
outputs bitwise identical to the no-telemetry run
(``tests/test_telemetry.py``).

:func:`round_frame` is the single assembly point both FEEL drivers and
the legacy loop call, so the recorded field set cannot drift between
them; :func:`event_frame` adds the event-driver extras.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax.numpy as jnp

from repro.core import bandwidth as bw
from repro.core import scheduler as sched_lib
from repro.core import wireless

Array = Any
Frame = Dict[str, Array]


def sub2_frame(result: "sched_lib.ScheduleResult", gains: Array,
               net: "wireless.NetworkState",
               wcfg: "wireless.WirelessConfig",
               sch: "sched_lib.SchedulerConfig",
               payload_bits: Optional[Array]) -> Frame:
    """Sub2 solver trace: allocation vector, iterations, objective.

    ``sub2_obj`` is Eq. 15a at the solver's allocation; ``sub2_obj_eq``
    re-evaluates it at the equal-share allocation over the admitted set
    (the solver's warm-start shape), so ``sub2_gain = obj_eq - obj`` is
    the objective improvement the solve bought this round — the
    convergence-quality signal the report CLI summarizes.
    """
    sel = result.selected
    alpha_eq = sel / jnp.maximum(jnp.sum(sel), 1.0)
    rho = sch.sub2.rho
    obj = bw.sub2_objective(result.alpha, sel, result.t_train, gains,
                            net.tx_power, wcfg, rho,
                            payload_bits=payload_bits)
    obj_eq = bw.sub2_objective(alpha_eq, sel, result.t_train, gains,
                               net.tx_power, wcfg, rho,
                               payload_bits=payload_bits)
    return {
        "alpha": result.alpha,
        "sub2_iters": result.iterations,
        "sub2_obj": obj,
        "sub2_obj_eq": obj_eq,
        "sub2_gain": obj_eq - obj,
    }


def transport_frame(sel_eff: Array, result: "sched_lib.ScheduleResult",
                    energy: Array, payload_bits: Optional[Array],
                    wcfg: "wireless.WirelessConfig") -> Frame:
    """Per-device uplink accounting on the realized (post-drop) set.

    ``payload_bits`` is the codec's per-device payload (``None`` on
    uncompressed runs, where every device uploads ``wcfg.model_bits``);
    ``energy`` is the *realized* upload energy the driver accounted
    (post-fault/post-dispatch), and ``t_up`` the scheduler's per-device
    upload time with the unselected-infinity sentinel zeroed.
    """
    bits = jnp.full_like(sel_eff, float(wcfg.model_bits)) \
        if payload_bits is None else payload_bits
    t_up = jnp.where(jnp.isinf(result.t_up), 0.0, result.t_up)
    return {
        "payload_bits": bits * sel_eff,
        "t_up": t_up * sel_eff,
        "energy_up": energy,
    }


def fault_frame(draw, sel_eff: Array) -> Frame:
    """Fault events by type over the realized admitted set.

    Derived from the round's :class:`repro.core.faults.FaultDraw`: an
    *outage* burned its whole retry budget, a *dropout* died before its
    first attempt, a *straggler* drew a compute multiplier above 1.
    """
    sel = sel_eff > 0.0
    return {
        "fault_outage": (sel & (draw.attempts > 0.0)
                         & (draw.success <= 0.0)).astype(jnp.float32),
        "fault_dropout": (sel & (draw.attempts <= 0.0))
        .astype(jnp.float32),
        "fault_straggler": (sel & (draw.compute_mult > 1.0))
        .astype(jnp.float32),
        "fault_attempts": draw.attempts * sel_eff,
    }


def round_frame(tel, *, result, admitted: Array, sel_eff: Array,
                ok: Array, energy: Array, payload_bits: Optional[Array],
                gains: Array, net, wcfg, sch, key_sched, index: Array,
                ages: Array, staleness: Optional[Array],
                reliability: Optional[Array], draw,
                signals: Optional[Frame] = None) -> Frame:
    """Assemble one round's telemetry frame (both drivers + legacy loop).

    ``admitted`` is the scheduler's selection before the dispatch cap,
    ``sel_eff`` the realized (post-drop) set, ``ok`` the uploads that
    landed; ``ages``/``reliability``/``staleness`` are the values the
    *scheduler saw* (pre-update).  ``draw`` is the round's fault draw or
    ``None`` on a reliable edge — the fault group is recorded only when
    the fault subsystem actually ran.  ``signals`` is the pre-built
    learning-signal group (``repro.telemetry.health.signals_frame``) —
    the driver builds it from its signal carry when ``tel.signals``.
    """
    frame: Frame = {
        "admitted": admitted,
        "dispatched": sel_eff,
        "delivered": ok,
    }
    if tel.scores:
        frame.update(sched_lib.score_trace(
            key_sched, index, ages, sch, staleness=staleness,
            reliability=reliability))
        if staleness is not None:
            frame["staleness"] = staleness
    if tel.sub2:
        frame.update(sub2_frame(result, gains, net, wcfg, sch,
                                payload_bits))
    if tel.transport:
        frame.update(transport_frame(sel_eff, result, energy,
                                     payload_bits, wcfg))
    if tel.faults and draw is not None:
        frame.update(fault_frame(draw, sel_eff))
    if signals is not None:
        frame.update(signals)
    return frame


def event_frame(*, avail: Array, free: Array, in_flight: Array,
                buffer_fill: Array, flushed: Array, tau: Array,
                clock: Array, version: Array) -> Frame:
    """Event-driver extras: availability gate, pending/buffer state.

    ``in_flight`` is the end-of-tick pending mask (devices whose update
    has not been applied), ``tau`` the per-slot model-version staleness
    at flush evaluation, ``flushed`` whether the buffer emptied this
    tick, ``clock``/``version`` the post-tick simulated time and global
    model version.
    """
    return {
        "avail": avail,
        "free": free,
        "in_flight": in_flight,
        "buffer_fill": buffer_fill.astype(jnp.float32),
        "flushed": flushed.astype(jnp.float32),
        "staleness_tau": tau,
        "clock": clock,
        "model_version": version.astype(jnp.int32),
    }


__all__ = ["round_frame", "event_frame", "sub2_frame", "transport_frame",
           "fault_frame"]
