"""In-scan telemetry subsystem (DESIGN.md §13).

The paper's claims are argued from *per-round, per-device* quantities —
diversity ranks, admission decisions, Sub2 allocations, the energy
split — but the drivers only surface the nine aggregate
:class:`repro.core.federated.RoundMetrics` leaves; everything else is
computed inside the jit and thrown away.  This package makes those
internals observable without giving up the compiled drivers:

* :class:`TelemetryConfig` rides on ``FLConfig.telemetry``.  When set,
  the scan bodies of both FEEL drivers (synchronous and event-driven)
  and the legacy loop emit a per-round *frame* — a flat dict of stacked
  arrays (``repro.telemetry.record``) holding scheduler score
  decompositions, admission/drop/dispatch outcomes, Sub2 solver traces,
  per-device payload bits and realized upload energy/time, fault events
  by type, and (event mode) availability/staleness state.  Frames ride
  the scan's ``ys`` output, so telemetry costs zero host syncs.
* ``telemetry=None`` (the default) statically dispatches today's
  program **bitwise** — the same ``is_inert``/:func:`active` pattern as
  ``core.faults``: every frame computation sits behind a Python-level
  ``if tel is not None`` so the disabled jaxpr is literally unchanged.
* Host-side durability lives in ``repro.telemetry.sinks`` (fsync-safe
  JSONL round-event writer + the resume-safe rewind shared with the
  sweep runner, and a run manifest), and ``python -m
  repro.telemetry.report`` renders a run summary from a JSONL log.
* :func:`phase_scope` wraps the four driver phases — ``schedule``,
  ``local_train``, ``aggregate``, ``stream_refresh`` — in
  ``jax.named_scope`` so ``jax.profiler.trace`` output (see
  ``benchmarks/run.py --profile``) attributes time to them.

Contracts (``tests/test_telemetry.py``): with telemetry enabled the
*primary* outputs (params, metrics) are bitwise identical to the
``telemetry=None`` run across every subsystem composition (frames only
observe — no extra PRNG splits, no op feeding back into the round), and
``batch == S singles`` holds bitwise on every telemetry leaf.
"""

from __future__ import annotations

import dataclasses
from typing import FrozenSet, Optional

import jax

# The four profiled driver phases, in round order.  ``stream_refresh``
# only appears in streaming runs; the other three are always present.
PHASES = ("schedule", "local_train", "aggregate", "stream_refresh")

_seen_phases: set = set()


def phase_scope(name: str):
    """``jax.named_scope`` for one driver phase, recorded for tests.

    The scope is pure trace-time metadata (it names HLO ops for the
    profiler; no op changes), so the drivers enter it unconditionally —
    the ``telemetry=None`` bitwise contract is unaffected.
    """
    _seen_phases.add(name)
    return jax.named_scope(f"repro/{name}")


def seen_phases() -> FrozenSet[str]:
    """Phase scopes entered since process start (test introspection)."""
    return frozenset(_seen_phases)


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Static telemetry knobs (hashable; rides on ``FLConfig.telemetry``).

    Each flag gates one frame group; the all-``False`` instance is
    *inert* — it records nothing, so :func:`active` normalizes it to
    ``None`` and the drivers compile the identical no-telemetry
    program (the ``core.faults`` disabled-means-identical pattern).
    Admission outcomes (``admitted``/``dispatched``/``delivered``) are
    recorded whenever any group is on — they are the backbone every
    report view joins against.
    """

    scores: bool = True     # per-device scheduler score decomposition
    sub2: bool = True       # Sub2 allocation vector + objective trace
    transport: bool = True  # payload bits, realized upload time/energy
    faults: bool = True     # fault events by type (needs FLConfig.faults)
    events: bool = True     # event-mode availability/staleness state
    signals: bool = True    # per-device learning signals + fairness health


def is_inert(cfg: TelemetryConfig) -> bool:
    """True when the config records nothing at all."""
    return not (cfg.scores or cfg.sub2 or cfg.transport or cfg.faults
                or cfg.events or cfg.signals)


def active(cfg: Optional[TelemetryConfig]) -> Optional[TelemetryConfig]:
    """Normalize an inert config to ``None`` (the no-telemetry path).

    Every driver dispatches through this, so an all-``False``
    :class:`TelemetryConfig` compiles the *same program* as
    ``telemetry=None`` — bitwise, because it is the identical
    computation.
    """
    if cfg is None or is_inert(cfg):
        return None
    return cfg


__all__ = ["TelemetryConfig", "is_inert", "active", "phase_scope",
           "seen_phases", "PHASES"]
