"""Durable host-side telemetry sinks (DESIGN.md §13).

One shared home for the repo's JSONL discipline:

* :func:`jsonl_append` — append one record, flush, optionally fsync.
  The sweep runner's per-chunk streaming goes through it unchanged.
* :func:`jsonl_rewind` — the resume-safe rewind contract factored out
  of ``sweep/runner.py``: keep lines whose cursor is at or below the
  resumed checkpoint, drop torn tails and non-dict lines, and rewrite
  the file **fsync-before-replace** (temp file in the same directory,
  fsynced, then ``os.replace``) so a crash mid-rewind can never leave a
  half-truncated log.
* :func:`write_round_frames` — one JSON line per round from a stacked
  telemetry frame dict (``repro.telemetry.record``), the format
  ``python -m repro.telemetry.report`` renders.
* :func:`run_manifest` / :func:`write_manifest` — the run's identity
  card: config fingerprint, jax/jaxlib versions, XLA flags, device
  topology, git sha.
"""

from __future__ import annotations

import hashlib
import json
import os
import subprocess
from typing import Any, Dict, Iterable, List, Optional

import numpy as np


# ---------------------------------------------------------------------------
# JSONL primitives
# ---------------------------------------------------------------------------

def sanitize(value):
    """Map non-finite floats to ``None`` recursively, deterministically.

    ``json.dumps`` emits literal ``NaN``/``Infinity`` for non-finite
    Python floats — invalid JSON that breaks every strict parser
    downstream.  All sink writers funnel dict records through this, so
    a NaN divergence sentinel round-trips through JSONL as ``null``
    (missing-not-invalid) instead of corrupting the line.
    """
    if isinstance(value, float):
        return value if np.isfinite(value) else None
    if isinstance(value, dict):
        return {k: sanitize(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [sanitize(v) for v in value]
    if isinstance(value, np.ndarray) or isinstance(value, np.generic):
        return _jsonify(value)
    return value


def jsonl_append(path: str, record: dict, fsync: bool = False) -> None:
    """Append one JSON line; flush always, fsync on request.

    Flush-only matches the sweep runner's historical behavior (a line
    is torn only if the process dies mid-``write``, which the rewind
    contract already tolerates); ``fsync=True`` additionally survives
    power loss, for round-event logs that feed offline analysis.
    Records pass through :func:`sanitize` so non-finite floats land as
    ``null`` rather than invalid bare ``NaN`` tokens.
    """
    with open(path, "a") as f:
        f.write(json.dumps(sanitize(record)) + "\n")
        f.flush()
        if fsync:
            os.fsync(f.fileno())


def jsonl_rewind(path: str, cursor: int, key: str = "cursor") -> None:
    """Drop lines past ``cursor`` (the resume-safe append contract).

    A killed run may have streamed records that were never
    checkpointed; those re-execute on resume, so their stale lines must
    go before the re-run appends duplicates.  Kept-line semantics are
    exactly the sweep runner's: stop at the first torn (non-JSON) line,
    the first non-dict line, or the first record past the cursor.  The
    rewrite goes through a same-directory temp file + fsync +
    ``os.replace`` so the log is never observable half-truncated.
    """
    if not os.path.exists(path):
        return
    kept: List[str] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                break                     # torn tail write: drop rest
            if not isinstance(rec, dict):
                break                     # valid JSON, wrong shape: ditto
            if rec.get(key, 0) > cursor:
                break
            kept.append(line)
    tmp = path + ".rewind.tmp"
    with open(tmp, "w") as f:
        for line in kept:
            f.write(line + "\n")
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def read_jsonl(path: str) -> List[dict]:
    """All well-formed dict records of a JSONL file (torn tail dropped,
    same tolerance as :func:`jsonl_rewind`)."""
    out: List[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                break
            if not isinstance(rec, dict):
                break
            out.append(rec)
    return out


# ---------------------------------------------------------------------------
# Round-event frames -> JSONL
# ---------------------------------------------------------------------------

def frames_to_host(frames: Dict[str, Any]) -> Dict[str, np.ndarray]:
    """One device->host transfer for a whole run's stacked frames."""
    import jax
    host = jax.device_get(frames)
    return {k: np.asarray(v) for k, v in host.items()}


def _jsonify(v: np.ndarray):
    a = np.asarray(v)
    if a.ndim == 0:
        x = a.item()
        if isinstance(x, float) and not np.isfinite(x):
            return None
        return x
    return [_jsonify(e) for e in a]


# RoundMetrics scalar leaves merged into each round line when the
# caller passes the run's metrics (the (R, K) leaves stay in the frame).
_METRIC_FIELDS = ("accuracy", "n_selected", "round_time", "energy_total",
                  "n_success", "n_dropped")


def write_round_frames(path: str, frames: Dict[str, Any],
                       metrics=None,
                       scenario: Optional[int] = None,
                       manifest: Optional[dict] = None,
                       fsync: bool = True) -> int:
    """Write a run's telemetry frames as one JSON line per round.

    ``frames`` leaves carry a leading round axis (the scan's ``ys``
    stacking); each line holds the round index, the optional scenario
    index (the sweep engine's fold_in-derived global index), and every
    frame field for that round.  ``metrics`` (a
    :class:`repro.core.federated.RoundMetrics`) merges the per-round
    scalar metrics — accuracy, round time, totals — into each line so
    the report CLI can render the round table from one file.  The file
    is written fresh (truncate, not append) — a scenario's log is a
    pure function of its run, so re-running overwrites rather than
    duplicating — and fsynced before close by default.  Returns the
    number of round lines written.
    """
    host = frames_to_host(frames)
    if metrics is not None:
        met_host = frames_to_host(
            {f: getattr(metrics, f) for f in _METRIC_FIELDS})
        host = {**met_host, **host}
    lengths = {v.shape[0] for v in host.values()}
    if len(lengths) != 1:
        raise ValueError(f"frame leaves disagree on round count: "
                         f"{sorted(lengths)}")
    rounds = lengths.pop()
    with open(path, "w") as f:
        if manifest is not None:
            f.write(json.dumps(sanitize({"type": "manifest", **manifest}))
                    + "\n")
        for r in range(rounds):
            rec: dict = {"type": "round", "round": r}
            if scenario is not None:
                rec["scenario"] = int(scenario)
            for name, arr in host.items():
                rec[name] = _jsonify(arr[r])
            f.write(json.dumps(rec) + "\n")
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    return rounds


# ---------------------------------------------------------------------------
# Run manifest
# ---------------------------------------------------------------------------

def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=5, cwd=os.path.dirname(os.path.abspath(__file__)))
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else None
    except (OSError, subprocess.SubprocessError):
        return None


def config_fingerprint(*cfgs) -> str:
    """Stable digest of the run's static configs (same ``repr`` canon
    as ``SweepSpec.fingerprint``, so frozen-dataclass configs hash
    deterministically)."""
    return hashlib.sha1(repr(tuple(cfgs)).encode()).hexdigest()


def run_manifest(*cfgs, extra: Optional[dict] = None) -> dict:
    """The run's identity card: everything needed to tie a JSONL log
    back to the code, configs and machine that produced it."""
    import jax
    devices = jax.devices()
    man = {
        "config_fingerprint": config_fingerprint(*cfgs),
        "configs": {type(c).__name__: repr(c) for c in cfgs},
        "jax_version": jax.__version__,
        "jaxlib_version": getattr(
            __import__("jaxlib"), "__version__", None),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "device_count": len(devices),
        "device_platform": devices[0].platform if devices else None,
        "backend": jax.default_backend(),
        "git_sha": _git_sha(),
    }
    if extra:
        man.update(extra)
    return man


def write_manifest(path: str, *cfgs, extra: Optional[dict] = None) -> dict:
    """Write the manifest JSON (fsync-before-replace) and return it."""
    man = run_manifest(*cfgs, extra=extra)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(man, f, indent=2, sort_keys=True, default=str)
        f.write("\n")
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return man


__all__ = ["jsonl_append", "jsonl_rewind", "read_jsonl", "frames_to_host",
           "write_round_frames", "run_manifest", "write_manifest",
           "config_fingerprint", "sanitize"]
