"""Run-report CLI: render a telemetry JSONL log as a text summary.

    PYTHONPATH=src python -m repro.telemetry.report run.jsonl [...]

Reads one or more JSONL round-event logs written by
``repro.telemetry.sinks.write_round_frames`` (an inline ``"type":
"manifest"`` first line is picked up automatically; ``--manifest``
points at a standalone manifest JSON) and prints:

* a **run summary** — rounds, scenarios, device count, manifest
  identity (jax version, backend, git sha, config fingerprint);
* a **round table** — selection/success/drop counts, accuracy, round
  time, energy, Sub2 iterations and objective gain per round;
* an **admission heatmap** — device x round, ``#`` delivered, ``x``
  admitted but failed/dropped, ``.`` idle (the DAS-vs-random admission
  texture at a glance);
* an **energy / fault breakdown** — realized upload energy plus fault
  events by type when the fault group was recorded;
* **Sub2 convergence stats** — iteration and objective-gain summary;
* **learning signals** — delivered loss-delta / update-norm summary
  plus divergence sentinel counts (signals group, DESIGN.md §14);
* **fairness** — end-of-run Jain indices over participation and energy
  and the starved-device count.

``--json`` emits the same content as a machine-readable dict
(:func:`summary_dict`) for the regression gate and external tooling.

Exit status 0 on a parsed log with at least one round record, 2 on
usage/IO errors, 1 on a log with no round records — so CI can assert
the whole pipeline (sim -> sink -> report) stayed wired.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional

import numpy as np

from repro.telemetry import sinks

# Display caps: logs can hold hundreds of devices/rounds; the heatmap
# stays terminal-sized and says what it truncated.
_MAX_HEAT_DEVICES = 64
_MAX_HEAT_ROUNDS = 96
_MAX_TABLE_ROUNDS = 40


def _fmt(v, width: int = 8, prec: int = 3) -> str:
    if v is None:
        return " " * (width - 3) + "nan"
    if isinstance(v, float):
        return f"{v:{width}.{prec}f}"
    return f"{v:{width}d}"


def _scalar(rec: dict, name: str):
    v = rec.get(name)
    if isinstance(v, list):
        return None
    return v


def load_rounds(paths: List[str]) -> tuple[List[dict], Optional[dict]]:
    """All round records across the given logs + the first inline
    manifest found (if any)."""
    rounds: List[dict] = []
    manifest: Optional[dict] = None
    for path in paths:
        for rec in sinks.read_jsonl(path):
            kind = rec.get("type")
            if kind == "manifest" and manifest is None:
                manifest = rec
            elif kind == "round" or "round" in rec:
                rounds.append(rec)
    rounds.sort(key=lambda r: (r.get("scenario", 0), r.get("round", 0)))
    return rounds, manifest


def _summary(rounds: List[dict], manifest: Optional[dict]) -> List[str]:
    scenarios = sorted({r.get("scenario") for r in rounds
                        if r.get("scenario") is not None})
    k = None
    for r in rounds:
        adm = r.get("admitted") or r.get("dispatched")
        if isinstance(adm, list):
            k = len(adm)
            break
    lines = ["== Run summary =="]
    per_scn = max(r.get("round", 0) for r in rounds) + 1
    lines.append(f"rounds: {per_scn}   round records: {len(rounds)}   "
                 f"scenarios: {len(scenarios) or 1}   "
                 f"devices: {k if k is not None else '?'}")
    if manifest is not None:
        lines.append(
            f"jax {manifest.get('jax_version', '?')} "
            f"({manifest.get('backend', '?')}, "
            f"{manifest.get('device_count', '?')} devices)   "
            f"git {str(manifest.get('git_sha'))[:12]}   "
            f"cfg {str(manifest.get('config_fingerprint'))[:12]}")
    return lines


def _round_table(rounds: List[dict]) -> List[str]:
    lines = ["== Round table ==",
             "round  n_sel  n_ok  n_drop       acc    time_s  energy_J"
             "  sub2_it  sub2_gain"]
    shown = rounds[:_MAX_TABLE_ROUNDS]
    for r in shown:
        disp = r.get("dispatched")
        deliv = r.get("delivered")
        n_sel = _scalar(r, "n_selected")
        if n_sel is None and isinstance(disp, list):
            n_sel = int(sum(1 for v in disp if v and v > 0))
        n_ok = _scalar(r, "n_success")
        if n_ok is None and isinstance(deliv, list):
            n_ok = int(sum(1 for v in deliv if v and v > 0))
        e_tot = _scalar(r, "energy_total")
        if e_tot is None and isinstance(r.get("energy_up"), list):
            e_tot = float(sum(v for v in r["energy_up"] if v))
        acc = _scalar(r, "accuracy")
        lines.append(
            f"{r.get('round', 0):5d}  "
            f"{_fmt(int(n_sel) if n_sel is not None else 0, 5)}  "
            f"{_fmt(int(n_ok) if n_ok is not None else 0, 4)}  "
            f"{_fmt(int(_scalar(r, 'n_dropped') or 0), 6)}  "
            f"{_fmt(float(acc) if acc is not None else None, 8)}  "
            f"{_fmt(float(_scalar(r, 'round_time') or 0.0), 8)}  "
            f"{_fmt(float(e_tot) if e_tot is not None else 0.0, 8)}  "
            f"{_fmt(int(_scalar(r, 'sub2_iters') or 0), 7)}  "
            f"{_fmt(float(_scalar(r, 'sub2_gain') or 0.0), 9, 4)}")
    if len(rounds) > len(shown):
        lines.append(f"... {len(rounds) - len(shown)} more round "
                     f"records not shown")
    return lines


def _heatmap(rounds: List[dict]) -> List[str]:
    # One scenario's texture: the first scenario present in the log.
    scn = rounds[0].get("scenario")
    rows = [r for r in rounds if r.get("scenario") == scn]
    rows = rows[:_MAX_HEAT_ROUNDS]
    disp0 = rows[0].get("dispatched") or rows[0].get("admitted")
    if not isinstance(disp0, list):
        return []
    k = len(disp0)
    k_shown = min(k, _MAX_HEAT_DEVICES)
    lines = ["== Admission heatmap (rows=devices, cols=rounds; "
             "'#'=delivered, 'x'=admitted w/o delivery, '.'=idle) =="]
    if scn is not None:
        lines[0] = lines[0][:-3] + f", scenario {scn} =="
    for d in range(k_shown):
        cells = []
        for r in rows:
            adm = (r.get("admitted") or r.get("dispatched") or [0] * k)[d]
            ok = (r.get("delivered") or [0] * k)[d]
            cells.append("#" if ok and ok > 0
                         else ("x" if adm and adm > 0 else "."))
        lines.append(f"dev {d:3d} " + "".join(cells))
    if k > k_shown:
        lines.append(f"... {k - k_shown} more devices not shown")
    return lines


def _energy_faults(rounds: List[dict]) -> List[str]:
    e_tot, n_dev_rounds = 0.0, 0
    outage = dropout = straggler = 0.0
    attempts, have_faults = [], False
    for r in rounds:
        e = r.get("energy_up")
        if isinstance(e, list):
            e_tot += float(sum(v for v in e if v))
            n_dev_rounds += sum(1 for v in e if v and v > 0)
        elif _scalar(r, "energy_total") is not None:
            e_tot += float(r["energy_total"])
        for name in ("fault_outage", "fault_dropout", "fault_straggler"):
            v = r.get(name)
            if isinstance(v, list):
                have_faults = True
        if have_faults:
            outage += float(sum(r.get("fault_outage") or []))
            dropout += float(sum(r.get("fault_dropout") or []))
            straggler += float(sum(r.get("fault_straggler") or []))
            att = r.get("fault_attempts")
            if isinstance(att, list):
                attempts.extend(v for v in att if v and v > 0)
    lines = ["== Energy / fault breakdown ==",
             f"upload energy: {e_tot:.4f} J"
             + (f" over {n_dev_rounds} device-rounds"
                if n_dev_rounds else "")]
    if have_faults:
        mean_att = float(np.mean(attempts)) if attempts else 0.0
        lines.append(f"fault events — outages: {int(outage)}, dropouts: "
                     f"{int(dropout)}, stragglers: {int(straggler)}; "
                     f"mean attempts (transmitting devices): "
                     f"{mean_att:.2f}")
    else:
        lines.append("fault events — none recorded (reliable edge or "
                     "fault group disabled)")
    return lines


def _sub2_stats(rounds: List[dict]) -> List[str]:
    iters = [r["sub2_iters"] for r in rounds
             if _scalar(r, "sub2_iters") is not None]
    gains = [r["sub2_gain"] for r in rounds
             if _scalar(r, "sub2_gain") is not None]
    if not iters and not gains:
        return ["== Sub2 convergence ==",
                "no Sub2 trace recorded (sub2 group disabled)"]
    lines = ["== Sub2 convergence =="]
    if iters:
        lines.append(f"outer iterations — mean {np.mean(iters):.2f}, "
                     f"max {int(np.max(iters))} over {len(iters)} rounds")
    if gains:
        lines.append(f"objective gain vs equal-share — mean "
                     f"{np.mean(gains):.5f}, min {np.min(gains):.5f}, "
                     f"max {np.max(gains):.5f}")
    return lines


def _last_per_scenario(rounds: List[dict]) -> List[dict]:
    """The final round record of each scenario (cumulative leaves —
    participation, energy, Jain — are end-of-run there)."""
    last: Dict = {}
    for r in rounds:
        last[r.get("scenario")] = r  # rounds are sorted by (scn, round)
    return list(last.values())


def _signals(rounds: List[dict]) -> List[str]:
    deltas, norms = [], []
    nonfinite = exploding = 0
    have = False
    for r in rounds:
        ld, un = r.get("sig_loss_delta"), r.get("sig_update_norm")
        deliv = r.get("delivered")
        if not isinstance(ld, list) or not isinstance(deliv, list):
            continue
        have = True
        for d, v in zip(deliv, ld):
            if d and d > 0 and v is not None:
                deltas.append(float(v))
        for d, v in zip(deliv, un or []):
            if d and d > 0 and v is not None:
                norms.append(float(v))
        nonfinite += int(_scalar(r, "div_nonfinite") or 0)
        exploding += int(_scalar(r, "div_exploding") or 0)
    if not have:
        return ["== Learning signals ==",
                "no signal trace recorded (signals group disabled)"]
    lines = ["== Learning signals =="]
    if deltas:
        lines.append(f"local loss delta (delivered) — mean "
                     f"{np.mean(deltas):+.5f}, min {np.min(deltas):+.5f}"
                     f", max {np.max(deltas):+.5f} over {len(deltas)} "
                     f"device-rounds")
    if norms:
        lines.append(f"update L2 norm (delivered) — mean "
                     f"{np.mean(norms):.5f}, max {np.max(norms):.5f}")
    lines.append(f"divergence sentinels — non-finite: {nonfinite}, "
                 f"exploding: {exploding}"
                 + ("  << CHECK RUN" if nonfinite or exploding else ""))
    return lines


def _fairness(rounds: List[dict]) -> List[str]:
    finals = [r for r in _last_per_scenario(rounds)
              if _scalar(r, "jain_participation") is not None]
    if not finals:
        return ["== Fairness ==",
                "no fairness trace recorded (signals group disabled)"]
    jp = [float(r["jain_participation"]) for r in finals]
    je = [float(r["jain_energy"]) for r in finals
          if _scalar(r, "jain_energy") is not None]
    starved = [int(r["starved"]) for r in finals
               if _scalar(r, "starved") is not None]
    lines = ["== Fairness (end of run) =="]
    lines.append(f"Jain(participation) — mean {np.mean(jp):.4f}, "
                 f"min {np.min(jp):.4f} over {len(jp)} scenario(s)")
    if je:
        lines.append(f"Jain(energy)        — mean {np.mean(je):.4f}, "
                     f"min {np.min(je):.4f}")
    if starved:
        lines.append(f"starved devices (never delivered) — mean "
                     f"{np.mean(starved):.1f}, max {int(np.max(starved))}")
    return lines


def render(rounds: List[dict],
           manifest: Optional[dict] = None) -> str:
    """The full text report for a list of round records."""
    blocks = [_summary(rounds, manifest), _round_table(rounds),
              _heatmap(rounds), _energy_faults(rounds),
              _sub2_stats(rounds), _signals(rounds), _fairness(rounds)]
    return "\n".join("\n".join(b) for b in blocks if b)


def summary_dict(rounds: List[dict],
                 manifest: Optional[dict] = None) -> dict:
    """Machine-readable report (the ``--json`` mode's payload).

    Mirrors the text sections: run identity, per-round scalar rows, the
    Sub2 / signal / fairness aggregates.  Consumed by the regression
    gate and external tooling so nothing screen-scrapes the table.
    """
    scenarios = sorted({r.get("scenario") for r in rounds
                        if r.get("scenario") is not None})
    out: dict = {
        "rounds": max(r.get("round", 0) for r in rounds) + 1,
        "round_records": len(rounds),
        "scenarios": len(scenarios) or 1,
        "manifest": {k: manifest.get(k) for k in
                     ("jax_version", "backend", "device_count",
                      "git_sha", "config_fingerprint")}
        if manifest else None,
        "round_table": [],
    }
    for r in rounds:
        out["round_table"].append({
            k: _scalar(r, k) for k in
            ("scenario", "round", "n_selected", "n_success", "n_dropped",
             "accuracy", "round_time", "energy_total", "sub2_iters",
             "sub2_gain", "jain_participation", "jain_energy", "starved",
             "div_nonfinite", "div_exploding")
            if r.get(k) is not None})
    iters = [r["sub2_iters"] for r in rounds
             if _scalar(r, "sub2_iters") is not None]
    gains = [r["sub2_gain"] for r in rounds
             if _scalar(r, "sub2_gain") is not None]
    out["sub2"] = {
        "mean_iterations": float(np.mean(iters)) if iters else None,
        "mean_gain": float(np.mean(gains)) if gains else None,
    }
    deltas, norms = [], []
    nonfinite = exploding = 0
    for r in rounds:
        ld, un = r.get("sig_loss_delta"), r.get("sig_update_norm")
        deliv = r.get("delivered")
        if not isinstance(ld, list) or not isinstance(deliv, list):
            continue
        deltas.extend(float(v) for d, v in zip(deliv, ld)
                      if d and d > 0 and v is not None)
        norms.extend(float(v) for d, v in zip(deliv, un or [])
                     if d and d > 0 and v is not None)
        nonfinite += int(_scalar(r, "div_nonfinite") or 0)
        exploding += int(_scalar(r, "div_exploding") or 0)
    out["signals"] = {
        "mean_loss_delta": float(np.mean(deltas)) if deltas else None,
        "mean_update_norm": float(np.mean(norms)) if norms else None,
        "div_nonfinite": nonfinite,
        "div_exploding": exploding,
    } if deltas or norms else None
    finals = [r for r in _last_per_scenario(rounds)
              if _scalar(r, "jain_participation") is not None]
    out["fairness"] = {
        "jain_participation": [float(r["jain_participation"])
                               for r in finals],
        "jain_energy": [float(r["jain_energy"]) for r in finals
                        if _scalar(r, "jain_energy") is not None],
        "starved": [int(r["starved"]) for r in finals
                    if _scalar(r, "starved") is not None],
    } if finals else None
    return out


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.telemetry.report",
        description="Render a telemetry JSONL round-event log.")
    ap.add_argument("logs", nargs="+", help="JSONL round-event file(s)")
    ap.add_argument("--manifest", default=None,
                    help="standalone run-manifest JSON to include")
    ap.add_argument("--json", action="store_true",
                    help="emit a machine-readable summary dict instead "
                         "of the text report")
    args = ap.parse_args(argv)
    manifest = None
    if args.manifest is not None:
        try:
            with open(args.manifest) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: cannot read manifest {args.manifest}: {e}",
                  file=sys.stderr)
            return 2
    try:
        rounds, inline = load_rounds(args.logs)
    except OSError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if manifest is None:
        manifest = inline
    if not rounds:
        print("no round records found", file=sys.stderr)
        return 1
    if args.json:
        print(json.dumps(sinks.sanitize(summary_dict(rounds, manifest)),
                         indent=2))
    else:
        print(render(rounds, manifest))
    return 0


if __name__ == "__main__":
    sys.exit(main())
