"""Architecture config registry: ``get(name)`` / ``--arch <id>``.

Each module defines ``CONFIG`` (the exact assigned architecture) built on
:class:`repro.models.config.ModelConfig`; ``CONFIG.reduced()`` is the
CPU-smoke variant.  Input shapes live in :mod:`repro.configs.shapes`.
"""

from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import ModelConfig

ARCH_IDS = (
    "qwen3_14b",
    "qwen3_moe_235b_a22b",
    "qwen2_vl_72b",
    "xlstm_125m",
    "h2o_danube_3_4b",
    "stablelm_12b",
    "mixtral_8x22b",
    "jamba_1_5_large_398b",
    "whisper_small",
    "codeqwen1_5_7b",
)

_ALIASES = {
    "qwen3-14b": "qwen3_14b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "xlstm-125m": "xlstm_125m",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "stablelm-12b": "stablelm_12b",
    "mixtral-8x22b": "mixtral_8x22b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "whisper-small": "whisper_small",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get(a) for a in ARCH_IDS}
