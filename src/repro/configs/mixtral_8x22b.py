"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) expert
d_ff=16384 vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088]"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    pattern=(LayerSpec("attn", "moe", sliding_window=True),),
    sliding_window=4096,
    num_experts=8,
    num_experts_per_tok=2,
    rope_theta=1.0e6,
    mlp_activation="swiglu",
    norm_type="rmsnorm",
)
