"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304; alternating
sLSTM + mLSTM blocks (no separate FFN — blocks carry their own
projections).  [arXiv:2405.04517]"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    arch_type="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=(LayerSpec("slstm", "none"), LayerSpec("mlstm", "none")),
    xlstm_heads=4,
    norm_type="rmsnorm",
)
