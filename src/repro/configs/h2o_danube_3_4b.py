"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000; llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    arch_type="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    pattern=(LayerSpec("attn", "mlp", sliding_window=True),),
    sliding_window=4096,
    rope_theta=1.0e4,
    mlp_activation="swiglu",
    norm_type="rmsnorm",
)
