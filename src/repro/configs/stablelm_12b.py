"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352; LayerNorm + partial rotary (25%).
[hf:stabilityai/stablelm-2-1_6b family]"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    pattern=(LayerSpec("attn", "mlp"),),
    rope_theta=1.0e4,
    rope_fraction=0.25,
    mlp_activation="swiglu",
    norm_type="layernorm",
)
