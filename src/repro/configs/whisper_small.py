"""whisper-small [audio] — 12L (x2: encoder + decoder) d_model=768 12H
d_ff=3072 vocab=51865; encoder-decoder with conv frontend STUB.
[arXiv:2212.04356]

Backbone only: the mel-spectrogram + conv feature extractor is a stub —
``input_specs`` feeds precomputed frame embeddings (batch, frames,
d_model) to the encoder.  The decoder is a standard causal transformer
with cross-attention and absolute sinusoidal positions.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    arch_type="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    pattern=(LayerSpec("attn", "mlp"),),
    encoder_layers=12,
    cross_attention=True,
    pos_embedding="absolute",
    mlp_activation="gelu",
    norm_type="layernorm",
    use_bias=True,
)
