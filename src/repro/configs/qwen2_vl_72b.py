"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064; M-RoPE, dynamic resolution.  [arXiv:2409.12191]

Backbone only: the ViT vision encoder + projector are a STUB —
``input_specs`` feeds precomputed patch embeddings of shape
(batch, seq, d_model) with 3-axis M-RoPE position ids (temporal, height,
width).  Decode consumes generated text tokens via the embed table.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    arch_type="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    pattern=(LayerSpec("attn", "mlp"),),
    mrope_sections=(16, 24, 24),
    rope_theta=1.0e6,
    input_mode="embeddings",
    mlp_activation="swiglu",
    norm_type="rmsnorm",
)
