"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, MoE 16 experts top-2; Mamba+attention 1:7 interleave, MoE
every other layer.  [arXiv:2403.19887]

Pattern period 8: one attention layer per 8 (position 0), Mamba
elsewhere; MoE on even positions, dense MLP on odd.  The Mamba mixer uses
the SSD (scalar-decay) formulation — the TPU adaptation recorded in
DESIGN.md §3.
"""

from repro.models.config import LayerSpec, ModelConfig

_PATTERN = tuple(
    LayerSpec(mixer=("attn" if i == 0 else "mamba"),
              ffn=("moe" if i % 2 == 0 else "mlp"))
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    pattern=_PATTERN,
    num_experts=16,
    num_experts_per_tok=2,
    ssm_state_dim=16,
    ssm_expand=2,
    ssm_head_dim=64,
    rope_theta=1.0e6,
    mlp_activation="swiglu",
    norm_type="rmsnorm",
)
