"""Assigned input shapes and per-(arch x shape) applicability.

  train_4k     seq=  4,096  global_batch=256  -> train_step
  prefill_32k  seq= 32,768  global_batch= 32  -> prefill_step
  decode_32k   seq= 32,768  global_batch=128  -> serve_step (1 new token,
                                                cache of seq_len)
  long_500k    seq=524,288  global_batch=  1  -> serve_step; requires
                                                sub-quadratic attention

``long_500k`` runs only for architectures with recurrent state or a
sliding window (xlstm, jamba, h2o-danube, mixtral); pure full-attention
archs skip it (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> InputShape:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) is in the assigned matrix; reason if not."""
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return False, ("full quadratic attention — long-context decode "
                       "skipped per DESIGN.md §4")
    return True, ""
