"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (MHA kv=32) d_ff=13440
vocab=92416; qwen1.5 architecture.  [hf:Qwen/CodeQwen1.5-7B]"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    pattern=(LayerSpec("attn", "mlp"),),
    rope_theta=1.0e6,
    mlp_activation="swiglu",
    norm_type="rmsnorm",
)
