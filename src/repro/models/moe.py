"""Feed-forward layers: dense MLP (SwiGLU / GELU) and top-k MoE.

Three MoE dispatch implementations, selected by ``cfg.moe_impl``:

* ``ragged`` (default) — sort tokens by assigned expert, run
  ``jax.lax.ragged_dot`` against the stacked expert weights, scatter-add
  back with the gate weights.  FLOPs equal the *active*-parameter cost
  (``top_k`` experts per token); this is the TPU-native analogue of a
  grouped GEMM.
* ``dense_grouped`` — GShard-style einsum dispatch with capacity within
  token groups of ``cfg.moe_group_size`` (robust under GSPMD, used as a
  fallback and as a perf-iteration comparison point).
* ``dense`` — every expert runs every token, combine by gate mask.  Only
  sane for the reduced smoke configs (<=4 experts).

Expert weights are stacked ``(E, D, F)``; the sharding rules place ``E``
on the ``model`` mesh axis when divisible (expert parallel: qwen3-moe
128/16, jamba 16/16) and otherwise shard ``F`` on ``model`` (mixtral 8
experts -> per-expert tensor parallel).

Router: softmax over expert logits, top-k, renormalized; Switch-style
load-balance auxiliary loss returned to the caller.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import ModelConfig
from repro.sharding import rules

Array = jax.Array
Params = Dict[str, Array]


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

def mlp_init(key: Array, cfg: ModelConfig, d_ff: int = 0) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = common.dtype_of(cfg.dtype_params)
    ks = jax.random.split(key, 3)
    if cfg.mlp_activation == "swiglu":
        p = {
            "wi": common.dense_init(ks[0], (d, f), d, dt),
            "wg": common.dense_init(ks[1], (d, f), d, dt),
            "wo": common.dense_init(ks[2], (f, d), f, dt),
        }
    else:
        p = {
            "wi": common.dense_init(ks[0], (d, f), d, dt),
            "wo": common.dense_init(ks[2], (f, d), f, dt),
        }
    if cfg.use_bias:
        p["bi"] = jnp.zeros((f,), dt)
        p["bo"] = jnp.zeros((d,), dt)
    return p


def mlp_apply(p: Params, x: Array, cfg: ModelConfig, mesh) -> Array:
    dt = x.dtype
    h = x @ p["wi"].astype(dt)
    if cfg.use_bias:
        h = h + p["bi"].astype(dt)
    if cfg.mlp_activation == "swiglu":
        h = jax.nn.silu(h) * (x @ p["wg"].astype(dt))
    else:
        h = jax.nn.gelu(h)
    h = rules.constrain(h, mesh, "batch", None, "tensor")
    out = h @ p["wo"].astype(dt)
    if cfg.use_bias:
        out = out + p["bo"].astype(dt)
    return rules.residual_constrain(out, mesh, cfg.sequence_sharding)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_init(key: Array, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = common.dtype_of(cfg.dtype_params)
    ks = jax.random.split(key, 4)
    p: Params = {
        "router": common.dense_init(ks[0], (d, e), d, jnp.float32),
        "wi": common.dense_init(ks[1], (e, d, f), d, dt),
        "wo": common.dense_init(ks[3], (e, f, d), f, dt),
    }
    if cfg.mlp_activation == "swiglu":
        p["wg"] = common.dense_init(ks[2], (e, d, f), d, dt)
    return p


def route(p: Params, x2d: Array, cfg: ModelConfig
          ) -> Tuple[Array, Array, Array]:
    """Top-k routing.  x2d: (T, D).

    Returns (expert_ids (T, k), gate_weights (T, k), aux_loss scalar).
    """
    logits = (x2d.astype(jnp.float32) @ p["router"])        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
    # Switch load-balance loss: E * sum_e fraction_e * mean_prob_e.
    e = cfg.num_experts
    assign = jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32)  # top-1 share
    aux = e * jnp.sum(jnp.mean(assign, axis=0) * jnp.mean(probs, axis=0))
    return ids, gate.astype(x2d.dtype), aux


def _expert_ffn_ragged(p: Params, x_sorted: Array, group_sizes: Array,
                       cfg: ModelConfig) -> Array:
    """(T*k, D) sorted-by-expert tokens -> (T*k, D) via ragged grouped GEMM."""
    dt = x_sorted.dtype
    h = jax.lax.ragged_dot(x_sorted, p["wi"].astype(dt), group_sizes)
    if cfg.mlp_activation == "swiglu":
        g = jax.lax.ragged_dot(x_sorted, p["wg"].astype(dt), group_sizes)
        h = jax.nn.silu(h) * g
    else:
        h = jax.nn.gelu(h)
    return jax.lax.ragged_dot(h, p["wo"].astype(dt), group_sizes)


def moe_apply_ragged(p: Params, x2d: Array, cfg: ModelConfig,
                     mesh) -> Tuple[Array, Array]:
    """Sort-based dispatch: active-parameter FLOPs, one grouped GEMM."""
    t, d = x2d.shape
    k = cfg.num_experts_per_tok
    ids, gate, aux = route(p, x2d, cfg)

    flat_ids = ids.reshape(-1)                                # (T*k,)
    order = jnp.argsort(flat_ids)
    token_of = order // k                                     # source token
    x_sorted = jnp.take(x2d, token_of, axis=0)                # (T*k, D)
    group_sizes = jnp.bincount(flat_ids, length=cfg.num_experts)
    y_sorted = _expert_ffn_ragged(p, x_sorted, group_sizes, cfg)
    w_sorted = jnp.take(gate.reshape(-1), order)[:, None]
    out = jnp.zeros((t, d), x2d.dtype).at[token_of].add(
        y_sorted * w_sorted.astype(y_sorted.dtype))
    return out, aux


def moe_apply_dense_grouped(p: Params, x2d: Array, cfg: ModelConfig,
                            mesh) -> Tuple[Array, Array]:
    """GShard einsum dispatch with per-group capacity buffers."""
    t, d = x2d.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    gs = min(cfg.moe_group_size, t)
    if t % gs:
        gs = t
    n_groups = t // gs
    # Capacity floor: tiny groups (decode: T=batch tokens) must not drop —
    # worst case all gs*k assignments land on one expert.
    cap = max(int(gs * k * cfg.moe_capacity_factor / e),
              min(gs * k, 16))

    ids, gate, aux = route(p, x2d, cfg)
    xg = x2d.reshape(n_groups, gs, d)
    idsg = ids.reshape(n_groups, gs, k)
    gateg = gate.reshape(n_groups, gs, k)

    def per_group(xs, ids_s, gate_s):
        # (gs, k) assignments -> dispatch one-hot (gs, E, cap)
        onehot = jax.nn.one_hot(ids_s, e, dtype=jnp.float32)    # (gs,k,E)
        pos = jnp.cumsum(onehot.sum(1), axis=0) - onehot.sum(1)  # (gs,E)
        pos_k = jnp.einsum("ske,se->sk", onehot, pos)            # slot idx
        keep = pos_k < cap
        cap_onehot = jax.nn.one_hot(pos_k, cap, dtype=jnp.float32)
        disp = (onehot[..., :, None] * cap_onehot[..., None, :]
                * keep[..., None, None])        # (gs, k, E, cap)
        disp_te = disp.sum(1)                                    # (gs,E,cap)
        xe = jnp.einsum("sec,sd->ecd", disp_te, xs.astype(jnp.float32))
        xe = xe.astype(xs.dtype)
        h = jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(xs.dtype))
        if cfg.mlp_activation == "swiglu":
            g = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(xs.dtype))
            h = jax.nn.silu(h) * g
        else:
            h = jax.nn.gelu(h)
        ye = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(xs.dtype))
        comb = jnp.einsum("skec,sk->sec", disp, gate_s.astype(jnp.float32))
        return jnp.einsum("sec,ecd->sd", comb.astype(ye.dtype), ye)

    out = jax.vmap(per_group)(xg, idsg, gateg).reshape(t, d)
    return out, aux


def moe_apply_dense(p: Params, x2d: Array, cfg: ModelConfig,
                    mesh) -> Tuple[Array, Array]:
    """Every expert on every token (smoke-scale only)."""
    ids, gate, aux = route(p, x2d, cfg)
    dt = x2d.dtype
    h = jnp.einsum("td,edf->tef", x2d, p["wi"].astype(dt))
    if cfg.mlp_activation == "swiglu":
        g = jnp.einsum("td,edf->tef", x2d, p["wg"].astype(dt))
        h = jax.nn.silu(h) * g
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("tef,efd->ted", h, p["wo"].astype(dt))     # (T,E,D)
    mask = jax.nn.one_hot(ids, cfg.num_experts, dtype=jnp.float32)  # (T,k,E)
    comb = jnp.einsum("tke,tk->te", mask, gate.astype(jnp.float32))
    return jnp.einsum("te,ted->td", comb.astype(dt), y), aux


def moe_apply(p: Params, x: Array, cfg: ModelConfig, mesh
              ) -> Tuple[Array, Array]:
    """x: (B, S, D) -> (out (B, S, D), aux scalar)."""
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    impl = {"ragged": moe_apply_ragged,
            "dense_grouped": moe_apply_dense_grouped,
            "dense": moe_apply_dense}[cfg.moe_impl]
    out, aux = impl(p, x2d, cfg, mesh)
    out = rules.residual_constrain(out.reshape(b, s, d), mesh,
                                   cfg.sequence_sharding)
    return out, aux
