"""Selective state-space mixer (Mamba), TPU-adapted SSD formulation.

Jamba interleaves Mamba-1 blocks with attention.  Mamba-1's per-channel
diagonal recurrence resists efficient chunking on the MXU (the inter-pair
decay couples (d_inner x d_state) per step), so — per the hardware-
adaptation mandate — we implement the **SSD (Mamba-2) formulation**:
scalar decay per head, which turns the sequence mixing into chunked
``(L x L)`` matmuls plus a small recurrent state carried across chunks.
This preserves the paper-relevant property (O(1) decode state, linear-time
prefill) while being MXU-native.  DESIGN.md records the substitution.

Recurrence per head (head dim ``p``, state dim ``n``)::

    a_t = exp(-softplus(dt_t + dt_bias) * exp(A_log))        # scalar decay
    h_t = a_t * h_{t-1} + dt_t * B_t  x_t^T                  # (n, p) state
    y_t = C_t^T h_t + D * x_t

Chunked evaluation (chunk ``L = cfg.ssm_chunk``) splits ``y`` into an
intra-chunk semiseparable matmul and an inter-chunk state term; the chunk
loop is an **unrolled** Python loop so ``cost_analysis`` sees every FLOP
(EXPERIMENTS.md §Dry-run methodology).

Block: in_proj -> [z | x | B | C | dt]; causal depthwise conv on x;
SSD mix; RMSNorm; gate by silu(z); out_proj.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import ModelConfig
from repro.sharding import rules

Array = jax.Array
Params = Dict[str, Array]


def init(key: Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    din = cfg.ssm_d_inner
    n = cfg.ssm_state_dim
    nh = cfg.ssm_num_heads
    dt = common.dtype_of(cfg.dtype_params)
    ks = jax.random.split(key, 9)
    # dt bias: softplus^-1 of U[1e-3, 1e-1] (mamba init)
    u = jax.random.uniform(ks[5], (nh,), minval=1e-3, maxval=1e-1)
    dt_bias = u + jnp.log(-jnp.expm1(-u))
    return {
        "wz": common.dense_init(ks[0], (d, din), d, dt),
        "wx": common.dense_init(ks[1], (d, din), d, dt),
        "wB": common.dense_init(ks[2], (d, n), d, dt),
        "wC": common.dense_init(ks[3], (d, n), d, dt),
        "wdt": common.dense_init(ks[4], (d, nh), d, dt),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(jax.random.uniform(ks[6], (nh,), minval=1.0,
                                            maxval=16.0)),
        "D": jnp.ones((nh,), jnp.float32),
        "conv": common.dense_init(ks[7], (cfg.ssm_conv_dim, din),
                                  cfg.ssm_conv_dim, dt),
        "norm": jnp.ones((din,), jnp.float32),
        "wo": common.dense_init(ks[8], (din, d), din, dt),
    }


def _causal_conv(x: Array, w: Array) -> Array:
    """Depthwise causal conv: x (B,S,C), w (K,C) -> (B,S,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # sum_j x[t-k+1+j] * w[j]
    out = jnp.zeros_like(x)
    for j in range(k):
        out = out + xp[:, j:j + x.shape[1]] * w[j].astype(x.dtype)
    return out


def _ssd_chunked(xh: Array, b: Array, c: Array, log_a: Array, dt_s: Array,
                 chunk: int, h0: Optional[Array] = None
                 ) -> Tuple[Array, Array]:
    """Chunked scalar-decay SSD.

    xh:    (B, S, nh, p)   head inputs
    b, c:  (B, S, n)       input/output projections (shared across heads)
    log_a: (B, S, nh)      per-step log decay (<= 0)
    dt_s:  (B, S, nh)      softplus(dt) step sizes
    h0:    (B, nh, n, p)   initial state (decode/prefill continuation)

    Returns (y (B,S,nh,p), h_final (B,nh,n,p)).  Chunk loop unrolled.
    """
    bsz, s, nh, p = xh.shape
    n = b.shape[-1]
    # Cap the unrolled chunk count at 64 (compile-size guard for 32k+
    # prefill); intra-chunk work stays O(S * L) in total.
    while s // chunk > 64:
        chunk *= 2
    if s % chunk:
        chunk = s
    h = (jnp.zeros((bsz, nh, n, p), jnp.float32) if h0 is None
         else h0.astype(jnp.float32))
    ys = []
    for start in range(0, s, chunk):
        sl = slice(start, start + chunk)
        xc = xh[:, sl].astype(jnp.float32)          # (B,L,nh,p)
        bc = b[:, sl].astype(jnp.float32)           # (B,L,n)
        cc = c[:, sl].astype(jnp.float32)           # (B,L,n)
        la = log_a[:, sl].astype(jnp.float32)       # (B,L,nh)
        dts = dt_s[:, sl].astype(jnp.float32)       # (B,L,nh)
        cum = jnp.cumsum(la, axis=1)                # (B,L,nh)
        # Intra-chunk: M[t,s'] = (C_t . B_s') * exp(cum_t - cum_s') * dt_s'
        cb = jnp.einsum("btn,bsn->bts", cc, bc)     # (B,L,L)
        decay = cum[:, :, None, :] - cum[:, None, :, :]      # (B,L,L,nh)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        # Mask BEFORE exp: above-diagonal decays are positive and large;
        # exp there overflows and where(mask, inf, 0) back-props NaN
        # (0 * inf).  See the jamba smoke test.
        decay = jnp.where(tri[None, :, :, None], decay, -1e30)
        w = jnp.exp(decay)
        m = cb[..., None] * w * dts[:, None, :, :]  # (B,L,L,nh)
        y_intra = jnp.einsum("btsh,bshp->bthp", m, xc)
        # Inter-chunk: y_inter[t] = C_t . (exp(cum_t) * h_prev)
        y_inter = jnp.einsum("btn,bth,bhnp->bthp", cc, jnp.exp(cum), h)
        ys.append(y_intra + y_inter)
        # State update: h = exp(cum_L) h + sum_s exp(cum_L - cum_s) dt B x^T
        w_state = jnp.exp(cum[:, -1:, :] - cum) * dts        # (B,L,nh)
        h = (jnp.exp(cum[:, -1])[:, :, None, None] * h
             + jnp.einsum("bsh,bsn,bshp->bhnp", w_state, bc, xc))
    y = jnp.concatenate(ys, axis=1) if len(ys) > 1 else ys[0]
    return y.astype(xh.dtype), h


def forward(p: Params, x: Array, cfg: ModelConfig, mesh,
            return_state: bool = False):
    """Full-sequence Mamba block.  x: (B, S, D)."""
    bsz, s, _ = x.shape
    nh, hp = cfg.ssm_num_heads, cfg.ssm_head_dim
    dt = x.dtype
    z = x @ p["wz"].astype(dt)
    xin = x @ p["wx"].astype(dt)
    xin = rules.constrain(xin, mesh, "batch", None, "tensor")
    xin = _causal_conv(xin, p["conv"])
    xin = jax.nn.silu(xin)
    b = x @ p["wB"].astype(dt)
    c = x @ p["wC"].astype(dt)
    dt_raw = x @ p["wdt"].astype(dt)
    dt_s = jax.nn.softplus(dt_raw.astype(jnp.float32)
                           + p["dt_bias"])            # (B,S,nh)
    log_a = -dt_s * jnp.exp(p["A_log"])               # (B,S,nh)
    xh = xin.reshape(bsz, s, nh, hp)
    y, h = _ssd_chunked(xh, b, c, log_a, dt_s, cfg.ssm_chunk)
    y = y + xh * p["D"][None, None, :, None].astype(dt)
    y = y.reshape(bsz, s, -1)
    y = common.rmsnorm(y, p["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z)
    out = y @ p["wo"].astype(dt)
    out = rules.residual_constrain(out, mesh, cfg.sequence_sharding)
    if return_state:
        conv_state = xin_raw_tail(x, p, cfg)
        return out, {"h": h.astype(jnp.float32), "conv": conv_state}
    return out, None


def xin_raw_tail(x: Array, p: Params, cfg: ModelConfig) -> Array:
    """Last (conv_dim - 1) pre-conv inputs, for decode continuation."""
    dt = x.dtype
    xin = x @ p["wx"].astype(dt)
    k = cfg.ssm_conv_dim
    return xin[:, -(k - 1):, :]


def init_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Array]:
    nh, hp, n = cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state_dim
    return {
        "h": jnp.zeros((batch, nh, n, hp), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_dim - 1, cfg.ssm_d_inner),
                          dtype),
    }


def decode(p: Params, x: Array, state: Dict[str, Array], cfg: ModelConfig,
           mesh) -> Tuple[Array, Dict[str, Array]]:
    """Single-token step.  x: (B, 1, D)."""
    bsz = x.shape[0]
    nh, hp = cfg.ssm_num_heads, cfg.ssm_head_dim
    dt = x.dtype
    xt = x[:, 0]
    z = xt @ p["wz"].astype(dt)
    xin_new = xt @ p["wx"].astype(dt)                 # (B, din)
    conv_buf = jnp.concatenate([state["conv"],
                                xin_new[:, None, :]], axis=1)  # (B,K,din)
    w = p["conv"].astype(dt)                          # (K, din)
    xin = jnp.einsum("bkc,kc->bc", conv_buf, w)
    xin = jax.nn.silu(xin)
    b = xt @ p["wB"].astype(dt)                       # (B, n)
    c = xt @ p["wC"].astype(dt)
    dt_s = jax.nn.softplus((xt @ p["wdt"].astype(dt)).astype(jnp.float32)
                           + p["dt_bias"])            # (B, nh)
    a = jnp.exp(-dt_s * jnp.exp(p["A_log"]))          # (B, nh)
    xh = xin.reshape(bsz, nh, hp).astype(jnp.float32)
    h = state["h"]
    h = (a[:, :, None, None] * h
         + jnp.einsum("bh,bn,bhp->bhnp", dt_s, b.astype(jnp.float32), xh))
    y = jnp.einsum("bn,bhnp->bhp", c.astype(jnp.float32), h)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(bsz, -1).astype(dt)
    y = common.rmsnorm(y, p["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z)
    out = (y @ p["wo"].astype(dt))[:, None, :]
    return out, {"h": h, "conv": conv_buf[:, 1:, :]}
