"""Model assembly: pattern-driven blocks, scan-over-layers, enc-dec.

A model is: input embedding (token table, or a pass-through for the
VLM/audio *embeddings* stub) -> ``num_groups`` repetitions of the layer
``pattern`` -> final norm -> LM head.

Layer parameters are stacked per pattern position with a leading group
dim, so ``layer_mode="scan"`` runs one ``lax.scan`` over groups (compile
time O(1) in depth) and ``layer_mode="unroll"`` slices the same stacked
params in a Python loop (exact ``cost_analysis``).  See EXPERIMENTS.md
§Dry-run for how roofline totals are recovered under scan.

Three entry points per model:

* :func:`forward`      — full-sequence logits (training / eval)
* :func:`prefill`      — full sequence -> last-token logits + decode cache
* :func:`decode_step`  — one token + cache -> logits + cache

Whisper-style enc-dec: :func:`encode` runs the (non-causal) encoder over
stub frame embeddings; decoder blocks add cross-attention against
per-layer K/V computed once from the encoder output.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, common, moe, ssm, xlstm
from repro.models.config import LayerSpec, ModelConfig
from repro.sharding import rules

Array = jax.Array
Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _block_init(key: Array, spec: LayerSpec, cfg: ModelConfig,
                cross: bool = False) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"norm1": common.norm_init(cfg.d_model, cfg.norm_type)}
    if spec.mixer == "attn":
        p["mixer"] = attention.init(ks[0], cfg)
    elif spec.mixer == "mamba":
        p["mixer"] = ssm.init(ks[0], cfg)
    elif spec.mixer == "mlstm":
        p["mixer"] = xlstm.mlstm_init(ks[0], cfg)
    elif spec.mixer == "slstm":
        p["mixer"] = xlstm.slstm_init(ks[0], cfg)
    else:
        raise ValueError(spec.mixer)
    if cross:
        p["norm_cross"] = common.norm_init(cfg.d_model, cfg.norm_type)
        p["cross"] = attention.init(ks[1], cfg, cross=True)
    if spec.ffn != "none":
        p["norm2"] = common.norm_init(cfg.d_model, cfg.norm_type)
        p["ffn"] = (moe.moe_init(ks[2], cfg) if spec.ffn == "moe"
                    else moe.mlp_init(ks[2], cfg))
    return p


def _stacked_layers(key: Array, cfg: ModelConfig, num_groups: int,
                    pattern: Tuple[LayerSpec, ...],
                    cross: bool = False) -> Params:
    """Per pattern position, stack ``num_groups`` block params."""
    out: Params = {}
    for i, spec in enumerate(pattern):
        keys = jax.random.split(jax.random.fold_in(key, i), num_groups)
        out[f"pos{i}"] = jax.vmap(
            lambda k: _block_init(k, spec, cfg, cross))(keys)
    return out


def init(key: Array, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    dt = common.dtype_of(cfg.dtype_params)
    p: Params = {
        "embed": common.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "layers": _stacked_layers(ks[1], cfg, cfg.num_groups, cfg.pattern,
                                  cross=cfg.cross_attention),
        "final_norm": common.norm_init(cfg.d_model, cfg.norm_type),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = common.dense_init(ks[2], (cfg.d_model,
                                                 cfg.vocab_size),
                                         cfg.d_model, dt)
    if cfg.is_encdec:
        enc_pattern = (LayerSpec("attn", "mlp"),)
        assert cfg.encoder_layers % 1 == 0
        p["encoder"] = {
            "layers": _stacked_layers(ks[3], cfg, cfg.encoder_layers,
                                      enc_pattern),
            "final_norm": common.norm_init(cfg.d_model, cfg.norm_type),
        }
    return p


def init_shapes(cfg: ModelConfig) -> Params:
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(lambda k: init(k, cfg), jax.random.key(0))


def param_count(cfg: ModelConfig) -> int:
    shapes = init_shapes(cfg)
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top-k of E experts)."""
    total = param_count(cfg)
    if not cfg.is_moe:
        return total
    shapes = init_shapes(cfg)
    inactive = 0
    for pos in shapes["layers"].values():
        ffn = pos.get("ffn", {})
        for name in ("wi", "wg", "wo"):
            if name in ffn:
                leaf = ffn[name]
                e = cfg.num_experts
                frac = (e - cfg.num_experts_per_tok) / e
                inactive += int(leaf.size * frac)
    return total - inactive


# ---------------------------------------------------------------------------
# Block application (shared by train / prefill / decode)
# ---------------------------------------------------------------------------

def _apply_mixer_full(bp: Params, spec: LayerSpec, x: Array,
                      cfg: ModelConfig, mesh, positions, causal,
                      collect_state: bool):
    if spec.mixer == "attn":
        out, kv = attention.forward(
            bp["mixer"], x, cfg, mesh, positions,
            layer_window=spec.sliding_window, causal=causal,
            return_kv=collect_state)
        return out, ({"k": kv[0], "v": kv[1]} if collect_state else None)
    if spec.mixer == "mamba":
        return ssm.forward(bp["mixer"], x, cfg, mesh,
                           return_state=collect_state)
    if spec.mixer == "mlstm":
        return xlstm.mlstm_forward(bp["mixer"], x, cfg, mesh,
                                   return_state=collect_state)
    if spec.mixer == "slstm":
        return xlstm.slstm_forward(bp["mixer"], x, cfg, mesh,
                                   return_state=collect_state)
    raise ValueError(spec.mixer)


def _apply_block_full(bp: Params, spec: LayerSpec, x: Array,
                      cfg: ModelConfig, mesh, positions, aux,
                      causal=None, enc_out: Optional[Array] = None,
                      collect_state: bool = False):
    """Pre-norm residual block. Returns (x, aux, state_or_None)."""
    h = common.apply_norm(bp["norm1"], x, cfg.norm_type, cfg.norm_eps)
    h, state = _apply_mixer_full(bp, spec, h, cfg, mesh, positions, causal,
                                 collect_state)
    x = x + h
    if "cross" in bp and enc_out is not None:
        h = common.apply_norm(bp["norm_cross"], x, cfg.norm_type,
                              cfg.norm_eps)
        kv = attention.cross_kv(bp["cross"], enc_out, cfg)
        h, _ = attention.forward(bp["cross"], h, cfg, mesh, None,
                                 layer_window=False, kv_override=kv,
                                 causal=False)
        x = x + h
        if collect_state and state is not None:
            state = dict(state, cross_k=kv[0], cross_v=kv[1])
    if spec.ffn != "none":
        h = common.apply_norm(bp["norm2"], x, cfg.norm_type, cfg.norm_eps)
        if spec.ffn == "moe":
            h, a = moe.moe_apply(bp["ffn"], h, cfg, mesh)
            aux = aux + a
        else:
            h = moe.mlp_apply(bp["ffn"], h, cfg, mesh)
        x = x + h
    return x, aux, state


def _run_stack(layers: Params, x: Array, cfg: ModelConfig, mesh,
               positions, pattern: Tuple[LayerSpec, ...], num_groups: int,
               causal=None, enc_out: Optional[Array] = None
               ) -> Tuple[Array, Array]:
    """Run the layer stack (no state collection). Returns (x, aux)."""

    def group_fn(x, aux, group_params):
        for i, spec in enumerate(pattern):
            x, aux, _ = _apply_block_full(
                group_params[f"pos{i}"], spec, x, cfg, mesh, positions,
                aux, causal=causal, enc_out=enc_out)
        return x, aux

    if cfg.remat:
        group_fn = jax.checkpoint(group_fn)

    aux = jnp.zeros((), jnp.float32)
    if cfg.layer_mode == "scan" and num_groups > 1:
        def body(carry, gp):
            x, aux = carry
            x, aux = group_fn(x, aux, gp)
            return (x, aux), None
        (x, aux), _ = jax.lax.scan(body, (x, aux), layers)
    else:
        for g in range(num_groups):
            gp = jax.tree_util.tree_map(lambda a: a[g], layers)
            x, aux = group_fn(x, aux, gp)
    return x, aux


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def embed_inputs(params: Params, inputs: Array, cfg: ModelConfig,
                 mesh) -> Array:
    """Token ids (B, S) -> embeddings, or pass through stub embeddings."""
    if inputs.dtype in (jnp.int32, jnp.int64):
        x = jnp.take(params["embed"], inputs, axis=0)
    else:
        x = inputs  # precomputed frontend embeddings (VLM / audio stub)
    x = x.astype(common.dtype_of(cfg.dtype_compute))
    if cfg.pos_embedding == "absolute":
        pos = common.sinusoidal_positions(x.shape[1], cfg.d_model)
        x = x + pos[None].astype(x.dtype)
    return rules.residual_constrain(x, mesh, cfg.sequence_sharding)


def lm_logits(params: Params, x: Array, cfg: ModelConfig, mesh) -> Array:
    x = common.apply_norm(params["final_norm"], x, cfg.norm_type,
                          cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = x @ head.astype(x.dtype)
    if cfg.logits_softcap > 0.0:
        logits = cfg.logits_softcap * jnp.tanh(logits / cfg.logits_softcap)
    return rules.constrain(logits, mesh, "batch", None, "tensor")


def default_positions(inputs: Array, cfg: ModelConfig) -> Array:
    b = inputs.shape[0]
    s = inputs.shape[1]
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if cfg.mrope_sections:
        return jnp.broadcast_to(pos[None], (3, b, s))   # text-like M-RoPE
    return pos


def encode(params: Params, embeds: Array, cfg: ModelConfig, mesh) -> Array:
    """Whisper encoder over stub frame embeddings (non-causal)."""
    x = embed_inputs(params, embeds, cfg, mesh)
    enc_pattern = (LayerSpec("attn", "mlp"),)
    x, _ = _run_stack(params["encoder"]["layers"], x, cfg, mesh,
                      None, enc_pattern, cfg.encoder_layers, causal=False)
    return common.apply_norm(params["encoder"]["final_norm"], x,
                             cfg.norm_type, cfg.norm_eps)


def forward(params: Params, inputs: Array, cfg: ModelConfig, mesh=None,
            positions: Optional[Array] = None,
            encoder_inputs: Optional[Array] = None,
            return_hidden: bool = False) -> Tuple[Array, Array]:
    """Full-sequence logits.  Returns (logits (B,S,V), moe aux loss).

    ``return_hidden=True`` returns the final-norm hidden states instead of
    logits, so the loss can fold the LM head into a chunked/rematerialized
    cross-entropy (the (B,S,V) f32 logits never fully materialize — see
    EXPERIMENTS.md §Perf).
    """
    enc_out = None
    if cfg.is_encdec:
        assert encoder_inputs is not None, "enc-dec needs encoder inputs"
        enc_out = encode(params, encoder_inputs, cfg, mesh)
    x = embed_inputs(params, inputs, cfg, mesh)
    if positions is None and cfg.pos_embedding == "rope":
        positions = default_positions(inputs, cfg)
    x, aux = _run_stack(params["layers"], x, cfg, mesh, positions,
                        cfg.pattern, cfg.num_groups, enc_out=enc_out)
    if return_hidden:
        x = common.apply_norm(params["final_norm"], x, cfg.norm_type,
                              cfg.norm_eps)
        return x, aux
    return lm_logits(params, x, cfg, mesh), aux


def head_matrix(params: Params, cfg: ModelConfig) -> Array:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------

def _layer_cache_init(spec: LayerSpec, cfg: ModelConfig, batch: int,
                      max_len: int, dtype,
                      enc_len: Optional[int] = None) -> Dict[str, Array]:
    if spec.mixer == "attn":
        size = (min(cfg.sliding_window, max_len)
                if spec.sliding_window and cfg.sliding_window else max_len)
        c = attention.init_cache(cfg, batch, size, dtype)
    elif spec.mixer == "mamba":
        c = ssm.init_state(cfg, batch, dtype)
    elif spec.mixer == "mlstm":
        c = xlstm.mlstm_init_state(cfg, batch)
    elif spec.mixer == "slstm":
        c = xlstm.slstm_init_state(cfg, batch)
    else:
        raise ValueError(spec.mixer)
    if cfg.cross_attention and enc_len is not None:
        hd = cfg.resolved_head_dim
        c = dict(c,
                 cross_k=jnp.zeros((batch, enc_len, cfg.num_kv_heads, hd),
                                   dtype),
                 cross_v=jnp.zeros((batch, enc_len, cfg.num_kv_heads, hd),
                                   dtype))
    return c


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
               enc_len: Optional[int] = None) -> Params:
    """Stacked (num_groups, ...) decode cache per pattern position."""
    dtype = dtype or common.dtype_of(cfg.dtype_compute)

    def stack(tree):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(
                a[None], (cfg.num_groups,) + a.shape).copy(), tree)

    return {f"pos{i}": stack(_layer_cache_init(spec, cfg, batch, max_len,
                                               dtype, enc_len))
            for i, spec in enumerate(cfg.pattern)}


def _decode_block(bp: Params, spec: LayerSpec, x: Array, cache, index,
                  cfg: ModelConfig, mesh):
    h = common.apply_norm(bp["norm1"], x, cfg.norm_type, cfg.norm_eps)
    if spec.mixer == "attn":
        h, new_cache = attention.decode(
            bp["mixer"], h, {"k": cache["k"], "v": cache["v"]}, index, cfg,
            mesh, layer_window=spec.sliding_window)
    elif spec.mixer == "mamba":
        h, new_cache = ssm.decode(bp["mixer"], h, cache, cfg, mesh)
    elif spec.mixer == "mlstm":
        h, new_cache = xlstm.mlstm_decode(bp["mixer"], h, cache, cfg, mesh)
    elif spec.mixer == "slstm":
        h, new_cache = xlstm.slstm_decode(bp["mixer"], h, cache, cfg, mesh)
    else:
        raise ValueError(spec.mixer)
    x = x + h
    if "cross" in bp and "cross_k" in cache:
        h = common.apply_norm(bp["norm_cross"], x, cfg.norm_type,
                              cfg.norm_eps)
        h, _ = attention.decode(bp["cross"], h, {}, index, cfg, mesh,
                                layer_window=False,
                                cross_cache=(cache["cross_k"],
                                             cache["cross_v"]))
        x = x + h
        new_cache = dict(new_cache, cross_k=cache["cross_k"],
                         cross_v=cache["cross_v"])
    if spec.ffn != "none":
        h = common.apply_norm(bp["norm2"], x, cfg.norm_type, cfg.norm_eps)
        if spec.ffn == "moe":
            h, _ = moe.moe_apply(bp["ffn"], h, cfg, mesh)
        else:
            h = moe.mlp_apply(bp["ffn"], h, cfg, mesh)
        x = x + h
    return x, new_cache


def decode_step(params: Params, tokens: Array, cache: Params, index: Array,
                cfg: ModelConfig, mesh=None) -> Tuple[Array, Params]:
    """One decode step.  tokens: (B, 1) int32; index: scalar position.

    Returns (logits (B, 1, V), new cache).
    """
    if cfg.pos_embedding == "absolute":
        # Embed manually with the position-`index` sinusoid (the batch
        # path in embed_inputs would add position 0).
        x = jnp.take(params["embed"], tokens, axis=0).astype(
            common.dtype_of(cfg.dtype_compute))
        table = common.sinusoidal_positions(cache_max_len(cache),
                                            cfg.d_model)
        x = x + jax.lax.dynamic_slice_in_dim(
            table, jnp.asarray(index, jnp.int32), 1, axis=0
        )[None].astype(x.dtype)
    else:
        x = embed_inputs(params, tokens, cfg, mesh)
    positions = (jnp.full((3, x.shape[0], 1), index)
                 if cfg.mrope_sections else
                 jnp.full((x.shape[0], 1), index))

    new_cache: Params = {}
    if cfg.layer_mode == "scan" and cfg.num_groups > 1:
        def body(x, slices):
            gp, gc = slices
            caches_out = []
            for i, spec in enumerate(cfg.pattern):
                xi, ci = _decode_block_with_positions(
                    gp[f"pos{i}"], spec, x, gc[f"pos{i}"], index, cfg,
                    mesh, positions)
                x = xi
                caches_out.append(ci)
            return x, {f"pos{i}": c for i, c in enumerate(caches_out)}
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    else:
        new_cache = {f"pos{i}": [] for i in range(len(cfg.pattern))}
        for g in range(cfg.num_groups):
            for i, spec in enumerate(cfg.pattern):
                gp = jax.tree_util.tree_map(
                    lambda a: a[g], params["layers"][f"pos{i}"])
                gc = jax.tree_util.tree_map(lambda a: a[g],
                                            cache[f"pos{i}"])
                x, ci = _decode_block_with_positions(
                    gp, spec, x, gc, index, cfg, mesh, positions)
                new_cache[f"pos{i}"].append(ci)
        new_cache = {
            k: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *v)
            for k, v in new_cache.items()}
    logits = lm_logits(params, x, cfg, mesh)
    return logits, new_cache


def _decode_block_with_positions(bp, spec, x, cache, index, cfg, mesh,
                                 positions):
    # attention.decode derives positions from `index`; recurrent mixers
    # ignore positions entirely.
    del positions
    return _decode_block(bp, spec, x, cache, index, cfg, mesh)


def cache_max_len(cache: Params) -> int:
    for pos in cache.values():
        if "k" in pos:
            return int(pos["k"].shape[2])
    return 1


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def _cache_constrain(x: Array, mesh) -> Array:
    """Shard prefill K/V like the decode cache: KV heads over `model`
    when divisible, else head_dim.  Applied INSIDE the layer stack so the
    scan's stacked cache buffer is sharded (out_shardings alone leaves a
    replicated temp — §Perf-hillclimb pair C)."""
    if mesh is None or getattr(mesh, "empty", True):
        return x
    kv = x.shape[-2]
    size = mesh.shape.get("model", 1)
    if kv % max(size, 1) == 0:
        return rules.constrain(x, mesh, "batch", None, "tensor", None)
    return rules.constrain(x, mesh, "batch", None, None, "tensor")


def _attn_cache_layout(state: Dict[str, Array], spec: LayerSpec,
                       cfg: ModelConfig, seq_len: int,
                       pad_to: Optional[int],
                       mesh=None) -> Dict[str, Array]:
    """Re-lay prefill K/V into the decode cache format.

    Full-attention layers: zero-pad the sequence dim to ``pad_to`` so
    decode has write headroom.  SWA layers: scatter the last ``window``
    entries into ring-buffer slots ``pos % window``.
    """
    if "k" not in state:
        return state
    k = _cache_constrain(state["k"], mesh)
    v = _cache_constrain(state["v"], mesh)
    out = dict(state)
    out["k"], out["v"] = k, v
    if spec.sliding_window and cfg.sliding_window:
        w = cfg.sliding_window
        p0 = max(0, seq_len - w)
        slots = jnp.arange(p0, seq_len) % w
        ring_k = jnp.zeros((k.shape[0], w) + k.shape[2:], k.dtype)
        ring_v = jnp.zeros_like(ring_k)
        out["k"] = ring_k.at[:, slots].set(k[:, p0:])
        out["v"] = ring_v.at[:, slots].set(v[:, p0:])
    elif pad_to is not None and pad_to > seq_len:
        pad = [(0, 0)] * k.ndim
        pad[1] = (0, pad_to - seq_len)
        out["k"] = jnp.pad(k, pad)
        out["v"] = jnp.pad(v, pad)
    return out


def prefill(params: Params, inputs: Array, cfg: ModelConfig, mesh=None,
            encoder_inputs: Optional[Array] = None,
            pad_to: Optional[int] = None) -> Tuple[Array, Params]:
    """Process the prompt; return (last-token logits, decode cache).

    Attention layers keep their K/V re-laid for decode (zero-padded to
    ``pad_to``, or ring-buffer layout for SWA layers); recurrent layers
    keep their final state.
    """
    enc_out = None
    if cfg.is_encdec:
        assert encoder_inputs is not None
        enc_out = encode(params, encoder_inputs, cfg, mesh)
    x = embed_inputs(params, inputs, cfg, mesh)
    positions = (default_positions(inputs, cfg)
                 if cfg.pos_embedding == "rope" else None)

    caches: Params = {f"pos{i}": [] for i in range(len(cfg.pattern))}

    seq_len = inputs.shape[1]

    def group_fn(x, gp):
        states = []
        for i, spec in enumerate(cfg.pattern):
            x, _, st = _apply_block_full(gp[f"pos{i}"], spec, x, cfg, mesh,
                                         positions, jnp.zeros(()),
                                         enc_out=enc_out,
                                         collect_state=True)
            states.append(_attn_cache_layout(st, spec, cfg, seq_len,
                                             pad_to, mesh))
        return x, states

    if cfg.layer_mode == "scan" and cfg.num_groups > 1:
        def body(x, gp):
            x, states = group_fn(x, gp)
            return x, {f"pos{i}": s for i, s in enumerate(states)}
        x, stacked = jax.lax.scan(body, x, params["layers"])
        caches = stacked
    else:
        for g in range(cfg.num_groups):
            gp = jax.tree_util.tree_map(lambda a: a[g], params["layers"])
            x, states = group_fn(x, gp)
            for i, st in enumerate(states):
                caches[f"pos{i}"].append(st)
        caches = {k: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *v)
                  for k, v in caches.items()}
    logits = lm_logits(params, x[:, -1:, :], cfg, mesh)
    return logits, caches
