"""Unified model configuration for the assigned architecture zoo.

One frozen (hashable, jit-static) dataclass describes every architecture:
dense / MoE / SSM / hybrid / VLM / audio enc-dec.  Layer heterogeneity is
expressed as a ``pattern`` of :class:`LayerSpec` entries cycled over the
depth (Jamba: period 8 — one attention layer per 8, MoE every other;
xLSTM: alternating sLSTM/mLSTM), which also fixes the scan-over-layers
grouping: parameters are stacked per pattern position and scanned over
``num_layers / len(pattern)`` groups.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One position in the repeating layer pattern."""

    mixer: str = "attn"     # attn | mamba | mlstm | slstm
    ffn: str = "mlp"        # mlp | moe | none
    sliding_window: bool = False  # this attn layer uses the SWA window


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    arch_type: str = "dense"   # dense | moe | vlm | ssm | hybrid | audio
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 32000
    head_dim: int = 0          # 0 -> d_model // num_heads
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)

    # Attention options
    qk_norm: bool = False
    rope_theta: float = 1.0e4
    rope_fraction: float = 1.0     # partial rotary (stablelm: 0.25)
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w) halves
    sliding_window: int = 0        # window size for SWA layers
    causal: bool = True
    attn_chunk: int = 1024         # q-chunk for blocked attention (unrolled)

    # FFN / MoE
    mlp_activation: str = "swiglu"   # swiglu | gelu
    num_experts: int = 0
    num_experts_per_tok: int = 0
    router_aux_weight: float = 0.01
    moe_impl: str = "dense_grouped"  # dense_grouped | ragged | dense
    # ragged (sort + lax.ragged_dot) is the TPU-target grouped-GEMM
    # path but does not partition under GSPMD today (it replicates
    # the gathered token matrix -> 705 GB/device at qwen3-moe
    # train_4k; EXPERIMENTS.md §Perf-hillclimb).  The GShard einsum
    # dispatch shards cleanly and is the lowering default.
    moe_group_size: int = 4096       # dense_grouped dispatch group
    moe_capacity_factor: float = 1.25

    # SSM (Mamba, SSD formulation — DESIGN.md hardware adaptation)
    ssm_state_dim: int = 128
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256

    # xLSTM
    xlstm_heads: int = 4

    # Encoder-decoder (whisper)
    encoder_layers: int = 0
    cross_attention: bool = False

    # Input modality: "tokens" or "embeddings" (VLM/audio frontend stub)
    input_mode: str = "tokens"
    pos_embedding: str = "rope"    # rope | absolute (whisper)

    # Numerics / execution
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    use_bias: bool = False
    norm_eps: float = 1.0e-6
    dtype_compute: str = "bfloat16"
    dtype_params: str = "float32"
    tie_embeddings: bool = False
    remat: bool = True
    layer_mode: str = "scan"       # scan | unroll (see EXPERIMENTS.md §Dry-run)
    sequence_sharding: bool = True # Megatron-SP residual stream (§Perf)
    logits_softcap: float = 0.0

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def pattern_period(self) -> int:
        return len(self.pattern)

    @property
    def num_groups(self) -> int:
        assert self.num_layers % self.pattern_period == 0, (
            f"{self.name}: num_layers={self.num_layers} not divisible by "
            f"pattern period {self.pattern_period}")
        return self.num_layers // self.pattern_period

    @property
    def is_moe(self) -> bool:
        return any(s.ffn == "moe" for s in self.pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM/hybrid state or sliding window."""
        mixers = {s.mixer for s in self.pattern}
        if mixers - {"attn"}:
            # Any recurrent mixer -> O(1) state; attn layers in hybrids use
            # the SWA cache policy for long contexts.
            return True
        return self.sliding_window > 0

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: <=2 pattern periods, d_model<=512, <=4 experts."""
        small = dict(
            num_layers=self.pattern_period,
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, min(self.num_heads, 4)),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=64 if self.resolved_head_dim >= 64 else
                     self.resolved_head_dim,
            num_experts=min(self.num_experts, 4),
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            # Dropless dispatch at smoke scale: the decode-parity oracle
            # (prefill+decode == forward) only holds exactly when no
            # assignment is dropped, and with e<=4 experts a capacity
            # factor of e makes even the all-tokens-on-one-expert worst
            # case fit (cap = gs*k*e/e = gs*k).
            moe_capacity_factor=4.0,
            encoder_layers=min(self.encoder_layers, 2),
            sliding_window=min(self.sliding_window, 128)
            if self.sliding_window else 0,
            ssm_state_dim=min(self.ssm_state_dim, 16),
            ssm_chunk=64,
            attn_chunk=128,
            mrope_sections=(4, 6, 6) if self.mrope_sections else (),
            dtype_compute="float32",
            dtype_params="float32",
            remat=False,
        )
        # Keep kv divides q heads.
        if small["num_heads"] % small["num_kv_heads"]:
            small["num_kv_heads"] = small["num_heads"]
        small.update(overrides)
        return dataclasses.replace(self, **small)
