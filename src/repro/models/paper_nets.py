"""The paper's two evaluation models (§VI-A.2): a small CNN and an MLP.

CNN: two 5x5 conv layers (10 then 20 channels, each followed by 2x2 max
pool), a 50-unit ReLU fully-connected layer, and a softmax output.
MLP: two fully-connected layers.

Pure-pytree definitions: ``init(key, spec) -> params``,
``apply(params, images) -> logits``.  ``images`` are float32 (B, H, W) in
[0, 1].  Conv via ``jax.lax.conv_general_dilated`` (NCHW).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

Array = jax.Array
Params = Dict[str, Array]


@dataclasses.dataclass(frozen=True)
class PaperNetSpec:
    kind: str = "cnn"          # cnn | mlp
    image_size: int = 28
    num_classes: int = 10
    mlp_hidden: int = 200
    cnn_hidden: int = 50


def _dense_init(key: Array, n_in: int, n_out: int) -> Dict[str, Array]:
    scale = jnp.sqrt(2.0 / n_in)
    return {
        "w": jax.random.normal(key, (n_in, n_out), jnp.float32) * scale,
        "b": jnp.zeros((n_out,), jnp.float32),
    }


def _conv_init(key: Array, c_in: int, c_out: int, hw: int) -> Dict[str, Array]:
    fan_in = c_in * hw * hw
    scale = jnp.sqrt(2.0 / fan_in)
    return {
        "w": jax.random.normal(key, (c_out, c_in, hw, hw),
                               jnp.float32) * scale,
        "b": jnp.zeros((c_out,), jnp.float32),
    }


def _conv(x: Array, p: Dict[str, Array]) -> Array:
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return y + p["b"][None, :, None, None]


def _maxpool2(x: Array) -> Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, window_dimensions=(1, 1, 2, 2),
        window_strides=(1, 1, 2, 2), padding="VALID")


def _cnn_flat_dim(spec: PaperNetSpec) -> int:
    s = spec.image_size
    s = (s - 4) // 2          # conv 5x5 VALID + pool 2
    s = (s - 4) // 2
    return 20 * s * s


def init(key: Array, spec: PaperNetSpec) -> Params:
    if spec.kind == "cnn":
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "conv1": _conv_init(k1, 1, 10, 5),
            "conv2": _conv_init(k2, 10, 20, 5),
            "fc1": _dense_init(k3, _cnn_flat_dim(spec), spec.cnn_hidden),
            "fc2": _dense_init(k4, spec.cnn_hidden, spec.num_classes),
        }
    if spec.kind == "mlp":
        k1, k2 = jax.random.split(key)
        d_in = spec.image_size * spec.image_size
        return {
            "fc1": _dense_init(k1, d_in, spec.mlp_hidden),
            "fc2": _dense_init(k2, spec.mlp_hidden, spec.num_classes),
        }
    raise ValueError(f"unknown paper net kind: {spec.kind!r}")


def apply(params: Params, images: Array, spec: PaperNetSpec) -> Array:
    """images: (B, H, W) float32 -> logits (B, C)."""
    b = images.shape[0]
    if spec.kind == "cnn":
        x = images[:, None, :, :]                       # NCHW
        x = _maxpool2(jax.nn.relu(_conv(x, params["conv1"])))
        x = _maxpool2(jax.nn.relu(_conv(x, params["conv2"])))
        x = x.reshape(b, -1)
        x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
        return x @ params["fc2"]["w"] + params["fc2"]["b"]
    x = images.reshape(b, -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def loss_fn(params: Params, images: Array, labels: Array, mask: Array,
            spec: PaperNetSpec) -> Array:
    """Masked mean softmax cross-entropy (padded-batch safe)."""
    logits = apply(params, images, spec)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom


def accuracy(params: Params, images: Array, labels: Array,
             spec: PaperNetSpec) -> Array:
    logits = apply(params, images, spec)
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels)
                    .astype(jnp.float32))


def num_params(params: Params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
