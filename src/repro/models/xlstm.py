"""xLSTM mixers (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM.

**mLSTM** is a gated linear-attention recurrence with a matrix state per
head::

    C_t = f_t C_{t-1} + i_t v_t k_t^T        (d_v x d_k matrix memory)
    n_t = f_t n_{t-1} + i_t k_t              (normalizer)
    h_t = (C_t q_t) / max(|n_t^T q_t|, stab)

with exponential input gate ``i = exp(i~)`` and sigmoid-in-log-space
forget gate, stabilized by the running magnitude ``m_t`` (paper App. A).
We implement the **chunkwise-parallel form** (like the SSD mixer): within
a chunk all pairwise terms are one masked matmul in log-stabilized space;
across chunks the (C, n, m) state is carried.  The chunk loop is unrolled
for cost-analysis fidelity.  A sequential reference lives in the tests.

**sLSTM** keeps scalar memories with recurrent (block-diagonal per-head)
hidden mixing, which is inherently sequential -> ``lax.scan`` over time.
Its per-step cost is tiny (d^2 recurrences at d_model=768); the roofline
harness applies the documented trip-count correction for this scan.

Both blocks follow the xLSTM residual-block layout with input up-
projection (mLSTM: expand 2x) — matching the assigned ``xlstm-125m``
config where ``d_ff = 0`` (no separate FFN).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import ModelConfig
from repro.sharding import rules

Array = jax.Array
Params = Dict[str, Array]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key: Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    din = 2 * d                       # xLSTM mLSTM block expansion = 2
    nh = cfg.xlstm_heads
    assert din % nh == 0
    dt = common.dtype_of(cfg.dtype_params)
    ks = jax.random.split(key, 8)
    return {
        "wup": common.dense_init(ks[0], (d, din), d, dt),
        "wgate": common.dense_init(ks[1], (d, din), d, dt),
        "wq": common.dense_init(ks[2], (din, din), din, dt),
        "wk": common.dense_init(ks[3], (din, din), din, dt),
        "wv": common.dense_init(ks[4], (din, din), din, dt),
        "wif": common.dense_init(ks[5], (din, 2 * nh), din, jnp.float32),
        "if_bias": jnp.concatenate([jnp.zeros((nh,)),
                                    3.0 * jnp.ones((nh,))]),  # forget ~ open
        "norm": jnp.ones((din,), jnp.float32),
        "wo": common.dense_init(ks[6], (din, d), din, dt),
    }


def _mlstm_chunked(q: Array, k: Array, v: Array, ig: Array, fg: Array,
                   chunk: int,
                   state: Optional[Dict[str, Array]] = None
                   ) -> Tuple[Array, Dict[str, Array]]:
    """Chunkwise mLSTM.

    q,k,v: (B, S, nh, hd); ig, fg: (B, S, nh) raw gate pre-activations.
    state: {"C": (B,nh,hd,hd), "n": (B,nh,hd), "m": (B,nh)}.
    Returns (h (B,S,nh,hd), new state).  Log-space stabilized.
    """
    bsz, s, nh, hd = q.shape
    while s // chunk > 64:   # compile-size guard (see ssm._ssd_chunked)
        chunk *= 2
    if s % chunk:
        chunk = s
    if state is None:
        c_st = jnp.zeros((bsz, nh, hd, hd), jnp.float32)
        n_st = jnp.zeros((bsz, nh, hd), jnp.float32)
        m_st = jnp.full((bsz, nh), -1e30, jnp.float32)
    else:
        c_st, n_st, m_st = (state["C"].astype(jnp.float32),
                            state["n"].astype(jnp.float32),
                            state["m"].astype(jnp.float32))
    logf = jax.nn.log_sigmoid(fg.astype(jnp.float32))     # (B,S,nh)
    scale = hd ** -0.5
    outs = []
    for start in range(0, s, chunk):
        sl = slice(start, start + chunk)
        qc = q[:, sl].astype(jnp.float32) * scale
        kc = k[:, sl].astype(jnp.float32)
        vc = v[:, sl].astype(jnp.float32)
        ic = ig[:, sl].astype(jnp.float32)                # (B,L,nh)
        fc = logf[:, sl]                                  # (B,L,nh)
        cum = jnp.cumsum(fc, axis=1)                      # F_t
        # log weight of source s' at target t: F_t - F_s' + i_s'  (s'<=t)
        lw = (cum[:, :, None, :] - cum[:, None, :, :]
              + ic[:, None, :, :])                        # (B,L,L,nh)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        lw = jnp.where(tri[None, :, :, None], lw, -jnp.inf)
        # inter-chunk log magnitude at t: F_t + m_prev
        inter_lm = cum + m_st[:, None, :]                 # (B,L,nh)
        m_t = jnp.maximum(jnp.max(lw, axis=2), inter_lm)  # (B,L,nh)
        m_t = jnp.maximum(m_t, -1e30)
        w = jnp.exp(lw - m_t[:, :, None, :])              # (B,L,L,nh)
        inter_w = jnp.exp(inter_lm - m_t)                 # (B,L,nh)
        # Scores (q_t . k_s) per head.
        qk = jnp.einsum("blhd,bshd->blsh", qc, kc)        # (B,L,L,nh)
        num_intra = jnp.einsum("blsh,blsh,bshd->blhd", qk, w, vc)
        num_inter = jnp.einsum("blhd,bhde,blh->blhe",
                               qc, c_st.swapaxes(-1, -2), inter_w)
        # normalizer: n_t . q_t
        den_intra = jnp.einsum("blsh,bshd,blhd->blh", w, kc, qc)
        den_inter = jnp.einsum("bhd,blhd,blh->blh", n_st, qc, inter_w)
        den = jnp.abs(den_intra + den_inter)
        den = jnp.maximum(den, jnp.exp(-m_t))
        h = (num_intra + num_inter) / den[..., None]
        outs.append(h)
        # State update to end of chunk.
        f_total = cum[:, -1]                              # (B,nh)
        m_new = jnp.maximum(f_total + m_st,
                            jnp.max(cum[:, -1:, :] - cum + ic, axis=1))
        w_st = jnp.exp(cum[:, -1:, :] - cum + ic - m_new[:, None, :])
        c_st = (jnp.exp(f_total + m_st - m_new)[:, :, None, None] * c_st
                + jnp.einsum("bsh,bshd,bshe->bhde", w_st, vc, kc))
        n_st = (jnp.exp(f_total + m_st - m_new)[:, :, None] * n_st
                + jnp.einsum("bsh,bshd->bhd", w_st, kc))
        m_st = m_new
    h = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return h, {"C": c_st, "n": n_st, "m": m_st}


def mlstm_forward(p: Params, x: Array, cfg: ModelConfig, mesh,
                  return_state: bool = False):
    bsz, s, _ = x.shape
    nh = cfg.xlstm_heads
    dt = x.dtype
    up = x @ p["wup"].astype(dt)
    gate = x @ p["wgate"].astype(dt)
    din = up.shape[-1]
    hd = din // nh
    q = (up @ p["wq"].astype(dt)).reshape(bsz, s, nh, hd)
    k = (up @ p["wk"].astype(dt)).reshape(bsz, s, nh, hd)
    v = (up @ p["wv"].astype(dt)).reshape(bsz, s, nh, hd)
    gif = (up.astype(jnp.float32) @ p["wif"]) + p["if_bias"]
    ig, fg = gif[..., :nh], gif[..., nh:]
    h, st = _mlstm_chunked(q, k, v, ig, fg, cfg.ssm_chunk)
    h = h.reshape(bsz, s, din).astype(dt)
    h = common.rmsnorm(h, p["norm"], cfg.norm_eps)
    h = h * jax.nn.silu(gate)
    out = h @ p["wo"].astype(dt)
    out = rules.residual_constrain(out, mesh, cfg.sequence_sharding)
    return (out, st) if return_state else (out, None)


def mlstm_init_state(cfg: ModelConfig, batch: int) -> Dict[str, Array]:
    din = 2 * cfg.d_model
    nh = cfg.xlstm_heads
    hd = din // nh
    return {"C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, nh, hd), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32)}


def mlstm_decode(p: Params, x: Array, state: Dict[str, Array],
                 cfg: ModelConfig, mesh) -> Tuple[Array, Dict[str, Array]]:
    """Single-token mLSTM step.  x: (B, 1, D)."""
    bsz = x.shape[0]
    nh = cfg.xlstm_heads
    dt = x.dtype
    xt = x[:, 0]
    up = xt @ p["wup"].astype(dt)
    gate = xt @ p["wgate"].astype(dt)
    din = up.shape[-1]
    hd = din // nh
    q = (up @ p["wq"].astype(dt)).reshape(bsz, nh, hd).astype(jnp.float32)
    k = (up @ p["wk"].astype(dt)).reshape(bsz, nh, hd).astype(jnp.float32)
    v = (up @ p["wv"].astype(dt)).reshape(bsz, nh, hd).astype(jnp.float32)
    q = q * hd ** -0.5
    gif = (up.astype(jnp.float32) @ p["wif"]) + p["if_bias"]
    ig, fg = gif[..., :nh], gif[..., nh:]
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + state["m"], ig)
    f_eff = jnp.exp(logf + state["m"] - m_new)
    i_eff = jnp.exp(ig - m_new)
    c_st = (f_eff[:, :, None, None] * state["C"]
            + i_eff[:, :, None, None] * v[..., :, None] * k[..., None, :])
    n_st = f_eff[..., None] * state["n"] + i_eff[..., None] * k
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", n_st, q))
    den = jnp.maximum(den, jnp.exp(-m_new))
    h = jnp.einsum("bhde,bhe->bhd", c_st, q) / den[..., None]
    h = h.reshape(bsz, din).astype(dt)
    h = common.rmsnorm(h, p["norm"], cfg.norm_eps)
    h = h * jax.nn.silu(gate)
    out = (h @ p["wo"].astype(dt))[:, None, :]
    return out, {"C": c_st, "n": n_st, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key: Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    nh = cfg.xlstm_heads
    hd = d // nh
    dt = common.dtype_of(cfg.dtype_params)
    ks = jax.random.split(key, 3)
    return {
        # 4 gates (i, f, z, o) from input ...
        "wx": common.dense_init(ks[0], (d, 4 * d), d, dt),
        # ... and block-diagonal recurrent mixing per head.
        "wr": common.dense_init(ks[1], (nh, hd, 4 * hd), hd, dt),
        "bias": jnp.concatenate([jnp.zeros((d,)), 3.0 * jnp.ones((d,)),
                                 jnp.zeros((2 * d,))]).astype(jnp.float32),
        "norm": jnp.ones((d,), jnp.float32),
        "wo": common.dense_init(ks[2], (d, d), d, dt),
    }


def _slstm_step(p: Params, cfg: ModelConfig, carry, gx_t):
    """carry: (c, n, h, m) each (B, d) float32; gx_t: (B, 4d) input part."""
    c, n, h, m = carry
    d = cfg.d_model
    nh = cfg.xlstm_heads
    hd = d // nh
    hr = h.reshape(h.shape[0], nh, hd)
    gr = jnp.einsum("bhd,hde->bhe", hr,
                    p["wr"].astype(jnp.float32)).reshape(h.shape[0], 4 * d)
    g = gx_t + gr + p["bias"]
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    logf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(logf + m, gi)
    i_eff = jnp.exp(gi - m_new)
    f_eff = jnp.exp(logf + m - m_new)
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    c_new = f_eff * c + i_eff * z
    n_new = f_eff * n + i_eff
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_forward(p: Params, x: Array, cfg: ModelConfig, mesh,
                  return_state: bool = False):
    """Sequential sLSTM over time (lax.scan)."""
    bsz, s, d = x.shape
    gx = (x @ p["wx"].astype(x.dtype)).astype(jnp.float32)   # (B,S,4d)
    carry0 = tuple(jnp.zeros((bsz, d), jnp.float32) for _ in range(3)) + (
        jnp.full((bsz, d), -1e30, jnp.float32),)
    carry0 = (carry0[0], carry0[1], carry0[2], carry0[3])

    def step(carry, gx_t):
        return _slstm_step(p, cfg, carry, gx_t)

    carry, hs = jax.lax.scan(step, carry0, gx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)                    # (B,S,d)
    h = common.rmsnorm(h, p["norm"], cfg.norm_eps)
    out = h @ p["wo"].astype(x.dtype)
    out = rules.residual_constrain(out, mesh, cfg.sequence_sharding)
    if return_state:
        c, n, hh, m = carry
        return out, {"c": c, "n": n, "h": hh, "m": m}
    return out, None


def slstm_init_state(cfg: ModelConfig, batch: int) -> Dict[str, Array]:
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "h": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.full((batch, d), -1e30, jnp.float32)}


def slstm_decode(p: Params, x: Array, state: Dict[str, Array],
                 cfg: ModelConfig, mesh) -> Tuple[Array, Dict[str, Array]]:
    gx = (x[:, 0] @ p["wx"].astype(x.dtype)).astype(jnp.float32)
    carry = (state["c"], state["n"], state["h"], state["m"])
    carry, h = _slstm_step(p, cfg, carry, gx)
    h = common.rmsnorm(h.astype(x.dtype), p["norm"], cfg.norm_eps)
    out = (h @ p["wo"].astype(x.dtype))[:, None, :]
    c, n, hh, m = carry
    return out, {"c": c, "n": n, "h": hh, "m": m}
