"""GQA attention: blocked training/prefill path + cached decode path.

Features driven by :class:`repro.models.config.ModelConfig`:

* grouped-query attention (``num_kv_heads`` <= ``num_heads``)
* per-head q/k RMS normalization (Qwen3 ``qk_norm``)
* RoPE, partial rotary (StableLM), M-RoPE (Qwen2-VL), or none (Whisper
  absolute embeddings are added at the embedding layer)
* sliding-window masking (Mistral/Mixtral/Danube SWA)
* cross-attention (Whisper decoder)

The training/prefill path is **q-chunked**: an *unrolled* Python loop over
query chunks computes scores against the full K/V, so peak score memory is
``(B, H, chunk, S)`` instead of ``(B, H, S, S)`` and — deliberately — no
inner ``lax.scan`` hides FLOPs from ``cost_analysis()`` (see EXPERIMENTS.md
§Dry-run methodology).  The Pallas flash-attention kernel
(``repro.kernels.flash_attention``) is the TPU-target replacement for this
path behind ``use_flash=True`` in ops form.

Decode attends one query token against a (B, S_max, KV, hd) cache written
in-place at ``cache["index"]``.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import ModelConfig
from repro.sharding import rules

Array = jax.Array
Params = Dict[str, Array]

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def init(key: Array, cfg: ModelConfig, cross: bool = False) -> Params:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 6)
    dt = common.dtype_of(cfg.dtype_params)
    p: Params = {
        "wq": common.dense_init(ks[0], (d, h * hd), d, dt),
        "wk": common.dense_init(ks[1], (d, kv * hd), d, dt),
        "wv": common.dense_init(ks[2], (d, kv * hd), d, dt),
        "wo": common.dense_init(ks[3], (h * hd, d), h * hd, dt),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
        p["bo"] = jnp.zeros((d,), dt)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(p: Params, x: Array, kv_src: Array, cfg: ModelConfig,
                 mesh) -> Tuple[Array, Array, Array]:
    """x -> q (B,Sq,H,hd); kv_src -> k, v (B,Skv,KV,hd)."""
    hd = cfg.resolved_head_dim
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = kv_src @ p["wk"].astype(dt)
    v = kv_src @ p["wv"].astype(dt)
    if cfg.use_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = rules.constrain(q, mesh, "batch", None, "tensor")
    k = rules.constrain(k, mesh, "batch", None, "tensor")
    v = rules.constrain(v, mesh, "batch", None, "tensor")
    q = q.reshape(*q.shape[:2], cfg.num_heads, hd)
    k = k.reshape(*k.shape[:2], cfg.num_kv_heads, hd)
    v = v.reshape(*v.shape[:2], cfg.num_kv_heads, hd)
    if cfg.qk_norm and "q_norm" in p:
        q = common.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = common.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _maybe_rope(q: Array, k: Array, positions: Optional[Array],
                cfg: ModelConfig) -> Tuple[Array, Array]:
    if cfg.pos_embedding != "rope" or positions is None:
        return q, k
    hd = cfg.resolved_head_dim
    if cfg.mrope_sections:
        sin, cos = common.mrope_sin_cos(positions, hd, cfg.rope_theta,
                                        cfg.mrope_sections)
    else:
        sin, cos = common.rope_sin_cos(positions, hd, cfg.rope_theta,
                                       cfg.rope_fraction)
    return common.apply_rope(q, sin, cos), common.apply_rope(k, sin, cos)


def _mask_bias(q_pos: Array, k_pos: Array, causal: bool,
               window: int) -> Array:
    """(Sq, Skv) additive mask: 0 where visible, NEG_INF where masked."""
    visible = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        visible &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        visible &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(visible, 0.0, NEG_INF).astype(jnp.float32)


def _repeat_kv(k: Array, num_heads: int, mesh) -> Array:
    """(B, S, KV, hd) -> (B, S, H, hd), head dim sharded over ``model``.

    Explicitly materializing the repeated K/V lets GSPMD shard the score
    tensors over the (full) head dim — without this the GQA reshape
    de-shards the heads and the per-chunk score buffers replicate (41 GB
    temp at qwen3-14b/train_4k; see EXPERIMENTS.md §Perf iteration 0).
    """
    b, s, kvh, hd = k.shape
    if kvh != num_heads:
        k = jnp.repeat(k, num_heads // kvh, axis=2)
    return rules.constrain_pad(k, mesh, "batch", None, "tensor", None)


def _scores_attend(q: Array, k: Array, v: Array, bias: Array) -> Array:
    """q (B,Sq,H,hd), k/v (B,Skv,H,hd), bias (Sq,Skv) -> (B,Sq,H,hd).

    Scores in float32 for numerical stability.
    """
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (hd ** -0.5) + bias[None, None]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


def attend_full(q: Array, k: Array, v: Array, cfg: ModelConfig,
                q_offset: int = 0, causal: Optional[bool] = None,
                window: int = 0, mesh=None) -> Array:
    """Blocked (q-chunked, unrolled) attention.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd).  Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    causal = cfg.causal if causal is None else causal
    q = rules.constrain_pad(q, mesh, "batch", None, "tensor", None)
    k = _repeat_kv(k, h, mesh)
    v = _repeat_kv(v, h, mesh)
    k_pos = jnp.arange(k.shape[1])

    chunk = min(cfg.attn_chunk, sq)
    if sq % chunk:
        chunk = sq  # fallback: single chunk
    outs = []
    for start in range(0, sq, chunk):
        q_pos = q_offset + start + jnp.arange(chunk)
        bias = _mask_bias(q_pos, k_pos, causal, window)
        outs.append(_scores_attend(
            q[:, start:start + chunk], k, v, bias))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out.reshape(b, sq, h, hd)


def forward(p: Params, x: Array, cfg: ModelConfig, mesh,
            positions: Optional[Array], layer_window: bool,
            kv_override: Optional[Tuple[Array, Array]] = None,
            causal: Optional[bool] = None,
            return_kv: bool = False):
    """Training/prefill attention over a full sequence.

    ``kv_override`` supplies precomputed (k, v) for cross-attention.
    Returns (out, (k, v) if return_kv else None).
    """
    kv_src = x if kv_override is None else None
    if kv_override is None:
        q, k, v = _project_qkv(p, x, x, cfg, mesh)
        q, k = _maybe_rope(q, k, positions, cfg)
    else:
        hd = cfg.resolved_head_dim
        dt = x.dtype
        q = x @ p["wq"].astype(dt)
        if cfg.use_bias:
            q = q + p["bq"].astype(dt)
        q = q.reshape(*q.shape[:2], cfg.num_heads, hd)
        k, v = kv_override
        causal = False if causal is None else causal
    del kv_src
    window = cfg.sliding_window if layer_window else 0
    out = attend_full(q, k, v, cfg, causal=causal, window=window,
                      mesh=mesh)
    out = out.reshape(*out.shape[:2], -1)
    out = out @ p["wo"].astype(out.dtype)
    if cfg.use_bias:
        out = out + p["bo"].astype(out.dtype)
    out = rules.residual_constrain(out, mesh, cfg.sequence_sharding)
    return (out, (k, v)) if return_kv else (out, None)


def cross_kv(p: Params, enc_out: Array, cfg: ModelConfig) -> Tuple[Array,
                                                                   Array]:
    """Precompute cross-attention K/V from encoder output (prefill once)."""
    hd = cfg.resolved_head_dim
    dt = enc_out.dtype
    k = enc_out @ p["wk"].astype(dt)
    v = enc_out @ p["wv"].astype(dt)
    if cfg.use_bias:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    k = k.reshape(*k.shape[:2], cfg.num_kv_heads, hd)
    v = v.reshape(*v.shape[:2], cfg.num_kv_heads, hd)
    return k, v


# ---------------------------------------------------------------------------
# Decode (one token, KV cache)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype) -> Dict[str, Array]:
    """Ring-buffer cache.  SWA layers allocate only the window."""
    kv = cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
    }


def decode(p: Params, x: Array, cache: Dict[str, Array], index: Array,
           cfg: ModelConfig, mesh, layer_window: bool,
           cross_cache: Optional[Tuple[Array, Array]] = None
           ) -> Tuple[Array, Dict[str, Array]]:
    """One-token decode.  x: (B, 1, D); ``index`` = absolute position of
    the new token.  For SWA layers the cache is a ring buffer of size
    ``sliding_window``; otherwise size ``max_len`` with positional mask.
    Cross-attention decode attends the full (static) encoder cache.
    """
    if cross_cache is not None:
        hd = cfg.resolved_head_dim
        dt = x.dtype
        q = x @ p["wq"].astype(dt)
        if cfg.use_bias:
            q = q + p["bq"].astype(dt)
        q = q.reshape(x.shape[0], 1, cfg.num_heads, hd)
        k, v = cross_cache
        out = attend_full(q, k, v, cfg, causal=False, window=0,
                          mesh=mesh)
        out = out.reshape(x.shape[0], 1, -1) @ p["wo"].astype(dt)
        if cfg.use_bias:
            out = out + p["bo"].astype(dt)
        return out, cache

    bsz = x.shape[0]
    if cfg.mrope_sections:
        positions = jnp.full((3, bsz, 1), index, jnp.int32)
    else:
        positions = jnp.full((bsz, 1), index, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, x, cfg, mesh)
    q, k_new = _maybe_rope(q, k_new, positions, cfg)

    max_len = cache["k"].shape[1]
    is_ring = bool(layer_window and cfg.sliding_window > 0)
    slot = index % max_len if is_ring else jnp.minimum(index, max_len - 1)
    k = cache["k"].at[:, slot].set(k_new[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[:, slot].set(v_new[:, 0].astype(cache["v"].dtype))

    # Positional validity: entries written so far.
    pos_ids = jnp.arange(max_len)
    if is_ring:
        # Ring buffer: slot p holds absolute position
        # index - ((slot - p) mod max_len); valid if within window & >= 0.
        age = (slot - pos_ids) % max_len
        abs_pos = index - age
        valid = abs_pos >= 0
    else:
        valid = pos_ids <= jnp.minimum(index, max_len - 1)
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)[None, :]

    b, _, h, hd = q.shape
    kr = _repeat_kv(k, h, mesh)
    vr = _repeat_kv(v, h, mesh)
    out = _scores_attend(q, kr, vr, bias)
    out = out.reshape(b, 1, h * hd)
    out = out @ p["wo"].astype(out.dtype)
    if cfg.use_bias:
        out = out + p["bo"].astype(out.dtype)
    return out, {"k": k, "v": v}
