"""Shared model-zoo building blocks: norms, init, rotary embeddings.

Parameters are plain nested dicts of ``jax.Array``; every initializer
takes an explicit key.  Compute dtype casting happens at block entry
(params stay in ``dtype_params``, activations in ``dtype_compute``).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key: Array, shape: Tuple[int, ...], fan_in: int,
               dtype=jnp.float32) -> Array:
    """Truncated-normal with 1/sqrt(fan_in) scale (LeCun-style)."""
    scale = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key: Array, vocab: int, dim: int, dtype=jnp.float32) -> Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32)
            * (dim ** -0.5)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, scale: Array, eps: float) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def layernorm(x: Array, scale: Array, bias: Array, eps: float) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


def norm_init(d: int, norm_type: str) -> dict:
    if norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def apply_norm(p: dict, x: Array, norm_type: str, eps: float) -> Array:
    if norm_type == "rmsnorm":
        return rmsnorm(x, p["scale"], eps)
    return layernorm(x, p["scale"], p["bias"], eps)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE / partial rotary / M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, fraction: float = 1.0) -> Array:
    """Inverse frequencies for the rotated fraction of the head dim."""
    rot = int(head_dim * fraction) // 2 * 2
    exponent = jnp.arange(0, rot, 2, dtype=jnp.float32) / max(rot, 1)
    return 1.0 / (theta ** exponent)        # (rot/2,)


def rope_sin_cos(positions: Array, head_dim: int, theta: float,
                 fraction: float = 1.0) -> Tuple[Array, Array]:
    """positions (..., S) -> sin/cos (..., S, rot/2)."""
    freqs = rope_freqs(head_dim, theta, fraction)
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.sin(angles), jnp.cos(angles)


def mrope_sin_cos(positions: Array, head_dim: int, theta: float,
                  sections: Tuple[int, ...]) -> Tuple[Array, Array]:
    """Qwen2-VL multimodal RoPE (arXiv:2409.12191).

    ``positions``: (3, B, S) — temporal / height / width position ids
    (equal for pure text).  ``sections`` split the rot/2 frequency slots
    among the three axes (Qwen2-VL: (16, 24, 24) for head_dim 128).
    Returns sin/cos of shape (B, S, rot/2).
    """
    assert positions.shape[0] == len(sections) == 3
    freqs = rope_freqs(head_dim, theta, 1.0)    # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (3,B,S,hd/2)
    # Select which axis drives each frequency slot.
    sect_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections),
        total_repeat_length=freqs.shape[0])     # (hd/2,)
    gathered = jnp.take_along_axis(
        angles, sect_id[None, None, None, :].astype(jnp.int32),
        axis=0)[0]                               # (B,S,hd/2)
    return jnp.sin(gathered), jnp.cos(gathered)


def apply_rope(x: Array, sin: Array, cos: Array) -> Array:
    """x: (B, S, H, hd); sin/cos: (B, S, rot/2).  Rotates the first
    ``2*rot/2`` channels (partial rotary leaves the tail untouched)."""
    rot2 = sin.shape[-1]
    x_rot, x_pass = x[..., :2 * rot2], x[..., 2 * rot2:]
    x1 = x_rot[..., 0::2]
    x2 = x_rot[..., 1::2]
    s = sin[..., None, :].astype(jnp.float32)
    c = cos[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = x1f * c - x2f * s
    o2 = x2f * c + x1f * s
    out = jnp.stack([o1, o2], axis=-1).reshape(x_rot.shape).astype(x.dtype)
    return jnp.concatenate([out, x_pass], axis=-1) if x_pass.shape[-1] \
        else out


def sinusoidal_positions(length: int, dim: int) -> Array:
    """Whisper-style absolute sinusoidal embeddings (length, dim)."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    angles = jnp.arange(length)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)
