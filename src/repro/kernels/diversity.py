"""Fused label-histogram -> diversity-measures kernel (paper Eq. 2/3).

Per device (FL client), compute the class histogram of its (masked) label
vector and reduce it to the two classification diversity measures in one
pass: Gini-Simpson ``1 - sum p^2`` and Shannon entropy ``-sum p log2 p``.

TPU mapping: grid over clients; each program holds one client's (N,)
labels + mask in VMEM, builds the (C,) histogram via an iota-compare
matmul-free reduction (C <= 64 classes broadcast against the label row),
then emits ``(gini, shannon, total)``.  N tiles of 8k labels x 4 B = 32 KB
VMEM — tiny; the win is fusing histogram+entropy so labels are read once
from HBM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _diversity_kernel(labels_ref, mask_ref, out_ref, *, num_classes: int):
    labels = labels_ref[...]                       # (1, N) int32
    mask = mask_ref[...].astype(jnp.float32)       # (1, N)
    classes = jax.lax.broadcasted_iota(jnp.int32, (num_classes, 1), 0)
    onehot = (labels == classes).astype(jnp.float32)      # (C, N)
    hist = jnp.sum(onehot * mask, axis=1)                 # (C,)
    total = jnp.sum(hist)
    p = hist / jnp.maximum(total, 1.0)
    gini = 1.0 - jnp.sum(p * p)
    logp = jnp.where(p > 0.0, jnp.log2(jnp.maximum(p, 1e-30)), 0.0)
    shannon = -jnp.sum(p * logp)
    out_ref[...] = jnp.stack([gini, shannon, total])[None, :]


def diversity_kernel(labels: jax.Array, mask: jax.Array, num_classes: int,
                     interpret: bool = True) -> jax.Array:
    """labels/mask: (K, N) -> (K, 3) [gini, shannon, count]."""
    k, n = labels.shape
    import functools
    return pl.pallas_call(
        functools.partial(_diversity_kernel, num_classes=num_classes),
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, 3), jnp.float32),
        interpret=interpret,
    )(labels, mask)
