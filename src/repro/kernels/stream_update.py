"""Fused streaming-data refresh kernel (DESIGN.md §7).

One FEEL round's data evolution for every device in one launch: apply
the round's count deltas to the ``(K, C)`` class-count matrix (clamped
at zero — evictions are negative deltas), optionally rescale devices
that overflow their buffer cap, recompute both classification diversity
measures (Gini-Simpson, Shannon) plus the sample count, and advance the
staleness carry ``stale' = [selected ? 0 : decay * stale] + arrivals``.
The un-fused path reads the count matrix three times (accumulate,
normalize, entropy) through HBM; here each scenario's ``(K, C)`` block
is loaded into VMEM once and every derived statistic falls out of the
same residency.

TPU mapping: grid over the scenario axis S (the vmapped FEEL driver's
lane); each program owns one scenario — ``(K, C)`` count and delta
blocks plus ``(K,)`` staleness/selection rows.  At paper scale
(K = 100, C = 10) that is a few KB of VMEM; the per-element work is
VPU-only (multiply/accumulate plus one ``log2`` per class), so the
kernel is bandwidth-bound and fusing removes the two extra round trips.
Validated against the pure-jnp oracle ``kernels/ref.py::stream_update``
in interpret mode (CPU), like the diversity/fedavg/sub2 kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stream_update_kernel(h_ref, d_ref, arr_ref, stale_ref, sel_ref,
                          h_out, stats_out, stale_out, *,
                          decay: float, size_cap: float):
    h0 = h_ref[0]                                   # (K, C)
    d = d_ref[0]                                    # (K, C)
    arrivals = arr_ref[0]                           # (K,)
    stale = stale_ref[0]                            # (K,)
    sel = sel_ref[0]                                # (K,)
    h = jnp.maximum(h0 + d, 0.0)
    if size_cap > 0.0:
        total = jnp.sum(h, axis=-1, keepdims=True)
        scale = jnp.where(total > size_cap,
                          size_cap / jnp.maximum(total, 1.0), 1.0)
        h = h * scale
    sizes = jnp.sum(h, axis=-1)
    p = h / jnp.maximum(sizes[:, None], 1.0)
    gini = 1.0 - jnp.sum(p * p, axis=-1)
    logp = jnp.where(p > 0.0, jnp.log2(jnp.maximum(p, 1e-30)), 0.0)
    shannon = -jnp.sum(p * logp, axis=-1)
    h_out[...] = h[None]
    stats_out[...] = jnp.stack([gini, shannon, sizes], axis=-1)[None]
    stale_out[...] = (jnp.where(sel > 0.0, 0.0, decay * stale)
                      + arrivals)[None]


def stream_update_kernel(hists: jax.Array, deltas: jax.Array,
                         arrivals: jax.Array, staleness: jax.Array,
                         selected: jax.Array, *,
                         decay: float, size_cap: float = 0.0,
                         interpret: bool = True
                         ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Batched fused refresh: ``(S, K, C)`` counts/deltas + ``(S, K)``
    arrivals/staleness/selection -> ``((S, K, C) counts, (S, K, 3)
    stats, (S, K) staleness)``.  Stats pack ``[gini, shannon, size]``
    like the ``diversity`` kernel.  See
    ``kernels/ref.py::stream_update`` for the exact contract."""
    s, k, c = hists.shape
    if deltas.shape != (s, k, c):
        raise ValueError(f"deltas must be {(s, k, c)}, got {deltas.shape}")
    for name, a in (("arrivals", arrivals), ("staleness", staleness),
                    ("selected", selected)):
        if a.shape != (s, k):
            raise ValueError(f"{name} must be {(s, k)}, got {a.shape}")
    kern = functools.partial(_stream_update_kernel, decay=decay,
                             size_cap=size_cap)
    mat = pl.BlockSpec((1, k, c), lambda i: (i, 0, 0))
    row = pl.BlockSpec((1, k), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(s,),
        in_specs=[mat, mat, row, row, row],
        out_specs=[mat, pl.BlockSpec((1, k, 3), lambda i: (i, 0, 0)), row],
        out_shape=[jax.ShapeDtypeStruct((s, k, c), jnp.float32),
                   jax.ShapeDtypeStruct((s, k, 3), jnp.float32),
                   jax.ShapeDtypeStruct((s, k), jnp.float32)],
        interpret=interpret,
    )(hists, deltas, arrivals, staleness, selected)
