"""Jit'd public wrappers around the Pallas kernels.

Handles padding to block multiples, layout (B, S, H, hd) <-> kernel
(BH, S, hd), GQA head expansion, and the interpret-mode switch (True off
TPU so the kernels validate on CPU; on real TPU backends pass
``interpret=False``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import compress as _compress
from repro.kernels import diversity as _div
from repro.kernels import fedavg_agg as _agg
from repro.kernels import flash_attention as _fa
from repro.kernels import stream_update as _stream
from repro.kernels import sub2_pgd as _pgd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, multiple: int):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def fedavg_agg(updates: jax.Array, weights: jax.Array,
               block_p: int = _agg.DEFAULT_BLOCK_P,
               interpret: bool | None = None) -> jax.Array:
    """FedAvg weighted aggregation: (K, P) x (K,) -> (P,)."""
    interpret = _default_interpret() if interpret is None else interpret
    k, p = updates.shape
    bp = min(block_p, max(128, 1 << (p - 1).bit_length()))
    padded, pad = _pad_to(updates, 1, bp)
    out = _agg.fedavg_agg_kernel(padded, weights, block_p=bp,
                                 interpret=interpret)
    return out[:p] if pad else out


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def fedavg_agg_masked(updates: jax.Array, weights: jax.Array,
                      mask: jax.Array,
                      block_p: int = _agg.DEFAULT_BLOCK_P,
                      interpret: bool | None = None) -> jax.Array:
    """Success-masked FedAvg aggregation: (K, P) x (K,) x (K,) -> (P,).

    The fault subsystem's degraded-aggregation lane (DESIGN.md §10):
    same padding/tiling as :func:`fedavg_agg`, with the upload-success
    mask folded into the weights inside the kernel.  No internal
    renormalization — an all-ones mask is bitwise the unmasked kernel.
    """
    interpret = _default_interpret() if interpret is None else interpret
    k, p = updates.shape
    bp = min(block_p, max(128, 1 << (p - 1).bit_length()))
    padded, pad = _pad_to(updates, 1, bp)
    out = _agg.fedavg_agg_masked_kernel(padded, weights, mask, block_p=bp,
                                        interpret=interpret)
    return out[:p] if pad else out


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def fedavg_agg_stale(updates: jax.Array, weights: jax.Array,
                     mask: jax.Array, stale_w: jax.Array,
                     block_p: int = _agg.DEFAULT_BLOCK_P,
                     interpret: bool | None = None) -> jax.Array:
    """Staleness-weighted masked FedAvg: (K, P) x (K,) x3 -> (P,).

    The event subsystem's buffered-flush lane (DESIGN.md §12): the
    masked aggregation with each update additionally discounted by its
    model-version staleness multiplier ``stale_w``.  Same padding and
    tiling as :func:`fedavg_agg_masked`; an all-ones ``stale_w`` is
    bitwise the masked kernel (synchronous-limit contract).
    """
    interpret = _default_interpret() if interpret is None else interpret
    k, p = updates.shape
    bp = min(block_p, max(128, 1 << (p - 1).bit_length()))
    padded, pad = _pad_to(updates, 1, bp)
    out = _agg.fedavg_agg_stale_kernel(padded, weights, mask, stale_w,
                                       block_p=bp, interpret=interpret)
    return out[:p] if pad else out


# Test/observability hook: counts how many times the batched-lane vmap
# rule below was traced.  A vmap of the single-instance `sub2_pgd` entry
# (the batched FEEL driver) is wired straight onto the kernel's (S, K)
# grid through jax.custom_batching — this counter is how tests assert
# the direct lane, not Pallas's generic batching rule, handled the map.
BATCHED_LANE_TRACES = 0


@functools.lru_cache(maxsize=32)
def _sub2_pgd_entry(rho: float, lr: float, tau: float, iters: int,
                    bandwidth_hz: float, min_alpha: float,
                    proj_iters: int, interpret: bool):
    """Single-instance kernel entry with a custom vmap rule.

    The plain path launches the kernel with a length-1 grid.  Under
    ``jax.vmap`` (one level — the scenario axis of
    ``federated.run_federated_batch``), the custom rule broadcasts any
    unbatched operands and launches the batched ``(S, K)`` grid
    directly, so the scenario axis maps 1:1 onto kernel grid steps
    instead of being reconstructed by the generic pallas batching rule.
    Cached per static-parameter tuple so repeat solves reuse one
    custom-vmap object (and jax's trace cache).  Payload bits ride as a
    ``(K,)`` operand row (not a static), so per-device compressed
    payloads keep this fused lane.
    """
    kern = functools.partial(
        _pgd.sub2_pgd_kernel, rho=rho, lr=lr, tau=tau, iters=iters,
        bandwidth_hz=bandwidth_hz, min_alpha=min_alpha,
        proj_iters=proj_iters, interpret=interpret)

    @jax.custom_batching.custom_vmap
    def single(selected, t_train, c, tx_power, bits, alpha0):
        alpha, obj = kern(selected[None], t_train[None], c[None],
                          tx_power[None], bits[None], alpha0[None])
        return alpha[0], obj[0]

    @single.def_vmap
    def _batched_lane(axis_size, in_batched, selected, t_train, c,
                      tx_power, bits, alpha0):
        global BATCHED_LANE_TRACES
        BATCHED_LANE_TRACES += 1
        args = [x if b else jnp.broadcast_to(x, (axis_size,) + x.shape)
                for x, b in zip((selected, t_train, c, tx_power, bits,
                                 alpha0), in_batched)]
        alpha, obj = kern(*args)
        return (alpha, obj), (True, True)

    return single


def sub2_pgd(selected: jax.Array, t_train: jax.Array, gains: jax.Array,
             tx_power: jax.Array, alpha0: jax.Array, *, rho: float,
             lr: float, tau: float, iters: int, bandwidth_hz: float,
             noise_psd: float, model_bits, min_alpha: float,
             proj_iters: int = _pgd.DEFAULT_PROJ_ITERS,
             interpret: bool | None = None
             ) -> tuple[jax.Array, jax.Array]:
    """Fused Sub2 PGD solve: whole descent in one kernel launch.

    Single instance: ``selected``/``t_train``/``gains``/``tx_power`` of
    (K,) with ``alpha0`` (2, K) -> ((K,) alpha, () objective).  Batched
    scenario lane: (S, K) rows with ``alpha0`` (S, 2, K) -> ((S, K),
    (S,)).  ``alpha0`` stacks the two starting points (water-filling, uniform); gains/power fold into the SNR coefficient
    c = g*P/(B*N0) here so the kernel sees one coefficient row.

    ``model_bits`` may be a Python/0-d scalar (nominal model size) or a
    per-device ``(K,)`` / ``(S, K)`` payload-bits array (compressed
    uplinks, DESIGN.md §9) — either way it is materialized to a bits
    row and fed to the kernel as an operand, so the fused lane survives
    per-device payloads.

    The single-instance entry carries a custom vmap rule: a ``vmap``
    over it (the batched FEEL driver) launches the (S, K) kernel grid
    directly (see :func:`_sub2_pgd_entry`).
    """
    interpret = _default_interpret() if interpret is None else interpret
    c = gains * tx_power / (bandwidth_hz * noise_psd)
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    bits = jnp.broadcast_to(f32(model_bits), selected.shape)
    args = (f32(selected), f32(t_train), f32(c), f32(tx_power), bits,
            f32(alpha0))
    if selected.ndim == 2:
        return _pgd.sub2_pgd_kernel(
            *args, rho=rho, lr=lr, tau=tau, iters=iters,
            bandwidth_hz=bandwidth_hz, min_alpha=min_alpha,
            proj_iters=proj_iters, interpret=interpret)
    entry = _sub2_pgd_entry(rho, lr, tau, iters, bandwidth_hz,
                            min_alpha, proj_iters, interpret)
    return entry(*args)


# Observability hook mirroring BATCHED_LANE_TRACES: counts traces of the
# compress kernel's direct batched-vmap lane (tests assert the scenario
# vmap hit the (S,)-grid launch, not pallas's generic batching rule).
COMPRESS_LANE_TRACES = 0


@functools.lru_cache(maxsize=32)
def _compress_entry(mode: str, keep: int, thresh_iters: int,
                    interpret: bool):
    """Single-instance compress entry with a custom vmap rule.

    The plain path launches the kernel with a length-1 grid.  Under
    ``jax.vmap`` (the scenario axis of ``federated.run_federated_batch``)
    the custom rule broadcasts any unbatched operands and launches the
    batched ``(S,)`` grid directly — same pattern as
    :func:`_sub2_pgd_entry`.
    """
    kern = functools.partial(_compress.compress_update_kernel, mode=mode,
                             keep=keep, thresh_iters=thresh_iters,
                             interpret=interpret)

    @jax.custom_batching.custom_vmap
    def single(updates, residual, widths, selected, noise):
        c, r = kern(updates[None], residual[None], widths[None],
                    selected[None], noise[None])
        return c[0], r[0]

    @single.def_vmap
    def _batched_lane(axis_size, in_batched, updates, residual, widths,
                      selected, noise):
        global COMPRESS_LANE_TRACES
        COMPRESS_LANE_TRACES += 1
        args = [x if b else jnp.broadcast_to(x, (axis_size,) + x.shape)
                for x, b in zip((updates, residual, widths, selected,
                                 noise), in_batched)]
        c, r = kern(*args)
        return (c, r), (True, True)

    return single


def compress_update(updates: jax.Array, residual: jax.Array,
                    widths: jax.Array, selected: jax.Array,
                    noise: jax.Array, *, mode: str, keep: int = 0,
                    thresh_iters: int = _compress.DEFAULT_THRESH_ITERS,
                    interpret: bool | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """Fused uplink compression: residual accumulate -> quantize/top-k
    -> dequantize-for-FedAvg in one launch.

    Single instance: ``(K, P)`` updates/residual/noise + ``(K,)``
    widths/selection -> ``((K, P) decoded, (K, P) residual)``.  Batched
    scenario lane: ``(S, K, P)`` / ``(S, K)`` — the grid runs over S.
    The single-instance entry carries a custom vmap rule so the vmapped
    FEEL driver lands on the batched grid directly
    (:func:`_compress_entry`).  Exact contract in
    ``kernels/ref.py::compress_update``.  Not jitted here: the caller
    is the FEEL round body, which is already tracing.
    """
    interpret = _default_interpret() if interpret is None else interpret
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    args = (f32(updates), f32(residual), f32(widths), f32(selected),
            f32(noise))
    if updates.ndim == 3:
        return _compress.compress_update_kernel(
            *args, mode=mode, keep=keep, thresh_iters=thresh_iters,
            interpret=interpret)
    entry = _compress_entry(mode, keep, thresh_iters, interpret)
    return entry(*args)


@functools.partial(jax.jit, static_argnames=("num_classes", "interpret"))
def diversity_stats(labels: jax.Array, mask: jax.Array, num_classes: int,
                    interpret: bool | None = None) -> jax.Array:
    """(K, N) labels/mask -> (K, 3) [gini-simpson, shannon, count]."""
    interpret = _default_interpret() if interpret is None else interpret
    return _div.diversity_kernel(labels, mask, num_classes,
                                 interpret=interpret)


def stream_update(hists: jax.Array, deltas: jax.Array,
                  arrivals: jax.Array, staleness: jax.Array,
                  selected: jax.Array, *,
                  decay: float, size_cap: float = 0.0,
                  interpret: bool | None = None
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused streaming refresh (one round of in-scan data evolution).

    Count-delta accumulation -> Gini/Shannon/size refresh -> staleness
    decay in one launch (``kernels/stream_update.py``; exact contract in
    ``kernels/ref.py::stream_update``).  Single instance: ``(K, C)``
    counts/deltas + ``(K,)`` arrivals/staleness/selection.  Batched
    scenario lane: ``(S, K, C)`` / ``(S, K)`` — the grid runs over S.
    Not jitted here: the caller is the FEEL round body, which is
    already tracing.
    """
    interpret = _default_interpret() if interpret is None else interpret
    batched = hists.ndim == 3
    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    hists, deltas, arrivals, staleness, selected = (
        f32(hists), f32(deltas), f32(arrivals), f32(staleness),
        f32(selected))
    if not batched:
        hists, deltas, arrivals, staleness, selected = (
            x[None] for x in (hists, deltas, arrivals, staleness,
                              selected))
    h, stats, stale = _stream.stream_update_kernel(
        hists, deltas, arrivals, staleness, selected, decay=decay,
        size_cap=size_cap, interpret=interpret)
    if not batched:
        return h[0], stats[0], stale[0]
    return h, stats, stale


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """Batched GQA flash attention.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) -> (B, Sq, H, hd).
    Sequences are zero-padded to block multiples; the causal mask plus the
    `k_pos < seq_len` guard inside the kernel keeps padding inert.
    """
    interpret = _default_interpret() if interpret is None else interpret
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    if kv != h:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, v.shape[1], hd)
    bq = min(block_q, sq)
    bk = min(block_k, kf.shape[1])
    kv_len = kf.shape[1]
    qf, qpad = _pad_to(qf, 1, bq)
    kf, _ = _pad_to(kf, 1, bk)
    vf, _ = _pad_to(vf, 1, bk)
    out = _fa.flash_attention_kernel(qf, kf, vf, causal=causal,
                                     window=window, block_q=bq, block_k=bk,
                                     kv_len=kv_len, interpret=interpret)
    out = out[:, :sq]
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
