"""Fused uplink-compression kernel (DESIGN.md §9).

One FEEL round's lossy uplink for every device in one launch: accumulate
the error-feedback residual onto the raw model updates (``v = u + r``),
compress each device's ``(P,)`` update row — stochastic b-bit
quantization or magnitude top-k sparsification — immediately dequantize
(the server aggregates *values*, so the decode is part of the round),
and advance the residual carry ``r' = selected ? v - c : r``.  The
un-fused path streams the ``(K, P)`` update matrix through HBM four
times (accumulate, row-max/threshold, quantize, residual); here each
scenario's block is loaded into VMEM once and every derived quantity
falls out of the same residency.

TPU mapping: grid over the scenario axis S (the vmapped FEEL driver's
lane); each program owns one scenario — ``(K, P)`` update / residual
blocks plus ``(K,)`` width and selection rows (quant additionally
streams a ``(K, P)`` noise block; topk takes a ``(K,)`` placeholder row
instead — it never reads noise, and a dead full block would cost real
VMEM traffic).  At paper scale
(K = 100, P ~ 12.7k MLP coordinates) that is ~25 MB of f32 blocks —
fine for the interpret-mode validation path this repo runs on CPU, but
a real-TPU launch at production P needs a P-blocked variant carrying
the row max / threshold in SMEM across P-tiles (ROADMAP open item).
The per-element work is VPU-only (abs/floor/compare), so the kernel is
bandwidth-bound and fusing removes the three extra round trips.

Quantization is *stochastically rounded*: the caller supplies the
uniform ``noise`` block (drawn with ``jax.random`` outside the launch),
so the kernel stays deterministic per input and bit-for-bit equal to
the pure-jnp oracle ``kernels/ref.py::compress_update`` — the same
pattern every kernel in this repo uses for its property tests.  Top-k
selects by a fixed-trip threshold bisection on ``count(|v| >= t)``
(monotone in ``t``) rather than a sort — sorts don't lower inside TPU
Pallas (see the Duchi projection note in DESIGN.md §6); float ties at
the threshold can keep marginally fewer/more than ``keep`` entries,
identically in kernel and oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MODES = ("quant", "topk")
DEFAULT_THRESH_ITERS = 32


def _compress_update_kernel(u_ref, r_ref, w_ref, sel_ref, n_ref,
                            c_out, r_out, *, mode: str, keep: int,
                            thresh_iters: int):
    u = u_ref[0]                                    # (K, P)
    r = r_ref[0]                                    # (K, P)
    widths = w_ref[0]                               # (K,)
    sel = sel_ref[0]                                # (K,)
    v = u + r                                       # residual accumulate
    av = jnp.abs(v)
    if mode == "quant":
        noise = n_ref[0]                            # (K, P)
        m = jnp.max(av, axis=-1, keepdims=True)     # per-device scale
        levels = jnp.maximum(jnp.exp2(widths[:, None]) - 1.0, 1.0)
        scaled = av / jnp.maximum(m, 1e-12) * levels
        fl = jnp.floor(scaled)
        q = fl + (noise < (scaled - fl)).astype(jnp.float32)
        c = jnp.sign(v) * q / levels * m
    else:                                           # topk
        lo = jnp.zeros(av.shape[:-1] + (1,), jnp.float32)
        hi = jnp.max(av, axis=-1, keepdims=True)

        def body(_, lohi):
            tlo, thi = lohi
            mid = 0.5 * (tlo + thi)
            cnt = jnp.sum((av >= mid).astype(jnp.float32), axis=-1,
                          keepdims=True)
            over = cnt > keep
            return jnp.where(over, mid, tlo), jnp.where(over, thi, mid)

        lo, hi = jax.lax.fori_loop(0, thresh_iters, body, (lo, hi))
        c = jnp.where(av >= hi, v, 0.0)
    c_out[...] = c[None]
    r_out[...] = jnp.where(sel[:, None] > 0.0, v - c, r)[None]


def compress_update_kernel(updates: jax.Array, residual: jax.Array,
                           widths: jax.Array, selected: jax.Array,
                           noise: jax.Array, *, mode: str, keep: int = 0,
                           thresh_iters: int = DEFAULT_THRESH_ITERS,
                           interpret: bool = True
                           ) -> tuple[jax.Array, jax.Array]:
    """Batched fused compress: ``(S, K, P)`` updates/residual/noise +
    ``(S, K)`` widths/selection -> ``((S, K, P) decoded values,
    (S, K, P) new residual)``.  ``mode`` picks stochastic ``widths``-bit
    quantization or magnitude top-``keep`` sparsification.  See
    ``kernels/ref.py::compress_update`` for the exact contract."""
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    s, k, p = updates.shape
    for name, a, want in (("residual", residual, (s, k, p)),
                          ("widths", widths, (s, k)),
                          ("selected", selected, (s, k))):
        if a.shape != want:
            raise ValueError(f"{name} must be {want}, got {a.shape}")
    # quant consumes per-coordinate noise; topk never reads it, so a
    # (S, K) placeholder row avoids streaming a dead (K, P) block into
    # the launch (a full block is still accepted for oracle sweeps).
    if mode == "quant" and noise.shape != (s, k, p):
        raise ValueError(f"noise must be {(s, k, p)}, got {noise.shape}")
    if noise.shape not in ((s, k, p), (s, k)):
        raise ValueError(f"noise must be {(s, k, p)} or {(s, k)}, got "
                         f"{noise.shape}")
    if mode == "topk" and not (0 < keep <= p):
        raise ValueError(f"topk keep must be in (0, {p}], got {keep}")
    kern = functools.partial(_compress_update_kernel, mode=mode,
                             keep=keep, thresh_iters=thresh_iters)
    mat = pl.BlockSpec((1, k, p), lambda i: (i, 0, 0))
    row = pl.BlockSpec((1, k), lambda i: (i, 0))
    noise_spec = mat if noise.ndim == 3 else row
    return pl.pallas_call(
        kern,
        grid=(s,),
        in_specs=[mat, mat, row, row, noise_spec],
        out_specs=[mat, mat],
        out_shape=[jax.ShapeDtypeStruct((s, k, p), jnp.float32),
                   jax.ShapeDtypeStruct((s, k, p), jnp.float32)],
        interpret=interpret,
    )(updates, residual, widths, selected, noise)
