"""Fused Sub2 projected-gradient kernel (paper Eq. 15 inner solve).

One Pallas launch runs the *entire* PGD descent for a bandwidth
allocation instance: analytic gradient of the smoothed objective ->
tangent projection (mean removal on the simplex) -> normalized
cosine-decayed step -> Duchi simplex projection -> exact-objective best
tracking, iterated ``pgd_iters`` times over two starting points, all
without leaving VMEM.  The un-fused path materializes every step's
intermediates through HBM; here the (K,) problem state lives in
registers/VMEM for the whole descent.

TPU mapping: grid over the scenario axis S; each program owns one
instance — mask/t_train/SNR-coefficient/power/payload-bits rows of (K,)
plus a (2, K)
block of starting points (water-filling, uniform).  K <= 1024 devices x a handful of (2, K) f32 temps is a few
KB of VMEM — the kernel is compute-bound on the VPU transcendentals
(log1p per rate eval), which is exactly what fusing is for.  The simplex
projection uses a fixed-trip theta-bisection (sum(max(v - theta, 0)) = 1
is monotone in theta) rather than a sort — sorts don't lower inside TPU
Pallas, and 32 halvings put theta well below float32 resolution.

The batched (S, K) lane is the vmapped scenario driver's shape; the
single-instance (K,) entry in ``kernels/ops.py`` adds the leading axis.
Validated against the pure-jnp oracle ``kernels/ref.py::sub2_pgd`` in
interpret mode (CPU), like the diversity/fedavg kernels.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_STARTS = 2          # water-filling + (warm start | uniform)
DEFAULT_PROJ_ITERS = 32


def _sub2_pgd_kernel(sel_ref, tt_ref, c_ref, pw_ref, bits_ref, a0_ref,
                     alpha_ref, obj_ref, *, rho: float, lr: float,
                     tau: float, iters: int, bandwidth_hz: float,
                     min_alpha: float, proj_iters: int):
    mask = sel_ref[0]                                  # (K,)
    tt = tt_ref[0]
    c = c_ref[0]
    pw = pw_ref[0]
    bits = bits_ref[0]                                 # (K,) payload bits
    a0 = a0_ref[0]                                     # (N_STARTS, K)
    n_act = jnp.maximum(jnp.sum(mask), 1.0)
    any_act = jnp.sum(mask) > 0.5
    scale = bandwidth_hz / math.log(2.0)

    def upload(av):
        """t_up for selected devices (alpha floored), 0 for unselected."""
        ae = jnp.maximum(av, min_alpha)
        rate = scale * ae * jnp.log1p(c / ae)
        return jnp.where(mask > 0.0,
                         bits / jnp.maximum(rate, 1e-12), 0.0)

    def exact_obj(av):                                 # (n, K) -> (n,)
        tu = upload(av)
        tot = jnp.where(mask > 0.0, tt + tu, 0.0)
        return (rho * jnp.sum(pw * tu, axis=-1)
                + (1.0 - rho) * jnp.max(tot, axis=-1))

    def tangent_grad(av):
        """Mean-removed gradient of the logsumexp-smoothed objective.

        Mirrors ``bandwidth.sub2_objective(smooth_tau=tau)`` under
        ``jax.grad``: unselected coords enter the softmax with total 0
        (they sit in the reference logsumexp too) and the result is
        masked to the selected set.
        """
        ae = jnp.maximum(av, min_alpha)
        l = jnp.log1p(c / ae)
        rate = jnp.maximum(scale * ae * l, 1e-12)
        slope = scale * (l - c / (ae + c))
        tu = jnp.where(mask > 0.0, bits / rate, 0.0)
        dtu = -bits * slope / (rate * rate)
        tot = jnp.where(mask > 0.0, tt + tu, 0.0)
        w = jax.nn.softmax(tot / tau, axis=-1)
        g = (rho * pw + (1.0 - rho) * w) * dtu * mask
        return (g - jnp.sum(g, axis=-1, keepdims=True) / n_act) * mask

    def project(v):
        """Rows of v onto {a >= 0, sum a = 1, a_i = 0 off-mask}.

        Theta-bisection form of the Duchi projection: the unique theta
        with sum(max(v - theta, 0)) = 1 over active coords.  Bracket:
        at min(v) - 1 every active term is >= 1 (sum >= n_act >= 1); at
        max(v) the sum is 0.
        """
        vm = jnp.where(mask > 0.0, v, 0.0)
        act = mask > 0.0
        lo = jnp.min(jnp.where(act, vm, jnp.inf), axis=-1,
                     keepdims=True) - 1.0
        hi = jnp.max(jnp.where(act, vm, -jnp.inf), axis=-1, keepdims=True)

        def pbody(_, lohi):
            plo, phi = lohi
            mid = 0.5 * (plo + phi)
            s = jnp.sum(jnp.where(act, jnp.maximum(vm - mid, 0.0), 0.0),
                        axis=-1, keepdims=True)
            over = s >= 1.0
            return jnp.where(over, mid, plo), jnp.where(over, phi, mid)

        lo, hi = jax.lax.fori_loop(0, proj_iters, pbody, (lo, hi))
        out = jnp.maximum(vm - 0.5 * (lo + hi), 0.0)
        out = jnp.where(act, out, 0.0)
        return jnp.where(any_act, out, jnp.zeros_like(out))

    def body(i, carry):
        a, best_a, best_o = carry
        gt = tangent_grad(a)
        gmax = jnp.max(jnp.abs(gt), axis=-1, keepdims=True)
        frac = i.astype(jnp.float32) / iters
        lr_i = lr * (0.5 * (1.0 + jnp.cos(jnp.pi * frac)))
        a = project(a - lr_i * gt / jnp.maximum(gmax, 1e-12))
        o = exact_obj(a)
        better = o < best_o
        return (a, jnp.where(better[:, None], a, best_a),
                jnp.where(better, o, best_o))

    a = project(a0)
    a, best_a, best_o = jax.lax.fori_loop(0, iters, body,
                                          (a, a, exact_obj(a)))
    pick = best_o[0] <= best_o[1]
    alpha_ref[...] = jnp.where(pick, best_a[0], best_a[1])[None, :]
    obj_ref[...] = jnp.where(pick, best_o[0], best_o[1])[None, None]


def sub2_pgd_kernel(selected: jax.Array, t_train: jax.Array,
                    snr_coeff: jax.Array, tx_power: jax.Array,
                    payload_bits: jax.Array,
                    alpha0: jax.Array, *, rho: float, lr: float,
                    tau: float, iters: int, bandwidth_hz: float,
                    min_alpha: float,
                    proj_iters: int = DEFAULT_PROJ_ITERS,
                    interpret: bool = True
                    ) -> tuple[jax.Array, jax.Array]:
    """Batched fused PGD: (S, K) instance rows -> ((S, K) alpha, (S,) obj).

    ``snr_coeff`` is c = g*P / (B*N0); ``payload_bits`` is the per-device
    (S, K) uplink payload (the scalar ``model_bits`` broadcast when no
    codec reshapes it); ``alpha0`` is (S, N_STARTS, K).
    """
    s, k = selected.shape
    if alpha0.shape != (s, N_STARTS, k):
        raise ValueError(f"alpha0 must be (S, {N_STARTS}, K), got "
                         f"{alpha0.shape}")
    kern = functools.partial(
        _sub2_pgd_kernel, rho=rho, lr=lr, tau=tau, iters=iters,
        bandwidth_hz=bandwidth_hz, min_alpha=min_alpha,
        proj_iters=proj_iters)
    row = pl.BlockSpec((1, k), lambda i: (i, 0))
    alpha, obj = pl.pallas_call(
        kern,
        grid=(s,),
        in_specs=[row, row, row, row, row,
                  pl.BlockSpec((1, N_STARTS, k), lambda i: (i, 0, 0))],
        out_specs=[row, pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((s, k), jnp.float32),
                   jax.ShapeDtypeStruct((s, 1), jnp.float32)],
        interpret=interpret,
    )(selected, t_train, snr_coeff, tx_power, payload_bits, alpha0)
    return alpha, obj[:, 0]
