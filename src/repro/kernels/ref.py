"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each function mirrors its kernel's contract exactly; the sweep tests in
``tests/test_kernels.py`` assert the kernels (interpret=True) match these
within dtype-appropriate tolerances.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fedavg_agg(updates: jax.Array, weights: jax.Array) -> jax.Array:
    """(K, P), (K,) -> (P,): FedAvg weighted sum in f32 accumulation."""
    out = jnp.einsum("kp,k->p", updates.astype(jnp.float32),
                     weights.astype(jnp.float32))
    return out.astype(updates.dtype)


def fedavg_agg_masked(updates: jax.Array, weights: jax.Array,
                      mask: jax.Array) -> jax.Array:
    """(K, P), (K,), (K,) -> (P,): success-masked FedAvg weighted sum.

    Mirrors ``fedavg_agg_masked_kernel`` exactly: the mask multiplies
    the weights *before* the reduction and nothing renormalizes — an
    all-ones mask reproduces :func:`fedavg_agg` bit for bit (the
    fault-subsystem property test).
    """
    w = weights.astype(jnp.float32) * mask.astype(jnp.float32)
    out = jnp.einsum("kp,k->p", updates.astype(jnp.float32), w)
    return out.astype(updates.dtype)


def fedavg_agg_stale(updates: jax.Array, weights: jax.Array,
                     mask: jax.Array, stale_w: jax.Array) -> jax.Array:
    """(K, P), (K,), (K,), (K,) -> (P,): staleness-weighted masked sum.

    Mirrors ``fedavg_agg_stale_kernel`` exactly: mask and staleness
    multiplier both fold into the weights *before* the reduction, no
    renormalization — an all-ones staleness row reproduces
    :func:`fedavg_agg_masked` bit for bit (the event subsystem's
    synchronous-limit property test).
    """
    w = weights.astype(jnp.float32) * mask.astype(jnp.float32) \
        * stale_w.astype(jnp.float32)
    out = jnp.einsum("kp,k->p", updates.astype(jnp.float32), w)
    return out.astype(updates.dtype)


def diversity(labels: jax.Array, mask: jax.Array,
              num_classes: int) -> jax.Array:
    """(K, N) labels/mask -> (K, 3) [gini, shannon, count]."""
    onehot = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)
    hist = jnp.sum(onehot * mask[..., None], axis=1)      # (K, C)
    total = jnp.sum(hist, axis=-1)
    p = hist / jnp.maximum(total, 1.0)[..., None]
    gini = 1.0 - jnp.sum(p * p, axis=-1)
    logp = jnp.where(p > 0.0, jnp.log2(jnp.maximum(p, 1e-30)), 0.0)
    shannon = -jnp.sum(p * logp, axis=-1)
    return jnp.stack([gini, shannon, total], axis=-1)


def stream_update(hists: jax.Array, deltas: jax.Array,
                  arrivals: jax.Array, staleness: jax.Array,
                  selected: jax.Array, *,
                  decay: float, size_cap: float = 0.0
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Streaming-data refresh oracle (``kernels/stream_update.py``).

    One FEEL round's data evolution over the per-device class-count
    matrix, fused into a single pass (DESIGN.md §7):

    1. count-delta accumulation: ``h' = max(h + delta, 0)`` (arrivals are
       positive deltas, evictions negative), then — when ``size_cap > 0``
       — a proportional rescale of any device exceeding the cap (buffer
       overflow evicts uniformly across classes);
    2. diversity refresh: Gini-Simpson, Shannon entropy and the sample
       count of the *new* counts, packed ``(…, K, 3)`` like the
       ``diversity`` kernel;
    3. staleness decay: ``stale' = [selected ? 0 : decay * stale] +
       arrivals`` — the decayed mass of data the server has not trained
       on.  ``arrivals`` is the arrival process's *reported* new-data
       mass, not the positive part of the net deltas: an eviction can
       cancel an arrival inside the same class, yet the device's
       distribution still turned over.  ``selected`` is the *previous*
       round's selection (participation consumes the backlog before
       this round's arrivals land).

    Shapes: ``hists``/``deltas`` ``(K, C)`` with ``arrivals``/
    ``staleness``/``selected`` ``(K,)``, or batched ``(S, K, C)`` /
    ``(S, K)`` — every reduction runs over trailing axes only.  This is
    also the production jnp path (``streaming.refresh`` with
    ``use_kernel=False``).
    """
    h = jnp.maximum(hists.astype(jnp.float32) + deltas.astype(jnp.float32),
                    0.0)
    if size_cap > 0.0:
        total = jnp.sum(h, axis=-1, keepdims=True)
        scale = jnp.where(total > size_cap,
                          size_cap / jnp.maximum(total, 1.0), 1.0)
        h = h * scale
    sizes = jnp.sum(h, axis=-1)
    p = h / jnp.maximum(sizes[..., None], 1.0)
    gini = 1.0 - jnp.sum(p * p, axis=-1)
    logp = jnp.where(p > 0.0, jnp.log2(jnp.maximum(p, 1e-30)), 0.0)
    shannon = -jnp.sum(p * logp, axis=-1)
    stats = jnp.stack([gini, shannon, sizes], axis=-1)
    stale = jnp.where(selected > 0.0, 0.0,
                      decay * staleness.astype(jnp.float32)) \
        + arrivals.astype(jnp.float32)
    return h, stats, stale


def compress_update(updates: jax.Array, residual: jax.Array,
                    widths: jax.Array, selected: jax.Array,
                    noise: jax.Array, *, mode: str, keep: int = 0,
                    thresh_iters: int = 32
                    ) -> tuple[jax.Array, jax.Array]:
    """Uplink-compression oracle (``kernels/compress.py``).

    One FEEL round's lossy uplink over the per-device flattened update
    matrix, fused into a single pass (DESIGN.md §9):

    1. error-feedback accumulate: ``v = updates + residual`` — the
       residual is the mass previous lossy rounds failed to transmit;
    2. compress + dequantize:

       * ``mode="quant"`` — stochastic ``widths``-bit quantization per
         device row: scale by the row max ``m``, split ``|v| / m`` into
         ``2^b - 1`` levels, round *stochastically* using the supplied
         uniform ``noise`` (unbiased: ``E[c] = v``), rebuild values.
         ``widths`` is per-device, so adaptive bit allocation rides the
         same path.  An all-zero row compresses to zeros; a row with
         one nonzero coordinate reconstructs it exactly (it IS the row
         max).
       * ``mode="topk"`` — keep the ``keep`` largest-magnitude
         coordinates per row (values exact, the rest zero).  The
         threshold comes from a fixed-trip bisection on
         ``count(|v| >= t)``, mirroring the kernel (sorts don't lower
         in TPU Pallas); float ties at the threshold may keep
         marginally fewer/more than ``keep``, identically in both.

    3. residual advance: ``r' = selected ? v - c : r`` — only devices
       that actually transmitted consume their backlog; ``selected`` is
       this round's selection mask.

    Shapes: ``updates``/``residual``/``noise`` ``(K, P)`` with
    ``widths``/``selected`` ``(K,)``, or batched ``(S, K, P)`` /
    ``(S, K)`` — every reduction runs over the trailing ``P`` axis
    only.  Returns ``(decoded values c, new residual)``; rows of ``c``
    for unselected devices are meaningless (their FedAvg weight is 0)
    and their residual is untouched.  This is also the production jnp
    path (``core.compression`` with ``use_kernel=False``).
    """
    v = updates.astype(jnp.float32) + residual.astype(jnp.float32)
    av = jnp.abs(v)
    if mode == "quant":
        m = jnp.max(av, axis=-1, keepdims=True)
        levels = jnp.maximum(
            jnp.exp2(widths.astype(jnp.float32)[..., None]) - 1.0, 1.0)
        scaled = av / jnp.maximum(m, 1e-12) * levels
        fl = jnp.floor(scaled)
        q = fl + (noise < (scaled - fl)).astype(jnp.float32)
        c = jnp.sign(v) * q / levels * m
    elif mode == "topk":
        lo = jnp.zeros(av.shape[:-1] + (1,), jnp.float32)
        hi = jnp.max(av, axis=-1, keepdims=True)

        def body(_, lohi):
            tlo, thi = lohi
            mid = 0.5 * (tlo + thi)
            cnt = jnp.sum((av >= mid).astype(jnp.float32), axis=-1,
                          keepdims=True)
            over = cnt > keep
            return jnp.where(over, mid, tlo), jnp.where(over, thi, mid)

        lo, hi = jax.lax.fori_loop(0, thresh_iters, body, (lo, hi))
        c = jnp.where(av >= hi, v, 0.0)
    else:
        raise ValueError(f"mode must be 'quant' or 'topk', got {mode!r}")
    new_r = jnp.where(selected[..., None] > 0.0, v - c, residual)
    return c, new_r


def sub2_pgd(selected: jax.Array, t_train: jax.Array,
             snr_coeff: jax.Array, tx_power: jax.Array,
             alpha0: jax.Array, *, rho: float, lr: float, tau: float,
             iters: int, bandwidth_hz: float, min_alpha: float,
             model_bits,
             proj_iters: int = 32) -> tuple[jax.Array, jax.Array]:
    """Single-instance fused-PGD oracle: (K,) rows + (2, K) starts ->
    ((K,) alpha, () objective).

    ``model_bits`` is a scalar nominal model size or a per-device
    ``(K,)`` payload-bits row — every use is elementwise, matching the
    kernel's bits operand lane.

    Same contract as ``sub2_pgd_kernel`` (tangent step with cosine lr,
    theta-bisection simplex projection, exact-objective best tracking
    over both starting points), but the gradient is derived
    *independently*: ``jax.grad`` of the logsumexp-smoothed objective,
    evaluated at the floored point — so a sign/derivative error in the
    kernel's hand-written analytic gradient fails the sweep test instead
    of being mirrored by the oracle.
    """
    import math
    mask = selected
    tt, c, pw = t_train, snr_coeff, tx_power
    n_act = jnp.maximum(jnp.sum(mask), 1.0)
    any_act = jnp.sum(mask) > 0.5
    scale = bandwidth_hz / math.log(2.0)

    def upload(av):
        rate = scale * av * jnp.log1p(c / av)
        return jnp.where(mask > 0.0,
                         model_bits / jnp.maximum(rate, 1e-12), 0.0)

    def exact_obj(av):
        tu = upload(jnp.maximum(av, min_alpha))
        tot = jnp.where(mask > 0.0, tt + tu, 0.0)
        return rho * jnp.sum(pw * tu) + (1.0 - rho) * jnp.max(tot)

    def smooth_obj(av):
        tu = upload(av)
        tot = jnp.where(mask > 0.0, tt + tu, 0.0)
        return (rho * jnp.sum(pw * tu)
                + (1.0 - rho) * tau * jax.nn.logsumexp(tot / tau))

    grad_fn = jax.grad(smooth_obj)

    def tangent_grad(av):
        # The kernel evaluates its analytic slope at the floored point;
        # feeding the floored point to autodiff matches that semantics.
        g = grad_fn(jnp.maximum(av, min_alpha)) * mask
        return (g - jnp.sum(g) / n_act) * mask

    def project(v):
        vm = jnp.where(mask > 0.0, v, 0.0)
        act = mask > 0.0
        lo = jnp.min(jnp.where(act, vm, jnp.inf)) - 1.0
        hi = jnp.max(jnp.where(act, vm, -jnp.inf))

        def pbody(_, lohi):
            plo, phi = lohi
            mid = 0.5 * (plo + phi)
            s = jnp.sum(jnp.where(act, jnp.maximum(vm - mid, 0.0), 0.0))
            over = s >= 1.0
            return jnp.where(over, mid, plo), jnp.where(over, phi, mid)

        lo, hi = jax.lax.fori_loop(0, proj_iters, pbody, (lo, hi))
        out = jnp.maximum(vm - 0.5 * (lo + hi), 0.0)
        out = jnp.where(act, out, 0.0)
        return jnp.where(any_act, out, jnp.zeros_like(out))

    def descend(a0_row):
        def body(i, carry):
            a, best_a, best_o = carry
            gt = tangent_grad(a)
            gmax = jnp.max(jnp.abs(gt))
            frac = i.astype(jnp.float32) / iters
            lr_i = lr * (0.5 * (1.0 + jnp.cos(jnp.pi * frac)))
            a = project(a - lr_i * gt / jnp.maximum(gmax, 1e-12))
            o = exact_obj(a)
            better = o < best_o
            return (a, jnp.where(better, a, best_a),
                    jnp.where(better, o, best_o))

        a = project(a0_row)
        _, best_a, best_o = jax.lax.fori_loop(0, iters, body,
                                              (a, a, exact_obj(a)))
        return best_a, best_o

    best_a, best_o = jax.vmap(descend)(alpha0)
    pick = best_o[0] <= best_o[1]
    return (jnp.where(pick, best_a[0], best_a[1]),
            jnp.where(pick, best_o[0], best_o[1]))


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0) -> jax.Array:
    """(BH, Sq, hd) x (BH, Skv, hd) -> (BH, Sq, hd), f32 softmax."""
    sq, skv = q.shape[1], k.shape[1]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    visible = jnp.ones((sq, skv), bool)
    if causal:
        visible &= k_pos <= q_pos
    if window > 0:
        visible &= k_pos > q_pos - window
    s = jnp.where(visible[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def mlstm_sequential(q, k, v, ig, fg):
    """Step-by-step mLSTM oracle for the chunked implementation.

    q,k,v: (B, S, nh, hd); ig/fg: (B, S, nh) raw gates.
    Returns h (B, S, nh, hd) float32.
    """
    b, s, nh, hd = q.shape
    qf = q.astype(jnp.float32) * hd ** -0.5
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(fg.astype(jnp.float32))
    igf = ig.astype(jnp.float32)

    def step(carry, t):
        c, n, m = carry
        m_new = jnp.maximum(logf[:, t] + m, igf[:, t])
        f_eff = jnp.exp(logf[:, t] + m - m_new)
        i_eff = jnp.exp(igf[:, t] - m_new)
        c = (f_eff[..., None, None] * c
             + i_eff[..., None, None] * vf[:, t][..., :, None]
             * kf[:, t][..., None, :])
        n = f_eff[..., None] * n + i_eff[..., None] * kf[:, t]
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", n, qf[:, t]))
        den = jnp.maximum(den, jnp.exp(-m_new))
        h = jnp.einsum("bhde,bhe->bhd", c, qf[:, t]) / den[..., None]
        return (c, n, m_new), h

    c0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, nh, hd), jnp.float32)
    m0 = jnp.full((b, nh), -1e30, jnp.float32)
    _, hs = jax.lax.scan(step, (c0, n0, m0), jnp.arange(s))
    return hs.swapaxes(0, 1)


def ssd_sequential(xh, bmat, cmat, log_a, dt_s):
    """Step-by-step SSD oracle (ssm._ssd_chunked contract)."""
    b, s, nh, p = xh.shape
    n = bmat.shape[-1]

    def step(h, t):
        a = jnp.exp(log_a[:, t])                       # (B, nh)
        h = (a[..., None, None] * h
             + jnp.einsum("bh,bn,bhp->bhnp", dt_s[:, t],
                          bmat[:, t].astype(jnp.float32),
                          xh[:, t].astype(jnp.float32)))
        y = jnp.einsum("bn,bhnp->bhp", cmat[:, t].astype(jnp.float32), h)
        return h, y

    h0 = jnp.zeros((b, nh, n, p), jnp.float32)
    h_final, ys = jax.lax.scan(step, h0, jnp.arange(s))
    return ys.swapaxes(0, 1), h_final
