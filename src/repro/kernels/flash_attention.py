"""Flash attention (forward) Pallas TPU kernel: causal + sliding window.

Online-softmax blocked attention (Dao et al.), adapted to the TPU memory
hierarchy: the kv loop is the innermost *grid* dimension (TPU grids
execute sequentially per core, so VMEM scratch carries the running
(m, l, acc) statistics across kv steps); q/k/v tiles stream HBM->VMEM via
BlockSpecs sized to the MXU (block_q x head_dim and block_k x head_dim,
multiples of 128).

Grid: (batch * q_heads, num_q_blocks, num_kv_blocks).  GQA is handled in
the index maps: q head ``h`` reads kv head ``h // group_size``.  Causal /
sliding-window masking is applied inside the block; fully-masked blocks
are skipped with ``pl.when`` (they still occupy grid steps — the TPU
cost is the skipped DMA, which XLA elides per-block).

The pure-jnp oracle lives in ``ref.py``; ``ops.py`` wraps the kernel with
padding + (B, S, H, hd) layout handling.  Validated with interpret=True
(CPU) across shape/dtype sweeps in tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, seq_len: int,
                  causal: bool, window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    visible = k_pos < seq_len
    if causal:
        visible &= k_pos <= q_pos
    if window > 0:
        visible &= k_pos > q_pos - window

    # Whole-block skip: any work in this (q, kv) block?
    q_lo = qi * block_q
    k_lo = ki * block_k
    block_live = jnp.bool_(True)
    if causal:
        block_live = jnp.logical_and(block_live,
                                     k_lo <= (q_lo + block_q - 1))
    if window > 0:
        block_live = jnp.logical_and(
            block_live, (k_lo + block_k - 1) > (q_lo - window))

    @pl.when(block_live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)                  # (bk, hd)
        s = q @ k.T                                       # (bq, bk)
        s = jnp.where(visible, s, NEG_INF)
        m_prev = m_scr[...]                               # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + p @ v
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           kv_len: int | None = None,
                           interpret: bool = True) -> jax.Array:
    """q: (BH, Sq, hd); k, v: (BH, Skv, hd) — flattened batch*head rows.

    Sq/Skv must be multiples of the block sizes (ops.py pads); ``kv_len``
    is the true (pre-padding) KV length used for the validity mask.
    Returns (BH, Sq, hd) in q.dtype.
    """
    bh, sq, hd = q.shape
    skv = k.shape[1]
    grid = (bh, sq // block_q, skv // block_k)
    scale = hd ** -0.5
    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_len=kv_len if kv_len is not None else skv, causal=causal,
        window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
