"""FedAvg weighted aggregation kernel (Alg. 1 line 12 hot loop).

``out[p] = sum_k w[k] * updates[k, p]`` over K stacked client updates —
a memory-bound weighted reduction executed every round on every parameter
buffer.  TPU mapping: grid over parameter-dim tiles; each program loads a
(K, BLOCK_P) VMEM tile of the stacked updates and the (K,) weight vector,
reduces over K in f32 on the VPU, writes a (BLOCK_P,) tile.

VMEM budget: K <= 256 clients x BLOCK_P=2048 x 4 B = 2 MB per tile (plus
double buffering) — comfortably inside the ~16 MB v5e VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_P = 2048


def _fedavg_kernel(updates_ref, weights_ref, out_ref):
    u = updates_ref[...].astype(jnp.float32)          # (K, BP)
    w = weights_ref[...].astype(jnp.float32)          # (K, 1)
    out_ref[...] = jnp.sum(u * w, axis=0).astype(out_ref.dtype)


def fedavg_agg_kernel(updates: jax.Array, weights: jax.Array,
                      block_p: int = DEFAULT_BLOCK_P,
                      interpret: bool = True) -> jax.Array:
    """updates: (K, P) with P % block_p == 0; weights: (K,) -> (P,)."""
    k, p = updates.shape
    grid = (p // block_p,)
    return pl.pallas_call(
        _fedavg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, block_p), lambda i: (0, i)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_p,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), updates.dtype),
        interpret=interpret,
    )(updates, weights[:, None])
