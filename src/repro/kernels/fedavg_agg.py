"""FedAvg weighted aggregation kernel (Alg. 1 line 12 hot loop).

``out[p] = sum_k w[k] * updates[k, p]`` over K stacked client updates —
a memory-bound weighted reduction executed every round on every parameter
buffer.  TPU mapping: grid over parameter-dim tiles; each program loads a
(K, BLOCK_P) VMEM tile of the stacked updates and the (K,) weight vector,
reduces over K in f32 on the VPU, writes a (BLOCK_P,) tile.

VMEM budget: K <= 256 clients x BLOCK_P=2048 x 4 B = 2 MB per tile (plus
double buffering) — comfortably inside the ~16 MB v5e VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_P = 2048


def _fedavg_kernel(updates_ref, weights_ref, out_ref):
    u = updates_ref[...].astype(jnp.float32)          # (K, BP)
    w = weights_ref[...].astype(jnp.float32)          # (K, 1)
    out_ref[...] = jnp.sum(u * w, axis=0).astype(out_ref.dtype)


def fedavg_agg_kernel(updates: jax.Array, weights: jax.Array,
                      block_p: int = DEFAULT_BLOCK_P,
                      interpret: bool = True) -> jax.Array:
    """updates: (K, P) with P % block_p == 0; weights: (K,) -> (P,)."""
    k, p = updates.shape
    grid = (p // block_p,)
    return pl.pallas_call(
        _fedavg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, block_p), lambda i: (0, i)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_p,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), updates.dtype),
        interpret=interpret,
    )(updates, weights[:, None])


def _fedavg_stale_kernel(updates_ref, weights_ref, mask_ref, stale_ref,
                         out_ref):
    u = updates_ref[...].astype(jnp.float32)          # (K, BP)
    w = weights_ref[...].astype(jnp.float32)          # (K, 1)
    m = mask_ref[...].astype(jnp.float32)             # (K, 1)
    s = stale_ref[...].astype(jnp.float32)            # (K, 1)
    out_ref[...] = jnp.sum(u * (w * m * s), axis=0).astype(out_ref.dtype)


def fedavg_agg_stale_kernel(updates: jax.Array, weights: jax.Array,
                            mask: jax.Array, stale_w: jax.Array,
                            block_p: int = DEFAULT_BLOCK_P,
                            interpret: bool = True) -> jax.Array:
    """Staleness-weighted masked FedAvg reduction (event subsystem,
    DESIGN.md §12).

    ``out[p] = sum_k w[k] * m[k] * s[k] * updates[k, p]`` — the masked
    reduction with a per-update staleness multiplier ``s`` fused into
    the weight load.  The buffered aggregator's flush discounts each
    arrived update by its model-version staleness ``(1 + tau)^-gamma``;
    at ``gamma = 0`` the multiplier row is exactly 1.0 and the kernel is
    bitwise :func:`fedavg_agg_masked_kernel` (the synchronous-limit
    parity contract).  No internal renormalization — callers fold the
    staleness discount into the normalizer themselves.  Same grid/VMEM
    mapping as the masked kernel; the third (K, 1) tile is noise
    against the (K, BLOCK_P) update tile.
    """
    k, p = updates.shape
    grid = (p // block_p,)
    return pl.pallas_call(
        _fedavg_stale_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, block_p), lambda i: (0, i)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_p,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), updates.dtype),
        interpret=interpret,
    )(updates, weights[:, None], mask[:, None], stale_w[:, None])


def _fedavg_masked_kernel(updates_ref, weights_ref, mask_ref, out_ref):
    u = updates_ref[...].astype(jnp.float32)          # (K, BP)
    w = weights_ref[...].astype(jnp.float32)          # (K, 1)
    m = mask_ref[...].astype(jnp.float32)             # (K, 1)
    out_ref[...] = jnp.sum(u * (w * m), axis=0).astype(out_ref.dtype)


def fedavg_agg_masked_kernel(updates: jax.Array, weights: jax.Array,
                             mask: jax.Array,
                             block_p: int = DEFAULT_BLOCK_P,
                             interpret: bool = True) -> jax.Array:
    """Failure-masked FedAvg reduction (fault subsystem, DESIGN.md §10).

    ``out[p] = sum_k w[k] * m[k] * updates[k, p]`` — the unmasked
    reduction with a success mask fused into the weight load.  The
    kernel does NOT renormalize over the mask: callers own the weight
    normalization, which is what makes an all-ones mask bitwise equal
    to :func:`fedavg_agg_kernel` (``w * 1.0 == w`` exactly in f32 —
    the property ``tests/test_faults.py`` pins).  Same grid/VMEM
    mapping as the unmasked kernel; the extra (K, 1) mask tile is
    noise against the (K, BLOCK_P) update tile.
    """
    k, p = updates.shape
    grid = (p // block_p,)
    return pl.pallas_call(
        _fedavg_masked_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, block_p), lambda i: (0, i)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_p,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), updates.dtype),
        interpret=interpret,
    )(updates, weights[:, None], mask[:, None])
