from repro.optim.optimizers import (OptimizerConfig, apply_updates,
                                    global_norm, init_state)

__all__ = ["OptimizerConfig", "apply_updates", "global_norm", "init_state"]
