"""Pytree optimizers: SGD(+momentum) and AdamW, built from scratch.

AdamW moment dtype is configurable (``state_dtype``): the >300B configs
(jamba-1.5-large) keep m/v in bf16 to fit HBM per DESIGN.md §5; everything
else defaults to f32.  Optimizer state shards exactly like the parameters
(the dry-run passes the same PartitionSpec tree).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | sgd
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    momentum: float = 0.9          # sgd
    grad_clip: float = 1.0         # global-norm clip; 0 disables
    state_dtype: str = "float32"   # float32 | bfloat16
    warmup_steps: int = 100
    schedule: str = "constant"     # constant | cosine
    total_steps: int = 10_000


def _sdtype(cfg: OptimizerConfig):
    return jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32


def init_state(params: Params, cfg: OptimizerConfig) -> dict:
    if cfg.name == "sgd":
        if cfg.momentum > 0.0:
            mu = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, _sdtype(cfg)), params)
            return {"mu": mu, "count": jnp.zeros((), jnp.int32)}
        return {"count": jnp.zeros((), jnp.int32)}
    mu = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, _sdtype(cfg)), params)
    nu = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, _sdtype(cfg)), params)
    return {"mu": mu, "nu": nu, "count": jnp.zeros((), jnp.int32)}


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def _lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    lr = jnp.asarray(cfg.learning_rate, jnp.float32)
    if cfg.warmup_steps > 0:
        lr = lr * jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    if cfg.schedule == "cosine":
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        lr = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return lr


def apply_updates(params: Params, grads: Params, state: dict,
                  cfg: OptimizerConfig) -> Tuple[Params, dict, dict]:
    """One optimizer step.  Returns (params, state, metrics)."""
    step = state["count"]
    gnorm = global_norm(grads)
    if cfg.grad_clip > 0.0:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
            grads)
    lr = _lr_at(cfg, step)
    metrics = {"grad_norm": gnorm, "lr": lr}

    if cfg.name == "sgd":
        if cfg.momentum > 0.0:
            mu = jax.tree_util.tree_map(
                lambda m, g: (cfg.momentum * m.astype(jnp.float32)
                              + g.astype(jnp.float32)).astype(m.dtype),
                state["mu"], grads)
            params = jax.tree_util.tree_map(
                lambda p, m: (p.astype(jnp.float32)
                              - lr * m.astype(jnp.float32)).astype(p.dtype),
                params, mu)
            return params, {"mu": mu, "count": step + 1}, metrics
        params = jax.tree_util.tree_map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        return params, {"count": step + 1}, metrics

    # AdamW
    b1, b2 = cfg.beta1, cfg.beta2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                        + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["mu"])
    flat_v = jax.tree_util.tree_leaves(state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"mu": new_m, "nu": new_v, "count": step + 1}, metrics
