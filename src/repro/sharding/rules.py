"""Logical-axis sharding rules for the production mesh (DESIGN.md §5).

Mesh axes: ``("pod", "data", "model")`` multi-pod or ``("data", "model")``
single-pod.  Logical axes used by the model zoo:

* ``batch``  -> ("pod", "data")   — data parallel
* ``fsdp``   -> ("pod", "data")   — parameter/optimizer sharding (2-D with
                                    ``tensor``)
* ``tensor`` -> ("model",)        — head / d_ff / expert / vocab dim
* ``expert`` -> ("model",)        — MoE expert-parallel (when divisible)
* ``cache_seq`` -> ("data",)      — decode KV-cache sequence sharding for
                                    batch-1 long-context decode
* everything else -> replicated

GSPMD handles non-divisible dims by padding (e.g. 40 heads over 16-way
``model``), which we accept and surface in the roofline notes.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical name -> tuple of mesh axis names (subset present in the mesh is
# used, preserving order).
LOGICAL_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "tensor": ("model",),
    "expert": ("model",),
    "cache_seq": ("data",),
    # Megatron-style sequence parallelism: the residual stream between
    # blocks shards its seq dim over the model axis, cutting saved-
    # activation memory by the TP degree (see EXPERIMENTS.md §Perf).
    "seq": ("model",),
}


def residual_constrain(x: jax.Array, mesh: Optional[Mesh],
                       seq_shard: bool) -> jax.Array:
    """Constrain a (B, S, D) residual-stream tensor between blocks."""
    return constrain(x, mesh, "batch", "seq" if seq_shard else None, None)


def constrain_pad(x: jax.Array, mesh: Optional[Mesh],
                  *logical: Optional[str]) -> jax.Array:
    """Like :func:`constrain` but keeps axes whose dim is NOT divisible —
    GSPMD pads unevenly (e.g. 40 heads over a 16-way model axis -> 3 per
    shard, 20% padding).  Used for attention head dims, where padding
    beats replicating the O(S^2) score buffers by far."""
    if mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(x, named(mesh, *logical))


def mesh_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def resolve(logical: Optional[str], mesh: Mesh):
    """Logical axis name -> mesh axes entry for a PartitionSpec."""
    if logical is None:
        return None
    axes = tuple(a for a in LOGICAL_RULES.get(logical, ())
                 if a in mesh.axis_names)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def spec(mesh: Mesh, *logical: Optional[str]) -> P:
    """Build a PartitionSpec from logical axis names."""
    return P(*(resolve(name, mesh) for name in logical))


def named(mesh: Mesh, *logical: Optional[str]) -> NamedSharding:
    return NamedSharding(mesh, spec(mesh, *logical))


def constrain(x: jax.Array, mesh: Optional[Mesh],
              *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh.

    Axes whose dim is not divisible by the mesh-axis product are dropped
    (replicated) instead of letting GSPMD pad — avoids silent 2x buffer
    blow-ups on e.g. batch=1 decode or 12-head models on a 16-way axis.
    """
    if mesh is None or mesh.empty:
        return x
    names = []
    for dim, name in zip(x.shape, logical):
        axes = LOGICAL_RULES.get(name, ()) if name else ()
        axes = tuple(a for a in axes if a in mesh.axis_names)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if name is not None and size > 1 and dim % size == 0:
            names.append(name)
        else:
            names.append(None)
    return jax.lax.with_sharding_constraint(x, named(mesh, *names))


def tree_spec(tree, fn) -> object:
    """Map ``fn(path_str, leaf) -> PartitionSpec`` over a pytree."""
    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(f"{path}/{k}", v) for k, v in node.items()}
        return fn(path, node)
    return walk("", tree)


def divisible(n: int, mesh: Mesh, axes: Sequence[str]) -> bool:
    size = 1
    for a in axes:
        if a in mesh.axis_names:
            size *= mesh.shape[a]
    return size > 0 and n % size == 0
