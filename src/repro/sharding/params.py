"""Parameter PartitionSpec assignment (path + shape based, MaxText-style).

Model-zoo params are nested dicts; ``param_specs`` walks the (shape) tree
and assigns a PartitionSpec per leaf:

* 2-D projections: contraction-side dim on ``fsdp`` (= pod+data), the
  wide output dim on ``tensor`` (= model) — standard 2-D (FSDP x TP).
* MoE expert stacks (E, D, F): expert-parallel over ``tensor`` when E is
  divisible by the model-axis size; otherwise per-expert tensor parallel
  on F.
* Stacked layers carry a leading group dim -> spec gets a ``None`` prefix.
* Norm scales / biases / gate vectors: replicated.

Everything here returns *specs*; NamedShardings are built in the launcher
where the mesh is known.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.sharding import rules

Params = Any


def _axis(mesh: Mesh, logical: Optional[str]):
    return rules.resolve(logical, mesh)


def _spec_for(path: str, ndim: int, shape: tuple, cfg: ModelConfig,
              mesh: Mesh, stacked: bool) -> P:
    """Spec for one leaf; ``stacked`` = leading layer-group dim present."""
    lead = (None,) if stacked else ()
    core_ndim = ndim - len(lead)
    fsdp = _axis(mesh, "fsdp")
    tensor = _axis(mesh, "tensor")
    name = path.rsplit("/", 1)[-1]

    if core_ndim <= 1:
        return P(*lead, None)

    # MoE expert stacks: (E, D, F) / (E, F, D)
    if name in ("wi", "wg", "wo") and core_ndim == 3:
        e = shape[len(lead)]
        if tensor is not None and rules.divisible(e, mesh, ("model",)):
            # expert-parallel; shard the other big dim on fsdp
            return P(*lead, tensor, fsdp, None)
        return (P(*lead, None, fsdp, tensor) if name in ("wi", "wg")
                else P(*lead, None, tensor, fsdp))

    # Vocab-dim tensors shard over `model` only: sharding their d_model
    # side over fsdp makes GSPMD reshard the (batch-sharded) hidden states
    # against the contraction dim — full-batch temp buffers (see
    # EXPERIMENTS.md §Perf iteration log).  V/16 keeps them small anyway.
    if name == "embed":
        return P(tensor, None)
    if name == "lm_head":
        return P(None, tensor)
    if name == "router":
        return P(*lead, fsdp, None)

    # sLSTM block-diagonal recurrent weights (nh, hd, 4hd): replicate
    # (small) .
    if name == "wr":
        return P(*lead, None, None, None)

    if core_ndim == 2:
        # Output-side projections back to d_model: contract dim sharded
        # on tensor.
        if name in ("wo",):
            return P(*lead, tensor, fsdp)
        # Input-side projections from d_model: wide dim on tensor.
        if name in ("wq", "wk", "wv", "wi", "wg", "wup", "wgate", "wz",
                    "wx"):
            return P(*lead, fsdp, tensor)
        if name in ("wB", "wC", "wdt", "wif", "wx4"):
            return P(*lead, fsdp, None)
        if name == "conv":
            return P(*lead, None, tensor)
        return P(*lead, fsdp, None)

    return P(*lead, *([None] * core_ndim))


def _sanitize(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop spec axes that do not divide the dim exactly — explicit
    in_shardings (unlike constraints) cannot be padded by GSPMD.
    E.g. whisper's vocab 51865 on a 16-way model axis."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        axes = entry if isinstance(entry, tuple) else (
            (entry,) if entry else ())
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if (size <= 1 or dim % size == 0) else None)
    return P(*out)


def param_specs(shapes: Params, cfg: ModelConfig, mesh: Mesh) -> Params:
    """Map a params shape-tree -> PartitionSpec tree."""

    def walk(path: str, node):
        if isinstance(node, dict):
            return {k: walk(f"{path}/{k}", v) for k, v in node.items()}
        stacked = "/layers/" in path or path.startswith("layers/")
        spec = _spec_for(path, len(node.shape), tuple(node.shape), cfg,
                         mesh, stacked)
        return _sanitize(spec, tuple(node.shape), mesh)

    return walk("", shapes)


def param_shardings(shapes: Params, cfg: ModelConfig,
                    mesh: Mesh) -> Params:
    specs = param_specs(shapes, cfg, mesh)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                                  is_leaf=lambda x: isinstance(x, P))
