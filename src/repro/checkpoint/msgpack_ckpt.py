"""Msgpack pytree checkpointing (no flax/orbax in the container).

Format: a msgpack map ``{"__version__": int, "__meta__": {...},
"leaves": {...}}`` with one entry per leaf: ``{"dtype": str,
"shape": [...], "data": bytes}``.  The versioned header
(:data:`FORMAT_VERSION`) lets downstream state formats — notably the
sweep runner's resume checkpoints (``repro.sweep.runner``) — refuse
files written by an incompatible future writer instead of silently
misreading them; files from before the header existed load as version
0.  Restore rebuilds the pytree and (optionally) device_puts every leaf
with a target sharding — sharding-aware restore for the pod launcher.
"""

from __future__ import annotations

import os
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

Params = Any

# Bump when the on-disk layout changes incompatibly.  Readers accept
# any version <= FORMAT_VERSION (additive evolution happens inside
# ``__meta__``); newer-versioned files fail loudly.
FORMAT_VERSION = 1


def _flatten_with_paths(tree: Params) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}

    def walk(path: str, node):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{path}/{k}" if path else str(k), node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{path}[{i}]", v)
        else:
            out[path] = np.asarray(node)

    walk("", tree)
    return out


def save(path: str, tree: Params, meta: Optional[dict] = None) -> None:
    flat = _flatten_with_paths(tree)
    payload = {
        "__version__": FORMAT_VERSION,
        "__meta__": meta or {},
        "leaves": {
            k: {"dtype": str(v.dtype), "shape": list(v.shape),
                "data": v.tobytes()}
            for k, v in flat.items()
        },
    }
    # Atomic + durable write: serialize into a sibling temp file, fsync
    # it, then rename over the target.  A kill at any point leaves
    # either the old complete checkpoint or the new complete one — a
    # torn ``path`` is impossible (the sweep runner's kill/resume
    # contract, ``tests/test_faults.py``).  A stale ``.tmp`` from a
    # kill mid-write is harmless: the next save truncates it.
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load_flat(path: str) -> tuple[Dict[str, np.ndarray], dict]:
    with open(path, "rb") as f:
        raw = f.read()
    try:
        payload = msgpack.unpackb(raw, raw=False)
        if not isinstance(payload, dict) or "leaves" not in payload:
            raise ValueError("not a checkpoint container")
    except Exception as e:
        # msgpack's unpack errors vary by decoder version (ExtraData,
        # OutOfData, FormatError, bare ValueError); normalize all of
        # them to one clear diagnosis with the path instead of a bare
        # decoder traceback.
        raise ValueError(
            f"{path}: corrupt or truncated checkpoint "
            f"({type(e).__name__}: {e}); the atomic writer never "
            f"produces this — the file was damaged after the fact"
        ) from e
    version = payload.get("__version__", 0)   # pre-header files: 0
    if version > FORMAT_VERSION:
        raise ValueError(
            f"{path}: checkpoint format version {version} is newer than "
            f"this reader ({FORMAT_VERSION})")
    leaves = {
        k: np.frombuffer(v["data"], dtype=np.dtype(v["dtype"])
                         ).reshape(v["shape"])
        for k, v in payload["leaves"].items()
    }
    return leaves, payload.get("__meta__", {})


def restore(path: str, like: Params,
            sharding_fn: Optional[Callable[[str], Any]] = None) -> Params:
    """Restore into the structure of ``like`` (shapes validated).

    ``sharding_fn(path) -> Sharding`` places each leaf on the mesh during
    restore (sharded device_put); None keeps host arrays.
    """
    flat, _ = load_flat(path)

    def walk(prefix: str, node):
        if isinstance(node, dict):
            return {k: walk(f"{prefix}/{k}" if prefix else str(k), node[k])
                    for k in sorted(node)}
        arr = flat[prefix]
        want = tuple(node.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"{prefix}: shape {arr.shape} != {want}")
        if sharding_fn is not None:
            return jax.device_put(arr, sharding_fn(prefix))
        return jnp.asarray(arr)

    return walk("", like)
