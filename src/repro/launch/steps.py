"""Step builders: train_step / prefill_step / serve_step (+ FedAvg round).

``make_train_step`` returns the canonical distributed training step:
forward (+ MoE aux loss), masked token cross-entropy, backward, optimizer
update — the function the multi-pod dry-run lowers for every
(architecture x input shape).

``make_federated_train_step`` is the paper's technique at datacenter
scale (DESIGN.md §3): the global batch is partitioned into ``num_clients``
client shards; per-client gradients are FedAvg-weighted by the scheduler's
selection mask and data sizes before the update — equivalent to Alg. 1
with E=1 at pod scale, with the DAS decision entering as the (selection,
weight) inputs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import optim
from repro.models import common, transformer
from repro.models.config import ModelConfig
from repro.sharding import rules

Params = Any


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token CE in f32 (sharded-vocab safe: logsumexp lowers to a
    reduction XLA partitions with the logits)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_xent(hidden: jax.Array, head: jax.Array, labels: jax.Array,
                 cfg: ModelConfig, mesh, num_chunks: int = 8) -> jax.Array:
    """Sequence-chunked, rematerialized softmax cross-entropy.

    The (B, S, V) logits tensor never fully materializes: each seq chunk's
    head matmul + CE is wrapped in ``jax.checkpoint`` so only per-chunk
    scalars survive the forward pass and the backward recomputes one
    chunk's logits at a time (§Perf: 6-8 GB/device saved at V=152k).
    """
    b, s, _ = hidden.shape
    if s % num_chunks:
        num_chunks = 1
    cs = s // num_chunks

    @jax.checkpoint
    def chunk_loss(xc, lc):
        logits = xc @ head.astype(xc.dtype)
        if cfg.logits_softcap > 0.0:
            logits = cfg.logits_softcap * jnp.tanh(
                logits / cfg.logits_softcap)
        logits = rules.constrain(logits, mesh, "batch", None, "tensor")
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    total = jnp.zeros((), jnp.float32)
    for i in range(num_chunks):
        sl = slice(i * cs, (i + 1) * cs)
        total = total + chunk_loss(hidden[:, sl], labels[:, sl])
    return total / (b * s)


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            mesh) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    hidden, aux = transformer.forward(
        params, batch["inputs"], cfg, mesh,
        positions=batch.get("positions"),
        encoder_inputs=batch.get("encoder_inputs"),
        return_hidden=True)
    ce = chunked_xent(hidden, transformer.head_matrix(params, cfg),
                      batch["labels"], cfg, mesh)
    total = ce + cfg.router_aux_weight * aux
    return total, {"loss": total, "ce": ce, "moe_aux": aux}


def init_train_state(key: jax.Array, cfg: ModelConfig,
                     ocfg: optim.OptimizerConfig) -> Dict[str, Any]:
    params = transformer.init(key, cfg)
    return {"params": params, "opt": optim.init_state(params, ocfg)}


def train_state_shapes(cfg: ModelConfig,
                       ocfg: optim.OptimizerConfig) -> Dict[str, Any]:
    return jax.eval_shape(
        lambda k: init_train_state(k, cfg, ocfg), jax.random.key(0))


def make_train_step(cfg: ModelConfig, ocfg: optim.OptimizerConfig,
                    mesh, microbatches: int = 1) -> Callable:
    """Canonical train step; ``microbatches > 1`` enables gradient
    accumulation (unrolled, so cost_analysis sees every FLOP): the global
    batch is split on the leading dim and per-microbatch grads are
    accumulated in f32 before one optimizer update.  Cuts activation
    memory by ~the microbatch factor at identical math (§Perf)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, mesh), has_aux=True)(params)

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]):
        if microbatches <= 1:
            (_, metrics), grads = grads_of(state["params"], batch)
        else:
            # lax.scan forces the microbatches to run sequentially —
            # an unrolled loop lets XLA overlap all forward passes and
            # *grows* peak memory (§Perf: 23 -> 36 GB, refuted).  FLOP
            # accounting for the scanned body is handled by the dry-run
            # harness (costs are taken from the microbatches=1 lowering,
            # which is FLOP-identical).
            def resh(k, v):
                ax = 1 if k == "positions" else 0
                m = microbatches
                shape = (v.shape[:ax] + (m, v.shape[ax] // m)
                         + v.shape[ax + 1:])
                mb = v.reshape(shape)
                return jnp.moveaxis(mb, ax, 0) if ax else mb

            stacked = {k: resh(k, v) for k, v in batch.items()}

            def body(carry, mb):
                grads_acc, metrics_acc = carry
                (_, m), g = grads_of(state["params"], mb)
                grads_acc = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32), grads_acc, g)
                metrics_acc = jax.tree_util.tree_map(jnp.add, metrics_acc,
                                                     m)
                return (grads_acc, metrics_acc), None

            zeros_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32),
                state["params"])
            zeros_m = {"loss": jnp.zeros((), jnp.float32),
                       "ce": jnp.zeros((), jnp.float32),
                       "moe_aux": jnp.zeros((), jnp.float32)}
            (grads, metrics), _ = jax.lax.scan(body, (zeros_g, zeros_m),
                                               stacked)
            inv = 1.0 / microbatches
            grads = jax.tree_util.tree_map(lambda x: x * inv, grads)
            metrics = jax.tree_util.tree_map(lambda x: x * inv, metrics)
        params, opt, opt_metrics = optim.apply_updates(
            state["params"], grads, state["opt"], ocfg)
        metrics.update(opt_metrics)
        return {"params": params, "opt": opt}, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh) -> Callable:
    def prefill_step(params: Params, batch: Dict[str, jax.Array]):
        return transformer.prefill(
            params, batch["inputs"], cfg, mesh,
            encoder_inputs=batch.get("encoder_inputs"))

    return prefill_step


def make_serve_step(cfg: ModelConfig, mesh) -> Callable:
    def serve_step(params: Params, tokens: jax.Array, cache: Params,
                   index: jax.Array):
        return transformer.decode_step(params, tokens, cache, index, cfg,
                                       mesh)

    return serve_step


# ---------------------------------------------------------------------------
# Federated (paper technique at pod scale)
# ---------------------------------------------------------------------------

def make_federated_train_step(cfg: ModelConfig,
                              ocfg: optim.OptimizerConfig, mesh,
                              num_clients: int) -> Callable:
    """FedAvg-weighted gradient step over client-sharded batches.

    batch["inputs"]/["labels"]: (num_clients, per_client_batch, seq);
    batch["selected"]: (num_clients,) {0,1} from the DAS scheduler;
    batch["sizes"]: (num_clients,) |D_k| for the FedAvg weights.

    Per-client mean gradients are combined with weights
    ``selected_k * |D_k| / sum(selected * |D|)`` — Alg. 1 line 12 as a
    weighted reduction over the client axis (sharded over pod+data).
    """

    def client_grads(params, inputs, labels):
        (_, m), g = jax.value_and_grad(
            lambda p: loss_fn(p, {"inputs": inputs, "labels": labels},
                              cfg, mesh), has_aux=True)(params)
        return g, m["ce"]

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]):
        w = batch["selected"].astype(jnp.float32) * \
            batch["sizes"].astype(jnp.float32)
        w = w / jnp.maximum(jnp.sum(w), 1e-9)
        grads_stacked, ces = jax.vmap(
            lambda i, l: client_grads(state["params"], i, l),
            in_axes=(0, 0))(batch["inputs"], batch["labels"])
        grads = jax.tree_util.tree_map(
            lambda g: jnp.tensordot(w, g.astype(jnp.float32), axes=1
                                    ).astype(g.dtype), grads_stacked)
        params, opt, opt_metrics = optim.apply_updates(
            state["params"], grads, state["opt"], ocfg)
        metrics = {"ce": jnp.sum(ces * w), **opt_metrics,
                   "n_selected": jnp.sum(batch["selected"])}
        return {"params": params, "opt": opt}, metrics

    return train_step
