"""ShapeDtypeStruct stand-ins for every model input (dry-run, no alloc).

``input_specs(cfg, shape, mesh)`` returns the exact pytree the step
functions consume, with shardings attached:

* train:   {"inputs", "labels" (+"positions" for M-RoPE,
            +"encoder_inputs" for enc-dec)}
* prefill: {"inputs" (+extras as above)}
* decode:  {"tokens", "index", "cache"}

Batch dims shard over (pod, data) when divisible (long_500k's batch=1
stays replicated); token/embedding feature dims replicate; decode caches
shard batch over (pod, data) and KV heads over model (GSPMD pads
non-divisible head counts — noted in the roofline analysis).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import InputShape
from repro.models import common, transformer
from repro.models.config import ModelConfig
from repro.sharding import rules

Params = Any


def _sds(shape, dtype, mesh: Mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _batch_spec(batch: int, mesh: Mesh, extra_dims: int) -> P:
    axes = rules.resolve("batch", mesh)
    size = 1
    names = axes if isinstance(axes, tuple) else ((axes,) if axes else ())
    for a in names:
        size *= mesh.shape[a]
    lead = axes if (axes and batch % max(size, 1) == 0 and batch >= size) \
        else None
    return P(lead, *([None] * extra_dims))


def token_specs(cfg: ModelConfig, batch: int, seq: int,
                mesh: Mesh) -> Dict[str, Any]:
    """Training/prefill inputs."""
    out: Dict[str, Any] = {}
    if cfg.input_mode == "embeddings" and not cfg.is_encdec:
        out["inputs"] = _sds((batch, seq, cfg.d_model), jnp.bfloat16, mesh,
                             _batch_spec(batch, mesh, 2))
    else:
        out["inputs"] = _sds((batch, seq), jnp.int32, mesh,
                             _batch_spec(batch, mesh, 1))
    out["labels"] = _sds((batch, seq), jnp.int32, mesh,
                         _batch_spec(batch, mesh, 1))
    if cfg.mrope_sections:
        out["positions"] = _sds((3, batch, seq), jnp.int32, mesh,
                                P(None, *_batch_spec(batch, mesh, 1)))
    if cfg.is_encdec:
        # Audio stub: precomputed frame embeddings, same sequence length.
        out["encoder_inputs"] = _sds((batch, seq, cfg.d_model),
                                     jnp.bfloat16, mesh,
                                     _batch_spec(batch, mesh, 2))
    return out


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                mesh: Mesh, enc_len: int = 0) -> Params:
    """ShapeDtypeStructs matching ``transformer.init_cache``."""
    dtype = common.dtype_of(cfg.dtype_compute)
    shapes = jax.eval_shape(
        lambda: transformer.init_cache(cfg, batch, max_len, dtype,
                                       enc_len or None))

    bspec = _batch_spec(batch, mesh, 0)
    b_axis = bspec[0]
    tensor = rules.resolve("tensor", mesh)

    def _div(dim: int) -> bool:
        # in_shardings must divide exactly (no GSPMD padding on inputs)
        size = 1
        names = tensor if isinstance(tensor, tuple) else (
            (tensor,) if tensor else ())
        for a in names:
            size *= mesh.shape[a]
        return size > 1 and dim % size == 0

    def leaf_spec(path: str, leaf) -> P:
        nd = len(leaf.shape)
        name = path.rsplit("/", 1)[-1]
        # (G, B, S, KV, hd) attention k/v: shard KV heads over `model`
        # when divisible, else the head_dim, else replicate (GQA head
        # counts < 16 are common; head_dim 128/64 always divides).
        if name in ("k", "v", "cross_k", "cross_v") and nd == 5:
            kv, hd = leaf.shape[3], leaf.shape[4]
            if _div(kv):
                return P(None, b_axis, None, tensor, None)
            if _div(hd):
                return P(None, b_axis, None, None, tensor)
            return P(None, b_axis, None, None, None)
        if name == "h" and nd == 5:          # mamba (G,B,nh,n,p)
            nh = leaf.shape[2]
            return P(None, b_axis, tensor if _div(nh) else None, None,
                     None)
        if name == "conv" and nd == 4:       # (G,B,K,din)
            din = leaf.shape[3]
            return P(None, b_axis, None, tensor if _div(din) else None)
        if nd >= 2:
            return P(None, b_axis, *([None] * (nd - 2)))
        return P(None)

    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(f"{path}/{k}", v) for k, v in node.items()}
        return _sds(node.shape, node.dtype, mesh, leaf_spec(path, node))

    return walk("", shapes)


def decode_specs(cfg: ModelConfig, shape: InputShape,
                 mesh: Mesh) -> Dict[str, Any]:
    batch = shape.global_batch
    enc_len = shape.seq_len if cfg.is_encdec else 0
    return {
        "tokens": _sds((batch, 1), jnp.int32, mesh,
                       _batch_spec(batch, mesh, 1)),
        "index": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": cache_specs(cfg, batch, shape.seq_len, mesh, enc_len),
    }


def input_specs(cfg: ModelConfig, shape: InputShape,
                mesh: Mesh) -> Dict[str, Any]:
    if shape.kind in ("train", "prefill"):
        return token_specs(cfg, shape.global_batch, shape.seq_len, mesh)
    return decode_specs(cfg, shape, mesh)
