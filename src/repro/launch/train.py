"""Generic distributed LM training driver.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --reduced --steps 50 [--batch 8 --seq 128] [--federated K]

Builds the model from the config registry, a host mesh over available
devices, synthetic LM token streams, and runs ``train_step`` (or the
federated variant with DAS scheduling when ``--federated K`` is given —
the paper's technique as a first-class training feature).  Checkpoints
via ``repro.checkpoint`` every ``--ckpt-every`` steps.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, optim
from repro.checkpoint import msgpack_ckpt
from repro.core import diversity, scheduler, wireless
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib


def synthetic_lm_batch(key, batch: int, seq: int, vocab: int,
                       num_clients: int = 0):
    """Markov-ish synthetic token stream (learnable bigram structure)."""
    k1, k2 = jax.random.split(key)
    base = jax.random.randint(k1, (batch, seq + 1), 0, vocab)
    # bigram structure: half the positions continue t+1 = (t*7+3) % vocab
    cont = (base[:, :-1] * 7 + 3) % vocab
    use = jax.random.bernoulli(k2, 0.5, cont.shape)
    tokens = jnp.where(use, cont, base[:, 1:])
    tokens = jnp.concatenate([base[:, :1], tokens], axis=1)
    batch_d = {"inputs": tokens[:, :-1], "labels": tokens[:, 1:]}
    if num_clients:
        batch_d = {k: v.reshape(num_clients, batch // num_clients, seq)
                   for k, v in batch_d.items()}
    return batch_d


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--federated", type=int, default=0,
                    help="number of FEEL clients (0 = plain training)")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-path", default="/tmp/repro_ckpt.msgpack")
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.federated:
        # global batch must split evenly into client shards
        args.batch = max(args.batch, args.federated)
        args.batch -= args.batch % args.federated
    ocfg = optim.OptimizerConfig(learning_rate=args.lr, warmup_steps=10)
    mesh = mesh_lib.make_host_mesh()
    print(f"[train] {cfg.name} reduced={args.reduced} mesh="
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))}")

    key = jax.random.key(0)
    state = steps_lib.init_train_state(key, cfg, ocfg)

    if args.federated:
        kc = args.federated
        step = jax.jit(steps_lib.make_federated_train_step(
            cfg, ocfg, mesh, num_clients=kc))
        wcfg = wireless.WirelessConfig()
        net = wireless.sample_network(jax.random.key(1), kc, wcfg)
        sizes = jax.random.randint(jax.random.key(2), (kc,), 50, 1500)
        ages = jnp.zeros((kc,), jnp.int32)
        # synthetic per-client label histograms drive the diversity index
        hists = jax.random.randint(jax.random.key(3), (kc, 10), 0,
                                   30).astype(jnp.float32)
        scfg = scheduler.SchedulerConfig(method="das", n_min=2,
                                         iterations_max=4)
    else:
        step = jax.jit(steps_lib.make_train_step(cfg, ocfg, mesh))

    t0 = time.time()
    for i in range(args.steps):
        key, kb, kf, ks = jax.random.split(key, 4)
        batch = synthetic_lm_batch(kb, args.batch, args.seq,
                                   cfg.vocab_size,
                                   args.federated)
        if args.federated:
            idx = diversity.diversity_index(
                label_hists=hists, data_sizes=sizes, ages=ages)
            gains = wireless.sample_fading(kf, net)
            res = scheduler.schedule(ks, idx, ages, sizes, gains, net,
                                     wcfg, scfg)
            ages = jnp.where(res.selected > 0, 0, ages + 1)
            batch = dict(batch, selected=res.selected,
                         sizes=sizes.astype(jnp.float32))
        state, metrics = step(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            ce = float(metrics["ce"])
            extra = (f" sel={int(metrics['n_selected'])}"
                     if args.federated else "")
            print(f"[train] step {i:4d} ce={ce:.4f} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step){extra}",
                  flush=True)
        if args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            msgpack_ckpt.save(args.ckpt_path, state["params"],
                              meta={"step": i + 1, "arch": cfg.name})
            print(f"[train] checkpoint -> {args.ckpt_path}")
    print(f"[train] done: final ce={float(metrics['ce']):.4f}")


if __name__ == "__main__":
    main()
