import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""§Perf hillclimb re-lowers: the optimized variants of the three pairs.

Pair A  qwen3-14b x train_4k      — §Perf iterations 0-6 (sequence
        parallelism, padded head sharding, vocab-dim sharding, scan
        microbatching) are already the shipped defaults; its baseline
        row in dryrun_results.json IS the optimized state.  This script
        re-measures it with iteration 7 (below) applied.
Pair B  qwen3-moe-235b x train_4k — MoE dispatch: ragged (sort +
        lax.ragged_dot; does not partition under GSPMD) -> GShard
        grouped einsum dispatch (moe_impl="dense_grouped").
Pair C  qwen2-vl-72b x prefill_32k — prefill output cache pinned to the
        decode cache sharding via out_shardings (was: replicated).

Iteration 7 (pair A): attn q-chunk 1024 -> 2048 (halves mask/bias
overhead + score-buffer count; napkin: ~no FLOP change, fewer
intermediate materializations).

Usage:  PYTHONPATH=src python -m repro.launch.hillclimb \
            [--out dryrun_hillclimb.json]
"""

import argparse
import dataclasses
import json

from repro import configs
from repro.configs import shapes as shapes_lib
from repro.launch import dryrun, mesh as mesh_lib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="dryrun_hillclimb.json")
    ap.add_argument("--pairs", default="A,B,C")
    args = ap.parse_args()
    mesh = mesh_lib.make_production_mesh(multi_pod=False)
    results = []
    pairs = set(args.pairs.split(","))

    def run(tag, cfg, shape_name, **kw):
        shape = shapes_lib.get_shape(shape_name)
        rec = dryrun.lower_one(cfg, shape, mesh, **kw)
        rec["tag"] = tag
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=float)
        print(f"[hillclimb] {tag}: mem={rec['memory']} "
              f"cost={rec['cost']} coll="
              f"{rec['collectives']['total_bytes'] / 1e9:.2f}GB",
              flush=True)

    if "B" in pairs:
        # Pair B: grouped dispatch (now the config default).
        cfg = configs.get("qwen3_moe_235b_a22b")
        run("B/moe-grouped-dispatch/train_4k", cfg, "train_4k")
    if "C" in pairs:
        # Pair C: prefill with pinned cache out_shardings (now default
        # in lower_one).
        cfg = configs.get("qwen2_vl_72b")
        run("C/prefill-pinned-cache/prefill_32k", cfg, "prefill_32k")
    if "A" in pairs:
        # Pair A iteration 7: larger attention q-chunk.
        cfg = dataclasses.replace(configs.get("qwen3_14b"),
                                  attn_chunk=2048)
        run("A/qchunk-2048/train_4k", cfg, "train_4k")
    if "B2" in pairs:
        # Pair B iteration 2: bigger dispatch groups (fewer, larger
        # einsums; same capacity math).
        cfg = dataclasses.replace(configs.get("qwen3_moe_235b_a22b"),
                                  moe_group_size=8192)
        run("B/moe-group-8192/train_4k", cfg, "train_4k")
    if "C2" in pairs:
        # Pair C iteration 2: prefill attention q-chunk 2048.
        cfg = dataclasses.replace(configs.get("qwen2_vl_72b"),
                                  attn_chunk=2048)
        run("C/qchunk-2048/prefill_32k", cfg, "prefill_32k")


if __name__ == "__main__":
    main()
