"""Production mesh construction (DESIGN.md §5).

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

Functions only — importing this module never touches jax device state;
``dryrun.py`` sets XLA_FLAGS for 512 host devices *before* any jax import.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over the actually-present devices (tests / examples)."""
    n = len(jax.devices())
    data = max(1, n // model_axis)
    return jax.make_mesh((data, model_axis), ("data", "model"))


def make_scenario_mesh(max_devices: int = 0,
                       axis: str = "scenario") -> jax.sharding.Mesh:
    """1-D mesh over the present devices for Monte-Carlo scenario sharding.

    The sweep engine (``repro.sweep.engine``) partitions the scenario
    axis of the batched FEEL sim over this mesh; with one device it
    degenerates to a 1-element mesh and ``shard_map`` becomes a no-op
    partitioning (same compiled program as the plain vmap).  On CPU,
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before
    importing jax) exposes N host devices — the CI sweep smoke exercises
    the real multi-device path that way.
    """
    n = len(jax.devices())
    if max_devices > 0:
        n = min(n, max_devices)
    return jax.make_mesh((n,), (axis,))


def scenario_shard_count(mesh: jax.sharding.Mesh,
                         axis: str = "scenario") -> int:
    return mesh.shape[axis] if axis in mesh.axis_names else 1


def data_parallel_size(mesh: jax.sharding.Mesh) -> int:
    size = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            size *= mesh.shape[a]
    return size
