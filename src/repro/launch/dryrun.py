import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh).

Run:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
        --shape train_4k [--multi-pod] [--all] [--out results.json]

For each combination this:

1. builds the production mesh (16x16 single pod / 2x16x16 multi-pod) over
   512 forced host devices,
2. lowers + compiles the appropriate step (train_step / prefill_step /
   serve_step) with ShapeDtypeStruct inputs (no allocation),
3. prints ``memory_analysis()`` (per-device bytes — proves it fits) and
   ``cost_analysis()`` (FLOPs / bytes for the §Roofline terms),
4. parses the post-SPMD HLO for collective operand bytes, and
5. appends a JSON record consumed by ``benchmarks/roofline.py``.

Cost-accounting methodology: XLA's cost analysis counts a ``while`` body
ONCE, so with scan-over-layers the per-program numbers exclude repeated
groups.  The harness therefore lowers each model **twice** (1-group and
2-group depth); the difference is the exact per-group cost and
``total = cost(1g) + (G-1) * (cost(2g) - cost(1g))``.  Inner chunk loops
(attention q-chunks, SSD chunks) are unrolled in the model code so the
per-group delta is exact.  The sLSTM time scan is corrected analytically
(trip count = seq_len) — see EXPERIMENTS.md §Dry-run.
"""

import argparse
import dataclasses
import json
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro import configs, optim
from repro.configs import shapes as shapes_lib
from repro.launch import mesh as mesh_lib
from repro.launch import specs as specs_lib
from repro.launch import steps as steps_lib
from repro.models import transformer
from repro.sharding import params as psharding


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s32|s8|u8|u32|s64|pred|f8\w*)"
                       r"\[([\d,]*)\]")
_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4,
                "s64": 8, "s8": 1, "u8": 1, "u32": 4, "pred": 1}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Sum byte sizes of all array shapes in an HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt[:4] if dt.startswith("f8")
                                      else dt, 4)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Parse post-SPMD HLO: per-collective-kind operand bytes + counts.

    Counts the *output* shape of each collective instruction (the bytes
    that cross links, up to the algorithm factor) — the standard proxy.
    """
    per_kind: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_OPS}
    counts: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
                     r"([\w-]+)\(", s)
        if not m:
            continue
        type_str, op = m.groups()
        op = op.rstrip("-start").rstrip("-done") if False else op
        base = op
        for suffix in ("-start", "-done"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base in per_kind:
            if op.endswith("-done"):
                continue  # avoid double counting start/done pairs
            per_kind[base] += _shape_bytes(type_str)
            counts[base] += 1
    return {"bytes": per_kind, "counts": counts,
            "total_bytes": sum(per_kind.values())}


# ---------------------------------------------------------------------------
# Lower + compile one (arch, shape, mesh)
# ---------------------------------------------------------------------------

def _cost_dict(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def _mem_dict(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        try:
            out[attr] = float(getattr(ma, attr))
        except AttributeError:
            pass
    return out


def default_microbatches(cfg, shape, mesh) -> int:
    """Gradient-accumulation factor keeping per-device activations sane.

    Target <= ~4 sequences per device per microbatch at seq 4k for models
    with d_model >= 4096 (see §Perf iteration log)."""
    if shape.kind != "train" or cfg.d_model < 4096:
        return 1
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    per_dev = shape.global_batch // max(dp, 1)
    return max(1, per_dev // 4)


def lower_one(cfg, shape: shapes_lib.InputShape, mesh,
              ocfg: Optional[optim.OptimizerConfig] = None,
              verbose: bool = True,
              microbatches: Optional[int] = None,
              mem_only: bool = False,
              with_mb_memory: bool = True) -> Dict[str, Any]:
    """Lower + compile; returns the record with costs & collectives."""
    ocfg = ocfg or optim.OptimizerConfig(
        state_dtype=("bfloat16" if cfg.arch_type in ("hybrid",)
                     or "235b" in cfg.name or "398b" in cfg.name
                     or "72b" in cfg.name else "float32"))
    if microbatches is None:
        microbatches = default_microbatches(cfg, shape, mesh)
    specs = specs_lib.input_specs(cfg, shape, mesh)
    t0 = time.time()

    mem_override = None
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            state_shapes = steps_lib.train_state_shapes(cfg, ocfg)
            pspecs = psharding.param_shardings(state_shapes["params"], cfg,
                                               mesh)
            opt_specs = _opt_shardings(state_shapes, pspecs, mesh)
            state_in = {"params": _attach(state_shapes["params"], pspecs),
                        "opt": opt_specs}
            # Costs/collectives from the microbatches=1 program (grad
            # accumulation is FLOP-identical); memory from the scan-of-
            # microbatches program (true sequenced peak).  mem_only skips
            # the cost program (multi-pod pass: sharding proof + memory).
            if mem_only and microbatches > 1:
                step = steps_lib.make_train_step(
                    cfg, ocfg, mesh, microbatches=microbatches)
                lowered = jax.jit(step).lower(state_in, specs)
            else:
                step = steps_lib.make_train_step(cfg, ocfg, mesh,
                                                 microbatches=1)
                lowered = jax.jit(step).lower(state_in, specs)
                if microbatches > 1 and with_mb_memory:
                    step_mb = steps_lib.make_train_step(
                        cfg, ocfg, mesh, microbatches=microbatches)
                    mem_override = _mem_dict(
                        jax.jit(step_mb).lower(state_in, specs).compile())
        elif shape.kind == "prefill":
            params_shapes = transformer.init_shapes(cfg)
            pspecs = psharding.param_shardings(params_shapes, cfg, mesh)
            step = steps_lib.make_prefill_step(cfg, mesh)
            # Pin the output cache to the decode cache sharding —
            # without out_shardings XLA materializes a replicated cache
            # (76 GB/device at qwen2-vl prefill_32k; §Perf-hillclimb).
            from jax.sharding import NamedSharding, PartitionSpec as P
            cache_sds = specs_lib.cache_specs(
                cfg, shape.global_batch, shape.seq_len, mesh,
                enc_len=shape.seq_len if cfg.is_encdec else 0)
            cache_out = jax.tree_util.tree_map(lambda s: s.sharding,
                                               cache_sds)
            logits_out = NamedSharding(mesh, P(None, None, None))
            lowered = jax.jit(
                step, out_shardings=(logits_out, cache_out)).lower(
                _attach(params_shapes, pspecs), specs)
        else:  # decode
            params_shapes = transformer.init_shapes(cfg)
            pspecs = psharding.param_shardings(params_shapes, cfg, mesh)
            step = steps_lib.make_serve_step(cfg, mesh)
            lowered = jax.jit(step).lower(
                _attach(params_shapes, pspecs), specs["tokens"],
                specs["cache"], specs["index"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    rec: Dict[str, Any] = {
        "arch": cfg.name,
        "shape": shape.name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "mesh_axes": list(mesh.axis_names),
        "num_devices": int(mesh.devices.size),
        "cost": _cost_dict(compiled),
        "memory": mem_override or _mem_dict(compiled),
        "memory_mb1": _mem_dict(compiled) if mem_override else None,
        "collectives": collective_bytes(compiled.as_text()),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "microbatches": microbatches,
    }
    if verbose:
        print(f"[dryrun] {cfg.name} x {shape.name} x {rec['mesh']}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis: {rec['memory']}")
        print(f"  cost_analysis:   {rec['cost']}")
        print(f"  collectives:     total "
              f"{rec['collectives']['total_bytes'] / 1e9:.3f} GB "
              f"{rec['collectives']['counts']}")
    return rec


def _attach(shapes, shardings):
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def _opt_shardings(state_shapes, pspecs, mesh):
    """Optimizer moments shard like their parameters; scalars replicate."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())
    out = {}
    for k, v in state_shapes["opt"].items():
        if k in ("mu", "nu"):
            out[k] = jax.tree_util.tree_map(
                lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                   sharding=sh),
                v, pspecs)
        else:
            out[k] = jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=rep)
    return out


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def run_matrix(arch_ids, shape_names, multi_pod_list, out_path: str,
               corrected: bool = True) -> int:
    """Staged execution (single CPU core budget):

    Stage 1 — baseline lower+compile for every (arch, shape, mesh):
      single-pod full record (cost + microbatched memory), multi-pod
      memory-mode proof.  This is the hard deliverable; dump after each.
    Stage 2 — depth-correction lowers (1-group / 2-group) per single-pod
      pair, updating ``cost_corrected``.
    """
    results = []
    failures = 0
    pairs = []
    for arch in arch_ids:
        cfg = configs.get(arch)
        for sname in shape_names:
            shape = shapes_lib.get_shape(sname)
            ok, why = shapes_lib.applicable(cfg, shape)
            if not ok:
                print(f"[dryrun] SKIP {cfg.name} x {sname}: {why}",
                      flush=True)
                results.append({"arch": cfg.name, "shape": sname,
                                "skipped": why})
                continue
            pairs.append((cfg, shape))

    # Stage 1: every pair, every mesh.
    for cfg, shape in pairs:
        for mp in multi_pod_list:
            mesh = mesh_lib.make_production_mesh(multi_pod=mp)
            try:
                rec = lower_one(cfg, shape, mesh, mem_only=mp)
                results.append(rec)
                print(f"[dryrun] OK {cfg.name} x {shape.name} x "
                      f"{rec['mesh']}", flush=True)
            except Exception as e:  # noqa: BLE001
                failures += 1
                print(f"[dryrun] FAIL {cfg.name} x {shape.name} "
                      f"multi_pod={mp}: {type(e).__name__}: {e}",
                      flush=True)
                results.append({"arch": cfg.name, "shape": shape.name,
                                "multi_pod": mp, "error": str(e)})
            _dump(results, out_path)

    # Stage 2: depth corrections (single-pod only).
    if corrected:
        mesh = mesh_lib.make_production_mesh(multi_pod=False)
        for cfg, shape in pairs:
            if cfg.num_groups == 1 or cfg.layer_mode == "unroll":
                for rec in results:
                    if (rec.get("arch") == cfg.name
                            and rec.get("shape") == shape.name
                            and rec.get("num_devices") == 256):
                        rec["cost_corrected"] = dict(rec["cost"])
                        rec["collectives_corrected_bytes"] = \
                            rec["collectives"]["total_bytes"]
                continue
            try:
                c1 = lower_one(dataclasses.replace(
                    cfg, num_layers=cfg.pattern_period), shape, mesh,
                    verbose=False, microbatches=1)
                c2 = lower_one(dataclasses.replace(
                    cfg, num_layers=2 * cfg.pattern_period), shape, mesh,
                    verbose=False, microbatches=1)
                for rec in results:
                    if (rec.get("arch") == cfg.name
                            and rec.get("shape") == shape.name
                            and rec.get("num_devices") == 256):
                        g = cfg.num_groups
                        rec["cost_corrected"] = {
                            key: c1["cost"][key] + (g - 1) *
                            (c2["cost"][key] - c1["cost"][key])
                            for key in ("flops", "bytes")}
                        rec["collectives_corrected_bytes"] = (
                            c1["collectives"]["total_bytes"] + (g - 1) *
                            (c2["collectives"]["total_bytes"]
                             - c1["collectives"]["total_bytes"]))
                print(f"[dryrun] CORRECTED {cfg.name} x {shape.name}",
                      flush=True)
            except Exception as e:  # noqa: BLE001
                print(f"[dryrun] CORRECTION-FAIL {cfg.name} x "
                      f"{shape.name}: {e}", flush=True)
            _dump(results, out_path)
    _dump(results, out_path)
    return failures


def _dump(results, out_path):
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1, default=float)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 mesh (default also runs 16x16)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-corrected", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()

    archs = configs.ARCH_IDS if (args.all or not args.arch) \
        else [args.arch]
    shape_names = ([s.name for s in shapes_lib.SHAPES]
                   if (args.all or not args.shape) else [args.shape])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = run_matrix(archs, shape_names, meshes, args.out,
                          corrected=not args.no_corrected)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
