"""Non-IID shard partitioner (paper §VI-A.2) + stacked client tensors.

The paper's protocol: sort by label, cut into ``num_shards`` shards of
``shard_size`` images (1200 x 50 for MNIST), then give each of the K
devices between 1 and 30 shards at random.  Every shard is single-class,
so a device's class coverage is the number of *distinct* classes among its
shards — the non-IID and unbalanced regime the diversity index targets.

Because shard draws ~U[1,30] over K=100 devices would request ~1550 of the
1200 shards, draws are proportionally rescaled (floor 1) to fit, matching
the paper's "allocate until exhausted" reading.

Output is a :class:`ClientDataset`: dense (K, cap, ...) arrays with a
validity mask, the shape the vmapped local-SGD trainer consumes.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PartitionSpec:
    num_devices: int = 100
    num_shards: int = 1200
    shard_size: int = 50
    min_shards: int = 1
    max_shards: int = 30
    test_fraction: float = 0.1  # paper: keep 10% for test


@dataclasses.dataclass
class ClientDataset:
    """Stacked per-client training data + global test split."""

    images: jnp.ndarray   # (K, cap, H, W) uint8
    labels: jnp.ndarray   # (K, cap) int32
    mask: jnp.ndarray     # (K, cap) float32, 1 = valid sample
    sizes: jnp.ndarray    # (K,) int32 = mask.sum(axis=1)
    test_images: jnp.ndarray  # (T, H, W) uint8
    test_labels: jnp.ndarray  # (T,) int32

    @property
    def num_devices(self) -> int:
        return self.images.shape[0]

    @property
    def capacity(self) -> int:
        return self.images.shape[1]


def arrival_affinity(label_hists: jnp.ndarray,
                     mix_uniform: float = 0.1) -> jnp.ndarray:
    """Per-device arrival class distribution for the streaming subsystem.

    A device keeps receiving data shaped like its shard partition — the
    paper's "depends on the local environment and usage pattern" — so the
    affinity is its initial class profile, floored by a uniform mixture
    so every class stays reachable (pure single-shard devices would
    otherwise never diversify and the drift processes would be inert).

    Args:
      label_hists: (…, K, C) initial class-count histograms.
      mix_uniform: weight of the uniform component in [0, 1].

    Returns: (…, K, C) rows summing to 1.
    """
    h = label_hists.astype(jnp.float32)
    num_classes = h.shape[-1]
    total = jnp.sum(h, axis=-1, keepdims=True)
    base = jnp.where(total > 0.0, h / jnp.maximum(total, 1.0),
                     1.0 / num_classes)
    return (1.0 - mix_uniform) * base + mix_uniform / num_classes


def draw_shard_counts(rng: np.random.Generator,
                      spec: PartitionSpec) -> np.ndarray:
    """Per-device shard counts, U[min,max] rescaled to fit the shard pool."""
    counts = rng.integers(spec.min_shards, spec.max_shards + 1,
                          size=spec.num_devices)
    total = int(counts.sum())
    if total > spec.num_shards:
        scaled = np.maximum(
            spec.min_shards,
            np.floor(counts * spec.num_shards / total).astype(np.int64))
        # Trim any residual overshoot from the largest holders.
        while scaled.sum() > spec.num_shards:
            i = int(np.argmax(scaled))
            scaled[i] -= 1
        counts = scaled
    return counts.astype(np.int64)


def partition(images: np.ndarray, labels: np.ndarray, seed: int,
              spec: PartitionSpec = PartitionSpec()) -> ClientDataset:
    """Apply the paper's shard protocol to a label-sorted dataset."""
    n = spec.num_shards * spec.shard_size
    if images.shape[0] < n:
        raise ValueError(
            f"need {n} samples for {spec.num_shards}x{spec.shard_size} "
            f"shards, got {images.shape[0]}")
    order = np.argsort(labels[:n], kind="stable")   # sort by digit label
    images, labels = images[:n][order], labels[:n][order]

    rng = np.random.default_rng(seed)
    # Hold out test samples per shard position (10%), keeping shards intact
    # for the remaining 90%: we instead hold out whole shards.
    num_test_shards = max(1, int(round(spec.num_shards *
                                       spec.test_fraction)))
    shard_ids = rng.permutation(spec.num_shards)
    test_shards = shard_ids[:num_test_shards]
    train_shards = shard_ids[num_test_shards:]

    def shard_slice(s: int) -> slice:
        return slice(s * spec.shard_size, (s + 1) * spec.shard_size)

    test_images = np.concatenate([images[shard_slice(s)]
                                  for s in test_shards])
    test_labels = np.concatenate([labels[shard_slice(s)]
                                  for s in test_shards])

    pool_spec = dataclasses.replace(spec, num_shards=len(train_shards))
    counts = draw_shard_counts(rng, pool_spec)
    cap = int(counts.max()) * spec.shard_size

    h, w = images.shape[1:]
    cli_images = np.zeros((spec.num_devices, cap, h, w), np.uint8)
    cli_labels = np.zeros((spec.num_devices, cap), np.int32)
    cli_mask = np.zeros((spec.num_devices, cap), np.float32)

    cursor = 0
    for k in range(spec.num_devices):
        got = 0
        for _ in range(int(counts[k])):
            s = train_shards[cursor]
            cursor += 1
            sl = shard_slice(s)
            cli_images[k, got:got + spec.shard_size] = images[sl]
            cli_labels[k, got:got + spec.shard_size] = labels[sl]
            cli_mask[k, got:got + spec.shard_size] = 1.0
            got += spec.shard_size
    sizes = cli_mask.sum(axis=1).astype(np.int32)

    return ClientDataset(
        images=jnp.asarray(cli_images),
        labels=jnp.asarray(cli_labels),
        mask=jnp.asarray(cli_mask),
        sizes=jnp.asarray(sizes),
        test_images=jnp.asarray(test_images),
        test_labels=jnp.asarray(test_labels),
    )
