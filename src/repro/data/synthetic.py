"""Synthetic class-prototype image dataset (offline MNIST stand-in).

The container has no dataset downloads, so the paper's MNIST is replaced by
a synthetic 10-class image problem with the *same shard-partition protocol*
(``repro.data.partition``).  The scheduling claims under reproduction
depend on the non-IID/unbalanced shard structure — which classes a device
holds and how many samples — not on MNIST pixels, so a learnable
class-conditional generator preserves the experiment's semantics.

Generator: per class, a smooth random prototype image plus a low-rank
"style" subspace; a sample is ``prototype + style @ coeffs + pixel noise``,
clipped to [0, 1].  A 2-layer MLP reaches >90% accuracy with enough
class coverage, and a model trained on a subset of classes generalizes
poorly — exactly the regime the diversity index exploits.

Images are stored as uint8 to keep the stacked client tensors small; cast
to float32 per batch.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    num_classes: int = 10
    image_size: int = 28
    style_rank: int = 4        # intra-class variation components
    style_scale: float = 0.35
    noise_scale: float = 0.15
    smooth_passes: int = 2     # box-blur passes for spatial coherence


def _smooth(img: np.ndarray, passes: int) -> np.ndarray:
    """Cheap box blur so prototypes have spatial structure (conv-friendly)."""
    for _ in range(passes):
        padded = np.pad(img, ((1, 1), (1, 1)), mode="edge")
        img = (padded[:-2, 1:-1] + padded[2:, 1:-1] + padded[1:-1, :-2]
               + padded[1:-1, 2:] + padded[1:-1, 1:-1]) / 5.0
    return img


def make_prototypes(seed: int, spec: SyntheticSpec) -> tuple[np.ndarray,
                                                             np.ndarray]:
    """Returns (prototypes (C,H,W), styles (C,R,H,W)) as float32 in ~[0,1]."""
    rng = np.random.default_rng(seed)
    h = spec.image_size
    protos = []
    styles = []
    for _ in range(spec.num_classes):
        p = _smooth(rng.standard_normal((h, h)), spec.smooth_passes)
        p = (p - p.min()) / max(p.max() - p.min(), 1e-6)
        protos.append(p)
        s = np.stack([
            _smooth(rng.standard_normal((h, h)), spec.smooth_passes)
            for _ in range(spec.style_rank)
        ])
        styles.append(s)
    return (np.asarray(protos, np.float32), np.asarray(styles, np.float32))


def generate(seed: int, samples_per_class: int,
             spec: SyntheticSpec = SyntheticSpec()) -> tuple[np.ndarray,
                                                             np.ndarray]:
    """Generate the full dataset: (images uint8 (N,H,W), labels int32 (N,)).

    Samples are ordered by class (the paper sorts by label before
    sharding), so the partitioner can slice shards directly.
    """
    protos, styles = make_prototypes(seed, spec)
    rng = np.random.default_rng(seed + 1)
    images = []
    labels = []
    for c in range(spec.num_classes):
        coeff = rng.standard_normal(
            (samples_per_class, spec.style_rank)).astype(np.float32)
        x = (protos[c][None]
             + spec.style_scale * np.einsum("nr,rhw->nhw", coeff, styles[c])
             + spec.noise_scale * rng.standard_normal(
                 (samples_per_class, spec.image_size, spec.image_size)
             ).astype(np.float32))
        x = np.clip(x, 0.0, 1.0)
        images.append((x * 255.0).astype(np.uint8))
        labels.append(np.full((samples_per_class,), c, np.int32))
    return np.concatenate(images), np.concatenate(labels)


def to_float(images: Array) -> Array:
    """uint8 -> float32 in [0, 1]."""
    return images.astype(jnp.float32) / 255.0


def sample_arrival_rates(key: Array, num_devices: int, rate: float,
                         spread: float = 0.5) -> Array:
    """Per-device mean arrivals/round for the streaming subsystem.

    ``rate * U[1 - spread, 1 + spread]`` — heterogeneous device activity
    (a phone in heavy use collects data faster than an idle one) around
    the configured mean, mirroring how the partitioner draws unequal
    shard counts.  Traceable: the streaming processes call this inside
    their jitted ``init`` with a per-scenario key.
    """
    u = jax.random.uniform(key, (num_devices,),
                           minval=1.0 - spread, maxval=1.0 + spread)
    return rate * u
