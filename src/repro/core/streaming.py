"""Streaming-data FEEL subsystem (DESIGN.md §7).

The paper computes the diversity index once from a frozen partition,
but the data FEEL actually schedules over "depends on the local
environment and usage pattern" — it drifts while training runs.  This
module makes every scenario non-stationary: traceable data-arrival
processes live inside the ``lax.scan`` carry of the FEEL drivers
(``core.federated``), so per-device dataset sizes and ``(K, C)``
class-count matrices evolve round by round under jit *and* under the
scenario ``vmap``, and the scheduler re-ranks on *current* data
richness instead of the round-0 snapshot (Hu et al. 2305.01238,
Taik et al. 2201.11247).

Three pieces:

* :class:`StreamConfig` — static process/selection knobs, carried on
  ``FLConfig.stream`` (``None`` = legacy static-data behavior,
  bit-for-bit).
* :class:`StreamState` — the per-round carry: the live class-count
  matrix, the staleness signal (decayed mass of not-yet-trained-on
  arrivals), the previous round's selection, and the process-owned
  fields (arrival affinity/rates, drift class, round counter).  One
  uniform pytree for every process, so the scan carry structure never
  depends on which process runs.
* the **arrival-process protocol** — ``init(key, hists0, cfg) ->
  StreamState`` and ``sample(key, state, cfg) -> (deltas, arrivals,
  state)``, both traceable (fixed shapes, no data-dependent Python
  control flow, §1 invariant).  ``deltas`` is a ``(K, C)`` count
  change: positive entries are arrivals, negative entries evictions.
  ``arrivals`` is the ``(K,)`` nonnegative mass of *new* data — the
  process must report it explicitly because it is not derivable from
  the net deltas (an eviction can cancel an arrival in the same class,
  which would silently starve the staleness signal).  Implementations
  register by name (:func:`register_process`), mirroring the allocator
  registry, so new workloads plug in without touching the drivers.

Built-in processes: ``static`` (zero deltas — the degenerate check),
``poisson`` (per-class Poisson arrivals along each device's shard
affinity), ``drift`` (bursty label drift: all arrivals land on a
per-device class that re-draws at random rounds), ``shift`` (a global
class-distribution wave rotating through label space), ``evict``
(Poisson arrivals + proportional buffer eviction), ``trace`` (replay
per-round deltas from a user-supplied ``(R, K, C)`` array — register
``Trace(deltas)`` over the data-less placeholder).

The per-round refresh — count-delta accumulation -> diversity-index
refresh -> staleness decay — is one fused pass (:func:`refresh`):
the pure-jnp reference ``kernels/ref.py::stream_update`` by default,
or the Pallas kernel ``kernels/stream_update.py`` with
``use_kernel=True`` (grid over the scenario lane).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from repro.data import partition as partition_lib
from repro.data import synthetic

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Static streaming knobs (hashable; rides on ``FLConfig.stream``)."""

    process: str = "poisson"      # arrival-process registry name
    rate: float = 20.0            # mean arrivals / device / round
    rate_spread: float = 0.5      # per-device rate heterogeneity (+- frac)
    mix_uniform: float = 0.1      # affinity floor (partition.arrival_affinity)
    burst_prob: float = 0.15      # drift: per-round class re-draw prob
    evict_frac: float = 0.05      # evict: buffer fraction dropped / round
    shift_period: float = 8.0     # shift: rounds per class-wave step
    shift_sharpness: float = 2.0  # shift: wave concentration (kappa)
    staleness_decay: float = 0.8  # lambda: backlog decay per round
    size_cap: float = 0.0         # per-device count cap (0: buffer capacity)
    use_kernel: bool = False      # refresh via the Pallas stream_update


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StreamState:
    """Scan-carried streaming state (leaves gain an (S,) axis under vmap).

    ``hists``/``staleness``/``selected_prev`` are driver-owned (updated
    by :func:`refresh` + the round's scheduling decision); ``affinity``/
    ``rates``/``drift_class``/``round`` belong to the arrival process.
    """

    hists: Array          # (K, C) live class-count matrix
    staleness: Array      # (K,)   decayed not-yet-trained-on arrival mass
    selected_prev: Array  # (K,)   previous round's selection {0,1}
    round: Array          # ()     int32 rounds elapsed
    affinity: Array       # (K, C) arrival class distribution
    rates: Array          # (K,)   mean arrivals / round
    drift_class: Array    # (K,)   int32 current drift class
    bank: object = None   # (R, K, C) per-scenario trace (TraceBank only)

    def tree_flatten(self):
        return ((self.hists, self.staleness, self.selected_prev,
                 self.round, self.affinity, self.rates,
                 self.drift_class, self.bank), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def base_state(hists0: Array, affinity: Array | None = None,
               rates: Array | None = None,
               drift_class: Array | None = None) -> StreamState:
    """Fresh :class:`StreamState` around the round-0 histograms.

    Helper for process ``init`` implementations (including custom test
    processes): fills driver-owned fields with their zero start and
    process fields with inert defaults unless given.
    """
    hists0 = hists0.astype(jnp.float32)
    zeros_k = jnp.zeros(hists0.shape[:-1], jnp.float32)
    if affinity is None:
        affinity = jnp.full_like(hists0, 1.0 / hists0.shape[-1])
    if rates is None:
        rates = zeros_k
    if drift_class is None:
        drift_class = jnp.zeros(hists0.shape[:-1], jnp.int32)
    return StreamState(hists=hists0, staleness=zeros_k,
                       selected_prev=zeros_k,
                       round=jnp.zeros((), jnp.int32),
                       affinity=affinity, rates=rates,
                       drift_class=drift_class)


@runtime_checkable
class ArrivalProcess(Protocol):
    """The arrival-process protocol consumed by the FEEL drivers."""

    def init(self, key: Array, hists0: Array,
             cfg: StreamConfig) -> StreamState:
        """Build the round-0 carry from the initial histograms."""
        ...

    def sample(self, key: Array, state: StreamState,
               cfg: StreamConfig
               ) -> Tuple[Array, Array, StreamState]:
        """One round's ``(K, C)`` count deltas, the ``(K,)`` nonnegative
        arrival mass, and the updated process fields.

        Must not touch the driver-owned fields (``hists``,
        ``staleness``, ``selected_prev``) — :func:`refresh` and the
        scheduling decision own those.
        """
        ...


@dataclasses.dataclass(frozen=True)
class Static:
    """Zero deltas: streaming plumbing on, data frozen (parity checks)."""

    def init(self, key: Array, hists0: Array,
             cfg: StreamConfig) -> StreamState:
        del key
        return base_state(hists0)

    def sample(self, key: Array, state: StreamState,
               cfg: StreamConfig) -> Tuple[Array, Array, StreamState]:
        del key, cfg
        return (jnp.zeros_like(state.hists),
                jnp.zeros_like(state.rates), state)


def _rates_and_affinity(key: Array, hists0: Array,
                        cfg: StreamConfig) -> Tuple[Array, Array]:
    rates = synthetic.sample_arrival_rates(key, hists0.shape[-2],
                                           cfg.rate, cfg.rate_spread)
    affinity = partition_lib.arrival_affinity(hists0, cfg.mix_uniform)
    return rates, affinity


@dataclasses.dataclass(frozen=True)
class Poisson:
    """Per-class Poisson arrivals along each device's shard affinity."""

    def init(self, key: Array, hists0: Array,
             cfg: StreamConfig) -> StreamState:
        rates, affinity = _rates_and_affinity(key, hists0, cfg)
        return base_state(hists0, affinity=affinity, rates=rates)

    def sample(self, key: Array, state: StreamState,
               cfg: StreamConfig) -> Tuple[Array, Array, StreamState]:
        del cfg
        lam = state.rates[..., None] * state.affinity
        deltas = jax.random.poisson(key, lam).astype(jnp.float32)
        return deltas, jnp.sum(deltas, axis=-1), state


@dataclasses.dataclass(frozen=True)
class Drift:
    """Bursty label drift: arrivals pile onto one per-device class that
    re-draws uniformly with probability ``burst_prob`` each round —
    a device's environment snaps to a new mode, not a smooth blend."""

    def init(self, key: Array, hists0: Array,
             cfg: StreamConfig) -> StreamState:
        # No affinity: arrivals land on the drift class, nothing else.
        rates = synthetic.sample_arrival_rates(key, hists0.shape[-2],
                                               cfg.rate, cfg.rate_spread)
        drift_class = jnp.argmax(hists0, axis=-1).astype(jnp.int32)
        return base_state(hists0, rates=rates, drift_class=drift_class)

    def sample(self, key: Array, state: StreamState,
               cfg: StreamConfig) -> Tuple[Array, Array, StreamState]:
        k_burst, k_class, k_count = jax.random.split(key, 3)
        num_classes = state.hists.shape[-1]
        shape = state.drift_class.shape
        redraw = jax.random.bernoulli(k_burst, cfg.burst_prob, shape)
        fresh = jax.random.randint(k_class, shape, 0, num_classes,
                                   jnp.int32)
        drift_class = jnp.where(redraw, fresh, state.drift_class)
        counts = jax.random.poisson(k_count,
                                    state.rates).astype(jnp.float32)
        onehot = jax.nn.one_hot(drift_class, num_classes,
                                dtype=jnp.float32)
        deltas = counts[..., None] * onehot
        return deltas, counts, dataclasses.replace(
            state, drift_class=drift_class)


@dataclasses.dataclass(frozen=True)
class Shift:
    """Global class-distribution shift: a von-Mises-style wave rotates
    through label space, advancing one class every ``shift_period``
    rounds — every device's arrivals follow the same moving mixture."""

    def init(self, key: Array, hists0: Array,
             cfg: StreamConfig) -> StreamState:
        # No affinity: every device's arrivals follow the global wave.
        rates = synthetic.sample_arrival_rates(key, hists0.shape[-2],
                                               cfg.rate, cfg.rate_spread)
        return base_state(hists0, rates=rates)

    def sample(self, key: Array, state: StreamState,
               cfg: StreamConfig) -> Tuple[Array, Array, StreamState]:
        num_classes = state.hists.shape[-1]
        classes = jnp.arange(num_classes, dtype=jnp.float32)
        centre = state.round.astype(jnp.float32) / cfg.shift_period
        phase = 2.0 * jnp.pi * (classes - centre) / num_classes
        wave = jax.nn.softmax(cfg.shift_sharpness * jnp.cos(phase))
        lam = state.rates[..., None] * wave
        deltas = jax.random.poisson(key, lam).astype(jnp.float32)
        return deltas, jnp.sum(deltas, axis=-1), state


@dataclasses.dataclass(frozen=True)
class Evict:
    """Poisson arrivals + proportional buffer eviction: each round a
    fraction ``evict_frac`` of the held counts ages out, so the live
    distribution chases the arrival distribution."""

    def init(self, key: Array, hists0: Array,
             cfg: StreamConfig) -> StreamState:
        rates, affinity = _rates_and_affinity(key, hists0, cfg)
        return base_state(hists0, affinity=affinity, rates=rates)

    def sample(self, key: Array, state: StreamState,
               cfg: StreamConfig) -> Tuple[Array, Array, StreamState]:
        lam = state.rates[..., None] * state.affinity
        arrived = jax.random.poisson(key, lam).astype(jnp.float32)
        deltas = arrived - cfg.evict_frac * state.hists
        # Arrival mass is the raw arrivals, NOT the positive net deltas:
        # under heavy eviction the per-class netting cancels arrivals,
        # but the device's distribution is still turning over — its
        # staleness must keep accumulating.
        return deltas, jnp.sum(arrived, axis=-1), state


@dataclasses.dataclass(frozen=True)
class Trace:
    """Replay per-round count deltas from a user-supplied ``(R, K, C)``
    array (ROADMAP trace-driven item, minimal version).

    ``sample`` at round ``r`` returns row ``deltas[r % R]`` — traces
    shorter than the run wrap around.  The reported arrival mass is the
    positive part of the trace deltas summed over classes; a trace that
    nets an arrival against an eviction inside one class under-reports
    that turnover (record arrivals and evictions in separate trace rows
    if the staleness signal must see both).  Register with data::

        streaming.register_process(
            "trace", lambda: streaming.Trace(deltas), overwrite=True)

    then run with ``StreamConfig(process="trace")`` — the built-in
    ``"trace"`` registration has no data and raises with this recipe.
    The replay is deterministic (keys unused) and traceable: the trace
    array closes over the compiled simulation as a constant and round
    indexing is a dynamic gather, so the process composes with the scan
    driver and the scenario vmap (every lane replays the same trace on
    its own schedule).
    """

    deltas: object = None        # (R, K, C) array-like

    def _array(self) -> Array:
        if self.deltas is None:
            raise ValueError(
                "trace process has no data — register your trace first: "
                "streaming.register_process('trace', lambda: "
                "streaming.Trace(deltas), overwrite=True) with a "
                "(rounds, K, C) delta array")
        d = jnp.asarray(self.deltas, jnp.float32)
        if d.ndim != 3:
            raise ValueError(f"trace deltas must be (R, K, C), got "
                             f"shape {d.shape}")
        return d

    def init(self, key: Array, hists0: Array,
             cfg: StreamConfig) -> StreamState:
        del key, cfg
        d = self._array()
        if d.shape[-2:] != hists0.shape[-2:]:
            raise ValueError(
                f"trace deltas {d.shape} do not match the (K, C) device "
                f"histograms {hists0.shape}")
        return base_state(hists0)

    def sample(self, key: Array, state: StreamState,
               cfg: StreamConfig) -> Tuple[Array, Array, StreamState]:
        del key, cfg
        d = self._array()
        row = jnp.take(d, state.round % d.shape[0], axis=0)
        arrivals = jnp.sum(jnp.maximum(row, 0.0), axis=-1)
        return row, arrivals, state


@dataclasses.dataclass(frozen=True)
class TraceBank:
    """Replay from a *bank* of traces: one ``(R, K, C)`` trace per
    scenario, drawn at ``init`` off the scenario key.

    :class:`Trace` replays the same deltas on every scenario lane — a
    Monte-Carlo sweep over S scenarios then averages S copies of one
    workload.  ``TraceBank`` holds an ``(S_bank, R, K, C)`` stack
    (e.g. :func:`trace_bank` over per-day usage logs) and each
    scenario's ``init`` draws one trace uniformly from the bank with
    its own scenario key, so the sweep averages over real workload
    variation.  The drawn trace rides in ``StreamState.bank`` — an
    ordinary carry leaf, so the draw composes with the scenario vmap
    and ``batch == S singles`` holds bitwise (the row choice depends
    only on the per-scenario key, never on the batch shape).  Register
    with data::

        streaming.register_process(
            "trace_bank", lambda: streaming.TraceBank(bank),
            overwrite=True)

    The built-in ``"trace_bank"`` registration has no data and raises
    with this recipe.
    """

    bank: object = None          # (S_bank, R, K, C) array-like

    def _array(self) -> Array:
        if self.bank is None:
            raise ValueError(
                "trace_bank process has no data — register your bank "
                "first: streaming.register_process('trace_bank', "
                "lambda: streaming.TraceBank(bank), overwrite=True) "
                "with an (S_bank, rounds, K, C) delta stack (see "
                "streaming.trace_bank / usage_log_to_deltas)")
        b = jnp.asarray(self.bank, jnp.float32)
        if b.ndim != 4:
            raise ValueError(f"trace bank must be (S_bank, R, K, C), "
                             f"got shape {b.shape}")
        return b

    def init(self, key: Array, hists0: Array,
             cfg: StreamConfig) -> StreamState:
        del cfg
        b = self._array()
        if b.shape[-2:] != hists0.shape[-2:]:
            raise ValueError(
                f"trace bank {b.shape} does not match the (K, C) device "
                f"histograms {hists0.shape}")
        row_id = jax.random.randint(key, (), 0, b.shape[0])
        st = base_state(hists0)
        return dataclasses.replace(st, bank=jnp.take(b, row_id, axis=0))

    def sample(self, key: Array, state: StreamState,
               cfg: StreamConfig) -> Tuple[Array, Array, StreamState]:
        del key, cfg
        d = state.bank
        row = jnp.take(d, state.round % d.shape[0], axis=0)
        arrivals = jnp.sum(jnp.maximum(row, 0.0), axis=-1)
        return row, arrivals, state


def usage_log_to_deltas(records, num_rounds: int, num_devices: int,
                        num_classes: int,
                        t_start: float | None = None,
                        t_end: float | None = None):
    """Bucket a usage log into the ``(R, K, C)`` delta array the
    ``trace`` / ``trace_bank`` processes replay.

    ``records`` is an iterable of usage events — JSONL strings or
    already-decoded dicts — each carrying a timestamp ``"t"``, a device
    id ``"device"``, a class label ``"class"`` and an optional signed
    ``"count"`` (default 1; negative counts record evictions).  The
    span ``[t_start, t_end)`` (default: the log's own extent) is cut
    into ``num_rounds`` equal windows and each event's count lands in
    its window's ``(device, class)`` cell; events outside the span or
    the device/class range are dropped.  Pure host-side numpy — runs
    once at setup, the result closes over the compiled simulation as a
    constant.
    """
    import json as _json
    import numpy as np
    parsed = []
    for rec in records:
        if isinstance(rec, (str, bytes)):
            rec = rec.strip()
            if not rec:
                continue
            rec = _json.loads(rec)
        parsed.append((float(rec["t"]), int(rec["device"]),
                       int(rec["class"]), float(rec.get("count", 1))))
    deltas = np.zeros((num_rounds, num_devices, num_classes), np.float32)
    if not parsed:
        return deltas
    times = np.array([p[0] for p in parsed])
    t0 = float(times.min()) if t_start is None else float(t_start)
    t1 = float(times.max()) if t_end is None else float(t_end)
    span = max(t1 - t0, 1e-12)
    for t, dev, cls, count in parsed:
        r = int((t - t0) / span * num_rounds)
        if t == t1 and t_end is None:
            r = num_rounds - 1       # closed right edge of the log span
        if not (0 <= r < num_rounds and 0 <= dev < num_devices
                and 0 <= cls < num_classes):
            continue
        deltas[r, dev, cls] += count
    return deltas


def trace_bank(logs, num_rounds: int, num_devices: int,
               num_classes: int, t_start: float | None = None,
               t_end: float | None = None):
    """Stack per-scenario usage logs into the ``(S_bank, R, K, C)``
    array :class:`TraceBank` draws from — one
    :func:`usage_log_to_deltas` pass per log (e.g. one log per day)."""
    import numpy as np
    if not logs:
        raise ValueError("trace_bank needs at least one usage log")
    return np.stack([
        usage_log_to_deltas(log, num_rounds, num_devices, num_classes,
                            t_start=t_start, t_end=t_end)
        for log in logs])


_PROCESSES: Dict[str, Callable[[], ArrivalProcess]] = {}


def register_process(name: str, factory: Callable[[], ArrivalProcess],
                     overwrite: bool = False) -> None:
    """Register an arrival-process factory (zero-arg -> process)."""
    if name in _PROCESSES and not overwrite:
        raise ValueError(f"arrival process {name!r} already registered")
    _PROCESSES[name] = factory


def process_names() -> tuple[str, ...]:
    return tuple(sorted(_PROCESSES))


def get_process(name: str) -> ArrivalProcess:
    """Build the named arrival process."""
    try:
        factory = _PROCESSES[name]
    except KeyError:
        raise ValueError(f"unknown arrival process {name!r}; registered: "
                         f"{process_names()}") from None
    return factory()


register_process("static", Static)
register_process("poisson", Poisson)
register_process("drift", Drift)
register_process("shift", Shift)
register_process("evict", Evict)
# Data-less placeholders: reserve the names and raise the registration
# recipe; users overwrite them with `Trace(deltas)` / `TraceBank(bank)`
# bound to real data.
register_process("trace", Trace)
register_process("trace_bank", TraceBank)


def refresh(hists: Array, deltas: Array, arrivals: Array,
            staleness: Array, selected_prev: Array, cfg: StreamConfig,
            size_cap: float | None = None,
            interpret: bool | None = None
            ) -> Tuple[Array, Array, Array]:
    """One round's fused data refresh: ``(hists', stats, staleness')``.

    ``stats`` packs ``[gini, shannon, size]`` per device — the inputs of
    ``diversity.diversity_index_from_stats``; ``arrivals`` is the
    process-reported ``(K,)`` arrival mass feeding the staleness carry.
    Dispatches to the Pallas ``stream_update`` kernel when
    ``cfg.use_kernel`` (grid over the scenario lane), else to the
    pure-jnp reference — the same function that serves as the kernel's
    property-test oracle, so both paths share one contract
    (``kernels/ref.py::stream_update``).  ``size_cap`` overrides
    ``cfg.size_cap`` (the drivers pass the padded-buffer capacity so the
    training workload stays within the physical sample buffers).
    """
    cap = cfg.size_cap if size_cap is None else size_cap
    if cfg.use_kernel:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.stream_update(
            hists, deltas, arrivals, staleness, selected_prev,
            decay=cfg.staleness_decay, size_cap=cap, interpret=interpret)
    from repro.kernels import ref as kernel_ref
    return kernel_ref.stream_update(
        hists, deltas, arrivals, staleness, selected_prev,
        decay=cfg.staleness_decay, size_cap=cap)
