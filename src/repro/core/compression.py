"""Compressed-uplink subsystem (DESIGN.md §9).

The paper's objective (Eq. 15) trades completion time against
transmission energy, yet the reproduction's devices all uploaded the
same scalar ``WirelessConfig.model_bits`` — the single biggest lever on
Eq. 6's upload time/energy was hard-coded.  This module makes the
uplink payload a **per-device, codec-dependent quantity** and makes the
uplink itself lossy with error feedback, so scheduling, Sub2 bandwidth
allocation and the sweep engine become a genuinely joint
compression-aware system (update compression is a first-class FEEL
design lever — see PAPERS.md: "Federated Edge Learning: Design Issues
and Challenges"; per-device channel-aware adaptation follows the
importance/channel-aware scheduling line of Ren et al.).

Three pieces:

* :class:`CompressionConfig` — static codec knobs, carried on
  ``FLConfig.compression`` (``None`` = legacy uncompressed behavior,
  bit-for-bit).
* the **codec protocol** — ``payload_bits(ccfg, wcfg, gains, index) ->
  (K,) uplink bits`` and ``apply(updates, residual, selected, key,
  ccfg, gains, index) -> (decoded values, new residual)``, both
  traceable (fixed shapes, no data-dependent Python control flow, the
  §1 invariant).  ``payload_bits`` feeds the wireless time/energy model
  and every Sub2 solver (the scalar ``model_bits`` became a ``(K,)``
  broadcastable input end-to-end); ``apply`` is the lossy round trip
  the FEEL round body runs on the flattened ``(K, P)`` update matrix.
  Implementations register by name (:func:`register_codec`), mirroring
  the allocator/arrival-process registries.
* the **error-feedback residual** — ``(K, P)`` carried in the scan
  state of both FEEL drivers (``core.federated``): what a lossy round
  fails to transmit is added back into the next round's update
  (Seide et al. / EF-SGD), and only devices that actually transmitted
  consume their backlog.

Built-in codecs: ``none`` (identity, payload = ``model_bits``),
``quant`` (stochastic ``bit_width``-bit quantization), ``topk``
(magnitude sparsification with per-entry index-cost accounting) and
``adaptive`` (per-device bit width picked from channel gain +
diversity rank: weak channels transmit coarser updates, rich-data
devices earn more bits).

**Payload accounting.**  The wireless model's ``model_bits`` is the
paper's nominal update size (Table I: 100 kbit), deliberately decoupled
from the simulated training model's parameter count; codecs keep that
decoupling by scaling the *nominal* payload — e.g. ``quant`` at b bits
uploads ``model_bits * b / full_bits`` — while the lossy value round
trip applies to the real updates.  ``topk`` charges each kept entry its
value bits plus ``ceil(log2(n_coords))`` index bits (the sparse
coordinate must be named).

The fused residual-accumulate -> quantize/top-k -> dequantize pass runs
as the pure-jnp reference ``kernels/ref.py::compress_update`` by
default, or the Pallas kernel ``kernels/compress.py`` with
``use_kernel=True`` (grid over the scenario lane, like
``stream_update``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Protocol, Tuple, \
    runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import wireless

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Static uplink-codec knobs (hashable; rides on
    ``FLConfig.compression``)."""

    codec: str = "quant"          # codec registry name
    bit_width: int = 8            # b: quantization levels = 2^b - 1
    topk_frac: float = 0.05       # fraction of coordinates topk keeps
    full_bits: float = 32.0       # uncompressed bits per coordinate
    value_bits: float = 0.0       # topk bits per kept value (0: full)
    index_bits: float = 0.0       # topk bits per index (0: ceil(log2 n))
    error_feedback: bool = True   # carry the EF residual in the scan
    adaptive_min_bits: int = 4    # adaptive: floor bit width
    adaptive_max_bits: int = 12   # adaptive: ceiling bit width
    adaptive_channel_weight: float = 0.5  # channel vs diversity mix
    thresh_iters: int = 32        # topk threshold-bisection trips
    use_kernel: bool = False      # route apply through kernels/compress


def nominal_coords(ccfg: CompressionConfig,
                   wcfg: wireless.WirelessConfig) -> float:
    """Coordinate count of the *nominal* payload: model_bits/full_bits."""
    return max(wcfg.model_bits / ccfg.full_bits, 1.0)


def topk_index_bits(ccfg: CompressionConfig,
                    wcfg: wireless.WirelessConfig) -> float:
    """Per-kept-entry index cost: configured, or ceil(log2(n_coords))."""
    if ccfg.index_bits > 0.0:
        return ccfg.index_bits
    return float(math.ceil(math.log2(max(nominal_coords(ccfg, wcfg),
                                         2.0))))


def rank01(x: Array) -> Array:
    """Rank-normalize to [0, 1] along the device axis (ties broken by
    position; constant input ranks by position too — acceptable for a
    scoring signal).  vmap/scan-safe: pure argsort, fixed shapes."""
    k = x.shape[-1]
    order = jnp.argsort(jnp.argsort(x, axis=-1), axis=-1)
    return order.astype(jnp.float32) / max(k - 1, 1)


def adaptive_bit_widths(ccfg: CompressionConfig, gains: Array,
                        index: Array) -> Array:
    """Per-device bit width from channel gain + diversity rank.

    ``score = w * rank(gain) + (1-w) * rank(index)`` mapped onto
    ``[adaptive_min_bits, adaptive_max_bits]`` and rounded: a device on
    a weak channel pays more time/energy per uploaded bit, so it
    transmits a coarser update; a device whose data the scheduler ranks
    rich earns resolution (its update moves the aggregate more under
    FedAvg's |D_k| weighting).  Returns float widths (whole numbers) so
    the quantizer's ``2^b - 1`` stays traceable.
    """
    w = ccfg.adaptive_channel_weight
    score = w * rank01(gains) + (1.0 - w) * rank01(index)
    span = float(ccfg.adaptive_max_bits - ccfg.adaptive_min_bits)
    bits = jnp.round(ccfg.adaptive_min_bits + score * span)
    return jnp.clip(bits, ccfg.adaptive_min_bits, ccfg.adaptive_max_bits)


@runtime_checkable
class Codec(Protocol):
    """The uplink-codec protocol consumed by the FEEL drivers."""

    def payload_bits(self, ccfg: CompressionConfig,
                     wcfg: wireless.WirelessConfig, gains: Array,
                     index: Array) -> Optional[Array]:
        """Per-device uplink bits ``(K,)`` for this round — the Eq. 6/9
        payload the scheduler and Sub2 solvers price.  ``None`` means
        "the nominal scalar ``wcfg.model_bits``" and keeps every solver
        on its scalar-payload path (bitwise-identical scheduling,
        including the `fused_pgd` kernel lane) — the ``none`` codec
        returns it."""
        ...

    def apply(self, updates: Array, residual: Array, selected: Array,
              key: Array, ccfg: CompressionConfig, gains: Array,
              index: Array) -> Tuple[Array, Array]:
        """Lossy round trip over the flattened ``(K, P)`` updates.

        Returns ``(decoded values, new residual)`` — the decoded values
        are what FedAvg aggregates; the residual advance must follow
        the error-feedback contract (``kernels/ref.py::
        compress_update``): only selected devices consume backlog.
        """
        ...


def _roundtrip(updates: Array, residual: Array, selected: Array,
               widths: Array, key: Array, ccfg: CompressionConfig, *,
               mode: str, keep: int = 0) -> Tuple[Array, Array]:
    """Shared fused pass: kernel or jnp reference per ``use_kernel``."""
    if mode == "quant":
        noise = jax.random.uniform(key, updates.shape)
    else:
        # topk is deterministic: a (K,) placeholder row satisfies the
        # shared signature without streaming a dead (K, P) block into
        # the kernel launch.
        noise = jnp.zeros(updates.shape[:-1], jnp.float32)
    if ccfg.use_kernel:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.compress_update(
            updates, residual, widths, selected, noise, mode=mode,
            keep=keep, thresh_iters=ccfg.thresh_iters)
    from repro.kernels import ref as kernel_ref
    return kernel_ref.compress_update(
        updates, residual, widths, selected, noise, mode=mode,
        keep=keep, thresh_iters=ccfg.thresh_iters)


@dataclasses.dataclass(frozen=True)
class NoneCodec:
    """Identity uplink: full-precision payload, no loss, no residual —
    the degenerate check (and the paper's original protocol)."""

    def payload_bits(self, ccfg, wcfg, gains, index):
        # None, not full(model_bits): the nominal payload keeps every
        # solver on its scalar path — bitwise-identical scheduling to
        # an uncompressed run, and the fused_pgd kernel lane survives
        # (per-device arrays route it to the jnp fallback).
        del ccfg, wcfg, gains, index
        return None

    def apply(self, updates, residual, selected, key, ccfg, gains,
              index):
        del selected, key, ccfg, gains, index
        return updates, residual


@dataclasses.dataclass(frozen=True)
class Quant:
    """Stochastic ``bit_width``-bit quantization (QSGD-style): payload
    shrinks by ``bit_width / full_bits``; the stochastic rounding is
    unbiased and the error-feedback residual absorbs the variance."""

    def payload_bits(self, ccfg, wcfg, gains, index):
        del index
        bits = wcfg.model_bits * ccfg.bit_width / ccfg.full_bits
        return jnp.full(gains.shape, bits, jnp.float32)

    def apply(self, updates, residual, selected, key, ccfg, gains,
              index):
        del gains, index
        widths = jnp.full(updates.shape[:-1], float(ccfg.bit_width),
                          jnp.float32)
        return _roundtrip(updates, residual, selected, widths, key,
                          ccfg, mode="quant")


def _topk_keep(ccfg: CompressionConfig, num_coords: int) -> int:
    return max(1, min(num_coords,
                      int(round(ccfg.topk_frac * num_coords))))


@dataclasses.dataclass(frozen=True)
class TopK:
    """Magnitude top-k sparsification with index-cost accounting: each
    kept entry ships its value (``value_bits``, default full precision)
    plus the coordinate index (``ceil(log2(n_coords))`` bits)."""

    def payload_bits(self, ccfg, wcfg, gains, index):
        del index
        vb = ccfg.value_bits or ccfg.full_bits
        per_entry = vb + topk_index_bits(ccfg, wcfg)
        bits = wcfg.model_bits * ccfg.topk_frac * per_entry \
            / ccfg.full_bits
        return jnp.full(gains.shape, bits, jnp.float32)

    def apply(self, updates, residual, selected, key, ccfg, gains,
              index):
        del gains, index
        keep = _topk_keep(ccfg, updates.shape[-1])
        widths = jnp.full(updates.shape[:-1], ccfg.full_bits,
                          jnp.float32)
        return _roundtrip(updates, residual, selected, widths, key,
                          ccfg, mode="topk", keep=keep)


@dataclasses.dataclass(frozen=True)
class Adaptive:
    """Channel- and data-aware bit allocation: per-device quantization
    width from :func:`adaptive_bit_widths` — the payload *and* the
    value loss both follow the per-round channel draw and diversity
    ranking, so weak-channel devices upload fewer bits (regression-
    pinned in ``tests/test_compression.py``)."""

    def payload_bits(self, ccfg, wcfg, gains, index):
        widths = adaptive_bit_widths(ccfg, gains, index)
        return wcfg.model_bits * widths / ccfg.full_bits

    def apply(self, updates, residual, selected, key, ccfg, gains,
              index):
        widths = adaptive_bit_widths(ccfg, gains, index)
        return _roundtrip(updates, residual, selected, widths, key,
                          ccfg, mode="quant")


_CODECS: Dict[str, Callable[[], Codec]] = {}


def register_codec(name: str, factory: Callable[[], Codec],
                   overwrite: bool = False) -> None:
    """Register an uplink-codec factory (zero-arg -> codec)."""
    if name in _CODECS and not overwrite:
        raise ValueError(f"codec {name!r} already registered")
    _CODECS[name] = factory


def codec_names() -> tuple[str, ...]:
    return tuple(sorted(_CODECS))


def get_codec(name: str) -> Codec:
    """Build the named uplink codec."""
    try:
        factory = _CODECS[name]
    except KeyError:
        raise ValueError(f"unknown codec {name!r}; registered: "
                         f"{codec_names()}") from None
    return factory()


register_codec("none", NoneCodec)
register_codec("quant", Quant)
register_codec("topk", TopK)
register_codec("adaptive", Adaptive)


def apply_codec(codec: Codec, updates: Array, residual: Array,
                selected: Array, key: Array, ccfg: CompressionConfig,
                gains: Array, index: Array,
                success: Optional[Array] = None) -> Tuple[Array, Array]:
    """Driver entry: codec round trip + the error-feedback gate.

    With ``error_feedback=False`` the residual is forced back to zero
    after the round (the codec still *sees* the zero residual, so the
    lossy path is the plain biased compressor) — one switch, one code
    path, and the scan carry shape never changes.

    ``success`` (fault subsystem, DESIGN.md §10) is the per-device
    upload-landed mask: only devices that actually *delivered* consume
    their residual backlog, and a scheduled device whose upload failed
    folds its entire raw update back into the residual — the compressed
    payload is lost on the air, but under error feedback the
    information is not (``tests/test_faults.py`` proves the round trip
    is lossless: ``r' = r + u`` bitwise for a failed device).  ``None``
    keeps the failure-blind contract unchanged.
    """
    transmitted = selected if success is None else selected * success
    c, res = codec.apply(updates, residual, transmitted, key, ccfg, gains,
                         index)
    if success is not None and ccfg.error_feedback:
        failed = selected * (1.0 - success)
        res = res + updates * failed[..., None]
    if not ccfg.error_feedback:
        res = jnp.zeros_like(res)
    return c, res


__all__ = ["CompressionConfig", "Codec", "NoneCodec", "Quant", "TopK",
           "Adaptive", "register_codec", "get_codec", "codec_names",
           "apply_codec", "adaptive_bit_widths", "rank01",
           "nominal_coords", "topk_index_bits"]
