"""Unreliable-edge subsystem: fault injection + retransmission (DESIGN.md §10).

Every driver in this repo used to assume a perfectly reliable edge: once
DAS admits a device, its upload always lands.  The FEEL design-issues
survey names outages and stragglers a first-order challenge (PAPERS.md,
arXiv 2009.00081), and intermittent availability is routine in streaming
FEEL (arXiv 2305.01238) — so this module makes unreliability a
first-class, traceable part of the round:

* **Channel outages** — each upload attempt independently fails with
  ``drop_prob`` (short-timescale interference), and a round whose
  sampled fading power ``|h|^2`` falls below ``deep_fade_threshold``
  fails *every* attempt (block fading: the deep fade outlives the
  retransmission window).
* **Retransmission with exponential backoff** — a failed attempt is
  retried up to ``max_retries`` times; attempt ``j`` waits
  ``backoff_base * 2^{j-1}`` upload-times before retrying.  Realized
  airtime and energy flow through ``wireless.upload_time`` /
  ``upload_energy`` via their ``airtime_mult`` argument, and the
  *expected* airtime multiplier (:func:`expected_time_mult`, closed form
  over the attempt distribution) inflates the payload bits the
  scheduler prices, so Sub2's deadline accounts for retries before they
  happen.
* **Heavy-tailed compute stragglers** — with ``straggler_prob`` a
  device's computation time is multiplied by ``straggler_scale *
  Pareto(straggler_tail)`` (tail index 2 keeps the mean finite but the
  variance borderline — the classic straggler tail).
* **Mid-round dropouts** — with ``dropout_prob`` the device dies before
  its upload starts: zero attempts, zero uplink energy, but its (possibly
  straggling) compute time still holds the synchronous round open.

All draws are keyed by a per-round fault key split from the scan carry's
PRNG stream, so faults are bit-for-bit reproducible across the scan
driver, the vmapped batch driver, and the legacy loop — the parity
contracts of DESIGN.md §3 extend to faulty runs unchanged.  A per-device
empirical-reliability EMA (:func:`reliability_update`) rides the scan
carry and feeds the scheduler's ``reliability_discount`` hook, making
selection failure-aware without any host round trip.

``FLConfig.faults = None`` (the default) is bitwise identical to the
pre-fault behavior: no extra key split, no carry extras, no changed op.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import wireless

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Static fault-process knobs (hashable; rides on ``FLConfig.faults``).

    The all-defaults instance is *inert*: every probability is 0 and no
    retries are allowed, so enabling it changes payload pricing and the
    realized accounting by exactly nothing (``tests/test_faults.py``
    asserts bitwise equality against ``faults=None``).
    """

    drop_prob: float = 0.0          # per-attempt Bernoulli upload failure
    deep_fade_threshold: float = 0.0  # |h|^2 floor; below it = block fade
    max_retries: int = 0            # retransmissions after the first try
    backoff_base: float = 0.5       # backoff before attempt j: base*2^(j-1)
    straggler_prob: float = 0.0     # P(device straggles this round)
    straggler_scale: float = 4.0    # compute-time multiplier floor
    straggler_tail: float = 2.0     # Pareto tail index of the multiplier
    dropout_prob: float = 0.0       # P(device dies before uploading)
    reliability_ema: float = 0.0    # EMA rate beta; 0 freezes rel at 1
    overprovision: int = 0          # extra devices Sub1 admits (n_min +=)
    # Chronic per-device heterogeneity (ROADMAP "chronically
    # heterogeneous faults", minimal version): when > 0, each device's
    # per-attempt drop rate is drawn ONCE per scenario as a
    # mean-preserving log-normal spread around ``drop_prob``
    # (:func:`chronic_rates`), so unreliability is *persistent per
    # device* and the reliability-EMA discount has signal to learn.
    # 0 keeps the i.i.d. process bitwise unchanged.  The config stays a
    # hashable static — the realized ``(K,)`` rates are a traced
    # operand threaded through :func:`sample_faults`.
    chronic_spread: float = 0.0     # sigma of log-normal per-device rates


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FaultDraw:
    """One round's realized fault process over the K device axis.

    ``success`` and ``attempts`` describe the *upload*: a device with
    ``attempts == 0`` dropped out mid-round; one with ``attempts > 0``
    and ``success == 0`` burned its whole retry budget.  All float32 so
    the draw vmaps over scenario lanes without dtype promotion.
    """

    success: Array       # (K,) {0,1} upload eventually landed
    attempts: Array      # (K,) attempts actually transmitted (0 = dropout)
    compute_mult: Array  # (K,) >= 1 computation-time multiplier

    def tree_flatten(self):
        return ((self.success, self.attempts, self.compute_mult), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def attempt_budget(cfg: FaultConfig) -> int:
    """Total transmission attempts a device may spend: 1 + retries."""
    return 1 + max(int(cfg.max_retries), 0)


def is_inert(cfg: FaultConfig) -> bool:
    """True when the config can never produce an observable fault.

    All fault probabilities zero, no deep-fade floor, no
    overprovisioning bump, and a frozen reliability EMA: such a config
    is *semantically* ``faults=None`` (retry budgets and backoff bases
    are irrelevant when nothing ever fails).  ``reliability_ema > 0``
    is deliberately non-inert — even with every upload succeeding,
    ``(1-beta) + beta`` need not round to exactly 1.0 in float32, so a
    live EMA could drift the scheduler's discount off the no-fault
    trajectory.
    """
    return (cfg.drop_prob <= 0.0 and cfg.deep_fade_threshold <= 0.0
            and cfg.straggler_prob <= 0.0 and cfg.dropout_prob <= 0.0
            and cfg.overprovision <= 0 and cfg.reliability_ema <= 0.0)


def active(cfg: Optional[FaultConfig]) -> Optional[FaultConfig]:
    """Normalize an inert config to ``None`` (the no-fault fast path).

    Every driver dispatches through this, so an all-zero
    :class:`FaultConfig` compiles the *same program* as ``faults=None``
    — the strongest possible form of the disabled-means-identical
    guarantee (bitwise, because it is the identical computation).
    """
    if cfg is None or is_inert(cfg):
        return None
    return cfg


def chronic_rates(key: Array, k: int,
                  cfg: FaultConfig) -> Optional[Array]:
    """Once-per-scenario ``(K,)`` per-device drop rates, or ``None``.

    Mean-preserving log-normal spread around the nominal rate:
    ``rate_k = drop_prob * exp(sigma * z_k - sigma^2 / 2)`` clipped to
    [0, 1], with ``sigma = chronic_spread`` and ``z_k ~ N(0, 1)`` drawn
    from a scenario-derived key.  Sampled *once* before the round loop
    and held fixed, so a device that rolls a bad rate stays bad for the
    whole run — the persistent signal the reliability EMA needs
    (i.i.d. per-round faults average out; EXPERIMENTS.md §Faults).
    Returns ``None`` (the scalar i.i.d. path, bitwise unchanged) when
    the spread or the nominal rate is zero.
    """
    if cfg.drop_prob <= 0.0 or cfg.chronic_spread <= 0.0:
        return None
    s = cfg.chronic_spread
    z = jax.random.normal(key, (k,))
    return jnp.clip(cfg.drop_prob * jnp.exp(s * z - 0.5 * s * s),
                    0.0, 1.0)


def sample_faults(key: Array, gains: Array, net: wireless.NetworkState,
                  cfg: FaultConfig,
                  drop_rates: Optional[Array] = None) -> FaultDraw:
    """Draw one round's fault realization (pure, traceable, vmap-safe).

    The deep fade is deterministic *within* the round — block fading
    means a faded channel stays faded for all ``attempt_budget``
    attempts — while the Bernoulli drops are independent per attempt
    (short interference bursts).  The fading power is recovered from the
    sampled gains as ``|h|^2 = gains / pathloss``, so the fade test sees
    exactly the channel the scheduler saw.  ``drop_rates`` (chronic
    per-device rates from :func:`chronic_rates`) replaces the scalar
    ``drop_prob`` in the per-attempt Bernoulli when supplied; ``None``
    is the i.i.d. path, bitwise identical to the pre-chronic draw.
    """
    k_drop, k_dropout, k_strag, k_tail = jax.random.split(key, 4)
    budget = attempt_budget(cfg)
    u_drop = jax.random.uniform(k_drop, gains.shape + (budget,))
    if drop_rates is None:
        dropped = u_drop < cfg.drop_prob
    else:
        dropped = u_drop < drop_rates[..., None]
    h2 = gains / jnp.maximum(net.pathloss, 1e-30)
    faded = h2 < cfg.deep_fade_threshold
    attempt_ok = (~dropped) & (~faded[..., None])
    any_ok = jnp.any(attempt_ok, axis=-1)
    # First successful attempt (1-based); a device that never succeeds
    # spends the whole budget before giving up.
    first = jnp.argmax(attempt_ok, axis=-1).astype(jnp.float32) + 1.0
    dropout = jax.random.uniform(k_dropout, gains.shape) < cfg.dropout_prob
    success = (any_ok & (~dropout)).astype(jnp.float32)
    attempts = jnp.where(dropout, 0.0,
                         jnp.where(any_ok, first, float(budget)))
    is_strag = jax.random.uniform(k_strag, gains.shape) < cfg.straggler_prob
    u_tail = jax.random.uniform(k_tail, gains.shape,
                                minval=1e-6, maxval=1.0)
    pareto = u_tail ** (-1.0 / max(cfg.straggler_tail, 1e-6))
    compute_mult = jnp.where(is_strag, cfg.straggler_scale * pareto, 1.0)
    return FaultDraw(success=success, attempts=attempts,
                     compute_mult=compute_mult)


def time_mult(attempts: Array, cfg: FaultConfig) -> Array:
    """Realized airtime multiplier of ``n`` attempts with backoff.

    ``n`` attempts transmit for ``n`` upload-times and wait
    ``backoff_base * (2^{n-1} - 1)`` upload-times in between (geometric
    sum of the per-retry backoffs).  Zero attempts (dropout) spend zero
    airtime.
    """
    n = attempts
    waits = cfg.backoff_base * (jnp.exp2(jnp.maximum(n, 1.0) - 1.0) - 1.0)
    return jnp.where(n > 0.0, n + waits, 0.0)


def expected_time_mult(cfg: FaultConfig) -> float:
    """E[airtime multiplier] over the Bernoulli attempt distribution.

    Closed form in plain Python (the result is a *static* trace
    constant): ``P(attempts=j) = q^{j-1}(1-q)`` for ``j < budget`` and
    ``q^{budget-1}`` for the final give-up-or-succeed attempt.  Deep
    fades and dropouts are left out on purpose — the fade depends on the
    current gains (already priced by the channel model) and a dropout
    spends *less* airtime, so pricing only the retry tax is the
    conservative deadline estimate.  ``drop_prob == 0`` gives exactly
    1.0, keeping fault-enabled-but-inert runs bitwise identical.
    Chronic per-device rates price at the *nominal* ``drop_prob`` (the
    spread's pre-clip mean) — the scheduler cannot see a scenario's
    realized rates at trace time, and the mean-rate price is the
    natural static stand-in.
    """
    budget = attempt_budget(cfg)
    q = min(max(float(cfg.drop_prob), 0.0), 1.0)
    if q <= 0.0 or budget == 1:
        return 1.0

    def mult(n: int) -> float:
        return n + cfg.backoff_base * (2.0 ** (n - 1) - 1.0)

    exp = sum(q ** (j - 1) * (1.0 - q) * mult(j)
              for j in range(1, budget))
    exp += q ** (budget - 1) * mult(budget)
    return float(exp)


def apply_faults(draw: FaultDraw, selected: Array, alpha: Array,
                 t_train: Array, gains: Array,
                 net: wireless.NetworkState,
                 wcfg: wireless.WirelessConfig,
                 payload_bits: Optional[Array], cfg: FaultConfig
                 ) -> Tuple[Array, Array, Array]:
    """Realized post-fault round accounting -> (ok, energy, round_time).

    Recomputes per-device upload time from the scheduler's bandwidth
    allocation at the *actual* payload (the scheduler priced
    retry-inflated bits; the air carries the real ones), then applies
    the realized attempt counts: airtime stretches by
    :func:`time_mult` (retries + backoff waits), energy charges
    ``attempts`` transmissions (backoff waits are radio-idle), and the
    synchronous round waits for every admitted device's straggling
    compute plus its full retry window — a failed device holds the
    round open exactly as long as its last futile attempt.
    """
    ok = selected * draw.success
    t_up = wireless.upload_time(alpha, gains, net.tx_power, wcfg,
                                payload_bits,
                                airtime_mult=time_mult(draw.attempts, cfg))
    t_up = jnp.where((selected > 0.0) & jnp.isfinite(t_up), t_up, 0.0)
    energy = wireless.upload_energy(alpha, gains, net.tx_power, wcfg,
                                    payload_bits,
                                    airtime_mult=draw.attempts)
    energy = jnp.where((selected > 0.0) & jnp.isfinite(energy),
                       energy, 0.0)
    t_total = jnp.where(selected > 0.0,
                        t_train * draw.compute_mult + t_up, 0.0)
    return ok, energy, jnp.max(t_total)


@functools.partial(jax.jit, static_argnames=("wcfg", "cfg"))
def fault_step(key: Array, selected: Array, alpha: Array, t_train: Array,
               gains: Array, net: wireless.NetworkState,
               wcfg: wireless.WirelessConfig,
               payload_bits: Optional[Array], cfg: FaultConfig,
               drop_rates: Optional[Array] = None
               ) -> Tuple[FaultDraw, Array, Array, Array]:
    """Jitted draw + realized accounting -> (draw, ok, energy, round_time).

    The legacy per-round loop must run the fault arithmetic under jit —
    not eagerly, op by op — because XLA's fusion (FMA contraction on
    CPU) rounds differently from the unfused op-at-a-time schedule, and
    the scan driver compiles the same expressions fused.  One shared
    jitted step keeps the scan == loop parity contract bitwise
    (``tests/test_faults.py``).
    """
    draw = sample_faults(key, gains, net, cfg, drop_rates)
    ok, energy, round_time = apply_faults(draw, selected, alpha, t_train,
                                          gains, net, wcfg, payload_bits,
                                          cfg)
    return draw, ok, energy, round_time


def reliability_update(rel: Array, selected: Array, ok: Array,
                       cfg: FaultConfig) -> Array:
    """Per-device empirical-reliability EMA (scan-carry resident).

    Only scheduled devices produce an observation (the server cannot
    see whether an unscheduled upload would have failed):
    ``rel' = (1-beta) rel + beta * success`` on the selected set,
    unchanged elsewhere.  ``beta == 0`` freezes the carry at its init
    (1.0), making the reliability signal inert.
    """
    beta = cfg.reliability_ema
    if beta <= 0.0:
        return rel
    obs = (ok > 0.0).astype(jnp.float32)
    return jnp.where(selected > 0.0,
                     (1.0 - beta) * rel + beta * obs, rel)


__all__ = ["FaultConfig", "FaultDraw", "active", "attempt_budget",
           "chronic_rates", "fault_step", "is_inert", "sample_faults",
           "time_mult", "expected_time_mult", "apply_faults",
           "reliability_update"]
