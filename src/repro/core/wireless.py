"""Wireless edge channel / time / energy models (paper §IV-C, Eq. 6-10).

The paper models a single-cell OFDMA uplink: ``K`` edge devices in a 500 m
square, one BS at the centre.  Per-device channel gain combines large-scale
pathloss and small-scale Rayleigh fading::

    |g_k|^2 = d_k^{-beta} * |h_k|^2 ,   h_k ~ Rayleigh(1)

Achievable uplink rate with bandwidth fraction ``alpha_k`` (Eq. 6)::

    r_k = alpha_k * B * log2(1 + g_k P_k / (alpha_k * B * N0))

Upload time (Eq. 9), transmit energy (Eq. 10), local training time (Eq. 8)
and synchronous round duration (Eq. 7) follow.

Everything is vectorized over the ``K`` device axis and jit-safe; the
scheduler (``core/scheduler.py``) composes these into Sub1/Sub2.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class WirelessConfig:
    """Static wireless-edge simulation parameters (paper Table I)."""

    bandwidth_hz: float = 1.0e6          # B: total OFDMA bandwidth
    noise_psd: float = 3.98e-21          # N0: -174 dBm/Hz
    pathloss_exp: float = 3.0            # beta (paper: alpha)
    cell_side_m: float = 500.0           # square side; BS at centre
    model_bits: float = 100e3            # s: update size (paper: 100 kbits)
    cpu_freq_range: tuple = (1.0e9, 3.0e9)      # f_k in [1, 3] GHz
    cycles_per_bit_range: tuple = (10.0, 30.0)  # C_k in [10, 30] cycles/bit
    tx_power_range: tuple = (1.0, 5.0)          # P_k in [1, 5] W
    bits_per_sample: float = 28.0 * 28.0 * 8.0  # MNIST-like greyscale image
    min_alpha: float = 1e-6              # numerical floor for bandwidth share


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class NetworkState:
    """Per-device random draws for one simulation run.

    ``pathloss`` is static across rounds; ``fading`` is redrawn each round
    via :func:`sample_fading`.
    """

    distance_m: Array      # (K,)
    pathloss: Array        # (K,)  d^-beta
    tx_power: Array        # (K,)  P_k [W]
    cpu_freq: Array        # (K,)  f_k [Hz]
    cycles_per_bit: Array  # (K,)  C_k

    def tree_flatten(self):
        return (
            (self.distance_m, self.pathloss, self.tx_power, self.cpu_freq,
             self.cycles_per_bit),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    @property
    def num_devices(self) -> int:
        return self.distance_m.shape[0]


def sample_network(key: Array, num_devices: int,
                   cfg: WirelessConfig) -> NetworkState:
    """Draw device placement and hardware capabilities (paper §VI-A.1)."""
    k_pos, k_pow, k_cpu, k_cyc = jax.random.split(key, 4)
    # Uniform placement in the square; BS at the centre.
    pos = jax.random.uniform(k_pos, (num_devices, 2),
                             minval=0.0, maxval=cfg.cell_side_m)
    centre = jnp.asarray([cfg.cell_side_m / 2.0, cfg.cell_side_m / 2.0])
    dist = jnp.linalg.norm(pos - centre, axis=-1)
    dist = jnp.maximum(dist, 1.0)  # 1 m exclusion zone
    pathloss = dist ** (-cfg.pathloss_exp)
    tx_power = jax.random.uniform(
        k_pow, (num_devices,), minval=cfg.tx_power_range[0],
        maxval=cfg.tx_power_range[1])
    cpu_freq = jax.random.uniform(
        k_cpu, (num_devices,), minval=cfg.cpu_freq_range[0],
        maxval=cfg.cpu_freq_range[1])
    cycles = jax.random.uniform(
        k_cyc, (num_devices,), minval=cfg.cycles_per_bit_range[0],
        maxval=cfg.cycles_per_bit_range[1])
    return NetworkState(dist, pathloss, tx_power, cpu_freq, cycles)


def sample_networks(key: Array, num_scenarios: int, num_devices: int,
                    cfg: WirelessConfig) -> NetworkState:
    """Draw ``S`` independent network realizations as one stacked pytree.

    Returns a :class:`NetworkState` whose leaves carry a leading
    ``(num_scenarios,)`` axis — the scenario axis the batched FEEL driver
    (``core.federated.run_federated_batch``) vmaps over.  Each scenario
    is distributed identically to a single :func:`sample_network` draw.
    """
    keys = jax.random.split(key, num_scenarios)
    return jax.vmap(lambda k: sample_network(k, num_devices, cfg))(keys)


def sample_networks_indexed(key: Array, indices: Array, num_devices: int,
                            cfg: WirelessConfig) -> NetworkState:
    """Network realizations for explicit *global* scenario indices.

    Scenario ``i``'s draw comes from ``fold_in(key, i)``, so the
    realization depends only on ``(key, i)`` — never on how many
    scenarios share the batch, how a sweep is chunked, or how many
    devices execute it.  The sweep engine (``repro.sweep``) builds every
    chunk's networks through this entry; ``sample_networks`` (split-
    based, batch-size-dependent) remains for one-shot callers.
    """
    indices = jnp.asarray(indices, jnp.uint32)
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(indices)
    return jax.vmap(lambda k: sample_network(k, num_devices, cfg))(keys)


def sample_fading(key: Array, net: NetworkState) -> Array:
    """Per-round channel gains ``|g_k|^2 = d^-beta * |h|^2`` with Rayleigh h.

    ``|h|^2`` for a unit Rayleigh variable is Exp(1)-distributed.  Shape
    follows ``net.pathloss`` — under a scenario vmap each lane draws its
    own independent fading from its own key.
    """
    h2 = jax.random.exponential(key, net.pathloss.shape)
    return net.pathloss * h2


def achievable_rate(alpha: Array, gains: Array, tx_power: Array,
                    cfg: WirelessConfig) -> Array:
    """Uplink rate r_k (Eq. 6), elementwise over devices.  bits/s.

    Safe at alpha -> 0 (rate -> 0): we floor alpha before the log and mask
    after, keeping the function differentiable for the PGD solver.
    """
    a = jnp.maximum(alpha, cfg.min_alpha)
    snr = gains * tx_power / (a * cfg.bandwidth_hz * cfg.noise_psd)
    rate = a * cfg.bandwidth_hz * jnp.log2(1.0 + snr)
    return jnp.where(alpha > 0.0, rate, 0.0)


def upload_time(alpha: Array, gains: Array, tx_power: Array,
                cfg: WirelessConfig,
                model_bits: Optional[float | Array] = None,
                airtime_mult: Optional[Array] = None) -> Array:
    """t_up_k = s_k / r_k (Eq. 9).  Infinite when alpha_k == 0.

    ``model_bits`` overrides the config's scalar payload; a ``(K,)``
    array gives each device its own codec-dependent payload (the
    compressed-uplink subsystem, DESIGN.md §9) — any shape
    broadcastable against the rate is accepted.

    ``airtime_mult`` scales the single-shot time by a realized
    retransmission multiplier (attempts + backoff waits, the fault
    subsystem of DESIGN.md §10); a multiplier of 0 — a device that
    dropped out before transmitting — yields exactly 0 airtime even
    where the single-shot time is infinite.
    """
    s = cfg.model_bits if model_bits is None else model_bits
    rate = achievable_rate(alpha, gains, tx_power, cfg)
    t = jnp.where(rate > 0.0, s / jnp.maximum(rate, 1e-12), jnp.inf)
    if airtime_mult is None:
        return t
    return jnp.where(airtime_mult > 0.0, t * airtime_mult, 0.0)


def upload_energy(alpha: Array, gains: Array, tx_power: Array,
                  cfg: WirelessConfig,
                  model_bits: Optional[float | Array] = None,
                  airtime_mult: Optional[Array] = None) -> Array:
    """E_k = P_k * t_up_k (Eq. 10).  ``model_bits`` may be per-device
    ``(K,)`` like :func:`upload_time`.  ``airtime_mult`` charges a
    realized *transmitting* multiplier — for retransmissions pass the
    attempt count, not the backoff-stretched airtime (the radio idles
    through backoff waits, Eq. 10 only bills transmission)."""
    t = upload_time(alpha, gains, tx_power, cfg, model_bits,
                    airtime_mult=airtime_mult)
    return tx_power * t


def train_time(data_sizes: Array, net: NetworkState, cfg: WirelessConfig,
               local_epochs: int | Array = 1) -> Array:
    """t_train_k = E * |D_k| * C_k / f_k (Eq. 8).

    ``|D_k|`` counts samples; C_k is cycles/bit so we convert samples to
    bits with ``cfg.bits_per_sample`` (the paper leaves the unit implicit).
    """
    bits = data_sizes.astype(jnp.float32) * cfg.bits_per_sample
    return local_epochs * bits * net.cycles_per_bit / net.cpu_freq


def round_time(selected: Array, t_train: Array, t_up: Array) -> Array:
    """T = max_k (t_train_k + t_up_k) x_k (Eq. 7); 0 if nothing selected."""
    total = jnp.where(selected > 0.0, t_train + t_up, 0.0)
    return jnp.max(total)
