"""Sub2 — bandwidth allocation (paper Eq. 15), JAX-native solvers.

The paper fixes the selection ``x`` and solves::

    min_alpha  rho * sum_k x_k E_k(alpha_k) + (1 - rho) * T(alpha)
    s.t.       sum_k alpha_k <= 1,   0 <= alpha_k <= 1

with an off-the-shelf (scipy) solver.  Both objective terms are strictly
decreasing in every ``alpha_k`` (more bandwidth -> faster upload -> less
time *and* less energy at fixed transmit power), so the budget binds:
``sum alpha = 1`` over the selected set.  We exploit the structure twice:

* :func:`min_time_allocation` — the ``rho = 0`` limit has a water-filling
  solution: all selected devices finish at the same instant ``T*``.  For a
  deadline ``T`` the minimal per-device share is ``alpha_k(T)`` obtained by
  inverting the rate function; feasibility ``sum_k alpha_k(T) <= 1`` is
  monotone in ``T`` -> bisection on ``T``.  The default solver is the
  *fused joint bisection*: one fixed-trip loop that carries the per-device
  rate-inversion state (a Newton iterate on the concave rate function)
  alongside the deadline bracket, so each deadline probe costs
  ``joint_newton_steps`` rate evaluations instead of a full inner
  bisection (~25x fewer solver FLOPs than the nested reference at the
  same <1e-3 agreement; see :func:`min_time_allocation_reference` and
  ``tests/test_allocator.py``).

* :func:`pgd_allocation` — general ``rho``: projected gradient descent on
  the selected-coordinate simplex (Duchi projection), with the round time
  smoothed by a logsumexp so the objective is differentiable.  Matches
  scipy's SLSQP to <1e-3 on random instances (see tests) while remaining
  jit-able inside the DAS loop.

Callers inside the scheduling stack do not import these solvers directly:
they go through the :class:`repro.core.allocator.Allocator` interface,
which also provides the Pallas-fused PGD variant (``kernels/sub2_pgd.py``)
and the warm-start plumbing used by ``das_schedule``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import wireless

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Sub2Params:
    rho: float = 0.5            # energy/time trade-off (paper: 1/2)
    time_bisect_iters: int = 60
    rate_bisect_iters: int = 50  # reference nested solver only
    newton_iters: int = 12       # standalone rate inversions + final polish
    joint_newton_steps: int = 2  # per-deadline-probe Newton refinement
    pgd_iters: int = 400
    pgd_lr: float = 0.05
    smooth_tau: float = 1e-3    # logsumexp temperature for max T (seconds)

    @classmethod
    def reference(cls, rho: float = 0.5) -> "Sub2Params":
        """Full-accuracy solve (the defaults): matches scipy SLSQP to
        <1e-3 on random instances.  Use for paper-figure numbers."""
        return cls(rho=rho)

    @classmethod
    def fast(cls, rho: float = 0.5) -> "Sub2Params":
        """Throughput preset for the scanned/vmapped simulation drivers.

        Sub2 runs inside every DAS outer iteration of every round of
        every scenario, so its fixed iteration counts multiply through
        the whole compiled program.  Halving the deadline bisection and
        cutting PGD to 120 steps keeps the allocation within ~1% of the
        reference objective on Table-I-scale instances (K <= 200) while
        cutting the per-decision op count — the right trade when the
        simulation, not the allocator, is the product.  (The rate
        inversion is Newton either way; ``rate_bisect_iters`` only
        affects the nested reference solver kept for parity tests.)
        """
        return cls(rho=rho, time_bisect_iters=30, rate_bisect_iters=25,
                   newton_iters=8, pgd_iters=120)


# ---------------------------------------------------------------------------
# Rate inversion: alpha such that rate(alpha) == r_req
# ---------------------------------------------------------------------------

# Sentinel/ceiling for the inverted share: rate is bounded above by
# B*c/ln2, so alpha = 4 exceeds any feasible-within-band requirement with
# margin; requirements beyond the band saturate here (callers check the
# budget, e.g. against the sum <= 1 constraint).
ALPHA_CEIL = 4.0


def _rate_and_slope(a: Array, c: Array, bandwidth_hz: float
                    ) -> tuple[Array, Array]:
    """rate(a) = a*B*log2(1 + c/a) and its derivative (both > 0).

    rate'(a) = (B/ln2) * (ln(1 + c/a) - c/(a + c)) — positive because
    ln(1+x) > x/(1+x), vanishing as the rate saturates at B*c/ln2.
    """
    scale = bandwidth_hz / jnp.log(2.0)
    l = jnp.log1p(c / a)
    return scale * a * l, scale * (l - c / (a + c))


def invert_rate_bisect(r_req: Array, gains: Array, tx_power: Array,
                       cfg: wireless.WirelessConfig,
                       iters: int = 50) -> Array:
    """Reference rate inversion (vectorized bisection).

    Kept as the oracle for the Newton solver and the nested reference
    deadline solve (``min_time_allocation_reference``); production paths
    use :func:`invert_rate`.
    """
    c = gains * tx_power / (cfg.bandwidth_hz * cfg.noise_psd)

    def rate(a):
        a = jnp.maximum(a, cfg.min_alpha)
        return a * cfg.bandwidth_hz * jnp.log2(1.0 + c / a)

    lo = jnp.zeros_like(r_req)
    hi = jnp.full_like(r_req, ALPHA_CEIL)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        ok = rate(mid) >= r_req
        return jnp.where(ok, lo, mid), jnp.where(ok, mid, hi)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return hi


def _newton_refine(a: Array, r_req: Array, c: Array,
                   cfg: wireless.WirelessConfig, steps: int) -> Array:
    """``steps`` Newton iterations on f(a) = rate(a) - r_req from ``a``.

    rate is concave increasing, so Newton converges globally: from below
    the root the iterates increase monotonically toward it; from above,
    one tangent step lands at-or-below the root (tangents of a concave
    function lie above it).  The only hazard is a tangent whose zero
    crossing is negative (far-above starts near rate saturation) — the
    clip into [min_alpha, ALPHA_CEIL] restores a valid starting point.
    Requirements beyond the band (f < 0 everywhere) drive the iterate
    into the ALPHA_CEIL ceiling, matching the bisection's sentinel.
    """
    def body(_, a):
        r, slope = _rate_and_slope(a, c, cfg.bandwidth_hz)
        step = (r - r_req) / jnp.maximum(slope, 1e-20)
        return jnp.clip(a - step, cfg.min_alpha, ALPHA_CEIL)

    a = jnp.clip(a, cfg.min_alpha, ALPHA_CEIL)
    return jax.lax.fori_loop(0, steps, body, a)


def invert_rate(r_req: Array, gains: Array, tx_power: Array,
                cfg: wireless.WirelessConfig, iters: int = 12,
                alpha0: Array | None = None) -> Array:
    """Minimal alpha achieving rate ``r_req`` (vectorized Newton).

    Newton on the concave rate function converges quadratically where the
    50-trip bisection converged linearly — 8-12 steps reach float32
    precision from a cold start, fewer when ``alpha0`` warm-starts the
    iterate (e.g. from the previous DAS iteration's allocation).  Returns
    alpha possibly > 1 (up to ``ALPHA_CEIL``) when the requirement is
    infeasible inside the band — callers check the budget.
    """
    c = gains * tx_power / (cfg.bandwidth_hz * cfg.noise_psd)
    if alpha0 is None:
        # Secant-style cold start: linearize the log factor at a = 1.
        denom = jnp.maximum(cfg.bandwidth_hz * jnp.log2(1.0 + c), 1e-20)
        alpha0 = r_req / denom
    return _newton_refine(alpha0, r_req, c, cfg, iters)


# ---------------------------------------------------------------------------
# rho -> 0 water-filling: minimize the round time T
# ---------------------------------------------------------------------------

def effective_payload_bits(payload_bits: Array | None,
                           airtime_mult: float,
                           cfg: wireless.WirelessConfig,
                           like: Array) -> Array | None:
    """Retry-priced payload for scheduling-time Sub2 solves (DESIGN.md §10).

    The fault subsystem's expected retransmission multiplier
    (``faults.expected_time_mult``) converts to *effective* uplink bits
    here — one boundary, so every deadline function and Sub2 solver
    prices the retry tax identically (time and energy are both linear in
    the payload at fixed alpha, Eq. 9/10).  ``airtime_mult == 1.0``
    returns the input untouched (bitwise-identity guarantee for inert
    fault configs); with no per-device payload the scalar
    ``cfg.model_bits`` is materialized as a ``(K,)`` array shaped like
    ``like`` — which routes ``fused_pgd`` onto its documented
    per-device-bits jnp fallback (``core.allocator``).
    """
    if airtime_mult == 1.0:
        return payload_bits
    if payload_bits is None:
        return jnp.full(like.shape, cfg.model_bits * airtime_mult,
                        jnp.float32)
    return payload_bits * jnp.float32(airtime_mult)


def _required_rate(deadline: Array, t_train: Array,
                   cfg: wireless.WirelessConfig,
                   payload_bits: Array | None = None) -> Array:
    """Upload rate needed to finish by ``deadline``; inf when the
    training alone already exceeds it.  ``payload_bits`` (``(K,)`` or
    broadcastable) overrides the scalar ``cfg.model_bits`` payload."""
    s = cfg.model_bits if payload_bits is None else payload_bits
    slack = deadline - t_train
    return jnp.where(slack > 0.0,
                     s / jnp.maximum(slack, 1e-9), jnp.inf)


def alpha_for_deadline(deadline: Array, selected: Array, t_train: Array,
                       gains: Array, tx_power: Array,
                       cfg: wireless.WirelessConfig,
                       rate_iters: int = 12,
                       solver: str = "newton",
                       payload_bits: Array | None = None) -> Array:
    """Minimal alpha_k letting each selected device finish by ``deadline``.

    Devices whose training alone exceeds the deadline get a sentinel share
    of ``ALPHA_CEIL`` (infeasible marker, exceeds any budget).  ``solver``
    picks the Newton inversion (default) or the bisection reference.
    ``payload_bits`` gives each device its own uplink payload (the
    compressed-uplink subsystem); ``None`` keeps the scalar
    ``cfg.model_bits``.
    """
    r_req = _required_rate(deadline, t_train, cfg, payload_bits)
    r_fin = jnp.where(jnp.isinf(r_req), 1e30, r_req)
    if solver == "newton":
        a = invert_rate(r_fin, gains, tx_power, cfg, iters=rate_iters)
    else:
        a = invert_rate_bisect(r_fin, gains, tx_power, cfg,
                               iters=rate_iters)
    a = jnp.where(jnp.isinf(r_req), ALPHA_CEIL, a)
    return jnp.where(selected > 0.0, a, 0.0)


def _deadline_bracket(selected: Array, t_train: Array, gains: Array,
                      tx_power: Array, cfg: wireless.WirelessConfig,
                      payload_bits: Array | None = None
                      ) -> tuple[Array, Array, Array]:
    """(lo, hi, equal_alpha): lo = max t_train (upload takes >0 time),
    hi = completion time at the equal-share allocation (feasible)."""
    n_sel = jnp.maximum(jnp.sum(selected), 1.0)
    equal_alpha = jnp.where(selected > 0.0, 1.0 / n_sel, 0.0)
    t_up_equal = wireless.upload_time(equal_alpha, gains, tx_power, cfg,
                                      payload_bits)
    hi = jnp.max(jnp.where(selected > 0.0, t_train + t_up_equal, 0.0))
    lo = jnp.max(jnp.where(selected > 0.0, t_train, 0.0))
    return lo, hi, equal_alpha


def min_time_allocation_reference(
        selected: Array, t_train: Array, gains: Array, tx_power: Array,
        cfg: wireless.WirelessConfig,
        params: Sub2Params = Sub2Params(),
        payload_bits: Array | None = None) -> tuple[Array, Array]:
    """Nested reference deadline solve: returns (alpha, T*).

    Outer bisection on the deadline T; a full inner rate bisection per
    device at every probe (``time_bisect_iters * rate_bisect_iters``
    fused loop bodies).  Kept as the oracle the fused joint bisection is
    property-tested against; production paths use
    :func:`min_time_allocation`.
    """
    any_sel = jnp.sum(selected) > 0.0
    lo0, hi0, _ = _deadline_bracket(selected, t_train, gains, tx_power,
                                    cfg, payload_bits)

    def feasible(deadline):
        a = alpha_for_deadline(deadline, selected, t_train, gains, tx_power,
                               cfg, rate_iters=params.rate_bisect_iters,
                               solver="bisect", payload_bits=payload_bits)
        return jnp.sum(a) <= 1.0

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        ok = feasible(mid)
        return jnp.where(ok, lo, mid), jnp.where(ok, mid, hi)

    lo, hi = jax.lax.fori_loop(0, params.time_bisect_iters, body, (lo0, hi0))
    t_star = hi
    alpha = alpha_for_deadline(t_star, selected, t_train, gains, tx_power,
                               cfg, rate_iters=params.rate_bisect_iters,
                               solver="bisect", payload_bits=payload_bits)
    # Normalize tiny bisection overshoot back inside the budget.
    total = jnp.sum(alpha)
    alpha = jnp.where(total > 1.0, alpha / total, alpha)
    alpha = jnp.where(any_sel, alpha, jnp.zeros_like(alpha))
    t_star = jnp.where(any_sel, t_star, 0.0)
    return alpha, t_star


def min_time_allocation(selected: Array, t_train: Array, gains: Array,
                        tx_power: Array, cfg: wireless.WirelessConfig,
                        params: Sub2Params = Sub2Params(),
                        alpha0: Array | None = None,
                        payload_bits: Array | None = None
                        ) -> tuple[Array, Array]:
    """Fused joint min-T solve: returns (alpha, T*).

    One fixed-trip loop bisects the deadline while *carrying the
    per-device rate-inversion state*: each probe refines the previous
    probe's alpha with ``joint_newton_steps`` Newton steps on the concave
    rate function instead of running a fresh inner bisection.  The carry
    is an excellent warm start because consecutive probes move the
    deadline by a halving bracket — so 2 Newton steps (quadratic) track
    the root to well under the bisection's own tolerance.  Cost per Sub2
    call drops from ``time_bisect_iters * rate_bisect_iters`` (~3000)
    rate evaluations to ``time_bisect_iters * joint_newton_steps`` plus a
    final ``newton_iters`` polish at T* (~130) — ~25x fewer solver FLOPs
    at <1e-3 agreement with :func:`min_time_allocation_reference`
    (property-tested in ``tests/test_allocator.py``).

    ``alpha0`` (e.g. the previous DAS iteration's allocation) seeds the
    Newton carry; Newton's global convergence on concave f makes any
    positive seed safe.  At the optimum every selected device finishes at
    T* (unless its single-device optimum is already faster with spare
    bandwidth).  ``payload_bits`` gives each device its own uplink
    payload (``(K,)``, compressed-uplink subsystem); ``None`` keeps the
    scalar ``cfg.model_bits``.
    """
    any_sel = jnp.sum(selected) > 0.0
    lo0, hi0, equal_alpha = _deadline_bracket(selected, t_train, gains,
                                              tx_power, cfg, payload_bits)
    c = gains * tx_power / (cfg.bandwidth_hz * cfg.noise_psd)
    seed = equal_alpha if alpha0 is None else alpha0
    a_carry = jnp.clip(seed, cfg.min_alpha, ALPHA_CEIL)

    def probe(deadline, a_carry, steps):
        """(alpha at deadline, refreshed carry): sentinel where the
        training alone exceeds the deadline, Newton-refined elsewhere."""
        r_req = _required_rate(deadline, t_train, cfg, payload_bits)
        finite = jnp.isfinite(r_req)
        a_new = _newton_refine(a_carry, jnp.where(finite, r_req, 1.0), c,
                               cfg, steps)
        a_eval = jnp.where(selected > 0.0,
                           jnp.where(finite, a_new, ALPHA_CEIL), 0.0)
        return a_eval, jnp.where(finite, a_new, a_carry)

    def body(_, carry):
        lo, hi, a_carry = carry
        mid = 0.5 * (lo + hi)
        a_eval, a_carry = probe(mid, a_carry, params.joint_newton_steps)
        ok = jnp.sum(a_eval) <= 1.0
        return jnp.where(ok, lo, mid), jnp.where(ok, mid, hi), a_carry

    lo, hi, a_carry = jax.lax.fori_loop(
        0, params.time_bisect_iters, body, (lo0, hi0, a_carry))
    t_star = hi
    alpha, _ = probe(t_star, a_carry, params.newton_iters)
    # Normalize tiny bisection overshoot back inside the budget.
    total = jnp.sum(alpha)
    alpha = jnp.where(total > 1.0, alpha / total, alpha)
    alpha = jnp.where(any_sel, alpha, jnp.zeros_like(alpha))
    t_star = jnp.where(any_sel, t_star, 0.0)
    return alpha, t_star


# ---------------------------------------------------------------------------
# General rho: projected gradient on the simplex
# ---------------------------------------------------------------------------

def project_simplex(v: Array, mask: Array, radius: float = 1.0) -> Array:
    """Euclidean projection of ``v`` (masked coords) onto the simplex
    {a >= 0, sum a = radius, a_i = 0 for mask_i = 0} (Duchi et al., 2008).
    """
    big_neg = -1e30
    n_active = jnp.maximum(jnp.sum(mask), 1.0)
    vm = jnp.where(mask > 0.0, v, big_neg)
    u = jnp.sort(vm)[::-1]
    css = jnp.cumsum(u)
    k = jnp.arange(1, v.shape[0] + 1, dtype=v.dtype)
    cond = (u * k > (css - radius)) & (u > big_neg / 2)
    rho_idx = jnp.sum(cond) - 1
    rho_idx = jnp.clip(rho_idx, 0, v.shape[0] - 1)
    theta = (css[rho_idx] - radius) / (rho_idx + 1.0)
    out = jnp.maximum(v - theta, 0.0)
    out = jnp.where(mask > 0.0, out, 0.0)
    # Guard: if nothing active, return zeros.
    return jnp.where(n_active > 0.5, out, jnp.zeros_like(out))


def sub2_objective(alpha: Array, selected: Array, t_train: Array,
                   gains: Array, tx_power: Array,
                   cfg: wireless.WirelessConfig, rho: float,
                   smooth_tau: float = 0.0,
                   energy_weights: Array | None = None,
                   payload_bits: Array | None = None) -> Array:
    """rho * sum w_k E_k + (1-rho) * T (Eq. 15a); optionally smoothed max.

    ``energy_weights`` (default: all ones) prices each device's energy
    term — the hook the importance-weighted allocator
    (``allocator.ImportanceWeighted``) uses to bias bandwidth toward
    devices whose updates matter more (Ren et al.-style pricing).  The
    realized physical energy is unchanged; only the optimization
    trade-off moves.  ``payload_bits`` (``(K,)``) makes the uplink
    payload per-device (compressed-uplink subsystem); ``None`` keeps
    the scalar ``cfg.model_bits``.
    """
    t_up = wireless.upload_time(alpha, gains, tx_power, cfg, payload_bits)
    t_up = jnp.where(selected > 0.0, t_up, 0.0)
    energy = jnp.where(selected > 0.0, tx_power * t_up, 0.0)
    if energy_weights is not None:
        energy = energy * energy_weights
    total = jnp.where(selected > 0.0, t_train + t_up, 0.0)
    if smooth_tau > 0.0:
        t_round = smooth_tau * jax.nn.logsumexp(total / smooth_tau)
    else:
        t_round = jnp.max(total)
    return rho * jnp.sum(energy) + (1.0 - rho) * t_round


def pgd_allocation(selected: Array, t_train: Array, gains: Array,
                   tx_power: Array, cfg: wireless.WirelessConfig,
                   params: Sub2Params = Sub2Params(),
                   alpha0: Array | None = None,
                   energy_weights: Array | None = None,
                   payload_bits: Array | None = None
                   ) -> tuple[Array, Array]:
    """Solve Sub2 for general rho by tangent-space projected gradient.

    Two starting points — min-time water-filling (optimal for rho=0) and
    the uniform share — each descended with the gradient's *tangential*
    component (mean removed: on the simplex a common offset projects to
    zero movement, so raw/Adam steps stall — see tests) under a cosine
    lr decay, tracking the best exact-max objective seen.  ``alpha0``
    (e.g. the previous DAS iteration's allocation) warm-starts the
    water-filling solve's Newton carry only — the two descent basins are
    kept distinct on purpose, so the best-of-two safeguard still
    explores the uniform basin on every call.  ``energy_weights``
    reprices per-device energy in the objective (importance-weighted
    allocator); the water-filling start ignores it (it is the rho -> 0
    limit, where the energy term vanishes).  ``payload_bits`` makes the
    uplink payload per-device throughout — objective, gradient and the
    water-filling start all price the same codec-dependent bits.
    Returns (alpha, objective).
    """
    mask = (selected > 0.0).astype(jnp.float32)
    n_act = jnp.maximum(jnp.sum(mask), 1.0)

    def exact_obj(a):
        return sub2_objective(a, selected, t_train, gains, tx_power, cfg,
                              params.rho, smooth_tau=0.0,
                              energy_weights=energy_weights,
                              payload_bits=payload_bits)

    grad_fn = jax.grad(
        lambda a: sub2_objective(a, selected, t_train, gains, tx_power, cfg,
                                 params.rho, params.smooth_tau,
                                 energy_weights=energy_weights,
                                 payload_bits=payload_bits))

    def descend(alpha0):
        alpha0 = project_simplex(alpha0, mask)

        def body(i, carry):
            a, best_a, best_o = carry
            g = grad_fn(a) * mask
            g_t = (g - jnp.sum(g) / n_act) * mask      # tangent component
            gmax = jnp.max(jnp.abs(g_t))
            frac = i.astype(jnp.float32) / params.pgd_iters
            lr = params.pgd_lr * (0.5 * (1 + jnp.cos(jnp.pi * frac)))
            a = project_simplex(
                a - lr * g_t / jnp.maximum(gmax, 1e-12), mask)
            o = exact_obj(a)
            better = o < best_o
            return (a, jnp.where(better, a, best_a),
                    jnp.where(better, o, best_o))

        init = (alpha0, alpha0, exact_obj(alpha0))
        _, best_a, best_o = jax.lax.fori_loop(0, params.pgd_iters, body,
                                              init)
        return best_a, best_o

    wf, _ = min_time_allocation(selected, t_train, gains, tx_power, cfg,
                                params, alpha0=alpha0,
                                payload_bits=payload_bits)
    uniform = mask / n_act
    a1, o1 = descend(wf)
    a2, o2 = descend(uniform)
    pick = o1 <= o2
    return jnp.where(pick, a1, a2), jnp.where(pick, o1, o2)
