"""Sub2 — bandwidth allocation (paper Eq. 15), JAX-native solvers.

The paper fixes the selection ``x`` and solves::

    min_alpha  rho * sum_k x_k E_k(alpha_k) + (1 - rho) * T(alpha)
    s.t.       sum_k alpha_k <= 1,   0 <= alpha_k <= 1

with an off-the-shelf (scipy) solver.  Both objective terms are strictly
decreasing in every ``alpha_k`` (more bandwidth -> faster upload -> less
time *and* less energy at fixed transmit power), so the budget binds:
``sum alpha = 1`` over the selected set.  We exploit the structure twice:

* :func:`min_time_allocation` — the ``rho = 0`` limit has a water-filling
  solution: all selected devices finish at the same instant ``T*``.  For a
  deadline ``T`` the minimal per-device share is ``alpha_k(T)`` obtained by
  inverting the rate function (monotone -> bisection); feasibility
  ``sum_k alpha_k(T) <= 1`` is monotone in ``T`` -> outer bisection on
  ``T``.  Fully vectorized, fixed iteration count, jit-safe.

* :func:`pgd_allocation` — general ``rho``: projected gradient descent on
  the selected-coordinate simplex (Duchi projection), with the round time
  smoothed by a logsumexp so the objective is differentiable.  Matches
  scipy's SLSQP to <1e-3 on random instances (see tests) while remaining
  jit-able inside the DAS loop.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import wireless

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Sub2Params:
    rho: float = 0.5            # energy/time trade-off (paper: 1/2)
    time_bisect_iters: int = 60
    rate_bisect_iters: int = 50
    pgd_iters: int = 400
    pgd_lr: float = 0.05
    smooth_tau: float = 1e-3    # logsumexp temperature for max T (seconds)

    @classmethod
    def reference(cls, rho: float = 0.5) -> "Sub2Params":
        """Full-accuracy solve (the defaults): matches scipy SLSQP to
        <1e-3 on random instances.  Use for paper-figure numbers."""
        return cls(rho=rho)

    @classmethod
    def fast(cls, rho: float = 0.5) -> "Sub2Params":
        """Throughput preset for the scanned/vmapped simulation drivers.

        Sub2 runs inside every DAS outer iteration of every round of
        every scenario, so its fixed iteration counts multiply through
        the whole compiled program.  Halving the bisections and cutting
        PGD to 120 steps keeps the allocation within ~1% of the
        reference objective on Table-I-scale instances (K <= 200) while
        cutting the per-decision op count ~4x — the right trade when the
        simulation, not the allocator, is the product.
        """
        return cls(rho=rho, time_bisect_iters=30, rate_bisect_iters=25,
                   pgd_iters=120)


# ---------------------------------------------------------------------------
# Rate inversion: alpha such that rate(alpha) == r_req
# ---------------------------------------------------------------------------

def invert_rate(r_req: Array, gains: Array, tx_power: Array,
                cfg: wireless.WirelessConfig, iters: int = 50) -> Array:
    """Minimal alpha achieving rate ``r_req`` (vectorized bisection).

    rate(alpha) = alpha*B*log2(1 + c/alpha), c = g*P/(B*N0), is strictly
    increasing and concave in alpha.  Returns alpha possibly > 1 when the
    requirement is infeasible inside the band — callers check the budget.
    """
    c = gains * tx_power / (cfg.bandwidth_hz * cfg.noise_psd)

    def rate(a):
        a = jnp.maximum(a, cfg.min_alpha)
        return a * cfg.bandwidth_hz * jnp.log2(1.0 + c / a)

    # Bracket: rate is bounded above by B*c/ln2; alpha up to 4 covers any
    # feasible-within-band requirement with margin.
    lo = jnp.zeros_like(r_req)
    hi = jnp.full_like(r_req, 4.0)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        ok = rate(mid) >= r_req
        return jnp.where(ok, lo, mid), jnp.where(ok, mid, hi)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return hi


# ---------------------------------------------------------------------------
# rho -> 0 water-filling: minimize the round time T
# ---------------------------------------------------------------------------

def alpha_for_deadline(deadline: Array, selected: Array, t_train: Array,
                       gains: Array, tx_power: Array,
                       cfg: wireless.WirelessConfig,
                       rate_iters: int = 50) -> Array:
    """Minimal alpha_k letting each selected device finish by ``deadline``.

    Devices whose training alone exceeds the deadline get a sentinel share
    of 4.0 (infeasible marker, exceeds any budget).
    """
    slack = deadline - t_train
    r_req = jnp.where(slack > 0.0, cfg.model_bits / jnp.maximum(slack, 1e-9),
                      jnp.inf)
    a = invert_rate(jnp.where(jnp.isinf(r_req), 1e30, r_req), gains,
                    tx_power, cfg, iters=rate_iters)
    a = jnp.where(jnp.isinf(r_req), 4.0, a)
    return jnp.where(selected > 0.0, a, 0.0)


def min_time_allocation(selected: Array, t_train: Array, gains: Array,
                        tx_power: Array, cfg: wireless.WirelessConfig,
                        params: Sub2Params = Sub2Params()) -> tuple[Array, Array]:
    """Water-filling min-T allocation: returns (alpha, T*).

    Outer bisection on the deadline T; inner rate inversion per device.
    At the optimum every selected device finishes at T* (unless its single-
    device optimum is already faster with spare bandwidth).
    """
    any_sel = jnp.sum(selected) > 0.0
    # Bracket the deadline: lower = max t_train (upload takes >0 time);
    # upper = time when every device gets an equal share (feasible point).
    n_sel = jnp.maximum(jnp.sum(selected), 1.0)
    equal_alpha = jnp.where(selected > 0.0, 1.0 / n_sel, 0.0)
    t_up_equal = wireless.upload_time(equal_alpha, gains, tx_power, cfg)
    hi0 = jnp.max(jnp.where(selected > 0.0, t_train + t_up_equal, 0.0))
    lo0 = jnp.max(jnp.where(selected > 0.0, t_train, 0.0))

    def feasible(deadline):
        a = alpha_for_deadline(deadline, selected, t_train, gains, tx_power,
                               cfg, rate_iters=params.rate_bisect_iters)
        return jnp.sum(a) <= 1.0

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        ok = feasible(mid)
        return jnp.where(ok, lo, mid), jnp.where(ok, mid, hi)

    lo, hi = jax.lax.fori_loop(0, params.time_bisect_iters, body, (lo0, hi0))
    t_star = hi
    alpha = alpha_for_deadline(t_star, selected, t_train, gains, tx_power,
                               cfg, rate_iters=params.rate_bisect_iters)
    # Normalize tiny bisection overshoot back inside the budget.
    total = jnp.sum(alpha)
    alpha = jnp.where(total > 1.0, alpha / total, alpha)
    alpha = jnp.where(any_sel, alpha, jnp.zeros_like(alpha))
    t_star = jnp.where(any_sel, t_star, 0.0)
    return alpha, t_star


# ---------------------------------------------------------------------------
# General rho: projected gradient on the simplex
# ---------------------------------------------------------------------------

def project_simplex(v: Array, mask: Array, radius: float = 1.0) -> Array:
    """Euclidean projection of ``v`` (masked coords) onto the simplex
    {a >= 0, sum a = radius, a_i = 0 for mask_i = 0} (Duchi et al., 2008).
    """
    big_neg = -1e30
    n_active = jnp.maximum(jnp.sum(mask), 1.0)
    vm = jnp.where(mask > 0.0, v, big_neg)
    u = jnp.sort(vm)[::-1]
    css = jnp.cumsum(u)
    k = jnp.arange(1, v.shape[0] + 1, dtype=v.dtype)
    cond = (u * k > (css - radius)) & (u > big_neg / 2)
    rho_idx = jnp.sum(cond) - 1
    rho_idx = jnp.clip(rho_idx, 0, v.shape[0] - 1)
    theta = (css[rho_idx] - radius) / (rho_idx + 1.0)
    out = jnp.maximum(v - theta, 0.0)
    out = jnp.where(mask > 0.0, out, 0.0)
    # Guard: if nothing active, return zeros.
    return jnp.where(n_active > 0.5, out, jnp.zeros_like(out))


def sub2_objective(alpha: Array, selected: Array, t_train: Array,
                   gains: Array, tx_power: Array,
                   cfg: wireless.WirelessConfig, rho: float,
                   smooth_tau: float = 0.0) -> Array:
    """rho * sum E_k + (1-rho) * T (Eq. 15a); optionally smoothed max."""
    t_up = wireless.upload_time(alpha, gains, tx_power, cfg)
    t_up = jnp.where(selected > 0.0, t_up, 0.0)
    energy = jnp.where(selected > 0.0, tx_power * t_up, 0.0)
    total = jnp.where(selected > 0.0, t_train + t_up, 0.0)
    if smooth_tau > 0.0:
        t_round = smooth_tau * jax.nn.logsumexp(total / smooth_tau)
    else:
        t_round = jnp.max(total)
    return rho * jnp.sum(energy) + (1.0 - rho) * t_round


def pgd_allocation(selected: Array, t_train: Array, gains: Array,
                   tx_power: Array, cfg: wireless.WirelessConfig,
                   params: Sub2Params = Sub2Params()) -> tuple[Array, Array]:
    """Solve Sub2 for general rho by tangent-space projected gradient.

    Two warm starts (min-time water-filling — optimal for rho=0 — and the
    uniform share), each descended with the gradient's *tangential*
    component (mean removed: on the simplex a common offset projects to
    zero movement, so raw/Adam steps stall — see tests) under a cosine lr
    decay, tracking the best exact-max objective seen.  Returns
    (alpha, objective).
    """
    mask = (selected > 0.0).astype(jnp.float32)
    n_act = jnp.maximum(jnp.sum(mask), 1.0)

    def exact_obj(a):
        return sub2_objective(a, selected, t_train, gains, tx_power, cfg,
                              params.rho, smooth_tau=0.0)

    grad_fn = jax.grad(
        lambda a: sub2_objective(a, selected, t_train, gains, tx_power, cfg,
                                 params.rho, params.smooth_tau))

    def descend(alpha0):
        alpha0 = project_simplex(alpha0, mask)

        def body(i, carry):
            a, best_a, best_o = carry
            g = grad_fn(a) * mask
            g_t = (g - jnp.sum(g) / n_act) * mask      # tangent component
            gmax = jnp.max(jnp.abs(g_t))
            frac = i.astype(jnp.float32) / params.pgd_iters
            lr = params.pgd_lr * (0.5 * (1 + jnp.cos(jnp.pi * frac)))
            a = project_simplex(
                a - lr * g_t / jnp.maximum(gmax, 1e-12), mask)
            o = exact_obj(a)
            better = o < best_o
            return (a, jnp.where(better, a, best_a),
                    jnp.where(better, o, best_o))

        init = (alpha0, alpha0, exact_obj(alpha0))
        _, best_a, best_o = jax.lax.fori_loop(0, params.pgd_iters, body,
                                              init)
        return best_a, best_o

    wf, _ = min_time_allocation(selected, t_train, gains, tx_power, cfg,
                                params)
    uniform = mask / n_act
    a1, o1 = descend(wf)
    a2, o2 = descend(uniform)
    pick = o1 <= o2
    return jnp.where(pick, a1, a2), jnp.where(pick, o1, o2)
