"""Allocator subsystem — the one interface every policy solves Sub2 through.

The paper's round decision (Alg. 2) alternates Sub1 selection with the
Sub2 bandwidth solve (Eq. 15); the baselines (ABS / random / top-n /
full) run Sub2 once over their fixed selection.  This module makes that
inner solve a pluggable component instead of a hard-coded call:

* :class:`Allocator` — the protocol:
  ``solve(selected, t_train, gains, tx_power, cfg, alpha0=None)
  -> (alpha, objective)``.  ``alpha0`` is the warm-start contract: the
  caller's best prior allocation (``das_schedule`` passes the previous
  outer iteration's alpha), or ``None`` on a cold call.  Implementations
  must be traceable (fixed-trip interiors) so policies stay scan/vmap
  safe, and must return a feasible alpha (sum <= 1, zero off-selection).
* :class:`WaterFilling` — the rho -> 0 limit: fused joint-bisection
  min-time solve (``bandwidth.min_time_allocation``).
* :class:`PGD` — tangent-space projected gradient with the water-filling
  + warm-start/uniform double descent (``bandwidth.pgd_allocation``).
* :class:`FusedPGD` — the same double descent executed by the Pallas
  kernel ``kernels/sub2_pgd.py``: one launch fuses gradient -> tangent
  projection -> cosine-lr step -> simplex projection -> objective
  tracking for the whole descent (interpret-mode on CPU, compiled on
  TPU).
* :class:`ImportanceWeighted` — the Ren et al.-style objective: per-device
  energy priced by gradient importance x channel cost
  (:func:`importance_weights`), solved with the same tangent PGD.

New objectives plug in via :func:`register` without touching any
scheduling policy; policies pick an implementation by name through
``SchedulerConfig.allocator``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import bandwidth as bw
from repro.core import wireless

Array = jax.Array


@runtime_checkable
class Allocator(Protocol):
    """Sub2 solver interface consumed by every scheduling policy."""

    params: bw.Sub2Params

    def solve(self, selected: Array, t_train: Array, gains: Array,
              tx_power: Array, cfg: wireless.WirelessConfig,
              alpha0: Optional[Array] = None,
              data_sizes: Optional[Array] = None,
              payload_bits: Optional[Array] = None
              ) -> tuple[Array, Array]:
        """Return (alpha, objective) for the given selection.

        ``alpha0`` optionally warm-starts the solver with the caller's
        previous allocation; implementations must accept ``None``.
        ``data_sizes`` is the per-device |D_k| the policies already hold
        — data-aware objectives (``ImportanceWeighted``) consume it;
        plain time/energy objectives ignore it.  ``payload_bits`` is
        the per-device ``(K,)`` uplink payload from the compressed-
        uplink subsystem (DESIGN.md §9); ``None`` means the scalar
        ``cfg.model_bits``, and implementations must honor the array
        in their time/energy terms.
        """
        ...


@dataclasses.dataclass(frozen=True)
class WaterFilling:
    """rho -> 0 limit: every selected device finishes at T* (Eq. 15 with
    the energy term dropped).  Objective reported at the caller's rho so
    allocators are comparable."""

    params: bw.Sub2Params = bw.Sub2Params()

    def solve(self, selected: Array, t_train: Array, gains: Array,
              tx_power: Array, cfg: wireless.WirelessConfig,
              alpha0: Optional[Array] = None,
              data_sizes: Optional[Array] = None,
              payload_bits: Optional[Array] = None
              ) -> tuple[Array, Array]:
        del data_sizes
        alpha, _ = bw.min_time_allocation(selected, t_train, gains,
                                          tx_power, cfg, self.params,
                                          alpha0=alpha0,
                                          payload_bits=payload_bits)
        obj = bw.sub2_objective(alpha, selected, t_train, gains, tx_power,
                                cfg, self.params.rho,
                                payload_bits=payload_bits)
        return alpha, obj


@dataclasses.dataclass(frozen=True)
class PGD:
    """Tangent-space projected gradient (the jnp reference solver)."""

    params: bw.Sub2Params = bw.Sub2Params()

    def solve(self, selected: Array, t_train: Array, gains: Array,
              tx_power: Array, cfg: wireless.WirelessConfig,
              alpha0: Optional[Array] = None,
              data_sizes: Optional[Array] = None,
              payload_bits: Optional[Array] = None
              ) -> tuple[Array, Array]:
        del data_sizes
        return bw.pgd_allocation(selected, t_train, gains, tx_power, cfg,
                                 self.params, alpha0=alpha0,
                                 payload_bits=payload_bits)


@dataclasses.dataclass(frozen=True)
class FusedPGD:
    """PGD descent fused into one Pallas launch per decision.

    The joint-bisection water-filling solve supplies the first starting
    point (and consumes the warm start); the kernel then runs the entire
    double descent in VMEM.  ``interpret=None`` follows the backend
    (interpret on CPU, compiled on TPU) like the other kernel wrappers.

    ``payload_bits`` rides the kernel's per-device bits *operand* lane
    (``kernels/sub2_pgd.py``): compressed per-device payloads and the
    nominal scalar ``cfg.model_bits`` take the same fused path — the
    bits row is always materialized to ``(K,)`` and fed as an operand,
    never baked as a static.  A device-uniform bits row is arithmetic-
    identical (elementwise) to the old scalar static, so pre-existing
    uncompressed runs are bitwise unchanged.
    """

    params: bw.Sub2Params = bw.Sub2Params()
    interpret: Optional[bool] = None

    def solve(self, selected: Array, t_train: Array, gains: Array,
              tx_power: Array, cfg: wireless.WirelessConfig,
              alpha0: Optional[Array] = None,
              data_sizes: Optional[Array] = None,
              payload_bits: Optional[Array] = None
              ) -> tuple[Array, Array]:
        del data_sizes
        from repro.kernels import ops as kernel_ops
        mask = (selected > 0.0).astype(jnp.float32)
        n_act = jnp.maximum(jnp.sum(mask), 1.0)
        bits = cfg.model_bits if payload_bits is None else payload_bits
        # alpha0 seeds the water-filling Newton carry only; the descent
        # keeps both distinct basins (wf, uniform) like pgd_allocation.
        wf, _ = bw.min_time_allocation(selected, t_train, gains, tx_power,
                                       cfg, self.params, alpha0=alpha0,
                                       payload_bits=payload_bits)
        starts = jnp.stack([wf, mask / n_act])
        p = self.params
        return kernel_ops.sub2_pgd(
            mask, t_train, gains, tx_power, starts, rho=p.rho,
            lr=p.pgd_lr, tau=p.smooth_tau, iters=p.pgd_iters,
            bandwidth_hz=cfg.bandwidth_hz, noise_psd=cfg.noise_psd,
            model_bits=bits, min_alpha=cfg.min_alpha,
            interpret=self.interpret)


def importance_weights(selected: Array, t_train: Array, gains: Array,
                       tx_power: Array, cfg: wireless.WirelessConfig,
                       beta: float = 1.0,
                       data_sizes: Optional[Array] = None) -> Array:
    """Per-device energy prices w_k: gradient-importance x channel pricing.

    Gradient importance follows FedAvg's own weighting: the aggregate
    update weights device k by ``|D_k|``, so a device carrying more of
    the round's data carries more of the aggregate gradient (the Ren et
    al. reading) — ``data_sizes`` is that |D_k|, passed through from the
    policies.  When a caller outside the scheduling stack omits it, the
    workload time ``t_train`` stands in (proportional to ``|D_k| * C_k /
    f_k``, i.e. data share confounded with hardware speed — acceptable
    for a fallback, not for the primary path).  Channel pricing divides
    by the device's spectral efficiency at full band: a weak channel
    pays more energy per uploaded bit, so its energy term is priced up
    and the solver compensates with bandwidth.  Both factors are
    normalized to mean 1 over the selected set, exponentiated by
    ``beta`` and clipped, so ``beta = 0`` recovers the unweighted
    objective exactly and the weights stay O(1).
    """
    mask = (selected > 0.0).astype(jnp.float32)
    n_act = jnp.maximum(jnp.sum(mask, axis=-1, keepdims=True), 1.0)

    def mean_norm(v):
        m = jnp.sum(v * mask, axis=-1, keepdims=True) / n_act
        return v / jnp.maximum(m, 1e-12)

    volume = t_train if data_sizes is None \
        else data_sizes.astype(jnp.float32)
    imp = mean_norm(volume)
    snr_full = gains * tx_power / (cfg.bandwidth_hz * cfg.noise_psd)
    spectral_eff = jnp.log1p(snr_full)
    price = 1.0 / jnp.maximum(mean_norm(spectral_eff), 1e-6)
    w = jnp.clip((imp * price) ** beta, 0.05, 20.0)
    return jnp.where(mask > 0.0, w, 1.0)


@dataclasses.dataclass(frozen=True)
class ImportanceWeighted:
    """Importance-weighted Sub2 objective (Ren et al. / Taik et al. style).

    Solves ``min rho * sum_k w_k E_k + (1-rho) T`` with per-device energy
    prices ``w_k`` from :func:`importance_weights` — devices whose updates
    matter more (larger workload share) or whose channels are costlier
    are priced up, pulling bandwidth toward them relative to the plain
    ``pgd`` objective.  Same tangent-PGD machinery as :class:`PGD`
    (``bandwidth.pgd_allocation`` with ``energy_weights``), so it keeps
    the feasibility and scan/vmap-safety invariants.
    """

    params: bw.Sub2Params = bw.Sub2Params()
    beta: float = 1.0

    def solve(self, selected: Array, t_train: Array, gains: Array,
              tx_power: Array, cfg: wireless.WirelessConfig,
              alpha0: Optional[Array] = None,
              data_sizes: Optional[Array] = None,
              payload_bits: Optional[Array] = None
              ) -> tuple[Array, Array]:
        w = importance_weights(selected, t_train, gains, tx_power, cfg,
                               self.beta, data_sizes=data_sizes)
        return bw.pgd_allocation(selected, t_train, gains, tx_power, cfg,
                                 self.params, alpha0=alpha0,
                                 energy_weights=w,
                                 payload_bits=payload_bits)


_REGISTRY: Dict[str, Callable[[bw.Sub2Params], Allocator]] = {}


def register(name: str, factory: Callable[[bw.Sub2Params], Allocator],
             overwrite: bool = False) -> None:
    """Register an allocator factory (``Sub2Params -> Allocator``)."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"allocator {name!r} already registered")
    _REGISTRY[name] = factory


def names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get(name: str, params: bw.Sub2Params = bw.Sub2Params()) -> Allocator:
    """Build the named allocator around ``params``."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown allocator {name!r}; registered: {names()}") from None
    return factory(params)


register("waterfilling", WaterFilling)
register("pgd", PGD)
register("fused_pgd", FusedPGD)
register("importance", ImportanceWeighted)
