"""Scheduling policies for FEEL rounds (paper Alg. 2 + §VI baselines).

Implements:

* :func:`das_schedule` — the paper's Data-Aware Scheduling: iterate Sub1
  (selection, ``core.selection``) and Sub2 (bandwidth, ``core.bandwidth``)
  until the (x, alpha) pair stabilizes or ``iterations_max`` is hit
  (Algorithm 2).
* :func:`abs_schedule` — age-based scheduling baseline (Yang et al.):
  priority ``f(k) = log(1 + age_k)``.
* :func:`random_schedule` — uniform-random priorities.
* :func:`full_schedule` — the paper's "baseline": every device
  participates, bandwidth optimized with Sub2 only.
* :func:`topn_schedule` — fixed-count stress-test mode used by the paper's
  Fig. 2/3 experiments (select exactly n by a given priority, then Sub2).

All policies share one jit-able entry point, :func:`schedule`, returning a
:class:`ScheduleResult` with the realized per-round time/energy so the FL
driver (``core.federated``) can account costs identically across policies.
:func:`schedule_impl` is the un-jitted body for callers that already trace
(the scan-over-rounds driver, vmapped scenario batches).

Every policy solves Sub2 through the :class:`repro.core.allocator`
interface — ``SchedulerConfig.allocator`` names the implementation
(``pgd`` default, ``waterfilling``, ``fused_pgd`` for the Pallas-fused
descent) and the DAS loop warm-starts it with the previous outer
iteration's allocation.  Swapping allocators never touches policy code.

Every policy is scan/vmap-safe: no data-dependent Python control flow,
and the DAS outer loop freezes its carry on convergence, so batch lanes
that converge early stop updating even while vmap keeps the loop alive
for their peers — ``vmap(das) == stack(das)`` bit-for-bit, and single
runs still exit early.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import allocator as alloc_lib
from repro.core import bandwidth as bw
from repro.core import diversity
from repro.core import selection as sel
from repro.core import wireless

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    method: str = "das"              # das | abs | random | full
    n_min: int = 1                   # N in (13e)
    n_fixed: Optional[int] = None    # paper Fig. 2/3 stress mode
    iterations_max: int = 8          # Alg. 2 outer iterations
    local_epochs: int = 1            # E, enters t_train (Eq. 8)
    sub1: sel.Sub1Params = sel.Sub1Params()
    sub2: bw.Sub2Params = bw.Sub2Params()
    allocator: str = "pgd"           # Sub2 solver (core.allocator registry)
    x_tol: float = 0.5               # convergence: selection unchanged
    alpha_tol: float = 1e-4          # convergence: allocation stable
    # Streaming-data hook (DESIGN.md §7): weight gamma_s of the staleness
    # boost applied to DAS's index and ABS's age priority when the driver
    # supplies per-device staleness (decayed unseen-arrival mass from
    # core.streaming).  0 disables the hook — bit-identical to pre-
    # streaming behavior whether or not staleness is passed.
    staleness_weight: float = 0.0
    # Unreliable-edge hook (DESIGN.md §10): weight gamma_r of the
    # empirical-reliability discount applied to DAS's index and ABS's
    # age priority when the driver supplies the per-device reliability
    # EMA (``core.faults.reliability_update``).  0 disables the hook —
    # bit-identical to failure-blind ranking whether or not a
    # reliability signal is passed.
    reliability_weight: float = 0.0
    # Alg. 2 under-specifies how Sub1 prices a currently-unselected
    # device's energy.  "strict" uses the current allocation (alpha ~ 0 ->
    # infinite energy -> monotone shrinking selection, the literal
    # reading, reproduces the paper's small selected sets);  "mean"
    # re-prices dropouts at the mean selected share so the set can grow
    # back (selects 80%+ at Table-I constants).  See EXPERIMENTS.md
    # §Repro-divergences.
    reentry: str = "strict"          # strict | mean


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ScheduleResult:
    selected: Array      # (K,) {0,1}
    alpha: Array         # (K,) bandwidth shares, sum <= 1
    t_train: Array       # (K,) seconds
    t_up: Array          # (K,) seconds (inf if unselected)
    energy: Array        # (K,) joules (0 if unselected)
    round_time: Array    # scalar, Eq. 7
    iterations: Array    # scalar, DAS outer iterations used

    def tree_flatten(self):
        return ((self.selected, self.alpha, self.t_train, self.t_up,
                 self.energy, self.round_time, self.iterations), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def staleness_boost(priority: Array, staleness: Optional[Array],
                    sch: SchedulerConfig) -> Array:
    """Staleness-aware re-ranking hook (streaming subsystem, DESIGN.md §7).

    Adds ``gamma_s * normalize(log1p(staleness))`` to a selection
    priority, so devices sitting on a large mass of data the server has
    not trained on yet rise in the ranking.  Identity when no staleness
    signal is supplied or the weight is 0 — the static-data round path
    is untouched.  ``log1p`` matches the age-priority shape (diminishing
    returns in the backlog); max-normalization keeps the boost on the
    same [0, gamma_s] scale as the index terms (Eq. 4).
    """
    if staleness is None or sch.staleness_weight == 0.0:
        return priority
    boost = diversity.normalize_metric(jnp.log1p(staleness))
    return priority + sch.staleness_weight * boost


def reliability_discount(priority: Array, reliability: Optional[Array],
                         sch: SchedulerConfig) -> Array:
    """Failure-aware re-ranking hook (fault subsystem, DESIGN.md §10).

    Scales a selection priority by ``(1 - gamma_r) + gamma_r * rel_k``
    with ``rel_k`` the per-device empirical-reliability EMA in [0, 1]
    (``core.faults``): a device that keeps failing its uploads sees its
    priority shrink toward ``(1 - gamma_r)`` of nominal, while a
    perfectly reliable one (``rel = 1``) is untouched at any weight.
    Multiplicative on purpose — DAS's index and ABS's age priority are
    both nonnegative scores, and a multiplicative discount preserves
    their zero point (a zero-value device cannot be *promoted* by mere
    reliability).  Identity when no signal is supplied or the weight is
    0, keeping failure-blind runs bitwise unchanged.
    """
    if reliability is None or sch.reliability_weight == 0.0:
        return priority
    w = sch.reliability_weight
    return priority * ((1.0 - w) + w * reliability)


def score_trace(key: Optional[Array], index: Array, ages: Array,
                sch: SchedulerConfig,
                staleness: Optional[Array] = None,
                reliability: Optional[Array] = None) -> dict:
    """Per-device selection-score decomposition (telemetry, DESIGN.md §13).

    Recomputes — next to the policies, so edits co-locate — the priority
    surface each method ranks on: the raw base priority
    (``score_base``: the diversity index for DAS, ``log1p(age)`` for
    ABS, the uniform draw for random, ones for full), the
    staleness-boosted value, the reliability-discounted final priority,
    and the resulting dense rank (0 = highest).  Uses the *same* key and
    hook functions the policies consume, so the trace reproduces the
    exact surface ``schedule_impl`` ranked on without touching policy
    internals or drawing extra randomness.  Pure and traceable; only
    the telemetry subsystem calls it, so disabled runs compile no trace.
    """
    if sch.method == "das":
        base = index
    elif sch.method == "abs":
        base = jnp.log1p(ages.astype(jnp.float32))
    elif sch.method == "random":
        base = jax.random.uniform(key, index.shape)
    elif sch.method == "full":
        base = jnp.ones_like(index)
    else:
        raise ValueError(f"unknown scheduling method: {sch.method!r}")
    if sch.method in ("das", "abs"):
        boosted = staleness_boost(base, staleness, sch)
        final = reliability_discount(boosted, reliability, sch)
        if sch.method == "abs" and key is not None:
            # ABS's small random tiebreak (same key the policy used).
            final = final + 1e-4 * jax.random.uniform(key, final.shape)
    else:
        boosted = base
        final = base
    rank = jnp.argsort(jnp.argsort(-final)).astype(jnp.int32)
    return {"score_base": base, "score_boosted": boosted,
            "score_final": final, "score_rank": rank}


def _finalize(selected: Array, alpha: Array, t_train: Array, gains: Array,
              net: wireless.NetworkState, cfg: wireless.WirelessConfig,
              iterations: Array | int = 0,
              payload_bits: Optional[Array] = None) -> ScheduleResult:
    t_up = wireless.upload_time(alpha, gains, net.tx_power, cfg,
                                payload_bits)
    t_up = jnp.where(selected > 0.0, t_up, jnp.inf)
    energy = jnp.where(selected > 0.0, net.tx_power *
                       jnp.where(jnp.isinf(t_up), 0.0, t_up), 0.0)
    t_round = wireless.round_time(
        selected, t_train, jnp.where(jnp.isinf(t_up), 0.0, t_up))
    return ScheduleResult(selected, alpha, t_train,
                          t_up, energy, t_round,
                          jnp.asarray(iterations, jnp.int32))


# ---------------------------------------------------------------------------
# DAS — Algorithm 2
# ---------------------------------------------------------------------------

def das_schedule(index: Array, data_sizes: Array, gains: Array,
                 net: wireless.NetworkState, cfg: wireless.WirelessConfig,
                 sch: SchedulerConfig,
                 alloc: Optional[alloc_lib.Allocator] = None,
                 payload_bits: Optional[Array] = None
                 ) -> ScheduleResult:
    """Data-aware scheduling: iterate Sub1 <-> Sub2 (paper Alg. 2).

    Sub1 needs per-device energies at *some* bandwidth point.  Selected
    devices use their current alpha; unselected devices are evaluated at
    the mean selected share (a hypothetical re-entry allocation), so the
    selection can both shrink and grow across iterations.  Sub2 runs
    through ``alloc`` (default: the config's registered allocator),
    warm-started with the previous outer iteration's allocation — the
    fixed point barely moves between Alg. 2 iterations, so the solver's
    Newton/PGD interiors start next to their solution.  ``payload_bits``
    (compressed-uplink subsystem, DESIGN.md §9) makes every energy/time
    term per-device — Sub1 then ranks on the *effective
    post-compression* upload cost, not the nominal model size.
    """
    alloc = alloc or alloc_lib.get(sch.allocator, sch.sub2)
    k = index.shape[0]
    t_train = wireless.train_time(data_sizes, net, cfg, sch.local_epochs)

    x0 = jnp.ones((k,), jnp.float32)                 # Alg. 2 line 1
    alpha0 = jnp.full((k,), 1.0 / k, jnp.float32)    # line 2: uniform

    def active(carry):
        x, alpha, x_prev, alpha_prev, it = carry
        changed = (jnp.sum(jnp.abs(x - x_prev)) >= sch.x_tol) | \
                  (jnp.max(jnp.abs(alpha - alpha_prev)) >= sch.alpha_tol)
        return (it == 0) | changed

    def alg2_iter(carry):
        x, alpha, _, _, it = carry
        if sch.reentry == "mean":
            # Hypothetical share for currently-unselected devices.
            n_sel = jnp.maximum(jnp.sum(x), 1.0)
            mean_share = jnp.sum(alpha) / n_sel
            alpha_eval = jnp.where(alpha > cfg.min_alpha, alpha,
                                   jnp.maximum(mean_share, 1.0 / k))
        else:  # strict: dropped devices keep their ~zero allocation
            alpha_eval = jnp.maximum(alpha, cfg.min_alpha)
        t_up = wireless.upload_time(alpha_eval, gains, net.tx_power, cfg,
                                    payload_bits)
        energy = net.tx_power * t_up
        # Sub1: select.
        x_new, _, _ = sel.solve_sub1(energy, t_train + t_up, index,
                                     dataclasses.replace(
                                         sch.sub1, n_min=sch.n_min))
        # Sub2: allocate bandwidth over the new selection, warm-started
        # from the allocation this iteration is refining.
        alpha_new, _ = alloc.solve(x_new, t_train, gains, net.tx_power,
                                   cfg, alpha0=alpha,
                                   data_sizes=data_sizes,
                                   payload_bits=payload_bits)
        return x_new, alpha_new, x, alpha, it + 1

    def cond(carry):
        return (carry[4] < sch.iterations_max) & active(carry)

    def body(carry):
        # Freeze-on-convergence carry: a single run exits the while_loop
        # as soon as it converges (the legacy early-exit behavior), while
        # under vmap — where the loop continues until EVERY batch lane's
        # cond is false — converged lanes stop moving instead of being
        # dragged through extra iterations by unconverged peers.  Result:
        # vmap(das) == stack(das) bit-for-bit, at early-exit cost.
        live = active(carry)
        nxt = alg2_iter(carry)
        return tuple(jnp.where(live, n, c) for n, c in zip(nxt, carry))

    init = (x0, alpha0, jnp.zeros_like(x0), jnp.zeros_like(alpha0),
            jnp.asarray(0, jnp.int32))
    x, alpha, _, _, iters = jax.lax.while_loop(cond, body, init)
    return _finalize(x, alpha, t_train, gains, net, cfg, iters,
                     payload_bits)


# ---------------------------------------------------------------------------
# Priority-based baselines (ABS / random / fixed-n)
# ---------------------------------------------------------------------------

def _topn_by_priority(priority: Array, n: int) -> Array:
    _, top = jax.lax.top_k(priority, n)
    return jnp.zeros_like(priority).at[top].set(1.0)


def topn_schedule(priority: Array, n: int, data_sizes: Array, gains: Array,
                  net: wireless.NetworkState, cfg: wireless.WirelessConfig,
                  sch: SchedulerConfig,
                  alloc: Optional[alloc_lib.Allocator] = None,
                  payload_bits: Optional[Array] = None
                  ) -> ScheduleResult:
    """Select exactly ``n`` devices by ``priority``, then run Sub2."""
    alloc = alloc or alloc_lib.get(sch.allocator, sch.sub2)
    t_train = wireless.train_time(data_sizes, net, cfg, sch.local_epochs)
    x = _topn_by_priority(priority, n)
    alpha, _ = alloc.solve(x, t_train, gains, net.tx_power, cfg,
                           data_sizes=data_sizes,
                           payload_bits=payload_bits)
    return _finalize(x, alpha, t_train, gains, net, cfg,
                     payload_bits=payload_bits)


def abs_schedule(ages: Array, data_sizes: Array, gains: Array,
                 net: wireless.NetworkState, cfg: wireless.WirelessConfig,
                 sch: SchedulerConfig, key: Optional[Array] = None,
                 deadline: Optional[float] = None,
                 alloc: Optional[alloc_lib.Allocator] = None,
                 staleness: Optional[Array] = None,
                 payload_bits: Optional[Array] = None,
                 reliability: Optional[Array] = None) -> ScheduleResult:
    """Age-based scheduling (paper §VI baselines, Yang et al. f(k)).

    Priority is ``log(1 + age)`` with a small random tiebreak (all-zero
    ages on round 0 would otherwise pick device order).  With ``n_fixed``
    it is a top-n policy; otherwise devices are admitted greedily in
    priority order while the deadline's minimal bandwidth fits the budget
    — mirroring "collect as many aged updates as fit" from [9, 10].
    Under streaming data, ``staleness`` re-ranks through
    :func:`staleness_boost` (model age and data backlog both measure how
    overdue a device's contribution is).
    """
    alloc = alloc or alloc_lib.get(sch.allocator, sch.sub2)
    t_train = wireless.train_time(data_sizes, net, cfg, sch.local_epochs)
    priority = jnp.log1p(ages.astype(jnp.float32))
    priority = staleness_boost(priority, staleness, sch)
    priority = reliability_discount(priority, reliability, sch)
    if key is not None:
        priority = priority + 1e-4 * jax.random.uniform(key, priority.shape)
    if sch.n_fixed is not None:
        return topn_schedule(priority, sch.n_fixed, data_sizes, gains, net,
                             cfg, sch, alloc, payload_bits)
    # Greedy admission under a deadline: per-device minimal alpha at the
    # deadline is independent across devices -> sort + cumsum.
    if deadline is None:
        # Default deadline: median device at an equal 1/8 band share.
        a_ref = jnp.full_like(priority, 1.0 / 8.0)
        t_ref = t_train + wireless.upload_time(a_ref, gains, net.tx_power,
                                               cfg, payload_bits)
        deadline_arr = jnp.median(t_ref)
    else:
        deadline_arr = jnp.asarray(deadline, jnp.float32)
    ones = jnp.ones_like(priority)
    a_min = bw.alpha_for_deadline(deadline_arr, ones, t_train, gains,
                                  net.tx_power, cfg,
                                  rate_iters=sch.sub2.newton_iters,
                                  payload_bits=payload_bits)
    order = jnp.argsort(-priority)
    a_sorted = a_min[order]
    # n_min backstop (13e): the top-n_min devices are admitted regardless
    # of deadline feasibility — but a forced admit that *cannot* meet the
    # deadline (share sentinel/share > the whole band) must have its
    # share clamped out of the budget accounting before the final Sub2
    # call.  Cumsum'ing the sentinel would permanently blow the budget
    # and silently lock every feasible lower-priority device out of
    # admission, collapsing the selection to the top-n_min sort order.
    # The forced straggler blows the deadline whichever way the band is
    # split, so it contributes zero to the deadline packing; the final
    # Sub2 solve reallocates real bandwidth over everything admitted.
    # (A *feasible* forced admit keeps its true share — it genuinely
    # consumes that much band at the deadline.  An infeasible non-forced
    # row still blocks itself and everyone behind it: ordered greedy
    # admission, unchanged.)
    forced = jnp.arange(priority.shape[0]) < sch.n_min
    a_budget = jnp.where(forced & (a_sorted > 1.0), 0.0, a_sorted)
    admit_sorted = (jnp.cumsum(a_budget) <= 1.0) | forced
    x = jnp.zeros_like(priority).at[order].set(
        admit_sorted.astype(jnp.float32))
    alpha, _ = alloc.solve(x, t_train, gains, net.tx_power, cfg,
                           data_sizes=data_sizes,
                           payload_bits=payload_bits)
    return _finalize(x, alpha, t_train, gains, net, cfg,
                     payload_bits=payload_bits)


def random_schedule(key: Array, data_sizes: Array, gains: Array,
                    net: wireless.NetworkState,
                    cfg: wireless.WirelessConfig,
                    sch: SchedulerConfig,
                    alloc: Optional[alloc_lib.Allocator] = None,
                    payload_bits: Optional[Array] = None
                    ) -> ScheduleResult:
    """Uniform-random selection baseline (paper §VI-B)."""
    priority = jax.random.uniform(key, data_sizes.shape)
    n = sch.n_fixed if sch.n_fixed is not None else sch.n_min
    return topn_schedule(priority, n, data_sizes, gains, net, cfg, sch,
                         alloc, payload_bits)


def full_schedule(data_sizes: Array, gains: Array,
                  net: wireless.NetworkState, cfg: wireless.WirelessConfig,
                  sch: SchedulerConfig,
                  alloc: Optional[alloc_lib.Allocator] = None,
                  payload_bits: Optional[Array] = None
                  ) -> ScheduleResult:
    """Paper's baseline: all devices participate; Sub2 optimizes alpha."""
    alloc = alloc or alloc_lib.get(sch.allocator, sch.sub2)
    t_train = wireless.train_time(data_sizes, net, cfg, sch.local_epochs)
    x = jnp.ones_like(data_sizes, dtype=jnp.float32)
    alpha, _ = alloc.solve(x, t_train, gains, net.tx_power, cfg,
                           data_sizes=data_sizes,
                           payload_bits=payload_bits)
    return _finalize(x, alpha, t_train, gains, net, cfg,
                     payload_bits=payload_bits)


# ---------------------------------------------------------------------------
# Unified entry point
# ---------------------------------------------------------------------------

def schedule_impl(key: Array, index: Array, ages: Array, data_sizes: Array,
                  gains: Array, net: wireless.NetworkState,
                  cfg: wireless.WirelessConfig,
                  sch: SchedulerConfig,
                  staleness: Optional[Array] = None,
                  payload_bits: Optional[Array] = None,
                  reliability: Optional[Array] = None) -> ScheduleResult:
    """Un-jitted :func:`schedule` body.

    Call this from code that is already inside a trace — the
    scan-over-rounds FEEL driver and its vmapped scenario batch
    (``core.federated``) — so the decision inlines into the surrounding
    program instead of nesting a jit call.  The Sub2 allocator is built
    once here (from ``sch.allocator``/``sch.sub2``) and threaded through
    whichever policy dispatches.  ``staleness`` (streaming subsystem)
    re-ranks DAS's index and ABS's age priority via
    :func:`staleness_boost`; random/full ignore it by design (they are
    the data-agnostic baselines).  ``payload_bits`` (compressed-uplink
    subsystem, DESIGN.md §9) is the per-device ``(K,)`` codec payload —
    every policy's time/energy terms, Sub2 solves and the realized
    :class:`ScheduleResult` accounting price those bits instead of the
    scalar ``cfg.model_bits``.  ``reliability`` (fault subsystem,
    DESIGN.md §10) is the per-device empirical-reliability EMA —
    :func:`reliability_discount` shrinks the priority of devices whose
    uploads keep failing; random/full ignore it like they ignore
    staleness (failure-blind baselines).
    """
    alloc = alloc_lib.get(sch.allocator, sch.sub2)
    if sch.method == "das":
        index = staleness_boost(index, staleness, sch)
        index = reliability_discount(index, reliability, sch)
        if sch.n_fixed is not None:
            return topn_schedule(index, sch.n_fixed, data_sizes, gains, net,
                                 cfg, sch, alloc, payload_bits)
        return das_schedule(index, data_sizes, gains, net, cfg, sch, alloc,
                            payload_bits)
    if sch.method == "abs":
        return abs_schedule(ages, data_sizes, gains, net, cfg, sch, key,
                            alloc=alloc, staleness=staleness,
                            payload_bits=payload_bits,
                            reliability=reliability)
    if sch.method == "random":
        return random_schedule(key, data_sizes, gains, net, cfg, sch, alloc,
                               payload_bits)
    if sch.method == "full":
        return full_schedule(data_sizes, gains, net, cfg, sch, alloc,
                             payload_bits)
    raise ValueError(f"unknown scheduling method: {sch.method!r}")


@functools.partial(jax.jit, static_argnames=("cfg", "sch"))
def schedule(key: Array, index: Array, ages: Array, data_sizes: Array,
             gains: Array, net: wireless.NetworkState,
             cfg: wireless.WirelessConfig,
             sch: SchedulerConfig,
             staleness: Optional[Array] = None,
             payload_bits: Optional[Array] = None,
             reliability: Optional[Array] = None) -> ScheduleResult:
    """Dispatch on ``sch.method``; one jit for the whole round's decision."""
    return schedule_impl(key, index, ages, data_sizes, gains, net, cfg, sch,
                         staleness, payload_bits, reliability)
