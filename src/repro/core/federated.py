"""FEEL orchestration — the paper's Algorithm 1 (FedAvg + scheduling).

Each round:

1. Devices report (transmit power, |D_k|, diversity index) — here the
   index is computed from on-device label histograms
   (``core.diversity.diversity_index``), sizes and ages.
2. Fresh channel fading is drawn; the scheduler (``core.scheduler``)
   returns the selected set and bandwidth allocation.
3. Selected devices run ``E`` local epochs of SGD from the global model
   (vmapped over the *entire* client axis, masked by selection — static
   shapes, one jit).
4. The server aggregates with FedAvg weights ``|D_k| / D_r`` (Alg. 1
   line 12) — optionally through the ``fedavg_agg`` Pallas kernel path.
5. Ages update (selected -> 0, others += 1); energy/time accumulate.

Drivers (DESIGN.md §3):

* :func:`run_federated` — the device-resident driver: the entire
  ``num_rounds`` simulation (diversity index, fading draw, scheduling,
  masked local training, FedAvg, age update, folded evaluation, metric
  accumulation) is ONE ``jax.lax.scan`` over rounds inside one jit.
  Per-round metrics come back as stacked arrays (:class:`RoundMetrics`)
  and a thin host adapter converts them to the historical
  :class:`RoundRecord` list, so callers of the old per-round loop keep
  working unchanged.
* :func:`run_federated_batch` — ``vmap`` of the scanned simulation over
  a leading scenario axis (PRNG key x :class:`wireless.NetworkState`
  realization): S independent FEEL runs execute as one SPMD program.
  Every scheduling policy is vmap-deterministic (``core.scheduler``),
  so scenario ``i`` of a batch is bit-for-bit the single run with
  ``nets[i]``/``keys[i]``.
* :func:`run_federated_loop` — the legacy host-side Python loop (two
  jit dispatches + >=5 host syncs per round), kept as the reference
  implementation for the parity tests and the ``fl_e2e`` old-vs-new
  benchmark.

The client axis is shardable: on a pod, ``client_batch_spec`` places
clients over the ``data`` mesh axis so K local trainings run as one SPMD
program — the cross-silo mapping described in DESIGN.md §3.

Streaming data (``FLConfig.stream``, DESIGN.md §7): when set, a
:class:`repro.core.streaming.StreamState` joins the scan carry — each
round samples data arrivals, refreshes per-device class counts /
diversity stats / staleness in one fused pass, and schedules + trains on
the refreshed statistics.  Both drivers and the legacy loop share the
sequence, so every parity contract above extends to streaming runs.

Compressed uplink (``FLConfig.compression``, DESIGN.md §9): when set,
devices upload codec-compressed updates — the codec's per-device
payload bits flow into scheduling and Sub2 (Eq. 6/9/10 price the
*effective* post-compression bits), the round's FedAvg aggregates the
dequantized values, and the ``(K, P)`` error-feedback residual joins
the scan carry so lossy rounds stay bit-for-bit reproducible across
drivers (scan == legacy loop, batch == S independent runs).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bandwidth, compression, diversity, faults, \
    scheduler, streaming, wireless
from repro.core import events as events_lib
from repro.data import partition as partition_lib
from repro.data import synthetic
from repro import telemetry as telemetry_lib
from repro.telemetry import health as telemetry_health
from repro.telemetry import record as telemetry_record

Array = jax.Array
Params = Any


@dataclasses.dataclass(frozen=True)
class FLConfig:
    num_rounds: int = 15                  # paper: 15 rounds
    local_epochs: int = 1                 # E
    batch_size: int = 50                  # one shard per step
    learning_rate: float = 0.05
    momentum: float = 0.0
    num_classes: int = 10
    measure: str = "gini_simpson"
    index_weights: diversity.IndexWeights = diversity.IndexWeights()
    use_kernel_agg: bool = False          # route FedAvg through Pallas
    # Streaming-data subsystem (DESIGN.md §7): when set, per-device data
    # evolves round by round inside the scan carry and the scheduler
    # re-ranks on the refreshed statistics.  None = static data,
    # bit-for-bit the pre-streaming behavior.
    stream: Optional[streaming.StreamConfig] = None
    # Compressed-uplink subsystem (DESIGN.md §9): when set, devices
    # upload codec-compressed updates — per-device payload bits price
    # scheduling and Sub2, the lossy round trip shapes the aggregate,
    # and the error-feedback residual joins the scan carry.  None =
    # full-precision uploads, bit-for-bit the pre-compression behavior.
    compression: Optional[compression.CompressionConfig] = None
    # Unreliable-edge subsystem (DESIGN.md §10): when set, per-round
    # fault processes (outages, deep fades, stragglers, dropouts) are
    # drawn inside the scan, uploads retry with exponential backoff,
    # FedAvg aggregates over the success mask only, and the scheduler
    # discounts priorities by a per-device reliability EMA carried in
    # the scan state.  None = perfectly reliable edge, bit-for-bit the
    # pre-fault behavior.
    faults: Optional[faults.FaultConfig] = None
    # Admitted-set dense-block dispatch (DESIGN.md §11): static capacity
    # of the training block.  When set, each round gathers the admitted
    # devices into a fixed ``(n_cap, ...)`` block (stable argsort on the
    # selection mask), runs the vmapped local trainer over only those
    # lanes, and scatters the results back for FedAvg.  Admitted devices
    # beyond the capacity are dropped deterministically by schedule rank
    # and counted in ``RoundMetrics.n_dropped``.  None = today's
    # masked-all-K path, bitwise unchanged.
    dispatch_cap: Optional[int] = None
    # Scan-carry memory diet (DESIGN.md §11): storage dtype for the
    # ``(K, P)`` error-feedback residual and the ``(K, C)`` stream
    # stats between rounds ("bfloat16"/"float16").  Arithmetic stays
    # float32 — state is downcast on carry write and upcast on read, in
    # helpers shared by both drivers so the scan==legacy parity holds at
    # reduced precision too.  None (or "float32") = full-precision
    # carry, bitwise unchanged.
    carry_dtype: Optional[str] = None
    # Event-driven asynchronous FEEL (DESIGN.md §12): when set, the
    # simulation runs as a scan over scheduling *events* instead of
    # synchronous rounds — per-device availability processes gate
    # admission, uploads land after their compute + channel time, and
    # the server applies staleness-weighted buffered aggregation
    # (``core.events``).  ``make_feel_sim``/``make_feel_sim_batch``
    # delegate to the event drivers, so the sweep engine and batch
    # driver compose unchanged.  None = synchronous rounds; the event
    # scan's synchronous limit reproduces them bitwise
    # (``tests/test_events.py``).
    events: Optional[events_lib.EventConfig] = None
    # In-scan telemetry subsystem (DESIGN.md §13): when set, the scan
    # bodies of both drivers (and the legacy loop) emit a per-round
    # telemetry frame — scheduler score decompositions, admission/
    # dispatch/delivery outcomes, Sub2 solver traces, per-device
    # transport accounting, fault events by type, event-mode
    # availability state — as an extra stacked output alongside
    # RoundMetrics.  The frame only observes (no extra PRNG draws,
    # nothing feeds back into the round), so the primary outputs stay
    # bitwise identical to a disabled run.  None = no telemetry,
    # bitwise today's program (the faults.active inert-config pattern).
    telemetry: Optional[telemetry_lib.TelemetryConfig] = None


def sim_length(fcfg: FLConfig) -> int:
    """Rows in the simulation's metrics: ``num_rounds`` for the
    synchronous drivers, ``events.num_events`` (when set) for the event
    drivers — the one place that resolves the default, so the sweep
    engine's Welford aggregates and checkpoint shapes stay in step with
    whichever driver ``make_feel_sim`` delegates to."""
    if fcfg.events is not None and fcfg.events.num_events is not None:
        return fcfg.events.num_events
    return fcfg.num_rounds


@dataclasses.dataclass
class RoundRecord:
    round: int
    accuracy: float
    n_selected: int
    round_time: float
    energy_total: float
    energy_per_device: float
    selected: np.ndarray
    # Devices whose upload actually landed; equals n_selected on a
    # reliable edge (faults=None).  Defaulted so pre-fault positional
    # constructors keep working; the -1 sentinel is normalized to
    # n_selected in __post_init__ so it never reaches users.
    n_success: int = -1
    # Admitted devices dropped by the dispatch capacity this round
    # (always 0 with ``dispatch_cap=None``).  Defaulted like n_success.
    n_dropped: int = 0

    def __post_init__(self):
        if self.n_success < 0:
            self.n_success = self.n_selected


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RoundMetrics:
    """Per-round simulation outputs as stacked device arrays.

    Leaves carry a leading ``(num_rounds,)`` axis — and an additional
    leading scenario axis when produced by :func:`run_federated_batch`.
    ``accuracy`` is NaN on rounds where evaluation was skipped
    (``eval_every`` stride), matching the legacy record semantics.
    """

    accuracy: Array      # (R,)
    n_selected: Array    # (R,) int32
    round_time: Array    # (R,)
    energy: Array        # (R, K) per-device joules (0 if unselected)
    energy_total: Array  # (R,)
    selected: Array      # (R, K) {0,1}
    iterations: Array    # (R,) int32 DAS outer iterations
    n_success: Array     # (R,) int32 uploads that landed (= n_selected
                         # on a reliable edge)
    n_dropped: Array     # (R,) int32 admitted devices dropped by the
                         # dispatch capacity (0 with dispatch_cap=None)

    def tree_flatten(self):
        return ((self.accuracy, self.n_selected, self.round_time,
                 self.energy, self.energy_total, self.selected,
                 self.iterations, self.n_success, self.n_dropped), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


# ---------------------------------------------------------------------------
# Local training (vmapped over clients)
# ---------------------------------------------------------------------------

def make_local_trainer(loss_fn: Callable[[Params, Array, Array, Array],
                                         Array],
                       cfg: FLConfig) -> Callable:
    """Build the vmapped multi-epoch local-SGD update.

    Every client runs ``steps_k = E * ceil(size_k / B)`` gradient steps;
    clients are padded to the max step count and masked, so one
    ``lax.scan`` covers the heterogeneous dataset sizes (the wireless time
    model separately charges each device for its true workload, Eq. 8).
    """

    def local_sgd(params: Params, images: Array, labels: Array,
                  mask: Array, steps_active: Array, key: Array) -> Params:
        cap = images.shape[0]

        def step(carry, inp):
            p, vel = carry
            k, active = inp
            idx = jax.random.randint(k, (cfg.batch_size,), 0, cap)
            bx = synthetic.to_float(images[idx])
            by = labels[idx]
            bm = mask[idx]
            g = jax.grad(loss_fn)(p, bx, by, bm)
            vel = jax.tree_util.tree_map(
                lambda v, gi: cfg.momentum * v + gi, vel, g)
            p_new = jax.tree_util.tree_map(
                lambda w, v: w - cfg.learning_rate * v, p, vel)
            p = jax.tree_util.tree_map(
                lambda new, old: jnp.where(active > 0.0, new, old),
                p_new, p)
            return (p, vel), None

        vel0 = jax.tree_util.tree_map(jnp.zeros_like, params)
        keys = jax.random.split(key, steps_active.shape[0])
        (params, _), _ = jax.lax.scan(step, (params, vel0),
                                      (keys, steps_active))
        return params

    return jax.vmap(local_sgd, in_axes=(None, 0, 0, 0, 0, 0))


def fedavg_aggregate(client_params: Params, weights: Array,
                     use_kernel: bool = False) -> Params:
    """g <- sum_k (D_k / D_r) w_k (Alg. 1 line 12) over stacked params.

    ``weights`` must already be normalized over the selected set (zeros
    for unselected clients).

    The kernel path flattens the whole pytree once — every leaf reshaped
    to ``(K, -1)`` and concatenated — so the Pallas ``fedavg_agg`` kernel
    launches once per round instead of once per parameter leaf (leaves
    must share a dtype, which stacked model params do).
    """
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        leaves, treedef = jax.tree_util.tree_flatten(client_params)
        dtypes = {leaf.dtype for leaf in leaves}
        if len(dtypes) != 1:
            # concatenate would silently promote mixed-dtype leaves,
            # diverging from the dtype-preserving tensordot path.
            raise TypeError(
                f"kernel FedAvg path needs uniform leaf dtype, got "
                f"{sorted(map(str, dtypes))}")
        k = leaves[0].shape[0]
        sizes = [int(np.prod(leaf.shape[1:])) for leaf in leaves]
        flat = jnp.concatenate(
            [leaf.reshape(k, -1) for leaf in leaves], axis=1)
        agg = kernel_ops.fedavg_agg(flat, weights)
        outs, offset = [], 0
        for leaf, size in zip(leaves, sizes):
            outs.append(agg[offset:offset + size].reshape(leaf.shape[1:]))
            offset += size
        return jax.tree_util.tree_unflatten(treedef, outs)
    return jax.tree_util.tree_map(
        lambda stacked: jnp.tensordot(weights, stacked, axes=1),
        client_params)


# ---------------------------------------------------------------------------
# Admitted-set dense-block dispatch (DESIGN.md §11)
# ---------------------------------------------------------------------------

def dispatch_plan(selected: Array, n_cap: int
                  ) -> Tuple[Array, Array, Array]:
    """Gather plan for the dense training block: ``(idx, sel_eff, n_dropped)``.

    ``idx`` is the ``(min(n_cap, K),)`` device indices that occupy the
    block's lanes, ``sel_eff`` the ``(K,)`` realized selection mask after
    capacity drops, and ``n_dropped`` the int32 count of admitted devices
    that did not fit.

    Schedule rank: ``jnp.argsort`` is stable, so ``argsort(-selected)``
    lists the admitted devices first *in device-index order*, then the
    rest.  The rank is a pure function of the selection mask — no
    data-dependent shapes, no host sync, identical under ``vmap`` — which
    is what makes overflow drops deterministic across the batch/shard_map
    drivers (the batch == singles contract).  Admitted devices with rank
    ``>= n_cap`` are dropped for the round.
    """
    k = selected.shape[0]
    n_lanes = min(int(n_cap), k)                    # static
    order = jnp.argsort(-selected)
    idx = order[:n_lanes]
    sel_eff = jnp.zeros_like(selected).at[idx].set(selected[idx])
    n_dropped = (jnp.sum(selected) - jnp.sum(sel_eff)).astype(jnp.int32)
    return idx, sel_eff, n_dropped


def _dispatch_accounting(result, sel_eff: Array) -> Tuple[Array, Array]:
    """Re-price a scheduled round on the *realized* (post-drop) set.

    The scheduler already charged energy/airtime for every admitted
    device; capacity-dropped devices never train or transmit, so their
    energy is zeroed and the round's wall clock is the max over the
    surviving set only.  Shared by the scan body and the (jitted) legacy
    loop so both drivers price drops identically.
    """
    energy = result.energy * sel_eff
    t_up = jnp.where(jnp.isinf(result.t_up), 0.0, result.t_up)
    return energy, wireless.round_time(sel_eff, result.t_train, t_up)


# Legacy-loop entries: jitted (not eager) on purpose, mirroring
# ``faults.fault_step`` — the scan driver compiles the same arithmetic
# fused, and op-at-a-time eager scheduling is the one way the loop could
# drift off the scan bitwise.
_dispatch_plan_jit = jax.jit(dispatch_plan, static_argnums=(1,))
_dispatch_accounting_jit = jax.jit(_dispatch_accounting)
_signal_update_jit = jax.jit(telemetry_health.signal_update)


def _carry_dtype(fcfg: FLConfig):
    """Storage dtype for the dieted scan-carry state, or None.

    ``float32`` normalizes to None (the storage dtype already is f32, so
    emitting casts would only change the jaxpr, not the values).
    """
    if fcfg.carry_dtype is None:
        return None
    dt = jnp.dtype(fcfg.carry_dtype)
    if dt == jnp.dtype(jnp.float32):
        return None
    if dt not in (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16)):
        raise ValueError(
            f"carry_dtype must be one of bfloat16/float16/float32, got "
            f"{fcfg.carry_dtype!r}")
    return dt


# ---------------------------------------------------------------------------
# One federated round (shared by the scan driver and the legacy loop)
# ---------------------------------------------------------------------------

def _masked_local_train(trainer: Callable, max_steps: int, cfg: FLConfig,
                        params: Params, images: Array, labels: Array,
                        mask: Array, sizes: Array, selected: Array,
                        key: Array,
                        dispatch_idx: Optional[Array] = None
                        ) -> Tuple[Params, Array]:
    """Masked local SGD for all K clients -> (stacked params, FedAvg w).

    The single definition of the per-client step schedule and the
    ``D_k / D_r`` weight normalization — the plain and compressed round
    bodies both call it, so the scan==legacy parity contracts cannot be
    broken by editing one copy.

    ``dispatch_idx`` (DESIGN.md §11) switches on the dense-block path:
    the per-device operands are gathered into a ``(n_cap, ...)`` block,
    the vmapped trainer runs over only those lanes, and the trained
    params scatter back into the ``(K, ...)`` layout with the global
    model as filler.  Two invariants make ``dispatch_cap >= K`` bitwise
    equal to the masked path: (a) device ``d``'s PRNG key is
    ``split(key, K)[d]`` gathered by lane — a device's SGD noise never
    depends on which lane it lands in — and (b) the scatter restores
    device order *before* FedAvg, so the aggregation's float reduction
    order is the same one the masked path uses.
    """
    k = images.shape[0]
    # Per-client active step schedule: E * ceil(size_k / B) steps.
    steps_k = cfg.local_epochs * jnp.ceil(
        sizes.astype(jnp.float32) / cfg.batch_size)
    step_idx = jnp.arange(max_steps, dtype=jnp.float32)[None, :]
    active = (step_idx < steps_k[:, None]).astype(jnp.float32)
    active = active * selected[:, None]             # frozen if unselected
    keys = jax.random.split(key, k)
    with telemetry_lib.phase_scope("local_train"):
        if dispatch_idx is None:
            client_params = trainer(params, images, labels, mask, active,
                                    keys)
        else:
            idx = dispatch_idx
            block = trainer(params, images[idx], labels[idx], mask[idx],
                            active[idx], keys[idx])
            # Scatter the trained lanes back to device order; every
            # off-block device is frozen at the global model (exactly
            # what its masked-path lane would have returned).
            client_params = jax.tree_util.tree_map(
                lambda p, b: jnp.broadcast_to(p[None], (k,) + p.shape)
                .at[idx].set(b),
                params, block)
    # FedAvg weights D_k / D_r over the selected set.
    w = sizes.astype(jnp.float32) * selected
    w = w / jnp.maximum(jnp.sum(w), 1.0)
    return client_params, w


def _train_round(trainer: Callable, max_steps: int, cfg: FLConfig,
                 params: Params, images: Array, labels: Array, mask: Array,
                 sizes: Array, selected: Array, key: Array,
                 dispatch_idx: Optional[Array] = None,
                 sig_fn: Optional[Callable] = None) -> Params:
    """Masked local training for all K clients + FedAvg. Pure, traceable.

    An empty admitted set (possible when ``n_min == 0`` and every device
    misses the deadline) must carry the previous model forward — the
    all-zero weights would otherwise *replace* the global model with
    zeros.  The guard is a scalar select, so any non-empty round keeps
    the aggregated value bitwise unchanged.  Under dispatch the guard
    still works: an all-dropped/all-unselected round scatters nothing
    but frozen lanes and the zero-weight aggregate is discarded.

    ``sig_fn`` (telemetry signals group, DESIGN.md §14) is the
    learning-signal observer from :func:`_make_sig_fn`: when set, the
    return value grows a trailing ``(loss_delta, update_norm)`` pair
    computed from the stacked client params *before* aggregation.  A
    pure observer — the aggregate itself is untouched.
    """
    client_params, w = _masked_local_train(trainer, max_steps, cfg, params,
                                           images, labels, mask, sizes,
                                           selected, key,
                                           dispatch_idx=dispatch_idx)
    obs = sig_fn(params, client_params, None, images, labels, mask) \
        if sig_fn is not None else None
    with telemetry_lib.phase_scope("aggregate"):
        agg = fedavg_aggregate(client_params, w, cfg.use_kernel_agg)
        any_sel = jnp.sum(selected) > 0.0
        new_params = jax.tree_util.tree_map(
            lambda a, p: jnp.where(any_sel, a, p), agg, params)
    if sig_fn is not None:
        return new_params, obs
    return new_params


def fedavg_aggregate_masked(params: Params, client_params: Params,
                            weights: Array, mask: Array,
                            use_kernel: bool = False) -> Params:
    """Failure-aware FedAvg in update form (fault subsystem, DESIGN.md §10).

    ``g' = g + sum_k w_k m_k (w^k - g)`` with ``weights`` normalized by
    the caller over the success set and ``mask`` the upload-success
    indicator.  The update form is the graceful-degradation guarantee:
    all-zero masked weights leave ``g`` exactly unchanged (the server
    carries the previous model when every upload fails), with no branch.
    The kernel path flattens the per-client deltas once and runs the
    masked ``fedavg_agg`` Pallas lane.
    """
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        leaves, _ = jax.tree_util.tree_flatten(client_params)
        p_leaves, p_treedef = jax.tree_util.tree_flatten(params)
        dtypes = {leaf.dtype for leaf in p_leaves}
        if len(dtypes) != 1:
            raise TypeError(
                f"kernel FedAvg path needs uniform leaf dtype, got "
                f"{sorted(map(str, dtypes))}")
        k = leaves[0].shape[0]
        deltas = jnp.concatenate(
            [(cl - p[None]).reshape(k, -1)
             for cl, p in zip(leaves, p_leaves)], axis=1)
        agg = kernel_ops.fedavg_agg_masked(deltas, weights, mask)
        outs, offset = [], 0
        for p in p_leaves:
            size = int(np.prod(p.shape))
            outs.append(p + agg[offset:offset + size].reshape(p.shape)
                        .astype(p.dtype))
            offset += size
        return jax.tree_util.tree_unflatten(p_treedef, outs)
    # Broadcast-multiply-reduce, NOT tensordot: a batched dot_general
    # lowers through a different CPU matmul tiling than the single-lane
    # one, so the vmapped batch driver would drift a few ULP off the
    # per-scenario runs.  The explicit sum keeps one reduction order in
    # every context (the batch == singles bitwise contract).
    wm = weights * mask
    return jax.tree_util.tree_map(
        lambda p, st: p + jnp.sum(
            wm.reshape(wm.shape + (1,) * (st.ndim - 1)) * (st - p[None]),
            axis=0).astype(p.dtype),
        params, client_params)


def _train_round_faulty(trainer: Callable, max_steps: int, cfg: FLConfig,
                        params: Params, images: Array, labels: Array,
                        mask: Array, sizes: Array, selected: Array,
                        ok: Array, key: Array,
                        dispatch_idx: Optional[Array] = None,
                        sig_fn: Optional[Callable] = None) -> Params:
    """Fault-aware round: train the *selected* set, aggregate the *ok* set.

    Every admitted device runs its local epochs (the failure happens at
    upload time, after the compute was spent), but only devices whose
    upload landed contribute to FedAvg — weights are renormalized over
    the success set, so the aggregate stays a convex combination and an
    all-fail round degrades to carrying the previous model
    (:func:`fedavg_aggregate_masked`).

    ``sig_fn``: see :func:`_train_round` — appends the observer's
    ``(loss_delta, update_norm)`` pair to the return value.
    """
    client_params, _ = _masked_local_train(trainer, max_steps, cfg, params,
                                           images, labels, mask, sizes,
                                           selected, key,
                                           dispatch_idx=dispatch_idx)
    obs = sig_fn(params, client_params, None, images, labels, mask) \
        if sig_fn is not None else None
    with telemetry_lib.phase_scope("aggregate"):
        w = sizes.astype(jnp.float32) * ok
        w = w / jnp.maximum(jnp.sum(w), 1.0)
        new_params = fedavg_aggregate_masked(params, client_params, w, ok,
                                             cfg.use_kernel_agg)
    if sig_fn is not None:
        return new_params, obs
    return new_params


def _max_local_steps(cfg: FLConfig, capacity: int) -> int:
    steps_per_epoch = max(1, -(-capacity // cfg.batch_size))
    return cfg.local_epochs * steps_per_epoch


# ---------------------------------------------------------------------------
# Compressed uplink (DESIGN.md §9): lossy updates + error feedback
# ---------------------------------------------------------------------------

def flat_param_size(params: Params) -> int:
    """Total flattened coordinate count — the error-feedback residual's
    trailing dimension (static from the param shapes)."""
    return sum(int(np.prod(leaf.shape))
               for leaf in jax.tree_util.tree_leaves(params))


def _comp_setup(fcfg: FLConfig) -> compression.Codec:
    """Codec instance for a compressed run (shared by the scan driver
    and the legacy loop so their uplink sequence cannot drift apart)."""
    return compression.get_codec(fcfg.compression.codec)


def _train_round_compressed(trainer: Callable, max_steps: int,
                            fcfg: FLConfig, codec: compression.Codec,
                            params: Params, images: Array, labels: Array,
                            mask: Array, sizes: Array, selected: Array,
                            key: Array, residual: Array, gains: Array,
                            index: Array,
                            success: Optional[Array] = None,
                            dispatch_idx: Optional[Array] = None,
                            sig_fn: Optional[Callable] = None
                            ) -> Tuple[Params, Array]:
    """Masked local training + compressed-uplink FedAvg.  Pure, traceable.

    Local SGD is identical to :func:`_train_round`; the aggregation
    differs: client *updates* (``w_k - g``) are flattened to one
    ``(K, P)`` matrix, pushed through the codec's fused
    residual-accumulate -> compress -> dequantize pass
    (``compression.apply_codec``), and the decoded values are averaged
    with the FedAvg weights onto the global model (``g' = g + sum_k
    (D_k / D_r) c_k``).  Returns the new params and the advanced
    error-feedback residual (only selected devices consume backlog).
    Unselected clients are frozen, so their raw update is exactly zero
    and their decoded row is multiplied by a zero weight.

    ``success`` (fault subsystem, DESIGN.md §10) is the upload-landed
    mask: FedAvg weights renormalize over the *successful* set, the
    codec consumes backlog only for devices that delivered, and a
    failed device's whole update folds back into its error-feedback
    residual (``compression.apply_codec``).  The update-form aggregate
    means an all-fail round carries the previous model unchanged.
    ``None`` is the reliable-edge path, bitwise the pre-fault behavior.

    ``dispatch_idx`` (DESIGN.md §11): the dense block trains ``n_cap``
    lanes and the trained params scatter back to the ``(K, ...)`` layout
    *before* the updates are flattened — off-block rows equal the global
    model bitwise, so their raw update is exactly zero, the codec sees
    them as untransmitted, and the ``(K, P)`` EF residual carry keeps
    its population shape under dispatch.

    With ``fcfg.carry_dtype`` set the residual is *stored* at reduced
    precision between rounds: upcast to f32 here on entry, advanced in
    f32 by the codec, and downcast on return.  Both drivers call this
    one body, so the cast points cannot drift apart.
    """
    k = images.shape[0]
    cdt = _carry_dtype(fcfg)
    if cdt is not None:
        residual = residual.astype(jnp.float32)
    k_sgd, k_comp = jax.random.split(key)
    client_params, w = _masked_local_train(trainer, max_steps, fcfg,
                                           params, images, labels, mask,
                                           sizes, selected, k_sgd,
                                           dispatch_idx=dispatch_idx)
    leaves, _ = jax.tree_util.tree_flatten(client_params)
    p_leaves, p_treedef = jax.tree_util.tree_flatten(params)
    dtypes = {leaf.dtype for leaf in p_leaves}
    if len(dtypes) != 1:
        # the flattened (K, P) update matrix would silently promote
        # mixed-dtype leaves; same guard as the kernel FedAvg path.
        raise TypeError(f"compressed uplink needs uniform leaf dtype, "
                        f"got {sorted(map(str, dtypes))}")
    updates = jnp.concatenate(
        [(cl - p[None]).reshape(k, -1)
         for cl, p in zip(leaves, p_leaves)], axis=1)
    obs = sig_fn(params, client_params, updates, images, labels, mask) \
        if sig_fn is not None else None
    if success is not None:
        w = sizes.astype(jnp.float32) * selected * success
        w = w / jnp.maximum(jnp.sum(w), 1.0)
    with telemetry_lib.phase_scope("aggregate"):
        c, residual = compression.apply_codec(
            codec, updates, residual, selected, k_comp, fcfg.compression,
            gains, index, success=success)
        if cdt is not None:
            residual = residual.astype(cdt)
        agg = jnp.tensordot(w, c, axes=1)           # (P,)
        outs, offset = [], 0
        for p in p_leaves:
            size = int(np.prod(p.shape))
            outs.append(p + agg[offset:offset + size].reshape(p.shape)
                        .astype(p.dtype))
            offset += size
        new_params = jax.tree_util.tree_unflatten(p_treedef, outs)
    if sig_fn is not None:
        return new_params, residual, obs
    return new_params, residual


def _sched_cfg(scfg: scheduler.SchedulerConfig,
               fcfg: FLConfig) -> scheduler.SchedulerConfig:
    """Round-time scheduler config shared by the scan driver and the
    legacy loop (the parity contract depends on both deriving it
    identically).  Syncs ``local_epochs`` and — with faults enabled —
    applies the overprovisioning bump: Sub1 admits ``overprovision``
    extra devices so the *expected* surviving set still meets the
    original floor (DESIGN.md §10)."""
    sch = dataclasses.replace(scfg, local_epochs=fcfg.local_epochs)
    flt = faults.active(fcfg.faults)
    if flt is not None and flt.overprovision > 0:
        sch = dataclasses.replace(
            sch, n_min=sch.n_min + flt.overprovision,
            n_fixed=None if sch.n_fixed is None
            else sch.n_fixed + flt.overprovision)
    return sch


def _make_sig_fn(loss_fn: Callable, fcfg: FLConfig,
                 capacity: int) -> Callable:
    """Learning-signal observer for the telemetry ``signals`` group.

    Returns ``sig_fn(params0, client_params, updates, images, labels,
    mask) -> (loss_delta, update_norm)``, both ``(K,) f32``.  The
    compressed round passes its existing flattened ``(K, P)`` update
    matrix; the plain/faulty rounds pass ``None`` and the matrix is
    built here with the same ravel order, so every driver path shares
    one norm reduction.  The loss probe evaluates a fixed leading
    window of each shard (no PRNG), so enabling signals cannot perturb
    the round (DESIGN.md §14 purity contract).  The window is capped
    at ``health.PROBE_CAP`` samples: the probe costs two forward
    passes per device per round, and an uncapped batch-size window
    prices at ~25% of the whole round body — the cap keeps the
    signals group inside the <1.10 telemetry overhead budget.
    """
    probe = telemetry_health.make_signal_probe(
        loss_fn, min(fcfg.batch_size, capacity,
                     telemetry_health.PROBE_CAP))

    def sig_fn(params0, client_params, updates, images, labels, mask):
        if updates is None:
            updates = telemetry_health.flatten_updates(client_params,
                                                       params0)
        return (probe(params0, client_params, images, labels, mask),
                telemetry_health.update_norms(updates))

    return sig_fn


def _sig_enabled(fcfg: FLConfig) -> bool:
    tel = telemetry_lib.active(fcfg.telemetry)
    return tel is not None and tel.signals


def make_round_fn(loss_fn: Callable, cfg: FLConfig,
                  capacity: int,
                  sig_fn: Optional[Callable] = None) -> Callable:
    """Returns jit'd ``round_fn(params, data, selected, weights, key)``.

    ``selected``/``weights`` come from the scheduler (host side); the round
    body — local training for all K clients, masked FedAvg — is one SPMD
    program.  Used by the legacy per-round loop; the scan driver inlines
    the same :func:`_train_round` body.  With ``cfg.compression`` set the
    returned function is the compressed-uplink round
    (:func:`_train_round_compressed`): it additionally takes
    ``(residual, gains, index)`` and returns ``(params, residual)``.
    With ``cfg.faults`` set (and no compression) it is the fault-aware
    round (:func:`_train_round_faulty`), taking the upload-success mask
    ``ok`` after ``selected``; the compressed round takes the mask as
    its ``success`` keyword either way.  Every variant accepts a
    ``dispatch_idx`` keyword (the dense-block gather indices from
    :func:`dispatch_plan`; None = masked all-K path).
    """
    trainer = make_local_trainer(loss_fn, cfg)
    max_steps = _max_local_steps(cfg, capacity)
    if cfg.compression is not None:
        codec = _comp_setup(cfg)
        return jax.jit(functools.partial(_train_round_compressed, trainer,
                                         max_steps, cfg, codec,
                                         sig_fn=sig_fn))
    if faults.active(cfg.faults) is not None:
        return jax.jit(functools.partial(_train_round_faulty, trainer,
                                         max_steps, cfg, sig_fn=sig_fn))
    return jax.jit(functools.partial(_train_round, trainer, max_steps, cfg,
                                     sig_fn=sig_fn))


# ---------------------------------------------------------------------------
# Device-resident simulation: scan over rounds, one jit
# ---------------------------------------------------------------------------

def _eval_mask(num_rounds: int, eval_every: int) -> np.ndarray:
    """Static per-round evaluate-or-skip schedule (legacy semantics)."""
    mask = np.zeros((num_rounds,), np.bool_)
    mask[::max(eval_every, 1)] = True
    mask[-1] = True
    return mask


def _stream_size_cap(stream: streaming.StreamConfig, capacity: int) -> float:
    """Effective per-device count cap for a streaming run.

    Streamed sizes drive the local step counts and FedAvg weights, so
    they must stay within the padded sample buffers; the configured cap
    (if any) is additionally clipped to the physical capacity.
    """
    if stream.size_cap <= 0.0:
        return float(capacity)
    return min(float(stream.size_cap), float(capacity))


def _stream_setup(fcfg: FLConfig, capacity: int):
    """(process, size_cap, stats column of ``fcfg.measure``).

    Shared by the scan driver and the legacy loop so their streaming
    setup cannot drift apart (the parity contract depends on it).
    """
    process = streaming.get_process(fcfg.stream.process)
    size_cap = _stream_size_cap(fcfg.stream, capacity)
    if fcfg.measure not in ("gini_simpson", "shannon"):
        raise ValueError(f"unknown diversity measure: {fcfg.measure!r}")
    return process, size_cap, 0 if fcfg.measure == "gini_simpson" else 1


def _stream_round(process, fcfg: FLConfig, size_cap: float,
                  measure_col: int, k_arr: Array,
                  st: streaming.StreamState, ages: Array):
    """One round's data evolution: sample -> fused refresh -> index.

    Returns ``(index, sizes, staleness, refreshed hists, state)``.  The
    single definition of the streaming round sequence — the scan body
    and the legacy loop both call it, so the bit-for-bit parity between
    them cannot be broken by editing one copy.

    With ``fcfg.carry_dtype`` set the ``(K, C)`` hists and ``(K,)``
    staleness arrive at storage precision (see :func:`_stream_advance`);
    they are upcast here before any arithmetic so the whole refresh runs
    in f32 and only the carried state pays the diet.
    """
    with telemetry_lib.phase_scope("stream_refresh"):
        cdt = _carry_dtype(fcfg)
        if cdt is not None:
            st = dataclasses.replace(
                st, hists=st.hists.astype(jnp.float32),
                staleness=st.staleness.astype(jnp.float32))
        deltas, arrivals, st = process.sample(k_arr, st, fcfg.stream)
        hists_r, stats, stale = streaming.refresh(
            st.hists, deltas, arrivals, st.staleness, st.selected_prev,
            fcfg.stream, size_cap=size_cap)
        sizes_r = stats[..., 2]
        index = diversity.diversity_index_from_stats(
            div=stats[..., measure_col], data_sizes=sizes_r, ages=ages,
            weights=fcfg.index_weights)
        return index, sizes_r, stale, hists_r, st


def _stream_advance(st: streaming.StreamState, hists_r: Array,
                    stale: Array, selected: Array,
                    cdt=None) -> streaming.StreamState:
    """Post-decision carry update (driver-owned StreamState fields).

    ``cdt`` (from :func:`_carry_dtype`) is the storage dtype of the
    dieted carry: the refreshed hists/staleness are downcast on write
    and :func:`_stream_round` upcasts them on the next read.
    """
    if cdt is not None:
        hists_r = hists_r.astype(cdt)
        stale = stale.astype(cdt)
    return dataclasses.replace(st, hists=hists_r, staleness=stale,
                               selected_prev=selected,
                               round=st.round + 1)


def _diet_stream_state(st: streaming.StreamState,
                       cdt) -> streaming.StreamState:
    """Cast a fresh StreamState's carried stats to storage precision so
    the round-0 carry structure matches what :func:`_stream_advance`
    writes (scan carries must be dtype-stable)."""
    if cdt is None:
        return st
    return dataclasses.replace(st, hists=st.hists.astype(cdt),
                               staleness=st.staleness.astype(cdt))


def _make_sim(loss_fn: Callable, eval_fn: Callable, wcfg, scfg, fcfg,
              capacity: int, eval_every: int) -> Callable:
    """Build the traceable whole-simulation function (no jit applied).

    The returned ``sim(params, images, labels, mask, sizes, hists,
    test_x, test_labels, net, key)`` runs all ``fcfg.num_rounds`` rounds
    as a single ``lax.scan`` and returns ``(final_params, RoundMetrics)``.
    Evaluation is folded into the scan at the static ``eval_every``
    stride via ``lax.cond`` on a per-round flag carried as scan inputs —
    the flag is un-batched under the scenario vmap, so skipped rounds
    skip the eval computation in the batched program too.

    With ``fcfg.stream`` set, the scan carry additionally holds a
    :class:`streaming.StreamState`: each round samples count deltas from
    the arrival process, refreshes the class-count matrix / diversity
    stats / staleness in one fused pass (``streaming.refresh``), and
    feeds the *refreshed* sizes and index — plus the staleness signal —
    into scheduling and training (DESIGN.md §7).

    With ``fcfg.compression`` set, the carry additionally holds the
    ``(K, P)`` error-feedback residual (DESIGN.md §9): each round the
    codec's per-device payload bits price scheduling and Sub2, the
    round's updates go through the fused residual-accumulate ->
    compress -> dequantize pass, and the residual advances for the
    devices that transmitted.  Streaming and compression compose — the
    carry simply holds both extras.
    """
    trainer = make_local_trainer(loss_fn, fcfg)
    max_steps = _max_local_steps(fcfg, capacity)
    sch = _sched_cfg(scfg, fcfg)
    do_eval = jnp.asarray(_eval_mask(fcfg.num_rounds, eval_every))
    n_cap = fcfg.dispatch_cap
    if n_cap is not None and n_cap < 1:
        raise ValueError(f"dispatch_cap must be >= 1, got {n_cap}")
    cdt = _carry_dtype(fcfg)
    stream = fcfg.stream
    if stream is not None:
        process, size_cap, measure_col = _stream_setup(fcfg, capacity)
    comp = fcfg.compression
    if comp is not None:
        codec = _comp_setup(fcfg)
    flt = faults.active(fcfg.faults)
    exp_mult = faults.expected_time_mult(flt) if flt is not None else 1.0
    tel = telemetry_lib.active(fcfg.telemetry)
    sig_fn = _make_sig_fn(loss_fn, fcfg, capacity) \
        if (tel is not None and tel.signals) else None

    def sim(params: Params, images: Array, labels: Array, mask: Array,
            sizes: Array, hists: Array, test_x: Array, test_labels: Array,
            net: wireless.NetworkState, key: Array
            ) -> Tuple[Params, RoundMetrics]:
        k_dev = sizes.shape[0]
        # Chronic per-device drop rates (DESIGN.md §10): drawn once per
        # scenario off the *pristine* scenario key (folded, before the
        # streaming init split, so every other stream is untouched) and
        # held fixed across rounds.  None unless chronic_spread > 0 —
        # the i.i.d. fault path stays bitwise identical.
        drop_rates = faults.chronic_rates(
            jax.random.fold_in(key, 0xC407), k_dev, flt) \
            if flt is not None else None
        if stream is not None:
            key, k_init = jax.random.split(key)
            state0 = _diet_stream_state(
                process.init(k_init, hists, stream), cdt)
        if comp is not None:
            residual0 = jnp.zeros((k_dev, flat_param_size(params)),
                                  cdt or jnp.float32)

        def body(carry, do_ev):
            params, ages, key = carry[:3]
            pos = 3
            if stream is not None:
                st = carry[pos]
                pos += 1
            if comp is not None:
                residual = carry[pos]
                pos += 1
            if flt is not None:
                rel = carry[pos]
                pos += 1
            if sig_fn is not None:
                sigst = carry[pos]
            # One extra split for streaming, appended at the end; the
            # fault stream is *folded* off the carried key instead of
            # widening the split, because ``split(key, n)`` re-keys every
            # output when ``n`` changes — folding keeps every other
            # stream bitwise identical, so an inert FaultConfig (all
            # probabilities zero) reproduces ``faults=None`` exactly
            # (``tests/test_faults.py``).
            n_keys = 4 + (stream is not None)
            subkeys = jax.random.split(key, n_keys)
            key, k_fade, k_sched, k_train = subkeys[:4]
            if stream is not None:
                k_arr = subkeys[4]
            if flt is not None:
                k_fault = jax.random.fold_in(key, 0xFA17)
            if stream is None:
                index = diversity.diversity_index(
                    label_hists=hists, data_sizes=sizes, ages=ages,
                    weights=fcfg.index_weights, measure=fcfg.measure)
                sizes_r, stale = sizes, None
            else:
                index, sizes_r, stale, hists_r, st = _stream_round(
                    process, fcfg, size_cap, measure_col, k_arr, st, ages)
            gains = wireless.sample_fading(k_fade, net)
            payload = codec.payload_bits(comp, wcfg, gains, index) \
                if comp is not None else None
            # Scheduling prices retry-inflated bits (expected airtime
            # multiplier, a static constant) so Sub2's deadline reserves
            # the retransmission window before it happens.
            payload_sched = bandwidth.effective_payload_bits(
                payload, exp_mult, wcfg, gains) if flt is not None \
                else payload
            with telemetry_lib.phase_scope("schedule"):
                result = scheduler.schedule_impl(
                    k_sched, index, ages, sizes_r, gains, net, wcfg, sch,
                    staleness=stale, payload_bits=payload_sched,
                    reliability=rel if flt is not None else None)
            selected = result.selected
            admitted = selected
            # Dense-block dispatch (DESIGN.md §11): the plan runs right
            # after scheduling so faults, training, ages, reliability
            # and metrics all see the *realized* (post-drop) selection.
            if n_cap is None:
                didx = None
                n_dropped = jnp.zeros((), jnp.int32)
            else:
                didx, selected, n_dropped = dispatch_plan(selected, n_cap)
            if flt is None:
                ok = selected
                draw = None
                if n_cap is None:
                    energy = result.energy
                    round_time = result.round_time
                else:
                    energy, round_time = _dispatch_accounting(result,
                                                              selected)
            else:
                draw = faults.sample_faults(k_fault, gains, net, flt,
                                            drop_rates)
                ok, energy, round_time = faults.apply_faults(
                    draw, selected, result.alpha, result.t_train, gains,
                    net, wcfg, payload, flt)
            if comp is None:
                if flt is None:
                    out = _train_round(trainer, max_steps, fcfg, params,
                                       images, labels, mask, sizes_r,
                                       selected, k_train,
                                       dispatch_idx=didx, sig_fn=sig_fn)
                else:
                    out = _train_round_faulty(
                        trainer, max_steps, fcfg, params, images, labels,
                        mask, sizes_r, selected, ok, k_train,
                        dispatch_idx=didx, sig_fn=sig_fn)
                if sig_fn is not None:
                    params, obs = out
                else:
                    params = out
            else:
                out = _train_round_compressed(
                    trainer, max_steps, fcfg, codec, params, images,
                    labels, mask, sizes_r, selected, k_train, residual,
                    gains, index,
                    success=draw.success if flt is not None else None,
                    dispatch_idx=didx, sig_fn=sig_fn)
                if sig_fn is not None:
                    params, residual, obs = out
                else:
                    params, residual = out
            # Learning-signal carry (DESIGN.md §14): fold this round's
            # delivered observations in *before* the frame is built, so
            # the frame snapshots the exact post-round state a
            # learning-signal scheduler would rank on next round.
            if sig_fn is not None:
                loss_delta, upd_norm = obs
                sigst = telemetry_health.signal_update(
                    sigst, ok, loss_delta, upd_norm, energy)
            # Telemetry frame (DESIGN.md §13): built *before* the
            # ages/reliability carry updates so the trace records the
            # signals the scheduler actually saw.  Pure observer — no
            # PRNG draws, nothing feeds back — and statically absent
            # with telemetry=None (the bitwise contract).
            if tel is not None:
                frame = telemetry_record.round_frame(
                    tel, result=result, admitted=admitted,
                    sel_eff=selected, ok=ok, energy=energy,
                    payload_bits=payload, gains=gains, net=net,
                    wcfg=wcfg, sch=sch, key_sched=k_sched, index=index,
                    ages=ages, staleness=stale,
                    reliability=rel if flt is not None else None,
                    draw=draw,
                    signals=telemetry_health.signals_frame(
                        sigst, ok, loss_delta, upd_norm)
                    if sig_fn is not None else None)
            # Participation = delivered: ages reset and streaming
            # backlog clears only for uploads that landed.
            ages = jnp.where(ok > 0.0, 0, ages + 1)
            if flt is not None:
                rel = faults.reliability_update(rel, selected, ok, flt)
            acc = jax.lax.cond(
                do_ev,
                lambda p: jnp.asarray(eval_fn(p, test_x, test_labels),
                                      jnp.float32),
                lambda p: jnp.full((), jnp.nan, jnp.float32),
                params)
            met = RoundMetrics(
                accuracy=acc,
                n_selected=jnp.sum(selected).astype(jnp.int32),
                round_time=round_time,
                energy=energy,
                energy_total=jnp.sum(energy),
                selected=selected,
                iterations=result.iterations,
                n_success=jnp.sum(ok).astype(jnp.int32),
                n_dropped=n_dropped,
            )
            out = (params, ages, key)
            if stream is not None:
                out += (_stream_advance(st, hists_r, stale, ok, cdt),)
            if comp is not None:
                out += (residual,)
            if flt is not None:
                out += (rel,)
            if sig_fn is not None:
                out += (sigst,)
            if tel is not None:
                return out, (met, frame)
            return out, met

        ages0 = jnp.zeros((k_dev,), jnp.int32)
        carry0 = (params, ages0, key)
        if stream is not None:
            carry0 += (state0,)
        if comp is not None:
            carry0 += (residual0,)
        if flt is not None:
            carry0 += (jnp.ones((k_dev,), jnp.float32),)
        if sig_fn is not None:
            carry0 += (telemetry_health.signal_init(k_dev),)
        if tel is not None:
            out_carry, (metrics, frames) = jax.lax.scan(body, carry0,
                                                        do_eval)
            return out_carry[0], metrics, frames
        out_carry, metrics = jax.lax.scan(body, carry0, do_eval)
        return out_carry[0], metrics

    return sim


def make_feel_sim(*, loss_fn: Callable, eval_fn: Callable,
                  wcfg: wireless.WirelessConfig,
                  scfg: scheduler.SchedulerConfig, fcfg: FLConfig,
                  capacity: int, eval_every: int = 1,
                  donate_params: bool = False) -> Callable:
    """Jitted single-scenario simulation (see :func:`_make_sim`).

    ``donate_params=True`` donates the initial-params argument to the
    scan carry, letting XLA alias the global model's input buffer with
    the returned final params instead of holding both across the whole
    scan — at paper scale (CNN params x K client replicas inside the
    round body) that is the difference between 2x and 1x of the global
    model at peak.  The caller must not reuse the donated arrays after
    the call (pass a fresh copy per invocation in sweeps); CPU-backend
    JAX may decline the donation with a warning, which is harmless.
    """
    if fcfg.events is not None:
        sim = events_lib._make_event_sim(loss_fn, eval_fn, wcfg, scfg,
                                         fcfg, capacity, eval_every)
    else:
        sim = _make_sim(loss_fn, eval_fn, wcfg, scfg, fcfg, capacity,
                        eval_every)
    return jax.jit(sim, donate_argnums=(0,) if donate_params else ())


def scenario_keys(base_key: Array, start: int, count: int) -> Array:
    """Per-scenario PRNG keys from *global* scenario indices.

    ``key_i = fold_in(base_key, i)`` for ``i in [start, start + count)``:
    scenario ``i``'s stream depends only on ``(base_key, i)``, never on
    how a sweep is chunked or how many devices execute the chunk — the
    seed-derivation contract the sweep engine (``repro.sweep``) and the
    benchmark harness rely on (``tests/test_sweep.py``).  Contrast
    ``jax.random.split(key, S)``, whose streams change with ``S``.
    """
    idx = jnp.arange(start, start + count, dtype=jnp.uint32)
    return jax.vmap(lambda i: jax.random.fold_in(base_key, i))(idx)


def tile_params(params: Params, num_scenarios: int) -> Params:
    """Stack ``num_scenarios`` copies of ``params`` along a new axis 0.

    Produces the fresh ``(S, ...)`` buffers the donating batch driver
    consumes (see :func:`make_feel_sim_batch`): the caller's original
    params stay untouched, and the tiled copies are safe to hand over.
    """
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (num_scenarios,) + a.shape),
        params)


def make_feel_sim_batch(*, loss_fn: Callable, eval_fn: Callable,
                        wcfg: wireless.WirelessConfig,
                        scfg: scheduler.SchedulerConfig, fcfg: FLConfig,
                        capacity: int, eval_every: int = 1,
                        donate_params: bool = False,
                        mesh: Optional[jax.sharding.Mesh] = None,
                        scenario_axis: str = "scenario") -> Callable:
    """Jitted S-scenario simulation: vmap over (net, key) only.

    Dataset and initial params broadcast; each scenario sees its own
    network realization and PRNG stream — the paper's Monte-Carlo
    averaging (Figs. 2-6) as one SPMD program.

    ``donate_params=True`` changes the params contract: pass leaves with
    a leading ``(S,)`` axis (:func:`tile_params`) and they are donated
    into the vmapped scan carry.  A *broadcast* input cannot be donated
    — XLA declines aliasing a ``(P,)`` buffer against the stacked
    ``(S, P)`` carry/output and silently copies — whereas the pre-tiled
    buffer is exactly the carry's shape, so the donation is actually
    usable (asserted in ``tests/test_federated.py``).  The batched carry
    materializes either way; donating it avoids holding a second copy
    across the whole scan.

    ``mesh`` is the spec-in/spec-out entry (DESIGN.md §8): pass a mesh
    carrying ``scenario_axis`` and the vmapped sim is wrapped in
    ``shard_map`` with the scenario axis of ``nets``/``keys`` (and the
    tiled params, when donating) partitioned over it and everything else
    replicated — each device runs the same vmapped scan on its
    ``S / mesh.shape[scenario_axis]`` local scenarios, with no
    cross-device communication (scenarios are independent by
    construction).  The batched ``fused_pgd`` / ``stream_update`` kernel
    lanes and ``donate_params`` compose unchanged: both operate on the
    per-shard local batch.  ``S`` must be divisible by the mesh axis
    size (the sweep engine falls back to ``mesh=None`` otherwise).
    """
    if fcfg.events is not None:
        sim = events_lib._make_event_sim(loss_fn, eval_fn, wcfg, scfg,
                                         fcfg, capacity, eval_every)
    else:
        sim = _make_sim(loss_fn, eval_fn, wcfg, scfg, fcfg, capacity,
                        eval_every)
    vsim = jax.vmap(sim, in_axes=(0 if donate_params else None,
                                  None, None, None, None,
                                  None, None, None, 0, 0))
    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        sharded = jax.sharding.PartitionSpec(scenario_axis)
        rep = jax.sharding.PartitionSpec()
        # Telemetry adds a third (frames) output with the same leading
        # scenario axis as params/metrics — sharded identically.
        n_out = 3 if telemetry_lib.active(fcfg.telemetry) is not None \
            else 2
        vsim = shard_map(
            vsim, mesh=mesh,
            in_specs=(sharded if donate_params else rep,
                      rep, rep, rep, rep, rep, rep, rep,
                      sharded, sharded),
            out_specs=(sharded,) * n_out,
            check_rep=False)
    return jax.jit(vsim, donate_argnums=(0,) if donate_params else ())


# ---------------------------------------------------------------------------
# Host-side adapters: stacked metrics -> RoundRecord list
# ---------------------------------------------------------------------------

def metrics_to_records(metrics: RoundMetrics) -> List[RoundRecord]:
    """One device->host transfer for the whole run's records."""
    m = jax.device_get(metrics)
    history: List[RoundRecord] = []
    for r in range(m.selected.shape[0]):
        n_sel = int(m.n_selected[r])
        e_total = float(m.energy_total[r])
        history.append(RoundRecord(
            round=r, accuracy=float(m.accuracy[r]), n_selected=n_sel,
            round_time=float(m.round_time[r]),
            energy_total=e_total,
            energy_per_device=e_total / max(n_sel, 1),
            selected=np.asarray(m.selected[r]),
            n_success=int(m.n_success[r]),
            n_dropped=int(m.n_dropped[r]),
        ))
    return history


def batch_metrics_to_records(metrics: RoundMetrics
                             ) -> List[List[RoundRecord]]:
    """Per-scenario record lists from (S, R, ...) stacked metrics.

    One device->host transfer for the whole batch; scenario slicing
    happens on the host copies.
    """
    host = jax.device_get(metrics)
    num_scenarios = host.selected.shape[0]
    return [
        metrics_to_records(jax.tree_util.tree_map(lambda a, s=s: a[s],
                                                  host))
        for s in range(num_scenarios)
    ]


def client_histograms(data: partition_lib.ClientDataset,
                      num_classes: int) -> Array:
    """On-device statistics reported to the server (Alg. 1 line 5).

    Public because sweep harnesses (``benchmarks/fl_e2e.py``) need the
    same histograms to feed ``make_feel_sim(_batch)`` directly.
    """
    return jax.vmap(
        lambda lab, m: diversity.label_histogram(lab, m, num_classes)
    )(data.labels, data.mask)


# ---------------------------------------------------------------------------
# Full training drivers (Alg. 1)
# ---------------------------------------------------------------------------

def run_federated(
    *,
    init_params: Params,
    loss_fn: Callable,
    eval_fn: Callable[[Params, Array, Array], Array],
    data: partition_lib.ClientDataset,
    net: wireless.NetworkState,
    wcfg: wireless.WirelessConfig,
    scfg: scheduler.SchedulerConfig,
    fcfg: FLConfig,
    key: Array,
    eval_every: int = 1,
    donate_params: bool = False,
) -> tuple[Params, List[RoundRecord]]:
    """Run ``num_rounds`` of FEEL; returns final params + per-round records.

    Scan-over-rounds driver: the whole simulation compiles to one XLA
    program (no per-round dispatch or host syncs).  Bit-for-bit
    consistent with :func:`run_federated_loop` for the same key.
    ``donate_params=True`` hands ``init_params`` to the scan carry (the
    caller must not reuse those arrays afterwards — see
    :func:`make_feel_sim`).

    With ``fcfg.telemetry`` set (DESIGN.md §13) the return grows a
    third element: the stacked per-round telemetry frame dict from
    ``repro.telemetry.record`` — callers with telemetry off see the
    historical 2-tuple unchanged.
    """
    sim = make_feel_sim(loss_fn=loss_fn, eval_fn=eval_fn, wcfg=wcfg,
                        scfg=scfg, fcfg=fcfg, capacity=data.capacity,
                        eval_every=eval_every, donate_params=donate_params)
    hists = client_histograms(data, fcfg.num_classes)
    test_x = synthetic.to_float(data.test_images)
    out = sim(init_params, data.images, data.labels, data.mask,
              data.sizes, hists, test_x, data.test_labels, net, key)
    if len(out) == 3:
        params, metrics, frames = out
        return params, metrics_to_records(metrics), frames
    params, metrics = out
    return params, metrics_to_records(metrics)


def run_federated_batch(
    *,
    init_params: Params,
    loss_fn: Callable,
    eval_fn: Callable[[Params, Array, Array], Array],
    data: partition_lib.ClientDataset,
    nets: wireless.NetworkState,
    wcfg: wireless.WirelessConfig,
    scfg: scheduler.SchedulerConfig,
    fcfg: FLConfig,
    keys: Array,
    eval_every: int = 1,
    donate_params: bool = False,
) -> tuple[Params, RoundMetrics]:
    """Run S independent FEEL scenarios as one vmapped scan.

    Args:
      nets: stacked :class:`wireless.NetworkState` with leading ``(S,)``
        leaf axis (see :func:`wireless.sample_networks`).
      keys: ``(S,)`` PRNG keys, one stream per scenario.
      donate_params: donate the initial params into the vmapped scan
        carry.  The caller's ``init_params`` stay valid: fresh ``(S,
        ...)`` tiled buffers (:func:`tile_params`) are built here and
        those are donated (see :func:`make_feel_sim_batch`).

    Returns:
      (params, metrics): final params stacked ``(S, ...)`` per leaf and
      :class:`RoundMetrics` with leading ``(S, R, ...)`` axes.  Use
      :func:`batch_metrics_to_records` for per-scenario record lists.
      With ``fcfg.telemetry`` set a third element joins: the stacked
      frame dict with leading ``(S, R, ...)`` axes — scenario ``i`` of
      the batch is bitwise the single run's frames (batch == singles,
      ``tests/test_telemetry.py``).
    """
    sim = make_feel_sim_batch(loss_fn=loss_fn, eval_fn=eval_fn, wcfg=wcfg,
                              scfg=scfg, fcfg=fcfg, capacity=data.capacity,
                              eval_every=eval_every,
                              donate_params=donate_params)
    hists = client_histograms(data, fcfg.num_classes)
    test_x = synthetic.to_float(data.test_images)
    if donate_params:
        init_params = tile_params(init_params, keys.shape[0])
    return sim(init_params, data.images, data.labels, data.mask,
               data.sizes, hists, test_x, data.test_labels, nets, keys)


def run_federated_loop(
    *,
    init_params: Params,
    loss_fn: Callable,
    eval_fn: Callable[[Params, Array, Array], Array],
    data: partition_lib.ClientDataset,
    net: wireless.NetworkState,
    wcfg: wireless.WirelessConfig,
    scfg: scheduler.SchedulerConfig,
    fcfg: FLConfig,
    key: Array,
    eval_every: int = 1,
) -> tuple[Params, List[RoundRecord]]:
    """Legacy host-side per-round loop (reference implementation).

    Dispatches two jits and forces several host syncs per round; kept for
    the scan-parity tests and the ``fl_e2e`` old-vs-new benchmark.
    Honors ``fcfg.stream`` with the same per-round sequence (and key
    splits) as the scan driver, so streaming runs stay bit-for-bit
    comparable (``tests/test_streaming.py``).  With ``fcfg.telemetry``
    set the return grows a third element — the stacked per-round frame
    dict (host numpy), same field set as the scan driver's.
    """
    if fcfg.events is not None:
        raise ValueError(
            "FLConfig.events is set: the event-driven drivers have no "
            "legacy per-round loop (their reference is the synchronous-"
            "limit parity contract, tests/test_events.py) — use "
            "make_feel_sim / make_feel_sim_batch")
    k_dev = data.num_devices
    sig_fn = _make_sig_fn(loss_fn, fcfg, data.capacity) \
        if _sig_enabled(fcfg) else None
    round_fn = make_round_fn(loss_fn, fcfg, data.capacity, sig_fn=sig_fn)
    hists = client_histograms(data, fcfg.num_classes)
    n_cap = fcfg.dispatch_cap
    if n_cap is not None and n_cap < 1:
        raise ValueError(f"dispatch_cap must be >= 1, got {n_cap}")
    cdt = _carry_dtype(fcfg)
    flt = faults.active(fcfg.faults)
    # Chronic rates off the pristine scenario key, before the streaming
    # init split — same derivation as the scan driver (parity contract).
    drop_rates = faults.chronic_rates(
        jax.random.fold_in(key, 0xC407), k_dev, flt) \
        if flt is not None else None
    stream = fcfg.stream
    if stream is not None:
        process, size_cap, measure_col = _stream_setup(fcfg, data.capacity)
        key, k_init = jax.random.split(key)
        st = _diet_stream_state(process.init(k_init, hists, stream), cdt)
    comp = fcfg.compression
    if comp is not None:
        codec = _comp_setup(fcfg)
        residual = jnp.zeros((k_dev, flat_param_size(init_params)),
                             cdt or jnp.float32)
    exp_mult = faults.expected_time_mult(flt) if flt is not None else 1.0
    rel = jnp.ones((k_dev,), jnp.float32) if flt is not None else None
    sch = _sched_cfg(scfg, fcfg)
    tel = telemetry_lib.active(fcfg.telemetry)
    frames_host: List[dict] = []
    if tel is not None:
        # Jitted (not eager) on purpose, like ``faults.fault_step`` and
        # ``_dispatch_plan_jit``: the scan driver compiles the frame
        # fused, and op-at-a-time eager arithmetic is the one way the
        # loop's recorded floats could drift off the scan's.
        @jax.jit
        def _frame_fn(result, admitted, sel_eff, ok, energy, payload,
                      gains, net_, k_sched, index, ages_, stale, rel_,
                      draw, sigst, loss_delta, upd_norm):
            return telemetry_record.round_frame(
                tel, result=result, admitted=admitted, sel_eff=sel_eff,
                ok=ok, energy=energy, payload_bits=payload, gains=gains,
                net=net_, wcfg=wcfg, sch=sch, key_sched=k_sched,
                index=index, ages=ages_, staleness=stale,
                reliability=rel_, draw=draw,
                signals=telemetry_health.signals_frame(
                    sigst, ok, loss_delta, upd_norm)
                if sigst is not None else None)

    ages = jnp.zeros((k_dev,), jnp.int32)
    params = init_params
    sigst = telemetry_health.signal_init(k_dev) \
        if sig_fn is not None else None
    history: List[RoundRecord] = []
    test_x = synthetic.to_float(data.test_images)

    for r in range(fcfg.num_rounds):
        # Same split counts and order as the scan body (parity contract):
        # base 4, +1 streaming arrivals; the fault draw folds off the
        # carried key (never widens the split — inert-config identity).
        n_keys = 4 + (stream is not None)
        subkeys = jax.random.split(key, n_keys)
        key, k_fade, k_sched, k_train = subkeys[:4]
        if flt is not None:
            k_fault = jax.random.fold_in(key, 0xFA17)
        if stream is None:
            index = diversity.diversity_index(
                label_hists=hists, data_sizes=data.sizes, ages=ages,
                weights=fcfg.index_weights, measure=fcfg.measure)
            sizes_r, stale = data.sizes, None
        else:
            index, sizes_r, stale, hists_r, st = _stream_round(
                process, fcfg, size_cap, measure_col, subkeys[4], st, ages)
        gains = wireless.sample_fading(k_fade, net)
        payload = codec.payload_bits(comp, wcfg, gains, index) \
            if comp is not None else None
        payload_sched = bandwidth.effective_payload_bits(
            payload, exp_mult, wcfg, gains) if flt is not None else payload
        result = scheduler.schedule(k_sched, index, ages, sizes_r,
                                    gains, net, wcfg, sch, stale,
                                    payload_sched, rel)
        selected = result.selected
        admitted = selected
        # Same dispatch plan + re-pricing as the scan body, through the
        # jitted entries (parity: fused == loop bitwise).
        if n_cap is None:
            didx = None
            n_dropped = jnp.zeros((), jnp.int32)
        else:
            didx, selected, n_dropped = _dispatch_plan_jit(selected, n_cap)
        if flt is None:
            ok = selected
            draw = None
            if n_cap is None:
                energy = result.energy
                round_time = result.round_time
            else:
                energy, round_time = _dispatch_accounting_jit(result,
                                                              selected)
        else:
            # Jitted (not eager) on purpose: the scan driver compiles
            # the same arithmetic fused, and CPU XLA's FMA contraction
            # rounds differently from the op-at-a-time eager schedule.
            draw, ok, energy, round_time = faults.fault_step(
                k_fault, selected, result.alpha, result.t_train, gains,
                net, wcfg, payload, flt, drop_rates)
        if comp is None:
            if flt is None:
                out = round_fn(params, data.images, data.labels,
                               data.mask, sizes_r, selected, k_train,
                               dispatch_idx=didx)
            else:
                out = round_fn(params, data.images, data.labels,
                               data.mask, sizes_r, selected, ok,
                               k_train, dispatch_idx=didx)
            if sig_fn is not None:
                params, obs = out
            else:
                params = out
        else:
            out = round_fn(
                params, data.images, data.labels, data.mask, sizes_r,
                selected, k_train, residual, gains, index,
                success=draw.success if flt is not None else None,
                dispatch_idx=didx)
            if sig_fn is not None:
                params, residual, obs = out
            else:
                params, residual = out
        # Signal carry folds in before the frame, same as the scan.
        loss_delta = upd_norm = None
        if sig_fn is not None:
            loss_delta, upd_norm = obs
            sigst = _signal_update_jit(sigst, ok, loss_delta, upd_norm,
                                       energy)
        # Frame before the ages/reliability updates — the trace records
        # the signals the scheduler saw (same placement as the scan).
        if tel is not None:
            frames_host.append(jax.device_get(_frame_fn(
                result, admitted, selected, ok, energy, payload, gains,
                net, k_sched, index, ages, stale, rel, draw,
                sigst, loss_delta, upd_norm)))
        ages = jnp.where(ok > 0.0, 0, ages + 1)
        if flt is not None:
            rel = faults.reliability_update(rel, selected, ok, flt)
        if stream is not None:
            st = _stream_advance(st, hists_r, stale, ok, cdt)

        if (r % eval_every) == 0 or r == fcfg.num_rounds - 1:
            acc = float(eval_fn(params, test_x, data.test_labels))
        else:
            acc = float("nan")
        n_sel = int(jnp.sum(selected))
        e_total = float(jnp.sum(energy))
        history.append(RoundRecord(
            round=r, accuracy=acc, n_selected=n_sel,
            round_time=float(round_time),
            energy_total=e_total,
            energy_per_device=e_total / max(n_sel, 1),
            selected=np.asarray(selected),
            n_success=int(jnp.sum(ok)),
            n_dropped=int(n_dropped),
        ))
    if tel is not None:
        frames = {name: np.stack([f[name] for f in frames_host])
                  for name in (frames_host[0] if frames_host else ())}
        return params, history, frames
    return params, history
