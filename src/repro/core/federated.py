"""FEEL orchestration — the paper's Algorithm 1 (FedAvg + scheduling).

Each round:

1. Devices report (transmit power, |D_k|, diversity index) — here the
   index is computed from on-device label histograms
   (``core.diversity.diversity_index``), sizes and ages.
2. Fresh channel fading is drawn; the scheduler (``core.scheduler``)
   returns the selected set and bandwidth allocation.
3. Selected devices run ``E`` local epochs of SGD from the global model
   (vmapped over the *entire* client axis, masked by selection — static
   shapes, one jit).
4. The server aggregates with FedAvg weights ``|D_k| / D_r`` (Alg. 1
   line 12) — optionally through the ``fedavg_agg`` Pallas kernel path.
5. Ages update (selected -> 0, others += 1); energy/time accumulate.

The client axis is shardable: on a pod, ``client_batch_spec`` places
clients over the ``data`` mesh axis so K local trainings run as one SPMD
program — the cross-silo mapping described in DESIGN.md §3.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import diversity, scheduler, wireless
from repro.data import partition as partition_lib
from repro.data import synthetic

Array = jax.Array
Params = Any


@dataclasses.dataclass(frozen=True)
class FLConfig:
    num_rounds: int = 15                  # paper: 15 rounds
    local_epochs: int = 1                 # E
    batch_size: int = 50                  # one shard per step
    learning_rate: float = 0.05
    momentum: float = 0.0
    num_classes: int = 10
    measure: str = "gini_simpson"
    index_weights: diversity.IndexWeights = diversity.IndexWeights()
    use_kernel_agg: bool = False          # route FedAvg through Pallas


@dataclasses.dataclass
class RoundRecord:
    round: int
    accuracy: float
    n_selected: int
    round_time: float
    energy_total: float
    energy_per_device: float
    selected: np.ndarray


# ---------------------------------------------------------------------------
# Local training (vmapped over clients)
# ---------------------------------------------------------------------------

def make_local_trainer(loss_fn: Callable[[Params, Array, Array, Array],
                                         Array],
                       cfg: FLConfig) -> Callable:
    """Build the vmapped multi-epoch local-SGD update.

    Every client runs ``steps_k = E * ceil(size_k / B)`` gradient steps;
    clients are padded to the max step count and masked, so one
    ``lax.scan`` covers the heterogeneous dataset sizes (the wireless time
    model separately charges each device for its true workload, Eq. 8).
    """

    def local_sgd(params: Params, images: Array, labels: Array,
                  mask: Array, steps_active: Array, key: Array) -> Params:
        cap = images.shape[0]
        max_steps = steps_active.shape[0]
        del max_steps

        def step(carry, inp):
            p, vel = carry
            k, active = inp
            idx = jax.random.randint(k, (cfg.batch_size,), 0, cap)
            bx = synthetic.to_float(images[idx])
            by = labels[idx]
            bm = mask[idx]
            g = jax.grad(loss_fn)(p, bx, by, bm)
            vel = jax.tree_util.tree_map(
                lambda v, gi: cfg.momentum * v + gi, vel, g)
            p_new = jax.tree_util.tree_map(
                lambda w, v: w - cfg.learning_rate * v, p, vel)
            p = jax.tree_util.tree_map(
                lambda new, old: jnp.where(active > 0.0, new, old),
                p_new, p)
            return (p, vel), None

        vel0 = jax.tree_util.tree_map(jnp.zeros_like, params)
        keys = jax.random.split(key, steps_active.shape[0])
        (params, _), _ = jax.lax.scan(step, (params, vel0),
                                      (keys, steps_active))
        return params

    return jax.vmap(local_sgd, in_axes=(None, 0, 0, 0, 0, 0))


def fedavg_aggregate(client_params: Params, weights: Array,
                     use_kernel: bool = False) -> Params:
    """g <- sum_k (D_k / D_r) w_k (Alg. 1 line 12) over stacked params.

    ``weights`` must already be normalized over the selected set (zeros
    for unselected clients).
    """
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        return jax.tree_util.tree_map(
            lambda stacked: kernel_ops.fedavg_agg(
                stacked.reshape(stacked.shape[0], -1), weights
            ).reshape(stacked.shape[1:]),
            client_params)
    return jax.tree_util.tree_map(
        lambda stacked: jnp.tensordot(weights, stacked, axes=1),
        client_params)


# ---------------------------------------------------------------------------
# One federated round (jit)
# ---------------------------------------------------------------------------

def make_round_fn(loss_fn: Callable, cfg: FLConfig,
                  capacity: int) -> Callable:
    """Returns jit'd ``round_fn(params, data, selected, weights, key)``.

    ``selected``/``weights`` come from the scheduler (host side); the round
    body — local training for all K clients, masked FedAvg — is one SPMD
    program.
    """
    trainer = make_local_trainer(loss_fn, cfg)
    steps_per_epoch = max(1, -(-capacity // cfg.batch_size))
    max_steps = cfg.local_epochs * steps_per_epoch

    @jax.jit
    def round_fn(params: Params, images: Array, labels: Array, mask: Array,
                 sizes: Array, selected: Array, key: Array) -> Params:
        k = images.shape[0]
        # Per-client active step schedule: E * ceil(size_k / B) steps.
        steps_k = cfg.local_epochs * jnp.ceil(
            sizes.astype(jnp.float32) / cfg.batch_size)
        step_idx = jnp.arange(max_steps, dtype=jnp.float32)[None, :]
        active = (step_idx < steps_k[:, None]).astype(jnp.float32)
        active = active * selected[:, None]             # frozen if unselected
        keys = jax.random.split(key, k)
        client_params = trainer(params, images, labels, mask, active, keys)
        # FedAvg weights D_k / D_r over the selected set.
        w = sizes.astype(jnp.float32) * selected
        w = w / jnp.maximum(jnp.sum(w), 1.0)
        return fedavg_aggregate(client_params, w, cfg.use_kernel_agg)

    return round_fn


# ---------------------------------------------------------------------------
# Full training driver (Alg. 1)
# ---------------------------------------------------------------------------

def run_federated(
    *,
    init_params: Params,
    loss_fn: Callable,
    eval_fn: Callable[[Params, Array, Array], Array],
    data: partition_lib.ClientDataset,
    net: wireless.NetworkState,
    wcfg: wireless.WirelessConfig,
    scfg: scheduler.SchedulerConfig,
    fcfg: FLConfig,
    key: Array,
    eval_every: int = 1,
) -> tuple[Params, List[RoundRecord]]:
    """Run ``num_rounds`` of FEEL; returns final params + per-round records."""
    k_dev = data.num_devices
    round_fn = make_round_fn(loss_fn, fcfg, data.capacity)

    # On-device statistics reported to the server (Alg. 1 line 5).
    hists = jax.vmap(
        lambda lab, m: diversity.label_histogram(lab, m, fcfg.num_classes)
    )(data.labels, data.mask)

    ages = jnp.zeros((k_dev,), jnp.int32)
    params = init_params
    history: List[RoundRecord] = []
    test_x = synthetic.to_float(data.test_images)

    for r in range(fcfg.num_rounds):
        key, k_fade, k_sched, k_train = jax.random.split(key, 4)
        index = diversity.diversity_index(
            label_hists=hists, data_sizes=data.sizes, ages=ages,
            weights=fcfg.index_weights, measure=fcfg.measure)
        gains = wireless.sample_fading(k_fade, net)
        sch = dataclasses.replace(scfg, local_epochs=fcfg.local_epochs)
        result = scheduler.schedule(k_sched, index, ages, data.sizes,
                                    gains, net, wcfg, sch)
        selected = result.selected
        params = round_fn(params, data.images, data.labels, data.mask,
                          data.sizes, selected, k_train)
        ages = jnp.where(selected > 0.0, 0, ages + 1)

        if (r % eval_every) == 0 or r == fcfg.num_rounds - 1:
            acc = float(eval_fn(params, test_x, data.test_labels))
        else:
            acc = float("nan")
        n_sel = int(jnp.sum(selected))
        e_total = float(jnp.sum(result.energy))
        history.append(RoundRecord(
            round=r, accuracy=acc, n_selected=n_sel,
            round_time=float(result.round_time),
            energy_total=e_total,
            energy_per_device=e_total / max(n_sel, 1),
            selected=np.asarray(selected),
        ))
    return params, history
