"""Dataset-diversity measures and the paper's diversity index (§III, §IV-B).

The paper's selection criterion is a weighted, normalized combination of
per-device dataset metrics (Eq. 4)::

    I_k = sum_i  v_{i,k} * gamma_i ,   v_{i,k} = metric_i(k) / max_k metric_i

with ``i in {dataset diversity, dataset size, age}``.  For classification
the dataset-diversity term uses the Gini-Simpson index ``1 - sum_c p_c^2``
(Eq. 2) or Shannon entropy (Eq. 3); for sequence data ApEn/SampEn.

All measures operate on *label statistics only* (a histogram) or on a small
data sample, matching the paper's privacy argument: devices upload a single
scalar, never raw data.

The fused histogram->index computation also exists as a Pallas TPU kernel
(``repro.kernels.diversity``); this module is the reference/jnp path used
everywhere shapes are small (K ~ 100 devices).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Classification diversity (Eq. 2 / Eq. 3)
# ---------------------------------------------------------------------------

def label_histogram(labels: Array, mask: Array, num_classes: int) -> Array:
    """Class-count histogram over a (possibly padded) label vector.

    Args:
      labels: (n,) int labels; entries with mask==0 are ignored.
      mask:   (n,) {0,1} validity mask (devices have unequal |D_k|).
      num_classes: C.

    Returns: (C,) float counts.
    """
    one_hot = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)
    return jnp.sum(one_hot * mask[..., None].astype(jnp.float32), axis=-2)


def class_probs(hist: Array) -> Array:
    total = jnp.sum(hist, axis=-1, keepdims=True)
    return hist / jnp.maximum(total, 1.0)


def simpson_index(probs: Array) -> Array:
    """lambda = sum_c p_c^2 (Eq. 2): P(two random samples share a class)."""
    return jnp.sum(probs * probs, axis=-1)


def gini_simpson(probs: Array) -> Array:
    """1 - lambda (paper's choice for MNIST): in [0, 1 - 1/C]."""
    return 1.0 - simpson_index(probs)


def shannon_entropy(probs: Array) -> Array:
    """H = -sum p log2 p (Eq. 3), with 0*log(0) := 0 (paper's caveat)."""
    logp = jnp.where(probs > 0.0, jnp.log2(jnp.maximum(probs, 1e-30)), 0.0)
    return -jnp.sum(probs * logp, axis=-1)


# ---------------------------------------------------------------------------
# Sequence diversity: approximate / sample entropy (§III)
# ---------------------------------------------------------------------------

def _phi_counts(series: Array, m: int, r: Array) -> Array:
    """Fraction of template pairs (length m) within Chebyshev distance r.

    Vectorized O(n^2) formulation; the paper notes ApEn/SampEn are heavy and
    should run on a small sample — callers pass n <= a few hundred.
    Returns (n-m+1,) per-template match fractions (self-match included).
    """
    n = series.shape[0]
    num_templates = n - m + 1
    idx = jnp.arange(num_templates)[:, None] + jnp.arange(m)[None, :]
    templates = series[idx]                                   # (nt, m)
    dist = jnp.max(
        jnp.abs(templates[:, None, :] - templates[None, :, :]), axis=-1)
    matches = (dist <= r).astype(jnp.float32)                 # (nt, nt)
    return jnp.mean(matches, axis=-1)


def approximate_entropy(series: Array, m: int = 2,
                        r_factor: float = 0.2) -> Array:
    """ApEn(m, r) = Phi^m(r) - Phi^{m+1}(r) (Pincus); r = r_factor * std."""
    r = r_factor * jnp.std(series)
    phi_m = jnp.mean(jnp.log(jnp.maximum(_phi_counts(series, m, r), 1e-12)))
    phi_m1 = jnp.mean(
        jnp.log(jnp.maximum(_phi_counts(series, m + 1, r), 1e-12)))
    return phi_m - phi_m1


def sample_entropy(series: Array, m: int = 2, r_factor: float = 0.2) -> Array:
    """SampEn(m, r) = -log(A/B), self-matches excluded (length-robust)."""
    r = r_factor * jnp.std(series)

    def pair_count(mm: int) -> Array:
        n = series.shape[0]
        nt = n - mm + 1
        idx = jnp.arange(nt)[:, None] + jnp.arange(mm)[None, :]
        t = series[idx]
        dist = jnp.max(jnp.abs(t[:, None, :] - t[None, :, :]), axis=-1)
        match = (dist <= r).astype(jnp.float32)
        match = match * (1.0 - jnp.eye(nt))  # exclude self-matches
        return jnp.sum(match)

    b = pair_count(m)
    a = pair_count(m + 1)
    return -jnp.log(jnp.maximum(a, 1e-12) / jnp.maximum(b, 1e-12))


# ---------------------------------------------------------------------------
# The diversity index I_k (Eq. 4)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IndexWeights:
    """gamma_i weights; the paper's experiments use 1/3 each."""

    diversity: float = 1.0 / 3.0
    size: float = 1.0 / 3.0
    age: float = 1.0 / 3.0


def normalize_metric(values: Array) -> Array:
    """v_i = value / max_k value (Eq. just above Eq. 4); 0 if all zero.

    The max runs over the trailing (device) axis only, so explicitly
    batched ``(S, K)`` inputs normalize per scenario — matching what a
    ``vmap`` over the scenario axis produces lane-by-lane.
    """
    m = jnp.max(values, axis=-1, keepdims=True)
    return jnp.where(m > 0.0, values / jnp.maximum(m, 1e-12), 0.0)


def age_priority(ages: Array) -> Array:
    """Age-of-update term f(k) = log(1 + T(k)) (Yang et al. form, §VI)."""
    return jnp.log1p(ages.astype(jnp.float32))


def diversity_index_from_stats(
    *,
    div: Array,
    data_sizes: Array,
    ages: Array,
    weights: IndexWeights = IndexWeights(),
) -> Array:
    """Incremental form of :func:`diversity_index` (Eq. 4).

    Consumes an *already-computed* per-device diversity measure instead of
    raw label histograms — the streaming subsystem's round path
    (``core.streaming``), where ``ops.stream_update`` refreshes the
    class-count matrix and emits ``(gini, shannon, size)`` in one fused
    pass, feeds those stats straight in here without re-touching the
    ``(K, C)`` counts.

    Args:
      div:        (K,) diversity measure values (Gini-Simpson or Shannon).
      data_sizes: (K,) |D_k| sample counts (float counts are fine).
      ages:       (K,) rounds since last selection.
      weights:    gamma_i.

    Returns: (K,) index values in [0, sum_i gamma_i].
    """
    terms: Mapping[str, Array] = {
        "diversity": normalize_metric(div) * weights.diversity,
        "size": normalize_metric(data_sizes.astype(jnp.float32))
                * weights.size,
        "age": normalize_metric(age_priority(ages)) * weights.age,
    }
    return terms["diversity"] + terms["size"] + terms["age"]


def diversity_measure(label_hists: Array, measure: str) -> Array:
    """(…, C) histograms -> (…,) diversity values for the named measure."""
    probs = class_probs(label_hists)
    if measure == "gini_simpson":
        return gini_simpson(probs)
    if measure == "shannon":
        return shannon_entropy(probs)
    raise ValueError(f"unknown diversity measure: {measure!r}")


def diversity_index(
    *,
    label_hists: Array,
    data_sizes: Array,
    ages: Array,
    weights: IndexWeights = IndexWeights(),
    measure: str = "gini_simpson",
) -> Array:
    """Compute I_k for every device (Eq. 4).

    Args:
      label_hists: (K, C) per-device class histograms (computed on-device).
      data_sizes:  (K,)   |D_k| sample counts.
      ages:        (K,)   rounds since last selection.
      weights:     gamma_i.
      measure:     'gini_simpson' | 'shannon'.

    Returns: (K,) index values in [0, sum_i gamma_i].

    Batched path: every op reduces over trailing axes only, so stacking a
    scenario axis in front of each argument — ``(S, K, C)`` / ``(S, K)``
    — yields per-scenario indices ``(S, K)`` without a vmap.
    """
    div = diversity_measure(label_hists, measure)
    return diversity_index_from_stats(div=div, data_sizes=data_sizes,
                                      ages=ages, weights=weights)
