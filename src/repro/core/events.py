"""Event-driven asynchronous FEEL (DESIGN.md §12).

The synchronous drivers (``core.federated``) advance the world one
*round* at a time: every admitted device trains, uploads, and the
server aggregates before anything else happens.  Real edge fleets are
not synchronous — devices come and go (charging, diurnal usage,
connectivity churn), uploads land whenever compute + channel time
elapses, and an asynchronous server applies updates as they arrive.
This module reframes the simulation as a jitted ``lax.scan`` over
*events* (scheduling ticks):

1. **Availability** — a per-device availability process gates which
   devices the scheduler may admit this tick: ``always`` (the
   synchronous limit), ``churn`` (i.i.d. Bernoulli presence), and
   ``diurnal`` (a correlated day/night activity wave whose shared phase
   and per-device jitter are drawn once per scenario off the scenario
   seed).  Processes register by name (:func:`register_availability`),
   mirroring the arrival-process and allocator registries.
2. **Dispatch** — free (available, not in-flight) devices are ranked
   and admitted by the *same* scheduling stack as the synchronous
   drivers (``scheduler.schedule_impl`` with staleness / payload /
   reliability signals), composed with the dense-block dispatch cap,
   the fault subsystem's retransmission pricing, and the compressed
   uplink's per-device payload bits.  Admitted devices train
   immediately on the current global model; their (flattened) updates
   enter a per-device pending slot with an *arrival time* of
   ``now + t_train + t_up`` (retry-stretched under faults) and a
   *birth version* (the global model version they trained from).
3. **Buffered aggregation** — uploads whose arrival time has elapsed
   join the server buffer; once the buffer holds ``buffer_size``
   updates the server flushes: a staleness-weighted FedAvg in update
   form, ``g' = g + sum_k w_k s(tau_k) (w^k - g)``, where
   ``s(tau) = (1 + tau)^-gamma`` discounts an update by how many model
   versions elapsed since its dispatch (the FedBuff rule, Nguyen et
   al.; 2305.01238's async-vs-sync probe).  ``gamma`` is
   ``EventConfig.staleness_decay`` — the update-weighting
   generalization of the scheduler's ``staleness_boost`` *priority*
   machinery.  The flush optionally runs through the Pallas
   ``fedavg_agg_stale`` kernel lane (``use_kernel_agg``).

**Synchronous-limit parity contract** (``tests/test_events.py``): with
every device always available (``availability="always"``), whole-cohort
ticks (``tick_horizon=0``), zero staleness decay, and a ``buffer_size``
no larger than the per-tick cohort, every dispatched update arrives and
flushes within its own tick with staleness 0 — and the event scan
reproduces the synchronous scan driver **bitwise**.  The contract is
stated against the update-form aggregation path (the fault-aware
synchronous round and the compressed round both use it); the key
discipline is copied from ``federated._make_sim`` exactly — the same
``split`` widths, the same ``fold_in`` salts for the fault and chronic
streams, and a *folded* (never split) availability stream — so every
PRNG draw matches the synchronous trajectory draw for draw.

The event drivers hang off ``FLConfig.events``: ``federated.
make_feel_sim`` / ``make_feel_sim_batch`` delegate here when the field
is set, so the sweep engine, the batch driver's vmap/shard_map lanes,
buffer donation, and the ``async`` sweep axis all compose without any
caller change.  ``batch == S singles`` holds bitwise like every other
subsystem (the availability draws are keyed off the per-scenario
stream).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Protocol, Tuple, \
    runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bandwidth, compression, diversity, faults, \
    scheduler, streaming, wireless
from repro import telemetry as telemetry_lib
from repro.telemetry import health as telemetry_health
from repro.telemetry import record as telemetry_record

Array = jax.Array
Params = Any

# fold_in salts for the event-only PRNG streams: folded off the carried
# (availability) / pristine scenario (phases) key, never a widened
# split, so the synchronous drivers' streams stay bitwise untouched.
_AVAIL_SALT = 0xA7A1
_PHASE_SALT = 0xD1A7


@dataclasses.dataclass(frozen=True)
class EventConfig:
    """Static event-scan knobs (hashable; rides on ``FLConfig.events``).

    ``tick_horizon`` is the wall-clock length of one scheduling tick in
    seconds: ``0.0`` (default) means whole-cohort ticks — the clock
    advances by the dispatched cohort's makespan, so every upload lands
    within its own tick (the synchronous limit).  A positive horizon
    caps the tick length instead: slow devices stay in flight across
    ticks, arrive late, and their updates carry genuine model-version
    staleness into the buffered flush.

    ``num_events`` is the scan length (``None`` = ``fcfg.num_rounds``);
    under a short horizon one synchronous round's work spreads over
    several events, so async sweeps typically raise it.
    """

    availability: str = "always"   # availability-process registry name
    avail_prob: float = 0.9        # churn: per-tick presence probability
    period: float = 24.0           # diurnal: ticks per activity cycle
    phase_spread: float = 0.5      # diurnal: per-device phase jitter (rad)
    duty: float = 0.5              # diurnal: mean availability fraction
    buffer_size: int = 1           # arrived updates needed to flush
    staleness_decay: float = 0.0   # gamma of the (1+tau)^-gamma weight
    tick_horizon: float = 0.0      # 0 = whole-cohort ticks (sync limit)
    num_events: Optional[int] = None


# ---------------------------------------------------------------------------
# Availability processes
# ---------------------------------------------------------------------------

@runtime_checkable
class AvailabilityProcess(Protocol):
    """Per-device availability gate consumed by the event drivers."""

    def init(self, key: Array, k: int, cfg: EventConfig) -> Array:
        """Once-per-scenario state (e.g. diurnal phases), shape (K,).

        ``key`` is folded off the *pristine* scenario key, so a
        process that ignores it (``always``) leaves every other stream
        bitwise untouched."""
        ...

    def sample(self, key: Array, state: Array, tick: Array,
               cfg: EventConfig) -> Array:
        """(K,) {0, 1} availability mask for one tick (traceable)."""
        ...


@dataclasses.dataclass(frozen=True)
class AlwaysOn:
    """Every device available every tick — the synchronous limit."""

    def init(self, key: Array, k: int, cfg: EventConfig) -> Array:
        del key, cfg
        return jnp.zeros((k,), jnp.float32)

    def sample(self, key: Array, state: Array, tick: Array,
               cfg: EventConfig) -> Array:
        del key, tick, cfg
        return jnp.ones_like(state)


@dataclasses.dataclass(frozen=True)
class Churn:
    """I.i.d. Bernoulli presence: each device is reachable with
    probability ``avail_prob`` each tick, independently."""

    def init(self, key: Array, k: int, cfg: EventConfig) -> Array:
        del key, cfg
        return jnp.zeros((k,), jnp.float32)

    def sample(self, key: Array, state: Array, tick: Array,
               cfg: EventConfig) -> Array:
        del tick
        u = jax.random.uniform(key, state.shape)
        return (u < cfg.avail_prob).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class Diurnal:
    """Correlated day/night activity keyed off the scenario seed.

    One shared cycle phase per scenario plus Gaussian per-device jitter
    (``phase_spread``) — the fleet wakes and sleeps *together*, which
    is what starves a scheduler in ways independent churn cannot.  The
    per-tick availability probability is the sinusoidal activity level
    rescaled so its cycle mean is ``duty`` (exact for
    ``duty <= 0.5``; clipped above).
    """

    def init(self, key: Array, k: int, cfg: EventConfig) -> Array:
        k_shared, k_dev = jax.random.split(key)
        shared = jax.random.uniform(k_shared, (),
                                    maxval=2.0 * jnp.pi)
        jitter = cfg.phase_spread * jax.random.normal(k_dev, (k,))
        return shared + jitter

    def sample(self, key: Array, state: Array, tick: Array,
               cfg: EventConfig) -> Array:
        t = tick.astype(jnp.float32)
        level = 0.5 * (1.0 + jnp.sin(
            2.0 * jnp.pi * t / cfg.period + state))
        p = jnp.clip(2.0 * cfg.duty * level, 0.0, 1.0)
        u = jax.random.uniform(key, state.shape)
        return (u < p).astype(jnp.float32)


_PROCESSES: Dict[str, Callable[[], AvailabilityProcess]] = {}


def register_availability(name: str,
                          factory: Callable[[], AvailabilityProcess],
                          overwrite: bool = False) -> None:
    """Register an availability-process factory (zero-arg -> process)."""
    if name in _PROCESSES and not overwrite:
        raise ValueError(f"availability process {name!r} already "
                         f"registered")
    _PROCESSES[name] = factory


def availability_names() -> tuple[str, ...]:
    return tuple(sorted(_PROCESSES))


def get_availability(name: str) -> AvailabilityProcess:
    """Build the named availability process."""
    try:
        factory = _PROCESSES[name]
    except KeyError:
        raise ValueError(
            f"unknown availability process {name!r}; registered: "
            f"{availability_names()}") from None
    return factory()


register_availability("always", AlwaysOn)
register_availability("churn", Churn)
register_availability("diurnal", Diurnal)


# ---------------------------------------------------------------------------
# Staleness-weighted buffered flush
# ---------------------------------------------------------------------------

def staleness_multiplier(staleness: Array, decay: float) -> Array:
    """FedBuff-style update discount ``(1 + tau)^-gamma``.

    ``decay == 0`` returns exact ones (no pow in the program), which is
    what makes the zero-decay flush weights bitwise identical to the
    synchronous FedAvg weights (the parity contract)."""
    if decay == 0.0:
        return jnp.ones_like(staleness)
    return jnp.power(1.0 + staleness, -decay)


def buffered_flush(params: Params, rows: Array, weights: Array,
                   arrived: Array, stale_mult: Array,
                   use_kernel: bool = False) -> Params:
    """Apply one buffer flush in update form over flattened rows.

    ``g' = g + sum_k (w_k * m_k * s_k) row_k`` with ``weights`` already
    normalized by the caller, ``arrived`` the buffer-membership mask
    and ``stale_mult`` the per-update staleness discount.  The
    reduction is the broadcast-multiply-reduce of
    ``federated.fedavg_aggregate_masked`` on the concatenated layout —
    per-coordinate arithmetic identical to the per-leaf form, which is
    what the synchronous-limit contract leans on.  The kernel path is
    the ``fedavg_agg_stale`` Pallas lane.
    """
    p_leaves, p_treedef = jax.tree_util.tree_flatten(params)
    if use_kernel:
        from repro.kernels import ops as kernel_ops
        agg = kernel_ops.fedavg_agg_stale(rows, weights, arrived,
                                          stale_mult)
    else:
        wm = weights * arrived * stale_mult
        agg = jnp.sum(wm[:, None] * rows, axis=0)
    outs, offset = [], 0
    for p in p_leaves:
        size = int(np.prod(p.shape))
        outs.append(p + agg[offset:offset + size].reshape(p.shape)
                    .astype(p.dtype))
        offset += size
    return jax.tree_util.tree_unflatten(p_treedef, outs)


# ---------------------------------------------------------------------------
# The event scan
# ---------------------------------------------------------------------------

def _make_event_sim(loss_fn: Callable, eval_fn: Callable, wcfg, scfg,
                    fcfg, capacity: int, eval_every: int) -> Callable:
    """Build the traceable event simulation (no jit applied).

    Same signature as ``federated._make_sim``'s product — ``sim(params,
    images, labels, mask, sizes, hists, test_x, test_labels, net, key)
    -> (final_params, RoundMetrics)`` — so the batch driver's vmap /
    shard_map wrappers, buffer donation and the sweep engine reuse it
    unchanged.  One metrics row per *event*; ``round_time`` is the wall
    clock the tick consumed and ``n_success`` the uploads that landed.

    Every synchronous-round helper is reused, not reimplemented: the
    local trainer, the masked/dense-block training body, the streaming
    round, the codec pass, the fault draw + accounting, the scheduler
    config derivation.  The event machinery wraps them with the
    pending/buffer carry — and reduces to the identity in the
    synchronous limit (see the module docstring contract).
    """
    from repro.core import federated as fed

    ecfg = fcfg.events
    if ecfg is None:
        raise ValueError("FLConfig.events is None — use the synchronous "
                         "drivers (federated.make_feel_sim)")
    if ecfg.buffer_size < 1:
        raise ValueError(f"buffer_size must be >= 1, got "
                         f"{ecfg.buffer_size}")
    if ecfg.tick_horizon < 0.0:
        raise ValueError(f"tick_horizon must be >= 0, got "
                         f"{ecfg.tick_horizon}")
    avail_proc = get_availability(ecfg.availability)
    num_events = ecfg.num_events or fcfg.num_rounds

    trainer = fed.make_local_trainer(loss_fn, fcfg)
    max_steps = fed._max_local_steps(fcfg, capacity)
    sch = fed._sched_cfg(scfg, fcfg)
    do_eval = jnp.asarray(fed._eval_mask(num_events, eval_every))
    ticks = jnp.arange(num_events, dtype=jnp.int32)
    n_cap = fcfg.dispatch_cap
    if n_cap is not None and n_cap < 1:
        raise ValueError(f"dispatch_cap must be >= 1, got {n_cap}")
    cdt = fed._carry_dtype(fcfg)
    stream = fcfg.stream
    if stream is not None:
        process, size_cap, measure_col = fed._stream_setup(fcfg, capacity)
    comp = fcfg.compression
    if comp is not None:
        codec = fed._comp_setup(fcfg)
    flt = faults.active(fcfg.faults)
    exp_mult = faults.expected_time_mult(flt) if flt is not None else 1.0
    tel = telemetry_lib.active(fcfg.telemetry)
    sig_fn = fed._make_sig_fn(loss_fn, fcfg, capacity) \
        if (tel is not None and tel.signals) else None
    gamma = ecfg.staleness_decay
    buf_target = float(ecfg.buffer_size)
    horizon = float(ecfg.tick_horizon)

    def sim(params: Params, images: Array, labels: Array, mask: Array,
            sizes: Array, hists: Array, test_x: Array, test_labels: Array,
            net: wireless.NetworkState, key: Array
            ) -> Tuple[Params, "fed.RoundMetrics"]:
        k_dev = sizes.shape[0]
        p_flat = fed.flat_param_size(params)
        # Once-per-scenario draws off the *pristine* scenario key —
        # folded before the streaming init split, exactly like the
        # synchronous driver, so every shared stream stays bitwise
        # identical between the two drivers.
        drop_rates = faults.chronic_rates(
            jax.random.fold_in(key, 0xC407), k_dev, flt) \
            if flt is not None else None
        avail_state = avail_proc.init(
            jax.random.fold_in(key, _PHASE_SALT), k_dev, ecfg)
        if stream is not None:
            key, k_init = jax.random.split(key)
            state0 = fed._diet_stream_state(
                process.init(k_init, hists, stream), cdt)
        if comp is not None:
            residual0 = jnp.zeros((k_dev, p_flat), cdt or jnp.float32)

        def body(carry, xs):
            do_ev, tick = xs
            (params, ages, key, clock, version, pend_rows, pend_mask,
             pend_size, pend_birth, pend_arrival) = carry[:10]
            pos = 10
            if stream is not None:
                st = carry[pos]
                pos += 1
            if comp is not None:
                residual = carry[pos]
                pos += 1
            if flt is not None:
                rel = carry[pos]
                pos += 1
            if sig_fn is not None:
                sigst = carry[pos]
            if cdt is not None:
                pend_rows = pend_rows.astype(jnp.float32)
            # Key discipline copied from the synchronous scan body:
            # same split widths, fault stream folded off the carried
            # key; the availability stream folds too (a widened split
            # would re-key everything and break the parity contract).
            n_keys = 4 + (stream is not None)
            subkeys = jax.random.split(key, n_keys)
            key, k_fade, k_sched, k_train = subkeys[:4]
            if stream is not None:
                k_arr = subkeys[4]
            if flt is not None:
                k_fault = jax.random.fold_in(key, 0xFA17)
            k_avail = jax.random.fold_in(key, _AVAIL_SALT)
            if stream is None:
                index = diversity.diversity_index(
                    label_hists=hists, data_sizes=sizes, ages=ages,
                    weights=fcfg.index_weights, measure=fcfg.measure)
                sizes_r, stale = sizes, None
            else:
                index, sizes_r, stale, hists_r, st = fed._stream_round(
                    process, fcfg, size_cap, measure_col, k_arr, st, ages)
            gains = wireless.sample_fading(k_fade, net)
            # Availability x in-flight gate.  Busy devices (update
            # pending or buffered-unapplied) cannot be re-dispatched;
            # unavailable devices rank at zero priority and are
            # hard-masked out of the admitted set.  In the synchronous
            # limit both masks are all-ones and every expression below
            # passes its input through bitwise unchanged.
            avail = avail_proc.sample(k_avail, avail_state, tick, ecfg)
            free = avail * (1.0 - pend_mask)
            index_g = jnp.where(free > 0.0, index, 0.0)
            payload = codec.payload_bits(comp, wcfg, gains, index_g) \
                if comp is not None else None
            payload_sched = bandwidth.effective_payload_bits(
                payload, exp_mult, wcfg, gains) if flt is not None \
                else payload
            with telemetry_lib.phase_scope("schedule"):
                result = scheduler.schedule_impl(
                    k_sched, index_g, ages, sizes_r, gains, net, wcfg,
                    sch, staleness=stale, payload_bits=payload_sched,
                    reliability=rel if flt is not None else None)
            selected = result.selected * free
            admitted = selected
            if n_cap is None:
                didx = None
                n_dropped = jnp.zeros((), jnp.int32)
            else:
                didx, selected, n_dropped = fed.dispatch_plan(selected,
                                                              n_cap)
            # Fault draw + realized accounting, plus the per-device
            # completion times the arrival queue needs (recomputed with
            # the synchronous drivers' own expressions, so the cohort
            # makespan and each device's arrival agree bitwise).
            if flt is None:
                ok = selected
                draw = None
                if n_cap is None:
                    energy = result.energy
                    round_time = result.round_time
                else:
                    energy, round_time = fed._dispatch_accounting(
                        result, selected)
                t_up = jnp.where(jnp.isinf(result.t_up), 0.0,
                                 result.t_up)
                t_done = jnp.where(selected > 0.0,
                                   result.t_train + t_up, 0.0)
            else:
                draw = faults.sample_faults(k_fault, gains, net, flt,
                                            drop_rates)
                ok, energy, round_time = faults.apply_faults(
                    draw, selected, result.alpha, result.t_train, gains,
                    net, wcfg, payload, flt)
                t_up = wireless.upload_time(
                    result.alpha, gains, net.tx_power, wcfg, payload,
                    airtime_mult=faults.time_mult(draw.attempts, flt))
                t_up = jnp.where((selected > 0.0) & jnp.isfinite(t_up),
                                 t_up, 0.0)
                t_done = jnp.where(
                    selected > 0.0,
                    result.t_train * draw.compute_mult + t_up, 0.0)
            # Local training happens at dispatch time on the *current*
            # model — the channel delay only decides when the server
            # sees the update, so the update itself is computed now and
            # parked in the device's pending slot.
            if comp is None:
                client_params, _ = fed._masked_local_train(
                    trainer, max_steps, fcfg, params, images, labels,
                    mask, sizes_r, selected, k_train, dispatch_idx=didx)
                leaves, _ = jax.tree_util.tree_flatten(client_params)
                p_leaves = jax.tree_util.tree_leaves(params)
                rows = jnp.concatenate(
                    [(cl - p[None]).reshape(k_dev, -1)
                     for cl, p in zip(leaves, p_leaves)], axis=1)
            else:
                k_sgd, k_comp = jax.random.split(k_train)
                client_params, _ = fed._masked_local_train(
                    trainer, max_steps, fcfg, params, images, labels,
                    mask, sizes_r, selected, k_sgd, dispatch_idx=didx)
                leaves, _ = jax.tree_util.tree_flatten(client_params)
                p_leaves = jax.tree_util.tree_leaves(params)
                updates = jnp.concatenate(
                    [(cl - p[None]).reshape(k_dev, -1)
                     for cl, p in zip(leaves, p_leaves)], axis=1)
                if cdt is not None:
                    residual = residual.astype(jnp.float32)
                rows, residual = compression.apply_codec(
                    codec, updates, residual, selected, k_comp,
                    fcfg.compression, gains, index_g,
                    success=draw.success if flt is not None else None)
                if cdt is not None:
                    residual = residual.astype(cdt)
            # Learning-signal observations (DESIGN.md §14), taken on the
            # *raw* pre-codec updates against the pre-flush global model
            # the devices actually trained from — same matrix/reduction
            # the synchronous driver observes, so signals agree in the
            # synchronous limit.  Pure observer; nothing feeds back.
            if sig_fn is not None:
                loss_delta, upd_norm = sig_fn(
                    params, client_params,
                    rows if comp is None else updates,
                    images, labels, mask)
                sigst = telemetry_health.signal_update(
                    sigst, ok, loss_delta, upd_norm, energy)
            # Enqueue the uploads that will land (a failed upload never
            # arrives; its energy is already charged and — under
            # compression — its update already folded back into the
            # error-feedback residual, exactly as in the synchronous
            # fault path).
            enq = ok
            pend_rows = jnp.where(enq[:, None] > 0.0, rows, pend_rows)
            pend_mask = jnp.where(enq > 0.0, 1.0, pend_mask)
            pend_size = jnp.where(enq > 0.0, sizes_r, pend_size)
            pend_birth = jnp.where(enq > 0.0, version, pend_birth)
            pend_arrival = jnp.where(enq > 0.0, clock + t_done,
                                     pend_arrival)
            # Clock advance: whole-cohort ticks in the synchronous
            # limit (dt = the cohort makespan, so every upload lands
            # in-tick), fixed-length ticks under a positive horizon.
            dt = round_time if horizon <= 0.0 \
                else jnp.full((), horizon, jnp.float32)
            clock = clock + dt
            arrived = pend_mask * (pend_arrival <= clock)
            buf_n = jnp.sum(arrived)
            do_flush = buf_n >= buf_target
            # Flush weights: FedAvg sizes over the arrived set, times
            # the staleness discount.  At gamma = 0 the discount is
            # exact ones and the whole expression is the synchronous
            # success-set normalization bitwise.
            tau = (version - pend_birth).astype(jnp.float32)
            s_mult = staleness_multiplier(tau, gamma)
            base = pend_size.astype(jnp.float32) * arrived
            # The effective per-update weight is base * s(tau) over its
            # own sum; at gamma = 0 the discount drops out of the
            # *program* (static branch), leaving the synchronous
            # success-set normalization bitwise.
            num = base * s_mult if gamma != 0.0 else base
            denom = jnp.maximum(jnp.sum(num), 1.0)
            with telemetry_lib.phase_scope("aggregate"):
                if comp is None:
                    # ``buffered_flush`` multiplies the discount in per
                    # row (the kernel lane's fused ``s`` operand), so
                    # only the normalizer is folded here.
                    flushed = buffered_flush(params, pend_rows,
                                             base / denom, arrived,
                                             s_mult, fcfg.use_kernel_agg)
                else:
                    # Mirror the compressed synchronous round's
                    # aggregation (tensordot over the decoded rows) so
                    # the compressed sync-limit parity is also bitwise.
                    agg = jnp.tensordot(num / denom, pend_rows, axes=1)
                    p_leaves2, p_treedef2 = jax.tree_util.tree_flatten(
                        params)
                    outs, offset = [], 0
                    for p in p_leaves2:
                        size = int(np.prod(p.shape))
                        outs.append(
                            p + agg[offset:offset + size]
                            .reshape(p.shape).astype(p.dtype))
                        offset += size
                    flushed = jax.tree_util.tree_unflatten(p_treedef2,
                                                           outs)
                params = jax.tree_util.tree_map(
                    lambda f, p: jnp.where(do_flush, f, p), flushed,
                    params)
            version = version + do_flush.astype(jnp.int32)
            # Applied updates leave the buffer; un-flushed arrivals
            # stay buffered (and their devices stay busy) until the
            # buffer fills.
            cleared = arrived * do_flush.astype(jnp.float32)
            pend_mask = pend_mask * (1.0 - cleared)
            if tel is not None:
                frame = telemetry_record.round_frame(
                    tel, result=result, admitted=admitted,
                    sel_eff=selected, ok=ok, energy=energy,
                    payload_bits=payload, gains=gains, net=net,
                    wcfg=wcfg, sch=sch, key_sched=k_sched, index=index_g,
                    ages=ages, staleness=stale,
                    reliability=rel if flt is not None else None,
                    draw=draw,
                    signals=telemetry_health.signals_frame(
                        sigst, ok, loss_delta, upd_norm)
                    if sig_fn is not None else None)
                if tel.events:
                    frame.update(telemetry_record.event_frame(
                        avail=avail, free=free, in_flight=pend_mask,
                        buffer_fill=buf_n, flushed=do_flush, tau=tau,
                        clock=clock, version=version))
            # Participation = delivered, exactly as in the synchronous
            # drivers: ages reset and the streaming backlog clears for
            # uploads that landed this tick.
            ages = jnp.where(ok > 0.0, 0, ages + 1)
            if flt is not None:
                rel = faults.reliability_update(rel, selected, ok, flt)
            acc = jax.lax.cond(
                do_ev,
                lambda p: jnp.asarray(eval_fn(p, test_x, test_labels),
                                      jnp.float32),
                lambda p: jnp.full((), jnp.nan, jnp.float32),
                params)
            met = fed.RoundMetrics(
                accuracy=acc,
                n_selected=jnp.sum(selected).astype(jnp.int32),
                round_time=dt,
                energy=energy,
                energy_total=jnp.sum(energy),
                selected=selected,
                iterations=result.iterations,
                n_success=jnp.sum(ok).astype(jnp.int32),
                n_dropped=n_dropped,
            )
            if cdt is not None:
                pend_rows = pend_rows.astype(cdt)
            out = (params, ages, key, clock, version, pend_rows,
                   pend_mask, pend_size, pend_birth, pend_arrival)
            if stream is not None:
                out += (fed._stream_advance(st, hists_r, stale, ok,
                                            cdt),)
            if comp is not None:
                out += (residual,)
            if flt is not None:
                out += (rel,)
            if sig_fn is not None:
                out += (sigst,)
            if tel is not None:
                return out, (met, frame)
            return out, met

        carry0 = (params,
                  jnp.zeros((k_dev,), jnp.int32),          # ages
                  key,
                  jnp.zeros((), jnp.float32),              # clock
                  jnp.zeros((), jnp.int32),                # model version
                  jnp.zeros((k_dev, p_flat), cdt or jnp.float32),
                  jnp.zeros((k_dev,), jnp.float32),        # pending mask
                  jnp.zeros((k_dev,), jnp.float32),        # pending sizes
                  jnp.zeros((k_dev,), jnp.int32),          # birth version
                  jnp.zeros((k_dev,), jnp.float32))        # arrival time
        if stream is not None:
            carry0 += (state0,)
        if comp is not None:
            carry0 += (residual0,)
        if flt is not None:
            carry0 += (jnp.ones((k_dev,), jnp.float32),)
        if sig_fn is not None:
            carry0 += (telemetry_health.signal_init(k_dev),)
        if tel is not None:
            out_carry, (metrics, frames) = jax.lax.scan(
                body, carry0, (do_eval, ticks))
            return out_carry[0], metrics, frames
        out_carry, metrics = jax.lax.scan(body, carry0, (do_eval, ticks))
        return out_carry[0], metrics

    return sim


def make_event_sim(*, loss_fn: Callable, eval_fn: Callable,
                   wcfg: wireless.WirelessConfig,
                   scfg: scheduler.SchedulerConfig, fcfg,
                   capacity: int, eval_every: int = 1,
                   donate_params: bool = False) -> Callable:
    """Jitted single-scenario event simulation (see
    :func:`_make_event_sim`).  Same donation contract as
    ``federated.make_feel_sim``."""
    sim = _make_event_sim(loss_fn, eval_fn, wcfg, scfg, fcfg, capacity,
                          eval_every)
    return jax.jit(sim, donate_argnums=(0,) if donate_params else ())


__all__ = ["EventConfig", "AvailabilityProcess", "AlwaysOn", "Churn",
           "Diurnal", "register_availability", "availability_names",
           "get_availability", "staleness_multiplier", "buffered_flush",
           "make_event_sim"]
