"""Sub1 — device selection via relaxation + rounding (paper Eq. 14/16).

For fixed per-device energy ``E_k``, completion time ``t_k = t_train_k +
t_up_k`` and diversity index ``I_k``, the paper relaxes the binary
selection to ``0 <= x_k <= 1`` (Eq. 16) and rounds, falling back to the
top-N priorities if the minimum-count constraint (14c) fails.

We solve the relaxation *exactly* instead of calling a generic LP solver.
Reinstating the deadline coupling (13b), the relaxed program is::

    min_{x,T}  lam_T * T + sum_k (lam_E E_k - lam_I I_k) x_k
    s.t.       t_k x_k <= T,  0 <= x_k <= 1.

For fixed ``T`` it separates per device: with cost coefficient
``c_k = lam_E E_k - lam_I I_k``, the optimum is ``x_k = min(1, T/t_k)`` if
``c_k < 0`` else ``0``.  The outer objective ``J(T)`` is piecewise-linear
with breakpoints at ``{t_k}``, so scanning the K breakpoints yields the
global optimum in O(K^2) vectorized work (K ~ 100).  The continuous ``x``
is the paper's "selection priority"; rounding + the top-N fallback follow
Algorithm 2 lines 6-9.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Sub1Params:
    lambda_e: float = 0.25   # paper §VI-A: lam_E = lam_T = 1/4, lam_I = 1/2
    lambda_t: float = 0.25
    lambda_i: float = 0.5
    n_min: int = 1           # N: minimum devices per round (paper: 1)


def solve_sub1_relaxed(energy: Array, times: Array, index: Array,
                       params: Sub1Params) -> tuple[Array, Array]:
    """Exact solution of the relaxed Sub1 (Eq. 16).

    Args:
      energy: (K,) E_k at the current bandwidth allocation.
      times:  (K,) t_train_k + t_up_k at the current allocation.
      index:  (K,) diversity index I_k.

    Returns:
      (x_relaxed, t_star): continuous priorities in [0, 1] and the optimal
      deadline.
    """
    c = params.lambda_e * energy - params.lambda_i * index      # (K,)
    beneficial = c < 0.0
    t_safe = jnp.maximum(times, 1e-9)

    # J(T) evaluated at every breakpoint T = t_j (plus T = 0).
    cand = jnp.concatenate([jnp.zeros((1,), times.dtype), t_safe])  # (K+1,)
    frac = jnp.minimum(1.0, cand[:, None] / t_safe[None, :])        # (K+1,K)
    contrib = jnp.where(beneficial[None, :], c[None, :] * frac, 0.0)
    j_vals = params.lambda_t * cand + jnp.sum(contrib, axis=1)      # (K+1,)
    t_star = cand[jnp.argmin(j_vals)]

    x = jnp.where(beneficial, jnp.minimum(1.0, t_star / t_safe), 0.0)
    return x, t_star


def round_with_min(x_relaxed: Array, index: Array, n_min: int) -> Array:
    """Round priorities to {0,1}; enforce (14c) via top-N fallback.

    The paper: "if the condition (14c) is not satisfied, we set x_k = 1 for
    the N devices with highest priorities."  Ties are broken by the
    diversity index so the fallback still prefers data-rich devices.
    """
    x = (x_relaxed >= 0.5).astype(jnp.float32)
    need_fallback = jnp.sum(x) < n_min
    # Priority = relaxed value, index as tiebreaker.
    idx_norm = index / jnp.maximum(jnp.max(index), 1e-12)
    priority = x_relaxed + 1e-4 * idx_norm
    _, top = jax.lax.top_k(priority, n_min)
    fallback = jnp.zeros_like(x).at[top].set(1.0)
    # Fallback *adds* to the rounded set (the constraint is >= N).
    return jnp.where(need_fallback, jnp.maximum(x, fallback), x)


def solve_sub1(energy: Array, times: Array, index: Array,
               params: Sub1Params) -> tuple[Array, Array, Array]:
    """Full Sub1: relax -> round -> enforce minimum count.

    Returns (x_binary, x_relaxed, t_star).
    """
    x_rel, t_star = solve_sub1_relaxed(energy, times, index, params)
    x_bin = round_with_min(x_rel, index, params.n_min)
    return x_bin, x_rel, t_star
