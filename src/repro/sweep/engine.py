"""Sharded Monte-Carlo execution + online Welford aggregation.

The engine turns a :class:`repro.sweep.grid.SweepSpec` into compiled
work: for each grid point it builds the batched FEEL sim
(``federated.make_feel_sim_batch``) — sharding the scenario axis over a
``scenario`` mesh axis via ``shard_map`` when a mesh is available — and
executes the point's scenarios in chunks of ``S``, folding every
chunk's ``(S, R)`` metrics into an **online Welford aggregate** carried
across chunks.  Host (and checkpoint) state is O(R) per grid point no
matter how many scenarios run: per-round mean/variance/min/max of
accuracy, energy and completion time, plus the per-scenario summary
scalars the paper figures need (final accuracy, totals, rounds to a
target accuracy).

Numerics: the fold uses the Chan et al. parallel-merge form — a chunk's
batch statistics (count/mean/M2 over the scenario axis) merge into the
carry in one step — with NaN-masking so eval-stride rounds (NaN
accuracy) simply don't count toward that round's statistics.  The fold
runs jitted on device; only the O(R) carry ever reaches the host.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import federated, wireless
from repro.data import partition as partition_lib
from repro.data import synthetic
from repro.launch import mesh as mesh_lib
from repro.sweep import grid as grid_lib

Array = jax.Array

# Salts separating the two per-scenario fold_in streams.
_NET_STREAM = 0
_SIM_STREAM = 1


def stream_bases(base_seed: int) -> Tuple[Array, Array]:
    """(net_base, sim_base) keys for a sweep's two per-scenario streams.

    Scenario ``i`` draws its network from ``fold_in(net_base, i)`` and
    its simulation stream from ``fold_in(sim_base, i)``.  Public so the
    unsharded driver path (``benchmarks.common.run_fl_batch``) derives
    the *same* scenarios as the engine — the sharded/unsharded parity
    contract compares like with like.
    """
    root = jax.random.key(base_seed)
    return (jax.random.fold_in(root, _NET_STREAM),
            jax.random.fold_in(root, _SIM_STREAM))


# ---------------------------------------------------------------------------
# Online Welford aggregation (masked, batched merge)
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Welford:
    """Running mean/variance/min/max over the scenario population.

    Leaves share a broadcastable shape (``(R,)`` for per-round metrics,
    ``()`` for per-scenario scalars).  ``count`` is per-element because
    masking (NaN accuracy on eval-stride rounds, never-reached targets)
    makes the effective sample size element-dependent.
    """

    count: Array
    mean: Array
    m2: Array
    min: Array
    max: Array

    def tree_flatten(self):
        return ((self.count, self.mean, self.m2, self.min, self.max),
                None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    @property
    def variance(self) -> Array:
        """Population variance (ddof=0), matching ``jnp.var``."""
        return jnp.where(self.count > 0, self.m2
                         / jnp.maximum(self.count, 1.0), jnp.nan)

    @property
    def std(self) -> Array:
        return jnp.sqrt(self.variance)


def welford_init(shape: Tuple[int, ...]) -> Welford:
    return Welford(count=jnp.zeros(shape, jnp.float32),
                   mean=jnp.zeros(shape, jnp.float32),
                   m2=jnp.zeros(shape, jnp.float32),
                   min=jnp.full(shape, jnp.inf, jnp.float32),
                   max=jnp.full(shape, -jnp.inf, jnp.float32))


def welford_fold(state: Welford, batch: Array,
                 mask: Optional[Array] = None) -> Welford:
    """Merge a ``(S, ...)`` batch into the carry (Chan et al. merge).

    ``mask`` (same shape, optional) excludes entries; NaNs are always
    excluded so eval-stride rounds never poison the fold.
    """
    batch = batch.astype(jnp.float32)
    valid = jnp.isfinite(batch)
    if mask is not None:
        valid = jnp.logical_and(valid, mask)
    x = jnp.where(valid, batch, 0.0)
    n_b = jnp.sum(valid, axis=0).astype(jnp.float32)
    safe_n = jnp.maximum(n_b, 1.0)
    mean_b = jnp.sum(x, axis=0) / safe_n
    m2_b = jnp.sum(jnp.where(valid, (x - mean_b) ** 2, 0.0), axis=0)
    n = state.count + n_b
    delta = mean_b - state.mean
    has = n_b > 0
    mean = jnp.where(has, state.mean + delta * n_b / jnp.maximum(n, 1.0),
                     state.mean)
    m2 = jnp.where(has, state.m2 + m2_b
                   + delta ** 2 * state.count * n_b / jnp.maximum(n, 1.0),
                   state.m2)
    mn = jnp.minimum(state.min, jnp.min(jnp.where(valid, batch, jnp.inf),
                                        axis=0))
    mx = jnp.maximum(state.max, jnp.max(jnp.where(valid, batch, -jnp.inf),
                                        axis=0))
    return Welford(count=n, mean=mean, m2=m2, min=mn, max=mx)


# ---------------------------------------------------------------------------
# Per-point aggregate: per-round Welford + per-scenario scalar Welford
# ---------------------------------------------------------------------------

ROUND_METRICS = ("accuracy", "round_time", "energy_total", "n_selected",
                 "n_success", "n_dropped")
SCALAR_METRICS = ("final_accuracy", "time_total", "energy_total",
                  "energy_per_device", "mean_selected", "rounds_to_target",
                  "reached_target")


def aggregate_init(num_rounds: int) -> Dict[str, Dict[str, Welford]]:
    return {
        "round": {m: welford_init((num_rounds,)) for m in ROUND_METRICS},
        "scalar": {m: welford_init(()) for m in SCALAR_METRICS},
    }


def _scenario_scalars(metrics: federated.RoundMetrics, target: float):
    """Per-scenario (S,) summary scalars + validity masks from (S, R)
    stacked metrics — the quantities ``benchmarks.common.totals`` and
    ``rounds_to_accuracy`` derive per scenario, computed on device."""
    acc = metrics.accuracy                       # (S, R), NaN on skipped
    n_sel = metrics.n_selected.astype(jnp.float32)
    e_tot = jnp.sum(metrics.energy_total, axis=1)
    t_tot = jnp.sum(metrics.round_time, axis=1)
    sel_tot = jnp.sum(n_sel, axis=1)
    hit = jnp.where(jnp.isnan(acc), False, acc >= target)   # (S, R)
    reached = jnp.any(hit, axis=1)
    first = jnp.argmax(hit, axis=1).astype(jnp.float32) + 1.0
    out = {
        "final_accuracy": acc[:, -1],
        "time_total": t_tot,
        "energy_total": e_tot,
        "energy_per_device": e_tot / jnp.maximum(sel_tot, 1.0),
        "mean_selected": jnp.mean(n_sel, axis=1),
        "rounds_to_target": first,
        "reached_target": reached.astype(jnp.float32),
    }
    masks = {m: None for m in out}
    masks["rounds_to_target"] = reached   # only scenarios that got there
    return out, masks


def aggregate_fold(agg: Dict[str, Dict[str, Welford]],
                   metrics: federated.RoundMetrics,
                   target: float) -> Dict[str, Dict[str, Welford]]:
    """Fold one chunk's ``(S, R)`` metrics into the O(R) carry."""
    per_round = {
        "accuracy": metrics.accuracy,
        "round_time": metrics.round_time,
        "energy_total": metrics.energy_total,
        "n_selected": metrics.n_selected.astype(jnp.float32),
        "n_success": metrics.n_success.astype(jnp.float32),
        "n_dropped": metrics.n_dropped.astype(jnp.float32),
    }
    scalars, masks = _scenario_scalars(metrics, target)
    return {
        "round": {m: welford_fold(agg["round"][m], per_round[m])
                  for m in ROUND_METRICS},
        "scalar": {m: welford_fold(agg["scalar"][m], scalars[m],
                                   masks[m])
                   for m in SCALAR_METRICS},
    }


def aggregate_summary(agg) -> Dict[str, Dict[str, np.ndarray]]:
    """Host-side view: ``{"round.accuracy": {mean, var, std, min, max,
    count}, ...}`` — everything the figure suites consume."""
    host = jax.device_get(agg)
    out: Dict[str, Dict[str, np.ndarray]] = {}
    for group, metrics in host.items():
        for name, w in metrics.items():
            count = np.asarray(w.count)
            valid = count > 0
            out[f"{group}.{name}"] = {
                "count": count,
                "mean": np.where(valid, np.asarray(w.mean), np.nan),
                "var": np.asarray(w.variance),
                "std": np.asarray(w.std),
                "min": np.where(valid, np.asarray(w.min), np.nan),
                "max": np.where(valid, np.asarray(w.max), np.nan),
            }
    return out


# -- adaptive scenario counts (SweepSpec.ci_target) -----------------------

def final_accuracy_ci_halfwidth(agg) -> float:
    """95% CI half-width of the final-accuracy mean from the Welford
    carry: ``1.96 * sqrt(m2 / (n-1)) / sqrt(n)`` (sample std / sqrt n).
    ``inf`` below two scenarios — a single draw has no spread estimate.
    One O(1) host transfer; callers are the chunk loops, which already
    sync per chunk for checkpointing.
    """
    w = agg["scalar"]["final_accuracy"]
    n = float(jax.device_get(w.count))
    if n < 2.0:
        return float("inf")
    m2 = max(float(jax.device_get(w.m2)), 0.0)
    return 1.96 * np.sqrt(m2 / (n - 1.0)) / np.sqrt(n)


def point_converged(agg, ci_target: float) -> bool:
    """True when adaptive stopping is on and the point's final-accuracy
    CI half-width is at or below the target."""
    if ci_target <= 0.0:
        return False
    return bool(final_accuracy_ci_halfwidth(agg) <= ci_target)


# -- checkpoint (de)serialization: Welford pytree <-> plain array tree ----

def aggregate_to_tree(agg) -> Dict[str, Dict[str, Dict[str, np.ndarray]]]:
    return {
        group: {
            name: {field: np.asarray(getattr(w, field))
                   for field in ("count", "mean", "m2", "min", "max")}
            for name, w in metrics.items()
        }
        for group, metrics in jax.device_get(agg).items()
    }


def aggregate_from_tree(tree) -> Dict[str, Dict[str, Welford]]:
    return {
        group: {
            name: Welford(**{f: jnp.asarray(leaves[f])
                             for f in ("count", "mean", "m2", "min",
                                       "max")})
            for name, leaves in metrics.items()
        }
        for group, metrics in tree.items()
    }


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class SweepEngine:
    """Executes a :class:`SweepSpec` chunk by chunk.

    One instance owns the problem data (dataset, model init, loss/eval)
    and a compiled-sim cache keyed by ``(grid point, chunk size,
    sharded?)`` — re-running a chunk size reuses the jit.  The mesh is
    built lazily from the present devices (``launch.mesh
    .make_scenario_mesh``); chunks whose size the mesh does not divide
    fall back to the unsharded vmap program transparently, so a sweep
    never fails on an awkward remainder chunk.
    """

    def __init__(self, spec: grid_lib.SweepSpec, *,
                 data: partition_lib.ClientDataset,
                 loss_fn: Callable, eval_fn: Callable,
                 init_params, target_accuracy: float = 0.85,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 use_sharding: bool = True,
                 donate_params: bool = False,
                 telemetry_dir: Optional[str] = None):
        self.spec = spec
        self.data = data
        self.loss_fn = loss_fn
        self.eval_fn = eval_fn
        self.init_params = init_params
        self.target_accuracy = float(target_accuracy)
        self.donate_params = donate_params
        # Per-scenario telemetry streams (DESIGN.md §13): grid points
        # whose FLConfig.telemetry is set return stacked frames from the
        # batch sim; when a directory is given each scenario's frames
        # land in their own JSONL file keyed by the fold_in-derived
        # global scenario index, so re-running a chunk (resume) simply
        # overwrites the same files with the same bytes.
        self.telemetry_dir = telemetry_dir
        self._manifest_written = False
        if mesh is None and use_sharding:
            mesh = mesh_lib.make_scenario_mesh()
        self.mesh = mesh
        self.points = spec.expand()
        self._sims: Dict[Tuple[int, int, bool], Callable] = {}
        self._hists: Dict[int, Array] = {}
        self._fold = jax.jit(aggregate_fold, static_argnums=(2,))
        # Problem-wide constants, computed once.
        self._test_x = synthetic.to_float(data.test_images)
        self._net_base, self._sim_base = stream_bases(spec.base_seed)

    # -- plumbing --------------------------------------------------------

    def _shard_count(self) -> int:
        if self.mesh is None:
            return 1
        return mesh_lib.scenario_shard_count(self.mesh)

    def _sim_for(self, point: grid_lib.GridPoint, size: int) -> Callable:
        sharded = self.mesh is not None and size % self._shard_count() == 0
        cache_key = (point.index, size, sharded)
        sim = self._sims.get(cache_key)
        if sim is None:
            sim = federated.make_feel_sim_batch(
                loss_fn=self.loss_fn, eval_fn=self.eval_fn,
                wcfg=point.wireless, scfg=point.sched, fcfg=point.fl,
                capacity=self.data.capacity,
                eval_every=self.spec.eval_every,
                donate_params=self.donate_params,
                mesh=self.mesh if sharded else None)
            self._sims[cache_key] = sim
        return sim

    def _hists_for(self, point: grid_lib.GridPoint) -> Array:
        # Constant per num_classes — cached so chunked runs don't rebuild
        # the (K, C) histogram scan every dispatch.
        c = point.fl.num_classes
        if c not in self._hists:
            self._hists[c] = federated.client_histograms(self.data, c)
        return self._hists[c]

    # -- execution -------------------------------------------------------

    def run_chunk(self, point: grid_lib.GridPoint, global_start: int,
                  size: int, agg):
        """Run scenarios [global_start, global_start + size) of a grid
        point and fold their metrics into ``agg``."""
        data = self.data
        indices = jnp.arange(global_start, global_start + size)
        nets = wireless.sample_networks_indexed(
            self._net_base, indices, data.num_devices, point.wireless)
        keys = federated.scenario_keys(self._sim_base, global_start, size)
        params = federated.tile_params(self.init_params, size) \
            if self.donate_params else self.init_params
        sim = self._sim_for(point, size)
        out = sim(params, data.images, data.labels, data.mask,
                  data.sizes, self._hists_for(point), self._test_x,
                  data.test_labels, nets, keys)
        if len(out) == 3:
            _, metrics, frames = out
            self._sink_frames(point, global_start, size, metrics, frames)
        else:
            _, metrics = out
        return self._fold(agg, metrics, self.target_accuracy)

    def _sink_frames(self, point: grid_lib.GridPoint, global_start: int,
                     size: int, metrics, frames) -> None:
        """One JSONL round-event file per scenario in the chunk, named
        by grid-point index and global scenario index (the same fold_in
        index that derives the scenario's streams, so a resumed re-run
        rewrites identical bytes), plus one run manifest per sweep."""
        if self.telemetry_dir is None:
            return
        from repro.telemetry import sinks
        os.makedirs(self.telemetry_dir, exist_ok=True)
        if not self._manifest_written:
            sinks.write_manifest(
                os.path.join(self.telemetry_dir, "manifest.json"),
                self.spec, extra={"kind": "sweep",
                                  "fingerprint": self.spec.fingerprint()})
            self._manifest_written = True
        host_frames = sinks.frames_to_host(frames)
        host_met = jax.device_get(metrics)
        for s in range(size):
            scn = global_start + s
            path = os.path.join(
                self.telemetry_dir,
                f"point{point.index:03d}_scn{scn:05d}.jsonl")
            sinks.write_round_frames(
                path,
                {k: v[s] for k, v in host_frames.items()},
                metrics=jax.tree_util.tree_map(lambda a, s=s: a[s],
                                               host_met),
                scenario=scn)

    def run_point(self, point: grid_lib.GridPoint, agg=None):
        """All chunks of one grid point folded into one fresh aggregate
        (mid-point resume is the runner's job — it drives
        :meth:`run_chunk` directly from its checkpointed cursor).
        With ``spec.ci_target > 0`` the chunk loop stops early once the
        final-accuracy CI half-width reaches the target."""
        if agg is None:
            agg = aggregate_init(federated.sim_length(point.fl))
        base = self.spec.scenario_start(point.index)
        for off, size in self.spec.point_chunks():
            if off > 0 and point_converged(agg, self.spec.ci_target):
                break
            agg = self.run_chunk(point, base + off, size, agg)
        return agg

    def run(self) -> List[Tuple[grid_lib.GridPoint,
                                Dict[str, Dict[str, np.ndarray]]]]:
        """The whole grid, no checkpointing (use ``runner.SweepRunner``
        for resumable execution).  Returns per-point summaries."""
        return [(p, aggregate_summary(self.run_point(p)))
                for p in self.points]


__all__ = ["Welford", "welford_init", "welford_fold", "aggregate_init",
           "aggregate_fold", "aggregate_summary", "aggregate_to_tree",
           "aggregate_from_tree", "SweepEngine", "ROUND_METRICS",
           "SCALAR_METRICS", "stream_bases",
           "final_accuracy_ci_halfwidth", "point_converged"]
