"""Sharded Monte-Carlo sweep engine (DESIGN.md §8).

``grid``   — declarative scenario grids (SweepSpec/Axis/GridPoint) with
             fold_in-derived, chunk-invariant per-scenario seeds.
``engine`` — shard_map chunk execution + online Welford aggregation
             (O(R) host state regardless of scenario count).
``runner`` — resumable execution: Welford carry + grid cursor
             checkpointed through ``checkpoint.msgpack_ckpt``.
"""

from repro.sweep.grid import Axis, GridPoint, SweepSpec
from repro.sweep.engine import (SweepEngine, Welford, aggregate_summary,
                                welford_fold, welford_init)
from repro.sweep.runner import SweepRunner, run_sweep

__all__ = ["Axis", "GridPoint", "SweepSpec", "SweepEngine", "Welford",
           "aggregate_summary", "welford_fold", "welford_init",
           "SweepRunner", "run_sweep"]
