"""Resumable sweep execution: chunk cursor + Welford carry on disk.

The runner walks the :meth:`SweepSpec.schedule` — the flat
``(point, global_start, size)`` chunk list — and checkpoints the
O(R)-sized state through ``checkpoint.msgpack_ckpt`` after every
``checkpoint_every`` chunks: the per-point Welford aggregates plus a
cursor and the spec fingerprint.  A killed sweep restarts **bit for
bit**: per-scenario streams are fold_in-derived from global indices
(chunking doesn't perturb them), the chunk schedule is part of the
fingerprint, and the Welford fold re-enters at exactly the chunk the
cursor names — so the resumed final aggregates are bitwise identical to
an uninterrupted run (``tests/test_sweep.py``).

Checkpoints refuse to resume across incompatible writers twice over:
the msgpack container's ``FORMAT_VERSION`` header guards the leaf
encoding, and ``STATE_VERSION`` in the meta dict guards the runner's
own state layout.  A fingerprint mismatch (the spec changed underneath
the checkpoint) is an error, not a silent restart.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import msgpack_ckpt
from repro.sweep import engine as engine_lib
from repro.sweep import grid as grid_lib

# Version of the runner's resume-state layout inside the checkpoint
# meta/tree (independent of the msgpack container version).
STATE_VERSION = 1


def _tree_from_flat(flat: Dict[str, np.ndarray]) -> dict:
    """Rebuild the nested dict msgpack_ckpt flattened ('/' separator;
    grid-point names never contain '/')."""
    tree: dict = {}
    for path, leaf in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return tree


@dataclasses.dataclass
class SweepRunner:
    """Drives a :class:`SweepEngine` through its chunk schedule with
    checkpointed progress.

    ``max_chunks`` bounds how many chunks one ``run`` call executes —
    the hook the kill/resume test uses, and a natural fit for
    preemptible allocations (run until evicted, resume later).
    """

    engine: engine_lib.SweepEngine
    ckpt_path: str
    checkpoint_every: int = 1

    def __post_init__(self):
        self.spec = self.engine.spec
        self._schedule = self.spec.schedule()
        self._points = self.engine.points

    # -- state <-> disk --------------------------------------------------

    def _save(self, aggs: Dict[int, object], cursor: int) -> None:
        # Keyed by the stable point index, not the formatted name: names
        # can collide (two axis values formatting alike) and string axis
        # values may contain '/', the flattener's path separator.
        tree = {"aggs": {str(i): engine_lib.aggregate_to_tree(a)
                         for i, a in aggs.items()}}
        msgpack_ckpt.save(self.ckpt_path, tree, meta={
            "state_version": STATE_VERSION,
            "cursor": cursor,
            "fingerprint": self.spec.fingerprint(),
            # Engine-owned knob that shapes the folded scalars
            # (rounds_to_target / reached_target): resuming under a
            # different target would silently mix populations.
            "target_accuracy": self.engine.target_accuracy,
            "total_chunks": len(self._schedule),
            "point_names": {str(p.index): p.name
                            for p in self._points},
        })

    def _load(self) -> Tuple[Dict[int, object], int]:
        flat, meta = msgpack_ckpt.load_flat(self.ckpt_path)
        version = meta.get("state_version", 0)
        if version != STATE_VERSION:
            raise ValueError(
                f"{self.ckpt_path}: sweep state version {version} != "
                f"supported {STATE_VERSION}")
        if meta.get("fingerprint") != self.spec.fingerprint():
            raise ValueError(
                f"{self.ckpt_path}: checkpoint was written for a "
                f"different SweepSpec (fingerprint mismatch) — refusing "
                f"to fold incompatible scenario populations")
        if meta.get("target_accuracy") != self.engine.target_accuracy:
            raise ValueError(
                f"{self.ckpt_path}: checkpoint target_accuracy "
                f"{meta.get('target_accuracy')} != engine's "
                f"{self.engine.target_accuracy} — the rounds_to_target "
                f"scalars would mix judgments against two targets")
        tree = _tree_from_flat(flat)
        aggs = {int(idx): engine_lib.aggregate_from_tree(sub)
                for idx, sub in tree.get("aggs", {}).items()}
        return aggs, int(meta["cursor"])

    # -- execution -------------------------------------------------------

    def run(self, resume: bool = True,
            max_chunks: Optional[int] = None
            ) -> Optional[List[Tuple[grid_lib.GridPoint,
                                     Dict[str, Dict[str, np.ndarray]]]]]:
        """Execute (the remainder of) the sweep.

        Returns per-point ``(GridPoint, summary)`` in grid order once
        every chunk has run; ``None`` if stopped early by
        ``max_chunks`` (state is checkpointed either way).
        """
        aggs: Dict[int, object] = {}
        cursor = 0
        if resume and os.path.exists(self.ckpt_path):
            aggs, cursor = self._load()
        executed = 0
        while cursor < len(self._schedule):
            if max_chunks is not None and executed >= max_chunks:
                self._save(aggs, cursor)
                return None
            point_idx, start, size = self._schedule[cursor]
            point = self._points[point_idx]
            agg = aggs.get(point_idx)
            if agg is None:
                agg = engine_lib.aggregate_init(point.fl.num_rounds)
            aggs[point_idx] = self.engine.run_chunk(point, start, size,
                                                    agg)
            cursor += 1
            executed += 1
            if cursor % self.checkpoint_every == 0 \
                    or cursor == len(self._schedule):
                self._save(aggs, cursor)
        return [(self._points[i], engine_lib.aggregate_summary(aggs[i]))
                for i in sorted(aggs)]


def run_sweep(spec: grid_lib.SweepSpec, *, data, loss_fn, eval_fn,
              init_params, ckpt_path: Optional[str] = None,
              target_accuracy: float = 0.85, use_sharding: bool = True,
              donate_params: bool = False, resume: bool = True):
    """One-call sweep: build the engine, optionally resume from
    ``ckpt_path``, return per-point summaries."""
    eng = engine_lib.SweepEngine(
        spec, data=data, loss_fn=loss_fn, eval_fn=eval_fn,
        init_params=init_params, target_accuracy=target_accuracy,
        use_sharding=use_sharding, donate_params=donate_params)
    if ckpt_path is None:
        return eng.run()
    return SweepRunner(eng, ckpt_path).run(resume=resume)


__all__ = ["SweepRunner", "run_sweep", "STATE_VERSION"]
