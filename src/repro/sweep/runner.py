"""Resumable sweep execution: chunk cursor + Welford carry on disk.

The runner walks the :meth:`SweepSpec.schedule` — the flat
``(point, global_start, size)`` chunk list — and checkpoints the
O(R)-sized state through ``checkpoint.msgpack_ckpt`` after every
``checkpoint_every`` chunks: the per-point Welford aggregates plus a
cursor and the spec fingerprint.  A killed sweep restarts **bit for
bit**: per-scenario streams are fold_in-derived from global indices
(chunking doesn't perturb them), the chunk schedule is part of the
fingerprint, and the Welford fold re-enters at exactly the chunk the
cursor names — so the resumed final aggregates are bitwise identical to
an uninterrupted run (``tests/test_sweep.py``).

Two live-operations hooks ride the same chunk walk: ``jsonl_path``
streams one JSON line of scalar aggregates per chunk for dashboards
(resume-safe: lines are keyed by cursor and rewound to the resumed
checkpoint before appending), and ``SweepSpec.ci_target`` skips a
point's remaining chunks once its final-accuracy CI is tight enough
(adaptive scenario counts — the Welford carry already holds the needed
moments).

Checkpoints refuse to resume across incompatible writers twice over:
the msgpack container's ``FORMAT_VERSION`` header guards the leaf
encoding, and ``STATE_VERSION`` in the meta dict guards the runner's
own state layout.  A fingerprint mismatch (the spec changed underneath
the checkpoint) is an error, not a silent restart.
"""

from __future__ import annotations

import dataclasses
import math
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint import msgpack_ckpt
from repro.core import federated
from repro.sweep import engine as engine_lib
from repro.sweep import grid as grid_lib
from repro.telemetry import sinks
from repro.telemetry import store as store_lib

# Version of the runner's resume-state layout inside the checkpoint
# meta/tree (independent of the msgpack container version).
STATE_VERSION = 1


def _tree_from_flat(flat: Dict[str, np.ndarray]) -> dict:
    """Rebuild the nested dict msgpack_ckpt flattened ('/' separator;
    grid-point names never contain '/')."""
    tree: dict = {}
    for path, leaf in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return tree


@dataclasses.dataclass
class SweepRunner:
    """Drives a :class:`SweepEngine` through its chunk schedule with
    checkpointed progress.

    ``max_chunks`` bounds how many chunks one ``run`` call executes —
    the hook the kill/resume test uses, and a natural fit for
    preemptible allocations (run until evicted, resume later).

    ``jsonl_path`` streams one JSON line per walked chunk — the point's
    *current* scalar aggregates (mean/std/count of final accuracy,
    totals, energy per device, ...) plus the chunk coordinates — so a
    live dashboard can tail the file while the sweep runs.  The append
    contract is resume-safe: every line carries the post-chunk
    ``cursor``, and on startup the file is rewound to the resumed
    cursor (lines from a killed run's un-checkpointed tail are
    dropped), so the line sequence always matches the Welford carry
    that produced it.  ``ckpt_path=None`` runs without checkpoints
    (JSONL streaming still works; resume obviously doesn't).

    With ``spec.ci_target > 0`` (adaptive scenario counts) a chunk
    whose point already reached the final-accuracy CI target is
    *skipped*: the cursor advances, a ``"skipped": true`` line is
    streamed, and no compute is spent.  Skipping is a pure function of
    the folded aggregate, so kill/resume reproduces the same schedule.
    """

    engine: engine_lib.SweepEngine
    ckpt_path: Optional[str]
    checkpoint_every: int = 1
    jsonl_path: Optional[str] = None
    store_path: Optional[str] = None

    def __post_init__(self):
        self.spec = self.engine.spec
        self._schedule = self.spec.schedule()
        self._points = self.engine.points

    # -- JSONL streaming -------------------------------------------------

    def _jsonl_rewind(self, cursor: int) -> None:
        """Drop lines past the resumed cursor (the resume-safe append
        contract): a killed run may have streamed chunks that were
        never checkpointed; those re-execute, so their stale lines must
        go before the re-run appends duplicates.  The kept-line
        semantics live in ``telemetry.sinks.jsonl_rewind`` (shared with
        the round-event logs), which also hardened the rewrite to
        fsync-before-replace."""
        if self.jsonl_path is None:
            return
        sinks.jsonl_rewind(self.jsonl_path, cursor)

    def _jsonl_emit(self, cursor: int, point: grid_lib.GridPoint,
                    start: int, size: int, agg, skipped: bool) -> None:
        if self.jsonl_path is None:
            return

        def _num(x) -> Optional[float]:
            v = float(x)
            return v if math.isfinite(v) else None

        summary = engine_lib.aggregate_summary(agg)
        scalars = {
            name.split(".", 1)[1]: {
                "mean": _num(stats["mean"]),
                "std": _num(stats["std"]),
                "min": _num(stats["min"]),
                "max": _num(stats["max"]),
                "count": float(stats["count"]),
            }
            for name, stats in summary.items()
            if name.startswith("scalar.")
        }
        rec = {
            "cursor": cursor,
            "point": point.index,
            "point_name": point.name,
            "global_start": start,
            "size": size,
            "skipped": skipped,
            "scalar": scalars,
        }
        sinks.jsonl_append(self.jsonl_path, rec)

    # -- state <-> disk --------------------------------------------------

    def _save(self, aggs: Dict[int, object], cursor: int) -> None:
        if self.ckpt_path is None:
            return
        # Keyed by the stable point index, not the formatted name: names
        # can collide (two axis values formatting alike) and string axis
        # values may contain '/', the flattener's path separator.
        tree = {"aggs": {str(i): engine_lib.aggregate_to_tree(a)
                         for i, a in aggs.items()}}
        msgpack_ckpt.save(self.ckpt_path, tree, meta={
            "state_version": STATE_VERSION,
            "cursor": cursor,
            "fingerprint": self.spec.fingerprint(),
            # Engine-owned knob that shapes the folded scalars
            # (rounds_to_target / reached_target): resuming under a
            # different target would silently mix populations.
            "target_accuracy": self.engine.target_accuracy,
            "total_chunks": len(self._schedule),
            # Arity of the per-round metric tuple folded into the
            # Welford aggregates: adding/removing a round metric
            # changes the aggregate pytree structure, and resuming an
            # old checkpoint would crash deep inside the fold with a
            # pytree-structure error.  Stamping it here turns that
            # into the loud schema check in :meth:`_load`.
            "round_metrics_arity": len(engine_lib.ROUND_METRICS),
            "point_names": {str(p.index): p.name
                            for p in self._points},
        })

    def _load(self) -> Tuple[Dict[int, object], int]:
        flat, meta = msgpack_ckpt.load_flat(self.ckpt_path)
        version = meta.get("state_version", 0)
        if version != STATE_VERSION:
            raise ValueError(
                f"{self.ckpt_path}: sweep state version {version} != "
                f"supported {STATE_VERSION}")
        arity = meta.get("round_metrics_arity", -1)
        if arity != len(engine_lib.ROUND_METRICS):
            raise ValueError(
                f"{self.ckpt_path}: checkpoint was written with "
                f"{'an unstamped' if arity < 0 else arity} round-metric "
                f"arity but this build folds "
                f"{len(engine_lib.ROUND_METRICS)} per-round metrics "
                f"({', '.join(engine_lib.ROUND_METRICS)}) — the Welford "
                f"aggregate layout changed, so this checkpoint cannot "
                f"be resumed.  Delete it (or point ckpt_path elsewhere) "
                f"and re-run the sweep from scratch.")
        if meta.get("fingerprint") != self.spec.fingerprint():
            raise ValueError(
                f"{self.ckpt_path}: checkpoint was written for a "
                f"different SweepSpec (fingerprint mismatch) — refusing "
                f"to fold incompatible scenario populations")
        if meta.get("target_accuracy") != self.engine.target_accuracy:
            raise ValueError(
                f"{self.ckpt_path}: checkpoint target_accuracy "
                f"{meta.get('target_accuracy')} != engine's "
                f"{self.engine.target_accuracy} — the rounds_to_target "
                f"scalars would mix judgments against two targets")
        tree = _tree_from_flat(flat)
        aggs = {int(idx): engine_lib.aggregate_from_tree(sub)
                for idx, sub in tree.get("aggs", {}).items()}
        return aggs, int(meta["cursor"])

    # -- execution -------------------------------------------------------

    def run(self, resume: bool = True,
            max_chunks: Optional[int] = None
            ) -> Optional[List[Tuple[grid_lib.GridPoint,
                                     Dict[str, Dict[str, np.ndarray]]]]]:
        """Execute (the remainder of) the sweep.

        Returns per-point ``(GridPoint, summary)`` in grid order once
        every chunk has run; ``None`` if stopped early by
        ``max_chunks`` (state is checkpointed either way).
        """
        aggs: Dict[int, object] = {}
        cursor = 0
        if resume and self.ckpt_path is not None \
                and os.path.exists(self.ckpt_path):
            aggs, cursor = self._load()
        self._jsonl_rewind(cursor)
        executed = 0
        while cursor < len(self._schedule):
            if max_chunks is not None and executed >= max_chunks:
                self._save(aggs, cursor)
                return None
            point_idx, start, size = self._schedule[cursor]
            point = self._points[point_idx]
            agg = aggs.get(point_idx)
            skipped = agg is not None and engine_lib.point_converged(
                agg, self.spec.ci_target)
            if not skipped:
                if agg is None:
                    agg = engine_lib.aggregate_init(
                        federated.sim_length(point.fl))
                agg = self.engine.run_chunk(point, start, size, agg)
                aggs[point_idx] = agg
                # Skips are free — only real compute draws down the
                # caller's max_chunks budget.
                executed += 1
            cursor += 1
            self._jsonl_emit(cursor, point, start, size, agg, skipped)
            if cursor % self.checkpoint_every == 0 \
                    or cursor == len(self._schedule):
                self._save(aggs, cursor)
        out = [(self._points[i], engine_lib.aggregate_summary(aggs[i]))
               for i in sorted(aggs)]
        self._store_append(out)
        return out

    # -- cross-run metrics store -----------------------------------------

    def _store_append(self, results) -> None:
        """One store record per completed grid point (DESIGN.md §14).

        The Welford aggregate holds scenario-level moments only — no
        per-device arrays — so each record carries the scenario-mean
        scalars under the store's canonical names.  Fairness indices
        are absent; the gate treats a metric missing from *both* sides
        as not-measured, so sweep baselines compare cleanly against
        sweep currents.
        """
        if self.store_path is None:
            return
        for point, summary in results:
            def _mean(name: str) -> Optional[float]:
                st = summary.get(f"scalar.{name}")
                if st is None or float(st["count"]) <= 0:
                    return None
                v = float(st["mean"])
                return v if math.isfinite(v) else None

            metrics = {
                "final_acc": _mean("final_accuracy"),
                "rounds_to_target": _mean("rounds_to_target"),
                "total_energy_j": _mean("energy_total"),
                "energy_per_device_j": _mean("energy_per_device"),
            }
            store_lib.append_run(
                self.store_path, metrics, run=f"sweep/{point.name}",
                configs=(self.spec,),
                extra={"point": point.index,
                       "spec_fingerprint": self.spec.fingerprint()})


def run_sweep(spec: grid_lib.SweepSpec, *, data, loss_fn, eval_fn,
              init_params, ckpt_path: Optional[str] = None,
              target_accuracy: float = 0.85, use_sharding: bool = True,
              donate_params: bool = False, resume: bool = True,
              jsonl_path: Optional[str] = None,
              telemetry_dir: Optional[str] = None,
              store_path: Optional[str] = None):
    """One-call sweep: build the engine, optionally resume from
    ``ckpt_path``, optionally stream per-chunk aggregates to
    ``jsonl_path``, return per-point summaries.  ``telemetry_dir``
    collects per-scenario round-event JSONL streams for grid points
    whose ``FLConfig.telemetry`` is set (DESIGN.md §13);
    ``store_path`` appends one cross-run summary record per completed
    point to the metrics store (DESIGN.md §14)."""
    eng = engine_lib.SweepEngine(
        spec, data=data, loss_fn=loss_fn, eval_fn=eval_fn,
        init_params=init_params, target_accuracy=target_accuracy,
        use_sharding=use_sharding, donate_params=donate_params,
        telemetry_dir=telemetry_dir)
    if ckpt_path is None and jsonl_path is None and store_path is None:
        # engine.run_point honors spec.ci_target on its own, so the
        # runner layer is only needed for checkpoints/JSONL streaming
        # and store appends.
        return eng.run()
    return SweepRunner(eng, ckpt_path, jsonl_path=jsonl_path,
                       store_path=store_path).run(resume=resume)


__all__ = ["SweepRunner", "run_sweep", "STATE_VERSION"]
