"""Declarative scenario grids for Monte-Carlo sweeps (DESIGN.md §8).

A :class:`SweepSpec` names the base configs (:class:`FLConfig`,
:class:`SchedulerConfig`, :class:`WirelessConfig`) plus a tuple of
:class:`Axis` overrides; :meth:`SweepSpec.expand` takes the cartesian
product and yields one :class:`GridPoint` per combination.  Every grid
point runs ``scenarios_per_point`` Monte-Carlo scenarios, numbered by a
**global scenario index**: slot ``j`` of every point under common
random numbers (the default — paired comparisons on identical channel
draws), or the disjoint ``point.index * scenarios_per_point + j``
ranges when ``common_random_numbers=False``.

Seed derivation is the load-bearing contract: scenario ``i``'s PRNG
streams come from ``jax.random.fold_in(base, i)`` — the network
realization from ``fold_in(net_base, i)``
(``wireless.sample_networks_indexed``) and the simulation stream from
``fold_in(sim_base, i)`` (``federated.scenario_keys``) — so the random
trajectory of a scenario depends only on ``(SweepSpec.base_seed, i)``.
Chunk size, chunk order, device count and shard placement can all
change without perturbing a single scenario (``tests/test_sweep.py``
proves it), which is what makes resumable and re-sharded sweeps
meaningful Monte-Carlo estimates of the same population.

Config axes are *static*: each grid point compiles its own simulation
(method/epochs/model-bits all shape the traced program), while the
scenario axis inside a point is the vmapped/sharded one.  The
``stream`` target patches fields of ``fl.stream`` so data-quality
sweeps (arrival rate x staleness weight x process) ride the same grid.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Any, List, Tuple

from repro.core import federated, scheduler, wireless

# Axis targets -> which base config the field override applies to.
TARGETS = ("fl", "sched", "wireless", "stream", "comp", "fault", "async")


@dataclasses.dataclass(frozen=True)
class Axis:
    """One swept dimension: ``target.field`` ranging over ``values``."""

    target: str    # fl | sched | wireless | stream | comp | fault | async
    field: str
    values: Tuple[Any, ...]

    def __post_init__(self):
        if self.target not in TARGETS:
            raise ValueError(f"unknown axis target {self.target!r}; "
                             f"expected one of {TARGETS}")
        if not self.values:
            raise ValueError(f"axis {self.target}.{self.field}: empty "
                             f"value tuple")


@dataclasses.dataclass(frozen=True)
class GridPoint:
    """One fully-resolved configuration of the sweep grid."""

    index: int                      # row-major position in the grid
    name: str                       # "method=das,n_fixed=3" ("base" if no axes)
    fl: federated.FLConfig
    sched: scheduler.SchedulerConfig
    wireless: wireless.WirelessConfig
    overrides: Tuple[Tuple[str, str, Any], ...]  # (target, field, value)


def _check_field(cfg: Any, target: str, field: str) -> None:
    names = {f.name for f in dataclasses.fields(cfg)}
    if field not in names:
        raise ValueError(f"axis {target}.{field}: {type(cfg).__name__} "
                         f"has no field {field!r}")


def _apply(fl: federated.FLConfig, sched: scheduler.SchedulerConfig,
           wcfg: wireless.WirelessConfig,
           overrides: Tuple[Tuple[str, str, Any], ...]):
    for target, field, value in overrides:
        if target == "fl":
            _check_field(fl, target, field)
            fl = dataclasses.replace(fl, **{field: value})
        elif target == "sched":
            _check_field(sched, target, field)
            sched = dataclasses.replace(sched, **{field: value})
        elif target == "wireless":
            _check_field(wcfg, target, field)
            wcfg = dataclasses.replace(wcfg, **{field: value})
        elif target == "stream":
            if fl.stream is None:
                raise ValueError(
                    f"axis stream.{field}: base FLConfig.stream is None "
                    f"(set a StreamConfig to sweep streaming knobs)")
            _check_field(fl.stream, target, field)
            fl = dataclasses.replace(
                fl, stream=dataclasses.replace(fl.stream, **{field: value}))
        elif target == "comp":
            if fl.compression is None:
                raise ValueError(
                    f"axis comp.{field}: base FLConfig.compression is "
                    f"None (set a CompressionConfig to sweep codec "
                    f"knobs)")
            _check_field(fl.compression, target, field)
            fl = dataclasses.replace(
                fl, compression=dataclasses.replace(fl.compression,
                                                    **{field: value}))
        elif target == "fault":
            if fl.faults is None:
                raise ValueError(
                    f"axis fault.{field}: base FLConfig.faults is None "
                    f"(set a FaultConfig to sweep unreliable-edge "
                    f"knobs)")
            _check_field(fl.faults, target, field)
            fl = dataclasses.replace(
                fl, faults=dataclasses.replace(fl.faults,
                                               **{field: value}))
        else:  # async
            if fl.events is None:
                raise ValueError(
                    f"axis async.{field}: base FLConfig.events is None "
                    f"(set an EventConfig to sweep event-scan knobs; "
                    f"for sync-vs-async itself use "
                    f"Axis(target='fl', field='events', "
                    f"values=(None, EventConfig(...))))")
            _check_field(fl.events, target, field)
            fl = dataclasses.replace(
                fl, events=dataclasses.replace(fl.events,
                                               **{field: value}))
    return fl, sched, wcfg


def _fmt(value: Any) -> str:
    if isinstance(value, float):
        return f"{value:g}"
    return str(value)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A Monte-Carlo sweep: config grid x scenarios, chunked for execution.

    ``chunk_scenarios`` bounds how many scenarios run per compiled
    dispatch (0 = all of a point's scenarios in one chunk); the engine
    shards each chunk's scenario axis over the mesh.  Chunking is an
    execution detail — per-scenario streams are chunk-invariant by the
    fold_in contract — but it *is* part of the resume schedule, so it
    joins :meth:`fingerprint`.

    ``ci_target > 0`` enables adaptive per-grid-point scenario counts:
    once a point's final-accuracy 95% CI half-width (from the Welford
    carry the engine already maintains) drops to ``ci_target`` or
    below, the point's remaining chunks are skipped — tight points stop
    early, noisy points spend the full budget.  Deterministic given the
    folded chunks, so resumes stay reproducible; the executed scenario
    set is data-dependent, which is exactly the feature.  It joins the
    fingerprint (it shapes the effective schedule).
    """

    fl: federated.FLConfig = federated.FLConfig()
    sched: scheduler.SchedulerConfig = scheduler.SchedulerConfig()
    wireless: wireless.WirelessConfig = wireless.WirelessConfig()
    axes: Tuple[Axis, ...] = ()
    scenarios_per_point: int = 4
    chunk_scenarios: int = 0        # 0 -> one chunk per grid point
    base_seed: int = 0
    eval_every: int = 1
    ci_target: float = 0.0          # 0 -> fixed scenario counts
    # Common random numbers (True, the default): every grid point runs
    # the SAME scenario indices 0..S-1, i.e. identical channel/PRNG
    # realizations — paired comparisons across config points (DAS vs
    # random on the same fading draws), the classic Monte-Carlo variance
    # reduction the paper figures rely on.  False gives each point its
    # own disjoint index range — independent populations.
    common_random_numbers: bool = True

    # -- grid expansion -------------------------------------------------

    def expand(self) -> List[GridPoint]:
        points: List[GridPoint] = []
        combos = itertools.product(*[ax.values for ax in self.axes]) \
            if self.axes else [()]
        for index, combo in enumerate(combos):
            overrides = tuple(
                (ax.target, ax.field, v)
                for ax, v in zip(self.axes, combo))
            fl, sched, wcfg = _apply(self.fl, self.sched, self.wireless,
                                     overrides)
            name = ",".join(f"{f}={_fmt(v)}" for _, f, v in overrides) \
                or "base"
            points.append(GridPoint(index=index, name=name, fl=fl,
                                    sched=sched, wireless=wcfg,
                                    overrides=overrides))
        return points

    @property
    def num_points(self) -> int:
        n = 1
        for ax in self.axes:
            n *= len(ax.values)
        return n

    @property
    def total_scenarios(self) -> int:
        return self.num_points * self.scenarios_per_point

    # -- execution schedule ---------------------------------------------

    def scenario_start(self, point_index: int) -> int:
        """Global index of the first scenario of a grid point (0 for
        every point under common random numbers)."""
        if self.common_random_numbers:
            return 0
        return point_index * self.scenarios_per_point

    def point_chunks(self) -> List[Tuple[int, int]]:
        """(offset within point, size) chunk schedule, same for every
        point.  The Welford fold visits chunks in this order, so the
        schedule is part of the resume contract."""
        size = self.chunk_scenarios or self.scenarios_per_point
        out = []
        off = 0
        while off < self.scenarios_per_point:
            out.append((off, min(size, self.scenarios_per_point - off)))
            off += size
        return out

    def schedule(self) -> List[Tuple[int, int, int]]:
        """Flat (point_index, global_start, size) chunk list — the unit
        of work the runner checkpoints between."""
        out = []
        for p in range(self.num_points):
            base = self.scenario_start(p)
            for off, size in self.point_chunks():
                out.append((p, base + off, size))
        return out

    # -- identity --------------------------------------------------------

    def fingerprint(self) -> str:
        """Stable digest of everything that shapes results *and* the
        chunk/fold schedule; a resume checkpoint with a different
        fingerprint is rejected (``repro.sweep.runner``)."""
        canon = repr((self.fl, self.sched, self.wireless, self.axes,
                      self.scenarios_per_point, self.chunk_scenarios,
                      self.base_seed, self.eval_every,
                      self.common_random_numbers, self.ci_target))
        return hashlib.sha1(canon.encode()).hexdigest()


__all__ = ["Axis", "GridPoint", "SweepSpec", "TARGETS"]
