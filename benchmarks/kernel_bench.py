"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs jnp reference.

On this container the numbers measure the *reference* path plus the
interpreted kernel (functional check); on a TPU backend the same harness
times the compiled kernels (interpret=False).
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, iters: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = True) -> List[Tuple[str, float, str]]:
    rows = []
    key = jax.random.key(0)

    k, p = 64, 65536 if not quick else 16384
    u = jax.random.normal(key, (k, p), jnp.float32)
    w = jax.nn.softmax(jax.random.normal(key, (k,)))
    ref_fn = jax.jit(ref.fedavg_agg)
    rows.append(("kernel/fedavg_agg/ref_jnp", round(_time(ref_fn, u, w), 1),
                 f"K={k} P={p}"))
    rows.append(("kernel/fedavg_agg/pallas_interpret",
                 round(_time(lambda a, b: ops.fedavg_agg(a, b), u, w), 1),
                 "interpret=True on CPU"))

    labels = jax.random.randint(key, (32, 1024), 0, 10)
    mask = jnp.ones((32, 1024), jnp.float32)
    rows.append(("kernel/diversity/ref_jnp",
                 round(_time(jax.jit(lambda l, m: ref.diversity(l, m, 10)),
                             labels, mask), 1), "K=32 N=1024"))
    rows.append(("kernel/diversity/pallas_interpret",
                 round(_time(lambda l, m: ops.diversity_stats(l, m, 10),
                             labels, mask), 1), ""))

    s = 512 if quick else 2048
    q = jax.random.normal(key, (1, s, 4, 64), jnp.bfloat16)
    kv = jax.random.normal(key, (1, s, 2, 64), jnp.bfloat16)

    def ref_attn(q_, k_, v_):
        kk = jnp.repeat(k_, 2, axis=2)
        vv = jnp.repeat(v_, 2, axis=2)
        flat = lambda x: x.transpose(0, 2, 1, 3).reshape(4, s, 64)
        return ref.flash_attention(flat(q_), flat(kk), flat(vv))

    rows.append(("kernel/flash_attention/ref_jnp",
                 round(_time(jax.jit(ref_attn), q, kv, kv), 1),
                 f"S={s} causal"))
    rows.append(("kernel/flash_attention/pallas_interpret",
                 round(_time(lambda a, b, c: ops.flash_attention(a, b, c),
                             q, kv, kv), 1), ""))
    return rows
