"""Paper-figure reproductions (one function per table/figure family).

Fig. 2  — accuracy vs rounds, limited device counts (3/5/7), DAS vs random
Fig. 3/8/9 — local epochs E in {1,2,3}, DAS vs random (+ baseline)
Fig. 4/5 — model-size sweep: rounds to goal accuracy, DAS vs ABS vs full
Fig. 6/7/10/11 — energy/device + completion time at goal accuracy

Every figure family runs through the sharded Monte-Carlo sweep engine
(``repro.sweep``, DESIGN.md §8): the figure's configuration dimensions
(device budget, local epochs, model size, method) are declarative
``Axis`` entries of ONE ``SweepSpec``, each grid point averages
``num_scenarios`` channel/PRNG realizations executed in shard_map'd
chunks, and only the O(R) Welford aggregates (per-round and
final-scalar mean/var/min/max) ever reach the host — figure memory is
independent of how many scenarios run (``num_scenarios=0`` picks 2/4
for quick/full).

Each function returns CSV rows: (name, value, derived-notes).
The claims validated per row are annotated in EXPERIMENTS.md §Repro.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from benchmarks import common
from repro.sweep import grid as sweep_grid

Row = Tuple[str, float, str]

Axis = sweep_grid.Axis


def _scenario_count(num_scenarios: int, quick: bool) -> int:
    return num_scenarios or (2 if quick else 4)


def _final_acc(summary) -> Dict[str, float]:
    s = summary["scalar.final_accuracy"]
    return {"mean": float(s["mean"]), "min": float(s["min"]),
            "max": float(s["max"])}


def fig2_limited_devices(quick: bool = True, model: str = "mlp",
                         num_scenarios: int = 0) -> List[Row]:
    """Accuracy vs limited device counts, averaged over Monte-Carlo
    scenarios via the sweep engine: one (n_fixed x method) grid, each
    point S sharded scenarios folded to O(R) aggregates.
    """
    scenarios = _scenario_count(num_scenarios, quick)
    results = common.run_fl_sweep(
        common.FLBenchConfig(quick=quick, model=model),
        scenarios,
        axes=[Axis("sched", "n_fixed", (3, 5, 7)),
              Axis("sched", "method", ("das", "random"))])
    rows: List[Row] = []
    accs: Dict[Tuple[int, str], float] = {}
    rounds = 0
    for point, summary in results:
        f = _final_acc(summary)
        n, method = point.sched.n_fixed, point.sched.method
        accs[(n, method)] = f["mean"]
        rounds = summary["round.accuracy"]["mean"].shape[0]
        rows.append((f"fig2/{model}/n{n}/{method}/final_acc",
                     round(f["mean"], 4),
                     f"rounds={rounds} S={scenarios} "
                     f"min={f['min']:.3f} max={f['max']:.3f}"))
    for n in (3, 5, 7):
        rows.append((f"fig2/{model}/n{n}/das_minus_random",
                     round(accs[(n, "das")] - accs[(n, "random")], 4),
                     "paper: DAS >= random, gap largest at small n"))
    return rows


def fig3_local_epochs(quick: bool = True, model: str = "mlp",
                      num_scenarios: int = 0) -> List[Row]:
    scenarios = _scenario_count(num_scenarios, quick)
    results = common.run_fl_sweep(
        common.FLBenchConfig(quick=quick, model=model, n_fixed=7),
        scenarios,
        axes=[Axis("fl", "local_epochs", (1, 2, 3)),
              Axis("sched", "method", ("das", "random"))])
    rows: List[Row] = []
    for point, summary in results:
        f = _final_acc(summary)
        rows.append((f"fig3/{model}/E{point.fl.local_epochs}/"
                     f"{point.sched.method}/final_acc",
                     round(f["mean"], 4),
                     f"S={scenarios} min={f['min']:.3f} "
                     f"max={f['max']:.3f}; paper: more E -> "
                     f"higher acc; DAS >= random"))
    return rows


def fig45_model_size(quick: bool = True, model: str = "mlp",
                     target: float = 0.85,
                     num_scenarios: int = 0) -> List[Row]:
    scenarios = _scenario_count(num_scenarios, quick)
    results = common.run_fl_sweep(
        common.FLBenchConfig(quick=quick, model=model),
        scenarios,
        axes=[Axis("wireless", "model_bits", (1e5, 5e5, 1e6)),
              Axis("sched", "method", ("das", "abs", "full"))],
        target=target)
    rows: List[Row] = []
    for point, summary in results:
        r2t = summary["scalar.rounds_to_target"]
        reached = int(r2t["count"])
        r_mean = round(float(r2t["mean"]), 2) if reached else -1
        s_bits = point.wireless.model_bits
        rows.append((f"fig45/{model}/s{int(s_bits)}/{point.sched.method}/"
                     f"rounds_to_{target}", r_mean,
                     f"S={scenarios} reached={reached}/{scenarios} "
                     f"final="
                     f"{float(summary['scalar.final_accuracy']['mean']):.3f} "
                     f"sel="
                     f"{float(summary['scalar.mean_selected']['mean']):.1f}"))
    return rows


def fig67_energy_time(quick: bool = True, model: str = "mlp",
                      num_scenarios: int = 0) -> List[Row]:
    scenarios = _scenario_count(num_scenarios, quick)
    results = common.run_fl_sweep(
        common.FLBenchConfig(quick=quick, model=model),
        scenarios,
        axes=[Axis("sched", "method", ("full", "abs", "das"))])
    rows: List[Row] = []
    ref_energy = None
    for point, summary in results:
        method = point.sched.method
        energy = float(summary["scalar.energy_per_device"]["mean"])
        rows.append((f"fig67/{model}/{method}/energy_per_device_j",
                     round(energy, 4),
                     f"S={scenarios} acc="
                     f"{float(summary['scalar.final_accuracy']['mean']):.3f}"))
        rows.append((f"fig67/{model}/{method}/completion_time_s",
                     round(float(summary["scalar.time_total"]["mean"]), 4),
                     f"sel/round="
                     f"{float(summary['scalar.mean_selected']['mean']):.1f}"))
        if method == "full":
            ref_energy = energy
        else:
            gain = 1.0 - energy / max(ref_energy, 1e-12)
            rows.append((f"fig67/{model}/{method}/energy_gain_vs_baseline",
                         round(gain, 4),
                         "paper: ~69-85% (ABS) / 79-97% (DAS)"))
    return rows


def selection_fraction_sweep(quick: bool = True,
                             num_scenarios: int = 0) -> List[Row]:
    """Repro-divergence probe: DAS selected fraction vs model size and
    re-entry pricing (EXPERIMENTS.md §Repro-divergences), as a
    (model_bits x reentry) grid through the sweep engine."""
    scenarios = _scenario_count(num_scenarios, quick)
    cfg = common.FLBenchConfig(quick=quick, model="mlp", method="das",
                               num_rounds=3)
    results = common.run_fl_sweep(
        cfg, scenarios,
        axes=[Axis("wireless", "model_bits", (1e5, 1e6)),
              Axis("sched", "reentry", ("strict", "mean"))])
    rows: List[Row] = []
    for point, summary in results:
        frac = (float(summary["scalar.mean_selected"]["mean"])
                / cfg.num_devices)
        rows.append((f"divergence/das_fraction/"
                     f"s{int(point.wireless.model_bits)}/"
                     f"{point.sched.reentry}", round(frac, 3),
                     f"S={scenarios}; paper claims <=0.20 "
                     f"(under-determined)"))
    return rows
