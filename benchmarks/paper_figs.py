"""Paper-figure reproductions (one function per table/figure family).

Fig. 2  — accuracy vs rounds, limited device counts (3/5/7), DAS vs random
Fig. 3/8/9 — local epochs E in {1,2,3}, DAS vs random (+ baseline)
Fig. 4/5 — model-size sweep: rounds to goal accuracy, DAS vs ABS vs full
Fig. 6/7/10/11 — energy/device + completion time at goal accuracy

Every figure family is scenario-averaged through the vmapped batch
driver (``federated.run_federated_batch``) — the paper averages over
channel realizations, and the batch driver runs the S Monte-Carlo
scenarios as one compiled program (``num_scenarios=0`` picks 2/4 for
quick/full).

Each function returns CSV rows: (name, value, derived-notes).
The claims validated per row are annotated in EXPERIMENTS.md §Repro.
"""

from __future__ import annotations

import time
from typing import List, Tuple

from benchmarks import common

Row = Tuple[str, float, str]


def _mean(xs) -> float:
    xs = list(xs)
    return sum(xs) / max(len(xs), 1)


def _scenario_count(num_scenarios: int, quick: bool) -> int:
    return num_scenarios or (2 if quick else 4)


def fig2_limited_devices(quick: bool = True, model: str = "mlp",
                         num_scenarios: int = 0) -> List[Row]:
    """Accuracy vs limited device counts, averaged over Monte-Carlo
    scenarios via the vmapped batch driver (paper Fig. 2 averages over
    channel realizations; ``num_scenarios=0`` picks 2/4 for quick/full).
    """
    scenarios = _scenario_count(num_scenarios, quick)
    rows: List[Row] = []
    for n in (3, 5, 7):
        accs = {}
        for method in ("das", "random"):
            hists = common.run_fl_batch(
                common.FLBenchConfig(quick=quick, model=model,
                                     method=method, n_fixed=n),
                scenarios)
            finals = [h[-1].accuracy for h in hists]
            accs[method] = _mean(finals)
            rows.append((f"fig2/{model}/n{n}/{method}/final_acc",
                         round(accs[method], 4),
                         f"rounds={len(hists[0])} S={scenarios} "
                         f"min={min(finals):.3f} max={max(finals):.3f}"))
        rows.append((f"fig2/{model}/n{n}/das_minus_random",
                     round(accs["das"] - accs["random"], 4),
                     "paper: DAS >= random, gap largest at small n"))
    return rows


def fig3_local_epochs(quick: bool = True, model: str = "mlp",
                      num_scenarios: int = 0) -> List[Row]:
    scenarios = _scenario_count(num_scenarios, quick)
    rows: List[Row] = []
    for epochs in (1, 2, 3):
        for method in ("das", "random"):
            hists = common.run_fl_batch(common.FLBenchConfig(
                quick=quick, model=model, method=method, n_fixed=7,
                local_epochs=epochs), scenarios)
            finals = [h[-1].accuracy for h in hists]
            rows.append((f"fig3/{model}/E{epochs}/{method}/final_acc",
                         round(_mean(finals), 4),
                         f"S={scenarios} min={min(finals):.3f} "
                         f"max={max(finals):.3f}; paper: more E -> "
                         f"higher acc; DAS >= random"))
    return rows


def fig45_model_size(quick: bool = True, model: str = "mlp",
                     target: float = 0.85,
                     num_scenarios: int = 0) -> List[Row]:
    scenarios = _scenario_count(num_scenarios, quick)
    rows: List[Row] = []
    for s_bits in (1e5, 5e5, 1e6):
        for method in ("das", "abs", "full"):
            hists = common.run_fl_batch(common.FLBenchConfig(
                quick=quick, model=model, method=method,
                model_bits=s_bits), scenarios)
            reached = [common.rounds_to_accuracy(h, target) for h in hists]
            hit = [r for r in reached if r > 0]
            r_mean = round(_mean(hit), 2) if hit else -1
            tot = [common.totals(h) for h in hists]
            rows.append((f"fig45/{model}/s{int(s_bits)}/{method}/"
                         f"rounds_to_{target}", r_mean,
                         f"S={scenarios} reached={len(hit)}/{scenarios} "
                         f"final={_mean(t['final_accuracy'] for t in tot):.3f} "
                         f"sel={_mean(t['mean_selected'] for t in tot):.1f}"))
    return rows


def fig67_energy_time(quick: bool = True, model: str = "mlp",
                      num_scenarios: int = 0) -> List[Row]:
    scenarios = _scenario_count(num_scenarios, quick)
    rows: List[Row] = []
    ref_energy = None
    for method in ("full", "abs", "das"):
        hists = common.run_fl_batch(common.FLBenchConfig(
            quick=quick, model=model, method=method), scenarios)
        tot = [common.totals(h) for h in hists]
        energy = _mean(t["energy_per_device_j"] for t in tot)
        rows.append((f"fig67/{model}/{method}/energy_per_device_j",
                     round(energy, 4),
                     f"S={scenarios} "
                     f"acc={_mean(t['final_accuracy'] for t in tot):.3f}"))
        rows.append((f"fig67/{model}/{method}/completion_time_s",
                     round(_mean(t["time_total_s"] for t in tot), 4),
                     f"sel/round="
                     f"{_mean(t['mean_selected'] for t in tot):.1f}"))
        if method == "full":
            ref_energy = energy
        else:
            gain = 1.0 - energy / max(ref_energy, 1e-12)
            rows.append((f"fig67/{model}/{method}/energy_gain_vs_baseline",
                         round(gain, 4),
                         "paper: ~69-85% (ABS) / 79-97% (DAS)"))
    return rows


def selection_fraction_sweep(quick: bool = True) -> List[Row]:
    """Repro-divergence probe: DAS selected fraction vs model size
    (EXPERIMENTS.md §Repro-divergences)."""
    rows: List[Row] = []
    for s_bits in (1e5, 1e6):
        for reentry in ("strict", "mean"):
            hist = common.run_fl(common.FLBenchConfig(
                quick=quick, model="mlp", method="das",
                model_bits=s_bits, num_rounds=3, reentry=reentry))
            frac = (sum(r.n_selected for r in hist) / len(hist)
                    / common.FLBenchConfig(quick=quick).num_devices)
            rows.append((f"divergence/das_fraction/s{int(s_bits)}/"
                         f"{reentry}", round(frac, 3),
                         "paper claims <=0.20 (under-determined)"))
    return rows
