"""Scheduler micro-benchmark: jitted DAS/ABS/random decision latency vs K.

Systems-level table (no paper analogue): the per-round scheduling cost a
MEC server (or pod controller) pays.  DAS = iterative Sub1/Sub2 through
the registered allocator; everything jit-compiled once per K.

The ``alloc/*`` rows isolate a single Sub2 solve per allocator stage:
``nested_bisect`` (the pre-refactor reference deadline solve),
``fused_bisect`` (joint bisection + Newton carry), ``pgd`` (tangent PGD
on top of the fused bisection) and ``fused_pgd`` (the Pallas kernel —
interpret mode off-TPU, so its CPU number measures the interpreter, not
the fused launch; see EXPERIMENTS.md §Perf).

The ``streaming/*`` rows measure the per-round data-refresh cost the
streaming subsystem adds to every round (DESIGN.md §7): the fused
count-delta -> diversity -> staleness pass, pure-jax reference vs the
Pallas ``stream_update`` kernel, single scenario and the batched
``(S, K, C)`` lane.

The ``compress/*`` rows measure the per-round fused uplink-compression
cost the compressed-uplink subsystem adds (DESIGN.md §9): the
residual-accumulate -> quantize/top-k -> dequantize pass over the
``(K, P)`` update matrix, pure-jax reference vs the Pallas
``compress_update`` kernel, single scenario and the batched
``(S, K, P)`` lane.

The ``faults/*`` rows measure what the unreliable-edge subsystem
(DESIGN.md §10) adds to a full scan-driver round: an outage-heavy
profile (Bernoulli drops + bounded retries + reliability-EMA
scheduling) and a straggler-heavy profile (heavy-tailed compute
multipliers + dropouts), each a miniature FEEL run reported as
ms/round.

The ``async/*`` rows price the event-driven asynchronous driver
(DESIGN.md §12): the synchronous scan baseline, the event scan in its
synchronous limit (what the availability/pending-buffer machinery
costs when inert — the bitwise-parity configuration) and full buffered
async mode under diurnal churn, each as ms per scan step.

The ``telemetry/*`` rows price the in-scan telemetry subsystem
(DESIGN.md §13): the scan driver with ``telemetry=None`` (the inert
dispatch — today's program bitwise) vs the full frame set threaded
through the same scan, reported as ms/round plus their ratio (the
acceptance target is <1.10 steady-state).

The ``sweep/*`` rows cover the Monte-Carlo sweep engine (DESIGN.md §8):
the jitted Welford chunk-fold (the O(R) aggregation every chunk pays)
and one engine chunk execution on a miniature FEEL world, shard_map'd
over the present devices vs the plain vmap program — plus
``chunk_compressed`` / ``chunk_faulty`` rows running the same chunk
with a ``quant`` codec grid point and a fault-injected grid point (the
CI compressed/faulty sweep smokes).  Under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the CI sweep
smoke) the sharded rows exercise the real multi-device partitioning.
"""

from __future__ import annotations

import functools
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core import allocator as alloc_lib
from repro.core import bandwidth as bw
from repro.core import diversity, scheduler, wireless
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref


def bench(method: str, k: int, iters: int = 5) -> float:
    wcfg = wireless.WirelessConfig()
    net = wireless.sample_network(jax.random.key(0), k, wcfg)
    gains = wireless.sample_fading(jax.random.key(1), net)
    sizes = jax.random.randint(jax.random.key(2), (k,), 50, 1500)
    hists = jax.random.randint(jax.random.key(3), (k, 10), 0,
                               30).astype(jnp.float32)
    ages = jnp.zeros((k,), jnp.int32)
    idx = diversity.diversity_index(label_hists=hists, data_sizes=sizes,
                                    ages=ages)
    sch = scheduler.SchedulerConfig(method=method, n_min=1,
                                    iterations_max=6)
    res = scheduler.schedule(jax.random.key(4), idx, ages, sizes, gains,
                             net, wcfg, sch)
    jax.block_until_ready(res.alpha)      # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        res = scheduler.schedule(jax.random.key(4), idx, ages, sizes,
                                 gains, net, wcfg, sch)
        jax.block_until_ready(res.alpha)
    return (time.perf_counter() - t0) / iters * 1e6


def _alloc_instance(k: int):
    wcfg = wireless.WirelessConfig()
    net = wireless.sample_network(jax.random.key(0), k, wcfg)
    gains = wireless.sample_fading(jax.random.key(1), net)
    sizes = jax.random.randint(jax.random.key(2), (k,), 50, 1500)
    t_train = wireless.train_time(sizes, net, wcfg)
    sel = (jax.random.uniform(jax.random.key(3), (k,)) > 0.5
           ).astype(jnp.float32).at[0].set(1.0)
    return wcfg, net, gains, t_train, sel


def bench_alloc(stage: str, k: int, iters: int = 20) -> float:
    """Latency of ONE Sub2 solve for the given allocator stage (us)."""
    wcfg, net, gains, t_train, sel = _alloc_instance(k)
    params = bw.Sub2Params()
    if stage == "nested_bisect":
        fn = jax.jit(lambda s, t, g, p: bw.min_time_allocation_reference(
            s, t, g, p, wcfg, params))
    elif stage == "fused_bisect":
        fn = jax.jit(lambda s, t, g, p: bw.min_time_allocation(
            s, t, g, p, wcfg, params))
    else:
        alloc = alloc_lib.get(stage, params)
        fn = jax.jit(lambda s, t, g, p: alloc.solve(s, t, g, p, wcfg))
    args = (sel, t_train, gains, net.tx_power)
    jax.block_until_ready(fn(*args))      # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_stream(path: str, k: int, c: int = 10, s: int = 1,
                 iters: int = 50) -> float:
    """Latency of ONE fused streaming refresh (us): count-delta
    accumulation -> diversity stats -> staleness decay, for one round's
    ``(S, K, C)`` state."""
    shape = (s, k, c) if s > 1 else (k, c)
    hists = jax.random.uniform(jax.random.key(0), shape, minval=0.0,
                               maxval=60.0)
    deltas = jax.random.uniform(jax.random.key(1), shape, minval=-4.0,
                                maxval=10.0)
    arrivals = jnp.sum(jnp.maximum(deltas, 0.0), axis=-1)
    stale = jnp.zeros(shape[:-1])
    sel = jnp.zeros(shape[:-1])
    if path == "ref":
        fn = jax.jit(functools.partial(kernel_ref.stream_update,
                                       decay=0.8, size_cap=0.0))
    else:
        fn = jax.jit(functools.partial(kernel_ops.stream_update,
                                       decay=0.8, size_cap=0.0))
    args = (hists, deltas, arrivals, stale, sel)
    jax.block_until_ready(fn(*args))      # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_compress(path: str, k: int, p: int = 4096, s: int = 1,
                   mode: str = "quant", iters: int = 20) -> float:
    """Latency of ONE fused compress pass (us): residual accumulate ->
    quantize/top-k -> dequantize over one round's ``(S, K, P)`` update
    matrix."""
    shape = (s, k, p) if s > 1 else (k, p)
    u = jax.random.normal(jax.random.key(0), shape)
    r = 0.2 * jax.random.normal(jax.random.key(1), shape)
    widths = jnp.full(shape[:-1], 8.0)
    sel = (jax.random.uniform(jax.random.key(2), shape[:-1]) > 0.5
           ).astype(jnp.float32)
    noise = jax.random.uniform(jax.random.key(3), shape)
    keep = max(1, p // 20)
    if path == "ref":
        fn = jax.jit(functools.partial(kernel_ref.compress_update,
                                       mode=mode, keep=keep))
    else:
        fn = jax.jit(functools.partial(kernel_ops.compress_update,
                                       mode=mode, keep=keep))
    args = (u, r, widths, sel, noise)
    jax.block_until_ready(fn(*args))      # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_faults(profile: str, k: int = 16, rounds: int = 4,
                 iters: int = 3) -> float:
    """ms per round of the full scan driver under a fault profile.

    ``outage`` = Bernoulli drops + bounded retries + reliability-EMA
    discounting (the retransmission machinery on the hot path);
    ``straggler`` = heavy-tailed compute multipliers + mid-round
    dropouts.  Measures the steady-state per-round cost the fault
    subsystem adds to the one-jit simulation (DESIGN.md §10).
    """
    import functools as _ft

    from repro.core import faults as faults_lib
    from repro.core import federated
    from repro.data import partition, synthetic
    from repro.models import paper_nets

    imgs, labs = synthetic.generate(0, samples_per_class=260)
    data = partition.partition(
        imgs, labs, seed=1,
        spec=partition.PartitionSpec(num_devices=k, num_shards=50,
                                     shard_size=50))
    mspec = paper_nets.PaperNetSpec(kind="mlp", mlp_hidden=16)
    params = paper_nets.init(jax.random.key(3), mspec)
    if profile == "outage":
        flt = faults_lib.FaultConfig(drop_prob=0.3, max_retries=2,
                                     reliability_ema=0.3, overprovision=1)
    else:
        flt = faults_lib.FaultConfig(straggler_prob=0.3,
                                     straggler_scale=4.0,
                                     dropout_prob=0.05)
    fcfg = federated.FLConfig(num_rounds=rounds, batch_size=50,
                              learning_rate=0.1, faults=flt)
    scfg = scheduler.SchedulerConfig(method="das", n_min=2,
                                     iterations_max=3)
    wcfg = wireless.WirelessConfig()
    net = wireless.sample_network(jax.random.key(0), k, wcfg)
    loss = _ft.partial(paper_nets.loss_fn, spec=mspec)
    ev = _ft.partial(paper_nets.accuracy, spec=mspec)
    sim = federated.make_feel_sim(loss_fn=loss, eval_fn=ev, wcfg=wcfg,
                                  scfg=scfg, fcfg=fcfg,
                                  capacity=data.capacity)
    hists = federated.client_histograms(data, fcfg.num_classes)
    test_x = synthetic.to_float(data.test_images)
    args = (params, data.images, data.labels, data.mask, data.sizes,
            hists, test_x, data.test_labels, net, jax.random.key(7))
    out = sim(*args)
    jax.block_until_ready(out[0])     # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = sim(*args)
        jax.block_until_ready(out[0])
    return (time.perf_counter() - t0) / iters / rounds * 1e3


def bench_events(profile: str, k: int = 16, rounds: int = 4,
                 iters: int = 3) -> float:
    """ms per event of the event-scan driver (DESIGN.md §12).

    ``sync`` = the synchronous scan driver baseline on the same world;
    ``sync_limit`` = the event driver in its synchronous limit (default
    ``EventConfig()`` — the bitwise-parity configuration), so the pair
    prices what the availability/pending-buffer machinery costs when it
    is doing nothing; ``diurnal`` = full async mode (correlated
    day/night churn, buffer_size 2, staleness discount, short tick
    horizon) — the steady-state per-event cost of buffered asynchronous
    aggregation.
    """
    import functools as _ft

    from repro.core import events as events_lib
    from repro.core import faults as faults_lib
    from repro.core import federated
    from repro.data import partition, synthetic
    from repro.models import paper_nets

    imgs, labs = synthetic.generate(0, samples_per_class=260)
    data = partition.partition(
        imgs, labs, seed=1,
        spec=partition.PartitionSpec(num_devices=k, num_shards=50,
                                     shard_size=50))
    mspec = paper_nets.PaperNetSpec(kind="mlp", mlp_hidden=16)
    params = paper_nets.init(jax.random.key(3), mspec)
    if profile == "sync":
        ecfg = None
    elif profile == "sync_limit":
        ecfg = events_lib.EventConfig()
    else:                                   # diurnal async
        ecfg = events_lib.EventConfig(
            availability="diurnal", duty=0.6, buffer_size=2,
            staleness_decay=0.5, tick_horizon=0.05, num_events=rounds)
    fcfg = federated.FLConfig(
        num_rounds=rounds, batch_size=50, learning_rate=0.1,
        faults=faults_lib.FaultConfig(reliability_ema=0.3), events=ecfg)
    scfg = scheduler.SchedulerConfig(method="das", n_min=2,
                                     iterations_max=3)
    wcfg = wireless.WirelessConfig()
    net = wireless.sample_network(jax.random.key(0), k, wcfg)
    loss = _ft.partial(paper_nets.loss_fn, spec=mspec)
    ev = _ft.partial(paper_nets.accuracy, spec=mspec)
    sim = federated.make_feel_sim(loss_fn=loss, eval_fn=ev, wcfg=wcfg,
                                  scfg=scfg, fcfg=fcfg,
                                  capacity=data.capacity)
    hists = federated.client_histograms(data, fcfg.num_classes)
    test_x = synthetic.to_float(data.test_images)
    args = (params, data.images, data.labels, data.mask, data.sizes,
            hists, test_x, data.test_labels, net, jax.random.key(7))
    out = sim(*args)
    jax.block_until_ready(out[0])     # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = sim(*args)
        jax.block_until_ready(out[0])
    n = federated.sim_length(fcfg)
    return (time.perf_counter() - t0) / iters / n * 1e3


def async_rows(quick: bool = True) -> List[Tuple[str, float, str]]:
    """The ``async/*`` rows: event-driver cost vs the sync scan (the CI
    event-driver smoke runs exactly these under 4 forced host devices)."""
    del quick                         # miniature either way
    rows = []
    ms_sync = bench_events("sync")
    ms_limit = bench_events("sync_limit")
    ms_async = bench_events("diurnal")
    rows.append(("async/sync/K16", round(ms_sync, 2),
                 "ms_per_round sync scan_driver baseline"))
    rows.append(("async/sync_limit/K16", round(ms_limit, 2),
                 "ms_per_event event driver, parity config"))
    rows.append(("async/diurnal/K16", round(ms_async, 2),
                 "ms_per_event buffered staleness-weighted"))
    rows.append(("async/overhead/K16",
                 round(ms_limit / max(ms_sync, 1e-9), 2),
                 "event sync-limit / sync scan per-round"))
    return rows


def bench_telemetry(enabled: bool, k: int = 100, rounds: int = 4,
                    iters: int = 3, log_path: str = None,
                    store_path: str = None) -> float:
    """ms per round of the scan driver with the telemetry frames on/off.

    ``enabled=False`` is today's program (``telemetry=None`` — the
    bitwise-inert dispatch); ``enabled=True`` threads the full frame
    set (scores + Sub2 trace + transport + faults) through the scan
    (DESIGN.md §13).  The pair prices the in-scan observability tax on
    a K-device round body; the acceptance target is <10% steady-state.
    ``log_path`` additionally sinks the enabled run's frames as a JSONL
    round-event log (the CI report smoke reads it back).
    """
    import functools as _ft

    from repro import telemetry as telemetry_lib
    from repro.core import faults as faults_lib
    from repro.core import federated
    from repro.core import streaming as streaming_lib
    from repro.data import partition, synthetic
    from repro.models import paper_nets
    from repro.telemetry import sinks

    # Pool scales with K: 2K shards x 50 samples over 10 classes.
    imgs, labs = synthetic.generate(
        0, samples_per_class=max(400, k * 10))
    data = partition.partition(
        imgs, labs, seed=1,
        spec=partition.PartitionSpec(num_devices=k, num_shards=2 * k,
                                     shard_size=50, min_shards=1,
                                     max_shards=1))
    mspec = paper_nets.PaperNetSpec(kind="mlp", mlp_hidden=16)
    params = paper_nets.init(jax.random.key(3), mspec)
    # Streaming is on in BOTH arms (the ratio stays a pure telemetry
    # price) so the frame set includes the staleness signal and the
    # profiler smoke sees all four repro/* phases, stream_refresh
    # included.
    fcfg = federated.FLConfig(
        num_rounds=rounds, batch_size=50, learning_rate=0.1,
        stream=streaming_lib.StreamConfig(),
        faults=faults_lib.FaultConfig(drop_prob=0.2, max_retries=2,
                                      reliability_ema=0.3),
        telemetry=telemetry_lib.TelemetryConfig() if enabled else None)
    scfg = scheduler.SchedulerConfig(method="das", n_min=2,
                                     iterations_max=3)
    wcfg = wireless.WirelessConfig()
    net = wireless.sample_network(jax.random.key(0), k, wcfg)
    loss = _ft.partial(paper_nets.loss_fn, spec=mspec)
    ev = _ft.partial(paper_nets.accuracy, spec=mspec)
    sim = federated.make_feel_sim(loss_fn=loss, eval_fn=ev, wcfg=wcfg,
                                  scfg=scfg, fcfg=fcfg,
                                  capacity=data.capacity)
    hists = federated.client_histograms(data, fcfg.num_classes)
    test_x = synthetic.to_float(data.test_images)
    args = (params, data.images, data.labels, data.mask, data.sizes,
            hists, test_x, data.test_labels, net, jax.random.key(7))
    out = sim(*args)
    jax.block_until_ready(out[0])     # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = sim(*args)
        jax.block_until_ready(out[0])
    ms = (time.perf_counter() - t0) / iters / rounds * 1e3
    if enabled and log_path is not None:
        _, metrics, frames = out
        sinks.write_round_frames(
            log_path, frames, metrics=metrics,
            manifest=sinks.run_manifest(fcfg, wcfg, scfg,
                                        extra={"kind": "bench"}))
    if enabled and store_path is not None:
        from repro.telemetry import store as store_lib
        metrics = out[1]
        summary = store_lib.run_summary(
            accuracy=metrics.accuracy, selected=metrics.selected,
            energy=metrics.energy,
            timings={"steady_s_per_round": ms / 1e3})
        store_lib.append_run(store_path, summary, run="telemetry_smoke",
                             configs=(fcfg, wcfg, scfg))
    return ms


def telemetry_rows(quick: bool = True, log_path: str = None,
                   store_path: str = None) -> List[Tuple[str, float, str]]:
    """The ``telemetry/*`` rows: in-scan frame overhead, inert vs
    enabled (the CI telemetry smoke runs these and then feeds
    ``log_path`` to ``python -m repro.telemetry.report``).
    ``store_path`` additionally appends the enabled run's summary to
    the cross-run metrics store (``repro.telemetry.store``)."""
    k = 24 if quick else 100
    ms_off = bench_telemetry(False, k=k)
    ms_on = bench_telemetry(True, k=k, log_path=log_path,
                            store_path=store_path)
    return [
        (f"telemetry/inert/K{k}", round(ms_off, 2),
         "ms_per_round telemetry=None scan_driver"),
        (f"telemetry/enabled/K{k}", round(ms_on, 2),
         "ms_per_round full frame set (scores+sub2+transport+faults)"),
        (f"telemetry/overhead/K{k}",
         round(ms_on / max(ms_off, 1e-9), 3),
         "enabled / inert steady per-round (target <1.10)"),
    ]


def bench_dispatch(cap, k: int = 32, rounds: int = 4,
                   iters: int = 3) -> float:
    """ms per round of the scan driver with a dense-block dispatch cap.

    ``cap=None`` is the masked all-K round body; an integer cap gathers
    the admitted devices into that many trainer lanes (DESIGN.md §11).
    The admitted set is pinned to ``n_fixed = k // 8`` so the two rows
    compare identical round sequences and the ratio is pure dispatch
    win.
    """
    import functools as _ft

    from repro.core import federated
    from repro.data import partition, synthetic
    from repro.models import paper_nets

    imgs, labs = synthetic.generate(0, samples_per_class=400)
    data = partition.partition(
        imgs, labs, seed=1,
        spec=partition.PartitionSpec(num_devices=k, num_shards=2 * k,
                                     shard_size=50, min_shards=1,
                                     max_shards=1))
    mspec = paper_nets.PaperNetSpec(kind="mlp", mlp_hidden=16)
    params = paper_nets.init(jax.random.key(3), mspec)
    fcfg = federated.FLConfig(num_rounds=rounds, batch_size=50,
                              learning_rate=0.1, dispatch_cap=cap)
    scfg = scheduler.SchedulerConfig(method="das", n_min=1,
                                     n_fixed=max(2, k // 8),
                                     iterations_max=3)
    wcfg = wireless.WirelessConfig()
    net = wireless.sample_network(jax.random.key(0), k, wcfg)
    loss = _ft.partial(paper_nets.loss_fn, spec=mspec)
    ev = _ft.partial(paper_nets.accuracy, spec=mspec)
    sim = federated.make_feel_sim(loss_fn=loss, eval_fn=ev, wcfg=wcfg,
                                  scfg=scfg, fcfg=fcfg,
                                  capacity=data.capacity,
                                  eval_every=rounds)
    hists = federated.client_histograms(data, fcfg.num_classes)
    test_x = synthetic.to_float(data.test_images)
    args = (params, data.images, data.labels, data.mask, data.sizes,
            hists, test_x, data.test_labels, net, jax.random.key(7))
    out = sim(*args)
    jax.block_until_ready(out[0])     # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = sim(*args)
        jax.block_until_ready(out[0])
    return (time.perf_counter() - t0) / iters / rounds * 1e3


def _sweep_world():
    """Miniature FEEL world for the engine chunk rows (kept tiny so the
    compile inside the bench stays a few seconds)."""
    import functools

    from repro.core import federated
    from repro.data import partition, synthetic
    from repro.models import paper_nets
    from repro.sweep import grid as sweep_grid

    imgs, labs = synthetic.generate(0, samples_per_class=260)
    data = partition.partition(
        imgs, labs, seed=1,
        spec=partition.PartitionSpec(num_devices=16, num_shards=50,
                                     shard_size=50))
    mspec = paper_nets.PaperNetSpec(kind="mlp", mlp_hidden=16)
    params = paper_nets.init(jax.random.key(3), mspec)
    spec = sweep_grid.SweepSpec(
        fl=federated.FLConfig(num_rounds=3, batch_size=50,
                              learning_rate=0.1),
        sched=scheduler.SchedulerConfig(method="das", n_min=2,
                                        iterations_max=3),
        wireless=wireless.WirelessConfig(),
        scenarios_per_point=4, base_seed=0)
    loss = functools.partial(paper_nets.loss_fn, spec=mspec)
    ev = functools.partial(paper_nets.accuracy, spec=mspec)
    return spec, data, loss, ev, params


def sweep_rows(quick: bool = True) -> List[Tuple[str, float, str]]:
    """``sweep/*`` micro rows: Welford fold + engine chunk latency."""
    from repro.sweep import engine as sweep_engine

    rows: List[Tuple[str, float, str]] = []
    s, r = (8, 16) if quick else (32, 16)
    batch = jax.random.normal(jax.random.key(0), (s, r))
    state = sweep_engine.welford_init((r,))
    fold = jax.jit(sweep_engine.welford_fold)
    state = fold(state, batch)                 # compile
    jax.block_until_ready(state.mean)
    iters = 100
    t0 = time.perf_counter()
    for _ in range(iters):
        state = fold(state, batch)
    jax.block_until_ready(state.mean)
    us = (time.perf_counter() - t0) / iters * 1e6
    rows.append((f"sweep/welford_fold/S{s}xR{r}", round(us, 1),
                 "us_per_chunk_fold"))

    spec, data, loss, ev, params = _sweep_world()
    n_dev = len(jax.devices())
    for mode in ("sharded", "vmap"):
        eng = sweep_engine.SweepEngine(
            spec, data=data, loss_fn=loss, eval_fn=ev,
            init_params=params, use_sharding=(mode == "sharded"))
        point = eng.points[0]
        agg = eng.run_point(point)             # compile + first exec
        jax.block_until_ready(agg["round"]["accuracy"].mean)
        t0 = time.perf_counter()
        agg = eng.run_point(point)
        jax.block_until_ready(agg["round"]["accuracy"].mean)
        ms = (time.perf_counter() - t0) * 1e3
        rows.append((f"sweep/chunk/S{spec.scenarios_per_point}_{mode}",
                     round(ms, 2),
                     f"ms_per_chunk devices={n_dev}"))

    # Compressed-sweep smoke (DESIGN.md §9): one quant-codec grid point
    # through the sharded engine — under the CI sweep step's 4 forced
    # host devices this runs the error-feedback carry and per-device
    # payload pricing inside the real shard_map partitioning.
    import dataclasses

    from repro.core import compression

    cspec = dataclasses.replace(
        spec, fl=dataclasses.replace(
            spec.fl, compression=compression.CompressionConfig(
                codec="quant", bit_width=8)))
    eng = sweep_engine.SweepEngine(
        cspec, data=data, loss_fn=loss, eval_fn=ev, init_params=params)
    point = eng.points[0]
    agg = eng.run_point(point)                 # compile + first exec
    jax.block_until_ready(agg["round"]["accuracy"].mean)
    t0 = time.perf_counter()
    agg = eng.run_point(point)
    jax.block_until_ready(agg["round"]["accuracy"].mean)
    ms = (time.perf_counter() - t0) * 1e3
    rows.append((f"sweep/chunk_compressed/"
                 f"S{cspec.scenarios_per_point}_sharded",
                 round(ms, 2),
                 f"ms_per_chunk codec=quant devices={n_dev}"))

    # Faulty-sweep smoke (DESIGN.md §10): one fault-injected grid point
    # through the sharded engine — under the CI sweep step's 4 forced
    # host devices the per-scenario fault draws, retry pricing and the
    # reliability-EMA carry run inside the real shard_map partitioning.
    from repro.core import faults as faults_lib

    fspec = dataclasses.replace(
        spec, fl=dataclasses.replace(
            spec.fl, faults=faults_lib.FaultConfig(
                drop_prob=0.3, max_retries=2, reliability_ema=0.3)))
    eng = sweep_engine.SweepEngine(
        fspec, data=data, loss_fn=loss, eval_fn=ev, init_params=params)
    point = eng.points[0]
    agg = eng.run_point(point)                 # compile + first exec
    jax.block_until_ready(agg["round"]["accuracy"].mean)
    t0 = time.perf_counter()
    agg = eng.run_point(point)
    jax.block_until_ready(agg["round"]["accuracy"].mean)
    ms = (time.perf_counter() - t0) * 1e3
    rows.append((f"sweep/chunk_faulty/"
                 f"S{fspec.scenarios_per_point}_sharded",
                 round(ms, 2),
                 f"ms_per_chunk drop=0.3 devices={n_dev}"))
    return rows


def run(quick: bool = True) -> List[Tuple[str, float, str]]:
    rows = []
    ks = (50, 100) if quick else (50, 100, 200, 400)
    for k in ks:
        for method in ("das", "abs", "random", "full"):
            us = bench(method, k)
            rows.append((f"sched/{method}/K{k}", round(us, 1),
                         "us_per_decision"))
    for k in ks:
        for stage in ("nested_bisect", "fused_bisect", "pgd",
                      "fused_pgd"):
            us = bench_alloc(stage, k)
            rows.append((f"alloc/{stage}/K{k}", round(us, 1),
                         "us_per_sub2_solve"))
    for k in ks:
        for path in ("ref", "kernel"):
            us = bench_stream(path, k)
            rows.append((f"streaming/{path}/K{k}", round(us, 1),
                         "us_per_refresh"))
    s_batch = 8 if quick else 16
    for path in ("ref", "kernel"):
        us = bench_stream(path, ks[-1], s=s_batch)
        rows.append((f"streaming/{path}_S{s_batch}/K{ks[-1]}",
                     round(us, 1), "us_per_batched_refresh"))
    p_comp = 4096
    for k in ks:
        for path in ("ref", "kernel"):
            us = bench_compress(path, k, p=p_comp)
            rows.append((f"compress/{path}/K{k}", round(us, 1),
                         f"us_per_quant_pass P={p_comp}"))
    us = bench_compress("ref", ks[-1], p=p_comp, mode="topk")
    rows.append((f"compress/ref_topk/K{ks[-1]}", round(us, 1),
                 f"us_per_topk_pass P={p_comp}"))
    for path in ("ref", "kernel"):
        us = bench_compress(path, ks[-1], p=p_comp, s=s_batch)
        rows.append((f"compress/{path}_S{s_batch}/K{ks[-1]}",
                     round(us, 1), "us_per_batched_quant_pass"))
    for profile in ("outage", "straggler"):
        ms = bench_faults(profile)
        rows.append((f"faults/{profile}/K16", round(ms, 2),
                     "ms_per_round scan_driver"))
    k_disp = 32
    ms_masked = bench_dispatch(None, k=k_disp)
    ms_block = bench_dispatch(max(2, k_disp // 8) + 1, k=k_disp)
    rows.append((f"dispatch/masked/K{k_disp}", round(ms_masked, 2),
                 "ms_per_round scan_driver all-K lanes"))
    rows.append((f"dispatch/block/K{k_disp}", round(ms_block, 2),
                 f"ms_per_round cap={max(2, k_disp // 8) + 1} lanes"))
    rows.append((f"dispatch/speedup/K{k_disp}",
                 round(ms_masked / ms_block, 2),
                 "masked / dense-block steady per-round"))
    rows.extend(async_rows(quick))
    rows.extend(telemetry_rows(quick))
    rows.extend(sweep_rows(quick))
    return rows
