"""Scheduler micro-benchmark: jitted DAS/ABS/random decision latency vs K.

Systems-level table (no paper analogue): the per-round scheduling cost a
MEC server (or pod controller) pays.  DAS = iterative Sub1/Sub2 with the
tangent-PGD allocator; everything jit-compiled once per K.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core import diversity, scheduler, wireless


def bench(method: str, k: int, iters: int = 5) -> float:
    wcfg = wireless.WirelessConfig()
    net = wireless.sample_network(jax.random.key(0), k, wcfg)
    gains = wireless.sample_fading(jax.random.key(1), net)
    sizes = jax.random.randint(jax.random.key(2), (k,), 50, 1500)
    hists = jax.random.randint(jax.random.key(3), (k, 10), 0,
                               30).astype(jnp.float32)
    ages = jnp.zeros((k,), jnp.int32)
    idx = diversity.diversity_index(label_hists=hists, data_sizes=sizes,
                                    ages=ages)
    sch = scheduler.SchedulerConfig(method=method, n_min=1,
                                    iterations_max=6)
    res = scheduler.schedule(jax.random.key(4), idx, ages, sizes, gains,
                             net, wcfg, sch)
    jax.block_until_ready(res.alpha)      # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        res = scheduler.schedule(jax.random.key(4), idx, ages, sizes,
                                 gains, net, wcfg, sch)
        jax.block_until_ready(res.alpha)
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = True) -> List[Tuple[str, float, str]]:
    rows = []
    ks = (50, 100) if quick else (50, 100, 200, 400)
    for k in ks:
        for method in ("das", "abs", "random", "full"):
            us = bench(method, k)
            rows.append((f"sched/{method}/K{k}", round(us, 1),
                         "us_per_decision"))
    return rows
