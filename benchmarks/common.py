"""Shared FEEL experiment harness for the paper-figure benchmarks.

Three entry points:

* :func:`run_fl` — builds the synthetic shard-partitioned dataset (paper
  §VI-A protocol), the wireless network, and runs ``num_rounds`` of
  Algorithm 1 under a given scheduling method via the scan-over-rounds
  driver, returning the per-round history (accuracy / energy / time /
  #selected).
* :func:`run_fl_batch` — the Monte-Carlo version: S network/PRNG
  scenarios through ``federated.run_federated_batch`` as ONE compiled
  program, returning per-scenario histories.  Scenario streams are
  fold_in-derived from global indices (``engine.stream_bases``), so
  scenario ``i`` here is the *same* scenario the sweep engine runs.
* :func:`run_fl_sweep` — the production path (DESIGN.md §8): a
  :class:`repro.sweep.SweepSpec` grid over config axes, executed in
  shard_map'd chunks with online Welford aggregation.  Host memory is
  O(R) per grid point regardless of scenario count; the paper-figure
  suites all go through this.

``quick=True`` shrinks the scale (K=40 devices, 300-shard pool, 8 rounds)
so the whole benchmark suite completes on the CPU container; ``--full``
restores the paper's K=100 / 1200x50 / 15 rounds.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import federated, scheduler, wireless
from repro.data import partition, synthetic
from repro.models import paper_nets
from repro.sweep import engine as sweep_engine
from repro.sweep import grid as sweep_grid
from repro.sweep import runner as sweep_runner


@dataclasses.dataclass(frozen=True)
class FLBenchConfig:
    quick: bool = True
    model: str = "mlp"            # mlp | cnn
    method: str = "das"           # das | abs | random | full
    n_fixed: Optional[int] = None
    local_epochs: int = 1
    model_bits: float = 100e3     # s (paper Table I: 100 kbit)
    num_rounds: int = 0           # 0 -> default per quick/full
    seed: int = 0
    reentry: str = "strict"

    @property
    def rounds(self) -> int:
        if self.num_rounds:
            return self.num_rounds
        return 8 if self.quick else 15

    @property
    def num_devices(self) -> int:
        return 40 if self.quick else 100

    @property
    def pspec(self) -> partition.PartitionSpec:
        if self.quick:
            return partition.PartitionSpec(num_devices=40, num_shards=300,
                                           shard_size=50)
        return partition.PartitionSpec()


@functools.lru_cache(maxsize=4)
def _dataset(quick: bool, seed: int):
    spc = 2000 if quick else 6000
    imgs, labs = synthetic.generate(seed, samples_per_class=spc)
    cfg = FLBenchConfig(quick=quick, seed=seed)
    return partition.partition(imgs, labs, seed=seed + 1, spec=cfg.pspec)


def _experiment(cfg: FLBenchConfig):
    data = _dataset(cfg.quick, cfg.seed)
    wcfg = wireless.WirelessConfig(model_bits=cfg.model_bits)
    mspec = paper_nets.PaperNetSpec(kind=cfg.model)
    params = paper_nets.init(jax.random.key(cfg.seed + 11), mspec)
    scfg = scheduler.SchedulerConfig(
        method=cfg.method, n_min=1, n_fixed=cfg.n_fixed,
        iterations_max=6, reentry=cfg.reentry)
    fcfg = federated.FLConfig(
        num_rounds=cfg.rounds, local_epochs=cfg.local_epochs,
        batch_size=50, learning_rate=0.1 if cfg.model == "mlp" else 0.05)
    loss = functools.partial(paper_nets.loss_fn, spec=mspec)
    ev = functools.partial(paper_nets.accuracy, spec=mspec)
    return data, wcfg, params, scfg, fcfg, loss, ev


def run_fl(cfg: FLBenchConfig) -> List[federated.RoundRecord]:
    data, wcfg, params, scfg, fcfg, loss, ev = _experiment(cfg)
    net = wireless.sample_network(jax.random.key(cfg.seed + 7),
                                  data.num_devices, wcfg)
    _, hist = federated.run_federated(
        init_params=params, loss_fn=loss, eval_fn=ev,
        data=data, net=net, wcfg=wcfg, scfg=scfg, fcfg=fcfg,
        key=jax.random.key(cfg.seed + 13))
    return hist


def run_fl_batch(cfg: FLBenchConfig, num_scenarios: int
                 ) -> List[List[federated.RoundRecord]]:
    """S Monte-Carlo scenarios (network realization x PRNG stream) as one
    vmapped scan; returns per-scenario histories.

    Scenario ``i`` derives from its *global index* via fold_in
    (``sweep.engine.stream_bases``), never from ``num_scenarios`` — so
    this unsharded driver path and the chunked/sharded sweep engine
    execute identical scenario populations (parity contract in
    ``tests/test_sweep.py``).
    """
    data, wcfg, params, scfg, fcfg, loss, ev = _experiment(cfg)
    net_base, sim_base = sweep_engine.stream_bases(cfg.seed)
    nets = wireless.sample_networks_indexed(
        net_base, jnp.arange(num_scenarios), data.num_devices, wcfg)
    keys = federated.scenario_keys(sim_base, 0, num_scenarios)
    _, metrics = federated.run_federated_batch(
        init_params=params, loss_fn=loss, eval_fn=ev,
        data=data, nets=nets, wcfg=wcfg, scfg=scfg, fcfg=fcfg, keys=keys)
    return federated.batch_metrics_to_records(metrics)


def _spec_from(wcfg, scfg, fcfg, seed: int, num_scenarios: int,
               axes: Sequence[sweep_grid.Axis],
               chunk_scenarios: int) -> sweep_grid.SweepSpec:
    return sweep_grid.SweepSpec(
        fl=fcfg, sched=scfg, wireless=wcfg, axes=tuple(axes),
        scenarios_per_point=num_scenarios,
        chunk_scenarios=chunk_scenarios, base_seed=seed)


def sweep_spec(cfg: FLBenchConfig, num_scenarios: int,
               axes: Sequence[sweep_grid.Axis] = (),
               chunk_scenarios: int = 0) -> sweep_grid.SweepSpec:
    """SweepSpec over this bench config's base world (axes optional)."""
    _, wcfg, _, scfg, fcfg, _, _ = _experiment(cfg)
    return _spec_from(wcfg, scfg, fcfg, cfg.seed, num_scenarios, axes,
                      chunk_scenarios)


def run_fl_sweep(cfg: FLBenchConfig, num_scenarios: int,
                 axes: Sequence[sweep_grid.Axis] = (),
                 target: float = 0.85, chunk_scenarios: int = 0,
                 use_sharding: bool = True,
                 ckpt_path: Optional[str] = None):
    """Monte-Carlo sweep through the sharded engine (DESIGN.md §8).

    Returns ``[(GridPoint, summary)]`` in grid order, where ``summary``
    maps ``"round.accuracy"``-style names to mean/var/std/min/max/count
    arrays (``sweep.engine.aggregate_summary``) — O(R) per grid point,
    independent of ``num_scenarios``.
    """
    data, wcfg, params, scfg, fcfg, loss, ev = _experiment(cfg)
    spec = _spec_from(wcfg, scfg, fcfg, cfg.seed, num_scenarios, axes,
                      chunk_scenarios)
    return sweep_runner.run_sweep(
        spec, data=data, loss_fn=loss, eval_fn=ev, init_params=params,
        ckpt_path=ckpt_path, target_accuracy=target,
        use_sharding=use_sharding)


def rounds_to_accuracy(hist, target: float) -> int:
    for rec in hist:
        if rec.accuracy == rec.accuracy and rec.accuracy >= target:
            return rec.round + 1
    return -1  # not reached


def totals(hist):
    e = sum(r.energy_total for r in hist)
    t = sum(r.round_time for r in hist)
    n = sum(r.n_selected for r in hist)
    return {"energy_total_j": e, "time_total_s": t,
            "energy_per_device_j": e / max(n, 1),
            "mean_selected": n / len(hist),
            "final_accuracy": hist[-1].accuracy}
