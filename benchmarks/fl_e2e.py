"""End-to-end FEEL simulation throughput: legacy loop vs scan vs batch.

Measures, per device count K:

* ``legacy/invocation`` — one :func:`federated.run_federated_loop` call
  exactly as the repo's sweep harness uses it: every invocation rebuilds
  (and therefore recompiles) the round jit, then dispatches 2 jits and
  >=5 host syncs per round.  This is what a Monte-Carlo sweep actually
  pays per scenario with the legacy driver.
* ``legacy/steady`` — the legacy loop's per-round cost with all jits
  prebuilt and warm (its floor: per-round dispatch + compute).
* ``scan/*`` — the device-resident scan driver: one compile, then whole
  simulations as single dispatches; invocations reuse the compiled sim
  (net/key are traced arguments, so a sweep compiles once).
* ``batch/*`` (at ``batch_devices``) — ``run_federated_batch``: S
  scenarios as one vmapped scan; one compile, one dispatch for the whole
  Monte-Carlo average.
* ``sweep/*`` (same scale) — the sharded sweep engine (``repro.sweep``,
  DESIGN.md §8): the S scenarios in shard_map'd chunks with online
  Welford aggregation, sharded over the present devices vs the plain
  vmap program.  On a 1-device host the two rows measure the same
  compiled partitioning; under forced host devices
  (``XLA_FLAGS=--xla_force_host_platform_device_count=N``) the sharded
  row is the real multi-device path.
* ``compressed/*`` (at K=``batch_devices``) — the compressed-uplink
  subsystem (DESIGN.md §9): the scan driver with ``codec=quant`` at 8
  bits vs ``codec=none`` — invocation latency (the quantize pass rides
  inside the same compiled scan; the flatten/error-feedback carry is
  common to both arms) and the simulated totals.  NB the energy ratio
  is a *joint* outcome, not ~b/32: at K=100 the 4x cheaper uplinks flip
  DAS's admission economics and the selected set grows, so total energy
  can RISE (1.46x measured) while per-device energy falls — see
  EXPERIMENTS.md §Compression.
* ``dispatch/*`` (at K=``batch_devices``, admitted pinned to
  ``n_fixed=15``) — the admitted-set dense-block dispatch (DESIGN.md
  §11): the scan driver with ``dispatch_cap=16`` (train 16 lanes,
  scatter back) vs the masked all-K body, same realized selection.
  The steady-state ratio is the PR's headline: training FLOPs scale
  with the *scheduled* set instead of the population.
* ``phase/*`` — per-phase wall clock of one round's stages (schedule /
  local-train / aggregate / stream-refresh), each as its own warmed
  jit, so perf work can see where the round budget goes instead of
  guessing from end-to-end aggregates.

Timing protocol (fairness): every arm reports ``*_compile_s`` (first
call, includes tracing+XLA compile) and a warm steady/exec number
separately, and every ``speedup``/ratio row says which of the two it is
built from — steady ratios never fold one arm's compile into the other
arm's denominator.  ``legacy_invocation`` is the one deliberate
exception: it measures the legacy driver exactly as the old sweep
harness invoked it (rebuilding the round jit every call), which *is*
that driver's real per-scenario cost.

The legacy driver is measured with the reference Sub2 allocator preset
it shipped with; the scan/batch drivers use ``Sub2Params.fast()`` — the
throughput preset this refactor introduces for simulation sweeps
(allocation within ~1% of the reference objective; see
``core/bandwidth.py``).  A same-preset legacy row (``legacy_fast``) is
reported so the protocol is transparent about how much comes from the
driver vs the preset.

Results go to stdout as CSV rows and to ``BENCH_fl_e2e.json``.  Targets
(ISSUE 1): >=5x per-scenario vs legacy invocations at K=100, >=20x
aggregate at S=16.  Measured numbers on the 2-core CPU container are
recorded as-is — see EXPERIMENTS.md §Perf for the analysis of where the
container falls short of the many-core targets.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core import bandwidth as bw
from repro.core import diversity, federated, scheduler, wireless
from repro.data import partition, synthetic
from repro.models import paper_nets

Row = Tuple[str, float, str]

BENCH_JSON = "BENCH_fl_e2e.json"


@dataclasses.dataclass(frozen=True)
class E2EConfig:
    device_counts: Tuple[int, ...] = (50, 100, 200)
    rounds: int = 8
    batch_scenarios: int = 16
    batch_devices: int = 100
    batch_size: int = 5           # small local batches: simulation regime
    max_shards: int = 1           # one shard per device -> 1 step/round
    mlp_hidden: int = 16
    method: str = "das"
    iterations_max: int = 4
    repeats: int = 3


def _world(k: int, cfg: E2EConfig):
    spc = max(120, (2 * k * 50) // 10 + 50)
    imgs, labs = synthetic.generate(0, samples_per_class=spc)
    pspec = partition.PartitionSpec(num_devices=k, num_shards=2 * k,
                                    shard_size=50, min_shards=1,
                                    max_shards=cfg.max_shards)
    data = partition.partition(imgs, labs, seed=1, spec=pspec)
    wcfg = wireless.WirelessConfig()
    net = wireless.sample_network(jax.random.key(0), k, wcfg)
    mspec = paper_nets.PaperNetSpec(kind="mlp", mlp_hidden=cfg.mlp_hidden)
    params = paper_nets.init(jax.random.key(3), mspec)
    loss = functools.partial(paper_nets.loss_fn, spec=mspec)
    ev = functools.partial(paper_nets.accuracy, spec=mspec)
    fcfg = federated.FLConfig(num_rounds=cfg.rounds,
                              batch_size=cfg.batch_size,
                              learning_rate=0.1)
    return data, net, wcfg, params, loss, ev, fcfg


def _scfg(cfg: E2EConfig, fast: bool) -> scheduler.SchedulerConfig:
    sub2 = bw.Sub2Params.fast() if fast else bw.Sub2Params.reference()
    return scheduler.SchedulerConfig(method=cfg.method, n_min=1,
                                     iterations_max=cfg.iterations_max,
                                     sub2=sub2)


def _median(fn: Callable[[], None], repeats: int) -> float:
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def _bench_single(k: int, cfg: E2EConfig) -> Dict[str, float]:
    data, net, wcfg, params, loss, ev, fcfg = _world(k, cfg)
    rounds = fcfg.num_rounds
    out: Dict[str, float] = {"devices": k, "rounds": rounds}

    # Legacy driver, as shipped (reference allocator, recompiles the
    # round jit inside every invocation).
    for label, fast in (("legacy", False), ("legacy_fast", True)):
        kw = dict(init_params=params, loss_fn=loss, eval_fn=ev, data=data,
                  net=net, wcfg=wcfg, scfg=_scfg(cfg, fast), fcfg=fcfg,
                  key=jax.random.key(4), eval_every=rounds)
        federated.run_federated_loop(**kw)   # warm the global schedule jit
        out[f"{label}_invocation_s"] = _median(
            lambda: federated.run_federated_loop(**kw), cfg.repeats)

    # Legacy steady state: prebuilt jits, per-round dispatch only.
    scfg_ref = _scfg(cfg, False)
    round_fn = federated.make_round_fn(loss, fcfg, data.capacity)
    hists = federated.client_histograms(data, fcfg.num_classes)
    sch = dataclasses.replace(scfg_ref, local_epochs=fcfg.local_epochs)

    def legacy_steady():
        ages = jnp.zeros((k,), jnp.int32)
        p = params
        key = jax.random.key(4)
        for _ in range(rounds):
            key, k_fade, k_sched, k_train = jax.random.split(key, 4)
            index = diversity.diversity_index(
                label_hists=hists, data_sizes=data.sizes, ages=ages,
                weights=fcfg.index_weights, measure=fcfg.measure)
            gains = wireless.sample_fading(k_fade, net)
            res = scheduler.schedule(k_sched, index, ages, data.sizes,
                                     gains, net, wcfg, sch)
            p = round_fn(p, data.images, data.labels, data.mask,
                         data.sizes, res.selected, k_train)
            ages = jnp.where(res.selected > 0.0, 0, ages + 1)
            _ = float(res.round_time), int(jnp.sum(res.selected))
        jax.block_until_ready(jax.tree_util.tree_leaves(p)[0])

    t0 = time.perf_counter()
    legacy_steady()
    out["legacy_steady_compile_s"] = time.perf_counter() - t0
    out["legacy_steady_s"] = _median(legacy_steady, cfg.repeats)

    # Scan driver: compile once, reuse across invocations (net and key
    # are traced arguments — a sweep pays one compile).
    sim = federated.make_feel_sim(
        loss_fn=loss, eval_fn=ev, wcfg=wcfg, scfg=_scfg(cfg, True),
        fcfg=fcfg, capacity=data.capacity, eval_every=rounds)
    test_x = synthetic.to_float(data.test_images)
    args = (params, data.images, data.labels, data.mask, data.sizes,
            hists, test_x, data.test_labels, net, jax.random.key(4))
    t0 = time.perf_counter()
    jax.block_until_ready(sim(*args))
    out["scan_first_call_s"] = time.perf_counter() - t0
    out["scan_invocation_s"] = _median(
        lambda: jax.block_until_ready(sim(*args)), cfg.repeats)
    # Compile/steady split: first call = trace + XLA compile + one warm
    # execution, so the compile share is the difference.
    out["scan_compile_s"] = (out["scan_first_call_s"]
                             - out["scan_invocation_s"])

    out["legacy_rounds_per_s"] = rounds / out["legacy_invocation_s"]
    out["scan_rounds_per_s"] = rounds / out["scan_invocation_s"]
    out["speedup_vs_legacy_invocation"] = (
        out["legacy_invocation_s"] / out["scan_invocation_s"])
    out["speedup_vs_legacy_steady"] = (
        out["legacy_steady_s"] / out["scan_invocation_s"])
    return out


def _bench_batch(cfg: E2EConfig,
                 single: Dict[str, float]) -> Dict[str, float]:
    k, s = cfg.batch_devices, cfg.batch_scenarios
    data, _, wcfg, params, loss, ev, fcfg = _world(k, cfg)
    rounds = fcfg.num_rounds
    nets = wireless.sample_networks(jax.random.key(7), s, k, wcfg)
    keys = jax.random.split(jax.random.key(4), s)
    simb = federated.make_feel_sim_batch(
        loss_fn=loss, eval_fn=ev, wcfg=wcfg, scfg=_scfg(cfg, True),
        fcfg=fcfg, capacity=data.capacity, eval_every=rounds)
    hists = federated.client_histograms(data, fcfg.num_classes)
    test_x = synthetic.to_float(data.test_images)
    args = (params, data.images, data.labels, data.mask, data.sizes,
            hists, test_x, data.test_labels, nets, keys)
    t0 = time.perf_counter()
    jax.block_until_ready(simb(*args))
    first = time.perf_counter() - t0
    exec_s = _median(lambda: jax.block_until_ready(simb(*args)),
                     cfg.repeats)
    legacy_seq = s * single["legacy_invocation_s"]
    return {
        "devices": k, "rounds": rounds, "scenarios": s,
        "batch_first_call_s": first,
        "batch_compile_s": first - exec_s,
        "batch_exec_s": exec_s,
        "scenarios_per_s": s / exec_s,
        "scenario_rounds_per_s": s * rounds / exec_s,
        "legacy_sequential_s": legacy_seq,
        "aggregate_speedup_vs_legacy": legacy_seq / exec_s,
        # Same-preset ratio: legacy invocations with Sub2Params.fast(),
        # i.e. pure driver speedup with the allocator preset held fixed
        # (the row above also banks the reference->fast cheapening).
        "aggregate_speedup_vs_legacy_fast":
            s * single["legacy_fast_invocation_s"] / exec_s,
        "aggregate_speedup_vs_legacy_steady":
            s * single["legacy_steady_s"] / exec_s,
    }


def _bench_sweep(cfg: E2EConfig,
                 single: Dict[str, float]) -> Dict[str, float]:
    """S scenarios through the sweep engine, sharded vs unsharded."""
    from repro.sweep import engine as sweep_engine
    from repro.sweep import grid as sweep_grid

    k, s = cfg.batch_devices, cfg.batch_scenarios
    data, _, wcfg, params, loss, ev, fcfg = _world(k, cfg)
    rounds = fcfg.num_rounds
    spec = sweep_grid.SweepSpec(
        fl=fcfg, sched=_scfg(cfg, True), wireless=wcfg,
        scenarios_per_point=s, chunk_scenarios=0, base_seed=0,
        eval_every=rounds)
    out: Dict[str, float] = {"devices": k, "rounds": rounds,
                             "scenarios": s,
                             "host_devices": len(jax.devices())}
    for mode, sharded in (("sharded", True), ("vmap", False)):
        eng = sweep_engine.SweepEngine(
            spec, data=data, loss_fn=loss, eval_fn=ev,
            init_params=params, use_sharding=sharded)
        point = eng.points[0]
        t0 = time.perf_counter()
        agg = eng.run_point(point)
        jax.block_until_ready(agg["round"]["accuracy"].mean)
        out[f"{mode}_first_call_s"] = time.perf_counter() - t0

        def exec_once(eng=eng, point=point):
            agg = eng.run_point(point)
            jax.block_until_ready(agg["round"]["accuracy"].mean)

        out[f"{mode}_exec_s"] = _median(exec_once, cfg.repeats)
        out[f"{mode}_compile_s"] = (out[f"{mode}_first_call_s"]
                                    - out[f"{mode}_exec_s"])
        out[f"{mode}_scenarios_per_s"] = s / out[f"{mode}_exec_s"]
    out["sharded_vs_vmap"] = out["vmap_exec_s"] / out["sharded_exec_s"]
    out["aggregate_speedup_vs_legacy"] = (
        s * single["legacy_invocation_s"] / out["sharded_exec_s"])
    return out


def _bench_compressed(cfg: E2EConfig) -> Tuple[Dict[str, float], object]:
    """Scan-driver invocations with codec=quant@8 vs codec=none.

    Returns ``(out, metrics_none)`` — the codec=none arm's RoundMetrics
    ride along so ``run`` can append a store summary without re-running
    the sim.
    """
    from repro.core import compression

    k = cfg.batch_devices
    data, net, wcfg, params, loss, ev, fcfg = _world(k, cfg)
    rounds = fcfg.num_rounds
    hists = federated.client_histograms(data, fcfg.num_classes)
    test_x = synthetic.to_float(data.test_images)
    out: Dict[str, float] = {"devices": k, "rounds": rounds}
    totals: Dict[str, Tuple[float, float]] = {}
    metrics_none = None
    for codec in ("none", "quant"):
        fcfg_c = dataclasses.replace(
            fcfg, compression=compression.CompressionConfig(
                codec=codec, bit_width=8))
        sim = federated.make_feel_sim(
            loss_fn=loss, eval_fn=ev, wcfg=wcfg, scfg=_scfg(cfg, True),
            fcfg=fcfg_c, capacity=data.capacity, eval_every=rounds)
        args = (params, data.images, data.labels, data.mask, data.sizes,
                hists, test_x, data.test_labels, net, jax.random.key(4))
        t0 = time.perf_counter()
        _, metrics = sim(*args)
        jax.block_until_ready(metrics.energy_total)
        out[f"{codec}_first_call_s"] = time.perf_counter() - t0
        out[f"{codec}_invocation_s"] = _median(
            lambda: jax.block_until_ready(sim(*args)[1].energy_total),
            cfg.repeats)
        out[f"{codec}_compile_s"] = (out[f"{codec}_first_call_s"]
                                     - out[f"{codec}_invocation_s"])
        totals[codec] = (float(jnp.sum(metrics.energy_total)),
                         float(metrics.accuracy[-1]))
        if codec == "none":
            metrics_none = metrics
    out["energy_none_j"], out["final_acc_none"] = totals["none"]
    out["energy_quant8_j"], out["final_acc_quant8"] = totals["quant"]
    out["energy_ratio_quant8_vs_none"] = (
        out["energy_quant8_j"] / max(out["energy_none_j"], 1e-12))
    out["invocation_overhead_vs_none"] = (
        out["quant_invocation_s"] / out["none_invocation_s"])
    return out, metrics_none


def _bench_dispatch(cfg: E2EConfig, k: int = 0, n_sched: int = 15,
                    n_cap: int = 16) -> Dict[str, float]:
    """Masked all-K scan vs dense-block dispatch at the same selection.

    The scheduler is pinned to ``n_fixed=n_sched`` admitted devices (the
    paper's DAS regime: a small rich subset of a large population) and
    ``dispatch_cap=n_cap >= n_sched`` so no device is capacity-dropped —
    both arms simulate the *identical* round sequence and the ratio is
    pure dispatch win: the vmapped trainer runs ``n_cap`` lanes instead
    of ``K``.
    """
    k = k or cfg.batch_devices
    data, net, wcfg, params, loss, ev, fcfg = _world(k, cfg)
    rounds = fcfg.num_rounds
    scfg = dataclasses.replace(_scfg(cfg, True), n_min=1,
                               n_fixed=n_sched)
    hists = federated.client_histograms(data, fcfg.num_classes)
    test_x = synthetic.to_float(data.test_images)
    out: Dict[str, float] = {"devices": k, "rounds": rounds,
                             "n_scheduled": n_sched,
                             "dispatch_cap": n_cap}
    metrics_by_arm = {}
    for label, fcfg_a in (("masked", fcfg),
                          ("dispatch",
                           dataclasses.replace(fcfg,
                                               dispatch_cap=n_cap))):
        sim = federated.make_feel_sim(
            loss_fn=loss, eval_fn=ev, wcfg=wcfg, scfg=scfg, fcfg=fcfg_a,
            capacity=data.capacity, eval_every=rounds)
        args = (params, data.images, data.labels, data.mask, data.sizes,
                hists, test_x, data.test_labels, net, jax.random.key(4))
        t0 = time.perf_counter()
        _, metrics = sim(*args)
        jax.block_until_ready(metrics.energy_total)
        out[f"{label}_first_call_s"] = time.perf_counter() - t0
        out[f"{label}_steady_s"] = _median(
            lambda: jax.block_until_ready(sim(*args)[1].energy_total),
            cfg.repeats)
        out[f"{label}_compile_s"] = (out[f"{label}_first_call_s"]
                                     - out[f"{label}_steady_s"])
        out[f"{label}_rounds_per_s"] = rounds / out[f"{label}_steady_s"]
        metrics_by_arm[label] = metrics
    # Same simulation on both arms (no capacity drops at cap>=n_fixed):
    # assert it so a parity regression can't masquerade as a speedup.
    import numpy as np
    m_m, m_d = metrics_by_arm["masked"], metrics_by_arm["dispatch"]
    out["parity_ok"] = float(
        np.array_equal(np.asarray(m_m.selected), np.asarray(m_d.selected))
        # equal_nan: non-evaluated rounds hold the NaN sentinel.
        and np.array_equal(np.asarray(m_m.accuracy),
                           np.asarray(m_d.accuracy), equal_nan=True))
    out["dropped_total"] = float(jnp.sum(m_d.n_dropped))
    out["steady_speedup"] = (out["masked_steady_s"]
                             / out["dispatch_steady_s"])
    out["compile_speedup"] = (out["masked_compile_s"]
                              / max(out["dispatch_compile_s"], 1e-9))
    return out


def _bench_phases(cfg: E2EConfig) -> Dict[str, float]:
    """One round's wall clock split into separately jitted+timed stages.

    Each stage is warmed and timed on its own: ``schedule`` (diversity
    index + DAS + Sub2), ``local_train`` (the vmapped masked local-SGD
    over all K), ``local_train_dispatch`` (the same trainer over a
    16-lane dense block, gather+scatter included), ``aggregate``
    (FedAvg over stacked client params) and ``stream_refresh`` (the
    fused arrival->refresh pass).  Stage sums won't exactly reproduce
    the fused scan round (XLA fuses across stages there) — the point is
    the *ratio* between stages, i.e. where optimization effort pays.
    """
    from repro.core import diversity as div_lib
    from repro.core import streaming

    k = cfg.batch_devices
    data, net, wcfg, params, loss, ev, fcfg = _world(k, cfg)
    scfg = dataclasses.replace(_scfg(cfg, True), n_min=1, n_fixed=15)
    sch = dataclasses.replace(scfg, local_epochs=fcfg.local_epochs)
    hists = federated.client_histograms(data, fcfg.num_classes)
    ages = jnp.zeros((k,), jnp.int32)
    gains = wireless.sample_fading(jax.random.key(1), net)
    out: Dict[str, float] = {"devices": k}

    def timed(label, fn, *args):
        jax.block_until_ready(fn(*args))          # compile + warm
        out[f"{label}_s"] = _median(
            lambda: jax.block_until_ready(fn(*args)), cfg.repeats)

    # Phase 1: scheduling (index + Sub1/Sub2 through the jitted entry).
    @jax.jit
    def phase_schedule(key, ages):
        index = div_lib.diversity_index(
            label_hists=hists, data_sizes=data.sizes, ages=ages,
            weights=fcfg.index_weights, measure=fcfg.measure)
        return scheduler.schedule_impl(key, index, ages, data.sizes,
                                       gains, net, wcfg, sch)
    timed("schedule", lambda: phase_schedule(jax.random.key(2), ages))
    res = phase_schedule(jax.random.key(2), ages)
    selected = res.selected

    # Phase 2: masked local training over all K lanes vs the dense
    # block (the tentpole's before/after, isolated from the driver).
    trainer = federated.make_local_trainer(loss, fcfg)
    max_steps = federated._max_local_steps(fcfg, data.capacity)
    train = jax.jit(functools.partial(
        federated._masked_local_train, trainer, max_steps, fcfg))
    timed("local_train",
          lambda: train(params, data.images, data.labels, data.mask,
                        data.sizes, selected, jax.random.key(3))[0])
    idx, sel_eff, _ = federated.dispatch_plan(selected, 16)
    train_d = jax.jit(functools.partial(
        federated._masked_local_train, trainer, max_steps, fcfg))
    timed("local_train_dispatch",
          lambda: train_d(params, data.images, data.labels, data.mask,
                          data.sizes, sel_eff, jax.random.key(3),
                          dispatch_idx=idx)[0])

    # Phase 3: FedAvg aggregation over stacked client params.
    client_params, w = train(params, data.images, data.labels, data.mask,
                             data.sizes, selected, jax.random.key(3))
    agg = jax.jit(functools.partial(federated.fedavg_aggregate,
                                    use_kernel=False))
    timed("aggregate", lambda: agg(client_params, w))

    # Phase 4: streaming refresh (arrival sample + fused stats pass).
    stream = streaming.StreamConfig(process="poisson")
    fcfg_s = dataclasses.replace(fcfg, stream=stream)
    process, size_cap, col = federated._stream_setup(fcfg_s,
                                                     data.capacity)
    st = process.init(jax.random.key(5), hists, stream)
    refresh = jax.jit(lambda key, st, ages: federated._stream_round(
        process, fcfg_s, size_cap, col, key, st, ages)[:3])
    timed("stream_refresh",
          lambda: refresh(jax.random.key(6), st, ages))

    total = sum(out[f"{p}_s"] for p in
                ("schedule", "local_train", "aggregate",
                 "stream_refresh"))
    for p in ("schedule", "local_train", "aggregate", "stream_refresh"):
        out[f"{p}_frac"] = out[f"{p}_s"] / total
    out["local_train_dispatch_speedup"] = (
        out["local_train_s"] / out["local_train_dispatch_s"])
    return out


def dispatch_rows(quick: bool = True) -> List[Row]:
    """Standalone dispatch smoke for CI (``benchmarks.run --only
    dispatch``, run under 4 forced host devices): a small-K masked vs
    dispatched comparison plus a batched dispatch run, so gather/scatter
    regressions in the round body fail fast without paying the full
    fl_e2e suite."""
    cfg = E2EConfig(rounds=3 if quick else 8, repeats=3,
                    batch_devices=32 if quick else 100)
    k = cfg.batch_devices
    d = _bench_dispatch(cfg, k=k, n_sched=max(3, k // 8),
                        n_cap=max(4, k // 8 + 1))
    rows: List[Row] = [
        (f"dispatch/K{k}/steady_speedup", round(d["steady_speedup"], 2),
         f"cap={int(d['dispatch_cap'])} vs masked all-K, "
         f"parity_ok={int(d['parity_ok'])}"),
        (f"dispatch/K{k}/masked_steady_s",
         round(d["masked_steady_s"], 4), "warm scan invocation"),
        (f"dispatch/K{k}/dispatch_steady_s",
         round(d["dispatch_steady_s"], 4), "warm scan invocation"),
    ]
    # Batched dispatch under whatever host devices CI forced: the
    # vmapped gather/scatter path must run and drop deterministically.
    data, _, wcfg, params, loss, ev, fcfg = _world(k, cfg)
    s = 4
    nets = wireless.sample_networks(jax.random.key(7), s, k, wcfg)
    keys = federated.scenario_keys(jax.random.key(4), 0, s)
    fcfg_d = dataclasses.replace(fcfg, dispatch_cap=max(2, k // 16))
    scfg = dataclasses.replace(_scfg(cfg, True), n_min=max(3, k // 8))
    t0 = time.perf_counter()
    _, metrics = federated.run_federated_batch(
        fcfg=fcfg_d, init_params=params, loss_fn=loss, eval_fn=ev,
        data=data, nets=nets, wcfg=wcfg, scfg=scfg, keys=keys)
    jax.block_until_ready(metrics.n_dropped)
    rows.append((f"dispatch/K{k}/batch_S{s}_first_call_s",
                 round(time.perf_counter() - t0, 3),
                 f"dropped_total={int(jnp.sum(metrics.n_dropped))} "
                 f"devices={len(jax.devices())}"))
    return rows


def run(quick: bool = True, store_path: str | None = None) -> List[Row]:
    cfg = E2EConfig(rounds=5 if quick else 15, repeats=5)
    results: Dict[str, object] = {"quick": quick,
                                  "config": dataclasses.asdict(cfg)}
    rows: List[Row] = []
    singles: Dict[int, Dict[str, float]] = {}
    for k in cfg.device_counts:
        r = _bench_single(k, cfg)
        singles[k] = r
        results[f"single_K{k}"] = r
        rows.append((f"fl_e2e/K{k}/legacy_rounds_per_s",
                     round(r["legacy_rounds_per_s"], 2),
                     f"invocation={r['legacy_invocation_s']:.3f}s"))
        rows.append((f"fl_e2e/K{k}/scan_rounds_per_s",
                     round(r["scan_rounds_per_s"], 2),
                     f"compile={r['scan_first_call_s']:.1f}s"))
        rows.append((f"fl_e2e/K{k}/speedup_vs_legacy_invocation",
                     round(r["speedup_vs_legacy_invocation"], 2),
                     "target >=5 at K=100"))
        rows.append((f"fl_e2e/K{k}/speedup_vs_legacy_steady",
                     round(r["speedup_vs_legacy_steady"], 2),
                     "warm scan vs warm legacy floor (steady/steady)"))
        rows.append((f"fl_e2e/K{k}/scan_compile_s",
                     round(r["scan_compile_s"], 2),
                     f"steady={r['scan_invocation_s']:.3f}s "
                     f"(compile reported separately)"))
    b = _bench_batch(cfg, singles[cfg.batch_devices])
    results["batch"] = b
    rows.append((f"fl_e2e/batch_S{cfg.batch_scenarios}/scenarios_per_s",
                 round(b["scenarios_per_s"], 3),
                 f"K={cfg.batch_devices} steady exec"))
    rows.append((f"fl_e2e/batch_S{cfg.batch_scenarios}/compile_s",
                 round(b["batch_compile_s"], 2),
                 f"steady exec={b['batch_exec_s']:.3f}s"))
    rows.append((f"fl_e2e/batch_S{cfg.batch_scenarios}/aggregate_speedup",
                 round(b["aggregate_speedup_vs_legacy"], 2),
                 "steady batch vs sequential legacy invocations "
                 "(legacy recompiles per call by design); target >=20"))
    rows.append((f"fl_e2e/batch_S{cfg.batch_scenarios}/"
                 f"aggregate_speedup_same_preset",
                 round(b["aggregate_speedup_vs_legacy_fast"], 2),
                 "vs sequential legacy_fast invocations (driver only)"))
    rows.append((f"fl_e2e/batch_S{cfg.batch_scenarios}/"
                 f"aggregate_speedup_vs_legacy_steady",
                 round(b["aggregate_speedup_vs_legacy_steady"], 2),
                 "steady vs steady: warm batch exec vs S x warm legacy "
                 "rounds"))
    comp, comp_metrics = _bench_compressed(cfg)
    results["compressed"] = comp
    rows.append((f"fl_e2e/compressed_K{cfg.batch_devices}/"
                 f"energy_ratio_quant8_vs_none",
                 round(comp["energy_ratio_quant8_vs_none"], 4),
                 "joint effect: cheap uplinks can grow the admitted set "
                 "(EXPERIMENTS.md SCompression)"))
    rows.append((f"fl_e2e/compressed_K{cfg.batch_devices}/"
                 f"invocation_overhead",
                 round(comp["invocation_overhead_vs_none"], 3),
                 "quant8 vs codec=none scan: quantize arithmetic only "
                 "(flatten+EF carry is common to both arms)"))
    rows.append((f"fl_e2e/compressed_K{cfg.batch_devices}/"
                 f"final_acc_delta",
                 round(comp["final_acc_quant8"]
                       - comp["final_acc_none"], 4),
                 "quant8 - none at equal rounds"))
    d = _bench_dispatch(cfg)
    results[f"dispatch_K{cfg.batch_devices}"] = d
    rows.append((f"fl_e2e/dispatch_K{cfg.batch_devices}/steady_speedup",
                 round(d["steady_speedup"], 2),
                 f"cap={int(d['dispatch_cap'])} lanes vs masked all-K at "
                 f"admitted={int(d['n_scheduled'])}; steady/steady; "
                 f"target >=2"))
    rows.append((f"fl_e2e/dispatch_K{cfg.batch_devices}/"
                 f"dispatch_rounds_per_s",
                 round(d["dispatch_rounds_per_s"], 2),
                 f"masked={d['masked_rounds_per_s']:.2f} rounds/s; "
                 f"parity_ok={int(d['parity_ok'])}"))
    rows.append((f"fl_e2e/dispatch_K{cfg.batch_devices}/compile_s",
                 round(d["dispatch_compile_s"], 2),
                 f"masked compile={d['masked_compile_s']:.2f}s"))
    ph = _bench_phases(cfg)
    results[f"phases_K{cfg.batch_devices}"] = ph
    for p in ("schedule", "local_train", "aggregate", "stream_refresh"):
        rows.append((f"fl_e2e/phase_K{cfg.batch_devices}/{p}_ms",
                     round(1e3 * ph[f"{p}_s"], 3),
                     f"{100 * ph[f'{p}_frac']:.1f}% of stage sum"))
    rows.append((f"fl_e2e/phase_K{cfg.batch_devices}/"
                 f"local_train_dispatch_ms",
                 round(1e3 * ph["local_train_dispatch_s"], 3),
                 f"{ph['local_train_dispatch_speedup']:.2f}x vs masked "
                 f"all-K stage"))
    sw = _bench_sweep(cfg, singles[cfg.batch_devices])
    results["sweep"] = sw
    rows.append((f"fl_e2e/sweep_S{cfg.batch_scenarios}/"
                 f"sharded_scenarios_per_s",
                 round(sw["sharded_scenarios_per_s"], 3),
                 f"engine, devices={int(sw['host_devices'])}"))
    rows.append((f"fl_e2e/sweep_S{cfg.batch_scenarios}/sharded_vs_vmap",
                 round(sw["sharded_vs_vmap"], 2),
                 "sweep engine shard_map vs plain vmap exec"))
    rows.append((f"fl_e2e/sweep_S{cfg.batch_scenarios}/"
                 f"aggregate_speedup",
                 round(sw["aggregate_speedup_vs_legacy"], 2),
                 "vs sequential legacy invocations"))
    with open(BENCH_JSON, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    rows.append(("fl_e2e/json_written", 1.0, BENCH_JSON))
    if store_path is not None:
        # Cross-run history (repro.telemetry.store): learning outcome
        # from the codec=none sim + the K=batch_devices single-driver
        # timings.  The regression gate compares this record against
        # the committed CI baseline.
        from repro.telemetry import store as store_lib
        single = singles[cfg.batch_devices]
        summary = store_lib.run_summary(
            accuracy=comp_metrics.accuracy,
            selected=comp_metrics.selected,
            energy=comp_metrics.energy,
            timings={
                "steady_s_per_round":
                    single["scan_invocation_s"] / cfg.rounds,
                "compile_s": single["scan_compile_s"],
            })
        store_lib.append_run(store_path, summary, run="fl_e2e",
                             configs=(cfg,))
        rows.append(("fl_e2e/store_appended", 1.0, store_path))
    return rows


if __name__ == "__main__":
    for row in run():
        print(f"{row[0]},{row[1]},{row[2]}")
