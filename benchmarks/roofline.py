"""Roofline analysis from the dry-run records (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.  Terms per (arch x shape), single-pod mesh:

  compute    = HLO_FLOPs / (chips x peak)        [per-device HLO -> /chip]
  memory     = HLO_bytes / (chips x HBM_bw)
  collective = collective_bytes / (chips x link_bw)

``cost_analysis`` is per-*device* program, so terms divide by one chip's
rates directly.  Depth-corrected values (scan-over-layers; see dryrun
docstring) are used when present.  sLSTM trip-count correction: xlstm
pairs multiply the scanned sLSTM body by seq_len analytically (flagged in
the notes column).

MODEL_FLOPS = 6 * N_active * D tokens (training; 2ND for single-token
decode) gives the useful-compute ratio.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List

from repro import configs
from repro.configs import shapes as shapes_lib
from repro.models import transformer

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link


def model_flops(arch: str, shape: shapes_lib.InputShape) -> float:
    cfg = configs.get(arch)
    n_active = transformer.active_param_count(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train"
                                   else (shape.seq_len
                                         if shape.kind == "prefill"
                                         else 1))
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def analyze(records: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    out = []
    for rec in records:
        if rec.get("skipped") or rec.get("error"):
            continue
        if rec.get("num_devices") != 256:      # roofline = single pod
            continue
        arch, sname = rec["arch"], rec["shape"]
        shape = shapes_lib.get_shape(sname)
        cost = rec.get("cost_corrected") or rec["cost"]
        coll = rec.get("collectives_corrected_bytes",
                       rec["collectives"]["total_bytes"])
        t_compute = cost["flops"] / PEAK_FLOPS
        t_memory = cost["bytes"] / HBM_BW
        t_coll = coll / ICI_BW
        terms = {"compute": t_compute, "memory": t_memory,
                 "collective": t_coll}
        dominant = max(terms, key=terms.get)
        mf = model_flops(arch, shape)
        mf_per_dev = mf / 256.0
        ratio = mf_per_dev / max(cost["flops"], 1.0)
        out.append({
            "arch": arch, "shape": sname,
            "compute_s": t_compute, "memory_s": t_memory,
            "collective_s": t_coll, "dominant": dominant,
            "model_flops_per_dev": mf_per_dev,
            "useful_ratio": ratio,
            "memory_gb": (rec["memory"].get("temp_size_in_bytes", 0)
                          + rec["memory"].get("argument_size_in_bytes", 0)
                          ) / 1e9,
            "corrected": "cost_corrected" in rec,
        })
    return out


def table(rows: List[Dict[str, Any]]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'dominant':>10s} "
           f"{'useful':>7s} {'mem_GB':>7s}")
    lines = [hdr]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']:10.4f} "
            f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
            f"{r['dominant']:>10s} {r['useful_ratio']:7.3f} "
            f"{r['memory_gb']:7.1f}")
    return "\n".join(lines)


def main(path: str = "dryrun_results.json") -> List[Dict[str, Any]]:
    with open(path) as f:
        records = json.load(f)
    rows = analyze(records)
    print(table(rows))
    return rows


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json")
