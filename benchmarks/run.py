"""Benchmark orchestrator: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig2,...]
                                            [--host-tuned]

Prints ``name,value,derived`` CSV rows.  Default (quick) mode shrinks the
FL scale so the whole suite runs on the CPU container; ``--full`` is the
paper's K=100 / 1200x50-shard / 15-round configuration.

Suites: fig2 (limited devices, scenario-averaged via the vmapped batch
driver), fig3 (local epochs), fig45 (model size), fig67 (energy/time vs
baseline+ABS), divergence (selected-fraction probe), fl_e2e (legacy loop
vs scan vs batch vs sharded-sweep simulation throughput; writes
BENCH_fl_e2e.json), sched (scheduler latency, includes sweep/* rows),
sweep (sweep engine rows only — the CI shard_map smoke), dispatch
(dense-block dispatch smoke — the CI gather/scatter regression guard),
async (event-driver smoke — sync scan vs event-scan sync limit vs
buffered async under diurnal churn), telemetry (in-scan frame overhead,
inert vs enabled; ``--telemetry-log`` sinks the enabled run's JSONL
round-event log for ``python -m repro.telemetry.report``),
kernels (Pallas micro), roofline (requires dryrun_results.json from
repro.launch.dryrun).

``--profile DIR`` wraps the selected suites in ``jax.profiler.trace``
and emits a ``profile/phases_seen`` row naming which ``repro/*`` named
scopes (schedule, local_train, aggregate, stream_refresh) the drivers
entered — the CI profiler smoke asserts all four.

``--host-tuned`` re-execs the process with the host-tuning idioms the
related training repos bake into their launchers (SNIPPETS.md §1-2):
``LD_PRELOAD`` tcmalloc when the library is present on the box,
``--xla_force_host_platform_device_count=<cores>`` so the sharded sweep
rows get real host devices, and quieted TF logging.  Env applied before
jax is imported (the re-exec happens before any suite import); a guard
variable prevents exec loops, and existing ``XLA_FLAGS``/``LD_PRELOAD``
settings are extended, never clobbered.
"""

from __future__ import annotations

import argparse
import glob
import os
import sys
import time

_TUNED_GUARD = "REPRO_HOST_TUNED"

_TCMALLOC_GLOBS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc*.so*",
    "/usr/lib/*/libtcmalloc*.so*",
    "/usr/lib64/libtcmalloc*.so*",
    "/usr/local/lib/libtcmalloc*.so*",
)


def _host_tuned_env() -> dict:
    """Tuned environment for the re-exec (pure; tested separately)."""
    env = dict(os.environ)
    env[_TUNED_GUARD] = "1"
    env.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")
    cores = os.cpu_count() or 1
    flags = env.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        flags = (f"{flags} " if flags else "") + \
            f"--xla_force_host_platform_device_count={cores}"
        env["XLA_FLAGS"] = flags
    tcmalloc = sorted(p for pat in _TCMALLOC_GLOBS
                      for p in glob.glob(pat))
    if tcmalloc and "tcmalloc" not in env.get("LD_PRELOAD", ""):
        preload = env.get("LD_PRELOAD", "")
        env["LD_PRELOAD"] = (f"{preload} {tcmalloc[0]}".strip())
        # Silence tcmalloc's large-alloc spam for the big scan buffers.
        env.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
                       "10000000000")
    return env


def _reexec_host_tuned() -> None:
    env = _host_tuned_env()
    has_tcm = "tcmalloc" in env.get("LD_PRELOAD", "")
    print(f"# host-tuned re-exec: devices={os.cpu_count() or 1}, "
          f"tcmalloc={'yes' if has_tcm else 'absent'}",
          file=sys.stderr)
    os.execve(sys.executable,
              [sys.executable, "-m", "benchmarks.run"] + sys.argv[1:],
              env)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="explicit quick mode (the default; used by the "
                         "CI smoke step)")
    ap.add_argument("--only", default="")
    ap.add_argument("--dryrun-json", default="dryrun_results.json")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="wrap the selected suites in jax.profiler.trace"
                         "(DIR) and report which repro/* named phases "
                         "(schedule, local_train, aggregate, "
                         "stream_refresh) were entered")
    ap.add_argument("--telemetry-log", default=None, metavar="PATH",
                    help="with the telemetry suite: sink the enabled "
                         "run's round frames to this JSONL file (the CI "
                         "report smoke reads it back)")
    ap.add_argument("--metrics-store", default=None, metavar="PATH",
                    help="append run summaries (final acc, energy, "
                         "fairness, timings) to this cross-run JSONL "
                         "store (repro.telemetry.store) — the "
                         "regression-gate input")
    ap.add_argument("--host-tuned", action="store_true",
                    help="re-exec with tcmalloc LD_PRELOAD (if present) "
                         "and one forced XLA host device per core "
                         "before importing jax")
    args = ap.parse_args()
    if args.full and args.quick:
        ap.error("--full and --quick are mutually exclusive")
    if args.host_tuned and os.environ.get(_TUNED_GUARD) != "1":
        _reexec_host_tuned()
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    def want(name: str) -> bool:
        return only is None or name in only

    print("name,value,derived")
    t0 = time.time()

    profile_ctx = None
    if args.profile is not None:
        import jax
        profile_ctx = jax.profiler.trace(args.profile)
        profile_ctx.__enter__()

    def run_suites() -> None:
        if want("fig2") or want("fig3") or want("fig45") or want("fig67") \
                or want("divergence"):
            from benchmarks import paper_figs
            if want("fig2"):
                for r in paper_figs.fig2_limited_devices(quick):
                    _emit(r)
            if want("fig3"):
                for r in paper_figs.fig3_local_epochs(quick):
                    _emit(r)
            if want("fig45"):
                for r in paper_figs.fig45_model_size(quick):
                    _emit(r)
            if want("fig67"):
                for r in paper_figs.fig67_energy_time(quick):
                    _emit(r)
            if want("divergence"):
                for r in paper_figs.selection_fraction_sweep(quick):
                    _emit(r)

        if want("fl_e2e"):
            from benchmarks import fl_e2e
            for r in fl_e2e.run(quick, store_path=args.metrics_store):
                _emit(r)

        if want("sched"):
            from benchmarks import sched_micro
            for r in sched_micro.run(quick):
                _emit(r)
        elif want("sweep"):
            # Standalone sweep smoke (CI runs this under
            # XLA_FLAGS=--xla_force_host_platform_device_count=4 so the
            # sharded row exercises the real shard_map partitioning).
            from benchmarks import sched_micro
            for r in sched_micro.sweep_rows(quick):
                _emit(r)

        if want("async") and not want("sched"):
            # Standalone event-driver smoke (CI runs this under 4 forced
            # host devices): sync scan vs event-scan sync limit vs full
            # buffered async, without paying the full sched suite.
            from benchmarks import sched_micro
            for r in sched_micro.async_rows(quick):
                _emit(r)

        if want("telemetry") and not want("sched"):
            # Standalone telemetry smoke (CI runs this under 4 forced
            # host devices): inert vs enabled frame overhead, plus the
            # enabled run's JSONL round-event log for the report-CLI
            # check.
            from benchmarks import sched_micro
            for r in sched_micro.telemetry_rows(
                    quick, log_path=args.telemetry_log,
                    store_path=args.metrics_store):
                _emit(r)

        if want("dispatch") and not want("fl_e2e"):
            # Standalone dispatch smoke (CI runs this under 4 forced
            # host devices): masked vs dense-block scan + a batched
            # dispatched run, without paying the full fl_e2e suite.
            from benchmarks import fl_e2e
            for r in fl_e2e.dispatch_rows(quick):
                _emit(r)

        if want("kernels"):
            from benchmarks import kernel_bench
            for r in kernel_bench.run(quick):
                _emit(r)

        if want("roofline"):
            if os.path.exists(args.dryrun_json):
                from benchmarks import roofline
                for row in roofline.analyze(
                        __import__("json").load(open(args.dryrun_json))):
                    _emit((f"roofline/{row['arch']}/{row['shape']}/"
                           f"{row['dominant']}",
                           round(max(row['compute_s'], row['memory_s'],
                                     row['collective_s']), 4),
                           f"useful={row['useful_ratio']:.3f}"))
            else:
                print(f"# roofline skipped: {args.dryrun_json} not found "
                      f"(run repro.launch.dryrun first)", file=sys.stderr)

    # try/finally so a suite raising mid-run still finalizes the
    # profiler trace directory and emits phases_seen — a half-written
    # trace dir with no closing __exit__ is unreadable by the viewer.
    try:
        run_suites()
    finally:
        if profile_ctx is not None:
            profile_ctx.__exit__(None, None, None)
            from repro import telemetry
            seen = sorted(telemetry.seen_phases())
            _emit(("profile/phases_seen", len(seen),
                   "named_scopes " + "+".join(seen) if seen else
                   "named_scopes none"))
            print(f"# profiler trace written to {args.profile}",
                  file=sys.stderr)

    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


def _emit(row) -> None:
    name, value, derived = row
    print(f"{name},{value},{derived}")
    sys.stdout.flush()


if __name__ == "__main__":
    main()
