"""Benchmark orchestrator: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig2,...]

Prints ``name,value,derived`` CSV rows.  Default (quick) mode shrinks the
FL scale so the whole suite runs on the CPU container; ``--full`` is the
paper's K=100 / 1200x50-shard / 15-round configuration.

Suites: fig2 (limited devices, scenario-averaged via the vmapped batch
driver), fig3 (local epochs), fig45 (model size), fig67 (energy/time vs
baseline+ABS), divergence (selected-fraction probe), fl_e2e (legacy loop
vs scan vs batch vs sharded-sweep simulation throughput; writes
BENCH_fl_e2e.json), sched (scheduler latency, includes sweep/* rows),
sweep (sweep engine rows only — the CI shard_map smoke), kernels
(Pallas micro), roofline (requires dryrun_results.json from
repro.launch.dryrun).
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="explicit quick mode (the default; used by the "
                         "CI smoke step)")
    ap.add_argument("--only", default="")
    ap.add_argument("--dryrun-json", default="dryrun_results.json")
    args = ap.parse_args()
    if args.full and args.quick:
        ap.error("--full and --quick are mutually exclusive")
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    def want(name: str) -> bool:
        return only is None or name in only

    print("name,value,derived")
    t0 = time.time()

    if want("fig2") or want("fig3") or want("fig45") or want("fig67") \
            or want("divergence"):
        from benchmarks import paper_figs
        if want("fig2"):
            for r in paper_figs.fig2_limited_devices(quick):
                _emit(r)
        if want("fig3"):
            for r in paper_figs.fig3_local_epochs(quick):
                _emit(r)
        if want("fig45"):
            for r in paper_figs.fig45_model_size(quick):
                _emit(r)
        if want("fig67"):
            for r in paper_figs.fig67_energy_time(quick):
                _emit(r)
        if want("divergence"):
            for r in paper_figs.selection_fraction_sweep(quick):
                _emit(r)

    if want("fl_e2e"):
        from benchmarks import fl_e2e
        for r in fl_e2e.run(quick):
            _emit(r)

    if want("sched"):
        from benchmarks import sched_micro
        for r in sched_micro.run(quick):
            _emit(r)
    elif want("sweep"):
        # Standalone sweep smoke (CI runs this under
        # XLA_FLAGS=--xla_force_host_platform_device_count=4 so the
        # sharded row exercises the real shard_map partitioning).
        from benchmarks import sched_micro
        for r in sched_micro.sweep_rows(quick):
            _emit(r)

    if want("kernels"):
        from benchmarks import kernel_bench
        for r in kernel_bench.run(quick):
            _emit(r)

    if want("roofline"):
        if os.path.exists(args.dryrun_json):
            from benchmarks import roofline
            for row in roofline.analyze(
                    __import__("json").load(open(args.dryrun_json))):
                _emit((f"roofline/{row['arch']}/{row['shape']}/"
                       f"{row['dominant']}",
                       round(max(row['compute_s'], row['memory_s'],
                                 row['collective_s']), 4),
                       f"useful={row['useful_ratio']:.3f}"))
        else:
            print(f"# roofline skipped: {args.dryrun_json} not found "
                  f"(run repro.launch.dryrun first)", file=sys.stderr)

    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


def _emit(row) -> None:
    name, value, derived = row
    print(f"{name},{value},{derived}")
    sys.stdout.flush()


if __name__ == "__main__":
    main()
